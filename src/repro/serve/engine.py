"""Serving tier: continuous batching with a CARE request dispatcher.

This is the paper's own setting at the systems level: requests are jobs,
replica groups are servers, and the front-end dispatcher routes by
JSAQ over *approximated* per-replica queue occupancy.  Replicas mirror the
dispatcher's emulation (they know both their true state and, because
updates are deterministic, exactly what the dispatcher believes -- the
paper's information asymmetry) and send a correction message only when the
trigger of the shared protocol core (:mod:`repro.core.care.comm`, the same
RT/DT/ET/hybrid implementation the slotted and MoE-dispatch simulators use)
fires -- so dispatcher<->replica control traffic is sparse even at high
request rates.

The engine is discrete-time (slot = one decode iteration across replicas),
matching the paper's simulation setting; each replica runs continuous
batching with a fixed decode-slot budget, admitting queued requests as
slots free up.  Completion requires ``decode_len`` iterations after a
prefill cost proportional to the prompt.

Two interchangeable execution paths share one workload and one semantics:

* **numpy reference** (:class:`CareDispatcher` + :func:`run_serving_sim`)
  -- a host-side per-slot loop.  Replica state is vectorised (decode slots
  are a ``(replicas, decode_slots)`` remaining-work matrix, pending
  requests live in per-replica circular rings) but slots advance in
  Python.  This is the *pluggable* path: ``model_fn`` hooks a real
  ``decode_step`` closure into every slot (examples/serve_care.py), and it
  is the golden reference the jax path is tested against bit for bit.
* **jax engine** (:func:`serve_one` / :func:`serve_grid`) -- the same
  dynamics as a jitted fixed-horizon ``lax.scan`` with the static/traced
  split of the slotted tier: :class:`EngineStatic` fixes shapes and code
  paths (replicas, decode_slots, queue_cap, the padded scan length and
  per-slot arrival-lane width, the comm *kind*), :class:`EngineScenario`
  is a registered pytree of traced operands (trigger thresholds,
  ``msr_drain``, the effective ``horizon``).  ``serve_grid`` runs a whole
  regime ladder x seed sweep as **one compiled program** -- vmap over the
  flattened (cell x seed) axis, shard_map across local devices with
  wrap-around padding -- which is what scales the replica step past 1k
  replicas (``bench_serving``'s ``serve/replicas1024`` row).

The routing-policy axis (PR 5) lifts the hard-coded JSAQ into a static
``policy`` kind -- ``jsaq`` / ``sqd`` (SQ(d)) / ``rr`` (round robin) /
``drain`` (drain-time-aware JSAQ under heterogeneous per-replica
``decode_rates``) -- selected at trace time like the comm kind, so the
full (policy x comm) matrix of the paper's composition claim runs on both
backends.  The rates themselves are traced :class:`EngineScenario`
operands (a heterogeneous-speed ladder shares one compiled program);
replicas decode by the deterministic credit schedule of
:func:`repro.core.care.workload.service_units` and the drain-time score
reuses :func:`repro.core.care.routing.expected_drain_slots`, both shared
with the slotted tier.

Bit-identical equivalence is by construction: the workload (per-slot
arrival counts, per-request prefill/decode sizes, routing tie-break and
SQ(d) subset uniforms) is pre-sampled host-side by :func:`sample_workload`
into a :class:`ServeWorkload` both paths consume.  Arrival lanes are
padded to ``EngineStatic.max_arrivals`` with an active mask (exactly like
the padded horizon), tie-break/subset uniforms are float32 so both
backends truncate to the same ranks, and the emulated occupancy is
carried in float32 on *both* backends (the reference dispatcher switched
from float64 in PR 5 -- exact for the historical dyadic drains), so every
drain and score product is the same IEEE single-precision op and the
guarantee covers non-dyadic ``decode_rates`` too.

RNG streams (re-keyed in PR 4): the workload stream and the dispatcher's
tie-break stream are split with ``np.random.SeedSequence(seed).spawn(2)``
so arrival randomness and routing randomness are independent -- the old
engine seeded both from ``default_rng(seed)``, correlating them.  PR 5
appends a third child stream for the SQ(d) subset uniforms;
``SeedSequence`` spawning is prefix-stable, so the first two streams (and
every pre-PR 5 golden) are unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Literal, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.care import comm as comm_lib
from repro.core.care import metrics as metrics_lib
from repro.core.care import routing as routing_lib
from repro.core.care import workload as workload_lib
from repro.kernels import ops as kernel_ops

# The serving tier's routing-policy suite (paper Sec 2.1.4 restated for
# continuous batching).  All policies consume the same state vector JSAQ
# does -- the dispatcher's approximated occupancy, or the true occupancy
# under comm="exact" -- so the policy axis composes with every comm kind:
#
# * ``jsaq``  -- join the shortest (approximated) queue (the default).
# * ``sqd``   -- SQ(d): sample ``sqd`` distinct replicas from pre-drawn
#   uniforms, join the shortest among them.
# * ``rr``    -- round robin, deterministic cyclic assignment.
# * ``drain`` -- drain-time-aware JSAQ: minimise the expected drain time
#   ``occ_i * E[S] / r_i`` (``routing.expected_drain_slots``) under
#   heterogeneous per-replica ``decode_rates``; reduces to JSAQ when the
#   rates are uniform (scaling by one positive constant is
#   argmin-invariant, with an identical f32 tie set).
# * ``jiq`` / ``hsq`` -- the *pull* (server-initiated) family: replicas
#   push tokens through the matching comm kind (``comm`` must equal the
#   policy -- the token channel is the policy's other half) and the
#   dispatcher routes to the replica holding the most tokens, degrading
#   to a uniform tie-broken fallback when the pool is empty.  JIQ tokens
#   mark idle replicas; hyper-scalable-JSQ tokens carry the headroom
#   below the threshold ``x``, refreshed at least every ``rt_period``
#   slots.  Token traffic is billed on the same wire as push updates
#   (evaluate -> net_step), so the message-rate axis stays honest.
ServePolicy = Literal["jsaq", "sqd", "rr", "drain", "jiq", "hsq"]

# Pull policies: route on the dispatcher-side token pool, not a queue
# vector (mirrors routing.PULL_POLICIES for the slotted tier).
PULL_POLICIES = routing_lib.PULL_POLICIES

# Pre-drawn subset-uniform lane width of ServeWorkload.sub_u: SQ(d) cells
# need d <= SQD_MAX.  Fixed so cells differing only in policy / d share
# one workload stream (the paper's comparison method).
SQD_MAX = 8


def mean_decode_rate(decode_rates: Optional[Sequence[float]]) -> float:
    """Mean per-replica decode rate: the capacity multiplier of a profile.

    The single implementation behind every workload-stream key
    (:meth:`ServeConfig.workload_key`, :func:`run_serving_sim`, tests):
    the cached stream is keyed on this value, so all consumers must derive
    it identically or the two backends would sample different workloads.
    """
    if decode_rates is None:
        return 1.0
    return float(np.mean(np.asarray(decode_rates, np.float64)))


@dataclasses.dataclass
class Request:
    rid: int
    arrival: int
    prefill_cost: int  # slots of prefill work
    decode_len: int  # decode iterations to complete
    started: int = -1
    finished: int = -1


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_replicas: int = 8
    decode_slots: int = 16  # concurrent sequences per replica
    et_x: int = 4  # ET threshold on queue-occupancy error
    comm: str = "et"  # "et" | "dt" | "rt" | "et_rt" | "exact" | "jiq" | "hsq"
    dt_x: int = 4
    rt_period: int = 16
    msr_drain: float = 1.0  # emulated completions per slot per busy replica
    policy: ServePolicy = "jsaq"
    sqd: int = 2  # subset size of the "sqd" policy
    # Per-replica decode speeds in work units per decode iteration; None =
    # homogeneous unit rates.  Realised by the deterministic credit
    # schedule of workload.service_units, mirrored by the MSR drain.
    decode_rates: Optional[Tuple[float, ...]] = None
    # Mean request work components; the "drain" policy's E[S] term.
    mean_prefill: float = 4.0
    mean_decode: float = 64.0
    # Tie-break mode: False = pre-drawn f32-uniform rank (the historical
    # convention), True = lowest index (the Pallas kernel convention --
    # see kernels/jsaq_route.py).
    deterministic_ties: bool = False
    # Control plane (fault-injection layer; see comm.py).  network="net"
    # routes every replica->dispatcher update through comm.net_step;
    # fault runs the crash/recovery or transient-slowdown replica process.
    network: str = "none"  # "none" | "net"
    net_delay: int = 0
    net_jitter: int = 0
    net_drop: float = 0.0
    suspect_age: int = 0  # staleness bound in slots (0 = no suspect masking)
    # Wire transport (network="net" only): "fire_forget" is the historical
    # one-shot path; "ack" runs the reliable transport of
    # comm.net_step_ack (timeout/retransmit/backoff + keepalives).
    transport: str = "fire_forget"  # "fire_forget" | "ack"
    ack_timeout: int = 0  # slots a sender waits for an ack (>= 1 under ack)
    backoff_base: float = 1.0  # timeout multiplier per retransmit (>= 1)
    max_retries: int = 0  # retransmits before an update is abandoned
    ka_period: int = 0  # server keepalive period in slots (0 = none)
    fault: str = "none"  # "none" | "crash" | "slow"
    crash_rate: float = 0.0
    recover_rate: float = 0.0
    slow_factor: float = 1.0

    def comm_config(self) -> comm_lib.CommConfig:
        """This tier's trigger parameters in shared-core terms."""
        if self.comm == "et":
            return comm_lib.CommConfig(kind="et", x=self.et_x)
        if self.comm == "dt":
            return comm_lib.CommConfig(kind="dt", x=self.dt_x)
        if self.comm == "rt":
            return comm_lib.CommConfig(kind="rt", rt_period=self.rt_period)
        if self.comm == "et_rt":
            return comm_lib.CommConfig(
                kind="et_rt", x=self.et_x, rt_period=self.rt_period
            )
        if self.comm == "exact":
            return comm_lib.CommConfig(kind="exact")
        if self.comm == "jiq":
            return comm_lib.CommConfig(kind="jiq")
        if self.comm == "hsq":
            # hsq reuses the ET threshold as the queue threshold and the
            # RT period as the token-refresh period (both traced knobs).
            return comm_lib.CommConfig(
                kind="hsq", x=self.et_x, rt_period=self.rt_period
            )
        raise ValueError(f"unknown comm mode: {self.comm}")


# ---------------------------------------------------------------------------
# Grid-facing configuration: one serving cell = static structure + scenario.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One serving grid cell as the user sees it (hashable).

    Splits into the two halves the compiled program takes:
    :meth:`static_part` (shapes + comm kind -- jit specialises on it) and
    :meth:`scenario` (traced operands).  ``load`` / ``mean_prefill`` /
    ``mean_decode`` parameterise the *host-side* workload sampler (they
    never enter the traced program; the sampled arrays do), while ``x`` /
    ``rt_period`` / ``msr_drain`` are genuinely traced -- an ET-x ladder
    shares one compiled program.
    """

    replicas: int = 8
    decode_slots: int = 16
    slots: int = 20_000
    load: float = 0.9
    comm: str = "et"  # "et" | "dt" | "rt" | "et_rt" | "exact" | "jiq" | "hsq"
    x: float = 4.0  # ET/DT threshold (traced)
    rt_period: int = 16
    msr_drain: float = 1.0
    mean_prefill: int = 4
    mean_decode: int = 64
    queue_cap: int = 512  # per-replica pending ring capacity (jax path)
    policy: ServePolicy = "jsaq"
    sqd: int = 2  # subset size of the "sqd" policy (static; <= SQD_MAX)
    # Per-replica decode speeds (hashable tuple; length == replicas).  None
    # = homogeneous unit rates.  The rates are *traced* EngineScenario
    # operands (a heterogeneous-speed ladder shares one compiled program);
    # only their presence is structural (EngineStatic.use_rates).
    decode_rates: Optional[Tuple[float, ...]] = None
    max_slots: Optional[int] = None  # padded scan length (>= slots)
    # Padded arrival-lane width; 0 = derive from the sampled batch.  Pin it
    # (e.g. to the maximum over every seed set a benchmark will submit) so
    # repeat invocations reuse one compiled shape.
    max_arrivals: int = 0
    # Routing engine for the within-slot arrival-lane loop: "dense" (the
    # golden lax.scan lane body) or "pallas" (the fused
    # kernels/jsaq_route.serve_route_pallas kernel; requires policy
    # "jsaq" and deterministic_ties).  Tie-break mode as in EngineConfig.
    route_backend: str = "dense"
    deterministic_ties: bool = False
    # Control plane (fault-injection layer; see comm.py).  The *kinds*
    # are static (trace-time code paths); every numeric knob is a traced
    # EngineScenario operand, so a delay x drop ladder shares one
    # compiled program.
    network: str = "none"  # "none" | "net"
    net_delay: int = 0
    net_jitter: int = 0
    net_drop: float = 0.0
    suspect_age: int = 0
    # Wire transport: the *kind* is static ("fire_forget" keeps the
    # historical one-shot wire, structurally absent ack state; "ack" runs
    # comm.net_step_ack) while ack_timeout / backoff_base / max_retries /
    # ka_period are traced EngineScenario operands -- a timeout ladder
    # shares one compiled program with its siblings.
    transport: str = "fire_forget"  # "fire_forget" | "ack"
    ack_timeout: int = 0
    backoff_base: float = 1.0
    max_retries: int = 0
    ka_period: int = 0
    fault: str = "none"  # "none" | "crash" | "slow"
    crash_rate: float = 0.0
    recover_rate: float = 0.0
    slow_factor: float = 1.0

    def rate_scale(self) -> float:
        """Mean decode rate: the capacity multiplier of heterogeneity."""
        return mean_decode_rate(self.decode_rates)

    def arrival_rate(self) -> float:
        """Offered per-slot arrival rate: load x service capacity."""
        mean_work = self.mean_prefill + self.mean_decode
        return (
            self.load * self.replicas * self.decode_slots
            * self.rate_scale() / mean_work
        )

    def static_part(self) -> "EngineStatic":
        if self.max_slots is not None and self.max_slots < self.slots:
            raise ValueError(
                f"max_slots ({self.max_slots}) must be >= slots ({self.slots})"
            )
        if self.policy == "sqd" and not 1 <= self.sqd <= min(
            self.replicas, SQD_MAX
        ):
            raise ValueError(
                f"sqd ({self.sqd}) must be in [1, min(replicas, {SQD_MAX})]"
            )
        if (
            self.decode_rates is not None
            and len(self.decode_rates) != self.replicas
        ):
            raise ValueError(
                f"decode_rates has {len(self.decode_rates)} entries for "
                f"{self.replicas} replicas"
            )
        if self.route_backend == "pallas":
            if self.policy != "jsaq":
                raise ValueError(
                    f"route_backend='pallas' supports policy 'jsaq' only, "
                    f"got {self.policy!r}"
                )
            if not self.deterministic_ties:
                raise ValueError(
                    "route_backend='pallas' requires deterministic_ties="
                    "True (the kernel breaks ties to the lowest index)"
                )
            if self.network != "none" or self.fault != "none":
                raise NotImplementedError(
                    f"route_backend='pallas' does not support the degraded "
                    f"control plane (network={self.network!r}, "
                    f"fault={self.fault!r}); use route_backend='dense'"
                )
        comm_lib.validate_control_plane(
            network=self.network,
            net_delay=self.net_delay,
            net_jitter=self.net_jitter,
            net_drop=self.net_drop,
            suspect_age=self.suspect_age,
            fault=self.fault,
            crash_rate=self.crash_rate,
            recover_rate=self.recover_rate,
            slow_factor=self.slow_factor,
            policy=self.policy,
            comm=self.comm,
            token_refresh=(
                float(self.rt_period) if self.policy == "hsq" else None
            ),
            transport=self.transport,
            ack_timeout=self.ack_timeout,
            backoff_base=self.backoff_base,
            max_retries=self.max_retries,
            ka_period=self.ka_period,
        )
        if self.network != "none" and self.comm == "exact":
            raise ValueError(
                "comm='exact' assumes instant delivery (per-departure "
                "accounting); it cannot compose with network="
                f"{self.network!r}"
            )
        return EngineStatic(
            replicas=self.replicas,
            decode_slots=self.decode_slots,
            queue_cap=self.queue_cap,
            slots=self.max_slots if self.max_slots is not None else self.slots,
            comm=self.comm,
            policy=self.policy,
            # Only the "sqd" policy reads the subset size; normalise it to
            # 0 otherwise so cells differing in the unused knob share one
            # compiled program instead of fragmenting the grid.
            sqd=self.sqd if self.policy == "sqd" else 0,
            use_rates=self.decode_rates is not None,
            max_arrivals=self.max_arrivals,
            route_backend=self.route_backend,
            deterministic_ties=self.deterministic_ties,
            network=self.network,
            transport=self.transport,
            fault=self.fault,
        )

    def scenario(self) -> "EngineScenario":
        return EngineScenario.create(
            load=self.load,
            x=self.x,
            rt_period=self.rt_period,
            msr_drain=self.msr_drain,
            mean_prefill=self.mean_prefill,
            mean_decode=self.mean_decode,
            horizon=self.slots,
            replicas=self.replicas,
            decode_rates=self.decode_rates,
            net_delay=self.net_delay,
            net_jitter=self.net_jitter,
            net_drop=self.net_drop,
            suspect_age=self.suspect_age,
            ack_timeout=self.ack_timeout,
            backoff_base=self.backoff_base,
            max_retries=self.max_retries,
            ka_period=self.ka_period,
            crash_rate=self.crash_rate,
            recover_rate=self.recover_rate,
            slow_factor=self.slow_factor,
        )

    def engine_config(self) -> EngineConfig:
        """The numpy-reference view of this cell's dispatcher parameters."""
        return EngineConfig(
            num_replicas=self.replicas,
            decode_slots=self.decode_slots,
            et_x=int(self.x) if float(self.x).is_integer() else self.x,
            comm=self.comm,
            dt_x=int(self.x) if float(self.x).is_integer() else self.x,
            rt_period=self.rt_period,
            msr_drain=self.msr_drain,
            policy=self.policy,
            sqd=self.sqd,
            decode_rates=self.decode_rates,
            mean_prefill=float(self.mean_prefill),
            mean_decode=float(self.mean_decode),
            deterministic_ties=self.deterministic_ties,
            network=self.network,
            net_delay=self.net_delay,
            net_jitter=self.net_jitter,
            net_drop=self.net_drop,
            suspect_age=self.suspect_age,
            transport=self.transport,
            ack_timeout=self.ack_timeout,
            backoff_base=self.backoff_base,
            max_retries=self.max_retries,
            ka_period=self.ka_period,
            fault=self.fault,
            crash_rate=self.crash_rate,
            recover_rate=self.recover_rate,
            slow_factor=self.slow_factor,
        )

    def workload_key(self) -> tuple:
        """The sampler's parameter tuple: cells sharing it share a stream.

        Keyed on the *mean* decode rate (the capacity multiplier), not the
        rate profile: a 2:1 ladder and its uniform control with the same
        mean replay one stream, and all-ones rates share the
        ``decode_rates=None`` stream -- routing/policy parameters never
        enter (the paper's comparison method).
        """
        return (
            self.replicas, self.decode_slots, self.slots, self.load,
            self.mean_prefill, self.mean_decode, self.rate_scale(),
            # Extra uniform streams of the degraded control plane --
            # drawn from prefix-stable SeedSequence children, so cells
            # with both kinds off replay the historical stream byte for
            # byte (only the *presence* of each stream keys the cache).
            self.network != "none", self.fault != "none",
            # The ack/keepalive uniform stream rides a sixth prefix-stable
            # child: its presence keys the cache, fire_forget cells keep
            # the historical 9-tuple stream bytes untouched.
            self.transport == "ack",
        )


@dataclasses.dataclass(frozen=True)
class EngineStatic:
    """Compile-time structure of the jax serving program (hashable).

    ``slots`` is the *padded* scan length (each cell's effective length is
    the traced ``EngineScenario.horizon``) and ``max_arrivals`` the padded
    per-slot arrival-lane width (lanes beyond a slot's sampled arrival
    count are masked no-ops).  ``max_arrivals=0`` means "derive from the
    sampled workload" -- :func:`serve_grid` replaces it with the batch
    maximum, rounded up so near-miss batches reuse a compiled program.
    ``policy`` / ``sqd`` select the routing code path at trace time (like
    the comm kind); ``use_rates`` switches the decode step and MSR drain
    to the heterogeneous credit schedule (the rates themselves are traced
    :class:`EngineScenario` operands).  ``trace_occupancy`` additionally
    emits the end-of-slot per-replica occupancy trace (tests / checkpoint
    fingerprints only -- it makes the program output O(slots x replicas)).
    """

    replicas: int = 8
    decode_slots: int = 16
    queue_cap: int = 512
    slots: int = 20_000
    comm: str = "et"
    policy: ServePolicy = "jsaq"
    sqd: int = 2
    use_rates: bool = False
    max_arrivals: int = 0
    trace_occupancy: bool = False
    route_backend: str = "dense"  # "dense" | "pallas" (see ServeConfig)
    deterministic_ties: bool = False
    network: str = "none"  # "none" | "net" (control-plane kind, static)
    # Wire transport kind (static, like network): "ack" swaps the carry's
    # NetState for an AckNetState and the delivery step for net_step_ack;
    # "fire_forget" keeps the historical program structure untouched.
    transport: str = "fire_forget"  # "fire_forget" | "ack"
    fault: str = "none"  # "none" | "crash" | "slow" (replica fault kind)
    # Segment-engine mode (serve_stream): ``slots`` becomes the *chunk*
    # length, the carry is threaded across jit calls (donated in place),
    # the rid ring carries arrival slots instead of request ids, and
    # completions fold into the on-device StreamMetrics accumulators
    # instead of the O(offered) comp_slot scatter.  The slot body is
    # otherwise op-identical to the fixed-horizon scan, which is what
    # makes any chunking bit-identical to the monolithic trace.
    stream: bool = False


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EngineScenario:
    """Traced scenario operands of one serving cell (a registered pytree).

    ``x`` / ``rt_period`` / ``msr_drain`` / ``decode_rates`` / ``horizon``
    are consumed by the scan as array operands, so cells sweeping them
    share one compiled program -- in particular a heterogeneous-speed
    ladder compiles once.  ``load`` rides along for reporting only;
    ``mean_prefill`` / ``mean_decode`` parameterise the host-side workload
    sampler *and* feed the ``drain`` policy's E[S] term.
    """

    load: jnp.ndarray  # () f32 (reporting)
    x: jnp.ndarray  # () f32 ET/DT threshold
    rt_period: jnp.ndarray  # () i32 RT period in slots
    msr_drain: jnp.ndarray  # () f32 emulated completions/slot/busy replica
    mean_prefill: jnp.ndarray  # () f32 (drain policy E[S] term)
    mean_decode: jnp.ndarray  # () f32 (drain policy E[S] term)
    decode_rates: jnp.ndarray  # (R,) f32 per-replica speeds (ones if unused)
    horizon: jnp.ndarray  # () i32 effective slots (<= EngineStatic.slots)
    # Degraded-control-plane operands (neutral when the kinds are "none"):
    net_delay: jnp.ndarray  # () i32 base delivery delay in slots
    net_jitter: jnp.ndarray  # () i32 extra uniform delay in [0, jitter]
    net_drop: jnp.ndarray  # () f32 i.i.d. message-drop probability
    suspect_age: jnp.ndarray  # () i32 staleness bound (0 = no masking)
    # Reliable-transport operands (neutral under transport="fire_forget";
    # a timeout x backoff ladder shares one compiled program):
    ack_timeout: jnp.ndarray  # () i32 timeout window of a new send (slots)
    backoff_base: jnp.ndarray  # () f32 window multiplier per retransmit
    max_retries: jnp.ndarray  # () i32 retransmits before abandoning
    ka_period: jnp.ndarray  # () i32 server keepalive period (0 = none)
    crash_rate: jnp.ndarray  # () f32 per-slot fault-entry probability
    recover_rate: jnp.ndarray  # () f32 per-slot fault-exit probability
    slow_factor: jnp.ndarray  # () f32 service-rate scale of fault="slow"
    # Streaming-mode warmup: completions landing before this absolute slot
    # are discarded from the StreamMetrics accumulators (transient
    # discard); inert in fixed-horizon mode.
    warmup: jnp.ndarray  # () i32

    @staticmethod
    def create(
        load: float,
        x: float = 4.0,
        rt_period: int = 16,
        msr_drain: float = 1.0,
        mean_prefill: float = 4,
        mean_decode: float = 64,
        horizon: Optional[int] = None,
        replicas: int = 8,
        decode_rates: Optional[Sequence[float]] = None,
        net_delay: int = 0,
        net_jitter: int = 0,
        net_drop: float = 0.0,
        suspect_age: int = 0,
        ack_timeout: int = 0,
        backoff_base: float = 1.0,
        max_retries: int = 0,
        ka_period: int = 0,
        crash_rate: float = 0.0,
        recover_rate: float = 0.0,
        slow_factor: float = 1.0,
        warmup: int = 0,
    ) -> "EngineScenario":
        if horizon is None:
            horizon = np.iinfo(np.int32).max
        rates = (
            jnp.ones((replicas,), jnp.float32)
            if decode_rates is None
            else jnp.asarray(decode_rates, jnp.float32)
        )
        return EngineScenario(
            load=jnp.float32(load),
            x=jnp.float32(x),
            rt_period=jnp.int32(rt_period),
            msr_drain=jnp.float32(msr_drain),
            mean_prefill=jnp.float32(mean_prefill),
            mean_decode=jnp.float32(mean_decode),
            decode_rates=rates,
            horizon=jnp.int32(horizon),
            net_delay=jnp.int32(net_delay),
            net_jitter=jnp.int32(net_jitter),
            net_drop=jnp.float32(net_drop),
            suspect_age=jnp.int32(suspect_age),
            ack_timeout=jnp.int32(ack_timeout),
            backoff_base=jnp.float32(backoff_base),
            max_retries=jnp.int32(max_retries),
            ka_period=jnp.int32(ka_period),
            crash_rate=jnp.float32(crash_rate),
            recover_rate=jnp.float32(recover_rate),
            slow_factor=jnp.float32(slow_factor),
            warmup=jnp.int32(warmup),
        )


def stack_scenarios(scenarios: Sequence[EngineScenario]) -> EngineScenario:
    """Stack unbatched cells into one batched scenario (leading axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamMetrics:
    """On-device streaming JCT/message accumulators (segment-engine carry).

    At soak scale (1e7+ slots) completion records cannot be concatenated
    host-side, so the chunk carry folds every completion into O(1) state
    the moment it happens:

    * ``count`` / ``mean`` / ``m2`` -- Welford running mean and sum of
      squared deviations over post-warmup JCTs, combined per slot with
      Chan's parallel-batch rule.  The combine happens *inside* the scan
      for each slot's completion batch, so the accumulator trajectory is
      independent of how the stream is chunked -- any chunking is
      bit-identical.  f32: good to ~1e7 completions before the n/(n+b)
      ratios lose single-precision mass; tail quantiles never rely on it.
    * ``hist`` -- the fixed-bucket log-spaced JCT histogram of
      :func:`repro.core.care.metrics.jct_bucket` (exact integer
      bucketing), the robust source of tail quantiles at any scale.
    * ``max_jct`` -- exact running maximum.

    Message/drop totals live where they always did (``CommState.msgs``,
    ``NetState.drops``) -- the carry threads them across chunks unchanged.
    """

    count: jnp.ndarray  # () i32 post-warmup completions
    mean: jnp.ndarray  # () f32 running mean JCT
    m2: jnp.ndarray  # () f32 running sum of squared deviations
    max_jct: jnp.ndarray  # () i32 exact max JCT
    hist: jnp.ndarray  # (metrics.HIST_BUCKETS,) i32 log-bucket counts

    @staticmethod
    def init() -> "StreamMetrics":
        return StreamMetrics(
            count=jnp.zeros((), jnp.int32),
            mean=jnp.zeros((), jnp.float32),
            m2=jnp.zeros((), jnp.float32),
            max_jct=jnp.zeros((), jnp.int32),
            hist=jnp.zeros((metrics_lib.HIST_BUCKETS,), jnp.int32),
        )

    def update(self, jct: jnp.ndarray, meas: jnp.ndarray) -> "StreamMetrics":
        """Fold one slot's completion batch in (``meas`` masks ``jct``).

        Chan's batch combine in f32 -- per slot, never per chunk, so the
        result cannot depend on chunk boundaries.  A slot with no measured
        completions is an exact no-op on every field.
        """
        n_b = jnp.sum(meas, dtype=jnp.int32)
        has = n_b > 0
        jf = jct.astype(jnp.float32)
        n_bf = n_b.astype(jnp.float32)
        mean_b = jnp.sum(jnp.where(meas, jf, 0.0)) / jnp.maximum(n_bf, 1.0)
        m2_b = jnp.sum(jnp.where(meas, (jf - mean_b) ** 2, 0.0))
        n_af = self.count.astype(jnp.float32)
        tot = jnp.maximum(n_af + n_bf, 1.0)
        delta = mean_b - self.mean
        mean = jnp.where(has, self.mean + delta * n_bf / tot, self.mean)
        m2 = jnp.where(
            has, self.m2 + m2_b + delta * delta * n_af * n_bf / tot, self.m2
        )
        bucket = jnp.where(
            meas, metrics_lib.jct_bucket(jct, xp=jnp), metrics_lib.HIST_BUCKETS
        ).reshape(-1)
        hist = self.hist.at[bucket].add(1, mode="drop")
        max_jct = jnp.maximum(self.max_jct, jnp.max(jnp.where(meas, jct, 0)))
        return StreamMetrics(
            count=self.count + n_b, mean=mean, m2=m2, max_jct=max_jct,
            hist=hist,
        )


# ---------------------------------------------------------------------------
# Host-side workload sampling: one replayable stream both backends consume.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeWorkload:
    """Pre-sampled request stream (host-side numpy; rid = arrival order).

    Drawn once per (cell workload parameters, seed) and consumed by both
    the numpy reference and the jax scan, so the two are bit-identical by
    construction.  ``tie_u`` is float32 *at the source*: both backends
    compute the tie-break rank as ``int(f32(u) * f32(n_ties))``, so the
    f32 traced path cannot round differently from the host path.
    ``sub_u`` carries SQ(d)'s per-request subset uniforms (a third
    independent ``SeedSequence`` child) -- also float32 at the source, fed
    to the shared :func:`subset_mask` derivation on both backends.
    """

    n_arr: np.ndarray  # (T,) int64 arrivals per slot
    base: np.ndarray  # (T,) int64 rid of the first arrival in each slot
    prefill: np.ndarray  # (N,) int64 per-request prefill cost (>= 1)
    decode: np.ndarray  # (N,) int64 per-request decode length (>= 1)
    work: np.ndarray  # (N,) int64 total slot occupancy, max(p + d, 1)
    tie_u: np.ndarray  # (N,) float32 routing tie-break uniforms
    sub_u: np.ndarray  # (N, SQD_MAX) float32 SQ(d) subset uniforms
    arrival_slot: np.ndarray  # (N,) int64
    # Degraded-control-plane uniform streams (independent SeedSequence
    # children; None unless the corresponding kind is on, so the base
    # stream bytes never move): message-drop and jitter draws per
    # (slot, replica), and the fault-chain transition draws.
    net_drop_u: Optional[np.ndarray] = None  # (T, R) float32
    net_jit_u: Optional[np.ndarray] = None  # (T, R) float32
    fault_u: Optional[np.ndarray] = None  # (T, R) float32
    # Ack/keepalive-channel uniforms (transport="ack" only): rows are
    # (ack drop, ack jitter, ka drop, ka jitter) per net_step_ack.
    ack_u: Optional[np.ndarray] = None  # (T, 4, R) float32

    @property
    def total(self) -> int:
        return int(self.work.shape[0])


def sample_workload(
    seed: int,
    *,
    replicas: int,
    decode_slots: int,
    slots: int,
    load: float,
    mean_prefill: float = 4,
    mean_decode: float = 64,
    rate_scale: float = 1.0,
    with_net: bool = False,
    with_fault: bool = False,
    with_ack: bool = False,
) -> ServeWorkload:
    """Draw the replayable serving workload for one (parameters, seed).

    Streams are split with ``SeedSequence.spawn``: arrivals/sizes, routing
    tie-breaks and SQ(d) subset draws come from independent child streams,
    so changing one consumption (e.g. comparing policies, which route
    differently) can never perturb the offered workload and vice versa.
    ``rate_scale`` is the mean per-replica decode rate -- heterogeneous
    ``decode_rates`` scale the offered capacity without re-keying the
    tie-break or subset streams.  ``with_net`` / ``with_fault`` draw the
    degraded-control-plane uniforms from two further children (3 and 4);
    ``SeedSequence`` spawning is prefix-stable, so turning them on cannot
    move the first three streams -- a fault ladder replays the exact
    arrival/tie-break bytes of its fault-free control.  ``with_ack``
    (``transport="ack"``) draws the ack/keepalive-channel uniforms from a
    sixth child -- again prefix-stable, so an ack cell replays its
    fire-and-forget control's bytes on every other stream.
    """
    w_ss, r_ss, s_ss, n_ss, f_ss, a_ss = (
        np.random.SeedSequence(int(seed)).spawn(6)
    )
    wrng = np.random.default_rng(w_ss)
    rrng = np.random.default_rng(r_ss)
    srng = np.random.default_rng(s_ss)
    mean_work = mean_prefill + mean_decode
    rate = load * replicas * decode_slots * rate_scale / mean_work
    n_arr = wrng.poisson(rate, size=slots).astype(np.int64)
    total = int(n_arr.sum())
    prefill = 1 + wrng.poisson(mean_prefill, size=total).astype(np.int64)
    decode = 1 + wrng.poisson(mean_decode, size=total).astype(np.int64)
    work = np.maximum(prefill + decode, 1)
    tie_u = rrng.random(size=total, dtype=np.float32)
    sub_u = srng.random(size=(total, SQD_MAX), dtype=np.float32)
    base = np.concatenate([[0], np.cumsum(n_arr)[:-1]]).astype(np.int64)
    arrival_slot = np.repeat(np.arange(slots, dtype=np.int64), n_arr)
    net_drop_u = net_jit_u = fault_u = ack_u = None
    if with_net:
        nrng = np.random.default_rng(n_ss)
        net_drop_u = nrng.random(size=(slots, replicas), dtype=np.float32)
        net_jit_u = nrng.random(size=(slots, replicas), dtype=np.float32)
    if with_fault:
        frng = np.random.default_rng(f_ss)
        fault_u = frng.random(size=(slots, replicas), dtype=np.float32)
    if with_ack:
        arng = np.random.default_rng(a_ss)
        ack_u = arng.random(size=(slots, 4, replicas), dtype=np.float32)
    return ServeWorkload(
        n_arr=n_arr, base=base, prefill=prefill, decode=decode,
        work=work, tie_u=tie_u, sub_u=sub_u, arrival_slot=arrival_slot,
        net_drop_u=net_drop_u, net_jit_u=net_jit_u, fault_u=fault_u,
        ack_u=ack_u,
    )


@functools.lru_cache(maxsize=512)
def _cached_workload(key: tuple, seed: int) -> ServeWorkload:
    (replicas, decode_slots, slots, load, mean_prefill, mean_decode,
     rate_scale, with_net, with_fault, with_ack) = key
    return sample_workload(
        seed, replicas=replicas, decode_slots=decode_slots, slots=slots,
        load=load, mean_prefill=mean_prefill, mean_decode=mean_decode,
        rate_scale=rate_scale, with_net=with_net, with_fault=with_fault,
        with_ack=with_ack,
    )


def workload_for(cell: ServeConfig, seed: int) -> ServeWorkload:
    """The (memoised) workload of one cell x seed.  Cells differing only
    in comm kind / thresholds share the stream -- the paper's comparison
    method (identical input replayed under every policy)."""
    return _cached_workload(cell.workload_key(), int(seed))


def pick_min_tied(
    occ: np.ndarray,
    u: float,
    mask: Optional[np.ndarray] = None,
    deterministic: bool = False,
) -> int:
    """Index of the minimum of ``occ``; ties broken by the uniform ``u``.

    The rank is computed in float32 (``int(f32(u) * f32(n_ties))``) so the
    traced f32 engine reproduces the choice bit for bit; ``u`` must come
    from a float32 draw (``ServeWorkload.tie_u``) for that guarantee.

    ``deterministic=True`` ignores ``u`` and resolves ties to the lowest
    index -- the Pallas routing-kernel convention (rank 0 in the shared
    rank arithmetic), so every backend of the serving tier (this numpy
    reference, the traced lane, the fused kernel) picks the same replica
    on the same state vector.

    ``mask`` (optional, bool ``(R,)``) restricts the minimum to a candidate
    subset -- the SQ(d) path: non-candidates are lifted to ``+inf`` before
    the argmin, exactly as the traced lane does, so the tie set (and hence
    the rank arithmetic) is identical on both backends.  A single candidate
    is returned regardless of ``u``; an all-False mask returns ``-1`` (the
    engine never routes with an empty subset -- ``sqd >= 1``).
    """
    if mask is not None:
        if not mask.any():
            return -1
        occ = np.where(mask, occ, np.inf)
    ties = np.flatnonzero(occ == occ.min())
    if deterministic:
        return int(ties[0])
    rank = min(int(np.float32(u) * np.float32(len(ties))), len(ties) - 1)
    return int(ties[rank])


def subset_mask(u_row, n: int, d: int, xp=np):
    """SQ(d) candidate mask: ``d`` distinct of ``n`` replicas from uniforms.

    A partial Fisher-Yates draw consuming ``u_row[:d]`` (float32, from
    ``ServeWorkload.sub_u``): step ``i`` picks the ``k``-th of the ``n-i``
    still-available replicas with ``k = min(int(f32(u_i) * f32(n-i)),
    n-i-1)`` -- uniform over d-subsets, and pure float32/int32 arithmetic
    on either array namespace (``xp=np`` in the reference dispatcher,
    ``xp=jnp`` inside the traced lane), so both backends derive the *same*
    subset from the same pre-drawn row, bit for bit.
    """
    avail = xp.ones((n,), bool)
    mask = xp.zeros((n,), bool)
    for i in range(d):
        m = n - i  # Python int: the loop is unrolled at trace time
        u = xp.float32(u_row[i]) if xp is np else u_row[i]
        k = xp.minimum(
            (u * xp.float32(m)).astype(xp.int32), xp.int32(m - 1)
        )
        cum = xp.cumsum(avail.astype(xp.int32))
        pick = avail & (cum == k + 1)  # one-hot: k-th available replica
        mask = mask | pick
        avail = avail & ~pick
    return mask


# ---------------------------------------------------------------------------
# numpy reference: the pluggable-model_fn dispatcher (golden path).
# ---------------------------------------------------------------------------


class CareDispatcher:
    """Policy routing over approximated occupancy + shared-core triggers.

    All per-replica state is vectorised numpy: ``active_rem``/``active_rid``
    hold the decode slots (<= 0 remaining == free), ``_q_rid``/``_q_head``/
    ``_q_len`` are per-replica FIFO rings of pending request ids, and the
    trigger bookkeeping is a :class:`repro.core.care.comm.CommState`.

    ``cfg.policy`` selects the routing rule (see :data:`ServePolicy`); every
    policy consumes the same state vector JSAQ does -- the emulated
    occupancy, or the true occupancy under ``comm="exact"``.  The emulated
    occupancy is carried in **float32** (like the traced engine), so the
    bit-identity guarantee extends to non-dyadic drains and decode rates:
    both backends execute the same IEEE single-precision operations.

    ``rng`` (optional) injects the tie-break/subset streams;
    :func:`run_serving_sim` passes pre-drawn uniforms per request instead
    (``route(..., u=..., sub_u=...)``), in which case the internal stream
    is never consumed.
    """

    def __init__(
        self,
        cfg: EngineConfig,
        seed: int = 0,
        queue_cap: int = 4096,
        rng: Optional[np.random.Generator] = None,
    ):
        r, s = cfg.num_replicas, cfg.decode_slots
        if cfg.policy == "sqd" and not 1 <= cfg.sqd <= min(r, SQD_MAX):
            # Mirrors ServeConfig.static_part(): the pre-drawn sub_u rows
            # (and the rng fallback) carry SQD_MAX lanes, and a subset
            # larger than the replica set cannot be distinct.
            raise ValueError(
                f"sqd ({cfg.sqd}) must be in [1, min(num_replicas, "
                f"{SQD_MAX})]"
            )
        if (
            cfg.decode_rates is not None
            and len(cfg.decode_rates) != r
        ):
            raise ValueError(
                f"decode_rates has {len(cfg.decode_rates)} entries for "
                f"{r} replicas"
            )
        comm_lib.validate_control_plane(
            network=cfg.network,
            net_delay=cfg.net_delay,
            net_jitter=cfg.net_jitter,
            net_drop=cfg.net_drop,
            suspect_age=cfg.suspect_age,
            fault=cfg.fault,
            crash_rate=cfg.crash_rate,
            recover_rate=cfg.recover_rate,
            slow_factor=cfg.slow_factor,
            policy=cfg.policy,
            comm=cfg.comm,
            token_refresh=(
                float(cfg.rt_period) if cfg.policy == "hsq" else None
            ),
        )
        if cfg.network != "none" and cfg.comm == "exact":
            raise ValueError(
                "comm='exact' assumes instant delivery (per-departure "
                "accounting); it cannot compose with network="
                f"{cfg.network!r}"
            )
        self.cfg = cfg
        self._ccfg = cfg.comm_config()
        # Degraded control plane: per-replica in-flight message buffer
        # (network="net") and the fault mask of the crash/slow process.
        # transport="ack" swaps the wire state for an AckNetState and the
        # delivery step for net_step_ack (timeout/retransmit/backoff).
        if cfg.network != "none":
            if cfg.transport == "ack":
                self.net = comm_lib.AckNetState.init(
                    r, xp=np, payload_dtype=np.float32
                )
            else:
                self.net = comm_lib.NetState.init(
                    r, xp=np, payload_dtype=np.float32
                )
            self._ncfg = comm_lib.NetworkConfig(
                kind=cfg.network,
                delay=np.int32(cfg.net_delay),
                jitter=np.int32(cfg.net_jitter),
                drop=np.float32(cfg.net_drop),
                transport=cfg.transport,
                ack_timeout=np.int32(cfg.ack_timeout),
                backoff_base=np.float32(cfg.backoff_base),
                max_retries=np.int32(cfg.max_retries),
                ka_period=np.int32(cfg.ka_period),
            )
        else:
            self.net = None
            self._ncfg = None
        self.faulted = (
            np.zeros(r, bool) if cfg.fault != "none" else None
        )
        self.active_rem = np.zeros((r, s), np.int64)
        self.active_rid = np.full((r, s), -1, np.int64)
        self._qcap = queue_cap
        self._q_rid = np.full((r, queue_cap), -1, np.int64)
        self._q_head = np.zeros(r, np.int64)
        self._q_len = np.zeros(r, np.int64)
        self.approx = np.zeros(r, np.float32)  # emulated occupancy (f32)
        self.comm = comm_lib.CommState.init(r, xp=np)
        self.total_completions = 0
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self._rr_ptr = 0  # round-robin pointer ("rr" policy)
        self.last_subset: Optional[np.ndarray] = None  # "sqd" diagnostics
        # Pull-policy token pool: one slot per replica, refreshed on
        # token-message *delivery* (so stale pools under a degraded
        # network mirror the traced engine exactly).  token_misses counts
        # routed arrivals that found an empty pool (the uniform fallback);
        # token_sum integrates end-of-slot pool occupancy over slots.
        if cfg.policy in PULL_POLICIES:
            self._tokens: Optional[np.ndarray] = np.zeros(r, np.int32)
        else:
            self._tokens = None
        self.token_misses = 0
        self.token_sum = 0
        # Heterogeneous decode rates: None = unit rates (the historical
        # integer fast path).  The f32 vectors mirror the traced operands
        # exactly -- same IEEE products in the MSR drain and drain score.
        if cfg.decode_rates is None:
            self._rates = None
            self._drainv = np.float32(cfg.msr_drain) * np.ones(r, np.float32)
        else:
            self._rates = np.asarray(cfg.decode_rates, np.float32)
            self._drainv = np.float32(cfg.msr_drain) * self._rates
        rates_f32 = (
            np.ones(r, np.float32) if self._rates is None else self._rates
        )
        self._drain_slots = routing_lib.expected_drain_slots(
            np.float32(cfg.mean_prefill) + np.float32(cfg.mean_decode),
            rates_f32,
        )
        # rid-indexed request metadata (grown on demand).
        self._work = np.zeros(1024, np.int64)
        self._started = np.full(1024, -1, np.int64)
        self._store: dict[int, Request] = {}

    @property
    def messages(self) -> int:
        return int(self.comm.msgs)

    def true_occupancy(self) -> np.ndarray:
        """Exact per-replica occupancy (queued + active), shape (R,)."""
        return self._q_len + (self.active_rem > 0).sum(axis=1)

    def _ensure_rid(self, rid: int):
        while rid >= self._work.shape[0]:
            self._work = np.concatenate([self._work, np.zeros_like(self._work)])
            self._started = np.concatenate(
                [self._started, np.full_like(self._started, -1)]
            )

    def _grow_queues(self):
        r = self.cfg.num_replicas
        new = np.full((r, 2 * self._qcap), -1, np.int64)
        for i in range(r):  # linearise each ring into the new buffer
            idx = (self._q_head[i] + np.arange(self._q_len[i])) % self._qcap
            new[i, : self._q_len[i]] = self._q_rid[i, idx]
        self._q_rid, self._q_head, self._qcap = new, np.zeros(r, np.int64), 2 * self._qcap

    def route(
        self,
        req: Request,
        now: int,
        u: Optional[float] = None,
        sub_u: Optional[np.ndarray] = None,
    ) -> int:
        cfg = self.cfg
        if cfg.comm == "exact":
            occ = self.true_occupancy().astype(np.float32)
        else:
            occ = self.approx
        self.last_subset = None
        # Suspect-server exclusion: a replica whose last update is older
        # than the staleness bound is excluded from the shortest-queue
        # family's candidate set (all-suspect degrades to unmasked).  The
        # staleness clock is the network age when messages are delayed,
        # else the trigger's slots-since-message counter -- RT keepalives
        # reset either one, doubling as failure detection.
        healthy = None
        if cfg.suspect_age > 0:
            if self.net is not None and cfg.transport == "ack":
                # Keepalive-driven masking: the last-heard clock counts
                # any delivery (data or keepalive), and a server that
                # abandoned an update after max_retries is a self-suspect
                # until some later transmission is acked.
                healthy = (
                    self.net.ka_age <= cfg.suspect_age
                ) & ~self.net.gave_up
            else:
                age = (
                    self.net.age if self.net is not None
                    else self.comm.slots_since_msg
                )
                healthy = age <= cfg.suspect_age
            if not healthy.any():
                healthy = np.ones_like(healthy)
        if cfg.policy == "rr":
            if healthy is None:
                j = self._rr_ptr % cfg.num_replicas
                self._rr_ptr += 1
            else:
                # Masked round robin: skip suspect replicas to the
                # cyclically-next healthy one (same derivation as the
                # traced lane and routing.route_rr -- with an all-True
                # mask the choice equals the unmasked path).
                off = (
                    np.arange(cfg.num_replicas, dtype=np.int64)
                    - self._rr_ptr
                ) % cfg.num_replicas
                off = np.where(healthy, off, cfg.num_replicas)
                j = int(np.argmin(off))
                self._rr_ptr = j + 1
        else:
            if u is None:
                u = self.rng.random(dtype=np.float32)
            det = cfg.deterministic_ties
            if cfg.policy == "sqd":
                if sub_u is None:
                    sub_u = self.rng.random(size=SQD_MAX, dtype=np.float32)
                mask = subset_mask(sub_u, cfg.num_replicas, cfg.sqd, xp=np)
                self.last_subset = mask
                if healthy is not None:
                    m = mask & healthy
                    mask = m if m.any() else mask
                j = pick_min_tied(occ, u, mask=mask, deterministic=det)
            elif cfg.policy == "drain":
                j = pick_min_tied(
                    occ * self._drain_slots, u, mask=healthy,
                    deterministic=det,
                )
            elif cfg.policy in PULL_POLICIES:
                # Spend a token: join the replica holding the most (scored
                # as -tokens through the shared tie machinery, so an empty
                # pool is an all-tie -- the uniform fallback -- and the
                # suspect mask composes like every other policy).
                j = pick_min_tied(
                    (0 - self._tokens).astype(np.float32), u,
                    mask=healthy, deterministic=det,
                )
                if self._tokens[j] == 0:
                    self.token_misses += 1
                self._tokens[j] = max(int(self._tokens[j]) - 1, 0)
            else:  # jsaq
                j = pick_min_tied(occ, u, mask=healthy, deterministic=det)
        if cfg.policy == "sqd" and self.net is not None:
            # SQ(d) is a pull scheme: each routed arrival costs d query +
            # d response messages on the wire (2d round-trips), putting
            # query-based sampling on the same honest message-rate axis
            # as CARE's push updates.
            self.comm = dataclasses.replace(
                self.comm,
                msgs=self.comm.msgs + np.int32(2 * cfg.sqd),
            )
        if self._q_len[j] >= self._qcap:
            self._grow_queues()
        self._ensure_rid(req.rid)
        # A zero-work request still occupies a decode slot for one
        # iteration (matches the pre-vectorisation engine, where the first
        # decrement completed it); without the clamp it would sit at
        # rem == 0 forever and never be marked done.
        self._work[req.rid] = max(req.prefill_cost + req.decode_len, 1)
        self._store[req.rid] = req
        tail = (self._q_head[j] + self._q_len[j]) % self._qcap
        self._q_rid[j, tail] = req.rid
        self._q_len[j] += 1
        self.approx[j] += 1  # arrival known to the dispatcher (Eq. 10)
        return j

    def step(
        self,
        now: int,
        drop_u: Optional[np.ndarray] = None,
        jit_u: Optional[np.ndarray] = None,
        fault_u: Optional[np.ndarray] = None,
        ack_u: Optional[np.ndarray] = None,
    ) -> list[Request]:
        cfg = self.cfg
        rows = np.arange(cfg.num_replicas)[:, None]

        # 0. fault transitions (before admission, like the traced slot
        # body: arrivals were routed against the previous slot's state).
        recovered = None
        if self.faulted is not None:
            if fault_u is None:
                raise ValueError(
                    "step() needs this slot's fault_u row when "
                    f"fault={cfg.fault!r} (sample_workload with_fault=True)"
                )
            self.faulted, recovered = workload_lib.fault_transitions(
                self.faulted, np.asarray(fault_u, np.float32),
                np.float32(cfg.crash_rate), np.float32(cfg.recover_rate),
                xp=np,
            )

        # 1. admit: fill free decode slots from the pending rings, FIFO.
        free = self.active_rem <= 0
        free_rank = np.cumsum(free, axis=1) - 1
        n_admit = np.minimum(self._q_len, free.sum(axis=1))
        if cfg.fault == "crash" and self.faulted is not None:
            # A crashed replica is frozen: queued requests wait (conserved)
            # and resume admission on recovery.
            n_admit = np.where(self.faulted, 0, n_admit)
        take = free & (free_rank < n_admit[:, None])
        if take.any():
            qidx = (self._q_head[:, None] + free_rank) % self._qcap
            rid = self._q_rid[rows, qidx]
            self.active_rid = np.where(take, rid, self.active_rid)
            self.active_rem = np.where(take, self._work[rid], self.active_rem)
            self._started[rid[take]] = now
            self._q_head = (self._q_head + n_admit) % self._qcap
            self._q_len = self._q_len - n_admit

        # 2. service: one decode iteration on every active slot -- one work
        # unit at unit rates, or the slot's credit-schedule units under
        # heterogeneous decode_rates (shared with the slotted tier's
        # workload.service_units; a finishing unit beyond the remaining
        # work is forfeit, so rem may go negative == free).
        active = self.active_rem > 0
        if self.faulted is not None:
            if self._rates is None:
                nominal = np.ones(cfg.num_replicas, np.int64)
            else:
                nominal = workload_lib.service_units(now, self._rates, xp=np)
            units = workload_lib.faulted_service_units(
                now, self.faulted, nominal, cfg.fault,
                np.float32(cfg.slow_factor), rates=self._rates, xp=np,
            )
            self.active_rem = self.active_rem - units[:, None] * active
        elif self._rates is None:
            self.active_rem = self.active_rem - active
        else:
            units = workload_lib.service_units(now, self._rates, xp=np)
            self.active_rem = self.active_rem - units[:, None] * active
        done = active & (self.active_rem <= 0)
        completions = done.sum(axis=1)
        finished: list[Request] = []
        if done.any():
            for rid in self.active_rid[done]:
                req = self._store.pop(int(rid))
                req.started = int(self._started[rid])
                req.finished = now
                finished.append(req)
            self.active_rid[done] = -1
        self.total_completions += int(completions.sum())

        # 3. MSR drain: emulate service at the nominal completion rate,
        # scaled per replica by its decode rate (f32, like the traced path).
        busy = self.approx > 0
        self.approx = np.maximum(
            self.approx - self._drainv * busy, np.float32(0.0)
        )

        # 4. trigger (replicas mirror the emulation exactly) -- shared core.
        # Crashed replicas cannot send (counters keep advancing, so the
        # first healthy slot re-fires a due trigger) and a recovery forces
        # a resync message regardless of the trigger predicate.
        true_occ = self.true_occupancy().astype(np.float32)
        err = np.abs(true_occ - self.approx)
        can_send = force = None
        if cfg.fault == "crash" and self.faulted is not None:
            can_send = ~self.faulted
            force = recovered
        trig, self.comm = comm_lib.evaluate(
            self.comm, self._ccfg, err, completions, xp=np,
            can_send=can_send, force=force, q=true_occ,
            count_msgs=self.net is None,
        )
        # 5. network: triggered sends traverse the in-flight buffer (delay
        # + jitter + drop, piggyback batching); the dispatcher's view only
        # advances on *delivery* of the send-time snapshot.
        if self.net is not None:
            if drop_u is None or jit_u is None:
                raise ValueError(
                    "step() needs this slot's drop_u/jit_u rows when "
                    f"network={cfg.network!r} (sample_workload "
                    "with_net=True)"
                )
            if cfg.transport == "ack":
                if ack_u is None:
                    raise ValueError(
                        "step() needs this slot's ack_u rows when "
                        "transport='ack' (sample_workload with_ack=True)"
                    )
                delivered, payload, sent, self.net = comm_lib.net_step_ack(
                    self.net, self._ncfg, trig, true_occ,
                    np.asarray(drop_u, np.float32),
                    np.asarray(jit_u, np.float32),
                    np.asarray(ack_u, np.float32), xp=np,
                    can_send=can_send,
                )
            else:
                delivered, payload, sent, self.net = comm_lib.net_step(
                    self.net, self._ncfg, trig, true_occ,
                    np.asarray(drop_u, np.float32),
                    np.asarray(jit_u, np.float32), xp=np,
                    can_send=can_send,
                )
            self.comm = dataclasses.replace(
                self.comm, msgs=self.comm.msgs + sent
            )
            self.approx = np.where(delivered, payload, self.approx)
        else:
            self.approx = np.where(trig, true_occ, self.approx)
        # 6. pull-token refresh: a delivered token message *overwrites* the
        # sender's pool slot from the send-time queue snapshot (1 if idle
        # for JIQ, the headroom below the threshold for hsq -- f32
        # arithmetic truncated to int32, matching the traced engine).  A
        # crashed replica stops sending, so its stale tokens drain to zero
        # and are never replenished -- the safe-staleness property the
        # pull frontier measures.
        if self._tokens is not None:
            if cfg.comm == "jiq":
                def _fresh(p):
                    return (p == np.float32(0.0)).astype(np.int32)
            else:  # hsq
                def _fresh(p):
                    return np.maximum(
                        np.float32(self._ccfg.x) - p, np.float32(0.0)
                    ).astype(np.int32)
            if self.net is not None:
                self._tokens = np.where(
                    delivered, _fresh(payload), self._tokens
                )
            else:
                self._tokens = np.where(
                    trig, _fresh(true_occ), self._tokens
                )
            self.token_sum += int(self._tokens.sum())
        return finished


def run_serving_sim(
    cfg: EngineConfig,
    *,
    slots: int = 20_000,
    load: float = 0.9,
    mean_decode: int = 64,
    mean_prefill: int = 4,
    seed: int = 0,
    model_fn: Optional[Callable] = None,
    workload: Optional[ServeWorkload] = None,
    checkpoints: Sequence[int] = (),
) -> dict:
    """Drive the numpy engine with a pre-sampled workload; return metrics.

    The workload (arrival counts, request sizes, tie-break uniforms) comes
    from :func:`sample_workload` -- independent ``SeedSequence`` child
    streams -- unless an explicit ``workload`` is given (the equivalence
    tests feed the same object to both backends).  ``checkpoints`` lists
    slot indices at which the exact per-replica occupancy is snapshotted
    (``out["occupancy"][slot]``, captured at end of slot, matching the jax
    engine's ``trace_occupancy`` rows).
    """
    with_net = cfg.network != "none"
    with_fault = cfg.fault != "none"
    with_ack = with_net and cfg.transport == "ack"
    if workload is None:
        rate_scale = mean_decode_rate(cfg.decode_rates)
        workload = sample_workload(
            seed, replicas=cfg.num_replicas, decode_slots=cfg.decode_slots,
            slots=slots, load=load, mean_prefill=mean_prefill,
            mean_decode=mean_decode, rate_scale=rate_scale,
            with_net=with_net, with_fault=with_fault, with_ack=with_ack,
        )
    if with_net and workload.net_drop_u is None:
        raise ValueError(
            "workload lacks the network uniform streams; sample it with "
            "with_net=True"
        )
    if with_fault and workload.fault_u is None:
        raise ValueError(
            "workload lacks the fault uniform stream; sample it with "
            "with_fault=True"
        )
    if with_ack and workload.ack_u is None:
        raise ValueError(
            "workload lacks the ack/keepalive uniform stream; sample it "
            "with with_ack=True"
        )
    # One source of truth for E[S]: the drain policy's score must use the
    # same mean work the workload was sampled with, or the two backends
    # would scale occupancies by different f32 drain_slots vectors.
    # (ServeConfig.engine_config() already passes equal values, making
    # this a no-op on the grid path.)
    cfg = dataclasses.replace(
        cfg, mean_prefill=float(mean_prefill), mean_decode=float(mean_decode)
    )
    disp = CareDispatcher(cfg, seed)

    finished: list[Request] = []
    occupancy: dict[int, np.ndarray] = {}
    want_ckpt = set(int(c) for c in checkpoints)
    for now in range(slots):
        b = int(workload.base[now])
        for i in range(int(workload.n_arr[now])):
            rid = b + i
            req = Request(
                rid=rid,
                arrival=now,
                prefill_cost=int(workload.prefill[rid]),
                decode_len=int(workload.decode[rid]),
            )
            disp.route(
                req, now, u=float(workload.tie_u[rid]),
                sub_u=workload.sub_u[rid],
            )
        finished.extend(disp.step(
            now,
            drop_u=workload.net_drop_u[now] if with_net else None,
            jit_u=workload.net_jit_u[now] if with_net else None,
            fault_u=workload.fault_u[now] if with_fault else None,
            ack_u=workload.ack_u[now] if with_ack else None,
        ))
        if now in want_ckpt:
            occupancy[now] = disp.true_occupancy().copy()
        if model_fn is not None:
            model_fn(now)

    # JCT vector in rid (arrival) order so both backends emit the same
    # vector -- the old engine returned completion order, which is a
    # per-replica interleaving the batched scan has no business replaying.
    jct_by_rid = np.full(workload.total, -1, np.int64)
    for r in finished:
        jct_by_rid[r.rid] = r.finished - r.arrival + 1
    jct = jct_by_rid[jct_by_rid >= 0]
    base_msgs = max(disp.total_completions, 1)
    return {
        "jct": jct,
        "jct_by_rid": jct_by_rid,
        "mean_jct": float(jct.mean()) if jct.size else 0.0,
        "p99_jct": float(np.percentile(jct, 99)) if jct.size else 0.0,
        "completed": len(finished),
        "offered": workload.total,
        "messages": disp.messages,
        "msgs_per_completion": disp.messages / base_msgs,
        "final_occupancy": disp.true_occupancy().copy(),
        "occupancy": occupancy,
        "requests": finished,
        "net_drops": int(disp.net.drops) if disp.net is not None else 0,
        "retrans": (
            int(disp.net.retrans)
            if disp.net is not None and cfg.transport == "ack"
            else 0
        ),
        "token_misses": int(disp.token_misses),
        "token_sum": int(disp.token_sum),
    }


# ---------------------------------------------------------------------------
# jax engine: the same dynamics as one jitted fixed-horizon lax.scan.
# ---------------------------------------------------------------------------


def _serve_core(n_arr, work, tie_u, rid, sub_u, net_du, net_ju, fault_u,
                ack_u, n_cap, scn: EngineScenario, static: EngineStatic,
                carry=None, t0=None):
    """One serving run as a ``lax.scan`` over slots; traceable under vmap.

    Inputs are the padded per-slot workload: ``n_arr (T,)`` arrival counts,
    ``work``/``tie_u``/``rid`` ``(T, A)`` arrival-lane batches (lanes
    ``>= n_arr[t]`` are masked no-ops, like slots ``>= horizon``), and
    ``sub_u (T, A, D)`` the SQ(d) subset uniforms (``D = sqd`` under the
    "sqd" policy, else 0 -- the lanes exist but carry nothing).  ``n_cap``
    (static) sizes the rid-indexed completion-slot carry.

    The slot body mirrors :class:`CareDispatcher` operation for operation:
    sequential within-slot routing (an inner scan over arrival lanes --
    each routed arrival immediately bumps the occupancy the next one
    sees), then fault transitions -> admit -> decode -> MSR drain ->
    shared-core trigger -> network delivery.  ``net_du`` / ``net_ju`` /
    ``fault_u`` are the pre-drawn ``(T, R)`` control-plane uniforms
    (zero-width ``(T, 0)`` when the corresponding kind is off, so the
    grid sharding specs are shape-stable); ``ack_u`` is the ``(T, 4, R)``
    ack/keepalive-channel stream of ``transport="ack"`` (``(T, 0, 0)``
    otherwise).
    ``static.policy`` picks the route step at trace time; the drain-time
    score and heterogeneous decode/drain rates consume the traced
    ``scn.decode_rates`` operand, so a rate ladder shares one program.

    Segment mode (``static.stream``): ``carry`` resumes a previous chunk's
    final state and ``t0`` offsets the slot clock so ``t`` is absolute
    across chunks (``act = t < horizon`` then doubles as the tail-padding
    mask of a partial last chunk, exactly like the fixed engine's padded
    horizon).  The rid lanes are ignored -- a request's identity reduces
    to its arrival slot, synthesised on device -- and completions fold
    into the :class:`StreamMetrics` carry slot-by-slot instead of the
    rid-indexed ``comp_slot`` scatter.  Every op the dynamics see (routing,
    admission, decode, drain, trigger, delivery) is identical to the fixed
    path, which is what makes any chunking bit-identical to it.
    Exactness notes: the reference dispatcher carries its approximation in
    float32 too, so every drain/score product is the same IEEE single op
    on both backends (dyadic or not); decode credits are integers from the
    shared ``workload.service_units`` schedule; tie-break and subset ranks
    are computed in f32 on both sides (:func:`pick_min_tied` /
    :func:`subset_mask`).
    """
    r_n, s_n, c_n = static.replicas, static.decode_slots, static.queue_cap
    a_n, t_n = work.shape[1], work.shape[0]
    ccfg = comm_lib.CommConfig(kind=static.comm, x=scn.x,
                               rt_period=scn.rt_period)
    has_net = static.network != "none"
    has_fault = static.fault != "none"
    has_ack = has_net and static.transport == "ack"
    if has_ack:
        ncfg = comm_lib.NetworkConfig(
            kind=static.network, delay=scn.net_delay,
            jitter=scn.net_jitter, drop=scn.net_drop,
            transport="ack", ack_timeout=scn.ack_timeout,
            backoff_base=scn.backoff_base, max_retries=scn.max_retries,
            ka_period=scn.ka_period,
        )
    elif has_net:
        ncfg = comm_lib.NetworkConfig(
            kind=static.network, delay=scn.net_delay,
            jitter=scn.net_jitter, drop=scn.net_drop,
        )
    rep_idx = jnp.arange(r_n, dtype=jnp.int32)
    # Per-replica emulated drain; msr_drain * 1.0 is exact, so the unused
    # operand cannot perturb the homogeneous path.
    drainv = scn.msr_drain * scn.decode_rates
    if static.policy == "drain":
        drain_slots = routing_lib.expected_drain_slots(
            scn.mean_prefill + scn.mean_decode, scn.decode_rates
        )
    # Pull family: the carry grows a (tokens, token_miss, token_sum)
    # triple (None otherwise -- the default program structure is
    # unchanged).  ServeConfig.static_part / CareDispatcher validated the
    # 1:1 policy<->comm pairing already.
    has_pull = static.policy in PULL_POLICIES

    def slot(carry, xs):
        # Position 9 (``comp_slot``) is the rid-indexed completion-slot
        # scatter in fixed mode and the StreamMetrics accumulators in
        # stream mode; position 5 (``arid``) holds request ids in fixed
        # mode and arrival slots in stream mode.
        (q_len, q_head, q_work, q_rid, rem, arid, approx, comm_state,
         rr_ptr, comp_slot, total_comp, dropped, net_state, faulted,
         pull_state) = carry
        (t, n_arr_t, work_t, tie_t, rid_t, sub_t, ndu_t, nju_t, fu_t,
         aku_t) = xs
        if static.stream:
            # A streamed request's identity is its arrival slot: the ring
            # stores it, completion turns it into a JCT on device.
            rid_t = jnp.full((a_n,), t, jnp.int32)
        act = t < scn.horizon
        # Decode-slot busy count is frozen during the arrival phase -- the
        # dispatcher routes against the previous slot's replica state.
        busy_cnt = (rem > 0).sum(axis=1).astype(jnp.int32)

        # Suspect-server mask (graceful degradation): computed once per
        # slot from the carried staleness clock -- the network age under
        # delayed delivery, else the trigger's slots-since-message counter
        # (RT keepalives reset either, doubling as failure detection).
        # suspect_age is a traced operand; 0 yields an all-True mask,
        # which is decision-identical to no masking on both backends.
        healthy = None
        if has_ack:
            # Keepalive-driven masking (transport="ack"): the last-heard
            # clock counts data *and* keepalive deliveries, and a server
            # that abandoned an update after max_retries (gave_up) is a
            # self-suspect until a later transmission is acked.
            h = (
                (scn.suspect_age <= 0)
                | (net_state.ka_age <= scn.suspect_age)
            ) & ((scn.suspect_age <= 0) | ~net_state.gave_up)
            healthy = jnp.where(jnp.any(h), h, True)
        elif has_net or has_fault:
            age = net_state.age if has_net else comm_state.slots_since_msg
            h = (scn.suspect_age <= 0) | (age <= scn.suspect_age)
            healthy = jnp.where(jnp.any(h), h, True)

        # --- 1. route this slot's arrivals, sequentially (inner scan) ---
        # The scan carries only the small (R,) routing state (each routed
        # arrival immediately bumps the occupancy the next one sees); the
        # ring writes are deferred and applied as one vectorised scatter
        # below -- admitted lanes never collide (successive admits to the
        # same replica take successive tails) and masked lanes are routed
        # out of bounds and dropped.
        def lane(lc, lx):
            q_len, approx, rr_ptr, dropped, lpull = lc
            u, sub_l, lane_i = lx
            live = act & (lane_i < n_arr_t)
            if static.comm == "exact":
                occ = (q_len + busy_cnt).astype(jnp.float32)
            else:
                occ = approx
            if static.policy == "rr":
                if healthy is None:
                    # Deterministic cyclic assignment; the pointer
                    # advances only on live lanes (the reference routes
                    # only actual arrivals).
                    j = (rr_ptr % r_n).astype(jnp.int32)
                    rr_ptr = rr_ptr + live.astype(jnp.int32)
                else:
                    # Masked round robin: skip suspect replicas to the
                    # cyclically-next healthy one (routing.route_rr's
                    # derivation; all-True mask == unmasked decisions,
                    # with the pointer held in its bounded form).
                    off = (
                        jnp.arange(r_n, dtype=jnp.int32) - rr_ptr
                    ) % r_n
                    off = jnp.where(healthy, off, r_n)
                    j = jnp.argmin(off).astype(jnp.int32)
                    rr_ptr = jnp.where(live, j + 1, rr_ptr)
            elif static.policy in PULL_POLICIES:
                tokens, token_miss = lpull
                score = (0 - tokens).astype(jnp.float32)
                if healthy is not None:
                    score = jnp.where(healthy, score, jnp.inf)
                is_min = score == jnp.min(score)
                if static.deterministic_ties:
                    rank = jnp.zeros((), jnp.int32)
                else:
                    n_ties = jnp.sum(is_min, dtype=jnp.int32)
                    rank = jnp.minimum(
                        (u * n_ties.astype(jnp.float32)).astype(jnp.int32),
                        n_ties - 1,
                    )
                cum = jnp.cumsum(is_min.astype(jnp.int32))
                j = jnp.argmax(cum == rank + 1).astype(jnp.int32)
                # Spend the routed replica's token (empty pool counts a
                # miss -- the uniform fallback the frontier reports).
                sel_t = (rep_idx == j) & live
                tok_j = jnp.sum(jnp.where(rep_idx == j, tokens, 0))
                token_miss = token_miss + (
                    live & (tok_j == 0)
                ).astype(jnp.int32)
                tokens = jnp.maximum(tokens - sel_t.astype(jnp.int32), 0)
                lpull = (tokens, token_miss)
            else:
                if static.policy == "drain":
                    score = occ * drain_slots
                else:
                    score = occ
                if static.policy == "sqd":
                    cand = subset_mask(sub_l, r_n, static.sqd, xp=jnp)
                    if healthy is not None:
                        # Suspect exclusion within the sampled subset; an
                        # all-suspect subset falls back to the raw sample
                        # (mirrors the reference dispatcher exactly).
                        m = cand & healthy
                        cand = jnp.where(jnp.any(m), m, cand)
                    score = jnp.where(cand, score, jnp.inf)
                elif healthy is not None:
                    score = jnp.where(healthy, score, jnp.inf)
                is_min = score == jnp.min(score)
                if static.deterministic_ties:
                    # Lowest-index ties: rank 0 in the shared rank
                    # arithmetic (the Pallas kernel convention).
                    rank = jnp.zeros((), jnp.int32)
                else:
                    n_ties = jnp.sum(is_min, dtype=jnp.int32)
                    rank = jnp.minimum(
                        (u * n_ties.astype(jnp.float32)).astype(jnp.int32),
                        n_ties - 1,
                    )
                cum = jnp.cumsum(is_min.astype(jnp.int32))
                j = jnp.argmax(cum == rank + 1).astype(jnp.int32)
            onehot = rep_idx == j
            len_j = jnp.sum(jnp.where(onehot, q_len, 0))
            # The numpy ring grows on demand; the traced ring is fixed, so
            # a full ring drops the arrival (counted -- equivalence tests
            # size queue_cap to keep this path cold).
            admit = live & (len_j < c_n)
            sel = onehot & admit
            tail = (jnp.sum(jnp.where(onehot, q_head, 0)) + len_j) % c_n
            q_len = q_len + sel.astype(jnp.int32)
            approx = approx + sel.astype(jnp.float32)
            dropped = dropped + (live & ~admit).astype(jnp.int32)
            return (q_len, approx, rr_ptr, dropped, lpull), (j, tail, admit)

        if static.route_backend == "pallas":
            # Fused arrival-lane routing: the kernel's fori_loop over lanes
            # replaces the inner scan, carrying the same (q_len, approx)
            # state and emitting the same deferred scatter operands.  The
            # rr pointer is untouched (the pallas path is jsaq-only).
            jv, tailv, admitv, q_len, approx, d_drop = kernel_ops.serve_route(
                tie_t, q_len, q_head, busy_cnt, approx, n_arr_t, act,
                cap=c_n, comm=static.comm,
            )
            dropped = dropped + d_drop
        else:
            lpull = (
                (pull_state[0], pull_state[1]) if has_pull else None
            )
            lane_xs = (tie_t, sub_t, jnp.arange(a_n, dtype=jnp.int32))
            (q_len, approx, rr_ptr, dropped, lpull), (jv, tailv, admitv) = (
                jax.lax.scan(
                    lane, (q_len, approx, rr_ptr, dropped, lpull), lane_xs
                )
            )
        jv = jnp.where(admitv, jv, r_n)  # out of bounds -> dropped scatter
        q_work = q_work.at[jv, tailv].set(work_t, mode="drop")
        q_rid = q_rid.at[jv, tailv].set(rid_t, mode="drop")

        # --- 1b. fault transitions (after routing, before admission) ----
        recovered = None
        if has_fault:
            adv_f, recovered = workload_lib.fault_transitions(
                faulted, fu_t, scn.crash_rate, scn.recover_rate
            )
            faulted = jnp.where(act, adv_f, faulted)
            recovered = recovered & act

        # --- 2. admit: fill free decode slots from the rings, FIFO ------
        free = rem <= 0
        free_rank = jnp.cumsum(free, axis=1) - 1
        n_admit = jnp.minimum(q_len, free.sum(axis=1, dtype=jnp.int32))
        n_admit = jnp.where(act, n_admit, 0)
        if has_fault and static.fault == "crash":
            # A crashed replica is frozen: queued requests wait (conserved)
            # and resume admission on recovery.
            n_admit = jnp.where(faulted, 0, n_admit)
        take = free & (free_rank < n_admit[:, None])
        qidx = (q_head[:, None] + free_rank) % c_n
        w_gather = jnp.take_along_axis(q_work, qidx, axis=1)
        r_gather = jnp.take_along_axis(q_rid, qidx, axis=1)
        rem = jnp.where(take, w_gather, rem)
        arid = jnp.where(take, r_gather, arid)
        q_head = (q_head + n_admit) % c_n
        q_len = q_len - n_admit

        # --- 3. decode: one iteration on every active slot --------------
        # Unit rates decrement by one; heterogeneous rates by the slot's
        # credit-schedule units (rem may go negative == free, matching the
        # reference).
        active = (rem > 0) & act
        if has_fault:
            if static.use_rates:
                nominal = workload_lib.service_units(t, scn.decode_rates)
                rates = scn.decode_rates
            else:
                nominal = jnp.ones((r_n,), jnp.int32)
                rates = None
            units = workload_lib.faulted_service_units(
                t, faulted, nominal, static.fault, scn.slow_factor,
                rates=rates,
            )
            rem = rem - units[:, None] * active.astype(rem.dtype)
        elif static.use_rates:
            units = workload_lib.service_units(t, scn.decode_rates)
            rem = rem - units[:, None] * active.astype(rem.dtype)
        else:
            rem = rem - active.astype(rem.dtype)
        done = active & (rem <= 0)
        completions = done.sum(axis=1, dtype=jnp.int32)
        if static.stream:
            # arid carries arrival slots: the JCT is available on device
            # the slot a request completes, and folds straight into the
            # O(1) accumulators (post-warmup completions only).
            jct_t = t - arid + 1
            comp_slot = comp_slot.update(jct_t, done & (t >= scn.warmup))
        else:
            comp_idx = jnp.where(done, arid, n_cap).reshape(-1)
            comp_slot = comp_slot.at[comp_idx].max(
                jnp.where(done, t, -1).reshape(-1).astype(jnp.int32),
                mode="drop",
            )
        arid = jnp.where(done, -1, arid)
        total_comp = total_comp + jnp.sum(completions, dtype=jnp.int32)

        # --- 4. MSR drain (per-replica, decode-rate scaled) --------------
        busy = (approx > 0) & act
        approx = jnp.maximum(
            approx - drainv * busy.astype(jnp.float32), 0.0
        )

        # --- 5. trigger (shared core) -- freeze counters past horizon ----
        true_occ = (q_len + (rem > 0).sum(axis=1, dtype=jnp.int32)).astype(
            jnp.float32
        )
        err = jnp.abs(true_occ - approx)
        # Crashed replicas cannot send (counters keep advancing, so the
        # first healthy slot re-fires a due trigger); a recovery forces a
        # resync message regardless of the trigger predicate.  Under the
        # network model the trigger only expresses *intent*: message
        # accounting and the dispatcher-view update belong to net_step.
        can_send = force = None
        if has_fault and static.fault == "crash":
            can_send = ~faulted
            force = recovered
        trig, comm_adv = comm_lib.evaluate(
            comm_state, ccfg, err, completions,
            can_send=can_send, force=force, q=true_occ,
            count_msgs=not has_net,
        )
        trig = trig & act
        if has_net:
            # --- 6. network delivery (delay/jitter/drop + piggyback) ----
            if has_ack:
                delivered, payload, sent, net_adv = comm_lib.net_step_ack(
                    net_state, ncfg, trig, true_occ, ndu_t, nju_t, aku_t,
                    can_send=can_send,
                )
            else:
                delivered, payload, sent, net_adv = comm_lib.net_step(
                    net_state, ncfg, trig, true_occ, ndu_t, nju_t,
                    can_send=can_send,
                )
            delivered = delivered & act
            extra = jnp.where(act, sent, 0)
            if static.policy == "sqd":
                # SQ(d)'s 2d query round-trips per routed arrival, on the
                # same wire (mirrors CareDispatcher.route).
                n_live = jnp.minimum(n_arr_t, a_n).astype(jnp.int32)
                extra = extra + jnp.where(act, 2 * static.sqd * n_live, 0)
            comm_adv = dataclasses.replace(
                comm_adv, msgs=comm_adv.msgs + extra
            )
            net_state = jax.tree.map(
                lambda adv, old: jnp.where(act, adv, old), net_adv, net_state
            )
            approx = jnp.where(delivered, payload, approx)
        else:
            approx = jnp.where(trig, true_occ, approx)
        comm_state = jax.tree.map(
            lambda adv, old: jnp.where(act, adv, old), comm_adv, comm_state
        )
        if has_pull:
            # --- 7. pull-token refresh: a delivered token message
            # *overwrites* the sender's pool slot from the send-time queue
            # snapshot (1 if idle for JIQ, the threshold headroom for hsq
            # -- f32 truncated to int32, exactly like the reference).  A
            # crashed replica stops sending, so its stale tokens drain to
            # zero and are never replenished.
            tokens, token_miss = lpull
            if static.comm == "jiq":
                def _fresh(p):
                    return (p == 0.0).astype(jnp.int32)
            else:  # hsq
                def _fresh(p):
                    return jnp.maximum(scn.x - p, 0.0).astype(jnp.int32)
            if has_net:
                tokens = jnp.where(delivered, _fresh(payload), tokens)
            else:
                tokens = jnp.where(trig, _fresh(true_occ), tokens)
            token_sum = pull_state[2] + jnp.where(
                act, jnp.sum(tokens, dtype=jnp.int32), 0
            )
            pull_state = (tokens, token_miss, token_sum)

        carry = (q_len, q_head, q_work, q_rid, rem, arid, approx, comm_state,
                 rr_ptr, comp_slot, total_comp, dropped, net_state, faulted,
                 pull_state)
        out = true_occ.astype(jnp.int32) if static.trace_occupancy else None
        return carry, out

    init = _engine_init(static, n_cap) if carry is None else carry
    tv = jnp.arange(t_n, dtype=jnp.int32)
    if t0 is not None:
        tv = tv + t0  # absolute slot clock of the segment engine
    xs = (tv, n_arr, work, tie_u, rid, sub_u, net_du, net_ju, fault_u,
          ack_u)
    final, occ_trace = jax.lax.scan(slot, init, xs)
    if static.stream:
        # Segment mode: the caller threads the whole carry to the next
        # chunk; metrics/counters are read off it after the last one.
        return final
    (q_len, _, _, _, rem, _, _, comm_state, _, comp_slot, total_comp,
     dropped, net_state, _, pull_state) = final
    final_occ = q_len + (rem > 0).sum(axis=1, dtype=jnp.int32)
    net_drops = net_state.drops if has_net else jnp.zeros((), jnp.int32)
    token_miss = (
        pull_state[1] if has_pull else jnp.zeros((), jnp.int32)
    )
    token_sum = (
        pull_state[2] if has_pull else jnp.zeros((), jnp.int32)
    )
    outs = (comp_slot, comm_state.msgs, total_comp, dropped, final_occ,
            net_drops, token_miss, token_sum)
    if has_net and static.transport == "ack":
        # Retransmit total (ack cells only -- the fire_forget output
        # tuple, and hence its compiled program, is untouched).
        outs = outs + (net_state.retrans,)
    if static.trace_occupancy:
        outs = outs + (occ_trace,)
    return outs


def _engine_init(static: EngineStatic, n_cap: int):
    """The scan/stream carry at slot 0 (shared by both engine modes).

    Position 9 is the rid-indexed completion-slot scatter in fixed mode
    and the :class:`StreamMetrics` accumulators in stream mode; the
    control-plane subtrees are ``None`` when their kinds are off, so the
    default program structure is unchanged.
    """
    r_n, s_n, c_n = static.replicas, static.decode_slots, static.queue_cap
    comm0, net0, fault0 = comm_lib.control_plane_init(
        r_n, network=static.network, fault=static.fault,
        transport=static.transport, payload_dtype=jnp.float32,
    )
    return (
        jnp.zeros((r_n,), jnp.int32),  # q_len
        jnp.zeros((r_n,), jnp.int32),  # q_head
        jnp.zeros((r_n, c_n), jnp.int32),  # q_work ring
        jnp.full((r_n, c_n), -1, jnp.int32),  # q_rid / q_arr ring
        jnp.zeros((r_n, s_n), jnp.int32),  # rem (decode slots)
        jnp.full((r_n, s_n), -1, jnp.int32),  # arid / arrival slots
        jnp.zeros((r_n,), jnp.float32),  # approx
        comm0,
        jnp.zeros((), jnp.int32),  # rr_ptr ("rr" policy)
        StreamMetrics.init() if static.stream
        else jnp.full((n_cap,), -1, jnp.int32),  # comp_slot (rid-indexed)
        jnp.zeros((), jnp.int32),  # total completions
        jnp.zeros((), jnp.int32),  # dropped
        net0,
        fault0,
        # Pull-token pool + counters (None keeps the default structure).
        (
            jnp.zeros((r_n,), jnp.int32),  # tokens
            jnp.zeros((), jnp.int32),  # token_miss (empty-pool routes)
            jnp.zeros((), jnp.int32),  # token_sum (pool-occupancy integral)
        )
        if static.policy in PULL_POLICIES
        else None,
    )


@functools.partial(jax.jit, static_argnums=(10, 11))
def _serve_one_jit(n_arr, work, tie_u, rid, sub_u, net_du, net_ju, fault_u,
                   ack_u, scn, n_cap, static):
    return _serve_core(n_arr, work, tie_u, rid, sub_u, net_du, net_ju,
                       fault_u, ack_u, n_cap, scn, static)


_SERVE_GRID_PROGRAMS: list = []  # jitted grid wrappers, one per (static, n_dev)


@functools.lru_cache(maxsize=None)
def _serve_grid_fn(static: EngineStatic, n_cap: int, n_dev: int):
    """The one compiled program for a serving grid: vmap inside shard_map.

    Mirrors ``slotted_sim._grid_fn``: cached per (EngineStatic, rid
    capacity, device count); ``n_dev == 1`` skips the mesh (plain jitted
    vmap).  Re-invocations with a new batch length retrace -- counted by
    :func:`serve_compile_count`.
    """
    batched = jax.vmap(
        lambda n_arr, work, tie_u, rid, sub_u, net_du, net_ju, fault_u,
        ack_u, scn:
        _serve_core(
            n_arr, work, tie_u, rid, sub_u, net_du, net_ju, fault_u,
            ack_u, n_cap, scn, static
        )
    )
    if n_dev <= 1:
        fn = jax.jit(batched)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.asarray(jax.local_devices()[:n_dev]), ("runs",))
        spec = (P("runs"),) * 10
        fn = jax.jit(
            shard_map(batched, mesh=mesh, in_specs=spec, out_specs=P("runs"))
        )
    _SERVE_GRID_PROGRAMS.append(fn)
    return fn


def serve_compile_count() -> int:
    """Total XLA programs compiled by the serving grid path so far.

    Same accounting as ``slotted_sim.grid_compile_count``: sums the
    compiled-shape cache sizes of every jitted grid wrapper, so batch-shape
    retraces count as the real compile work they are.
    """
    return sum(
        getattr(f, "_cache_size", lambda: 1)() for f in _SERVE_GRID_PROGRAMS
    )


@dataclasses.dataclass
class ServeResult:
    """One serving run's outputs (host-side numpy; jct in rid order)."""

    jct: np.ndarray  # (completed,) completion times, rid (arrival) order
    jct_by_rid: np.ndarray  # (offered,) -1 where never completed
    completed: int
    offered: int
    messages: int
    dropped: int  # arrivals rejected on a full pending ring (jax path only)
    final_occupancy: np.ndarray  # (R,)
    mean_jct: float
    p99_jct: float
    msgs_per_completion: float
    net_drops: int = 0  # messages lost in flight (network="net" only)
    token_misses: int = 0  # pull routes that found an empty token pool
    token_sum: int = 0  # end-of-slot token-pool occupancy, summed over slots
    retrans: int = 0  # data retransmits (transport="ack" only)
    occupancy: Optional[np.ndarray] = None  # (T, R) when trace_occupancy

    @staticmethod
    def from_run(wl: ServeWorkload, comp_slot, msgs, total_comp, dropped,
                 final_occ, net_drops=0, token_misses=0, token_sum=0,
                 retrans=0, occ_trace=None) -> "ServeResult":
        comp_slot = np.asarray(comp_slot)[: wl.total].astype(np.int64)
        done = comp_slot >= 0
        jct_by_rid = np.where(done, comp_slot - wl.arrival_slot + 1, -1)
        jct = jct_by_rid[done]
        completed = int(done.sum())
        msgs = int(msgs)
        return ServeResult(
            jct=jct,
            jct_by_rid=jct_by_rid,
            completed=completed,
            offered=wl.total,
            messages=msgs,
            dropped=int(dropped),
            final_occupancy=np.asarray(final_occ),
            mean_jct=float(jct.mean()) if jct.size else 0.0,
            p99_jct=float(np.percentile(jct, 99)) if jct.size else 0.0,
            msgs_per_completion=msgs / max(int(total_comp), 1),
            net_drops=int(net_drops),
            token_misses=int(token_misses),
            token_sum=int(token_sum),
            retrans=int(retrans),
            occupancy=None if occ_trace is None else np.asarray(occ_trace),
        )


def _round_up(n: int, mult: int) -> int:
    return ((max(n, 1) + mult - 1) // mult) * mult


def _split_extra_outs(out_np, static: EngineStatic):
    """Split ``_serve_core``'s variable output tail by the static flags.

    The first 8 outputs are fixed; ``retrans`` rides along only under
    ``transport="ack"`` and the occupancy trace only under
    ``trace_occupancy`` (keeping the default output tuple -- and hence
    the compiled fire-and-forget program -- byte-identical).
    """
    base, rest = list(out_np[:8]), list(out_np[8:])
    retrans = 0
    if static.network != "none" and static.transport == "ack":
        retrans, rest = rest[0], rest[1:]
    occ = rest[0] if rest else None
    return base, retrans, occ


def _pad_workload(wl: ServeWorkload, t_pad: int, a_pad: int, d: int = 0,
                  with_rid: bool = True):
    """Pad one workload to the (T, A) lane grid the static program takes.

    ``d`` is the subset-uniform lane depth: ``sqd`` under the "sqd" policy
    (the first ``d`` ``sub_u`` columns ride along as a ``(T, A, d)``
    operand), 0 otherwise (a zero-width array -- no memory, no transfer).
    ``with_rid=False`` (stream mode) makes the rid lanes zero-width too:
    the segment engine synthesises a request's identity from its arrival
    slot on device, so the rid gather/transfer would be pure overhead in
    the per-chunk host loop.
    Fully vectorised (one fancy-indexed gather per array): this runs per
    (cell, seed) on every ``serve_grid`` invocation, including the warm
    replays benchmarks time, so a Python per-slot loop would bill host
    padding to the measured steady-state throughput.
    """
    t = wl.n_arr.shape[0]
    n_arr = np.zeros(t_pad, np.int32)
    n_arr[:t] = wl.n_arr
    work = np.zeros((t_pad, a_pad), np.int32)
    tie_u = np.zeros((t_pad, a_pad), np.float32)
    rid = np.zeros((t_pad, a_pad if with_rid else 0), np.int32)
    sub_u = np.zeros((t_pad, a_pad, d), np.float32)
    if wl.total:
        lane = np.arange(a_pad, dtype=np.int64)[None, :]
        mask = lane < wl.n_arr[:, None]  # (t, a_pad) live lanes
        idx = np.minimum(wl.base[:, None] + lane, wl.total - 1)
        work[:t] = np.where(mask, wl.work[idx], 0)
        tie_u[:t] = np.where(mask, wl.tie_u[idx], 0.0)
        if with_rid:
            rid[:t] = np.where(mask, idx, 0)
        if d:
            sub_u[:t] = np.where(
                mask[..., None], wl.sub_u[idx, :d], 0.0
            )

    def pad_cp(arr):
        # Control-plane uniforms: (T, R) per-slot rows, zero-width when
        # the corresponding kind is off (no memory, no transfer).
        if arr is None:
            return np.zeros((t_pad, 0), np.float32)
        out = np.zeros((t_pad, arr.shape[1]), np.float32)
        out[: arr.shape[0]] = arr
        return out

    def pad_ack(arr):
        # Ack/keepalive uniforms: (T, 4, R) slabs, zero-width when the
        # transport is fire_forget (no memory, no transfer).
        if arr is None:
            return np.zeros((t_pad, 0, 0), np.float32)
        out = np.zeros((t_pad,) + arr.shape[1:], np.float32)
        out[: arr.shape[0]] = arr
        return out

    return (n_arr, work, tie_u, rid, sub_u, pad_cp(wl.net_drop_u),
            pad_cp(wl.net_jit_u), pad_cp(wl.fault_u), pad_ack(wl.ack_u))


def serve_grid(
    seeds: Sequence[int],
    static: EngineStatic,
    cells: Sequence[ServeConfig],
    *,
    shard: bool = True,
) -> list[list[ServeResult]]:
    """Run a whole serving grid as **one compiled program**.

    Args:
      seeds: integer seeds; every cell replays the same seed set (the
        workload sampler is host-side numpy, keyed per (cell workload
        parameters, seed) -- cells differing only in comm thresholds share
        streams, the paper's comparison method).
      static: the shared program structure.  Every cell's
        ``static_part()`` must agree with it on shapes and comm kind;
        ``static.slots`` is the padded scan length (>= every cell's
        ``slots``) and ``static.max_arrivals`` the arrival-lane width
        (``0`` = derive from the sampled batch, rounded up to a multiple
        of 8 so near-miss batches reuse the program).
      cells: the grid cells (scenario operands + workload parameters).
      shard: shard the flattened ``(C*S,)`` run axis across local devices
        with ``shard_map`` (ragged batches padded with wrap-around
        duplicates, dropped on output).

    Returns:
      ``results[c][s]`` -- one :class:`ServeResult` per (cell, seed),
      bit-identical to the numpy reference ``run_serving_sim`` (asserted
      by ``tests/test_serve_engine.py``).
    """
    from repro.core.care.slotted_sim import _pad_indices

    cells = list(cells)
    seeds = [int(s) for s in seeds]
    for cell in cells:
        cs = cell.static_part()
        if (
            cs.replicas, cs.decode_slots, cs.queue_cap, cs.comm,
            cs.policy, cs.sqd, cs.use_rates, cs.route_backend,
            cs.deterministic_ties, cs.network, cs.transport, cs.fault,
        ) != (
            static.replicas, static.decode_slots, static.queue_cap,
            static.comm, static.policy, static.sqd, static.use_rates,
            static.route_backend, static.deterministic_ties,
            static.network, static.transport, static.fault,
        ):
            raise ValueError(
                f"cell static part {cs} does not match grid static {static}"
            )
        if cell.slots > static.slots:
            raise ValueError(
                f"cell slots {cell.slots} exceeds padded length {static.slots}"
            )

    wls = [[workload_for(cell, s) for s in seeds] for cell in cells]
    flat_wls = [w for row in wls for w in row]
    a_need = max(int(w.n_arr.max()) for w in flat_wls)
    a_pad = _round_up(a_need, 8)
    if static.max_arrivals:
        if static.max_arrivals < a_need:
            raise ValueError(
                f"static.max_arrivals={static.max_arrivals} below the "
                f"sampled batch maximum {a_need}"
            )
        a_pad = static.max_arrivals
    static = dataclasses.replace(static, max_arrivals=a_pad)
    n_cap = _round_up(max(w.total for w in flat_wls), 1024)
    d = static.sqd if static.policy == "sqd" else 0

    padded = [_pad_workload(w, static.slots, a_pad, d) for w in flat_wls]
    arrs = [jnp.asarray(np.stack([p[i] for p in padded])) for i in range(9)]
    scn_flat = stack_scenarios(
        [cell.scenario() for cell in cells for _ in seeds]
    )

    n = len(flat_wls)
    n_dev = jax.local_device_count() if shard else 1
    idx = _pad_indices(n, n_dev)
    if len(idx) != n:
        arrs = [a[idx] for a in arrs]
        scn_flat = jax.tree.map(lambda a: a[idx], scn_flat)

    out = _serve_grid_fn(static, n_cap, n_dev)(*arrs, scn_flat)
    out_np = [np.asarray(o)[:n] for o in out]
    base, retrans, occ = _split_extra_outs(out_np, static)
    s = len(seeds)
    return [
        [
            ServeResult.from_run(
                wls[c][j], *(o[c * s + j] for o in base),
                retrans=0 if isinstance(retrans, int)
                else retrans[c * s + j],
                occ_trace=None if occ is None else occ[c * s + j],
            )
            for j in range(s)
        ]
        for c in range(len(cells))
    ]


def serve_one(seed: int, cell: ServeConfig, *,
              trace_occupancy: bool = False,
              workload: Optional[ServeWorkload] = None) -> ServeResult:
    """Run one serving cell on the jax engine (its own compiled program).

    The single-run analogue of :func:`serve_grid` -- used by the
    equivalence tests as the per-cell reference the fused grid must
    reproduce (padding the arrival lanes or the rid capacity differently
    must not change results).  ``workload`` overrides the cached sampler
    stream (the chunk-invariance tests feed the assembled stream-sampler
    trace to both this fixed-horizon path and :func:`serve_stream`); it
    must cover at most ``cell.slots`` slots.
    """
    wl = workload if workload is not None else workload_for(cell, seed)
    if wl.n_arr.shape[0] > cell.slots:
        raise ValueError(
            f"workload covers {wl.n_arr.shape[0]} slots, cell.slots is "
            f"{cell.slots}"
        )
    a_need = max(int(wl.n_arr.max()), 1)
    if cell.max_arrivals:
        if cell.max_arrivals < a_need:
            raise ValueError(
                f"max_arrivals={cell.max_arrivals} below the sampled "
                f"per-slot maximum {a_need}"
            )
        a_pad = cell.max_arrivals  # pinned by the caller: reuse its shape
    else:
        a_pad = _round_up(a_need, 8)
    static = dataclasses.replace(
        cell.static_part(),
        max_arrivals=a_pad,
        trace_occupancy=trace_occupancy,
    )
    n_cap = _round_up(wl.total, 1024)
    d = static.sqd if static.policy == "sqd" else 0
    padded = _pad_workload(wl, static.slots, static.max_arrivals, d)
    out = _serve_one_jit(
        *(jnp.asarray(p) for p in padded), cell.scenario(), n_cap, static,
    )
    base, retrans, occ = _split_extra_outs(
        [np.asarray(o) for o in out], static
    )
    return ServeResult.from_run(wl, *base, retrans=retrans, occ_trace=occ)


# ---------------------------------------------------------------------------
# Segment engine (serve_stream): chunked unbounded-horizon serving.
#
# The fixed-horizon scan materialises the whole trace up front, which caps
# runs at host memory and leaves the host idle while the device computes.
# The segment engine runs the same slot body chunk by chunk: a jitted step
# carries the full engine state pytree across chunks with donated buffers
# (state updated in place), while the host samples chunk k+1's workload
# slab during chunk k's device execution -- JAX async dispatch gives the
# overlap for free because the driver never blocks mid-stream.  Workload
# blocks are keyed by prefix-stable SeedSequence children, so any chunking
# replays the identical trace bit for bit -- and so does the monolithic
# fixed-horizon scan fed the assembled trace (the golden tests' contract).
# This is also the seam a live arrival feed plugs into later: swap the
# sampler for a queue drain, resume from a snapshotted carry
# (comm.snapshot_state / comm.restore_state).
# ---------------------------------------------------------------------------

# Granularity of the prefix-stable stream sampler: every quantity of block
# j (slots [j*B, (j+1)*B)) is drawn from its own SeedSequence child keyed
# (stream, j), so block j's bytes never depend on how -- or whether --
# other blocks were sampled.  Chunk boundaries need not align with blocks.
STREAM_BLOCK = 1024


@dataclasses.dataclass(frozen=True)
class StreamParams:
    """Workload parameters of one request stream (hashable).

    The stream analogue of :meth:`ServeConfig.workload_key`: everything
    the sampler needs, nothing the router consumes.  ``diurnal_amp`` /
    ``diurnal_period`` modulate the arrival rate sinusoidally
    (``rate * (1 + amp * sin(2 pi t / period))``) -- the simulated-days
    soak cycles of the steady-state claims; 0/0 keeps a flat rate.
    """

    replicas: int
    decode_slots: int
    load: float
    mean_prefill: float = 4.0
    mean_decode: float = 64.0
    rate_scale: float = 1.0
    with_net: bool = False
    with_fault: bool = False
    with_ack: bool = False
    diurnal_amp: float = 0.0
    diurnal_period: int = 0

    @staticmethod
    def for_cell(cell: ServeConfig, *, diurnal_amp: float = 0.0,
                 diurnal_period: int = 0) -> "StreamParams":
        return StreamParams(
            replicas=cell.replicas,
            decode_slots=cell.decode_slots,
            load=cell.load,
            mean_prefill=float(cell.mean_prefill),
            mean_decode=float(cell.mean_decode),
            rate_scale=cell.rate_scale(),
            with_net=cell.network != "none",
            with_fault=cell.fault != "none",
            with_ack=cell.network != "none" and cell.transport == "ack",
            diurnal_amp=diurnal_amp,
            diurnal_period=diurnal_period,
        )


@dataclasses.dataclass
class _StreamBlock:
    """One sampled block: per-slot arrivals plus per-arrival draws."""

    n_arr: np.ndarray  # (B,) int64
    cum: np.ndarray  # (B + 1,) int64 arrivals before each in-block slot
    prefill: np.ndarray  # (total,) int64
    decode: np.ndarray  # (total,) int64
    work: np.ndarray  # (total,) int64
    tie_u: np.ndarray  # (total,) float32
    sub_u: np.ndarray  # (total, SQD_MAX) float32
    net_drop_u: Optional[np.ndarray]  # (B, R) float32
    net_jit_u: Optional[np.ndarray]  # (B, R) float32
    fault_u: Optional[np.ndarray]  # (B, R) float32
    ack_u: Optional[np.ndarray]  # (B, 4, R) float32


class StreamSampler:
    """Prefix-stable chunked workload sampling (host side of the stream).

    Five root ``SeedSequence`` children split the independent streams
    exactly like :func:`sample_workload` (arrivals/sizes, tie-breaks,
    SQ(d) subsets, network uniforms, fault uniforms); block ``j`` of each
    stream then draws from the *j-th child of that child*, constructed
    statelessly as ``SeedSequence(entropy, spawn_key + (j,))``.  Spawning
    is prefix-stable, so block j's bytes are a pure function of
    (seed, params, j): slabs of any size, sampled in any order, assemble
    into one well-defined infinite trace.  A small LRU of decoded blocks
    keeps sequential slab iteration O(chunk) in time and O(1) in memory.
    """

    _CACHE_BLOCKS = 8

    def __init__(self, seed: int, params: StreamParams):
        self.seed = int(seed)
        self.params = params
        root = np.random.SeedSequence(self.seed)
        # workload, tie, subset, net, fault, ack -- spawning is
        # prefix-stable, so the sixth (ack) child cannot move the first
        # five streams' bytes.
        self._roots = root.spawn(6)
        self._cache: dict[int, _StreamBlock] = {}

    def _rng(self, stream: int, j: int) -> np.random.Generator:
        child = self._roots[stream]
        ss = np.random.SeedSequence(
            entropy=child.entropy, spawn_key=child.spawn_key + (j,)
        )
        return np.random.default_rng(ss)

    def rate_at(self, t: np.ndarray) -> np.ndarray:
        """Offered per-slot arrival rate at absolute slots ``t``."""
        p = self.params
        mean_work = p.mean_prefill + p.mean_decode
        base = p.load * p.replicas * p.decode_slots * p.rate_scale / mean_work
        if not p.diurnal_period:
            return np.full(np.shape(t), base)
        phase = 2.0 * np.pi * np.asarray(t, np.float64) / p.diurnal_period
        return base * (1.0 + p.diurnal_amp * np.sin(phase))

    def _block(self, j: int) -> _StreamBlock:
        blk = self._cache.get(j)
        if blk is not None:
            return blk
        p, b = self.params, STREAM_BLOCK
        t = j * b + np.arange(b, dtype=np.int64)
        wrng = self._rng(0, j)
        n_arr = wrng.poisson(self.rate_at(t)).astype(np.int64)
        total = int(n_arr.sum())
        prefill = 1 + wrng.poisson(p.mean_prefill, size=total).astype(np.int64)
        decode = 1 + wrng.poisson(p.mean_decode, size=total).astype(np.int64)
        work = np.maximum(prefill + decode, 1)
        tie_u = self._rng(1, j).random(size=total, dtype=np.float32)
        sub_u = self._rng(2, j).random(size=(total, SQD_MAX), dtype=np.float32)
        net_drop_u = net_jit_u = fault_u = ack_u = None
        if p.with_net:
            nrng = self._rng(3, j)
            net_drop_u = nrng.random(size=(b, p.replicas), dtype=np.float32)
            net_jit_u = nrng.random(size=(b, p.replicas), dtype=np.float32)
        if p.with_fault:
            fault_u = self._rng(4, j).random(
                size=(b, p.replicas), dtype=np.float32
            )
        if p.with_ack:
            ack_u = self._rng(5, j).random(
                size=(b, 4, p.replicas), dtype=np.float32
            )
        blk = _StreamBlock(
            n_arr=n_arr,
            cum=np.concatenate([[0], np.cumsum(n_arr)]).astype(np.int64),
            prefill=prefill, decode=decode, work=work,
            tie_u=tie_u, sub_u=sub_u,
            net_drop_u=net_drop_u, net_jit_u=net_jit_u, fault_u=fault_u,
            ack_u=ack_u,
        )
        if len(self._cache) >= self._CACHE_BLOCKS:
            self._cache.pop(next(iter(self._cache)))
        self._cache[j] = blk
        return blk

    def slab(self, t0: int, t1: int) -> ServeWorkload:
        """The trace restricted to slots ``[t0, t1)`` as a ServeWorkload.

        ``base`` is slab-local (rid of a slot's first arrival *within the
        slab's arrays*); ``arrival_slot`` is absolute.  Bit-identical to
        the same span of any other slabbing -- the chunking contract.
        """
        if not 0 <= t0 < t1:
            raise ValueError(f"bad slab bounds [{t0}, {t1})")
        b = STREAM_BLOCK
        parts: list[tuple] = []
        for j in range(t0 // b, (t1 - 1) // b + 1):
            blk = self._block(j)
            lo = max(t0 - j * b, 0)
            hi = min(t1 - j * b, b)
            a0, a1 = int(blk.cum[lo]), int(blk.cum[hi])
            parts.append((blk, lo, hi, a0, a1))
        n_arr = np.concatenate([blk.n_arr[lo:hi] for blk, lo, hi, _, _ in parts])
        cat = lambda f: np.concatenate(  # noqa: E731 -- local glue
            [getattr(blk, f)[a0:a1] for blk, _, _, a0, a1 in parts]
        )
        cat_cp = lambda f: (  # noqa: E731
            None
            if getattr(parts[0][0], f) is None
            else np.concatenate(
                [getattr(blk, f)[lo:hi] for blk, lo, hi, _, _ in parts]
            )
        )
        return ServeWorkload(
            n_arr=n_arr,
            base=np.concatenate([[0], np.cumsum(n_arr)[:-1]]).astype(np.int64),
            prefill=cat("prefill"), decode=cat("decode"), work=cat("work"),
            tie_u=cat("tie_u"), sub_u=cat("sub_u"),
            arrival_slot=np.repeat(np.arange(t0, t1, dtype=np.int64), n_arr),
            net_drop_u=cat_cp("net_drop_u"), net_jit_u=cat_cp("net_jit_u"),
            fault_u=cat_cp("fault_u"), ack_u=cat_cp("ack_u"),
        )

    def full(self, slots: int) -> ServeWorkload:
        """The assembled monolithic trace of the first ``slots`` slots.

        Feeds the fixed-horizon reference (``serve_one(workload=...)`` /
        ``run_serving_sim(workload=...)``) in the chunk-invariance golden
        tests; O(slots) memory, so tests/examples only.
        """
        return self.slab(0, slots)


_STREAM_PROGRAMS: list = []  # jitted chunk steps, for compile accounting


@functools.lru_cache(maxsize=None)
def _stream_step_fn(static: EngineStatic):
    """The jitted chunk step: one compiled program per static structure.

    ``static.slots`` is the chunk length.  ``donate_argnums=(0,)`` donates
    the carry -- queues, CommState, NetState, fault mask, StreamMetrics --
    so XLA updates the state buffers in place across chunks instead of
    allocating a fresh copy per call.  ``static.max_arrivals`` is the
    chunk's padded lane width: a grown slab retraces once per new width
    (widths are rounded up, so growth stabilises fast) and lane padding
    is masked no-ops, so results never depend on it.
    """

    def step(carry, t0, n_arr, work, tie_u, rid, sub_u, net_du, net_ju,
             fault_u, ack_u, scn):
        return _serve_core(
            n_arr, work, tie_u, rid, sub_u, net_du, net_ju, fault_u,
            ack_u, 0, scn, static, carry=carry, t0=t0,
        )

    fn = jax.jit(step, donate_argnums=(0,))
    _STREAM_PROGRAMS.append(fn)
    return fn


def stream_compile_count() -> int:
    """Compiled chunk-step programs so far (same accounting as the grid)."""
    return sum(
        getattr(f, "_cache_size", lambda: 1)() for f in _STREAM_PROGRAMS
    )


@dataclasses.dataclass
class StreamState:
    """Resumable segment-engine state between :func:`serve_stream` calls.

    ``carry`` is the device pytree the next chunk step consumes (it is
    *donated* on resume -- a state can be resumed once; snapshot it with
    :func:`repro.core.care.comm.snapshot_state` first to keep a copy).
    """

    carry: tuple
    t_next: int
    offered: int
    a_pad: int
    sampler: StreamSampler


@dataclasses.dataclass
class StreamResult:
    """One stream segment's outputs (host-side scalars + histogram)."""

    slots: int  # slots run in this segment (cumulative if resumed)
    offered: int
    completed: int  # all completions, warmup included
    dropped: int
    messages: int
    net_drops: int
    count: int  # post-warmup completions measured by the accumulators
    mean_jct: float
    std_jct: float
    max_jct: int
    hist: np.ndarray  # (metrics.HIST_BUCKETS,) int64
    final_occupancy: np.ndarray  # (R,)
    state: StreamState
    token_misses: int = 0  # pull routes that found an empty token pool
    token_sum: int = 0  # end-of-slot token-pool occupancy over slots
    retrans: int = 0  # data retransmits (transport="ack" only)

    @property
    def msgs_per_slot(self) -> float:
        return self.messages / max(self.slots, 1)

    @property
    def msgs_per_completion(self) -> float:
        return self.messages / max(self.completed, 1)

    def jct_summary(self) -> dict:
        """NaN-safe summary (tail quantiles from the log histogram)."""
        return metrics_lib.stream_summary(
            self.count, self.mean_jct,
            self.std_jct * self.std_jct * max(self.count, 1),
            self.max_jct, self.hist,
        )


def serve_stream(
    seed: int,
    cell: ServeConfig,
    *,
    chunk: int = 4096,
    warmup: int = 0,
    slots: Optional[int] = None,
    sampler: Optional[StreamSampler] = None,
    state: Optional[StreamState] = None,
    prefetch: bool = True,
    diurnal_amp: float = 0.0,
    diurnal_period: int = 0,
) -> StreamResult:
    """Run one serving cell as a chunked stream in bounded memory.

    The segment engine: ``slots`` (default ``cell.slots``) total slots run
    as ``ceil(slots / chunk)`` jitted chunk steps threading one donated
    carry.  The host samples chunk k+1's slab while the device executes
    chunk k (``prefetch=True``; JAX async dispatch -- the driver never
    blocks mid-stream), so workload generation rides inside device time.
    ``prefetch=False`` is the synchronous no-prefetch reference the
    overlap benchmark compares against: identical results, but each slab
    is sampled only after the previous chunk's state is materialised.

    Bit-identity contract: for any chunk size -- and for the monolithic
    fixed-horizon engine fed ``StreamSampler.full(slots)`` -- every
    counter and every carried state array is identical bit for bit
    (golden-tested).  ``warmup`` discards completions landing before that
    absolute slot from the JCT accumulators (steady-state measurement);
    counters (messages, completions, drops) are never warmup-gated.

    ``state`` resumes a previous segment (its carry is donated -- resume a
    state at most once).  Totals (slots/offered/messages/...) are
    cumulative across resumed segments.  ``t + slots`` must stay below
    2^31 (the i32 slot clock).
    """
    if cell.route_backend == "pallas" and cell.policy != "jsaq":
        raise ValueError("stream mode inherits the pallas jsaq-only limits")
    slots = cell.slots if slots is None else int(slots)
    if slots <= 0:
        raise ValueError(f"slots must be positive, got {slots}")
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    base_static = cell.static_part()  # validates the cell
    d = base_static.sqd if base_static.policy == "sqd" else 0

    if state is not None:
        sampler = state.sampler
        t_start, offered = state.t_next, state.offered
        carry, a_pad = state.carry, state.a_pad
    else:
        if sampler is None:
            sampler = StreamSampler(
                seed,
                StreamParams.for_cell(
                    cell, diurnal_amp=diurnal_amp,
                    diurnal_period=diurnal_period,
                ),
            )
        t_start, offered = 0, 0
        carry, a_pad = None, 8
    t_end = t_start + slots
    if t_end >= np.iinfo(np.int32).max:
        raise ValueError(
            f"stream end {t_end} overflows the int32 slot clock"
        )
    scn = dataclasses.replace(
        cell.scenario(),
        horizon=jnp.int32(t_end),
        warmup=jnp.int32(warmup),
    )
    if carry is None:
        carry = _engine_init(
            dataclasses.replace(base_static, stream=True), 0
        )

    n_chunks = -(-slots // chunk)

    def prep(k: int):
        """Sample + pad + stage chunk k's slab (the host half of overlap)."""
        nonlocal a_pad, offered
        c0 = t_start + k * chunk
        wl = sampler.slab(c0, min(c0 + chunk, t_end))
        offered += wl.total
        need = int(wl.n_arr.max()) if wl.n_arr.size else 0
        if need > a_pad:
            a_pad = _round_up(need, 8)
        static_k = dataclasses.replace(
            base_static, slots=chunk, stream=True, max_arrivals=a_pad,
            trace_occupancy=False,
        )
        padded = _pad_workload(wl, chunk, a_pad, d, with_rid=False)
        return static_k, np.int32(c0), tuple(jnp.asarray(p) for p in padded)

    cur = prep(0)
    for k in range(n_chunks):
        static_k, t0_k, arrs = cur
        carry = _stream_step_fn(static_k)(carry, t0_k, *arrs, scn)
        if not prefetch:
            # Synchronous reference: drain the device before touching the
            # next slab, so host sampling serialises behind device time.
            carry = jax.block_until_ready(carry)
        if k + 1 < n_chunks:
            cur = prep(k + 1)
    carry = jax.block_until_ready(carry)

    (q_len, _, _, _, rem, _, _, comm_state, _, sm, total_comp, dropped,
     net_state, _, pull_state) = carry
    q_len_np = np.asarray(q_len)
    final_occ = q_len_np + (np.asarray(rem) > 0).sum(axis=1).astype(
        q_len_np.dtype
    )
    return StreamResult(
        slots=t_end,
        offered=offered,
        completed=int(total_comp),
        dropped=int(dropped),
        messages=int(comm_state.msgs),
        net_drops=int(net_state.drops) if net_state is not None else 0,
        count=int(sm.count),
        mean_jct=float(sm.mean),
        std_jct=float(
            np.sqrt(max(float(sm.m2), 0.0) / max(int(sm.count), 1))
        ),
        max_jct=int(sm.max_jct),
        hist=np.asarray(sm.hist, np.int64),
        final_occupancy=final_occ,
        state=StreamState(
            carry=carry, t_next=t_end, offered=offered, a_pad=a_pad,
            sampler=sampler,
        ),
        token_misses=int(pull_state[1]) if pull_state is not None else 0,
        token_sum=int(pull_state[2]) if pull_state is not None else 0,
        retrans=(
            int(net_state.retrans)
            if net_state is not None and hasattr(net_state, "retrans")
            else 0
        ),
    )
