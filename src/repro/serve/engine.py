"""Serving tier: continuous batching with a CARE request dispatcher.

This is the paper's own setting at the systems level: requests are jobs,
replica groups are servers, and the front-end dispatcher routes by
JSAQ over *approximated* per-replica queue occupancy.  Replicas mirror the
dispatcher's emulation (they know both their true state and, because
updates are deterministic, exactly what the dispatcher believes -- the
paper's information asymmetry) and send a correction message only when the
error reaches ``x`` (ET-x) -- so dispatcher<->replica control traffic is
sparse even at high request rates.

The engine is discrete-time (slot = one decode iteration across replicas),
matching the paper's simulation setting; each replica runs continuous
batching with a fixed decode-slot budget, admitting queued requests as
slots free up.  Completion requires ``decode_len`` iterations after a
prefill cost proportional to the prompt.

``model_fn`` is pluggable: ``None`` runs the queueing dynamics only (used
by benchmarks to measure JCT distributions at scale); a real
``decode_step`` closure runs actual token generation (examples/serve_care.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival: int
    prefill_cost: int  # slots of prefill work
    decode_len: int  # decode iterations to complete
    started: int = -1
    finished: int = -1


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_replicas: int = 8
    decode_slots: int = 16  # concurrent sequences per replica
    et_x: int = 4  # ET threshold on queue-occupancy error
    comm: str = "et"  # "et" | "dt" | "rt" | "exact"
    dt_x: int = 4
    rt_period: int = 16
    msr_drain: float = 1.0  # emulated completions per slot per busy replica


class Replica:
    """One replica group: continuous batching over admitted requests."""

    def __init__(self, cfg: EngineConfig):
        self.queue: deque[Request] = deque()
        self.active: list[list] = []  # [request, remaining_work]
        self.cfg = cfg
        self.completions = 0

    @property
    def occupancy(self) -> int:
        return len(self.queue) + len(self.active)

    def admit(self, req: Request, now: int):
        self.queue.append(req)

    def step(self, now: int) -> list[Request]:
        # admit while decode slots free
        while self.queue and len(self.active) < self.cfg.decode_slots:
            r = self.queue.popleft()
            r.started = now
            self.active.append([r, r.prefill_cost + r.decode_len])
        done = []
        for entry in self.active:
            entry[1] -= 1
            if entry[1] <= 0:
                entry[0].finished = now
                done.append(entry[0])
        self.active = [e for e in self.active if e[1] > 0]
        self.completions += len(done)
        return done


class CareDispatcher:
    """JSAQ over approximated occupancy + ET/DT/RT correction messages."""

    def __init__(self, cfg: EngineConfig, seed: int = 0):
        self.cfg = cfg
        self.replicas = [Replica(cfg) for _ in range(cfg.num_replicas)]
        self.approx = np.zeros(cfg.num_replicas)  # emulated occupancy
        self.deps_since = np.zeros(cfg.num_replicas, dtype=int)
        self.slots_since = np.zeros(cfg.num_replicas, dtype=int)
        self.messages = 0
        self.total_completions = 0
        self.rng = np.random.default_rng(seed)

    def route(self, req: Request, now: int) -> int:
        if self.cfg.comm == "exact":
            occ = np.array([r.occupancy for r in self.replicas], float)
        else:
            occ = self.approx
        j = int(self.rng.choice(np.flatnonzero(occ == occ.min())))
        self.replicas[j].admit(req, now)
        self.approx[j] += 1  # arrival known to the dispatcher (Eq. 10)
        return j

    def step(self, now: int) -> list[Request]:
        cfg = self.cfg
        finished: list[Request] = []
        completions = np.zeros(cfg.num_replicas, dtype=int)
        for i, rep in enumerate(self.replicas):
            done = rep.step(now)
            completions[i] = len(done)
            finished.extend(done)
        self.total_completions += int(completions.sum())
        self.deps_since += completions
        self.slots_since += 1

        # MSR drain: emulate service at the nominal completion rate.
        busy = self.approx > 0
        self.approx = np.maximum(self.approx - cfg.msr_drain * busy, 0.0)

        # server-side triggers (replicas mirror the emulation exactly)
        true_occ = np.array([r.occupancy for r in self.replicas], float)
        err = np.abs(true_occ - self.approx)
        if cfg.comm == "et":
            trig = err >= cfg.et_x
        elif cfg.comm == "dt":
            trig = self.deps_since >= cfg.dt_x
        elif cfg.comm == "rt":
            trig = self.slots_since >= cfg.rt_period
        else:  # exact: one message per completion
            trig = completions > 0
            self.messages += int(completions.sum()) - int(trig.sum())
        self.messages += int(trig.sum())
        self.approx = np.where(trig, true_occ, self.approx)
        self.deps_since = np.where(trig, 0, self.deps_since)
        self.slots_since = np.where(trig, 0, self.slots_since)
        return finished


def run_serving_sim(
    cfg: EngineConfig,
    *,
    slots: int = 20_000,
    load: float = 0.9,
    mean_decode: int = 64,
    mean_prefill: int = 4,
    seed: int = 0,
    model_fn: Optional[Callable] = None,
) -> dict:
    """Drive the engine with a Poisson-ish workload; return JCT metrics."""
    rng = np.random.default_rng(seed)
    disp = CareDispatcher(cfg, seed)
    # service capacity: num_replicas * decode_slots concurrent units, each
    # request occupies a slot for (prefill + decode) iterations.
    mean_work = mean_prefill + mean_decode
    arrival_rate = load * cfg.num_replicas * cfg.decode_slots / mean_work

    finished: list[Request] = []
    rid = 0
    for now in range(slots):
        n_arr = rng.poisson(arrival_rate)
        for _ in range(n_arr):
            req = Request(
                rid=rid,
                arrival=now,
                prefill_cost=1 + rng.poisson(mean_prefill),
                decode_len=1 + rng.poisson(mean_decode),
            )
            disp.route(req, now)
            rid += 1
        finished.extend(disp.step(now))
        if model_fn is not None:
            model_fn(now)

    jct = np.array([r.finished - r.arrival + 1 for r in finished])
    base_msgs = max(disp.total_completions, 1)
    return {
        "jct": jct,
        "mean_jct": float(jct.mean()) if jct.size else 0.0,
        "p99_jct": float(np.percentile(jct, 99)) if jct.size else 0.0,
        "completed": len(finished),
        "offered": rid,
        "messages": disp.messages,
        "msgs_per_completion": disp.messages / base_msgs,
    }
