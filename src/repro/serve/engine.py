"""Serving tier: continuous batching with a CARE request dispatcher.

This is the paper's own setting at the systems level: requests are jobs,
replica groups are servers, and the front-end dispatcher routes by
JSAQ over *approximated* per-replica queue occupancy.  Replicas mirror the
dispatcher's emulation (they know both their true state and, because
updates are deterministic, exactly what the dispatcher believes -- the
paper's information asymmetry) and send a correction message only when the
trigger of the shared protocol core (:mod:`repro.core.care.comm`, the same
RT/DT/ET/hybrid implementation the slotted and MoE-dispatch simulators use,
run here on its ``numpy`` backend) fires -- so dispatcher<->replica control
traffic is sparse even at high request rates.

The engine is discrete-time (slot = one decode iteration across replicas),
matching the paper's simulation setting; each replica runs continuous
batching with a fixed decode-slot budget, admitting queued requests as
slots free up.  Completion requires ``decode_len`` iterations after a
prefill cost proportional to the prompt.

Replica state is fully vectorised: decode slots are a ``(replicas,
decode_slots)`` remaining-work matrix and pending requests live in
per-replica circular ring buffers, so one engine step is a handful of
numpy array ops regardless of how many requests are in flight -- the hot
loop never iterates Python request objects (they are only materialised at
admission/completion boundaries, O(arrivals + completions) per slot).

``model_fn`` is pluggable: ``None`` runs the queueing dynamics only (used
by benchmarks to measure JCT distributions at scale); a real
``decode_step`` closure runs actual token generation (examples/serve_care.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.care import comm as comm_lib


@dataclasses.dataclass
class Request:
    rid: int
    arrival: int
    prefill_cost: int  # slots of prefill work
    decode_len: int  # decode iterations to complete
    started: int = -1
    finished: int = -1


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_replicas: int = 8
    decode_slots: int = 16  # concurrent sequences per replica
    et_x: int = 4  # ET threshold on queue-occupancy error
    comm: str = "et"  # "et" | "dt" | "rt" | "et_rt" | "exact"
    dt_x: int = 4
    rt_period: int = 16
    msr_drain: float = 1.0  # emulated completions per slot per busy replica

    def comm_config(self) -> comm_lib.CommConfig:
        """This tier's trigger parameters in shared-core terms."""
        if self.comm == "et":
            return comm_lib.CommConfig(kind="et", x=self.et_x)
        if self.comm == "dt":
            return comm_lib.CommConfig(kind="dt", x=self.dt_x)
        if self.comm == "rt":
            return comm_lib.CommConfig(kind="rt", rt_period=self.rt_period)
        if self.comm == "et_rt":
            return comm_lib.CommConfig(
                kind="et_rt", x=self.et_x, rt_period=self.rt_period
            )
        if self.comm == "exact":
            return comm_lib.CommConfig(kind="exact")
        raise ValueError(f"unknown comm mode: {self.comm}")


class CareDispatcher:
    """JSAQ over approximated occupancy + shared-core correction triggers.

    All per-replica state is vectorised numpy: ``active_rem``/``active_rid``
    hold the decode slots (0 remaining == free), ``_q_rid``/``_q_head``/
    ``_q_len`` are per-replica FIFO rings of pending request ids, and the
    trigger bookkeeping is a :class:`repro.core.care.comm.CommState`.
    """

    def __init__(self, cfg: EngineConfig, seed: int = 0, queue_cap: int = 4096):
        r, s = cfg.num_replicas, cfg.decode_slots
        self.cfg = cfg
        self._ccfg = cfg.comm_config()
        self.active_rem = np.zeros((r, s), np.int64)
        self.active_rid = np.full((r, s), -1, np.int64)
        self._qcap = queue_cap
        self._q_rid = np.full((r, queue_cap), -1, np.int64)
        self._q_head = np.zeros(r, np.int64)
        self._q_len = np.zeros(r, np.int64)
        self.approx = np.zeros(r)  # emulated occupancy
        self.comm = comm_lib.CommState.init(r, xp=np)
        self.total_completions = 0
        self.rng = np.random.default_rng(seed)
        # rid-indexed request metadata (grown on demand).
        self._work = np.zeros(1024, np.int64)
        self._started = np.full(1024, -1, np.int64)
        self._store: dict[int, Request] = {}

    @property
    def messages(self) -> int:
        return int(self.comm.msgs)

    def true_occupancy(self) -> np.ndarray:
        """Exact per-replica occupancy (queued + active), shape (R,)."""
        return self._q_len + (self.active_rem > 0).sum(axis=1)

    def _ensure_rid(self, rid: int):
        while rid >= self._work.shape[0]:
            self._work = np.concatenate([self._work, np.zeros_like(self._work)])
            self._started = np.concatenate(
                [self._started, np.full_like(self._started, -1)]
            )

    def _grow_queues(self):
        r = self.cfg.num_replicas
        new = np.full((r, 2 * self._qcap), -1, np.int64)
        for i in range(r):  # linearise each ring into the new buffer
            idx = (self._q_head[i] + np.arange(self._q_len[i])) % self._qcap
            new[i, : self._q_len[i]] = self._q_rid[i, idx]
        self._q_rid, self._q_head, self._qcap = new, np.zeros(r, np.int64), 2 * self._qcap

    def route(self, req: Request, now: int) -> int:
        if self.cfg.comm == "exact":
            occ = self.true_occupancy().astype(float)
        else:
            occ = self.approx
        j = int(self.rng.choice(np.flatnonzero(occ == occ.min())))
        if self._q_len[j] >= self._qcap:
            self._grow_queues()
        self._ensure_rid(req.rid)
        # A zero-work request still occupies a decode slot for one
        # iteration (matches the pre-vectorisation engine, where the first
        # decrement completed it); without the clamp it would sit at
        # rem == 0 forever and never be marked done.
        self._work[req.rid] = max(req.prefill_cost + req.decode_len, 1)
        self._store[req.rid] = req
        tail = (self._q_head[j] + self._q_len[j]) % self._qcap
        self._q_rid[j, tail] = req.rid
        self._q_len[j] += 1
        self.approx[j] += 1  # arrival known to the dispatcher (Eq. 10)
        return j

    def step(self, now: int) -> list[Request]:
        cfg = self.cfg
        rows = np.arange(cfg.num_replicas)[:, None]

        # 1. admit: fill free decode slots from the pending rings, FIFO.
        free = self.active_rem <= 0
        free_rank = np.cumsum(free, axis=1) - 1
        n_admit = np.minimum(self._q_len, free.sum(axis=1))
        take = free & (free_rank < n_admit[:, None])
        if take.any():
            qidx = (self._q_head[:, None] + free_rank) % self._qcap
            rid = self._q_rid[rows, qidx]
            self.active_rid = np.where(take, rid, self.active_rid)
            self.active_rem = np.where(take, self._work[rid], self.active_rem)
            self._started[rid[take]] = now
            self._q_head = (self._q_head + n_admit) % self._qcap
            self._q_len = self._q_len - n_admit

        # 2. service: one decode iteration on every active slot.
        active = self.active_rem > 0
        self.active_rem = self.active_rem - active
        done = active & (self.active_rem == 0)
        completions = done.sum(axis=1)
        finished: list[Request] = []
        if done.any():
            for rid in self.active_rid[done]:
                req = self._store.pop(int(rid))
                req.started = int(self._started[rid])
                req.finished = now
                finished.append(req)
            self.active_rid[done] = -1
        self.total_completions += int(completions.sum())

        # 3. MSR drain: emulate service at the nominal completion rate.
        busy = self.approx > 0
        self.approx = np.maximum(self.approx - cfg.msr_drain * busy, 0.0)

        # 4. trigger (replicas mirror the emulation exactly) -- shared core.
        true_occ = self.true_occupancy().astype(float)
        err = np.abs(true_occ - self.approx)
        trig, self.comm = comm_lib.evaluate(
            self.comm, self._ccfg, err, completions, xp=np
        )
        self.approx = np.where(trig, true_occ, self.approx)
        return finished


def run_serving_sim(
    cfg: EngineConfig,
    *,
    slots: int = 20_000,
    load: float = 0.9,
    mean_decode: int = 64,
    mean_prefill: int = 4,
    seed: int = 0,
    model_fn: Optional[Callable] = None,
) -> dict:
    """Drive the engine with a Poisson-ish workload; return JCT metrics."""
    rng = np.random.default_rng(seed)
    disp = CareDispatcher(cfg, seed)
    # service capacity: num_replicas * decode_slots concurrent units, each
    # request occupies a slot for (prefill + decode) iterations.
    mean_work = mean_prefill + mean_decode
    arrival_rate = load * cfg.num_replicas * cfg.decode_slots / mean_work

    finished: list[Request] = []
    rid = 0
    for now in range(slots):
        n_arr = rng.poisson(arrival_rate)
        for _ in range(n_arr):
            req = Request(
                rid=rid,
                arrival=now,
                prefill_cost=1 + rng.poisson(mean_prefill),
                decode_len=1 + rng.poisson(mean_decode),
            )
            disp.route(req, now)
            rid += 1
        finished.extend(disp.step(now))
        if model_fn is not None:
            model_fn(now)

    jct = np.array([r.finished - r.arrival + 1 for r in finished])
    base_msgs = max(disp.total_completions, 1)
    return {
        "jct": jct,
        "mean_jct": float(jct.mean()) if jct.size else 0.0,
        "p99_jct": float(np.percentile(jct, 99)) if jct.size else 0.0,
        "completed": len(finished),
        "offered": rid,
        "messages": disp.messages,
        "msgs_per_completion": disp.messages / base_msgs,
    }
