"""Deterministic synthetic data pipeline: sharded, restartable, skippable.

Tokens are a pure function of (seed, global step, position) via a counter-
mode hash, so:

* every data-parallel shard draws its own slice with zero coordination;
* restart-from-checkpoint resumes the exact stream by seeking to a step
  (``skip-ahead`` costs nothing -- there is no stateful iterator to replay);
* elastic re-sharding (a different dp_rank/dp_size split after a failure)
  still yields the same global batch sequence.

The token distribution is Zipf-like over the vocab (more realistic load for
embedding sharding and MoE routing than uniform), with a deterministic
"document" structure: periodic BOS and repeated n-grams so a model can
actually learn something in the examples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    bos_id: int = 1


def _hash_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Counter-mode integer hash (xorshift-multiply, u32)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _zipf_map(u: jnp.ndarray, vocab: int, a: float) -> jnp.ndarray:
    """Map uniform [0,1) to a Zipf-ish vocab id via inverse power CDF."""
    ids = jnp.power(u, a) * (vocab - 2)
    return (ids.astype(jnp.int32) + 2) % vocab  # reserve 0=pad, 1=bos


def global_batch_at(step: int, cfg: DataConfig) -> dict:
    """The full (global_batch, seq) batch for ``step`` (host-side)."""
    return shard_batch_at(step, cfg, dp_rank=0, dp_size=1)


def shard_batch_at(step: int, cfg: DataConfig, dp_rank: int, dp_size: int) -> dict:
    """This shard's rows of the global batch at ``step``.

    Rows are assigned round-robin by global row id, so changing dp_size
    (elastic re-shard) re-partitions the same global stream.
    """
    if cfg.global_batch % dp_size:
        raise ValueError(f"global_batch {cfg.global_batch} % dp_size {dp_size} != 0")
    rows_local = cfg.global_batch // dp_size
    row_ids = dp_rank + dp_size * np.arange(rows_local)
    return _make_rows(step, row_ids, cfg)


def _make_rows(step: int, row_ids: np.ndarray, cfg: DataConfig) -> dict:
    s = cfg.seq_len
    # counter = ((step * GB + row) * (S+1) + position)
    base = (np.uint64(step) * np.uint64(cfg.global_batch) + row_ids.astype(np.uint64))
    counters = base[:, None] * np.uint64(s + 1) + np.arange(s + 1, dtype=np.uint64)
    counters = (counters + np.uint64(cfg.seed) * np.uint64(0x9E3779B9)) & np.uint64(
        0xFFFFFFFF
    )
    h = np.asarray(_hash_u32(jnp.asarray(counters.astype(np.uint32))))
    u = h.astype(np.float64) / 2**32
    toks = np.asarray(_zipf_map(jnp.asarray(u), cfg.vocab_size, cfg.zipf_a))
    # documents: BOS every 256 tokens; learnable structure: echo token from
    # 8 positions back within the document half the time.
    pos = np.arange(s + 1)
    toks = np.where(pos[None, :] % 256 == 0, cfg.bos_id, toks)
    echo = np.roll(toks, 8, axis=1)
    use_echo = (h % 2 == 0) & (pos[None, :] % 256 >= 8)
    toks = np.where(use_echo, echo, toks).astype(np.int32)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].copy(),
    }


class ShardedLoader:
    """Iterator facade with explicit step state (checkpointable)."""

    def __init__(self, cfg: DataConfig, dp_rank: int = 0, dp_size: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.step = start_step

    def __next__(self) -> dict:
        b = shard_batch_at(self.step, self.cfg, self.dp_rank, self.dp_size)
        self.step += 1
        return b

    def __iter__(self):
        return self

    def skip_to(self, step: int):
        self.step = step
