"""Chameleon-34B backbone: early-fusion VLM [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
tokens share the vocabulary, so the backbone is a dense GQA transformer
with qk-norm).  The VQ tokenizer frontend is a STUB: input_specs()
provides fused token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
)
