"""Qwen1.5-4B: dense with QKV bias [hf:Qwen/Qwen1.5 family].

40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
