"""DeepSeek-V3 671B: MLA + 256-expert MoE + MTP [arXiv:2412.19437].

61L d_model=7168 128H, MLA (kv_lora 512, q_lora 1536, rope head 64),
1 shared + 256 routed experts top-8 (sigmoid gating), expert hidden 2048,
first 3 layers dense (hidden 18432), vocab 129280, MTP depth 1.
The CARE balancer replaces the per-step exact bias update (DESIGN 2.1).
"""
from repro.configs.base import CareConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe=True,
    n_routed_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    gate_fn="sigmoid",
    mtp=True,
    care=CareConfig(enabled=True, comm="dt", x=8, bias_alpha=2.0),
)
