"""Model / run configuration.

One flat frozen dataclass drives every architecture in the zoo; per-arch
modules in this package instantiate it with the exact assigned settings.
``reduced()`` derives the small same-family config used by the CPU smoke
tests (the full configs are only ever lowered AOT, never allocated).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class CareConfig:
    """CARE balancer settings for MoE routing (core/moe_balancer.py)."""

    enabled: bool = True
    comm: str = "dt"  # "dt" (sync every x steps) | "et" (error triggered)
    x: int = 8  # sync period / error threshold (tokens per expert, in
    #              units of the per-expert mean load)
    bias_alpha: float = 0.3  # proportional JSAQ bias gain on gate scores
    bias_clip: float = 2.0  # clip on the relative-overload signal
    gamma: float = 0.05  # integral bias gain (DeepSeek-V3-style update,
    #                       driven by the CARE-approximated load)
    drain: float = 0.85  # MSR drain factor per step (emulated service)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: Family = "dense"
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0  # 0 => off (gemma2: 50.0)
    final_softcap: float = 0.0  # 0 => off (gemma2: 30.0)
    sliding_window: int = 0  # 0 => all-global
    # "global" | "alt_local_global" (gemma2) | "mostly_local" (hymba)
    layer_pattern: str = "global"
    global_layers: tuple[int, ...] = ()  # explicit global layers (hymba)
    rope_theta: float = 10_000.0
    post_norms: bool = False  # gemma2 post-attn/post-ffn norms
    embed_scale: bool = False  # gemma2 sqrt(d_model) embedding scale
    query_scale: float = 0.0  # 0 => 1/sqrt(head_dim)

    # --- MLA (deepseek) ----------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0  # 0 => direct q projection
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- FFN / MoE ----------------------------------------------------------
    act: str = "silu"  # "silu" (swiglu) | "gelu" (geglu / plain)
    glu: bool = True
    moe: bool = False
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # routed/shared expert hidden size
    first_dense_layers: int = 0  # deepseek: leading dense layers
    gate_fn: str = "softmax"  # "softmax" (v2) | "sigmoid" (v3)
    moe_capacity_factor: float = 1.5
    care: CareConfig = CareConfig()

    # --- SSM ------------------------------------------------------------------
    ssm_state: int = 16  # mamba state size (hymba)
    rwkv_head_dim: int = 64
    ssm_expand: int = 2  # mamba inner expansion
    conv_kernel: int = 4

    # --- encoder-decoder -----------------------------------------------------
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper 30s @ 50Hz after conv stub

    # --- extras ----------------------------------------------------------------
    mtp: bool = False  # deepseek-v3 multi-token prediction head
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    use_pallas_router: bool = False  # TPU runtime only; CPU uses the oracle
    use_pallas_attention: bool = False  # TPU runtime flash kernel
    remat: bool = False  # activation checkpointing per layer

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic state: SSM / hybrid run the 500k decode shape."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Same-family tiny config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if self.family != "hybrid" else 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32,
            qk_rope_head_dim=16,
            qk_nope_head_dim=32,
            v_head_dim=32,
            n_routed_experts=8 if self.moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=64 if self.moe else 0,
            moe_capacity_factor=4.0,
            first_dense_layers=min(self.first_dense_layers, 1),
            encoder_layers=2 if self.encoder_decoder else 0,
            encoder_seq=16 if self.encoder_decoder else 1500,
            rwkv_head_dim=32,
            global_layers=(0,) if self.global_layers else (),
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
