"""Gemma2-9B: alternating local/global attention, logit softcaps
[arXiv:2408.00118].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000, window 4096,
attn softcap 50, final softcap 30, GeGLU, pre+post norms, query scale
1/sqrt(256), sqrt(d_model) embedding scale.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    sliding_window=4096,
    layer_pattern="alt_local_global",
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    post_norms=True,
    embed_scale=True,
    query_scale=0.0625,  # 1/sqrt(256)
    tie_embeddings=True,
)
