"""Hymba-1.5B: hybrid parallel attention + Mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention everywhere except 3 full-attention layers
(first / middle / last, per the paper); meta tokens and cross-layer KV
sharing omitted (DESIGN.md Section 6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    sliding_window=1024,
    layer_pattern="mostly_local",
    global_layers=(0, 15, 31),
    rope_theta=10_000.0,
)
