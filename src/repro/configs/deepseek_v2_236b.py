"""DeepSeek-V2 236B: MLA + 160-expert MoE [arXiv:2405.04434].

60L d_model=5120 128H, MLA kv_lora=512 (no q-lora in our build of v2-lite
lineage? full v2 uses q_lora 1536 -- kept), 2 shared + 160 routed top-6
(softmax gating), expert hidden 1536, first layer dense (hidden 12288),
vocab 102400.
"""
from repro.configs.base import CareConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,
    vocab_size=102400,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe=True,
    n_routed_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    gate_fn="softmax",
    care=CareConfig(enabled=True, comm="dt", x=8, bias_alpha=2.0),
)
