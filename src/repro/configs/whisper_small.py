"""Whisper-small backbone: encoder-decoder [arXiv:2212.04356].

12L enc + 12L dec, d_model=768 12H d_ff=3072 vocab=51865.  The conv audio
frontend is a STUB: input_specs() provides precomputed (B, 1500, 768)
frame embeddings (DESIGN.md Section 3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_decoder=True,
    encoder_layers=12,
    encoder_seq=1500,
    act="gelu",
    glu=False,
)
