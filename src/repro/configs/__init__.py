"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from repro.configs.base import SHAPES, CareConfig, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "hymba-1.5b": "hymba_1p5b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "smollm-135m": "smollm_135m",
    "qwen1.5-4b": "qwen1p5_4b",
    "qwen3-0.6b": "qwen3_0p6b",
    "gemma2-9b": "gemma2_9b",
    "whisper-small": "whisper_small",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "chameleon-34b": "chameleon_34b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    """Look up an architecture config by its assigned id."""
    import importlib

    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) cells; skips long_500k for full-attention
    archs per the assignment (noted in DESIGN.md Section 3)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = shape.name == "long_500k" and not cfg.supports_long_context
            if include_skipped or not skip:
                out.append((arch, shape.name, skip))
    return out
