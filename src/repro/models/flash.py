"""Blocked (flash-style) attention in pure JAX: lax.scan over KV blocks
with an online softmax, remat'd per block.

Why this exists: the naive softmax(QK^T)V materialises the (B, H, S, T)
score tensor through every op of the softmax chain, forward and backward
-- at 4k train / 32k prefill shapes that is the dominant HBM term of every
attention arch in the roofline (EXPERIMENTS.md Section Perf).  The blocked
form keeps only (B, H, S, KV_BLOCK) tiles live, and ``jax.checkpoint`` on
the block body makes the backward recompute tiles instead of saving them.

This is also the reference structure for the Pallas TPU kernel
(``kernels/flash_attn.py``): same tiling, same online-softmax carry; the
kernel keeps the tiles in VMEM so the score tensor never touches HBM at
all.  The pure-JAX version here is what the multi-pod dry-run lowers (the
CPU backend cannot compile Mosaic kernels).

Semantics match ``attention._sdpa`` exactly: scale -> optional softcap ->
causal/window mask -> softmax in f32 -> weighted sum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

KV_BLOCK = 1024
_NEG = -1e30


def flash_sdpa(
    q,
    k,
    v,
    *,
    scale: float,
    q_positions=None,
    causal: bool = True,
    window=None,
    softcap: float = 0.0,
    kv_block: int | None = None,
):
    """Blocked attention.  q: (B,S,H,Dh); k,v: (B,T,KVH,Dh[v]).

    ``window`` is a (possibly traced) scalar: only keys with
    ``q_pos - k_pos < window`` attend (pass None or >= T for global).
    ``kv_block=None`` picks fewer, larger blocks: lax.scan saves its carry
    (acc, m, l) per block for AD, so block count is pure overhead there;
    the per-op score-chain traffic is block-count invariant.
    Returns (B, S, H*Dv).
    """
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    if kv_block is None:
        kv_block = min(max(t // 2, KV_BLOCK), 4096)
    if t % kv_block or t <= kv_block:
        return _dense_sdpa(
            q, k, v, scale=scale, q_positions=q_positions, causal=causal,
            window=window, softcap=softcap,
        )
    nb = t // kv_block

    if q_positions is None:
        q_positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    qpos = q_positions[:, :, None, None, None]  # (B,S,1,1,1)
    qg = q.reshape(b, s, kvh, g, dh)

    kb = k.reshape(b, nb, kv_block, kvh, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, kvh, dv).transpose(1, 0, 2, 3, 4)
    koff = jnp.arange(nb, dtype=jnp.int32) * kv_block

    def block(carry, xs):
        acc, m, l = carry  # (B,S,KVH,G,Dv) f32, (B,S,KVH,G) f32 x2
        k_b, v_b, off = xs
        sc = jnp.einsum(
            "bskgd,btkd->bskgt", qg, k_b, preferred_element_type=jnp.float32
        ) * scale
        if softcap:
            sc = softcap * jnp.tanh(sc / softcap)
        kpos = (off + jnp.arange(kv_block, dtype=jnp.int32))[
            None, None, None, None, :
        ]
        if causal:
            ok = kpos <= qpos
            if window is not None:
                ok = ok & (qpos - kpos < window)
        else:
            ok = jnp.ones_like(kpos, bool)
        sc = jnp.where(ok, sc, _NEG)

        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bskgt,btkd->bskgd", p.astype(v_b.dtype), v_b)
        acc = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (acc, m_new, l), None

    init = (
        jnp.zeros((b, s, kvh, g, dv), jnp.float32),
        jnp.full((b, s, kvh, g), _NEG, jnp.float32),
        jnp.zeros((b, s, kvh, g), jnp.float32),
    )
    (acc, _m, l), _ = jax.lax.scan(
        jax.checkpoint(block), init, (kb, vb, koff)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype).reshape(b, s, h * dv)


def _dense_sdpa(
    q, k, v, *, scale, q_positions, causal, window, softcap
):
    """Unblocked fallback (short T); same semantics."""
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    sc = jnp.einsum(
        "bskgd,btkd->bskgt", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    if q_positions is None:
        q_positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    qpos = q_positions[:, :, None, None, None]
    kpos = jnp.arange(t, dtype=jnp.int32)[None, None, None, None, :]
    if causal:
        ok = kpos <= qpos
        if window is not None:
            ok = ok & (qpos - kpos < window)
        sc = jnp.where(ok, sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p.astype(v.dtype), v)
    return out.astype(q.dtype).reshape(b, s, h * dv)
