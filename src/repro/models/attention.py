"""Grouped-query attention with the zoo's option set.

Options (all driven by ModelConfig): GQA/MHA, QKV bias (qwen1.5), per-head
qk-RMSNorm (qwen3 / chameleon), logit soft-capping and local/global
alternation (gemma2), RoPE with configurable theta, cross-attention
(whisper decoder).

Call modes:
* full-sequence (train / prefill) -- optionally returns a populated KV
  cache for subsequent decode;
* single-token decode against a preallocated KV cache (written in place at
  ``pos`` via dynamic_update_slice).

The sliding window is a *traced* scalar so gemma2's alternating pattern and
hymba's mostly-local pattern run inside one scanned layer body (window is a
per-layer scan input; full attention uses window >= seq_len).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, flash


def init_attention(kg: common.KeyGen, cfg: ModelConfig):
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    pdt = common.dtype_of(cfg.param_dtype)
    p = {
        "wq": common.dense_init(kg(), (d, h * dh), pdt),
        "wk": common.dense_init(kg(), (d, kvh * dh), pdt),
        "wv": common.dense_init(kg(), (d, kvh * dh), pdt),
        "wo": common.dense_init(kg(), (h * dh, d), pdt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), pdt)
        p["bk"] = jnp.zeros((kvh * dh,), pdt)
        p["bv"] = jnp.zeros((kvh * dh,), pdt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), pdt)
        p["k_norm"] = jnp.ones((dh,), pdt)
    return p


def _project_qkv(p, x, kv_src, cfg: ModelConfig):
    dh = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*x.shape[:-1], h, dh)
    k = k.reshape(*kv_src.shape[:-1], kvh, dh)
    v = v.reshape(*kv_src.shape[:-1], kvh, dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: (B,S,H,Dh)  k,v: (B,T,Kv,Dh)  mask: broadcast to (B,1,1,S,T)."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = cfg.query_scale or (1.0 / dh**0.5)
    qg = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    scores = scores * scale
    if cfg.attn_softcap:
        scores = common.softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h * dh)


def attention_full(
    p,
    x,
    cfg: ModelConfig,
    *,
    window,
    kv_src=None,
    causal: bool = True,
    use_rope: bool = True,
    positions=None,
    return_cache: bool = False,
    cache_len: int = 0,
):
    """Full-sequence attention.  ``kv_src`` enables cross-attention."""
    b, s, _ = x.shape
    self_attn = kv_src is None
    kv_src = x if self_attn else kv_src
    t = kv_src.shape[1]
    q, k, v = _project_qkv(p, x, kv_src, cfg)

    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if use_rope and self_attn:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)

    # Attention path selection (EXPERIMENTS.md Section Perf): on TPU the
    # Pallas flash kernel keeps score tiles in VMEM; the pure-JAX blocked
    # form only pays off for wide-head MLA (it runs in mla_full), so dense
    # GQA defaults to the one-shot SDPA (kv_block >= T).
    scale = cfg.query_scale or (1.0 / q.shape[-1] ** 0.5)
    static_window = window if isinstance(window, int) or window is None else False
    if (
        cfg.use_pallas_attention
        and static_window is not False  # traced window -> jnp path
        and not (t % 128 or q.shape[1] % 128)
    ):
        from repro.kernels import ops as kernel_ops

        out = kernel_ops.flash_attention(
            q, k, v, scale=scale, causal=causal,
            window=static_window if causal else None,
            softcap=cfg.attn_softcap,
        ).reshape(b, s, -1) @ p["wo"]
    else:
        out = flash.flash_sdpa(
            q, k, v, scale=scale, q_positions=positions, causal=causal,
            window=window if causal else None, softcap=cfg.attn_softcap,
            kv_block=t,
        ) @ p["wo"]
    if not return_cache:
        return out, None
    # Preallocate a cache of cache_len and write the prefix.
    kvh, dh = k.shape[2], k.shape[3]
    kc = jnp.zeros((b, cache_len, kvh, dh), k.dtype)
    vc = jnp.zeros((b, cache_len, kvh, dh), v.dtype)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
    return out, {"k": kc, "v": vc}


def attention_decode(p, x, cache, pos, cfg: ModelConfig, *, window, use_rope=True):
    """One-token decode.  x: (B,1,D); cache k/v: (B,S,Kv,Dh); pos: scalar."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, x, cfg)
    positions = jnp.full((b, 1), pos, jnp.int32)
    if use_rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    t = kc.shape[1]
    kpos = jnp.arange(t, dtype=jnp.int32)[None, None, :]
    mask = (kpos <= pos) & (pos - kpos < window)
    mask = jnp.broadcast_to(mask, (b, 1, t))[:, None, None, :, :]
    out = _sdpa(q, kc, vc, mask, cfg) @ p["wo"]
    return out, {"k": kc, "v": vc}


def cross_attention_decode(p, x, cross_cache, cfg: ModelConfig):
    """Decode-time cross attention against precomputed encoder K/V."""
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, 1, cfg.num_heads, dh)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = cross_cache["k"], cross_cache["v"]
    t = k.shape[1]
    mask = jnp.ones((b, 1, 1, 1, t), bool)
    return _sdpa(q, k, v, mask, cfg) @ p["wo"]


def precompute_cross_kv(p, enc_out, cfg: ModelConfig):
    """Project encoder output to K/V once (whisper decode)."""
    dh = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(*enc_out.shape[:-1], kvh, dh)
    v = v.reshape(*enc_out.shape[:-1], kvh, dh)
    if cfg.qk_norm:
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return {"k": k, "v": v}
