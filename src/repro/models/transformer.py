"""Block definitions and the scanned layer stack for every family.

One scanned, weight-stacked layer body per family keeps the HLO size
independent of depth (61-layer deepseek compiles as fast as 12-layer
whisper).  Non-uniform leading layers (deepseek's first dense layers) run
as separate unscanned blocks.  Per-layer static-ish variation (gemma2's
local/global alternation, hymba's three global layers) is expressed as a
*traced* per-layer window input so the scan body stays uniform.

Modes: "train" (no cache), "prefill" (returns cache), "decode" (one token,
cache in/out).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import common, ffn, mla, parallel, ssm
from repro.models.parallel import ParallelContext

BIG_WINDOW = 1 << 30


# --------------------------------------------------------------------------
# per-layer window pattern
# --------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    l = cfg.num_layers
    w = np.full((l,), BIG_WINDOW, np.int32)
    if cfg.layer_pattern == "alt_local_global" and cfg.sliding_window:
        w[0::2] = cfg.sliding_window  # even layers local (gemma2)
    elif cfg.layer_pattern == "mostly_local" and cfg.sliding_window:
        w[:] = cfg.sliding_window
        for g in cfg.global_layers:
            if g < l:
                w[g] = BIG_WINDOW
    return w


# --------------------------------------------------------------------------
# block init
# --------------------------------------------------------------------------


def _norm_params(cfg: ModelConfig, with_bias: bool):
    pdt = common.dtype_of(cfg.param_dtype)
    p = {"scale": jnp.ones((cfg.d_model,), pdt)}
    if with_bias:
        p["bias"] = jnp.zeros((cfg.d_model,), pdt)
    return p


def _uses_layer_norm(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "audio")


def _norm(p, x, cfg: ModelConfig):
    if "bias" in p:
        return common.layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return common.rms_norm(x, p["scale"], cfg.norm_eps)


def init_lm_block(kg: common.KeyGen, cfg: ModelConfig, *, moe_layer: bool):
    ln = _uses_layer_norm(cfg)
    p: dict[str, Any] = {
        "ln1": _norm_params(cfg, ln),
        "ln2": _norm_params(cfg, ln),
    }
    p["attn"] = mla.init_mla(kg, cfg) if cfg.use_mla else attn_lib.init_attention(kg, cfg)
    if cfg.post_norms:
        p["ln1_post"] = _norm_params(cfg, ln)
        p["ln2_post"] = _norm_params(cfg, ln)
    if moe_layer:
        p["moe"] = ffn.init_moe_ffn(kg, cfg)
    else:
        p["ffn"] = ffn.init_dense_ffn(kg, cfg)
    return p


def init_rwkv_block(kg: common.KeyGen, cfg: ModelConfig):
    return {
        "ln1": _norm_params(cfg, True),
        "ln2": _norm_params(cfg, True),
        "tm": ssm.init_rwkv_time_mix(kg, cfg),
        "cm": ssm.init_rwkv_channel_mix(kg, cfg),
    }


def init_hymba_block(kg: common.KeyGen, cfg: ModelConfig):
    pdt = common.dtype_of(cfg.param_dtype)
    return {
        "ln1": _norm_params(cfg, False),
        "ln2": _norm_params(cfg, False),
        "attn": attn_lib.init_attention(kg, cfg),
        "mamba": ssm.init_mamba(kg, cfg),
        "ffn": ffn.init_dense_ffn(kg, cfg),
        "attn_out_norm": jnp.ones((cfg.d_model,), pdt),
        "ssm_out_norm": jnp.ones((cfg.d_model,), pdt),
    }


def init_encoder_block(kg: common.KeyGen, cfg: ModelConfig):
    return {
        "ln1": _norm_params(cfg, True),
        "ln2": _norm_params(cfg, True),
        "attn": attn_lib.init_attention(kg, cfg),
        "ffn": ffn.init_dense_ffn(kg, cfg),
    }


def init_decoder_block(kg: common.KeyGen, cfg: ModelConfig):
    return {
        "ln1": _norm_params(cfg, True),
        "ln_x": _norm_params(cfg, True),
        "ln2": _norm_params(cfg, True),
        "attn": attn_lib.init_attention(kg, cfg),
        "cross": attn_lib.init_attention(kg, cfg),
        "ffn": ffn.init_dense_ffn(kg, cfg),
    }


# --------------------------------------------------------------------------
# block forward (full sequence)
# --------------------------------------------------------------------------


def lm_block_full(
    p,
    x,
    cfg: ModelConfig,
    ctx: Optional[ParallelContext],
    *,
    window,
    bias,
    moe_layer: bool,
    return_cache: bool = False,
    cache_len: int = 0,
):
    h = _norm(p["ln1"], x, cfg)
    if cfg.use_mla:
        a, cache = mla.mla_full(
            p["attn"], h, cfg, return_cache=return_cache, cache_len=cache_len,
            ctx=ctx,
        )
    else:
        a, cache = attn_lib.attention_full(
            p["attn"], h, cfg, window=window,
            return_cache=return_cache, cache_len=cache_len,
        )
    if cfg.post_norms:
        a = _norm(p["ln1_post"], a, cfg)
    x = x + a

    h = _norm(p["ln2"], x, cfg)
    if moe_layer:
        f, counts = ffn.moe_ffn(p["moe"], h, bias, cfg, ctx)
    else:
        f = ffn.dense_ffn(p["ffn"], h, cfg)
        counts = _zero_counts(cfg, ctx)
    if cfg.post_norms:
        f = _norm(p["ln2_post"], f, cfg)
    x = common.grad_dtype_barrier(x + f)
    return x, cache, counts


def _zero_counts(cfg: ModelConfig, ctx):
    e = max(cfg.n_routed_experts, 1)
    if ctx is None:
        return jnp.zeros((e,), jnp.float32)
    return jnp.zeros((ctx.dp_size, ctx.tp_size, e), jnp.float32)


def lm_block_decode(p, x, cache, pos, cfg: ModelConfig, ctx, *, window, bias, moe_layer):
    h = _norm(p["ln1"], x, cfg)
    if cfg.use_mla:
        a, cache = mla.mla_decode(p["attn"], h, cache, pos, cfg)
    else:
        a, cache = attn_lib.attention_decode(p["attn"], h, cache, pos, cfg, window=window)
    if cfg.post_norms:
        a = _norm(p["ln1_post"], a, cfg)
    x = x + a
    h = _norm(p["ln2"], x, cfg)
    if moe_layer:
        f, counts = ffn.moe_ffn(p["moe"], h, bias, cfg, ctx)
    else:
        f = ffn.dense_ffn(p["ffn"], h, cfg)
        counts = _zero_counts(cfg, ctx)
    if cfg.post_norms:
        f = _norm(p["ln2_post"], f, cfg)
    return x + f, cache, counts


def rwkv_block(p, x, cfg: ModelConfig, state=None, ctx=None):
    """state: None (train) or dict(wkv, tm_shift, cm_shift).

    Sequence parallelism: the residual stream and every elementwise region
    (norms, ddlerp, token shift, channel mix) are sharded over the TP axis
    on the *sequence* dim; only the WKV recurrence runs sequence-gathered
    (it is sequential in S) and is head-sharded instead.  GSPMD inserts
    the S-gather before the time-mix matmuls and a reduce-scatter after
    wo -- the Megatron-SP schedule, derived from these constraints.
    """
    st = state or {}
    dp, tp = (ctx.dp_axes, ctx.tp_axis) if ctx is not None else (None, None)
    sp = lambda a: parallel.hint(a, ctx, dp, tp)  # noqa: E731  (B, S/tp, D)
    x = sp(x)
    h, wkv, tm_shift = ssm.rwkv_time_mix(
        p["tm"], _norm(p["ln1"], x, cfg), cfg,
        state=st.get("wkv"), shift_prev=st.get("tm_shift"), ctx=ctx,
    )
    x = sp(x + sp(h))
    h, cm_shift = ssm.rwkv_channel_mix(
        p["cm"], _norm(p["ln2"], x, cfg), cfg, shift_prev=st.get("cm_shift"),
        ctx=ctx,
    )
    x = common.grad_dtype_barrier(sp(x + h))
    new_state = {"wkv": wkv, "tm_shift": tm_shift, "cm_shift": cm_shift}
    return x, new_state


def hymba_block(
    p, x, cfg: ModelConfig, *, window, mode: str, cache=None, pos=None, cache_len=0
):
    h = _norm(p["ln1"], x, cfg)
    st = cache or {}
    if mode == "decode":
        a, kv = attn_lib.attention_decode(
            p["attn"], h, {"k": st["k"], "v": st["v"]}, pos, cfg, window=window
        )
    else:
        a, kv = attn_lib.attention_full(
            p["attn"], h, cfg, window=window,
            return_cache=(mode == "prefill"), cache_len=cache_len,
        )
    s, ssm_state, conv_state = ssm.mamba(
        p["mamba"], h, cfg, state=st.get("ssm"), conv_state=st.get("conv")
    )
    fused = 0.5 * (
        common.rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
        + common.rms_norm(s, p["ssm_out_norm"], cfg.norm_eps)
    )
    x = x + fused
    x = common.grad_dtype_barrier(
        x + ffn.dense_ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg)
    )
    new_cache = None
    if mode != "train":
        new_cache = {"ssm": ssm_state, "conv": conv_state}
        if kv is not None:
            new_cache.update(kv)
        elif mode == "decode":
            new_cache.update({"k": st["k"], "v": st["v"]})
    return x, new_cache


def encoder_block(p, x, cfg: ModelConfig):
    h = _norm(p["ln1"], x, cfg)
    a, _ = attn_lib.attention_full(p["attn"], h, cfg, window=BIG_WINDOW,
                                   causal=False, use_rope=False)
    x = x + a
    x = x + ffn.dense_ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg)
    return common.grad_dtype_barrier(x)


def decoder_block(
    p, x, enc_out, cfg: ModelConfig, *, mode: str, cache=None, pos=None, cache_len=0
):
    st = cache or {}
    h = _norm(p["ln1"], x, cfg)
    if mode == "decode":
        a, kv = attn_lib.attention_decode(
            p["attn"], h, {"k": st["k"], "v": st["v"]}, pos, cfg,
            window=BIG_WINDOW, use_rope=False,
        )
    else:
        a, kv = attn_lib.attention_full(
            p["attn"], h, cfg, window=BIG_WINDOW, use_rope=False,
            return_cache=(mode == "prefill"), cache_len=cache_len,
        )
    x = x + a
    h = _norm(p["ln_x"], x, cfg)
    if mode == "decode":
        c = attn_lib.cross_attention_decode(
            p["cross"], h, {"k": st["cross_k"], "v": st["cross_v"]}, cfg
        )
        cross_kv = {"k": st["cross_k"], "v": st["cross_v"]}
    else:
        c, _ = attn_lib.attention_full(
            p["cross"], h, cfg, window=BIG_WINDOW, kv_src=enc_out,
            causal=False, use_rope=False,
        )
        cross_kv = (
            attn_lib.precompute_cross_kv(p["cross"], enc_out, cfg)
            if mode == "prefill"
            else None
        )
    x = x + c
    x = common.grad_dtype_barrier(
        x + ffn.dense_ffn(p["ffn"], _norm(p["ln2"], x, cfg), cfg)
    )
    new_cache = None
    if mode != "train":
        new_cache = {}
        if kv is not None:
            new_cache.update(kv)
        if cross_kv is not None:
            new_cache["cross_k"] = cross_kv["k"]
            new_cache["cross_v"] = cross_kv["v"]
    return x, new_cache


# --------------------------------------------------------------------------
# scanned stacks
# --------------------------------------------------------------------------


def scan_stack(body, x, stacked_params, xs, cfg: ModelConfig):
    """Run ``body(p_l, x, xs_l) -> (x, ys_l)`` over stacked layers."""

    def f(carry, inputs):
        p_l, xs_l = inputs
        out, ys = body(p_l, carry, xs_l)
        return out, ys

    if cfg.remat:
        f = jax.checkpoint(f, prevent_cse=False)
    return jax.lax.scan(f, x, (stacked_params, xs))
