"""Top-level model API: init / train loss / prefill / decode for all archs.

Parameter tree layout::

  embed        (V, D)
  ln_in        rwkv pre-norm (ssm family)
  head_layers  {"0": block, ...}    deepseek leading dense layers (unscanned)
  layers       stacked block params (L_scan leading axis), lax.scan'd
  enc_layers   whisper encoder stack
  final_norm / enc_final_norm
  lm_head      (D, V) unless tied
  mtp          deepseek-v3 multi-token-prediction head

Caches are dicts of stacked arrays (see family-specific builders below).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common, transformer as tfm
from repro.models.parallel import ParallelContext


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def num_scanned_layers(cfg: ModelConfig) -> int:
    return cfg.num_layers - (cfg.first_dense_layers if cfg.moe else 0)


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    kg = common.KeyGen(key)
    pdt = common.dtype_of(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": common.dense_init(kg(), (cfg.vocab_size, cfg.d_model), pdt),
        "final_norm": tfm._norm_params(cfg, tfm._uses_layer_norm(cfg)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(kg(), (cfg.d_model, cfg.vocab_size), pdt)

    fam = cfg.family
    if fam == "ssm":
        params["ln_in"] = tfm._norm_params(cfg, True)
        blocks = [tfm.init_rwkv_block(kg, cfg) for _ in range(cfg.num_layers)]
    elif fam == "hybrid":
        blocks = [tfm.init_hymba_block(kg, cfg) for _ in range(cfg.num_layers)]
    elif fam == "audio":
        params["enc_layers"] = common.stack_layers(
            [tfm.init_encoder_block(kg, cfg) for _ in range(cfg.encoder_layers)]
        )
        params["enc_final_norm"] = tfm._norm_params(cfg, True)
        blocks = [tfm.init_decoder_block(kg, cfg) for _ in range(cfg.num_layers)]
    else:  # dense / moe / vlm
        if cfg.moe and cfg.first_dense_layers:
            params["head_layers"] = {
                str(i): tfm.init_lm_block(kg, cfg, moe_layer=False)
                for i in range(cfg.first_dense_layers)
            }
        blocks = [
            tfm.init_lm_block(kg, cfg, moe_layer=cfg.moe)
            for _ in range(num_scanned_layers(cfg))
        ]
    params["layers"] = common.stack_layers(blocks)

    if cfg.mtp:
        params["mtp"] = {
            "proj": common.dense_init(kg(), (2 * cfg.d_model, cfg.d_model), pdt),
            "block": tfm.init_lm_block(kg, cfg, moe_layer=False),
            "norm": tfm._norm_params(cfg, False),
        }
    return params


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg: ModelConfig):
    cdt = common.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
    if cfg.family == "ssm":
        x = tfm._norm(params["ln_in"], x, cfg)
    return x


def lm_head(params, x, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


def _bias_zeros(cfg: ModelConfig, ctx: Optional[ParallelContext]):
    l = num_scanned_layers(cfg)
    e = max(cfg.n_routed_experts, 1)
    if ctx is None:
        return jnp.zeros((l, e), jnp.float32)
    return jnp.zeros((l, ctx.dp_size, ctx.tp_size, e), jnp.float32)


# --------------------------------------------------------------------------
# forward stacks (train)
# --------------------------------------------------------------------------


def _windows(cfg: ModelConfig):
    w = tfm.layer_windows(cfg)
    if cfg.moe and cfg.first_dense_layers:
        return w[cfg.first_dense_layers :]
    return w


def _run_train_stack(params, x, cfg: ModelConfig, ctx, bias):
    fam = cfg.family
    if fam == "ssm":

        def body(p, h, _xs):
            h, _ = tfm.rwkv_block(p, h, cfg, ctx=ctx)
            return h, jnp.zeros((), jnp.float32)

        x, _ = tfm.scan_stack(body, x, params["layers"], jnp.zeros((cfg.num_layers,)), cfg)
        return x, None

    if fam == "hybrid":
        windows = jnp.asarray(tfm.layer_windows(cfg))

        def body(p, h, w):
            h, _ = tfm.hymba_block(p, h, cfg, window=w, mode="train")
            return h, jnp.zeros((), jnp.float32)

        x, _ = tfm.scan_stack(body, x, params["layers"], windows, cfg)
        return x, None

    if fam == "audio":
        raise AssertionError("audio handled in train_loss")

    # dense / moe / vlm
    if cfg.moe and cfg.first_dense_layers:
        for i in range(cfg.first_dense_layers):
            x, _, _ = tfm.lm_block_full(
                params["head_layers"][str(i)], x, cfg, ctx,
                window=tfm.BIG_WINDOW, bias=None, moe_layer=False,
            )
    windows = jnp.asarray(_windows(cfg))
    if bias is None:
        bias = _bias_zeros(cfg, ctx)

    def body(p, h, xs):
        w, b = xs
        h, _, counts = tfm.lm_block_full(
            p, h, cfg, ctx, window=w, bias=b, moe_layer=cfg.moe
        )
        return h, counts

    x, counts = tfm.scan_stack(body, x, params["layers"], (windows, bias), cfg)
    return x, (counts if cfg.moe else None)


def train_loss(params, batch, cfg: ModelConfig, ctx=None, bias=None):
    """Returns (loss, aux) -- aux carries per-layer dispatch counts (MoE)."""
    if cfg.family == "audio":
        return _whisper_train_loss(params, batch, cfg)

    tokens, labels = batch["tokens"], batch["labels"]
    x = embed_tokens(params, tokens, cfg)
    x, counts = _run_train_stack(params, x, cfg, ctx, bias)
    h_final = x
    x = tfm._norm(params["final_norm"], x, cfg)
    logits = lm_head(params, x, cfg)
    loss = common.cross_entropy(logits, labels, cfg.final_softcap)
    aux = {"counts": counts, "loss_main": loss}

    if cfg.mtp:
        mtp = params["mtp"]
        nxt = embed_tokens(params, tokens, cfg)[:, 1:, :]
        h = jnp.concatenate(
            [common.rms_norm(h_final[:, :-1, :], mtp["norm"]["scale"], cfg.norm_eps), nxt],
            axis=-1,
        ) @ mtp["proj"]
        h, _, _ = tfm.lm_block_full(
            mtp["block"], h, cfg, ctx, window=tfm.BIG_WINDOW, bias=None, moe_layer=False
        )
        h = tfm._norm(params["final_norm"], h, cfg)
        mtp_logits = lm_head(params, h, cfg)
        mtp_loss = common.cross_entropy(mtp_logits, labels[:, 1:], cfg.final_softcap)
        aux["loss_mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    return loss, aux


def _whisper_encode(params, frames, cfg: ModelConfig):
    cdt = common.dtype_of(cfg.compute_dtype)
    s = frames.shape[1]
    pos = jnp.asarray(common.sinusoidal_positions(s, cfg.d_model), cdt)
    x = frames.astype(cdt) + pos[None]

    def body(p, h, _xs):
        return tfm.encoder_block(p, h, cfg), jnp.zeros((), jnp.float32)

    x, _ = tfm.scan_stack(body, x, params["enc_layers"], jnp.zeros((cfg.encoder_layers,)), cfg)
    return tfm._norm(params["enc_final_norm"], x, cfg)


def _whisper_embed_dec(params, tokens, cfg: ModelConfig, pos_offset=0):
    cdt = common.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    s = tokens.shape[1]
    pos_tab = jnp.asarray(
        common.sinusoidal_positions(pos_offset + s, cfg.d_model), cdt
    )[pos_offset:]
    return x + pos_tab[None]


def _whisper_train_loss(params, batch, cfg: ModelConfig):
    enc_out = _whisper_encode(params, batch["frames"], cfg)
    x = _whisper_embed_dec(params, batch["tokens"], cfg)

    def body(p, h, _xs):
        h, _ = tfm.decoder_block(p, h, enc_out, cfg, mode="train")
        return h, jnp.zeros((), jnp.float32)

    x, _ = tfm.scan_stack(body, x, params["layers"], jnp.zeros((cfg.num_layers,)), cfg)
    x = tfm._norm(params["final_norm"], x, cfg)
    logits = lm_head(params, x, cfg)
    loss = common.cross_entropy(logits, batch["labels"])
    return loss, {"counts": None, "loss_main": loss}


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------


def prefill(params, batch, cfg: ModelConfig, ctx=None, cache_len: int = 0, bias=None):
    """Full-sequence forward building a decode cache.

    Returns (last-token logits (B, V), cache).
    """
    cache_len = cache_len or batch["tokens"].shape[1]
    fam = cfg.family

    if fam == "audio":
        enc_out = _whisper_encode(params, batch["frames"], cfg)
        x = _whisper_embed_dec(params, batch["tokens"], cfg)

        def body(p, h, _xs):
            h, c = tfm.decoder_block(
                p, h, enc_out, cfg, mode="prefill", cache_len=cache_len
            )
            return h, c

        x, cache = tfm.scan_stack(
            body, x, params["layers"], jnp.zeros((cfg.num_layers,)), cfg
        )
        cache = {"scan": cache}
    elif fam == "ssm":
        x = embed_tokens(params, batch["tokens"], cfg)

        def body(p, h, _xs):
            return tfm.rwkv_block(p, h, cfg, state=None, ctx=ctx)

        x, cache = tfm.scan_stack(
            body, x, params["layers"], jnp.zeros((cfg.num_layers,)), cfg
        )
        cache = {"scan": cache}
    elif fam == "hybrid":
        x = embed_tokens(params, batch["tokens"], cfg)
        windows = jnp.asarray(tfm.layer_windows(cfg))

        def body(p, h, w):
            return tfm.hymba_block(
                p, h, cfg, window=w, mode="prefill", cache_len=cache_len
            )

        x, cache = tfm.scan_stack(body, x, params["layers"], windows, cfg)
        cache = {"scan": cache}
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
        head_caches = {}
        if cfg.moe and cfg.first_dense_layers:
            for i in range(cfg.first_dense_layers):
                x, c, _ = tfm.lm_block_full(
                    params["head_layers"][str(i)], x, cfg, ctx,
                    window=tfm.BIG_WINDOW, bias=None, moe_layer=False,
                    return_cache=True, cache_len=cache_len,
                )
                head_caches[str(i)] = c
        windows = jnp.asarray(_windows(cfg))
        if bias is None:
            bias = _bias_zeros(cfg, ctx)

        def body(p, h, xs):
            w, b = xs
            h, c, _ = tfm.lm_block_full(
                p, h, cfg, ctx, window=w, bias=b, moe_layer=cfg.moe,
                return_cache=True, cache_len=cache_len,
            )
            return h, c

        x, cache = tfm.scan_stack(body, x, params["layers"], (windows, bias), cfg)
        cache = {"scan": cache}
        if head_caches:
            cache["head"] = head_caches

    x = tfm._norm(params["final_norm"], x[:, -1:, :], cfg)
    logits = lm_head(params, x, cfg)[:, 0, :]
    if cfg.final_softcap:
        logits = common.softcap(logits, cfg.final_softcap)
    return logits, cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def init_decode_cache(params, cfg: ModelConfig, batch: int, cache_len: int, ctx=None):
    """Preallocated cache for decode-only lowering (decode_32k / long_500k)."""
    cdt = common.dtype_of(cfg.compute_dtype)
    l = num_scanned_layers(cfg)
    fam = cfg.family
    dh = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads

    def kv(layers):
        return {
            "k": jnp.zeros((layers, batch, cache_len, kvh, dh), cdt),
            "v": jnp.zeros((layers, batch, cache_len, kvh, dh), cdt),
        }

    if fam == "ssm":
        h = cfg.d_model // cfg.rwkv_head_dim
        n = cfg.rwkv_head_dim
        return {
            "scan": {
                "wkv": jnp.zeros((l, batch, h, n, n), jnp.float32),
                "tm_shift": jnp.zeros((l, batch, cfg.d_model), cdt),
                "cm_shift": jnp.zeros((l, batch, cfg.d_model), cdt),
            }
        }
    if fam == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        c = kv(l)
        c.update(
            {
                "ssm": jnp.zeros((l, batch, di, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((l, batch, cfg.conv_kernel - 1, di), cdt),
            }
        )
        return {"scan": c}
    if fam == "audio":
        c = kv(l)
        c["cross_k"] = jnp.zeros((l, batch, cfg.encoder_seq, kvh, dh), cdt)
        c["cross_v"] = jnp.zeros((l, batch, cfg.encoder_seq, kvh, dh), cdt)
        return {"scan": c}
    if cfg.use_mla:
        cache = {
            "scan": {
                "ckv": jnp.zeros((l, batch, cache_len, cfg.kv_lora_rank), cdt),
                "k_rope": jnp.zeros((l, batch, cache_len, cfg.qk_rope_head_dim), cdt),
            }
        }
        if cfg.moe and cfg.first_dense_layers:
            cache["head"] = {
                str(i): {
                    "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), cdt),
                    "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), cdt),
                }
                for i in range(cfg.first_dense_layers)
            }
        return cache
    return {"scan": kv(l)}


def decode_step(params, tokens, cache, pos, cfg: ModelConfig, ctx=None, bias=None):
    """One decode step.  tokens: (B,); pos: scalar int32 (next position).

    Returns (logits (B, V), new cache).
    """
    fam = cfg.family
    if fam == "audio":
        cdt = common.dtype_of(cfg.compute_dtype)
        x = params["embed"][tokens[:, None]].astype(cdt)
        cache_len = cache["scan"]["k"].shape[2]
        pos_tab = jnp.asarray(
            common.sinusoidal_positions(cache_len, cfg.d_model), cdt
        )
        x = x + jax.lax.dynamic_slice_in_dim(pos_tab, pos, 1, 0)[None]
    else:
        x = embed_tokens(params, tokens[:, None], cfg)

    new_cache: dict[str, Any] = {}
    if fam == "ssm":

        def body(p, h, c):
            return tfm.rwkv_block(p, h, cfg, state=c, ctx=ctx)

        x, sc = tfm.scan_stack(body, x, (params["layers"]), cache["scan"], cfg)
        # scan passes (params, xs); repack:
        new_cache["scan"] = sc
    elif fam == "hybrid":
        windows = jnp.asarray(tfm.layer_windows(cfg))

        def body(p, h, xs):
            w, c = xs
            return tfm.hymba_block(p, h, cfg, window=w, mode="decode", cache=c, pos=pos)

        x, sc = tfm.scan_stack(body, x, params["layers"], (windows, cache["scan"]), cfg)
        new_cache["scan"] = sc
    elif fam == "audio":

        def body(p, h, c):
            return tfm.decoder_block(p, h, None, cfg, mode="decode", cache=c, pos=pos)

        x, sc = tfm.scan_stack(body, x, params["layers"], cache["scan"], cfg)
        new_cache["scan"] = sc
    else:
        if cfg.moe and cfg.first_dense_layers:
            new_cache["head"] = {}
            for i in range(cfg.first_dense_layers):
                x, c, _ = tfm.lm_block_decode(
                    params["head_layers"][str(i)], x, cache["head"][str(i)], pos, cfg,
                    ctx, window=tfm.BIG_WINDOW, bias=None, moe_layer=False,
                )
                new_cache["head"][str(i)] = c
        windows = jnp.asarray(_windows(cfg))
        if bias is None:
            bias = _bias_zeros(cfg, ctx)

        def body(p, h, xs):
            w, b, c = xs
            h, c2, _ = tfm.lm_block_decode(
                p, h, c, pos, cfg, ctx, window=w, bias=b, moe_layer=cfg.moe
            )
            return h, c2

        x, sc = tfm.scan_stack(
            body, x, params["layers"], (windows, bias, cache["scan"]), cfg
        )
        new_cache["scan"] = sc

    x = tfm._norm(params["final_norm"], x, cfg)
    logits = lm_head(params, x, cfg)[:, 0, :]
    if cfg.final_softcap:
        logits = common.softcap(logits, cfg.final_softcap)
    return logits, new_cache
