"""Feed-forward blocks: dense (GLU / plain) and expert-parallel MoE.

The MoE layer is the framework's manual-collective region.  Einsum
(GShard-style) dispatch wastes O(T * S * k) memory or O(T * E * C * D)
FLOPs at deepseek scale, so we do what production EP systems do, expressed
in jax-native constructs (DESIGN.md Section 2.3):

  route locally -> scatter tokens into per-expert capacity buffers ->
  all_to_all over the EP axes -> expert matmuls -> all_to_all back ->
  weighted gather-combine.

Expert-parallel axis selection (models/parallel.py):
* E divisible by the full (data x model) product: experts sharded over all
  chips (deepseek-v3, 256 experts / 256 chips -> 1 per chip);
* otherwise experts shard the TP axis and their weights are FSDP-sharded
  over 'data' with an explicit per-layer all-gather (deepseek-v2,
  160 = 10 x 16).

Routing goes through the CARE-biased top-k router (kernels/ref.py oracle by
default; the Pallas kernel on TPU via ``use_pallas_router``).  Counts are
returned *per dispatcher* (no implicit all-reduce) so the balancer's sparse
sync -- the paper's contribution -- is the only place global counts are
ever materialised.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import common
from repro.models.parallel import ParallelContext

def init_dense_ffn(kg: common.KeyGen, cfg: ModelConfig, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    pdt = common.dtype_of(cfg.param_dtype)
    out_scale = 0.02 / max(cfg.num_layers, 1) ** 0.5
    p = {
        "w_in": common.dense_init(kg(), (d, f), pdt),
        "w_out": common.dense_init(kg(), (f, d), pdt, scale=out_scale),
    }
    if cfg.glu:
        p["w_gate"] = common.dense_init(kg(), (d, f), pdt)
    return p


def dense_ffn(p, x, cfg: ModelConfig):
    act = common.activation(cfg.act)
    h = act(x @ p["w_in"])
    if cfg.glu:
        h = h * (x @ p["w_gate"])
    return h @ p["w_out"]


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


def init_moe_ffn(kg: common.KeyGen, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_routed_experts, cfg.moe_d_ff
    pdt = common.dtype_of(cfg.param_dtype)
    out_scale = 0.02 / max(cfg.num_layers, 1) ** 0.5
    p = {
        "gate": common.dense_init(kg(), (d, e), jnp.float32),
        "w_in": common.dense_init(kg(), (e, d, f), pdt),
        "w_gate_h": common.dense_init(kg(), (e, d, f), pdt),
        "w_out": common.dense_init(kg(), (e, f, d), pdt, scale=out_scale),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_dense_ffn(kg, cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _route(logits, bias, cfg: ModelConfig):
    if cfg.use_pallas_router:
        return kernel_ops.moe_route(logits, bias, cfg.moe_top_k, gate_fn=cfg.gate_fn)
    return kernel_ref.moe_route_ref(logits, bias, cfg.moe_top_k, cfg.gate_fn)


def _capacity(t_loc: int, k: int, e: int, factor: float) -> int:
    cap = int(max(4, -(-t_loc * k * factor // e)))
    return min(cap, t_loc * k)


def _moe_local(xt, bias, p, cfg: ModelConfig, ctx: ParallelContext | None = None):
    """Per-device MoE body.  xt: (t_loc, D) local tokens.

    Expert weights in ``p`` are already *local* shards: (E_loc, D, F) under
    pure EP sharding, or (E_loc, D/fsdp, F) under EP+FSDP (gathered here).
    """
    t_loc, d = xt.shape
    e, k = cfg.n_routed_experts, cfg.moe_top_k
    cdt = common.dtype_of(cfg.compute_dtype)

    w_in_l, w_gate_l, w_out_l = p["w_in"], p["w_gate_h"], p["w_out"]
    if ctx is not None and ctx.fsdp_axis is not None:
        # Expert weights are FSDP-sharded on the D/F dim: gather per layer.
        w_in_l = jax.lax.all_gather(w_in_l, ctx.fsdp_axis, axis=1, tiled=True)
        w_gate_l = jax.lax.all_gather(w_gate_l, ctx.fsdp_axis, axis=1, tiled=True)
        w_out_l = jax.lax.all_gather(w_out_l, ctx.fsdp_axis, axis=2, tiled=True)

    logits = xt.astype(jnp.float32) @ p["gate"]
    idx, weights, counts = _route(logits, bias, cfg)  # (t,k),(t,k),(E,)

    cap = _capacity(t_loc, k, e, cfg.moe_capacity_factor)
    # Position of each (token, slot) within its expert's capacity buffer.
    flat_e = idx.reshape(-1)  # (t*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos * onehot, axis=1)  # (t*k,)
    keep = pos < cap
    lin = jnp.where(keep, flat_e * cap + pos, e * cap)  # overflow -> sink row

    buf = jnp.zeros((e * cap + 1, d), cdt)
    tok_rows = jnp.repeat(xt.astype(cdt), k, axis=0)  # (t*k, D)
    buf = buf.at[lin].add(tok_rows)
    buf = buf[: e * cap]

    ep = ctx.ep_size if ctx is not None else 1
    e_loc = e // ep
    if ep > 1:
        send = buf.reshape(ep, e_loc * cap, d)
        recv = jax.lax.all_to_all(
            send, ctx.ep_axes, split_axis=0, concat_axis=0, tiled=False
        )  # (EP, E_loc*cap, D): slice [j] came from device j
        work = recv.reshape(ep, e_loc, cap, d).transpose(1, 0, 2, 3)
        work = work.reshape(e_loc, ep * cap, d)
    else:
        work = buf.reshape(e, cap, d)

    act = common.activation(cfg.act)
    h = act(jnp.einsum("end,edf->enf", work, w_in_l))
    h = h * jnp.einsum("end,edf->enf", work, w_gate_l)
    out = jnp.einsum("enf,efd->end", h, w_out_l)

    if ep > 1:
        out = out.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3)
        out = out.reshape(ep, e_loc * cap, d)
        back = jax.lax.all_to_all(
            out, ctx.ep_axes, split_axis=0, concat_axis=0, tiled=False
        )
        back = back.reshape(e * cap, d)
    else:
        back = out.reshape(e * cap, d)

    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)
    picked = back[lin]  # (t*k, D); sink row is zero
    w_flat = (weights.reshape(-1, 1) * keep[:, None]).astype(cdt)
    y = jnp.sum((picked * w_flat).reshape(t_loc, k, d), axis=1)
    return y, counts.astype(jnp.float32)


def moe_ffn(p, x, bias, cfg: ModelConfig, ctx: ParallelContext | None = None):
    """Expert-parallel MoE forward.

    Args:
      p: layer params.  x: (B, S, D).  bias: per-dispatcher CARE selection
        bias -- (E,) when ctx is None, else (DP, TP, E) sharded one row per
        dispatcher.  ctx: parallel context (None = single device).

    Returns:
      (y, counts): y (B, S, D); counts -- (E,) local counts when ctx is
      None, else (DP, TP, E) per-dispatcher counts (no cross-device
      reduction here; the CARE balancer syncs sparsely).
    """
    b, s, d = x.shape

    manual = (
        ctx is not None
        and s % ctx.tp_size == 0
        and b % ctx.dp_size == 0
        and ctx.ep_size > 1
    )
    if not manual:
        # Single-device reference path, and the decode path (tokens too few
        # to shard over TP): GSPMD-auto on small global arrays.
        bias_flat = bias.reshape(-1, cfg.n_routed_experts).mean(axis=0)
        y, counts = _moe_local(x.reshape(b * s, d), bias_flat, p, cfg)
        y = y.reshape(b, s, d)
        if cfg.n_shared_experts:
            y = y + dense_ffn(p["shared"], x, cfg)
        if ctx is not None:
            counts = jnp.broadcast_to(
                counts[None, None, :] / (ctx.dp_size * ctx.tp_size),
                (ctx.dp_size, ctx.tp_size, cfg.n_routed_experts),
            )
        return y, counts

    P = jax.sharding.PartitionSpec
    dp, tp = ctx.dp_axes, ctx.tp_axis
    e = cfg.n_routed_experts

    def body(x_loc, bias_loc, gate, w_in, w_gate_h, w_out):
        bl, sl, _ = x_loc.shape
        pp = {"gate": gate, "w_in": w_in, "w_gate_h": w_gate_h, "w_out": w_out}
        y, counts = _moe_local(
            x_loc.reshape(bl * sl, d), bias_loc.reshape(-1), pp, cfg, ctx
        )
        return y.reshape(bl, sl, d), counts.reshape(1, 1, e)

    if ctx.fsdp_axis is not None:
        w_spec = P(tp, ctx.fsdp_axis, None)
        w_out_spec = P(tp, None, ctx.fsdp_axis)
    else:
        w_spec = P(ctx.ep_axes, None, None)
        w_out_spec = w_spec

    y, counts = jax.shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            P(dp, tp, None),  # x: batch over dp, seq over tp
            P(dp, tp, None),  # bias per dispatcher
            P(None, None),  # gate replicated
            w_spec,
            w_spec,
            w_out_spec,
        ),
        out_specs=(P(dp, tp, None), P(dp, tp, None)),
        check_vma=False,
    )(x, bias, p["gate"], p["w_in"], p["w_gate_h"], p["w_out"])

    if cfg.n_shared_experts:
        y = y + dense_ffn(p["shared"], x, cfg)
    return y, counts
