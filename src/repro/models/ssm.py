"""Recurrent sequence mixers: RWKV-6 ("Finch") and Mamba-1 (hymba branch).

Both are linear-state models, so the 500k-context decode shape is O(1) per
token: the entire context lives in a fixed-size state
(RWKV: (H, n, n) per layer; Mamba: (d_inner, N) + a small conv tail).

RWKV-6 follows arXiv:2404.05892: token-shift ddlerp (low-rank
data-dependent mixing), per-channel data-dependent decay
``w = exp(-exp(w0 + lora(x)))``, and the WKV6 recurrence

    o_t = r_t @ (S_{t-1} + (u * k_t) v_t^T),   S_t = diag(w_t) S_{t-1} + k_t v_t^T

with per-head GroupNorm and an output gate.  Training uses a time scan; the
chunked parallel form is a hillclimb candidate (EXPERIMENTS.md Section Perf).

Mamba-1 (hymba's SSM heads): in-proj -> causal conv -> selective SSM with
ZOH discretisation -> gated out-proj, state size N = cfg.ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, parallel

RWKV_LORA = 32
RWKV_DECAY_LORA = 64


# ==========================================================================
# RWKV-6
# ==========================================================================


def init_rwkv_time_mix(kg: common.KeyGen, cfg: ModelConfig):
    d = cfg.d_model
    pdt = common.dtype_of(cfg.param_dtype)
    h = d // cfg.rwkv_head_dim
    return {
        "mu_x": common.dense_init(kg(), (d,), pdt, scale=0.5),
        "mu": common.dense_init(kg(), (5, d), pdt, scale=0.5),
        "maa_w1": common.dense_init(kg(), (d, 5 * RWKV_LORA), pdt),
        "maa_w2": common.dense_init(kg(), (5, RWKV_LORA, d), pdt),
        "w0": common.dense_init(kg(), (d,), jnp.float32, scale=1.0),
        "decay_w1": common.dense_init(kg(), (d, RWKV_DECAY_LORA), pdt),
        "decay_w2": common.dense_init(kg(), (RWKV_DECAY_LORA, d), pdt),
        "u": common.dense_init(kg(), (h, cfg.rwkv_head_dim), jnp.float32, scale=0.5),
        "wr": common.dense_init(kg(), (d, d), pdt),
        "wk": common.dense_init(kg(), (d, d), pdt),
        "wv": common.dense_init(kg(), (d, d), pdt),
        "wg": common.dense_init(kg(), (d, d), pdt),
        "wo": common.dense_init(kg(), (d, d), pdt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
        "gn_scale": jnp.ones((d,), pdt),
        "gn_bias": jnp.zeros((d,), pdt),
    }


def init_rwkv_channel_mix(kg: common.KeyGen, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    pdt = common.dtype_of(cfg.param_dtype)
    return {
        "mu_k": common.dense_init(kg(), (d,), pdt, scale=0.5),
        "mu_r": common.dense_init(kg(), (d,), pdt, scale=0.5),
        "wk": common.dense_init(kg(), (d, f), pdt),
        "wv": common.dense_init(kg(), (f, d), pdt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
        "wr": common.dense_init(kg(), (d, d), pdt),
    }


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift mixing -> five mixed streams (w,k,v,r,g)."""
    dx = x_prev - x  # (B,S,D)
    xxx = x + dx * p["mu_x"]
    lora = jnp.tanh(xxx @ p["maa_w1"])  # (B,S,5*r)
    b, s, _ = lora.shape
    lora = lora.reshape(b, s, 5, RWKV_LORA)
    deltas = jnp.einsum("bsir,ird->ibsd", lora, p["maa_w2"])  # (5,B,S,D)
    mixed = x[None] + dx[None] * (p["mu"][:, None, None, :] + deltas)
    return mixed  # order: w, k, v, r, g


def _wkv6_scan(r, k, v, w, u, state):
    """WKV6 recurrence.  r,k,v,w: (B,S,H,n); u: (H,n); state: (B,H,n,n).

    Returns (out (B,S,H,n), final_state).  f32 state for stability.
    """
    r, k, v, w = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B,H,n)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,n,n)
        ot = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, ot

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


WKV_CHUNK = 32  # chunk length of the parallel form (VMEM-sized tiles)


def _wkv6_chunked(r, k, v, lw, u, state, chunk: int = WKV_CHUNK):
    """Chunked-parallel WKV6 -- identical math to ``_wkv6_scan``.

    Instead of one scan step per token (state read+write every step, tiny
    vector ops), the sequence is processed in chunks of C tokens: an
    O(C^2 n) intra-chunk "attention" with relative decays plus one state
    contraction per chunk.  State traffic drops by C, and the inner ops
    become (C, n) x (n, m) matmuls -- MXU-shaped on TPU.

    Numerical form: all relative decays are exponentials of *non-positive*
    log-decay sums (lw = log w = -exp(decay) <= 0), so every exp() here is
    <= 1 and the chunk math is stable at any chunk length.

    Args:
      r, k, v: (B, S, H, n); lw: (B, S, H, n) log-decay (<= 0, f32);
      u: (H, n); state: (B, H, n, n) f32.
    Returns (out (B, S, H, n) f32, final state).
    """
    b, s, h, n = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    r, k, v = (a.astype(jnp.float32) for a in (r, k, v))
    lw = lw.astype(jnp.float32)

    def to_chunks(a):  # (B,S,H,n) -> (NC, B, H, C, n)
        return a.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)

    # Clamp the per-step log-decay: w = exp(lw) <= 9e-14 is zero for every
    # practical purpose, and unbounded |lw| makes the in-chunk cumsum
    # differences (cum_ex[t] - cum[s]) cancel catastrophically in f32
    # (verified against a float64 sequential reference).
    lw = jnp.maximum(lw, -30.0)
    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    u_diag = u[None, :, :]  # (1, H, n)

    def one_chunk(s0, xs):
        rt, kt, vt, lwt = xs  # (B, H, C, n)
        cum = jnp.cumsum(lwt, axis=2)  # inclusive log-decay sums
        cum_ex = cum - lwt  # exclusive (sum over i < t)
        total = cum[:, :, -1:, :]  # (B,H,1,n)

        # Inter-chunk: queries decayed from the chunk start hit the state.
        q = rt * jnp.exp(cum_ex)  # (B,H,C,n)
        inter = jnp.einsum("bhcn,bhnm->bhcm", q, s0)

        # Intra-chunk: scores with per-channel relative decay, strictly
        # causal (s < t); the t == s "bonus" term uses u instead.
        dec = jnp.exp(cum_ex[:, :, :, None, :] - cum[:, :, None, :, :])
        scores = jnp.einsum("bhtn,bhsn,bhtsn->bhts", rt, kt, dec)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        intra = jnp.einsum("bhts,bhsm->bhtm", scores, vt)
        bonus = jnp.einsum("bhcn,bhcn->bhc", rt * u_diag[:, :, None, :], kt)
        intra = intra + bonus[..., None] * vt

        # State update: decay the carried state across the whole chunk and
        # add each key decayed from its own position to the chunk end.
        k_dec = kt * jnp.exp(total - cum)
        s_new = jnp.exp(total)[..., 0, :, None] * s0 + jnp.einsum(
            "bhcn,bhcm->bhnm", k_dec, vt
        )
        return s_new, inter + intra

    state, out = jax.lax.scan(one_chunk, state, (rc, kc, vc, lwc))
    # (NC, B, H, C, n) -> (B, S, H, n)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, s, h, n)
    return out, state


def rwkv_time_mix(p, x, cfg: ModelConfig, state=None, shift_prev=None, ctx=None):
    """x: (B,S,D).  state: (B,H,n,n) or None (zeros).  shift_prev: (B,D)."""
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    if shift_prev is None:
        shift_prev = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([shift_prev[:, None, :], x[:, :-1, :]], axis=1)

    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    decay = p["w0"] + (jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]).astype(jnp.float32)
    lw = -jnp.exp(decay.astype(jnp.float32))  # log w  (<= 0)
    # Keep the WKV path head-sharded over TP end-to-end (wr/wk/wv are
    # column-sharded, so their outputs are born sharded; the hints stop
    # GSPMD from gathering them back to replicated around the scan).
    # Single-token decode skips the hints: per-token reshard collectives
    # cost more than they save at S == 1 (measured).
    if s <= 1:
        ctx = None
    dp, tp = (ctx.dp_axes, ctx.tp_axis) if ctx is not None else (None, None)
    shard = lambda a: parallel.hint(a, ctx, dp, None, tp, None)  # noqa: E731
    r = shard((xr @ p["wr"]).reshape(b, s, h, n))
    k = shard((xk @ p["wk"]).reshape(b, s, h, n))
    v = shard((xv @ p["wv"]).reshape(b, s, h, n))
    g = parallel.hint(jax.nn.silu(xg @ p["wg"]), ctx, dp, None, tp)
    lw = shard(lw.reshape(b, s, h, n))

    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    state = parallel.hint(state, ctx, dp, tp)
    if s % WKV_CHUNK == 0 and s > WKV_CHUNK:
        # Chunked-parallel form: C-times less state traffic, MXU-shaped
        # inner matmuls (EXPERIMENTS.md Section Perf, rwkv hillclimb).
        out, state = _wkv6_chunked(r, k, v, lw, p["u"], state)
    else:
        out, state = _wkv6_scan(r, k, v, jnp.exp(lw), p["u"], state)
    state = parallel.hint(state, ctx, dp, tp)
    # Per-head group norm (local under head sharding).
    out = shard(out)
    mu = out.mean(axis=-1, keepdims=True)
    var = out.var(axis=-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d)
    out = parallel.hint(out, ctx, dp, None, tp)
    out = out * p["gn_scale"] + p["gn_bias"]
    out = (out.astype(x.dtype) * g) @ p["wo"]
    # Land the row-parallel output sequence-sharded: the TP partial sums
    # lower to a reduce-scatter instead of all-reduce + slice.
    out = parallel.hint(out, ctx, dp, tp)
    return out, state, x[:, -1, :]


def rwkv_channel_mix(p, x, cfg: ModelConfig, shift_prev=None, ctx=None):
    """Tensor-parallel FFN: wk column- / wv row-sharded, hidden F-sharded
    (keeps single-token decode weight traffic at 1/tp per chip)."""
    b, s, d = x.shape
    if shift_prev is None:
        shift_prev = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([shift_prev[:, None, :], x[:, :-1, :]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    dp, tp = (ctx.dp_axes, ctx.tp_axis) if ctx is not None else (None, None)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    k = parallel.hint(k, ctx, dp, None, tp)  # (B, S, F/tp) hidden sharded
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1, :]


# ==========================================================================
# Mamba-1 (hymba SSM branch)
# ==========================================================================


def init_mamba(kg: common.KeyGen, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    pdt = common.dtype_of(cfg.param_dtype)
    a_init = jnp.tile(
        jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, :], (di, 1)
    )
    return {
        "w_in": common.dense_init(kg(), (d, 2 * di), pdt),
        "conv": common.dense_init(kg(), (cfg.conv_kernel, di), pdt, scale=0.5),
        "conv_b": jnp.zeros((di,), pdt),
        "w_x": common.dense_init(kg(), (di, dt_rank + 2 * n), pdt),
        "w_dt": common.dense_init(kg(), (dt_rank, di), pdt),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": a_init,
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": common.dense_init(kg(), (di, d), pdt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
    }


def _causal_conv(x, kernel, bias, conv_state=None):
    """Depthwise causal conv.  x: (B,S,Di); kernel: (K,Di).

    conv_state: (B, K-1, Di) tail of the previous chunk (decode).
    Returns (y, new_conv_state).
    """
    kk = kernel.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([conv_state, x], axis=1)  # (B, S+K-1, Di)
    y = sum(
        xx[:, i : i + x.shape[1], :] * kernel[i][None, None, :] for i in range(kk)
    )
    return y + bias, xx[:, -(kk - 1) :, :]


def mamba(p, x, cfg: ModelConfig, state=None, conv_state=None):
    """Selective SSM.  x: (B,S,D) -> (B,S,D).  state: (B,Di,N)."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)

    xz = x @ p["w_in"]
    xi, z = xz[..., :di], xz[..., di:]
    xi, conv_state = _causal_conv(xi, p["conv"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    xdbc = xi @ p["w_x"]
    dt = jax.nn.softplus(
        (xdbc[..., :dt_rank] @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )  # (B,S,Di)
    bmat = xdbc[..., dt_rank : dt_rank + n].astype(jnp.float32)  # (B,S,N)
    cmat = xdbc[..., dt_rank + n :].astype(jnp.float32)  # (B,S,N)
    a = -jnp.exp(p["a_log"])  # (Di,N)

    if state is None:
        state = jnp.zeros((b, di, n), jnp.float32)

    xif = xi.astype(jnp.float32)

    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs  # (B,Di),(B,N),(B,N),(B,Di)
        da = jnp.exp(dt_t[..., None] * a[None])  # (B,Di,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(bmat, 1, 0),
        jnp.moveaxis(cmat, 1, 0),
        jnp.moveaxis(xif, 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1) + xif * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], state, conv_state
