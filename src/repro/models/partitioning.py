"""Partitioning rules: parameter / activation / cache PartitionSpecs.

Rule-based mapping from parameter-tree paths to PartitionSpecs:

* TP ('model' axis): attention heads, FFN hidden, vocab;
* EP: routed experts over (data, model) when divisible, else (model,) with
  FSDP weight sharding over 'data' (models/parallel.py);
* DP ('pod','data'): batch dims of activations, KV caches, and -- under
  ZeRO-1 -- the Adam moments (sharded over the first dp-divisible axis).

Everything degrades to replication when a dimension is not divisible, so
the same rules drive the 1-device smoke tests, the 256-chip pod and the
512-chip multi-pod mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.parallel import ParallelContext

# Leaf-name -> spec template for *unstacked* (single-layer) params.
#   "col"  : shard last dim over TP        (D, X) -> P(None, tp)
#   "row"  : shard first dim over TP       (X, D) -> P(tp, None)
#   "vec"  : shard the only dim over TP
#   "rep"  : replicate
_RULES = {
    "embed": "embed",
    "lm_head": "col",
    "wq": "col", "wk": "col", "wv": "col", "wg": "col", "wr": "col",
    "w_in": "col", "w_gate": "col", "w_gate_h": "col",
    "w_dq": "col", "w_uq": "col", "w_uk": "col", "w_uv": "col",
    "w_dkv": "rep", "maa_w1": "rep", "decay_w1": "rep", "w_x": "row_first",
    "wo": "row", "w_out": "row", "w_dt": "col", "proj": "rep",
    "conv": "col", "conv_b": "vec", "a_log": "row_first", "d_skip": "vec",
    "dt_bias": "vec", "bq": "vec", "bk": "vec", "bv": "vec",
    "u": "row_first", "gate": "rep",
    "maa_w2": "rep", "decay_w2": "rep",
}
# channel-mix weights (parent key "cm") have transposed roles.  (A fully
# replicated-weight SP variant was measured: it removes the train-time
# collectives but makes single-token decode weights-bound -- 4x worse --
# so the TP sharding stays; EXPERIMENTS.md §Perf.)
_CM_RULES = {"wk": "col", "wv": "row", "wr": "col"}


def _base_spec(rule: str, ndim: int, tp: str) -> P:
    if rule == "embed":
        return P(tp, None)
    if rule == "embed_d":
        # d_model-sharded: the token gather is fully local per chip (vocab
        # sharding makes GSPMD replicate the whole table per step).  Used
        # only for untied-head MoE archs -- under a tied head it would
        # force a vocab-sized logits all-reduce, and on dense archs the
        # D-sharded embedding output flips the residual-stream layout and
        # costs per-layer gathers (measured: EXPERIMENTS.md Section Perf).
        return P(None, tp)
    if rule == "col":
        return P(*([None] * (ndim - 1)), tp)
    if rule == "row":
        return P(tp, *([None] * (ndim - 1)))
    if rule == "row_first":
        return P(tp, *([None] * (ndim - 1)))
    if rule == "vec":
        return P(tp)
    return P(*([None] * ndim))


def _path_keys(path) -> list[str]:
    out = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            out.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            out.append(e.name)
    return out


def _divisible(spec: P, shape, mesh) -> P:
    """Downgrade any axis whose dimension is not divisible on the mesh."""
    fixed = []
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            fixed.append(None)
            continue
        group = names if isinstance(names, tuple) else (names,)
        size = int(np.prod([mesh.shape[a] for a in group]))
        fixed.append(names if dim % size == 0 else None)
    return P(*fixed)


def param_specs(abstract_params, cfg: ModelConfig, ctx: ParallelContext):
    """PartitionSpec pytree matching ``abstract_params``."""
    tp = ctx.tp_axis
    mesh = ctx.mesh
    moe_e = cfg.n_routed_experts

    def rule_for(path, leaf):
        keys = _path_keys(path)
        name = keys[-1]
        stacked = "layers" in keys or "enc_layers" in keys
        in_moe = "moe" in keys and "shared" not in keys
        in_cm = "cm" in keys

        if in_moe and name in ("w_in", "w_gate_h", "w_out"):
            if ctx.fsdp_axis is not None:
                # (E, D, F) / (E, F, D): experts over TP, D/F over fsdp axis
                if name == "w_out":
                    spec = P(tp, None, ctx.fsdp_axis)
                else:
                    spec = P(tp, ctx.fsdp_axis, None)
            else:
                spec = P(ctx.ep_axes, None, None)
        elif in_moe and name == "gate":
            spec = P(None, None)
        elif in_cm and name in _CM_RULES:
            spec = _base_spec(_CM_RULES[name], leaf.ndim - (1 if stacked else 0), tp)
        else:
            rule = _RULES.get(name, "rep")
            if rule == "embed" and cfg.moe and not cfg.tie_embeddings:
                rule = "embed_d"
            spec = _base_spec(rule, leaf.ndim - (1 if stacked else 0), tp)

        if stacked:
            spec = P(None, *spec)
        return _divisible(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule_for, abstract_params)


def zero1_specs(param_spec_tree, abstract_params, ctx: ParallelContext):
    """Adam-moment specs: param spec + shard one free axis over the dp axes.

    The first axis that is (a) unsharded in the param spec and (b) divisible
    by the dp product gets the dp axes -- classic ZeRO-1 partitioning
    without a separate parameter-gather step (GSPMD inserts it).
    """
    dp = ctx.dp_axes
    dp_size = ctx.dp_size
    mesh = ctx.mesh

    def widen(spec: P, leaf):
        entries = list(tuple(spec) + (None,) * (leaf.ndim - len(spec)))
        used = set()
        for e in entries:
            if e is None:
                continue
            used.update(e if isinstance(e, tuple) else (e,))
        if used & set(dp):
            return _divisible(P(*entries), leaf.shape, mesh)
        for i, (dim, cur) in enumerate(zip(leaf.shape, entries)):
            if cur is None and dim % dp_size == 0 and dim >= dp_size:
                entries[i] = dp if len(dp) > 1 else dp[0]
                break
        return _divisible(P(*entries), leaf.shape, mesh)

    return jax.tree_util.tree_map(widen, param_spec_tree, abstract_params)


def batch_specs(abstract_batch, ctx: ParallelContext):
    """Shard the batch dim over dp when divisible; everything else rep."""
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        s = P(dp, *([None] * (leaf.ndim - 1)))
        return _divisible(s, leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map(spec, abstract_batch)


_CACHE_RULES = {
    # KV caches (B, S, Kv, Dh): prefer head sharding over TP; fall back to
    # sequence sharding when the head count does not divide (gemma2 kv=8,
    # hymba kv=5 on a 16-wide TP axis) -- GSPMD turns the sharded-sequence
    # attention into partial softmax + reduction.
    "k": "kv",
    "v": "kv",
    "cross_k": "kv",
    "cross_v": "kv",
    # MLA compressed caches (B, S, R): shard the sequence.
    "ckv": ("dp", "tp", None),
    "k_rope": ("dp", "tp", None),
    "wkv": ("dp", "tp", None, None),
    "tm_shift": ("dp", "tp"),
    "cm_shift": ("dp", "tp"),
    "ssm": ("dp", "tp", None),
    "conv": ("dp", None, "tp"),
}


def cache_specs(abstract_cache, ctx: ParallelContext):
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    tp = ctx.tp_axis
    tp_size = ctx.tp_size

    def spec(path, leaf):
        keys = _path_keys(path)
        name = keys[-1]
        tpl = _CACHE_RULES.get(name)
        if tpl is None:
            return P(*([None] * leaf.ndim))
        stacked = "scan" in keys
        if tpl == "kv":
            b, s, kvh = leaf.shape[1 if stacked else 0 :][:3]
            if kvh % tp_size == 0:
                entries = [dp, None, tp, None]
            elif s % tp_size == 0:
                entries = [dp, tp, None, None]
            else:
                entries = [dp, None, None, None]
        else:
            entries = [dp if e == "dp" else tp if e == "tp" else None for e in tpl]
        if stacked:
            entries = [None] + entries
        entries = entries[: leaf.ndim] + [None] * (leaf.ndim - len(entries))
        return _divisible(P(*entries), leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)


def balancer_specs(abstract_state, ctx: ParallelContext):
    """(L, DP, TP, E) leaves: one row per dispatcher, sharded in place."""
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]

    def spec(leaf):
        if leaf.ndim == 4:
            return _divisible(P(None, dp, ctx.tp_axis, None), leaf.shape, ctx.mesh)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map(spec, abstract_state)


def to_shardings(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
