"""Parallel context: which mesh axes the model's manual regions use.

The model is mostly GSPMD-auto (pjit + sharding constraints), but the MoE
layer is a *manual* region (shard_map + all_to_all) because expert dispatch
is the one place where einsum-dispatch formulations waste O(E) compute or
memory and the collective schedule must be explicit.  This context carries
the mesh and axis-name assignments into the model; ``None`` means
single-device execution (smoke tests, reference numerics).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: jax.sharding.Mesh
    dp_axes: tuple[str, ...] = ("data",)  # batch / gradient axes
    tp_axis: str = "model"  # tensor-parallel axis
    ep_axes: tuple[str, ...] = ("data", "model")  # expert-parallel axes
    fsdp_axis: Optional[str] = None  # shard expert D dim when E doesn't
    #                                   divide the full EP product

    @property
    def ep_size(self) -> int:
        return int(
            __import__("math").prod(self.mesh.shape[a] for a in self.ep_axes)
        )

    @property
    def dp_size(self) -> int:
        return int(
            __import__("math").prod(self.mesh.shape[a] for a in self.dp_axes)
        )

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])


def hint(x, ctx: Optional[ParallelContext], *entries):
    """``with_sharding_constraint`` against the ctx mesh; no-op without one.

    ``entries`` are leading PartitionSpec entries (axis name, tuple of
    names, or None); trailing dims are unsharded.  Any entry whose
    dimension is not divisible on the mesh is downgraded to None, so the
    same hints drive smoke meshes and the 512-chip pod.
    """
    if ctx is None:
        return x
    import math

    from jax.sharding import NamedSharding, PartitionSpec

    fixed = []
    for dim, names in zip(x.shape, entries + (None,) * (x.ndim - len(entries))):
        if names is None:
            fixed.append(None)
            continue
        group = names if isinstance(names, tuple) else (names,)
        size = math.prod(ctx.mesh.shape[a] for a in group)
        fixed.append(names if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, PartitionSpec(*fixed))
    )


def choose_ep_axes(ctx_or_mesh, num_experts: int, dp_axes, tp_axis) -> tuple:
    """Pick EP axes: the widest mesh-axis product that divides E.

    Prefers (data..., model) for storage economy (deepseek-v3: 256 experts
    over 256 chips); falls back to (model,) + FSDP weight sharding over
    'data' when E only divides the TP axis (deepseek-v2: 160 = 10 x 16).
    """
    mesh = ctx_or_mesh
    full = [a for a in (*dp_axes, tp_axis) if a != "pod"]
    import math

    full_size = math.prod(mesh.shape[a] for a in full)
    if num_experts % full_size == 0:
        return tuple(full), None
    tp_size = mesh.shape[tp_axis]
    if num_experts % tp_size == 0:
        fsdp = "data" if "data" in mesh.shape else None
        return (tp_axis,), fsdp
    raise ValueError(
        f"num_experts={num_experts} not divisible by mesh axes {dict(mesh.shape)}"
    )
