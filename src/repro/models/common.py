"""Shared model building blocks: norms, RoPE, initialisers, dtype policy.

Parameters are plain nested dicts of jnp arrays (no framework dependency);
layer stacks are stored with a leading layer axis and consumed by
``lax.scan`` so the compiled HLO stays small regardless of depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[
        name
    ]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2, 2, shape)).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Sequential key splitter for building parameter trees."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6, *, offset: float = 0.0):
    """RMSNorm in f32 accumulation.  ``offset=1.0`` gives the gemma-style
    ``(1 + scale)`` parameterisation."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (offset + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x, cap: float):
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x, positions, theta: float):
    """Rotate pairs (x[..., ::2], x[..., 1::2]) -- interleaved convention.

    x: (..., S, H, Dh); positions: broadcastable to (..., S).
    """
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta), jnp.float32)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (length, dim)."""
    log_timescale = np.log(10_000.0) / (dim // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(dim // 2))
    scaled = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1).astype(np.float32)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _barrier_for(dtype_name: str):
    @jax.custom_vjp
    def barrier(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, ct):
        return (ct.astype(dtype_name),)

    barrier.defvjp(fwd, bwd)
    return barrier


def grad_dtype_barrier(x):
    """Identity forward; backward casts the cotangent to ``x.dtype``.

    Placed at block boundaries so activation gradients flow in the compute
    dtype (bf16) instead of the f32 they inherit from the loss head --
    halving every backward collective/DUS payload and letting the stacked
    per-layer gradient updates alias in place (no bf16<->f32 convert
    wrappers around the scan's dynamic-update-slice).  Standard
    mixed-precision practice: parameters and the cross-microbatch
    accumulator stay f32-mastered in the optimizer.
    """
    return _barrier_for(str(x.dtype))(x)


def stack_layers(per_layer_params: list):
    """Stack a list of identical pytrees along a new leading layer axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *per_layer_params)


def cross_entropy(logits, labels, final_cap: float = 0.0):
    """Token-mean cross entropy in f32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    if final_cap:
        logits = softcap(logits, final_cap)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def param_count(params) -> int:
    return int(
        sum(np.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    )
