"""Multi-head Latent Attention (DeepSeek v2/v3).

Queries and keys/values are projected through low-rank latents; the KV
cache stores only the compressed latent (kv_lora_rank) plus a single shared
RoPE key head -- 576 dims/token for v3 instead of ~32K for full MHA.

Two execution paths:
* ``mla_full``  -- expanded computation for train / prefill (materialises
  per-head K/V once over the whole sequence, MXU-friendly).
* ``mla_decode`` -- *absorbed* computation: W_uk is folded into the query
  and W_uv into the output so attention runs MQA-style against the
  compressed cache.  This is the TPU-native adaptation of DeepSeek's
  inference trick: per decoded token the cache traffic is
  O(S * (R + Dr)) instead of O(S * H * Dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, flash, parallel


def init_mla(kg: common.KeyGen, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    pdt = common.dtype_of(cfg.param_dtype)
    p = {
        "w_dkv": common.dense_init(kg(), (d, r + dr), pdt),
        "kv_norm": jnp.ones((r,), pdt),
        "w_uk": common.dense_init(kg(), (r, h * dn), pdt),
        "w_uv": common.dense_init(kg(), (r, h * dv), pdt),
        "wo": common.dense_init(kg(), (h * dv, d), pdt, scale=0.02 / max(cfg.num_layers, 1) ** 0.5),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = common.dense_init(kg(), (d, cfg.q_lora_rank), pdt)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), pdt)
        p["w_uq"] = common.dense_init(kg(), (cfg.q_lora_rank, h * (dn + dr)), pdt)
    else:
        p["wq"] = common.dense_init(kg(), (d, h * (dn + dr)), pdt)
    return p


def _queries(p, x, cfg: ModelConfig):
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = common.rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
        q = cq @ p["w_uq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(*x.shape[:-1], h, dn + dr)
    return q[..., :dn], q[..., dn:]  # (B,S,H,dn), (B,S,H,dr)


def _latents(p, x, cfg: ModelConfig, positions):
    """Compressed kv latent and rotated shared rope key."""
    r = cfg.kv_lora_rank
    ckv_full = x @ p["w_dkv"]
    ckv = common.rms_norm(ckv_full[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., r:][..., None, :]  # (B,S,1,dr) shared head
    k_rope = common.apply_rope(k_rope, positions, cfg.rope_theta)
    return ckv, k_rope[..., 0, :]  # (B,S,R), (B,S,dr)


def mla_full(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions=None,
    return_cache: bool = False,
    cache_len: int = 0,
    ctx=None,
):
    """Expanded MLA for train / prefill (causal, global attention).

    The expanded per-head K (nope + shared rope head) and V are kept
    *head-sharded* over TP (w_uq/w_uk/w_uv are column-sharded, so they are
    born that way; the hints stop GSPMD from resharding to sequence),
    making attention fully local per head shard.  Scores run through the
    blocked flash path so the (H, S, S) tensor is never materialised.
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    q_nope, q_rope = _queries(p, x, cfg)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    ckv, k_rope = _latents(p, x, cfg, positions)

    k_nope = (ckv @ p["w_uk"]).reshape(b, s, h, dn)
    v = (ckv @ p["w_uv"]).reshape(b, s, h, dv)

    dp, tp = (ctx.dp_axes, ctx.tp_axis) if ctx is not None else (None, None)
    shard = lambda a: parallel.hint(a, ctx, dp, None, tp, None)  # noqa: E731
    q = shard(jnp.concatenate([q_nope, q_rope], axis=-1))
    k = shard(
        jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
            axis=-1,
        )
    )
    v = shard(v)

    scale = 1.0 / (dn + dr) ** 0.5
    out = flash.flash_sdpa(
        q, k, v, scale=scale, q_positions=positions, causal=True
    )
    out = parallel.hint(out, ctx, dp, None, tp) @ p["wo"]
    out = parallel.hint(out, ctx, dp, tp)  # reduce-scatter landing (SP)

    if not return_cache:
        return out, None
    r = cfg.kv_lora_rank
    ckv_c = jnp.zeros((b, cache_len, r), ckv.dtype)
    kr_c = jnp.zeros((b, cache_len, dr), k_rope.dtype)
    ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv, (0, 0, 0))
    kr_c = jax.lax.dynamic_update_slice(kr_c, k_rope, (0, 0, 0))
    return out, {"ckv": ckv_c, "k_rope": kr_c}


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    """Absorbed single-token decode against the compressed cache."""
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    positions = jnp.full((b, 1), pos, jnp.int32)

    q_nope, q_rope = _queries(p, x, cfg)  # (B,1,H,dn),(B,1,H,dr)
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_t, kr_t = _latents(p, x, cfg, positions)  # (B,1,R),(B,1,dr)

    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_t.astype(cache["ckv"].dtype), (0, pos, 0)
    )
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_t.astype(cache["k_rope"].dtype), (0, pos, 0)
    )

    # Absorb W_uk into the query: q_eff[h] = W_uk[h] @ q_nope[h]  (R,)
    w_uk = p["w_uk"].reshape(r, h, dn)
    q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (B,1,H,R)

    scale = 1.0 / (dn + dr) ** 0.5
    scores = (
        jnp.einsum("bshr,btr->bhst", q_eff, ckv, preferred_element_type=jnp.float32)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope, preferred_element_type=jnp.float32)
    ) * scale
    t = ckv.shape[1]
    kpos = jnp.arange(t, dtype=jnp.int32)[None, None, None, :]
    scores = jnp.where(kpos <= pos, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)

    ctx = jnp.einsum("bhst,btr->bshr", probs, ckv)  # (B,1,H,R)
    w_uv = p["w_uv"].reshape(r, h, dv)
    out = jnp.einsum("bshr,rhd->bshd", ctx, w_uv).reshape(b, 1, h * dv)
    out = out @ p["wo"]
    return out, {"ckv": ckv, "k_rope": k_rope}
