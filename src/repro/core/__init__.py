"""Core library: the paper's contribution (CARE) as composable JAX modules."""

from repro.core.care import (  # noqa: F401
    Scenario,
    ServiceProcess,
    SimConfig,
    SimResult,
    StaticConfig,
    approx,
    comm,
    metrics,
    routing,
    simulate,
    simulate_batch,
    simulate_grid,
    stack_scenarios,
    theory,
    workload,
)
