"""Core library: the paper's contribution (CARE) as composable JAX modules."""

from repro.core.care import (  # noqa: F401
    SimConfig,
    SimResult,
    approx,
    comm,
    metrics,
    routing,
    simulate,
    simulate_batch,
    theory,
    workload,
)
