"""Core library: the paper's contribution (CARE) as composable JAX modules."""

from repro.core.care import (  # noqa: F401
    SimConfig,
    SimResult,
    approx,
    metrics,
    routing,
    simulate,
    theory,
)
