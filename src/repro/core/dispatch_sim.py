"""Multi-dispatcher MoE dispatch simulation: CARE at the expert tier.

The training-tier balancer (``core/moe_balancer.py``) is exact when a
single dispatcher routes every token (Remark 4.6: the balancer knows all
arrivals, so zero communication is needed).  The communication question
only arises with *multiple* dispatchers -- the [VKO20] setting the paper
targets -- where each router sees only its own arrivals and the exact
per-expert state lives with the experts.

This module simulates that setting with the paper's full queueing
structure mapped onto expert parallelism:

* ``E`` experts are the servers.  Each has a finite service capacity
  ``mu`` tokens/step and a FIFO backlog queue ``q_e`` -- tokens routed
  beyond ``mu`` wait (pipelined microbatches / deferred expert work).
  ``q_e(t+1) = max(q_e + a_e - mu, 0)`` is the slotted Lindley recursion;
  the ``max(.,0)`` idleness reflection is exactly why departures are hard
  to emulate (Section 6 of the paper).
* ``D`` dispatchers each route ``T`` tokens/step, top-k over gate scores
  drawn from a *dispatcher-specific, time-drifting* preference
  (heterogeneous, non-stationary traffic) plus a persistent global skew.
* Between messages each dispatcher runs the paper's emulation (Def 4.4):
  its own arrivals are known exactly (Eq. 10), the other ``D-1``
  dispatchers are emulated at the mean arrival rate (MSR applied to
  arrivals), and departures at the known service rate ``mu`` (MSR), with
  the same idleness reflection.  The emulation error is driven by the
  unobserved preference drift of the *other* dispatchers.
* Messages carry the exact queue state (paper Section 2.1.2); the
  trigger evaluation and message accounting come from the shared
  protocol core ``repro.core.care.comm`` (see ``comm_config()`` for how
  this tier's modes map onto it):
    - ``exact`` -- every dispatcher syncs every step (D messages/step,
      the 1-message-per-departure-batch baseline);
    - ``dt-x``  -- all dispatchers sync every x steps;
    - ``et-x``  -- the expert side mirrors every dispatcher's emulation
      (the paper's information asymmetry) and messages *only the
      dispatcher whose max queue error reached* ``x * mu`` tokens;
    - ``off``   -- pure local emulation, never corrected.
* Routing bias: JSAQ on the approximated queue -- the selection score is
  penalised by ``alpha * clip(rel(q_approx))`` plus an integral term that
  cancels the persistent skew (the PI controller of ``moe_balancer``).

Reported per regime: mean backlog (latency proxy, Little's law), the
queue-gap sup ``max_e q - min_e q`` (the paper's SSC metric), overflow
drops, and messages per step -- the communication-performance trade-off
restated for expert parallelism.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.care import comm as comm_lib


@dataclasses.dataclass(frozen=True)
class DispatchSimConfig:
    experts: int = 64
    dispatchers: int = 8
    tokens_per_step: int = 256  # per dispatcher
    top_k: int = 8
    steps: int = 400
    load: float = 0.92  # utilisation: arrivals / total service capacity
    comm: str = "et"  # "exact" | "dt" | "et" | "off"
    x: int = 2  # dt period / et error threshold (units of mu tokens)
    # Traffic model.
    base_skew: float = 1.0  # persistent global expert preference (std)
    drift: float = 0.10  # per-step random-walk std of dispatcher prefs
    noise: float = 1.0  # per-token logit noise std
    # Controller (mirrors CareConfig).
    bias_alpha: float = 0.6
    bias_clip: float = 2.0
    gamma: float = 0.02
    enabled: bool = True

    @property
    def mu(self) -> float:
        """Per-expert service capacity (tokens/step)."""
        arrivals = self.dispatchers * self.tokens_per_step * self.top_k
        return arrivals / (self.load * self.experts)

    def comm_config(self) -> comm_lib.CommConfig:
        """Map this tier's comm names onto the shared protocol core.

        ``exact`` (every dispatcher syncs every step) is RT with period 1;
        ``dt`` here is the paper's *time*-synchronised variant (all
        dispatchers every x steps), i.e. RT with period x; ``et`` is ET-x
        with the error measured in units of ``mu`` tokens; ``off`` never
        triggers.
        """
        if self.comm == "exact":
            return comm_lib.CommConfig(kind="rt", rt_period=1)
        if self.comm == "dt":
            return comm_lib.CommConfig(kind="rt", rt_period=self.x)
        if self.comm == "et":
            return comm_lib.CommConfig(kind="et", x=self.x)
        if self.comm == "off":
            return comm_lib.CommConfig(kind="none")
        raise ValueError(f"unknown comm mode: {self.comm}")


@dataclasses.dataclass
class DispatchSimResult:
    backlog: np.ndarray  # (steps,) mean per-expert queue
    gap: np.ndarray  # (steps,) max_e q - min_e q (SSC metric)
    messages: int
    msgs_per_step: float
    rel_comm: float  # msgs / (D * steps): fraction of the exact baseline
    tail_backlog: float  # mean over the 2nd half (steady state)
    tail_gap: float
    transient_gap: float  # mean over steps [50, steps/2): convergence cost
    max_err: float  # sup over (step, dispatcher) of |q - q_approx| / mu


def _rel(load):
    mean = jnp.mean(load, axis=-1, keepdims=True)
    return load / (mean + 1e-6) - 1.0


def _sim_core(key, cfg: DispatchSimConfig):
    d, e, t, k = cfg.dispatchers, cfg.experts, cfg.tokens_per_step, cfg.top_k
    mu = cfg.mu
    ccfg = cfg.comm_config()
    k_base, k_scan = jax.random.split(key)
    base = cfg.base_skew * jax.random.normal(k_base, (e,))

    def step(carry, skey):
        pref, q_true, q_app, bias, comm_state = carry
        k1, k2 = jax.random.split(skey)
        pref = pref + cfg.drift * jax.random.normal(k1, (d, e))
        logits = (
            base[None, None, :]
            + pref[:, None, :]
            + cfg.noise * jax.random.normal(k2, (d, t, e))
        )
        # JSAQ bias on the *approximated* queue (PI controller).
        if cfg.enabled:
            sel_bias = bias + cfg.bias_alpha * jnp.clip(
                _rel(q_app), -cfg.bias_clip, cfg.bias_clip
            )
        else:
            sel_bias = jnp.zeros((d, e))
        score = logits - sel_bias[:, None, :]
        _, idx = jax.lax.top_k(score, k)  # (D, T, k)
        counts = jnp.sum(
            jax.nn.one_hot(idx.reshape(d, -1), e, dtype=jnp.float32), axis=1
        )  # (D, E) arrivals per dispatcher

        # True expert queues: Lindley recursion with service capacity mu.
        g = jnp.sum(counts, axis=0)  # (E,) global arrivals this step
        q_true = jnp.maximum(q_true + g - mu, 0.0)

        # Dispatcher emulation: own arrivals exact, other dispatchers at the
        # mean rate (MSR on arrivals), service at mu (MSR on departures),
        # same idleness reflection.
        a_est = d * counts  # (D, E)
        q_app = jnp.maximum(q_app + a_est - mu, 0.0)

        bias = bias + cfg.gamma * jnp.clip(_rel(q_app), -1.0, 1.0)
        bias = bias - jnp.mean(bias, axis=-1, keepdims=True)

        err = jnp.max(jnp.abs(q_app - q_true[None, :]), axis=-1) / mu  # (D,)

        # Shared protocol core: one trigger implementation for all tiers.
        trigger, comm_state = comm_lib.evaluate(
            comm_state, ccfg, err, jnp.zeros((d,), jnp.int32)
        )
        q_app = jnp.where(trigger[:, None], q_true[None, :], q_app)

        backlog = jnp.mean(q_true)
        gap = jnp.max(q_true) - jnp.min(q_true)
        carry = (pref, q_true, q_app, bias, comm_state)
        return carry, (backlog, gap, jnp.max(err))

    init = (
        jnp.zeros((d, e)),
        jnp.zeros((e,)),
        jnp.zeros((d, e)),
        jnp.zeros((d, e)),
        comm_lib.CommState.init(d),
    )
    keys = jax.random.split(k_scan, cfg.steps)
    (_, _, _, _, comm_state), (backlog, gap, errs) = jax.lax.scan(
        step, init, keys
    )
    return backlog, gap, errs, comm_state.msgs


_sim = jax.jit(_sim_core, static_argnums=(1,))


@functools.partial(jax.jit, static_argnums=(1,))
def _sim_batch(keys, cfg: DispatchSimConfig):
    """All seeds in one program: vmap of the scan over a batch of keys."""
    return jax.vmap(lambda k: _sim_core(k, cfg))(keys)


def _finalize(backlog, gap, errs, msgs, cfg: DispatchSimConfig) -> DispatchSimResult:
    backlog, gap = np.asarray(backlog), np.asarray(gap)
    half = len(backlog) // 2
    return DispatchSimResult(
        backlog=backlog,
        gap=gap,
        messages=int(msgs),
        msgs_per_step=float(msgs) / cfg.steps,
        rel_comm=float(msgs) / (cfg.dispatchers * cfg.steps),
        tail_backlog=float(backlog[half:].mean()),
        tail_gap=float(gap[half:].mean()),
        transient_gap=float(gap[50:half].mean()) if half > 50 else float("nan"),
        max_err=float(np.asarray(errs).max()),
    )


def simulate(seed: int, cfg: DispatchSimConfig) -> DispatchSimResult:
    backlog, gap, errs, msgs = _sim(jax.random.key(seed), cfg)
    return _finalize(backlog, gap, errs, msgs, cfg)


def dispatch_batch(
    seeds, cfg: DispatchSimConfig
) -> list[DispatchSimResult]:
    """Run a seed sweep as one vmapped scan (one result per seed).

    The dispatch-tier analogue of ``slotted_sim.simulate_batch``:
    numerically identical to calling :func:`simulate` per seed (vmap is
    semantics-preserving), but every seed runs in a single compiled
    program -- ``bench_moe_balance``'s seed loop folds into one call.
    """
    keys = jnp.stack([jax.random.key(int(s)) for s in seeds])
    backlog, gap, errs, msgs = _sim_batch(keys, cfg)
    return [
        _finalize(backlog[i], gap[i], errs[i], msgs[i], cfg)
        for i in range(keys.shape[0])
    ]
