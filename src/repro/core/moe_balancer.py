"""CARE expert load balancer: the paper's technique inside MoE training.

Mapping (DESIGN.md Section 2.1): experts are the servers, tokens the jobs,
per-device routers the (multi-)dispatchers.  The balancer maintains an
*approximated* per-expert load and biases the gate's selection score by it
(JSAQ restricted to the gate's candidates).  Exact global counts are
synchronised only sparsely:

* ``dt`` -- every ``x`` steps (Departure-Triggered analogue; deterministic
  error bound between syncs given the drain model).
* ``et`` -- when the emulation error (computable exactly on the expert side,
  which observes true arrivals -- the paper's information asymmetry) reaches
  ``x`` times the mean per-expert load; a 1-bit flag all-reduce replaces the
  full count sync on quiet steps.

Between syncs the approximation evolves by the paper's queue-length
emulation (Definition 4.4): arrivals the dispatcher knows about (its own
routing decisions) minus an MSR drain -- experts "serve" their queue at a
nominal rate, modelled as a geometric drain factor per step.

The selection bias is a PI controller on the *approximated* relative load:

* proportional term  ``alpha * clip(load/mean - 1)`` -- reacts to the
  current (emulated) queue imbalance, exactly the JSAQ signal;
* integral term      ``bias += gamma * clip(load/mean - 1)`` -- accumulates
  until a *persistent* skew (a gate that systematically prefers some
  experts) is cancelled.  This is DeepSeek-V3's aux-loss-free bias update,
  except the driving signal is the CARE-approximated load maintained under
  sparse communication rather than per-step exact counts.

Both terms vanish when the approximated load is balanced, so the balancer
never injects noise into an already-balanced gate (an earlier
std-normalised variant amplified noise near balance and caused herding).

The state is carried in the train state, so the sync collective exists only
in the programs that actually sync -- the communication saving is visible
in the compiled HLO (benchmarks/bench_moe_balance.py and the roofline
artifacts measure it).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import CareConfig
from repro.core.care import comm as comm_lib

_EPS = 1e-6


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BalancerState:
    """Per-MoE-layer balancer state; leaves shaped (L, E) or (L, DP, TP, E)."""

    load_approx: jnp.ndarray  # dispatcher-side approximated load (float)
    true_load: jnp.ndarray  # expert-side exact load EMA (the message content)
    true_counts: jnp.ndarray  # expert-side exact counts since last sync
    bias: jnp.ndarray  # integral selection bias (same shape as load_approx)
    steps_since_sync: jnp.ndarray  # () int32

    @staticmethod
    def init(num_layers: int, num_experts: int) -> "BalancerState":
        z = jnp.zeros((num_layers, num_experts), jnp.float32)
        return BalancerState(
            load_approx=z,
            true_load=z,
            true_counts=z,
            bias=z,
            steps_since_sync=jnp.zeros((), jnp.int32),
        )


def _relative_overload(load: jnp.ndarray) -> jnp.ndarray:
    """(load / mean - 1) per layer; 0 everywhere when balanced."""
    mean = jnp.mean(load, axis=-1, keepdims=True)
    return load / (mean + _EPS) - 1.0


def selection_bias(state: BalancerState, cfg: CareConfig) -> jnp.ndarray:
    """JSAQ selection bias (L, E): positive for over-loaded experts.

    ``integral + alpha * clip(rel, +-clip)`` where ``rel`` is the relative
    overload of the *approximated* load.  The bias shifts only the selection
    score (combine weights stay unbiased), mirroring the kernel contract.
    """
    if not cfg.enabled:
        return jnp.zeros_like(state.load_approx)
    rel = _relative_overload(state.load_approx)
    prop = cfg.bias_alpha * jnp.clip(rel, -cfg.bias_clip, cfg.bias_clip)
    return state.bias + prop


def post_step_update(
    state: BalancerState, step_counts: jnp.ndarray, cfg: CareConfig
) -> BalancerState:
    """Advance the emulation by one training step (no communication).

    ``step_counts`` (L, E) are the dispatcher's own routed token counts --
    the arrival term of Eq. (10).  The MSR drain emulates expert service.
    The integral bias accumulates the approximated relative overload so a
    persistent gate skew is eventually cancelled exactly.
    """
    load = (state.load_approx + step_counts) * cfg.drain
    rel = _relative_overload(load)
    bias = state.bias + cfg.gamma * jnp.clip(rel, -1.0, 1.0)
    bias = bias - jnp.mean(bias, axis=-1, keepdims=True)  # keep zero-mean
    return BalancerState(
        load_approx=load,
        # Expert-side exact load EMA -- with a single dispatcher this equals
        # the emulation (the balancer knows every arrival: Remark 4.6); with
        # per-dispatcher rows it is the local view that ``sync`` reduces.
        true_load=(state.true_load + step_counts) * cfg.drain,
        true_counts=state.true_counts + step_counts,
        bias=bias,
        steps_since_sync=state.steps_since_sync + 1,
    )


def sync(state: BalancerState, cfg: CareConfig) -> BalancerState:
    """Exact synchronisation: snap the approximation to the true counts.

    With per-dispatcher state (L, DP, TP, E) the exact global count is the
    sum over the dispatcher axes; every dispatcher's approximation snaps to
    the same global value (in per-dispatcher units).  That cross-dispatcher
    reduction is the paper's "message": it is the only collective the
    balancer ever emits, and it exists only in the sync-step program.  The
    integral bias is derived state and needs no message of its own.
    """
    tl = state.true_load
    if tl.ndim == 4:
        # Per-dispatcher rows: the message is the global load state -- the
        # mean over dispatchers of the expert-side EMAs (the cross-device
        # reduction GSPMD lowers to an all-reduce in the sync program).
        glob = jnp.mean(tl, axis=(1, 2), keepdims=True)
        snapped = jnp.broadcast_to(glob, tl.shape)
    else:
        # Single dispatcher: the emulation already tracks the exact state
        # (Remark 4.6) -- the snap is numerically a no-op.
        snapped = tl
    return BalancerState(
        load_approx=snapped,
        true_load=tl,
        true_counts=jnp.zeros_like(state.true_counts),
        bias=state.bias,
        steps_since_sync=jnp.zeros((), jnp.int32),
    )


def needs_sync(state: BalancerState, cfg: CareConfig) -> jnp.ndarray:
    """ET/DT trigger predicate (scalar bool) for host-level scheduling.

    DT-x: every x steps.  ET-x: expert-side error (|true - approx| relative
    to the mean per-expert load) reaches x -- the server-side-adaptive
    pattern; the host reads this scalar (1 bit) instead of the full counts.
    """
    if cfg.comm == "dt":
        # Time-synchronised every x steps == RT with period x in
        # shared-core terms (cf. DispatchSimConfig.comm_config).
        return comm_lib.trigger(
            comm_lib.CommConfig(kind="rt", rt_period=cfg.x),
            slots_since=state.steps_since_sync,
        )
    mean_load = jnp.mean(state.true_load, axis=-1, keepdims=True) + _EPS
    err = jnp.abs(state.true_load - state.load_approx) / mean_load
    return comm_lib.trigger(
        comm_lib.CommConfig(kind="et", x=cfg.x), err=jnp.max(err)
    )


def balance_metrics(counts: jnp.ndarray) -> dict:
    """Load-balance quality of one step's dispatch counts (E,)."""
    c = counts.astype(jnp.float32)
    mean = jnp.mean(c) + 1e-9
    return {
        "max_over_mean": jnp.max(c) / mean,
        "min_over_mean": jnp.min(c) / mean,
        "cv": jnp.std(c) / mean,
    }
