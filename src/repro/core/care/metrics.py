"""Metrics for communication, approximation and performance (Section 2.1.5)."""
from __future__ import annotations

import numpy as np

from repro.core.care import slotted_sim


def ccdf(samples: np.ndarray, grid: np.ndarray | None = None):
    """Complement CDF of ``samples`` on ``grid`` (paper Figures 3, 8-12)."""
    samples = np.asarray(samples)
    if grid is None:
        hi = max(int(samples.max()) if samples.size else 1, 1)
        grid = np.unique(np.round(np.geomspace(1, hi, 128)).astype(np.int64))
    frac = np.array([(samples > g).mean() if samples.size else 0.0 for g in grid])
    return grid, frac


def jct_summary(jct: np.ndarray) -> dict:
    """Mean / tail percentiles of job completion times.

    Zero-completion safe: an empty sample (short-horizon quick runs, a
    streaming chunk whose warmup window swallowed every completion)
    yields all-zero statistics instead of NaN rows -- every percentile /
    mean reduction over JCTs must route through here or
    :func:`mean_jct`, never through raw ``np.mean``/``np.percentile``.
    The ``count`` field disambiguates a legitimately-zero mean from an
    empty window, so partial-window consumers never have to test
    ``mean == 0`` (which a real sample cannot produce: JCTs are >= 1).
    """
    jct = np.asarray(jct)
    if jct.size == 0:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0,
                "p99": 0.0, "p999": 0.0}
    return {
        "count": int(jct.size),
        "mean": float(jct.mean()),
        "p50": float(np.percentile(jct, 50)),
        "p90": float(np.percentile(jct, 90)),
        "p99": float(np.percentile(jct, 99)),
        "p999": float(np.percentile(jct, 99.9)),
    }


def mean_jct(jct: np.ndarray) -> float:
    """Mean JCT of a sample array; 0.0 (never NaN) when nothing completed."""
    jct = np.asarray(jct)
    return float(jct.mean()) if jct.size else 0.0


def relative_communication(
    result: slotted_sim.SimResult, policy: str, sqd: int = 2
) -> float:
    """Messages relative to the exact-state baseline (1 per departure).

    The paper measures communication "relative to the communication required
    for full state information", i.e. divides by the number of departures
    (which over long runs equals the number of arrivals for stable systems).
    """
    msgs = slotted_sim.exact_state_messages(result, policy, sqd)
    return msgs / max(result.departures, 1)


# ---------------------------------------------------------------------------
# Fixed-bucket log-spaced JCT histogram: the on-device tail-quantile
# accumulator of the streaming serving engine (serve/engine.py carries one
# in its chunk-step state).  Buckets must be computable with exact integer
# arithmetic on BOTH array namespaces (numpy host recomputation in tests,
# jax inside a jitted scan), so bucketing runs on floor(log2) via count-
# leading-zeros / float64 frexp -- never float32 log2, which can round a
# power-of-two boundary the wrong way.
# ---------------------------------------------------------------------------

# JCTs 1..3 get exact buckets; from 4 up, every octave [2^e, 2^(e+1)) is
# split into 4 linear sub-octaves (<= 25% relative width) through the full
# int32 range: 3 + 4 * 29 = 119 buckets.
HIST_BUCKETS = 119


def _floor_log2_i32(j, xp):
    """Exact floor(log2(j)) for positive int32 ``j`` on either namespace."""
    if xp is np:
        # float64 carries every int32 exactly, so frexp's exponent is exact.
        return (np.frexp(np.asarray(j, np.float64))[1] - 1).astype(np.int32)
    from jax import lax

    return (31 - lax.clz(j.astype(xp.int32))).astype(xp.int32)


def jct_bucket(j, xp=np):
    """Histogram bucket index of JCT ``j`` (int, clipped into [1, 2^31-1]).

    Pure integer arithmetic (shifts + masks after the exact floor-log2), so
    the jitted streaming engine and the numpy recomputation in tests place
    every sample in the same bucket bit for bit.
    """
    j = xp.clip(xp.asarray(j, xp.int32), 1, np.iinfo(np.int32).max)
    e = _floor_log2_i32(j, xp)
    sub = (j >> xp.maximum(e - 2, 0)) & 3
    return xp.where(e < 2, j - 1, 4 * e + sub - 5).astype(xp.int32)


def jct_bucket_edges() -> np.ndarray:
    """Lower edges of every histogram bucket plus the exclusive top, int64.

    ``edges[b] <= j < edges[b + 1]`` iff ``jct_bucket(j) == b``; shape
    ``(HIST_BUCKETS + 1,)`` with ``edges[-1] == 2^31``.
    """
    edges = np.empty(HIST_BUCKETS + 1, np.int64)
    edges[:3] = [1, 2, 3]
    b = np.arange(3, HIST_BUCKETS, dtype=np.int64)
    e, sub = (b + 5) // 4, (b + 5) % 4
    edges[3:HIST_BUCKETS] = (4 + sub) << (e - 2)
    edges[HIST_BUCKETS] = np.int64(2) ** 31
    return edges


def log_hist_quantiles(hist: np.ndarray, qs) -> np.ndarray:
    """Quantiles of a :func:`jct_bucket` histogram, one per ``q`` in ``qs``.

    Linear interpolation inside the containing bucket (exact for the
    single-value buckets 1/2/3, <= one sub-octave of error above).
    Zero-count safe like :func:`jct_summary`: an empty histogram -- a
    partial window with no completions -- yields defined zeros, never a
    divide by zero or NaN.
    """
    hist = np.asarray(hist, np.int64)
    qs = np.atleast_1d(np.asarray(qs, np.float64))
    total = int(hist.sum())
    if total == 0:
        return np.zeros(qs.shape)
    edges = jct_bucket_edges()
    cum = np.cumsum(hist)
    ranks = qs * (total - 1)
    out = np.empty(qs.shape)
    for i, rank in enumerate(ranks):
        b = int(np.searchsorted(cum, rank, side="right"))
        prev = cum[b - 1] if b > 0 else 0
        frac = (rank - prev + 0.5) / hist[b]
        out[i] = edges[b] + min(max(frac, 0.0), 1.0) * (edges[b + 1] - edges[b] - 1)
    return out


def stream_summary(count: int, mean: float, m2: float, max_jct: int,
                   hist: np.ndarray) -> dict:
    """Summary dict of the streaming engine's on-device JCT accumulators.

    ``count``/``mean``/``m2`` are the Welford accumulators, ``hist`` the
    log-bucket histogram (tail quantiles come from it -- robust regardless
    of the f32 moment precision), ``max_jct`` the exact maximum.  Partial
    windows are NaN-safe: ``count == 0`` yields all-zero statistics, same
    convention as :func:`jct_summary`.
    """
    count = int(count)
    hist = np.asarray(hist, np.int64)
    if count == 0 or int(hist.sum()) == 0:
        # Zero-count disambiguated path.  A warmup window can discard
        # every completion from the quantile histogram while the exact
        # ``max`` was tracked pre-discard (mode="drop" outliers likewise
        # count without histogram mass): clamping the empty histogram's
        # zero "quantiles" into [0, max] would fabricate a plausible
        # value that describes no sample.  Report count=0 -- the
        # unambiguous no-measured-quantiles marker -- with the tracked
        # max preserved for inspection.
        return {"count": 0, "mean": 0.0, "std": 0.0, "p50": 0.0,
                "p90": 0.0, "p99": 0.0, "p999": 0.0, "max": int(max_jct)}
    qs = log_hist_quantiles(hist, (0.5, 0.9, 0.99, 0.999))
    # The exact max is tracked alongside the histogram; interpolating
    # inside the top occupied bucket can overshoot it, so clamp (a
    # quantile above the sample maximum is a contradiction).
    p50, p90, p99, p999 = np.minimum(qs, float(max_jct))
    return {
        "count": count,
        "mean": float(mean),
        "std": float(np.sqrt(max(float(m2), 0.0) / count)),
        "p50": float(p50),
        "p90": float(p90),
        "p99": float(p99),
        "p999": float(p999),
        "max": int(max_jct),
    }


def token_summary(token_sum: int, token_misses: int, slots: int,
                  routed: int) -> dict:
    """Summary dict of the pull-policy token counters (JIQ / hsq runs).

    ``token_sum`` integrates end-of-slot token-pool occupancy over
    ``slots`` slots; ``token_misses`` counts routed jobs that found an
    empty pool (the uniform fallback), out of ``routed`` pull-routed jobs.
    Same zero-count contract as :func:`jct_summary` /
    :func:`stream_summary`: an empty window (``slots == 0``,
    ``routed == 0``, or both -- a warmup-swallowed chunk, a zero-arrival
    cell) yields finite all-zero statistics with a ``count`` field, never
    NaN or a divide by zero, so partial-window consumers can always
    aggregate rows blindly.
    """
    slots = int(slots)
    routed = int(routed)
    token_sum = int(token_sum)
    token_misses = int(token_misses)
    if routed == 0 and slots == 0:
        return {"count": 0, "mean_tokens": 0.0, "miss_rate": 0.0,
                "hit_rate": 0.0}
    miss_rate = token_misses / routed if routed else 0.0
    return {
        "count": routed,
        "mean_tokens": token_sum / slots if slots else 0.0,
        "miss_rate": miss_rate,
        "hit_rate": (1.0 - miss_rate) if routed else 0.0,
    }


def ccdf_dominates(a: np.ndarray, b: np.ndarray, tol: float = 0.02) -> bool:
    """True if JCT distribution ``a`` stochastically dominates ``b``
    (i.e. ``a`` is *better*: its CCDF is pointwise <= up to ``tol``)."""
    hi = int(max(a.max() if a.size else 1, b.max() if b.size else 1))
    grid = np.unique(np.round(np.geomspace(1, hi, 64)).astype(np.int64))
    _, ca = ccdf(a, grid)
    _, cb = ccdf(b, grid)
    return bool(np.all(ca <= cb + tol))
