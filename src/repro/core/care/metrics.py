"""Metrics for communication, approximation and performance (Section 2.1.5)."""
from __future__ import annotations

import numpy as np

from repro.core.care import slotted_sim


def ccdf(samples: np.ndarray, grid: np.ndarray | None = None):
    """Complement CDF of ``samples`` on ``grid`` (paper Figures 3, 8-12)."""
    samples = np.asarray(samples)
    if grid is None:
        hi = max(int(samples.max()) if samples.size else 1, 1)
        grid = np.unique(np.round(np.geomspace(1, hi, 128)).astype(np.int64))
    frac = np.array([(samples > g).mean() if samples.size else 0.0 for g in grid])
    return grid, frac


def jct_summary(jct: np.ndarray) -> dict:
    """Mean / tail percentiles of job completion times.

    Zero-completion safe: an empty sample (short-horizon quick runs)
    yields all-zero statistics instead of NaN rows -- every percentile /
    mean reduction over JCTs must route through here or
    :func:`mean_jct`, never through raw ``np.mean``/``np.percentile``.
    """
    jct = np.asarray(jct)
    if jct.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "p999": 0.0}
    return {
        "mean": float(jct.mean()),
        "p50": float(np.percentile(jct, 50)),
        "p90": float(np.percentile(jct, 90)),
        "p99": float(np.percentile(jct, 99)),
        "p999": float(np.percentile(jct, 99.9)),
    }


def mean_jct(jct: np.ndarray) -> float:
    """Mean JCT of a sample array; 0.0 (never NaN) when nothing completed."""
    jct = np.asarray(jct)
    return float(jct.mean()) if jct.size else 0.0


def relative_communication(
    result: slotted_sim.SimResult, policy: str, sqd: int = 2
) -> float:
    """Messages relative to the exact-state baseline (1 per departure).

    The paper measures communication "relative to the communication required
    for full state information", i.e. divides by the number of departures
    (which over long runs equals the number of arrivals for stable systems).
    """
    msgs = slotted_sim.exact_state_messages(result, policy, sqd)
    return msgs / max(result.departures, 1)


def ccdf_dominates(a: np.ndarray, b: np.ndarray, tol: float = 0.02) -> bool:
    """True if JCT distribution ``a`` stochastically dominates ``b``
    (i.e. ``a`` is *better*: its CCDF is pointwise <= up to ``tol``)."""
    hi = int(max(a.max() if a.size else 1, b.max() if b.size else 1))
    grid = np.unique(np.round(np.geomspace(1, hi, 64)).astype(np.int64))
    _, ca = ccdf(a, grid)
    _, cb = ccdf(b, grid)
    return bool(np.all(ca <= cb + tol))
