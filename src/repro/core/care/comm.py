"""Unified communication component of the CARE model (paper Section 2.1.2).

Single source of truth for *when a server reports its exact state to the
balancer*.  Every tier of the repo -- the slotted simulator
(``care/slotted_sim.py``), the multi-dispatcher MoE simulation
(``core/dispatch_sim.py``) and the serving engine (``serve/engine.py``) --
imports its trigger evaluation and message accounting from here, so the
paper's protocol exists exactly once and cannot drift between tiers.

Patterns (paper Section 2.1.2 / Section 6):

* ``rt``     -- Rate-Triggered RT-r: a message every ``rt_period`` slots
  (``r = 1/rt_period`` messages/slot).  No deterministic error bound
  (Section 6.2), purely time-driven.
* ``dt``     -- Departure-Triggered DT-x: a message after every ``x``
  departures.  With basic/MSR-x emulation this gives ``AQ <= x-1``
  (Theorem 2.3) at relative communication ``1/x``.
* ``et``     -- Error-Triggered ET-x: a message as soon as the (mirrored)
  approximation error reaches ``x``.  Bounds ``AQ <= x-1`` for *any*
  emulation algorithm (Prop 6.8); with MSR the relative communication
  decays as ``O(1/x^2)`` under heavy load (Theorem 2.5).
* ``et_rt``  -- hybrid ET-x with an RT fallback: triggers on error >= x
  *or* after ``rt_period`` silent slots, whichever comes first.  Keeps the
  deterministic ET bound while capping staleness in light-traffic /
  idle regimes where ET alone can stay silent arbitrarily long.
* ``exact``  -- full-state baseline: one message per departure
  (Prop 6.1), the denominator of "relative communication".
* ``none``   -- never trigger (exact-state policies whose communication is
  accounted analytically, or pure open-loop emulation).

The module is pure and vectorised over the server axis.  It is written
against the shared ``numpy``/``jax.numpy`` array API: pass ``xp=jnp``
(default) inside jitted ``lax.scan`` bodies (the slotted simulator and
the jax serving engine, whose trigger thresholds arrive as traced
``EngineScenario`` operands), or ``xp=np`` from host-side hot loops (the
numpy ``CareDispatcher`` reference) -- both produce identical trigger
decisions and message counts, which is what lets the serving tier's two
backends be bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal, Tuple

import jax
import jax.numpy as jnp

CommKind = Literal["none", "rt", "dt", "et", "et_rt", "exact"]


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Communication-pattern configuration: static kind, numeric thresholds.

    Attributes:
      kind: which trigger pattern runs (see module docstring).  Always a
        Python string -- it selects code paths via ``if`` at trace time, so
        it is compile-time by construction.
      x: DT-x departure count / ET-x error threshold.  Stored as a float so
        tiers measuring error in fractional units (e.g. tokens / mu) can use
        the same comparison; integer thresholds behave identically.
      rt_period: RT-r message period in slots; also the staleness cap of the
        ``et_rt`` hybrid.

    ``x`` and ``rt_period`` may be Python numbers *or traced scalars*: the
    trigger comparisons consume them as array operands, which is what lets
    the slotted simulator run a whole ``(load, x, rt_rate)`` grid as one
    compiled program (``slotted_sim.simulate_grid``).  A config holding
    tracers must not be hashed (i.e. never passed as a static jit
    argument); callers build it *inside* the traced function from the
    static kind plus scenario operands.
    """

    kind: CommKind = "et"
    x: float = 3
    rt_period: int = 100

    @staticmethod
    def from_rate(kind: CommKind, x: float = 3, rt_rate: float = 0.01) -> "CommConfig":
        """Build a config from a per-slot message *rate* (RT-r convention)."""
        period = max(int(round(1.0 / max(rt_rate, 1e-9))), 1)
        return CommConfig(kind=kind, x=x, rt_period=period)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommState:
    """Per-server trigger bookkeeping, shape ``(K,)`` (+ scalar totals).

    ``deps_since_msg`` / ``slots_since_msg`` count departures / slots since
    each server's last message; ``msgs`` is the running message total.
    Fields may be ``jax.numpy`` or ``numpy`` arrays -- the two backends are
    interchangeable (see module docstring).
    """

    deps_since_msg: Any  # (K,) int32
    slots_since_msg: Any  # (K,) int32
    msgs: Any  # () int32 total messages sent

    @staticmethod
    def init(k: int, xp=jnp) -> "CommState":
        return CommState(
            deps_since_msg=xp.zeros((k,), xp.int32),
            slots_since_msg=xp.zeros((k,), xp.int32),
            msgs=xp.zeros((), xp.int32),
        )


def trigger(
    cfg: CommConfig,
    *,
    err=None,
    deps_since=None,
    slots_since=None,
    new_deps=None,
    xp=jnp,
):
    """Pure trigger predicate on already-advanced counters.

    The single place the RT/DT/ET comparisons live.  :func:`evaluate` calls
    this after advancing its per-server counters; stateless callers (e.g.
    the training-tier balancer's host-level ``needs_sync``) call it directly
    with whatever scalar/vector counters they track.  Only the operands the
    ``cfg.kind`` needs may be ``None``-free.
    """
    if cfg.kind == "rt":
        return slots_since >= cfg.rt_period
    if cfg.kind == "dt":
        return deps_since >= cfg.x
    if cfg.kind == "et":
        return err >= cfg.x
    if cfg.kind == "et_rt":
        return (err >= cfg.x) | (slots_since >= cfg.rt_period)
    if cfg.kind == "exact":
        return new_deps > 0
    if cfg.kind == "none":
        return xp.zeros(xp.shape(deps_since), bool)
    raise ValueError(f"unknown communication kind: {cfg.kind}")


def evaluate(
    state: CommState,
    cfg: CommConfig,
    err,
    new_deps,
    xp=jnp,
) -> Tuple[Any, CommState]:
    """Advance the pattern by one slot and evaluate the trigger.

    Order matches the paper's slot semantics (and the seed simulator
    bit-for-bit): this slot's departures and the elapsed slot are counted
    *before* the trigger comparison, so a message fires in the same slot the
    condition is met and the end-of-slot error obeys ``AQ <= x-1`` for DT-x
    and ET-x (Theorem 2.3).

    Args:
      state: current :class:`CommState`.
      cfg: :class:`CommConfig` -- ``kind`` is Python-level (callers
        specialise on it); ``x`` / ``rt_period`` may be traced operands.
      err: ``(K,)`` current approximation error per server (any real dtype).
      new_deps: ``(K,)`` departures that completed this slot (int).
      xp: array namespace -- ``jax.numpy`` (default) or ``numpy``.

    Returns:
      ``(triggered, state')`` where ``triggered`` is a ``(K,)`` bool mask of
      servers that send a message this slot (the caller snaps its
      approximation to the truth for exactly these servers) and ``state'``
      has counters reset for triggered servers and ``msgs`` accumulated.
    """
    deps_since = state.deps_since_msg + new_deps
    slots_since = state.slots_since_msg + 1

    triggered = trigger(
        cfg,
        err=err,
        deps_since=deps_since,
        slots_since=slots_since,
        new_deps=new_deps,
        xp=xp,
    )

    if cfg.kind == "exact":
        # Full state information costs one message per departure (Prop 6.1),
        # even when several departures share a slot.
        sent = xp.sum(new_deps, dtype=xp.int32)
    else:
        sent = xp.sum(triggered, dtype=xp.int32)

    return triggered, CommState(
        deps_since_msg=xp.where(triggered, 0, deps_since),
        slots_since_msg=xp.where(triggered, 0, slots_since),
        msgs=state.msgs + sent,
    )
