"""Unified communication component of the CARE model (paper Section 2.1.2).

Single source of truth for *when a server reports its exact state to the
balancer*.  Every tier of the repo -- the slotted simulator
(``care/slotted_sim.py``), the multi-dispatcher MoE simulation
(``core/dispatch_sim.py``) and the serving engine (``serve/engine.py``) --
imports its trigger evaluation and message accounting from here, so the
paper's protocol exists exactly once and cannot drift between tiers.

Patterns (paper Section 2.1.2 / Section 6):

* ``rt``     -- Rate-Triggered RT-r: a message every ``rt_period`` slots
  (``r = 1/rt_period`` messages/slot).  No deterministic error bound
  (Section 6.2), purely time-driven.
* ``dt``     -- Departure-Triggered DT-x: a message after every ``x``
  departures.  With basic/MSR-x emulation this gives ``AQ <= x-1``
  (Theorem 2.3) at relative communication ``1/x``.
* ``et``     -- Error-Triggered ET-x: a message as soon as the (mirrored)
  approximation error reaches ``x``.  Bounds ``AQ <= x-1`` for *any*
  emulation algorithm (Prop 6.8); with MSR the relative communication
  decays as ``O(1/x^2)`` under heavy load (Theorem 2.5).
* ``et_rt``  -- hybrid ET-x with an RT fallback: triggers on error >= x
  *or* after ``rt_period`` silent slots, whichever comes first.  Keeps the
  deterministic ET bound while capping staleness in light-traffic /
  idle regimes where ET alone can stay silent arbitrarily long.
* ``exact``  -- full-state baseline: one message per departure
  (Prop 6.1), the denominator of "relative communication".
* ``none``   -- never trigger (exact-state policies whose communication is
  accounted analytically, or pure open-loop emulation).

Pull patterns (server-initiated tokens; van der Boor et al. 2019):

* ``jiq``    -- Join-the-Idle-Queue: a server sends exactly when it
  *becomes* idle (a departure leaves its queue empty), pushing an idle
  token to the balancer.  At most one message per job, by construction.
* ``hsq``    -- hyper-scalable JSQ: a server reports when its queue
  *drops below* the threshold ``x`` (a downward crossing), plus a
  periodic refresh every ``rt_period`` slots so the balancer's token
  pool is replenished at a traced rate even in steady traffic.

Both pull kinds carry the same payload as push kinds -- the sender's
exact queue length -- so the token traffic rides :func:`net_step`
unchanged and experiences the same delay/jitter/drop as push updates.
The balancer-side token pool lives with the policies (the routing layer),
not here: this module only decides *when a server speaks*.

The module is pure and vectorised over the server axis.  It is written
against the shared ``numpy``/``jax.numpy`` array API: pass ``xp=jnp``
(default) inside jitted ``lax.scan`` bodies (the slotted simulator and
the jax serving engine, whose trigger thresholds arrive as traced
``EngineScenario`` operands), or ``xp=np`` from host-side hot loops (the
numpy ``CareDispatcher`` reference) -- both produce identical trigger
decisions and message counts, which is what lets the serving tier's two
backends be bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal, Tuple

import jax
import jax.numpy as jnp

CommKind = Literal["none", "rt", "dt", "et", "et_rt", "exact", "jiq", "hsq"]

# Server-initiated (pull) comm kinds.  Each pairs 1:1 with the routing
# policy of the same name: the comm kind decides when a server pushes a
# token, the policy decides how the balancer spends its token pool.
PULL_KINDS = ("jiq", "hsq")

# Control-plane network model kinds: "none" keeps today's instant lossless
# delivery (bit-identical, zero overhead); "net" routes every message
# through the traced delay/jitter/drop model of :func:`net_step`.
NetworkKind = Literal["none", "net"]


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Communication-pattern configuration: static kind, numeric thresholds.

    Attributes:
      kind: which trigger pattern runs (see module docstring).  Always a
        Python string -- it selects code paths via ``if`` at trace time, so
        it is compile-time by construction.
      x: DT-x departure count / ET-x error threshold.  Stored as a float so
        tiers measuring error in fractional units (e.g. tokens / mu) can use
        the same comparison; integer thresholds behave identically.
      rt_period: RT-r message period in slots; also the staleness cap of the
        ``et_rt`` hybrid.

    ``x`` and ``rt_period`` may be Python numbers *or traced scalars*: the
    trigger comparisons consume them as array operands, which is what lets
    the slotted simulator run a whole ``(load, x, rt_rate)`` grid as one
    compiled program (``slotted_sim.simulate_grid``).  A config holding
    tracers must not be hashed (i.e. never passed as a static jit
    argument); callers build it *inside* the traced function from the
    static kind plus scenario operands.
    """

    kind: CommKind = "et"
    x: float = 3
    rt_period: int = 100

    @staticmethod
    def from_rate(kind: CommKind, x: float = 3, rt_rate: float = 0.01) -> "CommConfig":
        """Build a config from a per-slot message *rate* (RT-r convention)."""
        period = max(int(round(1.0 / max(rt_rate, 1e-9))), 1)
        return CommConfig(kind=kind, x=x, rt_period=period)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommState:
    """Per-server trigger bookkeeping, shape ``(K,)`` (+ scalar totals).

    ``deps_since_msg`` / ``slots_since_msg`` count departures / slots since
    each server's last message; ``msgs`` is the running message total.
    Fields may be ``jax.numpy`` or ``numpy`` arrays -- the two backends are
    interchangeable (see module docstring).
    """

    deps_since_msg: Any  # (K,) int32
    slots_since_msg: Any  # (K,) int32
    msgs: Any  # () int32 total messages sent

    @staticmethod
    def init(k: int, xp=jnp) -> "CommState":
        return CommState(
            deps_since_msg=xp.zeros((k,), xp.int32),
            slots_since_msg=xp.zeros((k,), xp.int32),
            msgs=xp.zeros((), xp.int32),
        )


def trigger(
    cfg: CommConfig,
    *,
    err=None,
    deps_since=None,
    slots_since=None,
    new_deps=None,
    q=None,
    xp=jnp,
):
    """Pure trigger predicate on already-advanced counters.

    The single place the RT/DT/ET (and pull-token) comparisons live.
    :func:`evaluate` calls this after advancing its per-server counters;
    stateless callers (e.g. the training-tier balancer's host-level
    ``needs_sync``) call it directly with whatever scalar/vector counters
    they track.  Only the operands the ``cfg.kind`` needs may be
    ``None``-free.  ``q`` is the end-of-slot queue length the pull kinds
    key on: ``jiq`` fires on the idle transition (this slot's departures
    emptied the queue), ``hsq`` on a downward crossing of the threshold
    ``x`` or after ``rt_period`` silent slots (the traced token-refresh
    period).
    """
    if cfg.kind == "rt":
        return slots_since >= cfg.rt_period
    if cfg.kind == "dt":
        return deps_since >= cfg.x
    if cfg.kind == "et":
        return err >= cfg.x
    if cfg.kind == "et_rt":
        return (err >= cfg.x) | (slots_since >= cfg.rt_period)
    if cfg.kind == "exact":
        return new_deps > 0
    if cfg.kind == "jiq":
        return (new_deps > 0) & (q == 0)
    if cfg.kind == "hsq":
        return ((q < cfg.x) & (q + new_deps >= cfg.x)) | (
            slots_since >= cfg.rt_period
        )
    if cfg.kind == "none":
        return xp.zeros(xp.shape(deps_since), bool)
    raise ValueError(f"unknown communication kind: {cfg.kind}")


def evaluate(
    state: CommState,
    cfg: CommConfig,
    err,
    new_deps,
    xp=jnp,
    *,
    can_send=None,
    force=None,
    q=None,
    count_msgs: bool = True,
) -> Tuple[Any, CommState]:
    """Advance the pattern by one slot and evaluate the trigger.

    Order matches the paper's slot semantics (and the seed simulator
    bit-for-bit): this slot's departures and the elapsed slot are counted
    *before* the trigger comparison, so a message fires in the same slot the
    condition is met and the end-of-slot error obeys ``AQ <= x-1`` for DT-x
    and ET-x (Theorem 2.3).

    Args:
      state: current :class:`CommState`.
      cfg: :class:`CommConfig` -- ``kind`` is Python-level (callers
        specialise on it); ``x`` / ``rt_period`` may be traced operands.
      err: ``(K,)`` current approximation error per server (any real dtype).
      new_deps: ``(K,)`` departures that completed this slot (int).
      xp: array namespace -- ``jax.numpy`` (default) or ``numpy``.
      can_send: optional ``(K,)`` bool -- servers able to send this slot.
        Crashed servers (fault process) pass ``False`` here: their trigger is
        suppressed but the underlying counters keep advancing, so the very
        first healthy slot re-fires any due trigger (resync retry path).
      force: optional ``(K,)`` bool -- servers that must send regardless of
        the trigger predicate (resync-on-recovery).  Applied before
        ``can_send``.
      q: optional ``(K,)`` end-of-slot queue length, required by the pull
        kinds (``jiq`` / ``hsq``) and ignored by everything else.
      count_msgs: when ``False`` the trigger *intent* is returned but
        ``msgs`` is left untouched -- the network model (:func:`net_step`)
        owns message accounting because piggyback batching makes
        sends-on-the-wire differ from trigger events.

    Returns:
      ``(triggered, state')`` where ``triggered`` is a ``(K,)`` bool mask of
      servers that send a message this slot (the caller snaps its
      approximation to the truth for exactly these servers) and ``state'``
      has counters reset for triggered servers and ``msgs`` accumulated.
    """
    deps_since = state.deps_since_msg + new_deps
    slots_since = state.slots_since_msg + 1

    triggered = trigger(
        cfg,
        err=err,
        deps_since=deps_since,
        slots_since=slots_since,
        new_deps=new_deps,
        q=q,
        xp=xp,
    )
    if force is not None:
        triggered = triggered | force
    if can_send is not None:
        triggered = triggered & can_send

    if not count_msgs:
        sent = xp.zeros((), xp.int32)
    elif cfg.kind == "exact":
        # Full state information costs one message per departure (Prop 6.1),
        # even when several departures share a slot.
        sent = xp.sum(new_deps, dtype=xp.int32)
    else:
        sent = xp.sum(triggered, dtype=xp.int32)

    return triggered, CommState(
        deps_since_msg=xp.where(triggered, 0, deps_since),
        slots_since_msg=xp.where(triggered, 0, slots_since),
        msgs=state.msgs + sent,
    )

@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Control-plane network model: static kind, traced numeric operands.

    Mirrors :class:`CommConfig`'s static-kind/traced-operand split.  With
    ``kind="none"`` no :class:`NetState` exists and delivery is today's
    instant lossless path, bit-identical.  With ``kind="net"`` every
    server->balancer message traverses :func:`net_step`:

    * ``delay`` -- deterministic delivery delay in slots (RTT/2; a message
      sent in slot t is applied at the balancer in slot ``t + delay``).
    * ``jitter`` -- additional uniform integer delay in ``[0, jitter]``,
      sampled i.i.d. per message.
    * ``drop`` -- i.i.d. probability a sent message is lost in flight.  A
      lost message still costs one message on the wire; no ack exists, so
      recovery relies on the trigger re-firing (ET re-arms as error keeps
      growing; RT/et_rt re-fires after ``rt_period`` slots).

    All three may be Python numbers or traced scalars, so a delay x drop
    ladder shares one compiled program.
    """

    kind: NetworkKind = "none"
    delay: Any = 0
    jitter: Any = 0
    drop: Any = 0.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetState:
    """Per-server in-flight message buffer, shape ``(K,)`` (+ scalar totals).

    Each server has one in-flight slot (messages are tiny and serialised per
    sender): ``timer`` counts down the slots until the in-flight message is
    applied at the balancer (``-1`` = nothing in flight), ``payload`` carries
    the state snapshot taken at send time, and ``pending`` marks a trigger
    that fired while a message was already in flight -- it is *piggybacked*:
    batched behind the in-flight message and sent (with a fresh snapshot)
    the slot the channel frees up, costing one message no matter how many
    triggers queued.  ``age`` counts slots since the balancer last received
    an update from each server -- the staleness clock the suspect-server
    timeout reads.  ``drops`` totals messages lost in flight.
    """

    timer: Any  # (K,) int32, -1 = idle
    payload: Any  # (K,) snapshot in flight (payload dtype is tier-specific)
    pending: Any  # (K,) bool, queued trigger to piggyback
    age: Any  # (K,) int32 slots since last delivered update
    drops: Any  # () int32 total messages lost

    @staticmethod
    def init(k: int, xp=jnp, payload_dtype=None) -> "NetState":
        dtype = payload_dtype if payload_dtype is not None else xp.int32
        return NetState(
            timer=xp.full((k,), -1, xp.int32),
            payload=xp.zeros((k,), dtype),
            pending=xp.zeros((k,), bool),
            age=xp.zeros((k,), xp.int32),
            drops=xp.zeros((), xp.int32),
        )


def net_step(
    state: NetState,
    cfg: NetworkConfig,
    triggered,
    payload_now,
    drop_u,
    jit_u,
    xp=jnp,
) -> Tuple[Any, Any, Any, NetState]:
    """Advance the network by one slot: send, fly, drop, deliver, piggyback.

    Written against the shared numpy/jax array namespace like
    :func:`evaluate`, so the jax scans and the numpy ``CareDispatcher``
    reference share one delivery semantics bit-for-bit.

    Per-slot order (all vectorised over the server axis):

    1. in-flight messages with ``timer == 0`` are *due* this slot;
    2. a server sends iff its channel is free (idle or due) and it either
       triggered now or has a ``pending`` piggybacked trigger -- the send
       snapshots ``payload_now`` (fresh state, not the stale queued one);
    3. each send costs one message; with probability ``drop`` it is lost
       (counted in ``drops``, never delivered, channel stays idle so the
       next trigger can retry);
    4. surviving sends draw ``delay + U{0..jitter}`` total delay: zero-delay
       sends deliver *this slot* (the ``none``-kind instant path, which is
       what makes a zero-operand ``net`` cell bit-identical to ``none``),
       positive-delay sends enter the in-flight buffer;
    5. due messages deliver; ``age`` resets for delivered servers and
       advances otherwise.

    Args:
      state: current :class:`NetState`.
      cfg: :class:`NetworkConfig` with ``kind == "net"``.
      triggered: ``(K,)`` bool trigger intents from :func:`evaluate`.
      payload_now: ``(K,)`` current true state to snapshot on send.
      drop_u: ``(K,)`` f32 i.i.d. uniforms for the drop draw.
      jit_u: ``(K,)`` f32 i.i.d. uniforms for the jitter draw.
      xp: array namespace -- ``jax.numpy`` (default) or ``numpy``.

    Returns:
      ``(delivered, out_payload, sent, state')``: ``delivered`` is the
      ``(K,)`` bool mask of servers whose update reaches the balancer this
      slot, ``out_payload`` the snapshot to apply for those servers, and
      ``sent`` the () int32 count of messages put on the wire this slot
      (the caller adds it to ``CommState.msgs``).
    """
    in_flight = state.timer >= 0
    due = in_flight & (state.timer == 0)
    free = ~in_flight | due

    send = (triggered | state.pending) & free
    # Triggers arriving while the channel is busy queue up for piggybacking;
    # a send clears the queue (the fresh snapshot covers everything queued).
    pending = (state.pending | triggered) & ~send

    lost = send & (drop_u < cfg.drop)
    # f32 jitter draw: u in [0,1) so floor(u * (jitter+1)) <= jitter.
    extra = (jit_u * xp.asarray(cfg.jitter + 1, xp.float32)).astype(xp.int32)
    total_delay = xp.asarray(cfg.delay, xp.int32) + extra

    enq = send & ~lost
    instant = enq & (total_delay == 0)
    flying = enq & (total_delay > 0)

    delivered = due | instant
    # Two distinct payloads on a handoff slot (a due delivery coinciding
    # with a new send): the *delivered* snapshot is the due message's
    # send-time payload (or the fresh one for an instant send, which
    # lands later within the slot and wins), while the *stored* snapshot
    # is the new send's -- the due payload must not be overwritten
    # before it is read.
    out_payload = xp.where(instant, payload_now, state.payload)
    stored = xp.where(flying | instant, payload_now, state.payload)

    timer = xp.where(
        flying,
        total_delay - 1,
        xp.where(in_flight & ~due, state.timer - 1, -1),
    ).astype(xp.int32)

    sent = xp.sum(send, dtype=xp.int32)
    return delivered, out_payload, sent, NetState(
        timer=timer,
        payload=stored,
        pending=pending,
        age=xp.where(delivered, 0, state.age + 1).astype(xp.int32),
        drops=state.drops + xp.sum(lost, dtype=xp.int32),
    )


def control_plane_init(
    k: int,
    *,
    network: str = "none",
    fault: str = "none",
    xp=jnp,
    payload_dtype=None,
):
    """Initial control-plane carries for one engine instance.

    The single constructor every tier's scan/stream carry goes through:
    returns ``(comm, net, faulted)`` where ``net`` / ``faulted`` are
    ``None`` (an empty pytree subtree) when the corresponding kind is off,
    so the default program structure is unchanged.  The streaming serving
    engine initialises its chunk carry here and a future live arrival feed
    resumes from the same triple via :func:`snapshot_state` /
    :func:`restore_state`.
    """
    comm = CommState.init(k, xp=xp)
    net = (
        NetState.init(k, xp=xp, payload_dtype=payload_dtype)
        if network != "none"
        else None
    )
    faulted = xp.zeros((k,), bool) if fault != "none" else None
    return comm, net, faulted


def snapshot_state(tree):
    """Host-side numpy copy of a control-plane (or whole-engine) carry.

    The persistence half of the resume seam: a carry pytree -- any nesting
    of :class:`CommState` / :class:`NetState` / plain arrays -- becomes
    concrete ``numpy`` arrays safe to hold across jit calls, pickle to
    disk, or hand to a host-side dispatcher between stream segments.
    """
    import numpy as np

    return jax.tree.map(lambda a: np.asarray(a), tree)


def restore_state(tree, xp=jnp):
    """Reconstitute a :func:`snapshot_state` carry on the target namespace.

    ``xp=jnp`` places the arrays back on device for the jitted scans;
    ``xp=np`` yields the numpy view the host-side ``CareDispatcher``
    mirrors consume.  Structure (including ``None`` subtrees for disabled
    kinds) is preserved, so the restored carry drops straight back into
    the compiled chunk step that produced it.
    """
    return jax.tree.map(lambda a: xp.asarray(a), tree)


def validate_control_plane(
    *,
    network: str = "none",
    net_delay: float = 0,
    net_jitter: float = 0,
    net_drop: float = 0.0,
    suspect_age: float = 0,
    fault: str = "none",
    crash_rate: float = 0.0,
    recover_rate: float = 0.0,
    slow_factor: float = 1.0,
    policy: str = None,
    comm: str = None,
    token_refresh: float = None,
) -> None:
    """Reject invalid network/fault/pull operands at config-validation time.

    Called from the host-side config entry points of both tiers
    (``SimConfig``/``Scenario.create`` and ``ServeConfig``/
    ``EngineConfig``) before anything is traced, mirroring the
    ``route_backend="pallas"`` corner-pinning style: every error names the
    offending field and the fix.

    ``policy`` / ``comm`` / ``token_refresh`` are the pull-family operands:
    when a tier passes its policy and comm kinds, the 1:1 pairing of the
    pull policies (``jiq`` / ``hsq``) with their token channels is enforced
    here, along with the sign of the hsq token-refresh operand (the traced
    rate in the slotted tier, the refresh period in the serving tier).
    Callers that do not model policies simply omit them.
    """
    if policy is not None and comm is not None:
        if policy in PULL_KINDS:
            if comm == "exact":
                raise ValueError(
                    f"policy={policy!r} cannot run under comm='exact' --"
                    " the exact full-state channel is push-per-departure"
                    " and would double-bill the token traffic; set"
                    f" comm={policy!r} (the matching pull token channel)"
                )
            if comm != policy:
                raise ValueError(
                    f"policy={policy!r} requires comm={policy!r} (its"
                    f" server-initiated token channel), got comm={comm!r}"
                )
        elif comm in PULL_KINDS:
            raise ValueError(
                f"comm={comm!r} is the token channel of policy={comm!r};"
                f" it cannot drive the push policy {policy!r}"
            )
    if token_refresh is not None and token_refresh < 0:
        raise ValueError(
            f"token_refresh must be >= 0 (the hsq token-refresh rate;"
            f" 0 disables the periodic refresh), got {token_refresh}"
        )
    if network not in ("none", "net"):
        raise ValueError(
            f"unknown network kind: {network!r} (expected 'none' or 'net')"
        )
    if fault not in ("none", "crash", "slow"):
        raise ValueError(
            f"unknown fault kind: {fault!r} "
            "(expected 'none', 'crash' or 'slow')"
        )
    if net_delay < 0:
        raise ValueError(f"net_delay must be >= 0 slots, got {net_delay}")
    if net_jitter < 0:
        raise ValueError(f"net_jitter must be >= 0 slots, got {net_jitter}")
    if net_drop < 0:
        raise ValueError(
            f"net_drop is a probability and must be >= 0, got {net_drop}"
        )
    if net_drop >= 1:
        raise ValueError(
            f"net_drop must be < 1, got {net_drop} -- a drop probability of"
            " 1 loses every message and no trigger retry can ever land"
        )
    if suspect_age < 0:
        raise ValueError(
            f"suspect_age must be >= 0 slots (0 disables suspect masking),"
            f" got {suspect_age}"
        )
    if network == "none":
        for field, val in (
            ("net_delay", net_delay),
            ("net_jitter", net_jitter),
            ("net_drop", net_drop),
        ):
            if val != 0:
                raise ValueError(
                    f"{field}={val} has no effect with network='none';"
                    " set network='net' to model the control plane"
                )
    if not 0.0 <= crash_rate <= 1.0:
        raise ValueError(
            f"crash_rate is a per-slot probability in [0, 1], got {crash_rate}"
        )
    if not 0.0 <= recover_rate <= 1.0:
        raise ValueError(
            f"recover_rate is a per-slot probability in [0, 1],"
            f" got {recover_rate}"
        )
    if crash_rate > 0 and recover_rate == 0:
        raise ValueError(
            "recover_rate must be > 0 when crash_rate > 0 -- with"
            f" recover_rate=0 every crashed server (crash_rate={crash_rate})"
            " stays down forever and the system drains to zero capacity"
        )
    if slow_factor <= 0 or slow_factor > 1:
        raise ValueError(
            f"slow_factor scales service_rates and must be in (0, 1],"
            f" got {slow_factor}"
        )
    if fault == "none":
        for field, val, neutral in (
            ("crash_rate", crash_rate, 0.0),
            ("recover_rate", recover_rate, 0.0),
            ("slow_factor", slow_factor, 1.0),
        ):
            if val != neutral:
                raise ValueError(
                    f"{field}={val} has no effect with fault='none';"
                    " set fault='crash' or fault='slow'"
                )
    if fault == "crash" and slow_factor != 1.0:
        raise ValueError(
            f"slow_factor={slow_factor} has no effect with fault='crash';"
            " use fault='slow' for transient slowdowns"
        )
    if suspect_age > 0 and network == "none" and fault == "none":
        raise ValueError(
            "suspect_age > 0 needs a modeled control plane -- with"
            " network='none' and fault='none' updates are instant and"
            " servers never fail, so the staleness timeout would only"
            " mis-mask idle servers; enable network='net' and/or a fault"
            " kind"
        )
