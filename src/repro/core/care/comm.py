"""Unified communication component of the CARE model (paper Section 2.1.2).

Single source of truth for *when a server reports its exact state to the
balancer*.  Every tier of the repo -- the slotted simulator
(``care/slotted_sim.py``), the multi-dispatcher MoE simulation
(``core/dispatch_sim.py``) and the serving engine (``serve/engine.py``) --
imports its trigger evaluation and message accounting from here, so the
paper's protocol exists exactly once and cannot drift between tiers.

Patterns (paper Section 2.1.2 / Section 6):

* ``rt``     -- Rate-Triggered RT-r: a message every ``rt_period`` slots
  (``r = 1/rt_period`` messages/slot).  No deterministic error bound
  (Section 6.2), purely time-driven.
* ``dt``     -- Departure-Triggered DT-x: a message after every ``x``
  departures.  With basic/MSR-x emulation this gives ``AQ <= x-1``
  (Theorem 2.3) at relative communication ``1/x``.
* ``et``     -- Error-Triggered ET-x: a message as soon as the (mirrored)
  approximation error reaches ``x``.  Bounds ``AQ <= x-1`` for *any*
  emulation algorithm (Prop 6.8); with MSR the relative communication
  decays as ``O(1/x^2)`` under heavy load (Theorem 2.5).
* ``et_rt``  -- hybrid ET-x with an RT fallback: triggers on error >= x
  *or* after ``rt_period`` silent slots, whichever comes first.  Keeps the
  deterministic ET bound while capping staleness in light-traffic /
  idle regimes where ET alone can stay silent arbitrarily long.
* ``exact``  -- full-state baseline: one message per departure
  (Prop 6.1), the denominator of "relative communication".
* ``none``   -- never trigger (exact-state policies whose communication is
  accounted analytically, or pure open-loop emulation).

Pull patterns (server-initiated tokens; van der Boor et al. 2019):

* ``jiq``    -- Join-the-Idle-Queue: a server sends exactly when it
  *becomes* idle (a departure leaves its queue empty), pushing an idle
  token to the balancer.  At most one message per job, by construction.
* ``hsq``    -- hyper-scalable JSQ: a server reports when its queue
  *drops below* the threshold ``x`` (a downward crossing), plus a
  periodic refresh every ``rt_period`` slots so the balancer's token
  pool is replenished at a traced rate even in steady traffic.

Both pull kinds carry the same payload as push kinds -- the sender's
exact queue length -- so the token traffic rides :func:`net_step`
unchanged and experiences the same delay/jitter/drop as push updates.
The balancer-side token pool lives with the policies (the routing layer),
not here: this module only decides *when a server speaks*.

The module is pure and vectorised over the server axis.  It is written
against the shared ``numpy``/``jax.numpy`` array API: pass ``xp=jnp``
(default) inside jitted ``lax.scan`` bodies (the slotted simulator and
the jax serving engine, whose trigger thresholds arrive as traced
``EngineScenario`` operands), or ``xp=np`` from host-side hot loops (the
numpy ``CareDispatcher`` reference) -- both produce identical trigger
decisions and message counts, which is what lets the serving tier's two
backends be bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal, Tuple

import jax
import jax.numpy as jnp

CommKind = Literal["none", "rt", "dt", "et", "et_rt", "exact", "jiq", "hsq"]

# Server-initiated (pull) comm kinds.  Each pairs 1:1 with the routing
# policy of the same name: the comm kind decides when a server pushes a
# token, the policy decides how the balancer spends its token pool.
PULL_KINDS = ("jiq", "hsq")

# Control-plane network model kinds: "none" keeps today's instant lossless
# delivery (bit-identical, zero overhead); "net" routes every message
# through the traced delay/jitter/drop model of :func:`net_step`.
NetworkKind = Literal["none", "net"]

# Control-plane transport kinds under ``network="net"``: "fire_forget" is
# the historical one-shot wire (a dropped message is gone; recovery relies
# on the trigger re-firing), "ack" is the reliable transport of
# :func:`net_step_ack` (per-send timeout window, exponential backoff,
# fresh-snapshot retransmit, abandonment after ``max_retries``).  A static
# kind: it selects the step function and the carry dataclass at trace
# time, so "fire_forget" programs carry no ack state at all.
TransportKind = Literal["fire_forget", "ack"]


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Communication-pattern configuration: static kind, numeric thresholds.

    Attributes:
      kind: which trigger pattern runs (see module docstring).  Always a
        Python string -- it selects code paths via ``if`` at trace time, so
        it is compile-time by construction.
      x: DT-x departure count / ET-x error threshold.  Stored as a float so
        tiers measuring error in fractional units (e.g. tokens / mu) can use
        the same comparison; integer thresholds behave identically.
      rt_period: RT-r message period in slots; also the staleness cap of the
        ``et_rt`` hybrid.

    ``x`` and ``rt_period`` may be Python numbers *or traced scalars*: the
    trigger comparisons consume them as array operands, which is what lets
    the slotted simulator run a whole ``(load, x, rt_rate)`` grid as one
    compiled program (``slotted_sim.simulate_grid``).  A config holding
    tracers must not be hashed (i.e. never passed as a static jit
    argument); callers build it *inside* the traced function from the
    static kind plus scenario operands.
    """

    kind: CommKind = "et"
    x: float = 3
    rt_period: int = 100

    @staticmethod
    def from_rate(kind: CommKind, x: float = 3, rt_rate: float = 0.01) -> "CommConfig":
        """Build a config from a per-slot message *rate* (RT-r convention)."""
        period = max(int(round(1.0 / max(rt_rate, 1e-9))), 1)
        return CommConfig(kind=kind, x=x, rt_period=period)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommState:
    """Per-server trigger bookkeeping, shape ``(K,)`` (+ scalar totals).

    ``deps_since_msg`` / ``slots_since_msg`` count departures / slots since
    each server's last message; ``msgs`` is the running message total.
    Fields may be ``jax.numpy`` or ``numpy`` arrays -- the two backends are
    interchangeable (see module docstring).
    """

    deps_since_msg: Any  # (K,) int32
    slots_since_msg: Any  # (K,) int32
    msgs: Any  # () int32 total messages sent

    @staticmethod
    def init(k: int, xp=jnp) -> "CommState":
        return CommState(
            deps_since_msg=xp.zeros((k,), xp.int32),
            slots_since_msg=xp.zeros((k,), xp.int32),
            msgs=xp.zeros((), xp.int32),
        )


def trigger(
    cfg: CommConfig,
    *,
    err=None,
    deps_since=None,
    slots_since=None,
    new_deps=None,
    q=None,
    xp=jnp,
):
    """Pure trigger predicate on already-advanced counters.

    The single place the RT/DT/ET (and pull-token) comparisons live.
    :func:`evaluate` calls this after advancing its per-server counters;
    stateless callers (e.g. the training-tier balancer's host-level
    ``needs_sync``) call it directly with whatever scalar/vector counters
    they track.  Only the operands the ``cfg.kind`` needs may be
    ``None``-free.  ``q`` is the end-of-slot queue length the pull kinds
    key on: ``jiq`` fires on the idle transition (this slot's departures
    emptied the queue), ``hsq`` on a downward crossing of the threshold
    ``x`` or after ``rt_period`` silent slots (the traced token-refresh
    period).
    """
    if cfg.kind == "rt":
        return slots_since >= cfg.rt_period
    if cfg.kind == "dt":
        return deps_since >= cfg.x
    if cfg.kind == "et":
        return err >= cfg.x
    if cfg.kind == "et_rt":
        return (err >= cfg.x) | (slots_since >= cfg.rt_period)
    if cfg.kind == "exact":
        return new_deps > 0
    if cfg.kind == "jiq":
        return (new_deps > 0) & (q == 0)
    if cfg.kind == "hsq":
        return ((q < cfg.x) & (q + new_deps >= cfg.x)) | (
            slots_since >= cfg.rt_period
        )
    if cfg.kind == "none":
        return xp.zeros(xp.shape(deps_since), bool)
    raise ValueError(f"unknown communication kind: {cfg.kind}")


def evaluate(
    state: CommState,
    cfg: CommConfig,
    err,
    new_deps,
    xp=jnp,
    *,
    can_send=None,
    force=None,
    q=None,
    count_msgs: bool = True,
) -> Tuple[Any, CommState]:
    """Advance the pattern by one slot and evaluate the trigger.

    Order matches the paper's slot semantics (and the seed simulator
    bit-for-bit): this slot's departures and the elapsed slot are counted
    *before* the trigger comparison, so a message fires in the same slot the
    condition is met and the end-of-slot error obeys ``AQ <= x-1`` for DT-x
    and ET-x (Theorem 2.3).

    Args:
      state: current :class:`CommState`.
      cfg: :class:`CommConfig` -- ``kind`` is Python-level (callers
        specialise on it); ``x`` / ``rt_period`` may be traced operands.
      err: ``(K,)`` current approximation error per server (any real dtype).
      new_deps: ``(K,)`` departures that completed this slot (int).
      xp: array namespace -- ``jax.numpy`` (default) or ``numpy``.
      can_send: optional ``(K,)`` bool -- servers able to send this slot.
        Crashed servers (fault process) pass ``False`` here: their trigger is
        suppressed but the underlying counters keep advancing, so the very
        first healthy slot re-fires any due trigger (resync retry path).
      force: optional ``(K,)`` bool -- servers that must send regardless of
        the trigger predicate (resync-on-recovery).  Applied before
        ``can_send``.
      q: optional ``(K,)`` end-of-slot queue length, required by the pull
        kinds (``jiq`` / ``hsq``) and ignored by everything else.
      count_msgs: when ``False`` the trigger *intent* is returned but
        ``msgs`` is left untouched -- the network model (:func:`net_step`)
        owns message accounting because piggyback batching makes
        sends-on-the-wire differ from trigger events.

    Returns:
      ``(triggered, state')`` where ``triggered`` is a ``(K,)`` bool mask of
      servers that send a message this slot (the caller snaps its
      approximation to the truth for exactly these servers) and ``state'``
      has counters reset for triggered servers and ``msgs`` accumulated.
    """
    deps_since = state.deps_since_msg + new_deps
    slots_since = state.slots_since_msg + 1

    triggered = trigger(
        cfg,
        err=err,
        deps_since=deps_since,
        slots_since=slots_since,
        new_deps=new_deps,
        q=q,
        xp=xp,
    )
    if force is not None:
        triggered = triggered | force
    if can_send is not None:
        triggered = triggered & can_send

    if not count_msgs:
        sent = xp.zeros((), xp.int32)
    elif cfg.kind == "exact":
        # Full state information costs one message per departure (Prop 6.1),
        # even when several departures share a slot.
        sent = xp.sum(new_deps, dtype=xp.int32)
    else:
        sent = xp.sum(triggered, dtype=xp.int32)

    return triggered, CommState(
        deps_since_msg=xp.where(triggered, 0, deps_since),
        slots_since_msg=xp.where(triggered, 0, slots_since),
        msgs=state.msgs + sent,
    )

@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Control-plane network model: static kind, traced numeric operands.

    Mirrors :class:`CommConfig`'s static-kind/traced-operand split.  With
    ``kind="none"`` no :class:`NetState` exists and delivery is today's
    instant lossless path, bit-identical.  With ``kind="net"`` every
    server->balancer message traverses :func:`net_step`:

    * ``delay`` -- deterministic delivery delay in slots (RTT/2; a message
      sent in slot t is applied at the balancer in slot ``t + delay``).
    * ``jitter`` -- additional uniform integer delay in ``[0, jitter]``,
      sampled i.i.d. per message.
    * ``drop`` -- i.i.d. probability a sent message is lost in flight.  A
      lost message still costs one message on the wire; under
      ``transport="fire_forget"`` no ack exists, so recovery relies on the
      trigger re-firing (ET re-arms as error keeps growing; RT/et_rt
      re-fires after ``rt_period`` slots).

    ``transport`` selects the wire semantics (a *static* kind, like
    ``kind``): ``"fire_forget"`` is the historical one-shot path above;
    ``"ack"`` runs :func:`net_step_ack`, where every data send opens a
    timeout window of ``ack_timeout`` slots (growing by ``backoff_base``
    per retry), an unacked message retransmits a *fresh* snapshot at
    expiry, and after ``max_retries`` retransmits the update is abandoned
    and the server marks itself suspect (``AckNetState.gave_up``).  Acks
    and the optional server keepalives (every ``ka_period`` slots) ride
    the same delay/jitter/drop wire and are billed as real messages.

    All numeric operands may be Python numbers or traced scalars, so a
    delay x drop x timeout ladder shares one compiled program.
    """

    kind: NetworkKind = "none"
    delay: Any = 0
    jitter: Any = 0
    drop: Any = 0.0
    transport: TransportKind = "fire_forget"
    # Reliable-transport operands (traced; neutral under "fire_forget").
    ack_timeout: Any = 0  # slots a sender waits for an ack (>= 1 under "ack")
    backoff_base: Any = 1.0  # timeout multiplier per retransmit (>= 1)
    max_retries: Any = 0  # retransmits before the update is abandoned
    ka_period: Any = 0  # server keepalive period in slots (0 = none)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetState:
    """Per-server in-flight message buffer, shape ``(K,)`` (+ scalar totals).

    Each server has one in-flight slot (messages are tiny and serialised per
    sender): ``timer`` counts down the slots until the in-flight message is
    applied at the balancer (``-1`` = nothing in flight), ``payload`` carries
    the state snapshot taken at send time, and ``pending`` marks a trigger
    that fired while a message was already in flight -- it is *piggybacked*:
    batched behind the in-flight message and sent (with a fresh snapshot)
    the slot the channel frees up, costing one message no matter how many
    triggers queued.  ``age`` counts slots since the balancer last received
    an update from each server -- the staleness clock the suspect-server
    timeout reads.  ``drops`` totals messages lost in flight.
    """

    timer: Any  # (K,) int32, -1 = idle
    payload: Any  # (K,) snapshot in flight (payload dtype is tier-specific)
    pending: Any  # (K,) bool, queued trigger to piggyback
    age: Any  # (K,) int32 slots since last delivered update
    drops: Any  # () int32 total messages lost

    @staticmethod
    def init(k: int, xp=jnp, payload_dtype=None) -> "NetState":
        dtype = payload_dtype if payload_dtype is not None else xp.int32
        return NetState(
            timer=xp.full((k,), -1, xp.int32),
            payload=xp.zeros((k,), dtype),
            pending=xp.zeros((k,), bool),
            age=xp.zeros((k,), xp.int32),
            drops=xp.zeros((), xp.int32),
        )


def net_step(
    state: NetState,
    cfg: NetworkConfig,
    triggered,
    payload_now,
    drop_u,
    jit_u,
    xp=jnp,
    can_send=None,
) -> Tuple[Any, Any, Any, NetState]:
    """Advance the network by one slot: send, fly, drop, deliver, piggyback.

    Written against the shared numpy/jax array namespace like
    :func:`evaluate`, so the jax scans and the numpy ``CareDispatcher``
    reference share one delivery semantics bit-for-bit.

    Per-slot order (all vectorised over the server axis):

    1. in-flight messages with ``timer == 0`` are *due* this slot;
    2. a server sends iff its channel is free (idle or due) and it either
       triggered now or has a ``pending`` piggybacked trigger -- the send
       snapshots ``payload_now`` (fresh state, not the stale queued one);
    3. each send costs one message; with probability ``drop`` it is lost
       (counted in ``drops``, never delivered, channel stays idle so the
       next trigger can retry);
    4. surviving sends draw ``delay + U{0..jitter}`` total delay: zero-delay
       sends deliver *this slot* (the ``none``-kind instant path, which is
       what makes a zero-operand ``net`` cell bit-identical to ``none``),
       positive-delay sends enter the in-flight buffer;
    5. due messages deliver; ``age`` resets for delivered servers and
       advances otherwise.

    Args:
      state: current :class:`NetState`.
      cfg: :class:`NetworkConfig` with ``kind == "net"``.
      triggered: ``(K,)`` bool trigger intents from :func:`evaluate`.
      payload_now: ``(K,)`` current true state to snapshot on send.
      drop_u: ``(K,)`` f32 i.i.d. uniforms for the drop draw.
      jit_u: ``(K,)`` f32 i.i.d. uniforms for the jitter draw.
      xp: array namespace -- ``jax.numpy`` (default) or ``numpy``.
      can_send: optional ``(K,)`` bool -- servers able to put a message on
        the wire this slot (crash-fault callers pass ``~faulted``).  A
        ``False`` server neither sends nor *keeps* a queued piggyback: its
        pre-crash ``pending`` snapshot intent is wiped, because the state
        it described died with the crash -- the forced recovery resync
        (a fresh snapshot) is the only correct re-entry message.

    Returns:
      ``(delivered, out_payload, sent, state')``: ``delivered`` is the
      ``(K,)`` bool mask of servers whose update reaches the balancer this
      slot, ``out_payload`` the snapshot to apply for those servers, and
      ``sent`` the () int32 count of messages put on the wire this slot
      (the caller adds it to ``CommState.msgs``).
    """
    in_flight = state.timer >= 0
    due = in_flight & (state.timer == 0)
    free = ~in_flight | due

    send = (triggered | state.pending) & free
    if can_send is not None:
        send = send & can_send
    # Triggers arriving while the channel is busy queue up for piggybacking;
    # a send clears the queue (the fresh snapshot covers everything queued).
    pending = (state.pending | triggered) & ~send
    if can_send is not None:
        # A crashed server's queued piggyback describes pre-crash state;
        # it must not fire at the next free slot ahead of the recovery
        # resync, so the crash wipes it.
        pending = pending & can_send

    lost = send & (drop_u < cfg.drop)
    # f32 jitter draw: u in [0,1) so floor(u * (jitter+1)) <= jitter.
    extra = (jit_u * xp.asarray(cfg.jitter + 1, xp.float32)).astype(xp.int32)
    total_delay = xp.asarray(cfg.delay, xp.int32) + extra

    enq = send & ~lost
    instant = enq & (total_delay == 0)
    flying = enq & (total_delay > 0)

    delivered = due | instant
    # Two distinct payloads on a handoff slot (a due delivery coinciding
    # with a new send): the *delivered* snapshot is the due message's
    # send-time payload (or the fresh one for an instant send, which
    # lands later within the slot and wins), while the *stored* snapshot
    # is the new send's -- the due payload must not be overwritten
    # before it is read.
    out_payload = xp.where(instant, payload_now, state.payload)
    stored = xp.where(flying | instant, payload_now, state.payload)

    timer = xp.where(
        flying,
        total_delay - 1,
        xp.where(in_flight & ~due, state.timer - 1, -1),
    ).astype(xp.int32)

    sent = xp.sum(send, dtype=xp.int32)
    return delivered, out_payload, sent, NetState(
        timer=timer,
        payload=stored,
        pending=pending,
        age=xp.where(delivered, 0, state.age + 1).astype(xp.int32),
        drops=state.drops + xp.sum(lost, dtype=xp.int32),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AckNetState:
    """Reliable-transport wire state, shape ``(K,)`` (+ scalar totals).

    The ``transport="ack"`` counterpart of :class:`NetState` -- a separate
    dataclass so fire-and-forget programs carry none of this structure.
    Three single-slot channels exist per server (supersede semantics: a
    newer message on a channel replaces an older one still in flight --
    under the retransmit protocol the newer snapshot strictly dominates):

    * data (server -> balancer): ``timer`` / ``payload`` / ``pending``
      exactly as in :class:`NetState`;
    * ack (balancer -> server): ``ack_timer``, one ack per data delivery;
    * keepalive (server -> balancer): ``ka_timer``, fired every
      ``ka_period`` slots (``ka_since`` is the sender-side clock).

    ``awaiting`` counts down the open timeout window of the latest data
    transmission (``-1`` = nothing awaited), ``backoff`` is the current
    window length on the exponential ladder (f32 so the traced base
    multiplies exactly the same way under numpy and jax -- no ``pow``,
    whose libm/XLA implementations could disagree bit-wise), ``retries``
    the retransmits spent on the awaited update, and ``gave_up`` marks a
    server that abandoned after ``max_retries`` -- a *self-suspect* that
    stays masked until some later transmission is acked.  ``ka_age`` is
    the balancer's last-heard clock (reset by any data *or* keepalive
    delivery) that keepalive-driven suspect masking reads; ``age`` remains
    the data-staleness clock.  ``drops`` totals losses across all three
    channels; ``retrans`` totals retransmitted data messages.
    """

    timer: Any  # (K,) int32 data in flight, -1 = idle
    payload: Any  # (K,) snapshot in flight (payload dtype is tier-specific)
    pending: Any  # (K,) bool, queued trigger to piggyback
    awaiting: Any  # (K,) int32 slots left in the timeout window, -1 = none
    backoff: Any  # (K,) f32 current timeout-window length (slots)
    retries: Any  # (K,) int32 retransmits spent on the awaited update
    ack_timer: Any  # (K,) int32 ack in flight, -1 = idle
    gave_up: Any  # (K,) bool abandoned after max_retries (self-suspect)
    ka_timer: Any  # (K,) int32 keepalive in flight, -1 = idle
    ka_since: Any  # (K,) int32 slots since last keepalive send
    ka_age: Any  # (K,) int32 balancer slots since last heard (data or ka)
    age: Any  # (K,) int32 slots since last delivered data update
    drops: Any  # () int32 total messages lost (data + ack + keepalive)
    retrans: Any  # () int32 total data retransmits

    @staticmethod
    def init(k: int, xp=jnp, payload_dtype=None) -> "AckNetState":
        dtype = payload_dtype if payload_dtype is not None else xp.int32
        return AckNetState(
            timer=xp.full((k,), -1, xp.int32),
            payload=xp.zeros((k,), dtype),
            pending=xp.zeros((k,), bool),
            awaiting=xp.full((k,), -1, xp.int32),
            backoff=xp.zeros((k,), xp.float32),
            retries=xp.zeros((k,), xp.int32),
            ack_timer=xp.full((k,), -1, xp.int32),
            gave_up=xp.zeros((k,), bool),
            ka_timer=xp.full((k,), -1, xp.int32),
            ka_since=xp.zeros((k,), xp.int32),
            ka_age=xp.zeros((k,), xp.int32),
            age=xp.zeros((k,), xp.int32),
            drops=xp.zeros((), xp.int32),
            retrans=xp.zeros((), xp.int32),
        )


def net_step_ack(
    state: AckNetState,
    cfg: NetworkConfig,
    triggered,
    payload_now,
    drop_u,
    jit_u,
    ack_u,
    xp=jnp,
    can_send=None,
) -> Tuple[Any, Any, Any, AckNetState]:
    """Advance the reliable (ack'd) transport by one slot.

    The ``transport="ack"`` counterpart of :func:`net_step`, written
    against the same shared numpy/jax namespace so both engine backends
    share one delivery semantics bit-for-bit.  Per-slot order:

    1. due traffic arrives: data at the balancer, acks and keepalives at
       their receivers;
    2. an arriving ack closes the sender's timeout window; a window that
       expires *un*-acked either retransmits -- a **fresh**
       ``payload_now`` snapshot, never the stale in-flight payload; by
       then the state it described is history -- or, once ``retries``
       reaches ``max_retries``, abandons the update and marks the server
       ``gave_up`` (self-suspect, cleared by the next successful ack);
    3. a free server (no open window, or one just closed) sends on a
       trigger or queued piggyback; every send (re- or new) opens a
       timeout window of ``backoff`` slots -- ``ack_timeout`` on a new
       send, multiplied by ``backoff_base`` (clamped at ``2^30``) per
       retransmit;
    4. data rides the wire exactly as in :func:`net_step` (drop, then
       ``delay + U{0..jitter}``; zero total delay delivers this slot);
    5. the balancer acks every delivery; acks ride the *same* wire with
       their own drop/jitter draws and are billed as messages -- the
       protocol's overhead must show on the message axis it is meant to
       protect;
    6. every ``ka_period`` slots a server fires a keepalive (same wire,
       also billed); any data or keepalive delivery resets the balancer's
       ``ka_age`` clock for that server.

    Args:
      state: current :class:`AckNetState`.
      cfg: :class:`NetworkConfig` with ``kind="net"``,
        ``transport="ack"``.
      triggered: ``(K,)`` bool trigger intents from :func:`evaluate`.
      payload_now: ``(K,)`` current true state to snapshot on send.
      drop_u / jit_u: ``(K,)`` f32 uniforms for the data-channel draws.
      ack_u: ``(4, K)`` f32 uniforms for the ack and keepalive channels,
        rows ``(ack drop, ack jitter, ka drop, ka jitter)``.
      xp: array namespace -- ``jax.numpy`` (default) or ``numpy``.
      can_send: optional ``(K,)`` bool; as in :func:`net_step`, a
        ``False`` server sends nothing (no new send, no retransmit, no
        keepalive), its queued ``pending`` is wiped, and an expired
        timeout window holds at zero until the server can act again.

    Returns:
      ``(delivered, out_payload, sent, state')`` exactly as
      :func:`net_step`; ``sent`` bills data sends, acks and keepalives.
    """
    # 1. due arrivals on the three channels.
    in_flight = state.timer >= 0
    due = in_flight & (state.timer == 0)
    ack_arr = (state.ack_timer >= 0) & (state.ack_timer == 0)
    ka_due = (state.ka_timer >= 0) & (state.ka_timer == 0)

    # 2. timeout bookkeeping: expiry -> retransmit or abandon.
    awaiting = state.awaiting >= 0
    expired = awaiting & ~ack_arr & (state.awaiting == 0)
    if can_send is not None:
        expired = expired & can_send
    abandon = expired & (state.retries >= xp.asarray(cfg.max_retries, xp.int32))
    retrans_now = expired & ~abandon

    # 3. sends: new triggers need a free window; retransmits reuse theirs.
    free = ~awaiting | ack_arr | abandon
    trig_all = triggered | state.pending
    if can_send is not None:
        trig_all = trig_all & can_send
    send_new = trig_all & free
    send = send_new | retrans_now
    pending = (state.pending | triggered) & ~send
    if can_send is not None:
        pending = pending & can_send

    # 4. data wire (identical draws and instant-delivery rule to net_step;
    # a send while an older message is still flying supersedes it).
    lost = send & (drop_u < cfg.drop)
    extra = (jit_u * xp.asarray(cfg.jitter + 1, xp.float32)).astype(xp.int32)
    total_delay = xp.asarray(cfg.delay, xp.int32) + extra
    enq = send & ~lost
    instant = enq & (total_delay == 0)
    flying = enq & (total_delay > 0)
    delivered = due | instant
    out_payload = xp.where(instant, payload_now, state.payload)
    stored = xp.where(flying | instant, payload_now, state.payload)
    timer = xp.where(
        flying,
        total_delay - 1,
        xp.where(
            send, -1, xp.where(in_flight & ~due, state.timer - 1, -1)
        ),
    ).astype(xp.int32)

    # 5. ack wire: one ack per delivery, own drop/jitter draws.
    ack_lost = delivered & (ack_u[0] < cfg.drop)
    ack_extra = (
        ack_u[1] * xp.asarray(cfg.jitter + 1, xp.float32)
    ).astype(xp.int32)
    ack_delay = xp.asarray(cfg.delay, xp.int32) + ack_extra
    ack_enq = delivered & ~ack_lost
    ack_instant = ack_enq & (ack_delay == 0)
    ack_flying = ack_enq & (ack_delay > 0)
    ack_timer = xp.where(
        ack_flying,
        ack_delay - 1,
        xp.where(
            delivered,
            -1,
            xp.where(
                (state.ack_timer >= 0) & ~ack_arr, state.ack_timer - 1, -1
            ),
        ),
    ).astype(xp.int32)
    acked = ack_arr | ack_instant

    # Timeout window for this slot's sends: the backoff ladder multiplies
    # (f32-exact under both namespaces); the i32 window is >= 1 slot.
    grown = xp.minimum(
        state.backoff * xp.asarray(cfg.backoff_base, xp.float32),
        xp.asarray(2.0**30, xp.float32),
    )
    backoff = xp.where(
        send_new,
        xp.asarray(cfg.ack_timeout, xp.float32),
        xp.where(retrans_now, grown, state.backoff),
    ).astype(xp.float32)
    window = xp.maximum(backoff.astype(xp.int32), 1)
    # A send whose data *and* ack both arrive this slot (the zero-delay
    # wire) completes its round trip immediately: no window stays open.
    rt_done = send & instant & ack_instant
    await_t = xp.where(
        send,
        xp.where(rt_done, -1, window - 1),
        xp.where(
            awaiting & ~acked & ~abandon,
            # maximum() holds an expired-but-unactionable window (crashed
            # sender) at zero so it fires on the first healthy slot.
            xp.maximum(state.awaiting - 1, 0),
            -1,
        ),
    ).astype(xp.int32)
    retries = xp.where(
        send_new,
        0,
        xp.where(
            retrans_now,
            state.retries + 1,
            xp.where(acked, 0, state.retries),
        ),
    ).astype(xp.int32)
    gave_up = (state.gave_up | abandon) & ~acked

    # 6. keepalives: fired by the server clock, same wire, billed.
    ka_p = xp.asarray(cfg.ka_period, xp.int32)
    ka_since = state.ka_since + 1
    ka_fire = (ka_p > 0) & (ka_since >= ka_p)
    if can_send is not None:
        ka_fire = ka_fire & can_send
    ka_lost = ka_fire & (ack_u[2] < cfg.drop)
    ka_extra = (
        ack_u[3] * xp.asarray(cfg.jitter + 1, xp.float32)
    ).astype(xp.int32)
    ka_delay = xp.asarray(cfg.delay, xp.int32) + ka_extra
    ka_enq = ka_fire & ~ka_lost
    ka_instant = ka_enq & (ka_delay == 0)
    ka_flying = ka_enq & (ka_delay > 0)
    ka_deliv = ka_due | ka_instant
    ka_timer = xp.where(
        ka_flying,
        ka_delay - 1,
        xp.where(
            ka_fire,
            -1,
            xp.where((state.ka_timer >= 0) & ~ka_due, state.ka_timer - 1, -1),
        ),
    ).astype(xp.int32)

    sent = (
        xp.sum(send, dtype=xp.int32)
        + xp.sum(delivered, dtype=xp.int32)  # acks: one per delivery
        + xp.sum(ka_fire, dtype=xp.int32)
    )
    return delivered, out_payload, sent, AckNetState(
        timer=timer,
        payload=stored,
        pending=pending,
        awaiting=await_t,
        backoff=backoff,
        retries=retries,
        ack_timer=ack_timer,
        gave_up=gave_up,
        ka_timer=ka_timer,
        ka_since=xp.where(ka_fire, 0, ka_since).astype(xp.int32),
        ka_age=xp.where(delivered | ka_deliv, 0, state.ka_age + 1).astype(
            xp.int32
        ),
        age=xp.where(delivered, 0, state.age + 1).astype(xp.int32),
        drops=state.drops
        + xp.sum(lost, dtype=xp.int32)
        + xp.sum(ack_lost, dtype=xp.int32)
        + xp.sum(ka_lost, dtype=xp.int32),
        retrans=state.retrans + xp.sum(retrans_now, dtype=xp.int32),
    )


def control_plane_init(
    k: int,
    *,
    network: str = "none",
    fault: str = "none",
    transport: str = "fire_forget",
    xp=jnp,
    payload_dtype=None,
):
    """Initial control-plane carries for one engine instance.

    The single constructor every tier's scan/stream carry goes through:
    returns ``(comm, net, faulted)`` where ``net`` / ``faulted`` are
    ``None`` (an empty pytree subtree) when the corresponding kind is off,
    so the default program structure is unchanged.  Under
    ``transport="ack"`` the wire state is an :class:`AckNetState`; the
    default "fire_forget" keeps the historical :class:`NetState`
    structure.  The streaming serving engine initialises its chunk carry
    here and a future live arrival feed resumes from the same triple via
    :func:`snapshot_state` / :func:`restore_state`.
    """
    comm = CommState.init(k, xp=xp)
    if network == "none":
        net = None
    elif transport == "ack":
        net = AckNetState.init(k, xp=xp, payload_dtype=payload_dtype)
    else:
        net = NetState.init(k, xp=xp, payload_dtype=payload_dtype)
    faulted = xp.zeros((k,), bool) if fault != "none" else None
    return comm, net, faulted


def snapshot_state(tree):
    """Host-side numpy copy of a control-plane (or whole-engine) carry.

    The persistence half of the resume seam: a carry pytree -- any nesting
    of :class:`CommState` / :class:`NetState` / :class:`AckNetState` /
    plain arrays -- becomes concrete ``numpy`` arrays safe to hold across
    jit calls, pickle to disk, or hand to a host-side dispatcher between
    stream segments.

    Scalar int32 counters (``CommState.msgs``, ``NetState.drops``,
    ``AckNetState.retrans``, the engine's completion totals, ...) are
    promoted to **int64** on the way out: a multi-segment soak aggregates
    host-side from these snapshots, and at 1e7-slot horizons with
    several messages per slot an int32 total wraps.  The promotion is
    reversed by :func:`restore_state`, so the on-device carry structure
    is untouched.
    """
    import numpy as np

    def cvt(a):
        a = np.asarray(a)
        if a.ndim == 0 and a.dtype == np.int32:
            return a.astype(np.int64)
        return a

    return jax.tree.map(cvt, tree)


def restore_state(tree, xp=jnp):
    """Reconstitute a :func:`snapshot_state` carry on the target namespace.

    ``xp=jnp`` places the arrays back on device for the jitted scans;
    ``xp=np`` yields the numpy view the host-side ``CareDispatcher``
    mirrors consume.  Structure (including ``None`` subtrees for disabled
    kinds) is preserved -- scalar int64 counters are narrowed back to the
    int32 the compiled carries declare (values above int32 range saturate
    rather than wrap, keeping the on-device counter monotone) -- so the
    restored carry drops straight back into the compiled chunk step that
    produced it.
    """
    import numpy as np

    def cvt(a):
        a = np.asarray(a)
        if a.ndim == 0 and a.dtype == np.int64:
            a = np.int32(min(int(a), np.iinfo(np.int32).max))
        return xp.asarray(a)

    return jax.tree.map(cvt, tree)


def validate_control_plane(
    *,
    network: str = "none",
    net_delay: float = 0,
    net_jitter: float = 0,
    net_drop: float = 0.0,
    suspect_age: float = 0,
    fault: str = "none",
    crash_rate: float = 0.0,
    recover_rate: float = 0.0,
    slow_factor: float = 1.0,
    transport: str = "fire_forget",
    ack_timeout: float = 0,
    backoff_base: float = 1.0,
    max_retries: float = 0,
    ka_period: float = 0,
    policy: str = None,
    comm: str = None,
    token_refresh: float = None,
) -> None:
    """Reject invalid network/fault/pull operands at config-validation time.

    Called from the host-side config entry points of both tiers
    (``SimConfig``/``Scenario.create`` and ``ServeConfig``/
    ``EngineConfig``) before anything is traced, mirroring the
    ``route_backend="pallas"`` corner-pinning style: every error names the
    offending field and the fix.

    ``policy`` / ``comm`` / ``token_refresh`` are the pull-family operands:
    when a tier passes its policy and comm kinds, the 1:1 pairing of the
    pull policies (``jiq`` / ``hsq``) with their token channels is enforced
    here, along with the sign of the hsq token-refresh operand (the traced
    rate in the slotted tier, the refresh period in the serving tier).
    Callers that do not model policies simply omit them.
    """
    if policy is not None and comm is not None:
        if policy in PULL_KINDS:
            if comm == "exact":
                raise ValueError(
                    f"policy={policy!r} cannot run under comm='exact' --"
                    " the exact full-state channel is push-per-departure"
                    " and would double-bill the token traffic; set"
                    f" comm={policy!r} (the matching pull token channel)"
                )
            if comm != policy:
                raise ValueError(
                    f"policy={policy!r} requires comm={policy!r} (its"
                    f" server-initiated token channel), got comm={comm!r}"
                )
        elif comm in PULL_KINDS:
            raise ValueError(
                f"comm={comm!r} is the token channel of policy={comm!r};"
                f" it cannot drive the push policy {policy!r}"
            )
    if token_refresh is not None and token_refresh < 0:
        raise ValueError(
            f"token_refresh must be >= 0 (the hsq token-refresh rate;"
            f" 0 disables the periodic refresh), got {token_refresh}"
        )
    if network not in ("none", "net"):
        raise ValueError(
            f"unknown network kind: {network!r} (expected 'none' or 'net')"
        )
    if fault not in ("none", "crash", "slow"):
        raise ValueError(
            f"unknown fault kind: {fault!r} "
            "(expected 'none', 'crash' or 'slow')"
        )
    if net_delay < 0:
        raise ValueError(f"net_delay must be >= 0 slots, got {net_delay}")
    if net_jitter < 0:
        raise ValueError(f"net_jitter must be >= 0 slots, got {net_jitter}")
    if net_drop < 0:
        raise ValueError(
            f"net_drop is a probability and must be >= 0, got {net_drop}"
        )
    if net_drop >= 1:
        raise ValueError(
            f"net_drop must be < 1, got {net_drop} -- a drop probability of"
            " 1 loses every message and no trigger retry can ever land"
        )
    if suspect_age < 0:
        raise ValueError(
            f"suspect_age must be >= 0 slots (0 disables suspect masking),"
            f" got {suspect_age}"
        )
    if network == "none":
        for field, val in (
            ("net_delay", net_delay),
            ("net_jitter", net_jitter),
            ("net_drop", net_drop),
        ):
            if val != 0:
                raise ValueError(
                    f"{field}={val} has no effect with network='none';"
                    " set network='net' to model the control plane"
                )
    if transport not in ("fire_forget", "ack"):
        raise ValueError(
            f"unknown transport kind: {transport!r} (expected"
            " 'fire_forget' or 'ack')"
        )
    if transport == "ack":
        if network == "none":
            raise ValueError(
                "transport='ack' needs network='net' -- with"
                " network='none' delivery is instant and lossless, so"
                " there is nothing to acknowledge"
            )
        if ack_timeout < 1:
            raise ValueError(
                f"ack_timeout must be >= 1 slot under transport='ack'"
                f" (a sender must wait at least one slot for its ack;"
                f" 0 would retransmit every slot forever), got"
                f" {ack_timeout}"
            )
        if backoff_base < 1:
            raise ValueError(
                f"backoff_base must be >= 1 (the timeout window may only"
                f" grow across retries), got {backoff_base}"
            )
        if max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0 (0 abandons after the first"
                f" unacked window), got {max_retries}"
            )
        if ka_period < 0:
            raise ValueError(
                f"ka_period must be >= 0 slots (0 disables keepalives),"
                f" got {ka_period}"
            )
    else:
        for field, val, neutral in (
            ("ack_timeout", ack_timeout, 0),
            ("backoff_base", backoff_base, 1.0),
            ("max_retries", max_retries, 0),
            ("ka_period", ka_period, 0),
        ):
            if val != neutral:
                raise ValueError(
                    f"{field}={val} has no effect with"
                    " transport='fire_forget'; set transport='ack' for"
                    " the reliable transport"
                )
    if not 0.0 <= crash_rate <= 1.0:
        raise ValueError(
            f"crash_rate is a per-slot probability in [0, 1], got {crash_rate}"
        )
    if not 0.0 <= recover_rate <= 1.0:
        raise ValueError(
            f"recover_rate is a per-slot probability in [0, 1],"
            f" got {recover_rate}"
        )
    if crash_rate > 0 and recover_rate == 0:
        raise ValueError(
            "recover_rate must be > 0 when crash_rate > 0 -- with"
            f" recover_rate=0 every crashed server (crash_rate={crash_rate})"
            " stays down forever and the system drains to zero capacity"
        )
    if slow_factor <= 0 or slow_factor > 1:
        raise ValueError(
            f"slow_factor scales service_rates and must be in (0, 1],"
            f" got {slow_factor}"
        )
    if fault == "none":
        for field, val, neutral in (
            ("crash_rate", crash_rate, 0.0),
            ("recover_rate", recover_rate, 0.0),
            ("slow_factor", slow_factor, 1.0),
        ):
            if val != neutral:
                raise ValueError(
                    f"{field}={val} has no effect with fault='none';"
                    " set fault='crash' or fault='slow'"
                )
    if fault == "crash" and slow_factor != 1.0:
        raise ValueError(
            f"slow_factor={slow_factor} has no effect with fault='crash';"
            " use fault='slow' for transient slowdowns"
        )
    if suspect_age > 0 and network == "none" and fault == "none":
        raise ValueError(
            "suspect_age > 0 needs a modeled control plane -- with"
            " network='none' and fault='none' updates are instant and"
            " servers never fail, so the staleness timeout would only"
            " mis-mask idle servers; enable network='net' and/or a fault"
            " kind"
        )
