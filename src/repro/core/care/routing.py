"""Resource-allocation component (paper Sections 2.1.4 and 9.1).

Routing policies map a (possibly approximated) state vector to a server
index.  All policies are pure functions of ``(q, rr_ptr, key)`` so the
simulator can treat them uniformly; which state vector (true or approximated)
is fed to the policy is decided by the caller.

Tie-breaking for the shortest-queue family is uniformly random, matching the
paper's JSAQ definition (Section 2.1.4).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

PolicyKind = Literal[
    "jsq", "jsaq", "sq2", "sqd", "rr", "random", "jiq", "hsq"
]

# Pull (server-initiated) policies: route on the balancer-side token pool
# maintained by the matching ``comm`` kind, not on a queue vector.
PULL_POLICIES = ("jiq", "hsq")


def expected_drain_slots(mean_size, rates):
    """Expected per-job drain time ``E[S] / r_i`` in slots, shape ``(K,)``.

    The drain-time-aware score of the shortest-queue family is
    ``q_i * expected_drain_slots(mean, rates)[i]`` -- a queue of 4 at a
    double-speed server beats a queue of 3 at a half-speed one.  The single
    implementation both tiers consume: the slotted simulator precomputes it
    once per run from traced ``Scenario`` operands, and the serving engine
    (jax scan *and* numpy ``CareDispatcher``) from ``decode_rates``.  Both
    operands must be float32 so the two serving backends produce the same
    IEEE quotient bit for bit.
    """
    return mean_size / rates


def argmin_random_ties(q: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """Index of the minimum of ``q``; ties broken uniformly at random."""
    is_min = q == jnp.min(q)
    # Gumbel trick restricted to the argmin set: uniform over ties.
    g = jax.random.gumbel(key, q.shape)
    score = jnp.where(is_min, g, -jnp.inf)
    return jnp.argmax(score).astype(jnp.int32)


def mask_scores(score: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Lift masked-out candidates' scores to ``+inf`` (suspect exclusion).

    ``mask`` marks *eligible* servers.  An all-``False`` mask falls back to
    all-eligible -- when every server looks suspect the balancer has no
    information to discriminate on, so it degrades to the unmasked policy
    rather than routing nowhere.  Scores are cast to float32 first (exact
    for integer queue lengths below 2**24, so tie sets -- and therefore
    decisions -- are identical to the integer path when the mask is
    all-``True``).
    """
    mask = jnp.where(jnp.any(mask), mask, True)
    return jnp.where(mask, score.astype(jnp.float32), jnp.inf)


def route_shortest(
    q: jnp.ndarray,
    key: jax.Array,
    deterministic: bool = False,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """JSQ / JSAQ: join the shortest (approximated) queue.

    ``deterministic=True`` resolves ties to the lowest index instead of
    uniformly at random -- the convention of the Pallas routing kernels
    (``kernels/jsaq_route.py``), so the dense path can be compared to the
    kernel path decision for decision.  The key is still accepted (and
    ignored) so the callers' stream plumbing is identical either way.
    ``mask`` (optional) restricts the candidate set (see
    :func:`mask_scores`); its presence is structural.
    """
    if mask is not None:
        q = mask_scores(q, mask)
    if deterministic:
        return jnp.argmin(q).astype(jnp.int32)
    return argmin_random_ties(q, key)


def route_sqd(
    q_true: jnp.ndarray,
    d: int,
    key: jax.Array,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """SQ(d): sample ``d`` distinct servers, join the shortest among them.

    ``mask`` (optional) excludes suspect servers *within the sampled
    subset*: the d queries still go out (the sample is taken before the
    balancer knows who answers), but a suspect candidate loses any
    comparison unless the whole subset is suspect (fallback per
    :func:`mask_scores`).
    """
    k = q_true.shape[0]
    key_perm, key_tie = jax.random.split(key)
    sample = jax.random.permutation(key_perm, k)[:d]
    sub = q_true[sample]
    if mask is not None:
        sub = mask_scores(sub, mask[sample])
    j = argmin_random_ties(sub, key_tie)
    return sample[j].astype(jnp.int32)


def route_rr(
    rr_ptr: jnp.ndarray,
    k: int,
    mask: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Round Robin: deterministic cyclic assignment.  Returns (server, ptr').

    ``mask`` (optional) restricts the candidate set: the pointer skips
    masked-out servers and lands on the cyclically-next eligible one (an
    all-``False`` mask degrades to unmasked, like :func:`mask_scores`).
    With an all-``True`` mask the choice and the pointer sequence are
    identical to the unmasked path.
    """
    if mask is None:
        return rr_ptr % k, (rr_ptr + 1) % k
    mask = jnp.where(jnp.any(mask), mask, True)
    # Cyclic distance from the pointer; masked-out servers pushed past the
    # horizon so argmin picks the nearest eligible server at/after ptr.
    off = (jnp.arange(k, dtype=jnp.int32) - rr_ptr) % k
    off = jnp.where(mask, off, k)
    server = jnp.argmin(off).astype(jnp.int32)
    return server, (server + 1) % k


def route_random(
    k: int,
    key: jax.Array,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Uniformly random assignment.

    ``mask`` (optional) restricts the draw to the eligible set: the r-th
    eligible server is picked with ``r ~ U{0..n_eligible-1}`` (an
    all-``False`` mask degrades to unmasked).  With an all-``True`` mask
    the draw consumes the key exactly like the unmasked path, so decisions
    are bit-identical.
    """
    if mask is None:
        return jax.random.randint(key, (), 0, k, jnp.int32)
    mask = jnp.where(jnp.any(mask), mask, True)
    n_elig = jnp.sum(mask, dtype=jnp.int32)
    r = jax.random.randint(key, (), 0, n_elig, jnp.int32)
    cum = jnp.cumsum(mask.astype(jnp.int32))
    return jnp.argmax(cum == r + 1).astype(jnp.int32)


def route_tokens(
    tokens: jnp.ndarray,
    key: jax.Array,
    deterministic: bool = False,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Pull policies (JIQ / hyper-scalable JSQ): spend a balancer token.

    ``tokens`` is the balancer-side ``(K,)`` int32 token pool maintained by
    the matching pull comm kind (1 per idle server for JIQ, the headroom
    below the threshold for hsq).  Routing joins the server holding the
    most tokens -- scored as ``-tokens`` through the shortest-queue
    machinery so ties (including the empty-pool case, where every server
    holds 0 and the policy degrades to a uniform-random fallback) resolve
    exactly like JSAQ, and suspect/affinity masks compose via
    :func:`mask_scores`.
    """
    score = (0 - tokens).astype(jnp.float32)
    return route_shortest(score, key, deterministic, mask)


def route(
    policy: PolicyKind,
    q_true: jnp.ndarray,
    q_app: jnp.ndarray,
    rr_ptr: jnp.ndarray,
    key: jax.Array,
    d: int = 2,
    drain_slots: jnp.ndarray | None = None,
    deterministic: bool = False,
    mask: jnp.ndarray | None = None,
    tokens: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch one job.  Returns ``(server, rr_ptr')``.

    ``mask`` (optional, ``(K,)`` bool) marks servers *eligible* -- the
    suspect-server exclusion of the degraded control plane and the
    per-class affinity constraint of multi-class workloads (an
    all-``False`` mask degrades to unmasked, see :func:`mask_scores`).
    Every policy honours it: the shortest-queue family and the pull
    policies lift masked scores to ``+inf``, ``rr`` skips masked servers
    to the cyclically-next eligible one, and ``random`` samples uniformly
    from the eligible set.  With an all-``True`` mask every policy's
    decisions are bit-identical to the unmasked path.

    ``tokens`` (``(K,)`` int32) is the balancer-side token pool the pull
    policies (``jiq`` / ``hsq``) route on; see :func:`route_tokens`.  The
    caller owns spending/refreshing it.

    ``deterministic`` (static) switches the shortest-queue family's
    tie-break from uniformly random to lowest index (the Pallas kernel
    convention); the subset-sampling and random policies keep their
    random draws regardless.

    ``policy`` is static (Python-level), so jitted callers specialise on it.
    ``drain_slots`` (optional, ``(K,)``) supplies the expected per-job
    drain time ``E[S] / r_i`` in slots under heterogeneous service rates:
    the shortest-queue family then minimises the *expected drain time*
    ``q_i * E[S] / r_i`` rather than the raw length, so a queue of 4 at a
    double-speed server beats a queue of 3 at a half-speed one.  It is an
    array operand (the traced ``ServiceProcess`` mean over the traced
    ``Scenario.service_rates`` in the grid simulator, precomputed once per
    run outside the scan), so rate profiles and mean sizes can vary per
    grid cell without recompiling; only its presence/absence is
    structural.  Scaling by any single positive mean is argmin-invariant,
    so homogeneous-mean decisions match the historical ``q_i / r_i`` score
    (golden-pinned for the rate profiles under test).
    """
    k = q_true.shape[0]
    if drain_slots is None:
        scaled_true, scaled_app = q_true, q_app
    else:
        scaled_true = q_true.astype(jnp.float32) * drain_slots
        scaled_app = q_app.astype(jnp.float32) * drain_slots
    if policy == "jsq":
        return route_shortest(scaled_true, key, deterministic, mask), rr_ptr
    if policy == "jsaq":
        return route_shortest(scaled_app, key, deterministic, mask), rr_ptr
    if policy == "sq2":
        return route_sqd(scaled_true, 2, key, mask), rr_ptr
    if policy == "sqd":
        return route_sqd(scaled_true, d, key, mask), rr_ptr
    if policy == "rr":
        server, ptr = route_rr(rr_ptr, k, mask)
        return server.astype(jnp.int32), ptr
    if policy == "random":
        return route_random(k, key, mask), rr_ptr
    if policy in PULL_POLICIES:
        return route_tokens(tokens, key, deterministic, mask), rr_ptr
    raise ValueError(f"unknown policy: {policy}")
