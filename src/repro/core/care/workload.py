"""Workload layer: arrival processes, service processes, rate scenarios.

The seed simulator hard-coded the paper's Section 9.1 setting -- Bernoulli
arrivals and Geometric(1/K) sizes on homogeneous unit-rate servers.  This
module generalises both axes so the slotted simulator can exercise the
regimes studied in the hyper-scalable / sparse-feedback literature
(van der Boor et al., PAPERS.md) without touching the scan body:

* **Arrivals** -- ``bernoulli`` (the paper's default) or ``mmpp``: a
  two-state Markov-modulated Bernoulli process.  The chain alternates
  between a *burst* state with arrival probability
  ``min(burst_intensity * load, 1)`` and a *lull* state chosen so the
  long-run rate is exactly ``load``; ``burst_stay`` is the per-slot
  probability of remaining in the current state (mean burst length
  ``1/(1-burst_stay)`` slots).  ``burst_intensity = 1`` degenerates to
  Bernoulli.  Either process can additionally be modulated by a
  **diurnal load curve** (:func:`diurnal_modulation`): the per-slot rate
  becomes ``rate * (1 + amp * sin(2 pi t / period))``, with traced
  amplitude/period operands, so time-varying load sweeps share one
  compiled program (``amp = 0`` is bit-identical to the flat rate).
* **Sizes** -- a :class:`ServiceProcess`: i.i.d. sizes in whole work
  units (slots), drawn at arrival time so the same input replays under
  every policy (the paper's comparison method).  The distribution *kind*
  is structural; the mean and tail-shape are traced operands:

  - ``geometric``     -- Geometric(1/mean), support {1, 2, ...} (paper).
  - ``deterministic`` -- every job takes exactly ``round(mean)`` slots.
  - ``pareto``        -- Pareto(scale, alpha) with ``alpha = tail > 1``
    and scale chosen so the continuous mean is ``mean``; discretised by
    ``ceil``.  Heavy-tailed: infinite variance for ``alpha <= 2``.
  - ``weibull``       -- Weibull(shape ``tail``, scale chosen for mean
    ``mean``); discretised by ``ceil``.  ``tail < 1`` gives a
    heavier-than-exponential tail, ``tail = 1`` is exponential-like.

* **Service rates** -- per-server speeds ``r_i`` in work units per slot.
  Speeds are realised by a deterministic credit schedule:
  ``units_i(t) = floor((t+1) r_i) - floor(t r_i)``, so a rate-0.5 server
  works every other slot and a rate-1.5 server alternates 1/2 units.  The
  schedule is a pure function of the slot index -- the balancer can mirror
  it exactly, which is what lets the MSR emulation stay correct under
  heterogeneity (the emulated queue drains with the *same* units).

All functions are jax-traceable and used both per-simulation and under
``jax.vmap`` inside :func:`repro.core.care.slotted_sim.simulate_grid`.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

ServiceKind = Literal["geometric", "deterministic", "pareto", "weibull"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["mean", "tail", "geo_log1p", "msr_slots", "scale", "inv_tail"],
    meta_fields=["kind"],
)
@dataclasses.dataclass(frozen=True)
class ServiceProcess:
    """Job-size distribution: a static *kind* plus traced operand bundle.

    The ``kind`` selects the sampler code path at trace time (it is pytree
    *metadata*, so stacking scenarios with different kinds fails loudly
    instead of silently mixing distributions); every numeric parameter is
    a traced scalar, so a grid sweeping ``mean`` or ``tail`` shares one
    compiled program.  Derived constants (``geo_log1p``, ``scale``,
    ``inv_tail``, ``msr_slots``) are computed host-side in float64 at
    :meth:`create` so the geometric path is bit-identical to the
    historical program that baked ``mean_service`` into the structure.

    Attributes:
      kind: distribution family (static; see module docstring).
      mean: () f32 -- mean job size in slots (continuous mean for the
        discretised heavy-tailed kinds).
      tail: () f32 -- tail-shape operand: Pareto ``alpha`` or Weibull
        shape ``k``.  Carried for reporting; samplers consume the derived
        ``inv_tail``/``scale``.
      geo_log1p: () f32 -- derived ``log1p(-1/mean)`` (geometric
        denominator), computed in float64 and cast once.
      msr_slots: () i32 -- derived ``round(mean)``: the deterministic
        per-job slot count the MSR emulation assigns (Definition 4.8).
      scale: () f32 -- derived Pareto scale ``x_m`` / Weibull scale
        ``lambda`` (0 for the kinds that need none).
      inv_tail: () f32 -- derived ``1/tail`` (0 when unused).
    """

    kind: str
    mean: jnp.ndarray
    tail: jnp.ndarray
    geo_log1p: jnp.ndarray
    msr_slots: jnp.ndarray
    scale: jnp.ndarray
    inv_tail: jnp.ndarray

    @staticmethod
    def create(
        kind: ServiceKind = "geometric",
        mean: float = 30.0,
        tail: float = 2.0,
    ) -> "ServiceProcess":
        mean = float(mean)
        tail = float(tail)
        if mean < 1.0:
            raise ValueError(f"mean service must be >= 1 slot, got {mean}")
        scale = 0.0
        inv_tail = 0.0
        if kind == "pareto":
            if tail <= 1.0:
                raise ValueError(
                    f"pareto tail index must be > 1 for a finite mean, got {tail}"
                )
            scale = mean * (tail - 1.0) / tail
            inv_tail = 1.0 / tail
        elif kind == "weibull":
            if tail <= 0.0:
                raise ValueError(f"weibull shape must be > 0, got {tail}")
            scale = mean / math.gamma(1.0 + 1.0 / tail)
            inv_tail = 1.0 / tail
        elif kind not in ("geometric", "deterministic"):
            raise ValueError(f"unknown service kind: {kind}")
        return ServiceProcess(
            kind=kind,
            mean=jnp.float32(mean),
            tail=jnp.float32(tail),
            geo_log1p=jnp.float32(np.log1p(-1.0 / np.float64(mean))),
            msr_slots=jnp.int32(max(int(round(mean)), 1)),
            scale=jnp.float32(scale),
            inv_tail=jnp.float32(inv_tail),
        )


def service_sizes(key: jax.Array, n: int, sp: ServiceProcess) -> jnp.ndarray:
    """``n`` i.i.d. job sizes in whole slots (support {1, 2, ...}).

    All kinds consume the *same* uniform draw, so two ServiceProcesses of
    the same kind replay the same sample path under different operands,
    and the geometric kind reproduces the seed simulator's stream exactly.
    """
    u = jax.random.uniform(key, (n,), jnp.float32, 1e-7, 1.0 - 1e-7)
    if sp.kind == "geometric":
        sizes = jnp.floor(jnp.log1p(-u) / sp.geo_log1p) + 1.0
    elif sp.kind == "deterministic":
        sizes = jnp.broadcast_to(jnp.round(sp.mean), (n,))
    elif sp.kind == "pareto":
        sizes = jnp.ceil(pareto_raw(u, sp.scale, sp.inv_tail))
    elif sp.kind == "weibull":
        sizes = jnp.ceil(weibull_raw(u, sp.scale, sp.inv_tail))
    else:
        raise ValueError(f"unknown service kind: {sp.kind}")
    return jnp.maximum(sizes, 1.0).astype(jnp.int32)


def pareto_raw(u: jnp.ndarray, scale, inv_tail) -> jnp.ndarray:
    """Continuous Pareto(scale, 1/inv_tail) samples via inverse CDF."""
    return scale * u ** (-inv_tail)


def weibull_raw(u: jnp.ndarray, scale, inv_tail) -> jnp.ndarray:
    """Continuous Weibull(shape 1/inv_tail, scale) samples via inverse CDF."""
    return scale * (-jnp.log(u)) ** inv_tail


def diurnal_modulation(t_idx: jnp.ndarray, amp, period) -> jnp.ndarray:
    """Per-slot rate multiplier ``1 + amp * sin(2 pi t / period)``.

    ``amp`` / ``period`` are traced operands.  The long-run mean of the
    multiplier is 1 (over whole periods), so the modulated process keeps
    its nominal average rate; keep ``amp <= min(1, 1/rate - 1)`` so the
    instantaneous rate stays a probability.  ``amp = 0`` returns exactly
    1.0 everywhere, so unmodulated cells are bit-identical to the flat
    arrival stream and share the modulated cells' compiled program.
    """
    phase = (2.0 * np.pi) * t_idx.astype(jnp.float32) / period
    return 1.0 + amp * jnp.sin(phase)


def geometric_sizes(key: jax.Array, n: int, mean: int) -> jnp.ndarray:
    """i.i.d. Geometric(1/mean) sizes with support {1, 2, ...}.

    Convenience wrapper over the ``geometric`` :class:`ServiceProcess`
    (single implementation of the inverse-CDF formula); bit-identical to
    the historical standalone sampler.
    """
    return service_sizes(key, n, ServiceProcess.create("geometric", mean))


def bernoulli_arrivals(
    key: jax.Array, slots: int, load, mod: jnp.ndarray | None = None
) -> jnp.ndarray:
    """One potential arrival per slot with probability ``load``.

    ``load`` may be a Python float or a traced scalar -- the grid simulator
    passes it as a :class:`~repro.core.care.slotted_sim.Scenario` operand.
    ``mod`` (optional, ``(slots,)``) multiplies the per-slot rate -- the
    diurnal curve of :func:`diurnal_modulation`; an all-ones ``mod`` is
    bit-identical to no modulation (``bernoulli(key, p, shape)`` is
    ``uniform(key, shape) < p`` and ``load * 1.0 == load``).
    """
    p = load if mod is None else load * mod
    return jax.random.bernoulli(key, p, (slots,))


def mmpp_arrivals(
    key: jax.Array,
    slots: int,
    load: float,
    burst_intensity: float = 1.6,
    burst_stay: float = 0.98,
) -> jnp.ndarray:
    """Bursty arrivals: 2-state Markov-modulated Bernoulli, mean rate ``load``.

    The symmetric chain spends half its time in each state, so with burst
    rate ``lam_hi = min(burst_intensity * load, 1)`` the lull rate
    ``lam_lo = 2 * load - lam_hi`` keeps the long-run arrival rate at
    ``load`` (``lam_lo`` is clipped at 0; intensities beyond ``2`` saturate).

    Host-side convenience wrapper: the rate balance runs in Python float64.
    Traced callers (the scenario grid) precompute ``lam_hi`` / ``lam_lo``
    the same way at :class:`Scenario` construction and call
    :func:`mmpp_arrivals_from_rates` directly -- keeping the two paths
    bit-identical.
    """
    lam_hi = min(burst_intensity * load, 1.0)
    lam_lo = max(2.0 * load - lam_hi, 0.0)
    return mmpp_arrivals_from_rates(key, slots, lam_hi, lam_lo, burst_stay)


def mmpp_arrivals_from_rates(
    key: jax.Array,
    slots: int,
    lam_hi,
    lam_lo,
    burst_stay,
    mod: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """MMPP arrivals from ready-made state rates (traceable operands).

    ``lam_hi`` / ``lam_lo`` / ``burst_stay`` may be Python floats or traced
    scalars; only ``slots`` is structural.  ``mod`` (optional, ``(slots,)``)
    multiplies the per-slot rate -- the diurnal curve of
    :func:`diurnal_modulation`; an all-ones ``mod`` is bit-identical to no
    modulation (``lam * 1.0 == lam``).
    """
    k_switch, k_arr = jax.random.split(key)
    switch = jax.random.uniform(k_switch, (slots,)) >= burst_stay
    u_arr = jax.random.uniform(k_arr, (slots,))
    mod = jnp.ones((slots,), jnp.float32) if mod is None else mod

    def step(state, xs):
        sw, u, m = xs
        state = jnp.where(sw, 1 - state, state)
        lam = jnp.where(state == 1, lam_hi, lam_lo) * m
        return state, u < lam

    _, arrive = jax.lax.scan(
        step, jnp.zeros((), jnp.int32), (switch, u_arr, mod)
    )
    return arrive


def arrival_classes(key: jax.Array, slots: int, mix) -> jnp.ndarray:
    """Per-slot arrival class ids from a traced class-mix simplex.

    ``mix`` is a ``(C,)`` f32 vector of class weights (normalised here, so
    any positive scaling works).  Each potential arrival independently
    draws its class via inverse-CDF on the cumulative mix -- a traced
    operand, so grids sweeping the mix share one compiled program; only
    ``C`` (the shape) is structural.  The constrained-routing tier pairs
    the returned ids with a ``(C, K)`` per-class affinity mask (Fox et
    al. 2025-style class SLAs) fed to the policies' candidate mask.
    """
    u = jax.random.uniform(key, (slots,), jnp.float32)
    cum = jnp.cumsum(mix) / jnp.sum(mix)
    cls = jnp.searchsorted(cum, u, side="right")
    return jnp.clip(cls, 0, mix.shape[0] - 1).astype(jnp.int32)


def service_units(slot_idx, rates, xp=jnp):
    """Work units each server completes in slot ``slot_idx`` (credit schedule).

    Deterministic in the slot index: ``floor((t+1) r) - floor(t r)``.  The
    long-run average is exactly ``r`` units/slot per server.  ``xp`` selects
    the array namespace: ``jnp`` (default) inside traced scan bodies, ``np``
    from the serving tier's host-side reference loop -- the schedule is pure
    float32 arithmetic on both, so the two backends mirror it bit for bit.
    """
    t = xp.asarray(slot_idx).astype(xp.float32)
    return (xp.floor((t + 1.0) * rates) - xp.floor(t * rates)).astype(xp.int32)

def fault_transitions(faulted, fault_u, crash_rate, recover_rate, xp=jnp):
    """One slot of the two-state server fault chain (crash <-> healthy).

    A healthy server crashes with per-slot probability ``crash_rate`` and a
    crashed server recovers with probability ``recover_rate``, driven by one
    i.i.d. uniform per (slot, server) -- the single draw serves both
    transitions because a server is in exactly one state.  ``xp`` selects
    the array namespace so the jax scans and the numpy ``CareDispatcher``
    reference replay identical fault sample paths from the same pre-drawn
    uniforms.

    Args:
      faulted: ``(K,)`` bool, servers currently down (or slowed).
      fault_u: ``(K,)`` f32 uniforms for this slot.
      crash_rate / recover_rate: per-slot probabilities (traced operands).

    Returns:
      ``(faulted', recovered)``: the new fault mask and the mask of servers
      that recovered *this slot* (the resync-on-recovery trigger).
    """
    crash = ~faulted & (fault_u < crash_rate)
    recover = faulted & (fault_u < recover_rate)
    return (faulted | crash) & ~recover, recover


def faulted_service_units(
    slot_idx, faulted, nominal_units, fault_kind, slow_factor, rates=None, xp=jnp
):
    """Effective per-server work units under the fault process.

    * ``fault_kind == "crash"``: a crashed server completes no work (its
      queued jobs are preserved and resume on recovery).
    * ``fault_kind == "slow"``: a faulted server's ``service_rates`` are
      scaled by ``slow_factor`` -- realised through the same deterministic
      credit schedule (:func:`service_units`) so a rate-1 server slowed to
      0.5 works every other slot.

    The *balancer's* MSR emulation keeps draining with the nominal units:
    it is fault-unaware by design, so a slowdown or crash grows the
    approximation error until the trigger fires (ET) or the staleness
    timeout marks the server suspect.

    Args:
      slot_idx: scalar slot index (for the credit schedule).
      faulted: ``(K,)`` bool fault mask for this slot.
      nominal_units: ``(K,)`` int32 fault-free units (scalar 1 broadcast is
        fine for homogeneous unit-rate servers).
      fault_kind: "crash" or "slow" (static).
      slow_factor: () f32 rate multiplier in (0, 1] (traced operand).
      rates: optional ``(K,)`` f32 nominal service rates (None = unit rate).
    """
    nominal_units = xp.asarray(nominal_units)
    if fault_kind == "crash":
        slowed = xp.zeros_like(nominal_units)
    elif fault_kind == "slow":
        base = (
            xp.ones(xp.shape(faulted), xp.float32)
            if rates is None
            else xp.asarray(rates, xp.float32)
        )
        slowed = service_units(slot_idx, base * slow_factor, xp=xp)
    else:
        raise ValueError(f"unknown fault kind: {fault_kind}")
    return xp.where(faulted, slowed, nominal_units)
