"""Workload layer: arrival processes and service-rate scenarios.

The seed simulator hard-coded the paper's Section 9.1 setting -- Bernoulli
arrivals and Geometric(1/K) sizes on homogeneous unit-rate servers.  This
module generalises both axes so the slotted simulator can exercise the
regimes studied in the hyper-scalable / sparse-feedback literature
(van der Boor et al., PAPERS.md) without touching the scan body:

* **Arrivals** -- ``bernoulli`` (the paper's default) or ``mmpp``: a
  two-state Markov-modulated Bernoulli process.  The chain alternates
  between a *burst* state with arrival probability
  ``min(burst_intensity * load, 1)`` and a *lull* state chosen so the
  long-run rate is exactly ``load``; ``burst_stay`` is the per-slot
  probability of remaining in the current state (mean burst length
  ``1/(1-burst_stay)`` slots).  ``burst_intensity = 1`` degenerates to
  Bernoulli.
* **Sizes** -- i.i.d. Geometric(1/mean) work units, drawn at arrival time so
  the same input replays under every policy (the paper's comparison
  method).
* **Service rates** -- per-server speeds ``r_i`` in work units per slot.
  Speeds are realised by a deterministic credit schedule:
  ``units_i(t) = floor((t+1) r_i) - floor(t r_i)``, so a rate-0.5 server
  works every other slot and a rate-1.5 server alternates 1/2 units.  The
  schedule is a pure function of the slot index -- the balancer can mirror
  it exactly, which is what lets the MSR emulation stay correct under
  heterogeneity (the emulated queue drains with the *same* units).

All functions are jax-traceable and used both per-simulation and under
``jax.vmap`` inside :func:`repro.core.care.slotted_sim.simulate_batch`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def geometric_sizes(key: jax.Array, n: int, mean: int) -> jnp.ndarray:
    """i.i.d. Geometric(1/mean) sizes with support {1, 2, ...}."""
    u = jax.random.uniform(key, (n,), jnp.float32, 1e-7, 1.0 - 1e-7)
    sizes = jnp.floor(jnp.log1p(-u) / np.log1p(-1.0 / mean)) + 1.0
    return jnp.maximum(sizes, 1.0).astype(jnp.int32)


def bernoulli_arrivals(key: jax.Array, slots: int, load) -> jnp.ndarray:
    """One potential arrival per slot with probability ``load``.

    ``load`` may be a Python float or a traced scalar -- the grid simulator
    passes it as a :class:`~repro.core.care.slotted_sim.Scenario` operand.
    """
    return jax.random.bernoulli(key, load, (slots,))


def mmpp_arrivals(
    key: jax.Array,
    slots: int,
    load: float,
    burst_intensity: float = 1.6,
    burst_stay: float = 0.98,
) -> jnp.ndarray:
    """Bursty arrivals: 2-state Markov-modulated Bernoulli, mean rate ``load``.

    The symmetric chain spends half its time in each state, so with burst
    rate ``lam_hi = min(burst_intensity * load, 1)`` the lull rate
    ``lam_lo = 2 * load - lam_hi`` keeps the long-run arrival rate at
    ``load`` (``lam_lo`` is clipped at 0; intensities beyond ``2`` saturate).

    Host-side convenience wrapper: the rate balance runs in Python float64.
    Traced callers (the scenario grid) precompute ``lam_hi`` / ``lam_lo``
    the same way at :class:`Scenario` construction and call
    :func:`mmpp_arrivals_from_rates` directly -- keeping the two paths
    bit-identical.
    """
    lam_hi = min(burst_intensity * load, 1.0)
    lam_lo = max(2.0 * load - lam_hi, 0.0)
    return mmpp_arrivals_from_rates(key, slots, lam_hi, lam_lo, burst_stay)


def mmpp_arrivals_from_rates(
    key: jax.Array,
    slots: int,
    lam_hi,
    lam_lo,
    burst_stay,
) -> jnp.ndarray:
    """MMPP arrivals from ready-made state rates (traceable operands).

    ``lam_hi`` / ``lam_lo`` / ``burst_stay`` may be Python floats or traced
    scalars; only ``slots`` is structural.
    """
    k_switch, k_arr = jax.random.split(key)
    switch = jax.random.uniform(k_switch, (slots,)) >= burst_stay
    u_arr = jax.random.uniform(k_arr, (slots,))

    def step(state, xs):
        sw, u = xs
        state = jnp.where(sw, 1 - state, state)
        lam = jnp.where(state == 1, lam_hi, lam_lo)
        return state, u < lam

    _, arrive = jax.lax.scan(step, jnp.zeros((), jnp.int32), (switch, u_arr))
    return arrive


def service_units(slot_idx: jnp.ndarray, rates: jnp.ndarray) -> jnp.ndarray:
    """Work units each server completes in slot ``slot_idx`` (credit schedule).

    Deterministic in the slot index: ``floor((t+1) r) - floor(t r)``.  The
    long-run average is exactly ``r`` units/slot per server.
    """
    t = slot_idx.astype(jnp.float32)
    return (jnp.floor((t + 1.0) * rates) - jnp.floor(t * rates)).astype(jnp.int32)
