"""Discrete-time slotted simulator for the CARE model (paper Section 9).

Dynamics (matching the paper's simulation setting exactly):

* K parallel FIFO servers, a single load balancer.
* In every slot, one job arrives with probability ``load`` (Bernoulli), or
  according to a bursty MMPP-modulated process (``cfg.arrival = "mmpp"``,
  see :mod:`repro.core.care.workload`).
* Job service requirements are i.i.d. Geometric(1/K) (mean K slots), drawn
  per job at arrival time so that *the same input* (arrival times and sizes)
  can be replayed under every policy -- the paper's comparison method.
* A busy server completes one unit of work per slot -- or ``r_i`` units under
  heterogeneous service rates (``cfg.service_rates``), realised by the
  deterministic credit schedule of :func:`workload.service_units` which the
  balancer mirrors exactly.

Within a slot the order of operations is:

  1. arrival (if any) is routed using the *pre-slot* state; a full FIFO
     (``q >= buffer_cap``) *drops* the arrival (counted in ``dropped``)
     instead of admitting it;
  2. every busy server works one unit; the head job departs when its
     remaining requirement reaches zero;
  3. the balancer's emulation advances one slot (approximation component);
  4. the communication pattern (:mod:`repro.core.care.comm` -- the single
     trigger implementation shared with the MoE dispatch simulator and the
     serving engine) evaluates its trigger and any triggered server sends a
     message carrying its exact queue length, which snaps the approximation
     to the truth.

Because a message fires in the same slot in which the trigger condition is
met, the end-of-slot approximation error satisfies ``AQ <= x - 1`` for DT-x
and ET-x (Theorem 2.3) -- asserted by the tests.

Static/traced split
-------------------

The paper's headline artifacts are *grids* over ``(load, x, rt_rate,
scenario)``.  To run a whole grid as one compiled program, the
configuration is split in two:

* :class:`StaticConfig` -- the *structure* of the program: array shapes
  (``servers``, ``slots``, ``buffer_cap``) and the policy / communication /
  approximation / arrival / service **kinds**, which select code paths via
  Python ``if``.  XLA must specialise on these; they are hashable static
  jit arguments and changing any of them costs a recompile.
* :class:`Scenario` -- a registered pytree of *traced array operands*:
  ``load``, ``x``, ``rt_rate`` (carried as the derived ``rt_period``
  operand), ``burst_intensity``/``burst_stay`` (carried as the derived
  ``lam_hi``/``lam_lo`` operands), ``service_rates``, the
  :class:`~repro.core.care.workload.ServiceProcess` operand bundle
  (traced mean / tail-shape), the diurnal-curve operands
  (``diurnal_amp``/``diurnal_period``) and the traced ``horizon``.
  Trigger thresholds, arrival/rate schedules, the size sampler and the
  MSR emulation constant consume these as arrays, so any number of
  scenario cells share one compiled program.

Padded fixed horizon
--------------------

``StaticConfig.slots`` is the *padded* scan length: the scan always runs
``slots`` steps, and each cell's effective length is the traced
``Scenario.horizon`` operand.  Slots at ``t >= horizon`` are masked into
no-ops (no arrivals, no service, no emulation drain, no trigger
evaluation -- every carry field is frozen), so cells with different
effective horizons -- e.g. the diffusion-scaling sweep of ``bench_ssc``,
which grows ``mean_service`` and the horizon together -- share one
compiled program instead of compiling once per horizon.  When
``horizon >= slots`` the mask is all-True and the program is
bit-identical to the historical unpadded one.  Note the *workload stream*
is keyed to the padded shape: two runs agree bit-for-bit exactly when
they share a ``StaticConfig`` (asserted against a per-cell reference
path in ``tests/test_grid.py``); changing the padding re-draws the
stream, just as changing ``slots`` always did.

:class:`SimConfig` remains the user-facing cell description; it is exactly
``static_part() + scenario()``.  Derived operands (``rt_period``,
``lam_hi``, ``lam_lo``, the ServiceProcess constants) are computed
host-side in float64 at :class:`Scenario` construction so the traced
program is bit-identical to the historical compile-per-cell program
(golden-tested in ``tests/test_grid.py``).

The whole simulation is a single ``jax.lax.scan``; all per-server state is
vectorised and job FIFOs are circular buffers carried through the scan, so
the simulator jit-compiles **once per StaticConfig** and runs at native
speed on CPU/TPU.  Batching entry points:

* :func:`simulate` -- one key, one cell.
* :func:`simulate_batch` -- vmap over a batch of PRNG keys for one cell.
* :func:`simulate_grid` -- the sweep entry point: one jit, ``vmap`` over
  the flattened ``(scenario x seed)`` axis, sharded across local devices
  with ``shard_map``.  Ragged batches are padded up to the device count
  (and the padding dropped on the way out), so they no longer fall back to
  a single device the way the old ``pmap`` path did.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.care import approx as approx_lib
from repro.core.care import comm as comm_lib
from repro.core.care import routing as routing_lib
from repro.core.care import workload as workload_lib

CommKind = comm_lib.CommKind


@dataclasses.dataclass(frozen=True)
class StaticConfig:
    """The compile-time structure of the simulator program (hashable).

    Only knobs that change the *traced program itself* live here: array
    shapes (``servers``, ``slots`` -- the *padded* scan length; each
    cell's effective length is the traced ``Scenario.horizon`` --
    ``buffer_cap``) and the policy / comm / approx / arrival / service
    kinds plus the two rate flags, which pick code paths via Python
    ``if`` at trace time.  Everything numeric a figure sweeps --
    including ``mean_service`` and the horizon, which used to be baked in
    here -- lives in :class:`Scenario` instead.
    """

    servers: int = 30
    slots: int = 100_000  # padded scan length (max horizon of the grid)
    policy: routing_lib.PolicyKind = "jsaq"
    comm: CommKind = "et"
    approx: approx_lib.ApproxKind = "msr"
    buffer_cap: int = 2048
    sqd: int = 2
    arrival: str = "bernoulli"  # "bernoulli" | "mmpp"
    service: workload_lib.ServiceKind = "geometric"
    use_rates: bool = False  # heterogeneous service_rates in play
    rate_aware: bool = True
    # Which routing engine executes the slot loop: "dense" (the golden
    # reference -- per-slot one-hot array ops) or "pallas" (the fused
    # kernels/jsaq_route.care_route_pallas mean-field kernel; requires
    # policy jsq/jsaq, msr approximation, deterministic service, unit
    # rates and deterministic_ties -- see _check_pallas_static).
    route_backend: str = "dense"
    # Shortest-queue tie-break: False = uniformly random (the paper's
    # JSAQ definition), True = lowest index (the kernel convention; the
    # mode in which dense and pallas backends are decision-identical).
    deterministic_ties: bool = False
    # Control-plane modelling (fault-injection layer).  ``network="net"``
    # routes every server->balancer message through ``comm.net_step``
    # (traced delay / jitter / drop operands; SQ(d) query round-trips are
    # then counted as real traffic too); ``fault`` runs the crash/recovery
    # or transient-slowdown server process of ``workload.fault_transitions``.
    # "none"/"none" is bit-identical to the historical instant, fault-free
    # program.
    network: str = "none"  # "none" | "net"
    # Wire semantics under network="net": "fire_forget" is the historical
    # one-shot path (structurally unchanged), "ack" runs the reliable
    # transport of comm.net_step_ack (timeout/retransmit/backoff windows,
    # acks and keepalives billed on the same wire).  Static because it
    # selects the carry structure (NetState vs AckNetState).
    transport: str = "fire_forget"  # "fire_forget" | "ack"
    fault: str = "none"  # "none" | "crash" | "slow"
    # Ring capacity for the stale true-state views the query policies
    # (jsq / sq2 / sqd) route on under network="net"; must exceed every
    # ``net_delay`` in the grid (validated at the host entry points).
    # Static because it is an array shape.
    net_delay_cap: int = 32
    # Number of arrival classes for constrained routing (an array shape:
    # ``Scenario.class_mix`` is (C,), ``class_affinity`` (C, K)).  With
    # ``classes == 1`` no class stream is drawn and the program is
    # byte-identical to the historical single-class one.
    classes: int = 1
    # True when the config supplied an explicit affinity mask.  A SINGLE
    # class with a restricted server set is a legitimate constraint (e.g.
    # a partial placement), so the mask must be applied even when no class
    # stream is drawn -- without this bit a (1, K) affinity would silently
    # no-op.  Unconstrained single-class programs keep constrained=False
    # and stay byte-identical to the historical trace.
    constrained: bool = False


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Scenario:
    """Traced scenario operands -- one grid cell (a registered pytree).

    The user-facing knobs ``rt_rate`` / ``burst_intensity`` are carried for
    reporting, but the scan consumes the *derived* operands ``rt_period``
    and ``lam_hi``/``lam_lo``: those derivations involve host float64
    arithmetic (``round``, the MMPP rate balance), so they are computed
    once at construction -- bit-identical to the historical
    compile-per-cell program -- and traced as ready-made arrays.

    Build cells with :meth:`create` (or ``SimConfig.scenario()``); stack
    cells along a leading axis with :func:`stack_scenarios` to form the
    batched operand :func:`simulate_grid` takes.
    """

    load: jnp.ndarray  # () f32 arrival rate
    x: jnp.ndarray  # () i32 DT-x / ET-x parameter
    rt_rate: jnp.ndarray  # () f32 RT-r rate (reporting; rt_period is used)
    rt_period: jnp.ndarray  # () i32 derived RT period in slots
    burst_intensity: jnp.ndarray  # () f32 MMPP knob (reporting)
    burst_stay: jnp.ndarray  # () f32 MMPP per-slot stay probability
    lam_hi: jnp.ndarray  # () f32 derived MMPP burst-state arrival rate
    lam_lo: jnp.ndarray  # () f32 derived MMPP lull-state arrival rate
    service_rates: jnp.ndarray  # (K,) f32 per-server speeds (ones if unused)
    service: workload_lib.ServiceProcess  # size-distribution operand bundle
    horizon: jnp.ndarray  # () i32 effective slots (>= StaticConfig.slots = unpadded)
    diurnal_amp: jnp.ndarray  # () f32 diurnal curve amplitude (0 = flat)
    diurnal_period: jnp.ndarray  # () f32 diurnal curve period in slots
    # Control-plane operands (all neutral when the static kinds are "none").
    net_delay: jnp.ndarray  # () i32 deterministic delivery delay (slots)
    net_jitter: jnp.ndarray  # () i32 max extra uniform delay (slots)
    net_drop: jnp.ndarray  # () f32 i.i.d. message-drop probability
    suspect_age: jnp.ndarray  # () i32 staleness bound (0 = no suspect masking)
    # Reliable-transport operands (neutral under transport="fire_forget").
    ack_timeout: jnp.ndarray  # () i32 base ack-wait window in slots
    backoff_base: jnp.ndarray  # () f32 timeout multiplier per retransmit
    max_retries: jnp.ndarray  # () i32 retransmits before abandoning
    ka_period: jnp.ndarray  # () i32 server keepalive period (0 = none)
    crash_rate: jnp.ndarray  # () f32 per-slot fault-entry probability
    recover_rate: jnp.ndarray  # () f32 per-slot fault-exit probability
    slow_factor: jnp.ndarray  # () f32 rate multiplier while slowed (fault="slow")
    # Constrained-routing operands (neutral single-class defaults).
    class_mix: jnp.ndarray  # (C,) f32 arrival-class weights
    class_affinity: jnp.ndarray  # (C, K) bool per-class eligible servers

    @staticmethod
    def create(
        servers: int,
        load: float,
        x: int = 3,
        rt_rate: float = 0.01,
        burst_intensity: float = 1.6,
        burst_stay: float = 0.98,
        service_rates: Optional[Sequence[float]] = None,
        mean_service: float = 30,
        service: workload_lib.ServiceKind = "geometric",
        service_tail: float = 2.0,
        horizon: Optional[int] = None,
        diurnal_amp: float = 0.0,
        diurnal_period: float = 1.0,
        arrival: str = "bernoulli",  # diurnal peak-rate validation only
        network: str = "none",  # control-plane operand validation only
        net_delay: int = 0,
        net_jitter: int = 0,
        net_drop: float = 0.0,
        suspect_age: int = 0,
        transport: str = "fire_forget",  # operand validation only
        ack_timeout: int = 0,
        backoff_base: float = 1.0,
        max_retries: int = 0,
        ka_period: int = 0,
        fault: str = "none",  # control-plane operand validation only
        crash_rate: float = 0.0,
        recover_rate: float = 0.0,
        slow_factor: float = 1.0,
        class_mix: Optional[Sequence[float]] = None,
        class_affinity: Optional[Sequence[Sequence[bool]]] = None,
        policy: Optional[str] = None,  # pull-pairing validation only
        comm: Optional[str] = None,  # pull-pairing validation only
    ) -> "Scenario":
        comm_lib.validate_control_plane(
            network=network,
            net_delay=net_delay,
            net_jitter=net_jitter,
            net_drop=net_drop,
            suspect_age=suspect_age,
            transport=transport,
            ack_timeout=ack_timeout,
            backoff_base=backoff_base,
            max_retries=max_retries,
            ka_period=ka_period,
            fault=fault,
            crash_rate=crash_rate,
            recover_rate=recover_rate,
            slow_factor=slow_factor,
            policy=policy,
            comm=comm,
            token_refresh=rt_rate if policy == "hsq" else None,
        )
        if class_affinity is not None and class_mix is None:
            raise ValueError(
                "class_affinity requires class_mix (one weight per class)"
            )
        if class_mix is None:
            mix = jnp.ones((1,), jnp.float32)
            aff = jnp.ones((1, servers), bool)
        else:
            mix_np = np.asarray(class_mix, np.float64)
            if mix_np.ndim != 1 or mix_np.size < 1:
                raise ValueError(
                    f"class_mix must be a 1-D weight vector, got shape "
                    f"{mix_np.shape}"
                )
            if np.any(mix_np < 0) or mix_np.sum() <= 0:
                raise ValueError(
                    "class_mix weights must be >= 0 with a positive sum, "
                    f"got {class_mix}"
                )
            aff_np = (
                np.ones((mix_np.size, servers), bool)
                if class_affinity is None
                else np.asarray(class_affinity, bool)
            )
            if aff_np.shape != (mix_np.size, servers):
                raise ValueError(
                    f"class_affinity must have shape (classes, servers) = "
                    f"({mix_np.size}, {servers}), got {aff_np.shape}"
                )
            if not aff_np.any(axis=1).all():
                empty = int(np.argmin(aff_np.any(axis=1)))
                raise ValueError(
                    f"class_affinity row {empty} has no eligible server; "
                    "every class needs at least one"
                )
            mix = jnp.asarray(mix_np, jnp.float32)
            aff = jnp.asarray(aff_np)
        lam_hi = min(burst_intensity * load, 1.0)
        lam_lo = max(2.0 * load - lam_hi, 0.0)
        period = max(int(round(1.0 / max(rt_rate, 1e-9))), 1)
        rates = (
            jnp.ones((servers,), jnp.float32)
            if service_rates is None
            else jnp.asarray(service_rates, jnp.float32)
        )
        diurnal_amp = float(diurnal_amp)
        if not 0.0 <= diurnal_amp <= 1.0:
            raise ValueError(
                f"diurnal_amp must be in [0, 1] (rate stays non-negative), "
                f"got {diurnal_amp}"
            )
        # The highest *modulated* rate must stay a probability, or the
        # u < rate comparison silently clips the sine peaks and the
        # long-run rate drops below the nominal load.  For mmpp that peak
        # is the burst-state rate, not load.
        base_peak = lam_hi if arrival == "mmpp" else load
        if diurnal_amp and base_peak * (1.0 + diurnal_amp) > 1.0 + 1e-9:
            raise ValueError(
                f"diurnal peak rate {base_peak:.4f}*(1+amp) = "
                f"{base_peak * (1.0 + diurnal_amp):.4f} exceeds 1 "
                f"(arrival={arrival!r}); lower amp to at most "
                f"{1.0 / base_peak - 1.0:.4f}"
            )
        if horizon is None:
            horizon = np.iinfo(np.int32).max  # unbounded: never mask
        return Scenario(
            load=jnp.float32(load),
            x=jnp.int32(x),
            rt_rate=jnp.float32(rt_rate),
            rt_period=jnp.int32(period),
            burst_intensity=jnp.float32(burst_intensity),
            burst_stay=jnp.float32(burst_stay),
            lam_hi=jnp.float32(lam_hi),
            lam_lo=jnp.float32(lam_lo),
            service_rates=rates,
            service=workload_lib.ServiceProcess.create(
                kind=service, mean=mean_service, tail=service_tail
            ),
            horizon=jnp.int32(horizon),
            diurnal_amp=jnp.float32(diurnal_amp),
            diurnal_period=jnp.float32(max(float(diurnal_period), 1e-6)),
            net_delay=jnp.int32(net_delay),
            net_jitter=jnp.int32(net_jitter),
            net_drop=jnp.float32(net_drop),
            suspect_age=jnp.int32(suspect_age),
            ack_timeout=jnp.int32(ack_timeout),
            backoff_base=jnp.float32(backoff_base),
            max_retries=jnp.int32(max_retries),
            ka_period=jnp.int32(ka_period),
            crash_rate=jnp.float32(crash_rate),
            recover_rate=jnp.float32(recover_rate),
            slow_factor=jnp.float32(slow_factor),
            class_mix=mix,
            class_affinity=aff,
        )


def stack_scenarios(scenarios: Sequence[Scenario]) -> Scenario:
    """Stack unbatched cells into one batched Scenario (leading axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *scenarios)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """One grid cell as the user sees it: static structure + scenario knobs.

    ``SimConfig`` is hashable (benchmark caches key on it) and splits into
    the two halves the compiled program takes: :meth:`static_part` (jit
    specialises on it) and :meth:`scenario` (traced operands).

    Scenario knobs beyond the paper's Section 9.1 setting:

    * ``arrival="mmpp"`` with ``burst_intensity`` / ``burst_stay`` switches
      to bursty Markov-modulated arrivals (long-run rate still ``load``).
    * ``service`` selects the job-size distribution kind (``geometric`` --
      the paper's default -- ``deterministic``, ``pareto``, ``weibull``;
      see :class:`~repro.core.care.workload.ServiceProcess`) with traced
      ``mean_service`` / ``service_tail`` operands.
    * ``diurnal_amp`` / ``diurnal_period`` modulate the arrival rate with
      a sinusoidal load curve; the long-run rate stays ``load``, which
      requires ``load * (1 + amp) <= 1`` (validated at construction --
      otherwise the Bernoulli clip would shave the peaks).  amp 0 = flat.
    * ``service_rates`` (length-``servers`` tuple) gives each server a speed
      in work units/slot; ``rate_aware=True`` makes the shortest-queue
      family minimise the expected drain time ``q_i * E[S] / r_i`` instead
      of the raw queue length.
    * ``comm="et_rt"`` enables the hybrid ET-x trigger with an RT fallback
      every ``1/rt_rate`` slots (staleness cap in light traffic).
    * ``max_slots`` pads the scan to a longer fixed horizon than ``slots``
      so cells with different effective horizons share one compiled
      program (see the module docstring); ``None`` means unpadded.
    """

    servers: int = 30
    slots: int = 100_000
    load: float = 0.95
    # Mean job size in slots; the paper uses Geometric(1/K) i.e. mean == K.
    mean_service: int = 30
    policy: routing_lib.PolicyKind = "jsaq"
    comm: CommKind = "et"
    x: int = 3  # DT-x / ET-x parameter (max tolerated error is x-1).
    rt_rate: float = 0.01  # RT-r per-server message rate (messages/slot).
    approx: approx_lib.ApproxKind = "msr"
    buffer_cap: int = 2048  # per-server FIFO capacity (power of two).
    sqd: int = 2
    # Scenario layer (see module docstring / workload.py).
    arrival: str = "bernoulli"  # "bernoulli" | "mmpp"
    burst_intensity: float = 1.6
    burst_stay: float = 0.98
    service_rates: Optional[Tuple[float, ...]] = None
    rate_aware: bool = True
    service: workload_lib.ServiceKind = "geometric"
    service_tail: float = 2.0  # pareto alpha / weibull shape
    diurnal_amp: float = 0.0
    diurnal_period: float = 1.0
    max_slots: Optional[int] = None  # padded scan length (>= slots)
    route_backend: str = "dense"  # "dense" | "pallas" (see StaticConfig)
    deterministic_ties: bool = False
    # Control plane (fault-injection layer; see StaticConfig / comm.py).
    network: str = "none"  # "none" | "net"
    net_delay: int = 0
    net_jitter: int = 0
    net_drop: float = 0.0
    suspect_age: int = 0  # staleness bound in slots (0 = no suspect masking)
    # Reliable transport (see comm.NetworkConfig): transport="ack" turns
    # every data send into an ack'd transmission with a timeout/retransmit
    # window; the four operands below are traced (one compiled program per
    # delay x drop x timeout ladder).
    transport: str = "fire_forget"  # "fire_forget" | "ack"
    ack_timeout: int = 0  # base ack-wait window in slots (>= 1 under ack)
    backoff_base: float = 1.0  # timeout multiplier per retransmit (>= 1)
    max_retries: int = 0  # retransmits before abandoning the update
    ka_period: int = 0  # server keepalive period in slots (0 = none)
    fault: str = "none"  # "none" | "crash" | "slow"
    crash_rate: float = 0.0
    recover_rate: float = 0.0
    slow_factor: float = 1.0
    net_delay_cap: int = 32  # stale-view ring capacity (static shape)
    # Constrained routing: per-class arrival weights and per-class server
    # affinity masks (rows must each keep >= 1 eligible server).  The mix
    # is a traced operand; only the class count C is structural.
    class_mix: Optional[Tuple[float, ...]] = None
    class_affinity: Optional[Tuple[Tuple[bool, ...], ...]] = None

    def static_part(self) -> StaticConfig:
        if self.max_slots is not None and self.max_slots < self.slots:
            raise ValueError(
                f"max_slots ({self.max_slots}) must be >= slots ({self.slots})"
            )
        if self.comm == "exact" and self.network != "none":
            raise ValueError(
                "comm='exact' cannot run through the network model: its "
                "per-departure message accounting (Prop 6.1) assumes "
                "instant delivery -- use comm='dt' with x=1 for a "
                "near-exact pattern under network='net'"
            )
        return StaticConfig(
            servers=self.servers,
            slots=self.max_slots if self.max_slots is not None else self.slots,
            policy=self.policy,
            comm=self.comm,
            approx=self.approx,
            buffer_cap=self.buffer_cap,
            sqd=self.sqd,
            arrival=self.arrival,
            service=self.service,
            use_rates=self.service_rates is not None,
            rate_aware=self.rate_aware,
            route_backend=self.route_backend,
            deterministic_ties=self.deterministic_ties,
            network=self.network,
            transport=self.transport,
            fault=self.fault,
            net_delay_cap=self.net_delay_cap,
            classes=(
                len(self.class_mix) if self.class_mix is not None else 1
            ),
            constrained=self.class_affinity is not None,
        )

    def scenario(self) -> Scenario:
        return Scenario.create(
            servers=self.servers,
            load=self.load,
            x=self.x,
            rt_rate=self.rt_rate,
            burst_intensity=self.burst_intensity,
            burst_stay=self.burst_stay,
            service_rates=self.service_rates,
            mean_service=self.mean_service,
            service=self.service,
            service_tail=self.service_tail,
            horizon=self.slots,
            diurnal_amp=self.diurnal_amp,
            diurnal_period=self.diurnal_period,
            arrival=self.arrival,
            network=self.network,
            net_delay=self.net_delay,
            net_jitter=self.net_jitter,
            net_drop=self.net_drop,
            suspect_age=self.suspect_age,
            transport=self.transport,
            ack_timeout=self.ack_timeout,
            backoff_base=self.backoff_base,
            max_retries=self.max_retries,
            ka_period=self.ka_period,
            fault=self.fault,
            crash_rate=self.crash_rate,
            recover_rate=self.recover_rate,
            slow_factor=self.slow_factor,
            class_mix=self.class_mix,
            class_affinity=self.class_affinity,
            policy=self.policy,
            comm=self.comm,
        )


@dataclasses.dataclass
class SimResult:
    """Simulation outputs (host-side numpy)."""

    jct: np.ndarray  # (num_jobs,) job completion times in slots (>=1)
    arrivals: int  # admitted arrivals (offered minus dropped)
    departures: int
    messages: int
    max_aq: int  # sup_t AQ(t) observed at slot ends
    max_queue: int
    overflow: bool  # any arrival dropped on a full FIFO
    per_server_arrivals: np.ndarray  # (K,)
    final_q: np.ndarray  # (K,)
    # messages per departure; the exact-state baseline is 1 (Prop 6.1).
    msgs_per_departure: float = 0.0
    queue_gap_sup: int = 0  # sup_t max_ij |Q_i - Q_j| (for SSC experiments)
    dropped: int = 0  # arrivals rejected because the FIFO was full
    net_drops: int = 0  # messages lost in flight (network="net")
    retrans: int = 0  # data retransmits (transport="ack"; zero otherwise)
    # Pull-policy counters (jiq / hsq; zero otherwise).
    token_misses: int = 0  # arrivals routed with an empty token pool
    token_sum: int = 0  # sum over active slots of end-of-slot pool size


@dataclasses.dataclass
class _Carry:
    q_true: jnp.ndarray  # (K,) true queue lengths
    head_rem: jnp.ndarray  # (K,) remaining slots of in-service job
    buf_jid: jnp.ndarray  # (K, B) circular FIFO of job ids (arrival slots)
    head_ptr: jnp.ndarray  # (K,) FIFO head index
    emu: approx_lib.EmuState
    comm: comm_lib.CommState  # shared trigger bookkeeping + message total
    rr_ptr: jnp.ndarray  # () round-robin pointer
    deps: jnp.ndarray  # () total departures
    arrs: jnp.ndarray  # () total admitted arrivals
    dropped: jnp.ndarray  # () arrivals rejected on a full FIFO
    per_srv: jnp.ndarray  # (K,) arrivals per server
    max_aq: jnp.ndarray  # () running sup of end-of-slot AQ
    max_q: jnp.ndarray  # () running sup of max queue length
    gap_sup: jnp.ndarray  # () running sup of max_ij |Q_i - Q_j|
    # Control-plane state; None (an empty pytree subtree) whenever the
    # corresponding static kind is off, so the "none" carry structure --
    # and therefore the compiled program -- is unchanged.
    fault_state: Optional[jnp.ndarray] = None  # (K,) bool servers faulted
    # In-flight message buffer: NetState under transport="fire_forget",
    # AckNetState under "ack" (the static transport kind picks the subtree).
    net: Optional[object] = None
    q_hist: Optional[jnp.ndarray] = None  # (cap, K) stale true-state ring
    # Pull-policy state (None unless policy is jiq/hsq): the balancer-side
    # token pool plus its counters.
    tokens: Optional[jnp.ndarray] = None  # (K,) i32 balancer token pool
    token_miss: Optional[jnp.ndarray] = None  # () i32 empty-pool routings
    token_sum: Optional[jnp.ndarray] = None  # () i32 summed pool occupancy


jax.tree_util.register_dataclass(
    _Carry, data_fields=[f.name for f in dataclasses.fields(_Carry)], meta_fields=[]
)


def _prep(key: jax.Array, static: StaticConfig, scn: Scenario):
    """Draw the replayable workload: (arrive, sizes, slot_keys, active)
    plus per-slot network / fault key streams when those kinds are on.

    Fully traceable in the scenario operands (the arrival and service
    *kinds* alone are static), so a grid of cells shares one compiled
    workload generator.  The arrival rate is modulated by the diurnal
    curve (``1 + amp * sin``; exactly 1.0 when ``amp == 0``) and masked by
    the traced ``horizon``: slots at ``t >= horizon`` never see an arrival
    and are frozen by the scan body (``active`` mask).
    """
    k_arr, k_size, k_scan = jax.random.split(key, 3)
    t = static.slots
    t_idx = jnp.arange(t, dtype=jnp.int32)
    mod = workload_lib.diurnal_modulation(
        t_idx, scn.diurnal_amp, scn.diurnal_period
    )
    if static.arrival == "mmpp":
        arrive = workload_lib.mmpp_arrivals_from_rates(
            k_arr, t, scn.lam_hi, scn.lam_lo, scn.burst_stay, mod=mod
        )
    else:
        arrive = workload_lib.bernoulli_arrivals(k_arr, t, scn.load, mod=mod)
    active = t_idx < scn.horizon
    arrive = arrive & active
    sizes = workload_lib.service_sizes(k_size, t, scn.service)
    slot_keys = jax.random.split(k_scan, t)
    out = (arrive, sizes, slot_keys, active)
    # Class / control-plane randomness comes from fold_in-derived side
    # streams so the three historical children of `key` -- and therefore
    # the whole single-class "none"-kind sample path -- stay byte-stable.
    if static.classes > 1:
        out += (
            workload_lib.arrival_classes(
                jax.random.fold_in(key, 13), t, scn.class_mix
            ),
        )
    if static.network != "none":
        out += (jax.random.split(jax.random.fold_in(key, 7), t),)
    if static.fault != "none":
        out += (jax.random.split(jax.random.fold_in(key, 11), t),)
    return out


def _sim_core(
    arrive, sizes, slot_keys, active, static: StaticConfig, scn: Scenario,
    net_keys=None, fault_keys=None, classes=None,
):
    """One full slotted run as a lax.scan; traceable (also under vmap).

    ``static`` selects code paths (Python ``if`` on kinds); every numeric
    scenario knob enters as a traced operand of ``scn``.  ``active`` is
    the per-slot horizon mask: on inactive slots every carry field is
    frozen (no service, no emulation drain, no trigger evaluation), so a
    padded scan produces exactly the state a shorter scan would leave
    behind.
    """
    k = static.servers
    b = static.buffer_cap
    if scn.service.kind != static.service:
        raise ValueError(
            f"Scenario service kind {scn.service.kind!r} does not match "
            f"StaticConfig.service {static.service!r}"
        )
    acfg = approx_lib.ApproxConfig(
        kind=static.approx, msr_slots=scn.service.msr_slots, x=scn.x
    )
    ccfg = comm_lib.CommConfig(
        kind=static.comm, x=scn.x, rt_period=scn.rt_period
    )
    has_net = static.network != "none"
    has_ack = has_net and static.transport == "ack"
    has_fault = static.fault != "none"
    has_cls = static.classes > 1
    has_pull = static.policy in routing_lib.PULL_POLICIES
    if has_pull and static.comm != static.policy:
        raise ValueError(
            f"policy={static.policy!r} requires comm={static.policy!r} "
            f"(its token channel), got comm={static.comm!r}"
        )
    if static.comm in comm_lib.PULL_KINDS and not has_pull:
        raise ValueError(
            f"comm={static.comm!r} is the token channel of "
            f"policy={static.comm!r}, got policy={static.policy!r}"
        )
    if has_net and static.comm == "exact":
        raise ValueError(
            "comm='exact' cannot run through the network model: its "
            "per-departure message accounting (Prop 6.1) assumes instant "
            "delivery -- use comm='dt' with x=1 under network='net'"
        )
    if has_ack:
        ncfg = comm_lib.NetworkConfig(
            kind=static.network,
            delay=scn.net_delay,
            jitter=scn.net_jitter,
            drop=scn.net_drop,
            transport="ack",
            ack_timeout=scn.ack_timeout,
            backoff_base=scn.backoff_base,
            max_retries=scn.max_retries,
            ka_period=scn.ka_period,
        )
    elif has_net:
        ncfg = comm_lib.NetworkConfig(
            kind=static.network,
            delay=scn.net_delay,
            jitter=scn.net_jitter,
            drop=scn.net_drop,
        )
    else:
        ncfg = None
    # Under a modeled network the query policies route on *stale* true
    # state: the 2d SQ(d) probes (and JSQ's state feed) suffer the same
    # delivery delay as push messages, read from a ring of end-of-slot
    # queue snapshots.  Delay 0 reads the previous slot's end state ==
    # this slot's pre-route state, bit-identical to the instant path.
    stale_ring = has_net and static.policy in ("jsq", "sq2", "sqd")
    cap = static.net_delay_cap
    if static.use_rates:
        rates = scn.service_rates
        # Expected per-job drain time E[S]/r_i in slots, precomputed once
        # outside the scan: both the mean and the rates are traced.  The
        # formula lives in routing.py so the serving tier's drain-time
        # policy cannot drift from this one.
        drain_slots = (
            routing_lib.expected_drain_slots(scn.service.mean, rates)
            if static.rate_aware
            else None
        )
    else:
        rates = None
        drain_slots = None

    def slot(c: _Carry, xs):
        arr, size, jid, skey, act = xs[:5]
        rest = xs[5:]
        ri = 0
        if has_cls:
            cls_t = rest[ri]
            ri += 1
        else:
            cls_t = None
        nkey = rest[ri] if has_net else None
        fkey = rest[-1] if has_fault else None

        # --- 0. fault transitions -------------------------------------
        # The server fault chain advances first: this slot's service (and
        # trigger suppression) sees this slot's fault state, matching the
        # numpy serving reference.  Frozen past the horizon.
        if has_fault:
            fault_u = jax.random.uniform(fkey, (k,), jnp.float32)
            faulted, recovered = workload_lib.fault_transitions(
                c.fault_state, fault_u, scn.crash_rate, scn.recover_rate
            )
            faulted = jnp.where(act, faulted, c.fault_state)
            recovered = recovered & act
        else:
            faulted = recovered = None

        # --- 1. arrival & routing -------------------------------------
        if stale_ring:
            hist_idx = jid - 1 - scn.net_delay
            q_route = jnp.where(hist_idx >= 0, c.q_hist[hist_idx % cap], 0)
        else:
            q_route = c.q_true
        if has_ack:
            # Under the ack transport suspect masking is keepalive-driven:
            # the balancer reads its last-heard clock (reset by any data
            # *or* keepalive delivery), and a server that abandoned an
            # update after max_retries is a self-suspect regardless of
            # age.  An all-suspect fleet falls back to all-healthy -- the
            # balancer must route somewhere.
            h = (
                (scn.suspect_age <= 0) | (c.net.ka_age <= scn.suspect_age)
            ) & ((scn.suspect_age <= 0) | ~c.net.gave_up)
            healthy = jnp.where(jnp.any(h), h, True)
        elif has_net or has_fault:
            # Staleness timeout: a server whose last delivered update is
            # older than suspect_age is suspect and excluded from the
            # shortest-queue candidate set (suspect_age 0 disables -- the
            # all-True mask is decision-identical to no mask).  Without a
            # network model delivery is instant, so the trigger counter
            # slots_since_msg *is* the update age.
            age = c.net.age if has_net else c.comm.slots_since_msg
            healthy = (scn.suspect_age <= 0) | (age <= scn.suspect_age)
        else:
            healthy = None
        if has_cls or static.constrained:
            # Per-class affinity constrains the candidate set; composed
            # with the suspect mask, an empty intersection falls back to
            # the affinity set alone (the SLA constraint is hard, the
            # staleness heuristic is soft) -- mirroring the SQ(d)-subset
            # fallback of the serving tier.  With a single constrained
            # class there is no class stream: every arrival reads row 0.
            aff = scn.class_affinity[cls_t if has_cls else 0]
            if healthy is not None:
                both = aff & healthy
                mask = jnp.where(jnp.any(both), both, aff)
            else:
                mask = aff
        else:
            mask = healthy
        server, rr_ptr = routing_lib.route(
            static.policy, q_route, c.emu.q_app, c.rr_ptr, skey,
            d=static.sqd, drain_slots=drain_slots,
            deterministic=static.deterministic_ties,
            mask=mask, tokens=c.tokens,
        )
        # Dense one-hot arithmetic instead of scalar gathers / scatters /
        # conds: under vmap those lower to serial per-batch-element loops
        # (or both-branch selects), which destroys the batched-scan
        # throughput; elementwise (K,) ops stay fully vectorised.
        onehot = jnp.arange(k, dtype=jnp.int32) == server
        if has_pull:
            # The balancer spends one token on every routed arrival (it
            # cannot see FIFO drops); an empty selected pool is a token
            # miss -- the uniform-random fallback path.
            tok_sel = jnp.sum(jnp.where(onehot, c.tokens, 0))
            token_miss = c.token_miss + (arr & (tok_sel == 0)).astype(
                jnp.int32
            )
            tokens = jnp.maximum(
                c.tokens - (onehot & arr).astype(jnp.int32), 0
            )
        else:
            token_miss = c.token_miss
            tokens = c.tokens
        q_sel = jnp.sum(jnp.where(onehot, c.q_true, 0))
        # A full FIFO drops the arrival (counted) rather than letting the
        # tail wrap onto the live head entry.
        admit = arr & (q_sel < b)
        dropped = c.dropped + (arr & ~admit).astype(jnp.int32)
        sel = onehot & admit
        head_sel = jnp.sum(jnp.where(onehot, c.head_ptr, 0))
        tail = (head_sel + q_sel) % b
        # Masked one-element scatter (the ring itself still needs indexing).
        buf_jid = c.buf_jid.at[server, tail].set(
            jnp.where(admit, jid, c.buf_jid[server, tail])
        )
        q_true = c.q_true + sel.astype(jnp.int32)
        head_rem = jnp.where(sel & (c.q_true == 0), size, c.head_rem)
        emu = approx_lib.emu_arrival_masked(c.emu, sel, acfg)
        arrs = c.arrs + admit.astype(jnp.int32)
        per_srv = c.per_srv + sel.astype(jnp.int32)

        # --- 2. service ------------------------------------------------
        # Past the cell's horizon (act False) nothing serves: the mask
        # freezes head_rem / q_true / deps exactly where the horizon left
        # them.  `act & True` is the identity, so unpadded runs are
        # bit-identical to the historical unmasked program.
        busy = (q_true > 0) & act
        if rates is None:
            units = None
            if has_fault:
                eff_units = workload_lib.faulted_service_units(
                    jid, faulted, jnp.ones((k,), jnp.int32),
                    static.fault, scn.slow_factor,
                )
                head_rem = jnp.where(busy, head_rem - eff_units, head_rem)
            else:
                head_rem = jnp.where(busy, head_rem - 1, head_rem)
        else:
            units = workload_lib.service_units(jid, rates)
            if has_fault:
                eff_units = workload_lib.faulted_service_units(
                    jid, faulted, units, static.fault, scn.slow_factor,
                    rates=rates,
                )
            else:
                eff_units = units
            head_rem = jnp.where(busy, head_rem - eff_units, head_rem)
        dep = busy & (head_rem <= 0)
        departed_jid = jnp.where(
            dep, buf_jid[jnp.arange(k), c.head_ptr % b], -1
        )
        q_true = jnp.where(dep, q_true - 1, q_true)
        head_ptr = jnp.where(dep, c.head_ptr + 1, c.head_ptr)
        # Promote the next job (if any) into service with its true size.
        next_jid = buf_jid[jnp.arange(k), head_ptr % b]
        next_size = sizes[jnp.clip(next_jid, 0, sizes.shape[0] - 1)]
        head_rem = jnp.where(dep & (q_true > 0), next_size, head_rem)
        deps = c.deps + jnp.sum(dep, dtype=jnp.int32)

        # --- 3. emulation drain -----------------------------------------
        emu = approx_lib.emu_drain_slot(emu, acfg, units=units, active=act)

        # --- 4/5. communication trigger (shared core, comm.py) ----------
        # The trigger counters (slots_since_msg in particular) must freeze
        # past the horizon, or RT/ET+RT cells would keep messaging through
        # the padding; evaluate unconditionally, then select the advanced
        # state only on active slots (the identity when act is True).
        err = approx_lib.approximation_error(emu, q_true)
        # Crashed servers cannot send (their counters keep advancing, so
        # the first healthy slot re-fires); a recovery force-sends a
        # resync.  The emulation keeps draining with *nominal* units --
        # the balancer is fault-unaware, so a crash or slowdown grows the
        # error until the trigger or the staleness timeout reacts.
        if has_fault and static.fault == "crash":
            can_send, force = ~faulted, recovered
        else:
            can_send = force = None
        triggered, comm_adv = comm_lib.evaluate(
            c.comm, ccfg, err, dep.astype(jnp.int32),
            can_send=can_send, force=force, q=q_true,
            count_msgs=not has_net,
        )
        triggered = triggered & act
        if has_ack:
            # The ack/keepalive channels draw from a third child of the
            # per-slot net key, so the fire_forget two-way split -- and
            # with it every pre-existing sample path -- stays byte-stable.
            kd, kj, ka = jax.random.split(nkey, 3)
            delivered, payload, sent, net_adv = comm_lib.net_step_ack(
                c.net, ncfg, triggered, q_true,
                jax.random.uniform(kd, (k,), jnp.float32),
                jax.random.uniform(kj, (k,), jnp.float32),
                jax.random.uniform(ka, (4, k), jnp.float32),
                can_send=can_send,
            )
        elif has_net:
            # can_send wipes a crashed server's queued piggyback so it
            # cannot send its pre-crash snapshot at the next free slot --
            # the recovery resync (force) is the re-announcement path.
            kd, kj = jax.random.split(nkey)
            delivered, payload, sent, net_adv = comm_lib.net_step(
                c.net, ncfg, triggered, q_true,
                jax.random.uniform(kd, (k,), jnp.float32),
                jax.random.uniform(kj, (k,), jnp.float32),
                can_send=can_send,
            )
        if has_net:
            delivered = delivered & act
            net_state = jax.tree.map(
                lambda adv, old: jnp.where(act, adv, old), net_adv, c.net
            )
            # net_step owns wire accounting (piggybacking batches queued
            # triggers into one send).
            comm_adv = comm_lib.CommState(
                deps_since_msg=comm_adv.deps_since_msg,
                slots_since_msg=comm_adv.slots_since_msg,
                msgs=comm_adv.msgs + jnp.where(act, sent, 0),
            )
            snap_mask, snap_payload = delivered, payload
        else:
            net_state = c.net
            snap_mask, snap_payload = triggered, q_true
        if has_net and static.policy in ("sq2", "sqd"):
            # SQ(d)'s query implementation costs 2d messages per offered
            # arrival (d probes + d replies), now counted as real traffic
            # on the same axis as the push-based schemes.  The probes ride
            # the same network: their staleness is the q_hist ring above
            # (they are not subject to loss -- a query that must be
            # re-issued would stall the arrival, so d is effectively the
            # retry budget).
            d_q = 2 if static.policy == "sq2" else static.sqd
            comm_adv = comm_lib.CommState(
                deps_since_msg=comm_adv.deps_since_msg,
                slots_since_msg=comm_adv.slots_since_msg,
                msgs=comm_adv.msgs + 2 * d_q * arr.astype(jnp.int32),
            )
        comm_state = jax.tree.map(
            lambda adv, old: jnp.where(act, adv, old), comm_adv, c.comm
        )
        emu = approx_lib.emu_message_reset(emu, snap_payload, snap_mask, acfg)
        if has_pull:
            # A delivered token message overwrites that server's pool
            # entry from the queue snapshot it carried: 1 iff idle for
            # JIQ, the headroom below the threshold for hsq.  Stale
            # tokens of a crashed server are spent and never refreshed,
            # which is what bounds its misroutes.
            if static.comm == "jiq":
                fresh = (snap_payload == 0).astype(jnp.int32)
            else:  # hsq
                fresh = jnp.maximum(scn.x - snap_payload, 0).astype(
                    jnp.int32
                )
            tokens = jnp.where(snap_mask, fresh, tokens)
            token_sum = c.token_sum + jnp.where(
                act, jnp.sum(tokens), 0
            ).astype(jnp.int32)
        else:
            token_sum = c.token_sum

        # --- 6. metrics ---------------------------------------------------
        if stale_ring:
            q_hist = c.q_hist.at[jid % cap].set(
                jnp.where(act, q_true, c.q_hist[jid % cap])
            )
        else:
            q_hist = c.q_hist
        aq = jnp.max(jnp.abs(q_true - emu.q_app))
        gap = jnp.max(q_true) - jnp.min(q_true)
        carry = _Carry(
            q_true=q_true,
            head_rem=head_rem,
            buf_jid=buf_jid,
            head_ptr=head_ptr,
            emu=emu,
            comm=comm_state,
            rr_ptr=rr_ptr,
            deps=deps,
            arrs=arrs,
            dropped=dropped,
            per_srv=per_srv,
            max_aq=jnp.maximum(c.max_aq, aq),
            max_q=jnp.maximum(c.max_q, jnp.max(q_true)),
            gap_sup=jnp.maximum(c.gap_sup, gap),
            fault_state=faulted,
            net=net_state,
            q_hist=q_hist,
            tokens=tokens,
            token_miss=token_miss,
            token_sum=token_sum,
        )
        return carry, departed_jid

    t = arrive.shape[0]
    init = _Carry(
        q_true=jnp.zeros((k,), jnp.int32),
        head_rem=jnp.zeros((k,), jnp.int32),
        buf_jid=jnp.full((k, b), -1, jnp.int32),
        head_ptr=jnp.zeros((k,), jnp.int32),
        emu=approx_lib.EmuState.init(jnp.zeros((k,), jnp.int32), acfg),
        comm=comm_lib.CommState.init(k),
        rr_ptr=jnp.zeros((), jnp.int32),
        deps=jnp.zeros((), jnp.int32),
        arrs=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
        per_srv=jnp.zeros((k,), jnp.int32),
        max_aq=jnp.zeros((), jnp.int32),
        max_q=jnp.zeros((), jnp.int32),
        gap_sup=jnp.zeros((), jnp.int32),
        fault_state=jnp.zeros((k,), bool) if has_fault else None,
        net=(
            (comm_lib.AckNetState.init(k) if has_ack else comm_lib.NetState.init(k))
            if has_net
            else None
        ),
        q_hist=jnp.zeros((cap, k), jnp.int32) if stale_ring else None,
        tokens=jnp.zeros((k,), jnp.int32) if has_pull else None,
        token_miss=jnp.zeros((), jnp.int32) if has_pull else None,
        token_sum=jnp.zeros((), jnp.int32) if has_pull else None,
    )
    xs = (arrive, sizes, jnp.arange(t, dtype=jnp.int32), slot_keys, active)
    if has_cls:
        xs += (classes,)
    if has_net:
        xs += (net_keys,)
    if has_fault:
        xs += (fault_keys,)
    final, departed = jax.lax.scan(slot, init, xs)

    # completion slot per job id (-1 if never completed).
    comp_slot = jnp.full((t,), -1, jnp.int32)
    slot_idx = jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[:, None], departed.shape
    )
    valid = departed >= 0
    comp_slot = comp_slot.at[jnp.where(valid, departed, 0)].max(
        jnp.where(valid, slot_idx, -1)
    )
    out = (
        comp_slot,
        final.comm.msgs,
        final.deps,
        final.arrs,
        final.max_aq,
        final.max_q,
        final.per_srv,
        final.q_true,
        final.dropped,
        final.gap_sup,
        final.net.drops if has_net else jnp.zeros((), jnp.int32),
        final.token_miss if has_pull else jnp.zeros((), jnp.int32),
        final.token_sum if has_pull else jnp.zeros((), jnp.int32),
    )
    if has_ack:
        # Appended only under transport="ack" so every fire_forget
        # program keeps its historical output arity (byte-identical).
        out = out + (final.net.retrans,)
    return out


def _run_one(key, scn: Scenario, static: StaticConfig):
    """Workload draw + scan for one (key, scenario) pair; vmap-able."""
    prep = _prep(key, static, scn)
    arrive, sizes, slot_keys, act = prep[:4]
    rest = list(prep[4:])
    classes = rest.pop(0) if static.classes > 1 else None
    net_keys = rest.pop(0) if static.network != "none" else None
    fault_keys = rest.pop(0) if static.fault != "none" else None
    return (arrive,) + _sim_core(
        arrive, sizes, slot_keys, act, static, scn,
        net_keys=net_keys, fault_keys=fault_keys, classes=classes,
    )


_simulate_jit = jax.jit(_run_one, static_argnums=(2,))


_GRID_PROGRAMS: list = []  # jitted grid wrappers, one per (static, n_dev)


@functools.lru_cache(maxsize=None)
def _grid_fn(static: StaticConfig, n_dev: int):
    """The one compiled program for a whole grid: vmap inside shard_map.

    Cached per (StaticConfig, device count) -- the device count is part of
    the key so an in-process topology change can never reuse a mesh built
    for a different shard count.  ``n_dev == 1`` skips the mesh entirely
    (plain jitted vmap), which is also the path `shard=False` forces.
    """
    batched = jax.vmap(lambda key, scn: _run_one(key, scn, static))
    if n_dev <= 1:
        fn = jax.jit(batched)
    else:
        mesh = Mesh(np.asarray(jax.local_devices()[:n_dev]), ("runs",))
        fn = jax.jit(shard_map(
            batched, mesh=mesh, in_specs=(P("runs"), P("runs")),
            out_specs=P("runs"),
        ))
    _GRID_PROGRAMS.append(fn)
    return fn


def grid_compile_count() -> int:
    """Total XLA programs compiled by the grid path so far.

    Sums the compiled-shape cache sizes of every (StaticConfig,
    device-count) jitted wrapper: re-invoking a cached wrapper with a new
    flattened batch length retraces and compiles a fresh executable, and
    that counts too -- this is real compile work, not wrapper
    instantiations.
    """
    # _cache_size is a private jax API (present on the pinned 0.4.x); a
    # future jax that drops it degrades to counting wrapper instantiations
    # rather than breaking every quick-mode benchmark run.
    return sum(
        getattr(f, "_cache_size", lambda: 1)() for f in _GRID_PROGRAMS
    )


def _check_pallas_static(static: StaticConfig) -> None:
    """Validate a StaticConfig against the fused kernel's restrictions.

    The mean-field kernel (``kernels/jsaq_route.care_route_pallas``)
    carries all per-server state as in-kernel loop carries and no per-job
    FIFO ring, which pins the modelling corner it reproduces exactly:
    shortest-queue routing with lowest-index ties, MSR emulation, and
    deterministic (mean-sized) jobs at unit rates -- the regime of the
    paper's mean-field / diffusion limits.  Anything else must use the
    dense reference backend.
    """
    if static.policy not in ("jsq", "jsaq"):
        raise ValueError(
            f"route_backend='pallas' supports policies 'jsq'/'jsaq', got "
            f"{static.policy!r}"
        )
    if static.approx != "msr":
        raise ValueError(
            f"route_backend='pallas' requires approx='msr', got "
            f"{static.approx!r}"
        )
    if static.service != "deterministic":
        raise ValueError(
            f"route_backend='pallas' requires service='deterministic' "
            f"(per-job sizes live in a FIFO ring the kernel does not "
            f"carry), got {static.service!r}"
        )
    if static.use_rates:
        raise ValueError(
            "route_backend='pallas' requires homogeneous unit service rates"
        )
    if not static.deterministic_ties:
        raise ValueError(
            "route_backend='pallas' requires deterministic_ties=True (the "
            "kernel breaks ties to the lowest index)"
        )
    if static.network != "none" or static.fault != "none":
        raise NotImplementedError(
            f"route_backend='pallas' does not implement the fault-injection "
            f"control plane (network={static.network!r}, "
            f"fault={static.fault!r}): care_route_pallas carries no "
            f"in-flight message buffer or fault state and would silently "
            f"compute instant-delivery, fault-free results -- use "
            f"route_backend='dense'"
        )
    if static.classes > 1 or static.constrained:
        raise NotImplementedError(
            f"route_backend='pallas' does not implement constrained "
            f"routing (classes={static.classes}, "
            f"constrained={static.constrained}): the kernel carries no "
            f"per-class affinity masks -- use route_backend='dense'"
        )


@functools.lru_cache(maxsize=None)
def _pallas_grid_fn(static: StaticConfig):
    """The one compiled program for a pallas-backend grid.

    The batched ``_prep`` (plain jnp -- identical workload stream to the
    dense backend, since only ``k_arr`` of the per-run key split feeds the
    arrival draw) builds the (N, T) arrival matrix, and a single
    ``care_route_pallas`` call advances every run as one kernel domain --
    the flattened run axis *is* the kernel's native domain axis, so no
    vmap-of-pallas is involved.  Output tuple matches ``_run_one`` so
    ``_finalize``/:class:`SimResult` are shared; ``comp_slot`` is all -1
    (per-job completion tracking needs the FIFO ring the mean-field
    kernel deliberately drops, so JCT metrics are empty at this scale).
    """
    from repro.kernels import ops as kernel_ops

    def run(keys, scn):
        arrive, _sizes, _keys, _active = jax.vmap(
            lambda k, s: _prep(k, static, s)
        )(keys, scn)
        params = jnp.stack(
            [
                scn.x.astype(jnp.int32),
                scn.rt_period.astype(jnp.int32),
                scn.service.msr_slots.astype(jnp.int32),
                scn.horizon.astype(jnp.int32),
            ],
            axis=1,
        )
        _routed, q_final, per_srv, stats = kernel_ops.care_route(
            arrive.astype(jnp.int32),
            params,
            servers=static.servers,
            cap=static.buffer_cap,
            policy=static.policy,
            comm=static.comm,
        )
        n, t = arrive.shape
        comp_slot = jnp.full((n, t), -1, jnp.int32)
        return (
            arrive,
            comp_slot,
            stats[:, 0],  # msgs
            stats[:, 1],  # deps
            stats[:, 2],  # arrs
            stats[:, 4],  # max_aq
            stats[:, 5],  # max_q
            per_srv,
            q_final,
            stats[:, 3],  # dropped
            stats[:, 6],  # gap_sup
            jnp.zeros((n,), jnp.int32),  # net_drops (no network model)
            jnp.zeros((n,), jnp.int32),  # token_misses (no pull policies)
            jnp.zeros((n,), jnp.int32),  # token_sum
        )

    fn = jax.jit(run)
    _GRID_PROGRAMS.append(fn)
    return fn


def _pad_indices(n: int, n_dev: int) -> np.ndarray:
    """Gather indices padding ``n`` runs up to a multiple of ``n_dev``.

    The pad entries re-run existing cells (wrap-around), so a ragged batch
    shards across *all* devices instead of falling back to one; the caller
    drops outputs beyond ``n``.  Handles ``n < n_dev`` too.
    """
    n_pad = ((n + n_dev - 1) // n_dev) * n_dev
    return np.arange(n_pad) % n


def _as_keys(keys: jax.Array | Sequence[int]) -> jax.Array:
    if isinstance(keys, jax.Array):
        return keys
    return jnp.stack([jax.random.key(int(s)) for s in keys])


def _check_diurnal_peak(static: StaticConfig, scn: Scenario) -> None:
    """Reject diurnal amplitudes whose *modulated* peak rate exceeds 1.

    ``Scenario.create`` already validates when told the arrival kind, but
    a hand-built Scenario meets its StaticConfig for the first time here
    (the host-level entry points; inside the traced core the operands are
    tracers and cannot be checked).  For mmpp the binding peak is the
    burst-state rate ``lam_hi``, not ``load``; a clipped peak would
    silently drop the long-run rate below nominal.
    """
    amp = np.asarray(scn.diurnal_amp)
    peak = np.asarray(scn.lam_hi if static.arrival == "mmpp" else scn.load)
    bad = (amp > 0) & (peak * (1.0 + amp) > 1.0 + 1e-6)
    if np.any(bad):
        raise ValueError(
            f"diurnal peak rate exceeds 1 for {int(np.sum(bad))} cell(s) "
            f"(arrival={static.arrival!r}: peak rate "
            f"{'lam_hi' if static.arrival == 'mmpp' else 'load'} * (1+amp) "
            f"must stay a probability)"
        )


def _check_control_plane(static: StaticConfig, scn: Scenario) -> None:
    """Validate network/fault operands against their static kinds.

    ``Scenario.create`` already validates when told the kinds, but a
    hand-built Scenario meets its StaticConfig for the first time here
    (host-level entry points; inside the traced core the operands are
    tracers).  Mirrors :func:`_check_diurnal_peak`; every error names the
    offending field.
    """
    delay = np.asarray(scn.net_delay)
    jitter = np.asarray(scn.net_jitter)
    drop = np.asarray(scn.net_drop)
    crash = np.asarray(scn.crash_rate)
    recover = np.asarray(scn.recover_rate)
    slow = np.asarray(scn.slow_factor)
    if (
        static.policy in routing_lib.PULL_POLICIES
        or static.comm in comm_lib.PULL_KINDS
    ):
        if static.comm != static.policy:
            raise ValueError(
                f"pull policies pair 1:1 with their token channel: "
                f"policy={static.policy!r} with comm={static.comm!r}"
            )
        if static.policy == "hsq" and np.any(np.asarray(scn.rt_rate) < 0):
            raise ValueError(
                "rt_rate (the hsq token-refresh rate) must be >= 0"
            )
    mix = np.asarray(scn.class_mix)
    if mix.shape[-1] != static.classes:
        raise ValueError(
            f"Scenario.class_mix has {mix.shape[-1]} classes but "
            f"StaticConfig.classes is {static.classes}"
        )
    aff = np.asarray(scn.class_affinity)
    if aff.shape[-2:] != (static.classes, static.servers):
        raise ValueError(
            f"Scenario.class_affinity must end in shape (classes, servers)"
            f" = ({static.classes}, {static.servers}), got {aff.shape}"
        )
    if static.network == "none":
        for name, arr, neutral in (
            ("net_delay", delay, 0),
            ("net_jitter", jitter, 0),
            ("net_drop", drop, 0),
        ):
            if np.any(arr != neutral):
                raise ValueError(
                    f"{name} is nonzero for {int(np.sum(arr != neutral))} "
                    f"cell(s) but network='none'; set network='net'"
                )
        if static.fault == "none" and np.any(np.asarray(scn.suspect_age) > 0):
            raise ValueError(
                "suspect_age > 0 needs a modeled control plane "
                "(network='net' and/or a fault kind)"
            )
    else:
        if np.any(delay < 0) or np.any(jitter < 0):
            raise ValueError("net_delay / net_jitter must be >= 0 slots")
        if np.any(drop < 0) or np.any(drop >= 1):
            raise ValueError(
                "net_drop is a probability and must be in [0, 1)"
            )
        if static.policy in ("jsq", "sq2", "sqd") and np.any(
            delay >= static.net_delay_cap
        ):
            raise ValueError(
                f"net_delay must be < net_delay_cap "
                f"({static.net_delay_cap}) for the query policies' stale "
                f"state ring, got max {int(np.max(delay))}; raise "
                f"StaticConfig.net_delay_cap"
            )
    timeout = np.asarray(scn.ack_timeout)
    base = np.asarray(scn.backoff_base)
    retries = np.asarray(scn.max_retries)
    ka = np.asarray(scn.ka_period)
    if static.transport == "ack":
        if static.network == "none":
            raise ValueError(
                "transport='ack' needs network='net' (instant lossless "
                "delivery has nothing to acknowledge)"
            )
        if np.any(timeout < 1):
            raise ValueError(
                f"ack_timeout must be >= 1 slot under transport='ack' "
                f"for {int(np.sum(timeout < 1))} cell(s)"
            )
        if np.any(base < 1):
            raise ValueError(
                "backoff_base must be >= 1 (the timeout window may only "
                "grow across retries)"
            )
        if np.any(retries < 0) or np.any(ka < 0):
            raise ValueError("max_retries / ka_period must be >= 0")
    else:
        for name, arr, neutral in (
            ("ack_timeout", timeout, 0),
            ("backoff_base", base, 1.0),
            ("max_retries", retries, 0),
            ("ka_period", ka, 0),
        ):
            if np.any(arr != neutral):
                raise ValueError(
                    f"{name} is non-neutral for "
                    f"{int(np.sum(arr != neutral))} cell(s) but "
                    f"transport='fire_forget'; set transport='ack'"
                )
    if static.fault == "none":
        for name, arr, neutral in (
            ("crash_rate", crash, 0.0),
            ("recover_rate", recover, 0.0),
            ("slow_factor", slow, 1.0),
        ):
            if np.any(arr != neutral):
                raise ValueError(
                    f"{name} is non-neutral for "
                    f"{int(np.sum(arr != neutral))} cell(s) but "
                    f"fault='none'; set fault='crash' or fault='slow'"
                )
    else:
        if np.any((crash < 0) | (crash > 1)) or np.any(
            (recover < 0) | (recover > 1)
        ):
            raise ValueError(
                "crash_rate / recover_rate are per-slot probabilities in "
                "[0, 1]"
            )
        if np.any((crash > 0) & (recover == 0)):
            raise ValueError(
                "recover_rate must be > 0 when crash_rate > 0 (faulted "
                "servers would never recover)"
            )
        if np.any((slow <= 0) | (slow > 1)):
            raise ValueError("slow_factor must be in (0, 1]")


def _finalize(arrive_np: np.ndarray, out) -> SimResult:
    """Convert one run's device outputs into a host-side SimResult."""
    out = tuple(out)
    # transport="ack" programs append a retransmit counter; fire_forget
    # keeps the historical 13-output tuple.
    retrans = np.asarray(out[13]) if len(out) > 13 else np.int32(0)
    (comp_slot, msgs, deps, arrs, max_aq, max_q, per_srv, final_q, dropped,
     gap_sup, net_drops, token_miss, token_sum) = (
        np.asarray(o) for o in out[:13]
    )

    arrival_slots = np.nonzero(arrive_np)[0]
    comp = comp_slot[arrival_slots]
    done = comp >= 0
    jct = comp[done] - arrival_slots[done] + 1

    deps_i = int(deps)
    msgs_i = int(msgs)
    return SimResult(
        jct=jct.astype(np.int64),
        arrivals=int(arrs),
        departures=deps_i,
        messages=msgs_i,
        max_aq=int(max_aq),
        max_queue=int(max_q),
        overflow=bool(dropped > 0),
        per_server_arrivals=per_srv,
        final_q=final_q,
        msgs_per_departure=(msgs_i / deps_i) if deps_i else 0.0,
        queue_gap_sup=int(gap_sup),
        dropped=int(dropped),
        net_drops=int(net_drops),
        retrans=int(retrans),
        token_misses=int(token_miss),
        token_sum=int(token_sum),
    )


def simulate(key: jax.Array, cfg: SimConfig) -> SimResult:
    """Run one slotted simulation; returns host-side metrics.

    Routes through the same traced core as :func:`simulate_grid`, so all
    cells sharing a :class:`StaticConfig` share one compiled program.
    """
    static, scn = cfg.static_part(), cfg.scenario()
    _check_diurnal_peak(static, scn)
    _check_control_plane(static, scn)
    if static.route_backend == "pallas":
        _check_pallas_static(static)
        out = _pallas_grid_fn(static)(
            key[None], jax.tree.map(lambda a: a[None], scn)
        )
        return _finalize(
            np.asarray(out[0][0]), tuple(o[0] for o in out[1:])
        )
    out = _simulate_jit(key, scn, static)
    return _finalize(np.asarray(out[0]), out[1:])


def simulate_grid(
    keys: jax.Array | Sequence[int],
    static_cfg: StaticConfig,
    scenarios: Scenario | Sequence[Scenario],
    *,
    shard: bool = True,
) -> list[list[SimResult]]:
    """Run a whole scenario grid as **one compiled program**.

    Args:
      keys: batched PRNG key array or sequence of integer seeds, shape
        ``(S,)`` -- every cell replays the same seed set.
      static_cfg: the shared program structure; every cell of the grid must
        agree on it (kinds and shapes are compile-time, by design -- see the
        module docstring).
      scenarios: ``C`` traced cells -- a sequence of unbatched
        :class:`Scenario` or an already-stacked batched Scenario.
      shard: shard the flattened ``(C*S,)`` run axis across local devices
        with ``shard_map``.  Ragged batches are padded up to the device
        count with wrap-around duplicate runs (dropped on output), so
        sharding never silently degrades to one device.

    Returns:
      ``results[c][s]`` -- one :class:`SimResult` per (cell, seed),
      bit-identical to ``simulate(key_s, cell_c)`` (asserted by
      ``tests/test_grid.py``): vmap, shard_map and padding are all
      semantics-preserving.
    """
    keys = _as_keys(keys)
    if isinstance(scenarios, Scenario):
        scn_stacked = scenarios
        c = int(jax.tree.leaves(scenarios)[0].shape[0])
    else:
        scenarios = list(scenarios)
        c = len(scenarios)
        scn_stacked = stack_scenarios(scenarios)
    _check_diurnal_peak(static_cfg, scn_stacked)
    _check_control_plane(static_cfg, scn_stacked)
    s = keys.shape[0]
    n = c * s

    # Flatten cell-major: run r = cell * S + seed.
    keys_flat = jnp.broadcast_to(keys[None], (c, s)).reshape((n,))
    scn_flat = jax.tree.map(
        lambda a: jnp.repeat(a, s, axis=0), scn_stacked
    )

    if static_cfg.route_backend == "pallas":
        # The kernel's grid axis is the flattened run axis itself; no
        # shard_map (the mean-field path targets one big accelerator).
        _check_pallas_static(static_cfg)
        out = _pallas_grid_fn(static_cfg)(keys_flat, scn_flat)
        out_np = [np.asarray(o) for o in out]
        arrive, rest = out_np[0], out_np[1:]
        return [
            [
                _finalize(
                    arrive[i * s + j], tuple(o[i * s + j] for o in rest)
                )
                for j in range(s)
            ]
            for i in range(c)
        ]

    n_dev = jax.local_device_count() if shard else 1
    idx = _pad_indices(n, n_dev)
    if len(idx) != n:
        keys_flat = keys_flat[idx]
        scn_flat = jax.tree.map(lambda a: a[idx], scn_flat)

    out = _grid_fn(static_cfg, n_dev)(keys_flat, scn_flat)
    out_np = [np.asarray(o)[:n] for o in out]
    arrive, rest = out_np[0], out_np[1:]
    return [
        [
            _finalize(arrive[i * s + j], tuple(o[i * s + j] for o in rest))
            for j in range(s)
        ]
        for i in range(c)
    ]


def simulate_batch(
    keys: jax.Array | Sequence[int], cfg: SimConfig, *, shard: bool = True
) -> list[SimResult]:
    """Run a batch of simulations in one batched scan (one per PRNG key).

    ``keys`` is either a batched PRNG key array or a sequence of integer
    seeds.  Numerically identical to calling :func:`simulate` per key (vmap
    is semantics-preserving -- asserted by the tests), but executes every
    run in a single program: the one-cell special case of
    :func:`simulate_grid`, inheriting its ``shard_map`` sharding across
    local devices (TPU/GPU, or CPU with
    ``--xla_force_host_platform_device_count``, which ``benchmarks/run.py``
    sets) -- that is where the wall-clock win comes from on CPU, since the
    slotted scan body fuses into a compute-bound loop that a single core
    can't amortise further.  Ragged batches are padded, not unsharded.
    """
    return simulate_grid(
        keys, cfg.static_part(), [cfg.scenario()], shard=shard
    )[0]


def exact_state_messages(
    result: SimResult, policy: str, sqd: int = 2, network: str = "none"
) -> int:
    """Messages the *policy itself* fundamentally needs (paper Fig. 5).

    JSQ needs one message per departure [LXK+11]; SQ(d) needs 2d messages per
    arrival under the query implementation; RR / Random need none.  CARE
    policies report their trigger-counted messages directly.  Under a
    modeled network (``network="net"``) the SQ(d) query round-trips are
    already counted as real traffic in ``result.messages`` (and suffer the
    delivery delay), so the analytic formula would double-count them.
    """
    if policy == "jsq":
        return result.departures
    if policy in ("sq2", "sqd") and network != "none":
        return result.messages
    if policy == "sq2":
        return 4 * result.arrivals
    if policy == "sqd":
        return 2 * sqd * result.arrivals
    if policy in ("rr", "random"):
        return 0
    return result.messages
