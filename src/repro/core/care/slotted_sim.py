"""Discrete-time slotted simulator for the CARE model (paper Section 9).

Dynamics (matching the paper's simulation setting exactly):

* K parallel FIFO servers, a single load balancer.
* In every slot, one job arrives with probability ``load`` (Bernoulli).
* Job service requirements are i.i.d. Geometric(1/K) (mean K slots), drawn
  per job at arrival time so that *the same input* (arrival times and sizes)
  can be replayed under every policy -- the paper's comparison method.
* A busy server completes one unit of work per slot.

Within a slot the order of operations is:

  1. arrival (if any) is routed using the *pre-slot* state;
  2. every busy server works one unit; the head job departs when its
     remaining requirement reaches zero;
  3. the balancer's emulation advances one slot (approximation component);
  4. the communication pattern evaluates its trigger and any triggered
     server sends a message carrying its exact queue length, which snaps the
     approximation to the truth.

Because a message fires in the same slot in which the trigger condition is
met, the end-of-slot approximation error satisfies ``AQ <= x - 1`` for DT-x
and ET-x (Theorem 2.3) -- asserted by the tests.

The whole simulation is a single ``jax.lax.scan``; all per-server state is
vectorised and job FIFOs are circular buffers carried through the scan, so
the simulator jit-compiles once per (policy, pattern, approximation) triple
and runs at native speed on CPU/TPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.care import approx as approx_lib
from repro.core.care import routing as routing_lib

CommKind = Literal["none", "rt", "dt", "et"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation configuration (hashable; jit specialises on it)."""

    servers: int = 30
    slots: int = 100_000
    load: float = 0.95
    # Mean job size in slots; the paper uses Geometric(1/K) i.e. mean == K.
    mean_service: int = 30
    policy: routing_lib.PolicyKind = "jsaq"
    comm: CommKind = "et"
    x: int = 3  # DT-x / ET-x parameter (max tolerated error is x-1).
    rt_rate: float = 0.01  # RT-r per-server message rate (messages/slot).
    approx: approx_lib.ApproxKind = "msr"
    buffer_cap: int = 2048  # per-server FIFO capacity (power of two).
    sqd: int = 2

    def approx_config(self) -> approx_lib.ApproxConfig:
        return approx_lib.ApproxConfig(
            kind=self.approx, msr_slots=self.mean_service, x=self.x
        )


@dataclasses.dataclass
class SimResult:
    """Simulation outputs (host-side numpy)."""

    jct: np.ndarray  # (num_jobs,) job completion times in slots (>=1)
    arrivals: int
    departures: int
    messages: int
    max_aq: int  # sup_t AQ(t) observed at slot ends
    max_queue: int
    overflow: bool
    per_server_arrivals: np.ndarray  # (K,)
    final_q: np.ndarray  # (K,)
    # messages per departure; the exact-state baseline is 1 (Prop 6.1).
    msgs_per_departure: float = 0.0
    queue_gap_sup: int = 0  # sup_t max_ij |Q_i - Q_j| (for SSC experiments)


def _geometric_sizes(key: jax.Array, n: int, mean: int) -> jnp.ndarray:
    """i.i.d. Geometric(1/mean) sizes with support {1, 2, ...}."""
    u = jax.random.uniform(key, (n,), jnp.float32, 1e-7, 1.0 - 1e-7)
    sizes = jnp.floor(jnp.log1p(-u) / np.log1p(-1.0 / mean)) + 1.0
    return jnp.maximum(sizes, 1.0).astype(jnp.int32)


@dataclasses.dataclass
class _Carry:
    q_true: jnp.ndarray  # (K,) true queue lengths
    head_rem: jnp.ndarray  # (K,) remaining slots of in-service job
    buf_jid: jnp.ndarray  # (K, B) circular FIFO of job ids (arrival slots)
    head_ptr: jnp.ndarray  # (K,) FIFO head index
    emu: approx_lib.EmuState
    deps_since_msg: jnp.ndarray  # (K,)
    slots_since_msg: jnp.ndarray  # (K,)
    rr_ptr: jnp.ndarray  # () round-robin pointer
    msgs: jnp.ndarray  # () total messages
    deps: jnp.ndarray  # () total departures
    arrs: jnp.ndarray  # () total arrivals
    per_srv: jnp.ndarray  # (K,) arrivals per server
    max_aq: jnp.ndarray  # () running sup of end-of-slot AQ
    max_q: jnp.ndarray  # () running sup of max queue length
    overflow: jnp.ndarray  # () bool, FIFO capacity exceeded
    gap_sup: jnp.ndarray  # () running sup of max_ij |Q_i - Q_j|


jax.tree_util.register_dataclass(
    _Carry, data_fields=[f.name for f in dataclasses.fields(_Carry)], meta_fields=[]
)


def simulate(key: jax.Array, cfg: SimConfig) -> SimResult:
    """Run one slotted simulation; returns host-side metrics."""
    k_arr, k_size, k_scan = jax.random.split(key, 3)
    t = cfg.slots
    arrive = jax.random.bernoulli(k_arr, cfg.load, (t,))
    sizes = _geometric_sizes(k_size, t, cfg.mean_service)
    slot_keys = jax.random.split(k_scan, t)

    out = _simulate_jit(arrive, sizes, slot_keys, cfg)
    (comp_slot, msgs, deps, arrs, max_aq, max_q, per_srv, final_q, overflow,
     gap_sup) = map(np.asarray, out)

    arrive_np = np.asarray(arrive)
    arrival_slots = np.nonzero(arrive_np)[0]
    comp = comp_slot[arrival_slots]
    done = comp >= 0
    jct = comp[done] - arrival_slots[done] + 1

    deps_i = int(deps)
    msgs_i = int(msgs)
    return SimResult(
        jct=jct.astype(np.int64),
        arrivals=int(arrs),
        departures=deps_i,
        messages=msgs_i,
        max_aq=int(max_aq),
        max_queue=int(max_q),
        overflow=bool(overflow),
        per_server_arrivals=per_srv,
        final_q=final_q,
        msgs_per_departure=(msgs_i / deps_i) if deps_i else 0.0,
        queue_gap_sup=int(gap_sup),
    )


@functools.partial(jax.jit, static_argnums=(3,))
def _simulate_jit(arrive, sizes, slot_keys, cfg: SimConfig):
    k = cfg.servers
    b = cfg.buffer_cap
    acfg = cfg.approx_config()
    rt_period = max(int(round(1.0 / max(cfg.rt_rate, 1e-9))), 1)

    def slot(c: _Carry, xs):
        arr, size, jid, skey = xs

        # --- 1. arrival & routing -------------------------------------
        server, rr_ptr = routing_lib.route(
            cfg.policy, c.q_true, c.emu.q_app, c.rr_ptr, skey, d=cfg.sqd
        )
        tail = (c.head_ptr[server] + c.q_true[server]) % b
        overflow = c.overflow | (arr & (c.q_true[server] >= b))
        buf_jid = jax.lax.cond(
            arr,
            lambda bj: bj.at[server, tail].set(jid),
            lambda bj: bj,
            c.buf_jid,
        )
        was_idle = c.q_true[server] == 0
        q_true = jnp.where(arr, c.q_true.at[server].add(1), c.q_true)
        head_rem = jnp.where(
            arr & was_idle, c.head_rem.at[server].set(size), c.head_rem
        )
        emu = jax.lax.cond(
            arr,
            lambda e: approx_lib.emu_arrival(e, server, acfg),
            lambda e: e,
            c.emu,
        )
        arrs = c.arrs + arr.astype(jnp.int32)
        per_srv = jnp.where(arr, c.per_srv.at[server].add(1), c.per_srv)

        # --- 2. service ------------------------------------------------
        busy = q_true > 0
        head_rem = jnp.where(busy, head_rem - 1, head_rem)
        dep = busy & (head_rem <= 0)
        departed_jid = jnp.where(
            dep, buf_jid[jnp.arange(k), c.head_ptr % b], -1
        )
        q_true = jnp.where(dep, q_true - 1, q_true)
        head_ptr = jnp.where(dep, c.head_ptr + 1, c.head_ptr)
        # Promote the next job (if any) into service with its true size.
        next_jid = buf_jid[jnp.arange(k), head_ptr % b]
        next_size = sizes[jnp.clip(next_jid, 0, sizes.shape[0] - 1)]
        head_rem = jnp.where(dep & (q_true > 0), next_size, head_rem)
        deps = c.deps + jnp.sum(dep, dtype=jnp.int32)
        deps_since_msg = c.deps_since_msg + dep.astype(jnp.int32)

        # --- 3. emulation drain -----------------------------------------
        emu = approx_lib.emu_drain_slot(emu, acfg)

        # --- 4/5. communication trigger ---------------------------------
        err = approx_lib.approximation_error(emu, q_true)
        slots_since_msg = c.slots_since_msg + 1
        if cfg.comm == "rt":
            triggered = slots_since_msg >= rt_period
        elif cfg.comm == "dt":
            triggered = deps_since_msg >= cfg.x
        elif cfg.comm == "et":
            triggered = err >= cfg.x
        else:  # "none": exact-state policies count messages analytically.
            triggered = jnp.zeros((k,), bool)

        msgs = c.msgs + jnp.sum(triggered, dtype=jnp.int32)
        emu = approx_lib.emu_message_reset(emu, q_true, triggered, acfg)
        deps_since_msg = jnp.where(triggered, 0, deps_since_msg)
        slots_since_msg = jnp.where(triggered, 0, slots_since_msg)

        # --- 6. metrics ---------------------------------------------------
        aq = jnp.max(jnp.abs(q_true - emu.q_app))
        gap = jnp.max(q_true) - jnp.min(q_true)
        carry = _Carry(
            q_true=q_true,
            head_rem=head_rem,
            buf_jid=buf_jid,
            head_ptr=head_ptr,
            emu=emu,
            deps_since_msg=deps_since_msg,
            slots_since_msg=slots_since_msg,
            rr_ptr=rr_ptr,
            msgs=msgs,
            deps=deps,
            arrs=arrs,
            per_srv=per_srv,
            max_aq=jnp.maximum(c.max_aq, aq),
            max_q=jnp.maximum(c.max_q, jnp.max(q_true)),
            overflow=overflow,
            gap_sup=jnp.maximum(c.gap_sup, gap),
        )
        return carry, departed_jid

    t = arrive.shape[0]
    init = _Carry(
        q_true=jnp.zeros((k,), jnp.int32),
        head_rem=jnp.zeros((k,), jnp.int32),
        buf_jid=jnp.full((k, b), -1, jnp.int32),
        head_ptr=jnp.zeros((k,), jnp.int32),
        emu=approx_lib.EmuState.init(jnp.zeros((k,), jnp.int32), acfg),
        deps_since_msg=jnp.zeros((k,), jnp.int32),
        slots_since_msg=jnp.zeros((k,), jnp.int32),
        rr_ptr=jnp.zeros((), jnp.int32),
        msgs=jnp.zeros((), jnp.int32),
        deps=jnp.zeros((), jnp.int32),
        arrs=jnp.zeros((), jnp.int32),
        per_srv=jnp.zeros((k,), jnp.int32),
        max_aq=jnp.zeros((), jnp.int32),
        max_q=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), bool),
        gap_sup=jnp.zeros((), jnp.int32),
    )
    xs = (arrive, sizes, jnp.arange(t, dtype=jnp.int32), slot_keys)
    final, departed = jax.lax.scan(slot, init, xs)

    # completion slot per job id (-1 if never completed).
    comp_slot = jnp.full((t,), -1, jnp.int32)
    slot_idx = jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[:, None], departed.shape
    )
    valid = departed >= 0
    comp_slot = comp_slot.at[jnp.where(valid, departed, 0)].max(
        jnp.where(valid, slot_idx, -1)
    )
    return (
        comp_slot,
        final.msgs,
        final.deps,
        final.arrs,
        final.max_aq,
        final.max_q,
        final.per_srv,
        final.q_true,
        final.overflow,
        final.gap_sup,
    )


def exact_state_messages(result: SimResult, policy: str, sqd: int = 2) -> int:
    """Messages the *policy itself* fundamentally needs (paper Fig. 5).

    JSQ needs one message per departure [LXK+11]; SQ(d) needs 2d messages per
    arrival under the query implementation; RR / Random need none.  CARE
    policies report their trigger-counted messages directly.
    """
    if policy == "jsq":
        return result.departures
    if policy == "sq2":
        return 4 * result.arrivals
    if policy == "sqd":
        return 2 * sqd * result.arrivals
    if policy in ("rr", "random"):
        return 0
    return result.messages
