"""Discrete-time slotted simulator for the CARE model (paper Section 9).

Dynamics (matching the paper's simulation setting exactly):

* K parallel FIFO servers, a single load balancer.
* In every slot, one job arrives with probability ``load`` (Bernoulli), or
  according to a bursty MMPP-modulated process (``cfg.arrival = "mmpp"``,
  see :mod:`repro.core.care.workload`).
* Job service requirements are i.i.d. Geometric(1/K) (mean K slots), drawn
  per job at arrival time so that *the same input* (arrival times and sizes)
  can be replayed under every policy -- the paper's comparison method.
* A busy server completes one unit of work per slot -- or ``r_i`` units under
  heterogeneous service rates (``cfg.service_rates``), realised by the
  deterministic credit schedule of :func:`workload.service_units` which the
  balancer mirrors exactly.

Within a slot the order of operations is:

  1. arrival (if any) is routed using the *pre-slot* state; a full FIFO
     (``q >= buffer_cap``) *drops* the arrival (counted in ``dropped``)
     instead of admitting it;
  2. every busy server works one unit; the head job departs when its
     remaining requirement reaches zero;
  3. the balancer's emulation advances one slot (approximation component);
  4. the communication pattern (:mod:`repro.core.care.comm` -- the single
     trigger implementation shared with the MoE dispatch simulator and the
     serving engine) evaluates its trigger and any triggered server sends a
     message carrying its exact queue length, which snaps the approximation
     to the truth.

Because a message fires in the same slot in which the trigger condition is
met, the end-of-slot approximation error satisfies ``AQ <= x - 1`` for DT-x
and ET-x (Theorem 2.3) -- asserted by the tests.

The whole simulation is a single ``jax.lax.scan``; all per-server state is
vectorised and job FIFOs are circular buffers carried through the scan, so
the simulator jit-compiles once per (policy, pattern, approximation) triple
and runs at native speed on CPU/TPU.  :func:`simulate_batch` vmaps the same
scan over a batch of PRNG keys, amortising per-op dispatch overhead across
seeds -- the entry point the benchmarks use for seed sweeps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.care import approx as approx_lib
from repro.core.care import comm as comm_lib
from repro.core.care import routing as routing_lib
from repro.core.care import workload as workload_lib

CommKind = comm_lib.CommKind


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static simulation configuration (hashable; jit specialises on it).

    Scenario knobs beyond the paper's Section 9.1 setting:

    * ``arrival="mmpp"`` with ``burst_intensity`` / ``burst_stay`` switches
      to bursty Markov-modulated arrivals (long-run rate still ``load``).
    * ``service_rates`` (length-``servers`` tuple) gives each server a speed
      in work units/slot; ``rate_aware=True`` makes the shortest-queue
      family minimise expected drain time ``q_i / r_i`` instead of raw
      queue length.
    * ``comm="et_rt"`` enables the hybrid ET-x trigger with an RT fallback
      every ``1/rt_rate`` slots (staleness cap in light traffic).
    """

    servers: int = 30
    slots: int = 100_000
    load: float = 0.95
    # Mean job size in slots; the paper uses Geometric(1/K) i.e. mean == K.
    mean_service: int = 30
    policy: routing_lib.PolicyKind = "jsaq"
    comm: CommKind = "et"
    x: int = 3  # DT-x / ET-x parameter (max tolerated error is x-1).
    rt_rate: float = 0.01  # RT-r per-server message rate (messages/slot).
    approx: approx_lib.ApproxKind = "msr"
    buffer_cap: int = 2048  # per-server FIFO capacity (power of two).
    sqd: int = 2
    # Scenario layer (see module docstring / workload.py).
    arrival: str = "bernoulli"  # "bernoulli" | "mmpp"
    burst_intensity: float = 1.6
    burst_stay: float = 0.98
    service_rates: Optional[Tuple[float, ...]] = None
    rate_aware: bool = True

    def approx_config(self) -> approx_lib.ApproxConfig:
        return approx_lib.ApproxConfig(
            kind=self.approx, msr_slots=self.mean_service, x=self.x
        )

    def comm_config(self) -> comm_lib.CommConfig:
        return comm_lib.CommConfig.from_rate(
            self.comm, x=self.x, rt_rate=self.rt_rate
        )


@dataclasses.dataclass
class SimResult:
    """Simulation outputs (host-side numpy)."""

    jct: np.ndarray  # (num_jobs,) job completion times in slots (>=1)
    arrivals: int  # admitted arrivals (offered minus dropped)
    departures: int
    messages: int
    max_aq: int  # sup_t AQ(t) observed at slot ends
    max_queue: int
    overflow: bool  # any arrival dropped on a full FIFO
    per_server_arrivals: np.ndarray  # (K,)
    final_q: np.ndarray  # (K,)
    # messages per departure; the exact-state baseline is 1 (Prop 6.1).
    msgs_per_departure: float = 0.0
    queue_gap_sup: int = 0  # sup_t max_ij |Q_i - Q_j| (for SSC experiments)
    dropped: int = 0  # arrivals rejected because the FIFO was full


@dataclasses.dataclass
class _Carry:
    q_true: jnp.ndarray  # (K,) true queue lengths
    head_rem: jnp.ndarray  # (K,) remaining slots of in-service job
    buf_jid: jnp.ndarray  # (K, B) circular FIFO of job ids (arrival slots)
    head_ptr: jnp.ndarray  # (K,) FIFO head index
    emu: approx_lib.EmuState
    comm: comm_lib.CommState  # shared trigger bookkeeping + message total
    rr_ptr: jnp.ndarray  # () round-robin pointer
    deps: jnp.ndarray  # () total departures
    arrs: jnp.ndarray  # () total admitted arrivals
    dropped: jnp.ndarray  # () arrivals rejected on a full FIFO
    per_srv: jnp.ndarray  # (K,) arrivals per server
    max_aq: jnp.ndarray  # () running sup of end-of-slot AQ
    max_q: jnp.ndarray  # () running sup of max queue length
    gap_sup: jnp.ndarray  # () running sup of max_ij |Q_i - Q_j|


jax.tree_util.register_dataclass(
    _Carry, data_fields=[f.name for f in dataclasses.fields(_Carry)], meta_fields=[]
)


def _prep(key: jax.Array, cfg: SimConfig):
    """Draw the replayable workload: (arrive, sizes, slot_keys)."""
    k_arr, k_size, k_scan = jax.random.split(key, 3)
    t = cfg.slots
    if cfg.arrival == "mmpp":
        arrive = workload_lib.mmpp_arrivals(
            k_arr, t, cfg.load, cfg.burst_intensity, cfg.burst_stay
        )
    else:
        arrive = workload_lib.bernoulli_arrivals(k_arr, t, cfg.load)
    sizes = workload_lib.geometric_sizes(k_size, t, cfg.mean_service)
    slot_keys = jax.random.split(k_scan, t)
    return arrive, sizes, slot_keys


def _sim_core(arrive, sizes, slot_keys, cfg: SimConfig):
    """One full slotted run as a lax.scan; traceable (also under vmap)."""
    k = cfg.servers
    b = cfg.buffer_cap
    acfg = cfg.approx_config()
    ccfg = cfg.comm_config()
    if cfg.service_rates is not None:
        rates = jnp.asarray(cfg.service_rates, jnp.float32)
        inv_rate = 1.0 / rates if cfg.rate_aware else None
    else:
        rates = None
        inv_rate = None

    def slot(c: _Carry, xs):
        arr, size, jid, skey = xs

        # --- 1. arrival & routing -------------------------------------
        server, rr_ptr = routing_lib.route(
            cfg.policy, c.q_true, c.emu.q_app, c.rr_ptr, skey,
            d=cfg.sqd, inv_rate=inv_rate,
        )
        # Dense one-hot arithmetic instead of scalar gathers / scatters /
        # conds: under vmap those lower to serial per-batch-element loops
        # (or both-branch selects), which destroys the batched-scan
        # throughput; elementwise (K,) ops stay fully vectorised.
        onehot = jnp.arange(k, dtype=jnp.int32) == server
        q_sel = jnp.sum(jnp.where(onehot, c.q_true, 0))
        # A full FIFO drops the arrival (counted) rather than letting the
        # tail wrap onto the live head entry.
        admit = arr & (q_sel < b)
        dropped = c.dropped + (arr & ~admit).astype(jnp.int32)
        sel = onehot & admit
        head_sel = jnp.sum(jnp.where(onehot, c.head_ptr, 0))
        tail = (head_sel + q_sel) % b
        # Masked one-element scatter (the ring itself still needs indexing).
        buf_jid = c.buf_jid.at[server, tail].set(
            jnp.where(admit, jid, c.buf_jid[server, tail])
        )
        q_true = c.q_true + sel.astype(jnp.int32)
        head_rem = jnp.where(sel & (c.q_true == 0), size, c.head_rem)
        emu = approx_lib.emu_arrival_masked(c.emu, sel, acfg)
        arrs = c.arrs + admit.astype(jnp.int32)
        per_srv = c.per_srv + sel.astype(jnp.int32)

        # --- 2. service ------------------------------------------------
        busy = q_true > 0
        if rates is None:
            units = None
            head_rem = jnp.where(busy, head_rem - 1, head_rem)
        else:
            units = workload_lib.service_units(jid, rates)
            head_rem = jnp.where(busy, head_rem - units, head_rem)
        dep = busy & (head_rem <= 0)
        departed_jid = jnp.where(
            dep, buf_jid[jnp.arange(k), c.head_ptr % b], -1
        )
        q_true = jnp.where(dep, q_true - 1, q_true)
        head_ptr = jnp.where(dep, c.head_ptr + 1, c.head_ptr)
        # Promote the next job (if any) into service with its true size.
        next_jid = buf_jid[jnp.arange(k), head_ptr % b]
        next_size = sizes[jnp.clip(next_jid, 0, sizes.shape[0] - 1)]
        head_rem = jnp.where(dep & (q_true > 0), next_size, head_rem)
        deps = c.deps + jnp.sum(dep, dtype=jnp.int32)

        # --- 3. emulation drain -----------------------------------------
        emu = approx_lib.emu_drain_slot(emu, acfg, units=units)

        # --- 4/5. communication trigger (shared core, comm.py) ----------
        err = approx_lib.approximation_error(emu, q_true)
        triggered, comm_state = comm_lib.evaluate(
            c.comm, ccfg, err, dep.astype(jnp.int32)
        )
        emu = approx_lib.emu_message_reset(emu, q_true, triggered, acfg)

        # --- 6. metrics ---------------------------------------------------
        aq = jnp.max(jnp.abs(q_true - emu.q_app))
        gap = jnp.max(q_true) - jnp.min(q_true)
        carry = _Carry(
            q_true=q_true,
            head_rem=head_rem,
            buf_jid=buf_jid,
            head_ptr=head_ptr,
            emu=emu,
            comm=comm_state,
            rr_ptr=rr_ptr,
            deps=deps,
            arrs=arrs,
            dropped=dropped,
            per_srv=per_srv,
            max_aq=jnp.maximum(c.max_aq, aq),
            max_q=jnp.maximum(c.max_q, jnp.max(q_true)),
            gap_sup=jnp.maximum(c.gap_sup, gap),
        )
        return carry, departed_jid

    t = arrive.shape[0]
    init = _Carry(
        q_true=jnp.zeros((k,), jnp.int32),
        head_rem=jnp.zeros((k,), jnp.int32),
        buf_jid=jnp.full((k, b), -1, jnp.int32),
        head_ptr=jnp.zeros((k,), jnp.int32),
        emu=approx_lib.EmuState.init(jnp.zeros((k,), jnp.int32), acfg),
        comm=comm_lib.CommState.init(k),
        rr_ptr=jnp.zeros((), jnp.int32),
        deps=jnp.zeros((), jnp.int32),
        arrs=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
        per_srv=jnp.zeros((k,), jnp.int32),
        max_aq=jnp.zeros((), jnp.int32),
        max_q=jnp.zeros((), jnp.int32),
        gap_sup=jnp.zeros((), jnp.int32),
    )
    xs = (arrive, sizes, jnp.arange(t, dtype=jnp.int32), slot_keys)
    final, departed = jax.lax.scan(slot, init, xs)

    # completion slot per job id (-1 if never completed).
    comp_slot = jnp.full((t,), -1, jnp.int32)
    slot_idx = jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[:, None], departed.shape
    )
    valid = departed >= 0
    comp_slot = comp_slot.at[jnp.where(valid, departed, 0)].max(
        jnp.where(valid, slot_idx, -1)
    )
    return (
        comp_slot,
        final.comm.msgs,
        final.deps,
        final.arrs,
        final.max_aq,
        final.max_q,
        final.per_srv,
        final.q_true,
        final.dropped,
        final.gap_sup,
    )


_simulate_jit = jax.jit(_sim_core, static_argnums=(3,))


def _batch_one(key, cfg: SimConfig):
    arrive, sizes, slot_keys = _prep(key, cfg)
    return (arrive,) + _sim_core(arrive, sizes, slot_keys, cfg)


@functools.partial(jax.jit, static_argnums=(1,))
def _simulate_batch_jit(keys, cfg: SimConfig):
    return jax.vmap(lambda k: _batch_one(k, cfg))(keys)


@functools.lru_cache(maxsize=None)
def _simulate_batch_pmap(cfg: SimConfig, n_dev: int):
    """Device-sharded batch: pmap over local devices, vmap within each.

    ``n_dev`` is part of the cache key: a pmap built for a different
    ``jax.local_device_count()`` (e.g. before a topology change in-process)
    would otherwise be silently reused and fail or undershard.
    """
    assert n_dev == jax.local_device_count(), (
        "cached pmap requested for a stale device topology"
    )
    return jax.pmap(jax.vmap(lambda k: _batch_one(k, cfg)))


def _finalize(arrive_np: np.ndarray, out, cfg: SimConfig) -> SimResult:
    """Convert one run's device outputs into a host-side SimResult."""
    (comp_slot, msgs, deps, arrs, max_aq, max_q, per_srv, final_q, dropped,
     gap_sup) = (np.asarray(o) for o in out)

    arrival_slots = np.nonzero(arrive_np)[0]
    comp = comp_slot[arrival_slots]
    done = comp >= 0
    jct = comp[done] - arrival_slots[done] + 1

    deps_i = int(deps)
    msgs_i = int(msgs)
    return SimResult(
        jct=jct.astype(np.int64),
        arrivals=int(arrs),
        departures=deps_i,
        messages=msgs_i,
        max_aq=int(max_aq),
        max_queue=int(max_q),
        overflow=bool(dropped > 0),
        per_server_arrivals=per_srv,
        final_q=final_q,
        msgs_per_departure=(msgs_i / deps_i) if deps_i else 0.0,
        queue_gap_sup=int(gap_sup),
        dropped=int(dropped),
    )


def simulate(key: jax.Array, cfg: SimConfig) -> SimResult:
    """Run one slotted simulation; returns host-side metrics."""
    arrive, sizes, slot_keys = _prep(key, cfg)
    out = _simulate_jit(arrive, sizes, slot_keys, cfg)
    return _finalize(np.asarray(arrive), out, cfg)


def simulate_batch(
    keys: jax.Array | Sequence[int], cfg: SimConfig, *, shard: bool = True
) -> list[SimResult]:
    """Run a batch of simulations in one batched scan (one per PRNG key).

    ``keys`` is either a batched PRNG key array or a sequence of integer
    seeds.  Numerically identical to calling :func:`simulate` per key (vmap
    is semantics-preserving -- asserted by the tests), but executes every
    run in a single program.  When more than one local device is visible
    (TPU/GPU, or CPU with ``--xla_force_host_platform_device_count``, which
    ``benchmarks/run.py`` sets) and the batch divides evenly, the batch is
    additionally *sharded across devices* with ``pmap`` -- that is where the
    wall-clock win comes from on CPU, since the slotted scan body fuses into
    a compute-bound loop that a single core can't amortise further.
    """
    if not isinstance(keys, jax.Array):
        keys = jnp.stack([jax.random.key(int(s)) for s in keys])
    n = keys.shape[0]
    n_dev = jax.local_device_count()
    if shard and n_dev > 1 and n % n_dev == 0:
        out = _simulate_batch_pmap(cfg, n_dev)(keys.reshape(n_dev, n // n_dev))
        out_np = [np.asarray(o).reshape((n,) + np.shape(o)[2:]) for o in out]
    else:
        out = _simulate_batch_jit(keys, cfg)
        out_np = [np.asarray(o) for o in out]
    arrive, rest = out_np[0], out_np[1:]
    return [
        _finalize(arrive[i], tuple(o[i] for o in rest), cfg)
        for i in range(n)
    ]


def exact_state_messages(result: SimResult, policy: str, sqd: int = 2) -> int:
    """Messages the *policy itself* fundamentally needs (paper Fig. 5).

    JSQ needs one message per departure [LXK+11]; SQ(d) needs 2d messages per
    arrival under the query implementation; RR / Random need none.  CARE
    policies report their trigger-counted messages directly.
    """
    if policy == "jsq":
        return result.departures
    if policy == "sq2":
        return 4 * result.arrivals
    if policy == "sqd":
        return 2 * sqd * result.arrivals
    if policy in ("rr", "random"):
        return 0
    return result.messages
