"""Approximation component of the CARE model (paper Section 4).

The load balancer keeps, for every server i, an approximation ``q_app[i]`` of
the true queue length ``q_true[i]``.  Between messages the approximation is
driven by (a) arrivals the balancer itself routed (known exactly, Eq. 10) and
(b) an *emulated* departure process encoding the approximation algorithm
(Observation 4.1: the error is determined solely by departure estimation).

Three algorithms from the paper:

* ``basic``  -- never emulate departures (Definition 4.2).  Error equals the
  number of true departures since the last message (Proposition 4.3).
* ``msr``    -- emulate a FIFO queue where every job gets its Mean Service
  Requirement, i.e. a deterministic ``msr_slots`` slots (Definition 4.8).
* ``msr_x``  -- MSR with the emulated departure count truncated at ``x - 1``
  (Definition 4.9), restoring the deterministic ``AQ <= x-1`` bound of
  Proposition 6.7.

All functions are pure and vectorised over the server axis so they can be
used inside ``lax.scan`` (slotted simulator), inside a jitted MoE router
(training-tier balancer) and by the serving dispatcher.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

ApproxKind = Literal["basic", "msr", "msr_x"]


@dataclasses.dataclass(frozen=True)
class ApproxConfig:
    """Configuration of the approximation algorithm.

    Attributes:
      kind: which approximation algorithm the balancer runs.  Always a
        Python string (selects code paths at trace time).
      msr_slots: mean service requirement in slots (``1/mu`` in slot units);
        the deterministic service time assigned to every emulated job.  May
        be a Python int *or a traced scalar* -- the slotted simulator passes
        the ``ServiceProcess`` mean as a traced operand so a grid of mean
        sizes shares one compiled program.
      x: the truncation parameter for ``msr_x`` (emulated departures are
        capped at ``x - 1``).  Ignored for other kinds.  May be a Python
        int *or a traced scalar* -- the truncation comparison consumes it
        as an array operand so a grid of x values shares one compiled
        program (``slotted_sim.simulate_grid``); a config holding a tracer
        must not be used as a static jit argument.
    """

    kind: ApproxKind = "msr"
    msr_slots: int = 30
    x: int = 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EmuState:
    """Balancer-side emulation state, one entry per server (shape ``(K,)``).

    ``q_app`` is the approximated queue length.  ``head_rem`` is the remaining
    emulated service (in slots) of the emulated in-service job; it is only
    meaningful when ``q_app > 0``.  ``emu_deps`` counts emulated departures
    since the last message (the quantity MSR-x truncates).
    """

    q_app: jnp.ndarray
    head_rem: jnp.ndarray
    emu_deps: jnp.ndarray

    @staticmethod
    def init(q0: jnp.ndarray, cfg: ApproxConfig) -> "EmuState":
        k = q0.shape[0]
        return EmuState(
            q_app=q0.astype(jnp.int32),
            head_rem=jnp.full((k,), cfg.msr_slots, jnp.int32),
            emu_deps=jnp.zeros((k,), jnp.int32),
        )


def emu_arrival(state: EmuState, server: jnp.ndarray, cfg: ApproxConfig) -> EmuState:
    """Register one arrival routed to ``server`` with the emulation.

    If the emulated queue was empty the arriving job enters service
    immediately and receives a fresh mean-service estimate.
    """
    was_empty = state.q_app[server] == 0
    q_app = state.q_app.at[server].add(1)
    head_rem = state.head_rem.at[server].set(
        jnp.where(was_empty, cfg.msr_slots, state.head_rem[server])
    )
    return EmuState(q_app=q_app, head_rem=head_rem, emu_deps=state.emu_deps)


def emu_arrival_masked(
    state: EmuState, sel: jnp.ndarray, cfg: ApproxConfig
) -> EmuState:
    """Register arrivals on the servers in the bool mask ``sel`` (``(K,)``).

    Branch-free form of :func:`emu_arrival` (identical semantics when at most
    one entry of ``sel`` is set and the caller masks it by the admit flag):
    dense ``where``/add ops instead of a ``lax.cond`` + scatter, so the
    update stays vectorised under ``jax.vmap`` (batched simulation) where a
    cond would lower to a both-branches select and a scatter to a serial
    per-batch loop.
    """
    was_empty = state.q_app == 0
    q_app = state.q_app + sel.astype(jnp.int32)
    head_rem = jnp.where(sel & was_empty, cfg.msr_slots, state.head_rem)
    return EmuState(q_app=q_app, head_rem=head_rem, emu_deps=state.emu_deps)


def emu_drain_slot(
    state: EmuState,
    cfg: ApproxConfig,
    units: jnp.ndarray | None = None,
    active=None,
) -> EmuState:
    """Advance the emulated queues by one time slot (vectorised over servers).

    ``basic``: no drain.  ``msr``: the emulated head departs after
    ``msr_slots`` busy slots.  ``msr_x``: same, but departures freeze once
    ``emu_deps == x - 1`` (Definition 4.9: subsequent jobs get service
    ``inf``).

    ``units`` (optional, ``(K,)`` int) is the per-server work completed this
    slot under heterogeneous service rates (``workload.service_units``); the
    schedule is deterministic so the balancer mirrors it exactly.  ``None``
    means the homogeneous unit-rate setting.

    ``active`` (optional, scalar bool, may be traced) freezes the emulation
    when False -- the padded-horizon simulator's way of making slots past a
    cell's traced horizon no-ops inside a fixed-length scan.
    """
    if cfg.kind == "basic":
        return state

    busy = state.q_app > 0
    if cfg.kind == "msr_x":
        allowed = state.emu_deps < (cfg.x - 1)
    else:
        allowed = jnp.ones_like(busy)
    ticking = busy & allowed
    if active is not None:
        ticking = ticking & active

    dec = 1 if units is None else units
    head_rem = jnp.where(ticking, state.head_rem - dec, state.head_rem)
    dep = ticking & (head_rem <= 0)
    q_app = jnp.where(dep, state.q_app - 1, state.q_app)
    emu_deps = jnp.where(dep, state.emu_deps + 1, state.emu_deps)
    # Next emulated job (if any) enters service with a fresh mean estimate.
    head_rem = jnp.where(dep, cfg.msr_slots, head_rem)
    return EmuState(q_app=q_app, head_rem=head_rem, emu_deps=emu_deps)


def emu_message_reset(
    state: EmuState, q_true: jnp.ndarray, triggered: jnp.ndarray, cfg: ApproxConfig
) -> EmuState:
    """Process messages: servers in ``triggered`` report their true length.

    A message carries the exact state (Section 2.1.2), so the approximation
    snaps to the truth and the emulation restarts -- every job present at the
    message time (including the in-service one, whose age the balancer does
    not know) is assigned a fresh mean-service estimate (Definition 4.4).
    """
    q_app = jnp.where(triggered, q_true, state.q_app)
    head_rem = jnp.where(triggered, cfg.msr_slots, state.head_rem)
    emu_deps = jnp.where(triggered, 0, state.emu_deps)
    return EmuState(q_app=q_app, head_rem=head_rem, emu_deps=emu_deps)


def approximation_error(state: EmuState, q_true: jnp.ndarray) -> jnp.ndarray:
    """Per-server approximation error ``AE_i(t)`` (Eq. 6)."""
    return jnp.abs(q_true - state.q_app)
