"""Closed-form communication/approximation bounds from the paper.

These curves back the benchmark tables so simulation results can be checked
against the theory they are supposed to satisfy:

* Theorem 2.3: DT-x / ET-x with basic or MSR-x give ``AQ <= x-1`` using at
  most ``1/x`` messages per departure.
* Theorem 2.4: ET-x + MSR, exponential service: expected inter-message time
  ``E[tau] >= (x/2 - 1)^2 / mu``  (x >= 3).
* Theorem 2.5: same, with infinite backlog: ``E[tau] >= x(x-1)/mu``; the
  implied relative communication is ``1/(x^2 - x)`` of the exact-state rate.
* Abstract's headline form, in terms of max error ``y = x - 1``:
  relative communication ``1/(y^2 + y)``.
"""
from __future__ import annotations

import numpy as np


def dt_relative_comm(x: np.ndarray | int) -> np.ndarray:
    """Thm 2.3 bound: messages per departure of DT-x / ET-x (basic, MSR-x)."""
    x = np.asarray(x, dtype=np.float64)
    return 1.0 / x


def et_msr_relative_comm_backlogged(x: np.ndarray | int) -> np.ndarray:
    """Thm 2.5 bound: relative communication of ET-x + MSR under heavy load."""
    x = np.asarray(x, dtype=np.float64)
    return 1.0 / (x * x - x)


def et_msr_relative_comm_general(x: np.ndarray | int) -> np.ndarray:
    """Thm 2.4 bound: relative communication of ET-x + MSR, general (x>=3)."""
    x = np.asarray(x, dtype=np.float64)
    return 1.0 / np.square(x / 2.0 - 1.0)


def headline_relative_comm(y: np.ndarray | int) -> np.ndarray:
    """Abstract form: error budget y ==> communication factor 1/(y^2 + y)."""
    y = np.asarray(y, dtype=np.float64)
    return 1.0 / (y * y + y)


def max_error_bound(x: int, comm: str, approx: str) -> float | None:
    """Deterministic AQ bound for a (pattern, algorithm) combination.

    Returns None when no deterministic bound exists (e.g. DT-x with
    unbounded MSR, Example 6.6; any RT-r combination, Section 6.2).
    """
    if comm == "et":
        return float(x - 1)  # Prop 6.8: holds for ANY emulation algorithm.
    if comm == "dt" and approx in ("basic", "msr_x"):
        return float(x - 1)  # Eq. (18) and Prop 6.7.
    return None


def messages_per_departure_bound(comm: str, approx: str, x: int) -> float | None:
    """Deterministic M(t) <= D(t)/x -type bound, when one exists."""
    if comm == "dt":
        return 1.0 / x  # Prop 6.4 (any approximation algorithm).
    if comm == "et" and approx in ("basic", "msr_x"):
        return 1.0 / x  # Prop 6.8.
    return None  # ET + MSR: only the stochastic bound of Prop 6.9.
