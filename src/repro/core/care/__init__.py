"""CARE: Communication, Approximation, Resource allocation, dynamic Environment.

Paper-faithful implementation of Mendelson & Xu (2022), "Load Balancing Using
Sparse Communication" / "CARE: Resource Allocation Using Sparse Communication".

Components
----------
comm        -- the communication protocol core (RT / DT / ET / ET+RT hybrid /
               exact trigger evaluation + message accounting); the single
               implementation shared by every tier (slotted sim, MoE
               dispatch sim, serving engine)
approx      -- approximation algorithms (basic / MSR / MSR-x queue emulation)
routing     -- resource-allocation policies (JSQ / JSAQ / SQ(d) / RR / Random)
workload    -- arrival processes (Bernoulli / bursty MMPP) and heterogeneous
               per-server service-rate schedules
slotted_sim -- discrete-time slotted simulator (paper Section 9), lax.scan
               based; ``simulate_batch`` vmaps it over a batch of seeds
metrics     -- AQ / communication / JCT-CCDF metrics
theory      -- closed-form bounds from Theorems 2.3, 2.4, 2.5
"""

from repro.core.care.slotted_sim import (  # noqa: F401
    SimConfig,
    SimResult,
    simulate,
    simulate_batch,
)
from repro.core.care import (  # noqa: F401
    approx,
    comm,
    metrics,
    routing,
    theory,
    workload,
)
