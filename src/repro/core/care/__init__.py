"""CARE: Communication, Approximation, Resource allocation, dynamic Environment.

Paper-faithful implementation of Mendelson & Xu (2022), "Load Balancing Using
Sparse Communication" / "CARE: Resource Allocation Using Sparse Communication".

Components
----------
approx      -- approximation algorithms (basic / MSR / MSR-x queue emulation)
routing     -- resource-allocation policies (JSQ / JSAQ / SQ(d) / RR / Random)
slotted_sim -- discrete-time slotted simulator (paper Section 9), lax.scan based
metrics     -- AQ / communication / JCT-CCDF metrics
theory      -- closed-form bounds from Theorems 2.3, 2.4, 2.5
"""

from repro.core.care.slotted_sim import (  # noqa: F401
    SimConfig,
    SimResult,
    simulate,
)
from repro.core.care import approx, metrics, routing, theory  # noqa: F401
