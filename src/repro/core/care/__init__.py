"""CARE: Communication, Approximation, Resource allocation, dynamic Environment.

Paper-faithful implementation of Mendelson & Xu (2022), "Load Balancing Using
Sparse Communication" / "CARE: Resource Allocation Using Sparse Communication".

Components
----------
comm        -- the communication protocol core (RT / DT / ET / ET+RT hybrid /
               exact trigger evaluation + message accounting); the single
               implementation shared by every tier (slotted sim, MoE
               dispatch sim, serving engine)
approx      -- approximation algorithms (basic / MSR / MSR-x queue emulation)
routing     -- resource-allocation policies (JSQ / JSAQ / SQ(d) / RR / Random)
workload    -- arrival processes (Bernoulli / bursty MMPP, optional diurnal
               modulation), ``ServiceProcess`` job-size distributions
               (geometric / deterministic / pareto / weibull; traced mean
               and tail operands) and heterogeneous per-server
               service-rate schedules
slotted_sim -- discrete-time slotted simulator (paper Section 9), lax.scan
               based; configuration is split into a static ``StaticConfig``
               (shapes + kinds; jit specialises) and a traced ``Scenario``
               pytree (load / x / rt_rate / burst / service_rates /
               service process / diurnal / horizon operands over a padded
               fixed-horizon scan); ``simulate_grid`` runs a whole
               scenario grid as one compiled program, vmapped over
               (cell x seed) and sharded across devices with ``shard_map``
metrics     -- AQ / communication / JCT-CCDF metrics
theory      -- closed-form bounds from Theorems 2.3, 2.4, 2.5
"""

from repro.core.care.slotted_sim import (  # noqa: F401
    Scenario,
    SimConfig,
    SimResult,
    StaticConfig,
    simulate,
    simulate_batch,
    simulate_grid,
    stack_scenarios,
)
from repro.core.care.workload import ServiceProcess  # noqa: F401
from repro.core.care import (  # noqa: F401
    approx,
    comm,
    metrics,
    routing,
    theory,
    workload,
)
