"""Elastic scaling and straggler policy for multi-pod training.

On node failure (or planned resize), the runtime must pick a new mesh from
the surviving hosts, re-shard the checkpointed state onto it, and resume
the data stream exactly where it stopped.  The pieces:

* ``plan_mesh``  -- largest valid (pod, data, model) factorisation of the
  surviving chip count, preferring to keep the model axis intact (changing
  TP degree would invalidate compiled kernels' efficiency assumptions and
  expert divisibility), shedding data-parallel replicas instead.
* ``remesh_plan`` -- describes what changes: dp_size, per-shard batch rows,
  whether recompilation is required.
* ``StragglerMonitor`` -- CARE-style detection: per-host step-duration
  approximations are maintained from sparse reports (ET-x: a host reports
  only when its deviation from its last report exceeds x standard
  deviations -- the paper's error-triggered pattern applied to telemetry),
  and persistent stragglers are proposed for eviction, triggering an
  elastic re-plan.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    pods: int
    data: int
    model: int
    dropped_chips: int

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


def plan_mesh(
    available_chips: int,
    *,
    model_axis: int = 16,
    chips_per_pod: int = 256,
    global_batch: int = 256,
) -> MeshPlan:
    """Largest usable mesh: keep TP fixed, shrink DP to what divides."""
    if available_chips < model_axis:
        raise ValueError(f"need at least {model_axis} chips (TP axis)")
    pods = max(available_chips // chips_per_pod, 1)
    per_pod = min(available_chips // pods, chips_per_pod)
    data = per_pod // model_axis
    # dp must divide the global batch to keep the stream re-shardable
    while data > 1 and global_batch % (data * pods):
        data -= 1
    used = pods * data * model_axis
    return MeshPlan(
        pods=pods, data=data, model=model_axis,
        dropped_chips=available_chips - used,
    )


def remesh_plan(old: MeshPlan, new: MeshPlan) -> dict:
    return {
        "recompile": (old.model != new.model) or (old.data != new.data)
        or (old.pods != new.pods),
        "dp_old": old.pods * old.data,
        "dp_new": new.pods * new.data,
        "reshard_params": old.model != new.model,
        "chips": (old.chips, new.chips),
    }


class StragglerMonitor:
    """ET-x telemetry: hosts report step time only on significant drift."""

    def __init__(self, num_hosts: int, et_threshold: float = 3.0,
                 evict_after: int = 5, slow_factor: float = 1.5):
        self.approx = np.zeros(num_hosts)  # balancer-side approximation
        self.et_threshold = et_threshold
        self.evict_after = evict_after
        self.slow_factor = slow_factor
        self.strikes = np.zeros(num_hosts, dtype=int)
        self.messages = 0
        self.observations = 0

    def host_report(self, host: int, step_time: float) -> bool:
        """Host-side trigger: report iff |obs - approx| > x * sigma.

        The very first observation of a host always reports (the monitor
        has no state to emulate from -- cold-starting silently would skew
        the fleet median).  Returns True if a message was sent.
        """
        self.observations += 1
        sigma = max(self.approx.std(), 1e-3)
        first = self.approx[host] == 0
        if first or abs(step_time - self.approx[host]) > self.et_threshold * sigma:
            self.approx[host] = step_time
            self.messages += 1
            return True
        return False

    def evictions(self) -> list[int]:
        """Hosts persistently slower than slow_factor x median."""
        med = np.median(self.approx[self.approx > 0]) if (self.approx > 0).any() else 0
        if med <= 0:
            return []
        slow = self.approx > self.slow_factor * med
        self.strikes = np.where(slow, self.strikes + 1, 0)
        return [int(h) for h in np.nonzero(self.strikes >= self.evict_after)[0]]

    @property
    def message_rate(self) -> float:
        return self.messages / max(self.observations, 1)
