"""Train-step factory: loss -> grads -> AdamW -> CARE balancer advance.

Two compiled programs implement the paper's sparse synchronisation at the
framework level (DESIGN.md Section 2.1):

* ``train_step``      -- no balancer sync: the only cross-device traffic is
  the gradient reduction and the MoE all-to-alls; the balancer advances by
  local emulation (the paper's approximation component).
* ``train_step_sync`` -- additionally snaps the balancer approximation to
  the exact global counts (the (L, DP, TP, E) -> (L, E) reduction is the
  paper's "message").

The host-side loop (``launch/train.py``) picks the program per step from
the DT-x schedule or the ET-x trigger scalar returned in the metrics --
the 1-bit flag that replaces the full sync on quiet steps.

Microbatch gradient accumulation runs as a ``lax.scan`` over microbatches
with the optimiser applied once -- the standard memory/efficiency shape for
large-batch training.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import moe_balancer
from repro.models import model
from repro.models.parallel import ParallelContext
from repro.optim import adamw


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: adamw.OptState
    balancer: Optional[moe_balancer.BalancerState]
    step: jnp.ndarray


def init_state(key, cfg: ModelConfig, ctx: Optional[ParallelContext] = None):
    params = model.init_params(key, cfg)
    bal = None
    if cfg.moe:
        l = model.num_scanned_layers(cfg)
        e = cfg.n_routed_experts
        shape = (l, e) if ctx is None else (l, ctx.dp_size, ctx.tp_size, e)
        z = jnp.zeros(shape, jnp.float32)
        bal = moe_balancer.BalancerState(
            load_approx=z,
            true_load=z,
            true_counts=z,
            bias=z,
            steps_since_sync=jnp.zeros((), jnp.int32),
        )
    return TrainState(
        params=params,
        opt=adamw.init(params),
        balancer=bal,
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.OptimConfig,
    ctx: Optional[ParallelContext] = None,
    *,
    sync: bool = False,
    microbatches: int = 1,
):
    """Build the jittable step.  ``sync`` selects the balancer-sync program."""

    def loss_fn(params, batch, bias):
        loss, aux = model.train_loss(params, batch, cfg, ctx, bias)
        return loss, aux

    def step_fn(state: TrainState, batch):
        bias = None
        if cfg.moe and state.balancer is not None:
            bias = moe_balancer.selection_bias(state.balancer, cfg.care)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if microbatches == 1:
            (loss, aux), grads = grad_fn(state.params, batch, bias)
            counts = aux["counts"]
        else:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch,
            )
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )

            def acc(carry, mbatch):
                g_acc, loss_acc, counts_acc = carry
                (loss, aux), g = grad_fn(state.params, mbatch, bias)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                c = aux["counts"]
                counts_acc = counts_acc + c if c is not None else counts_acc
                return (g_acc, loss_acc + loss, counts_acc), None

            zero_c = (
                jnp.zeros_like(state.balancer.true_counts)
                if state.balancer is not None
                else jnp.zeros(())
            )
            (grads, loss, counts), _ = jax.lax.scan(
                acc, (zero_g, jnp.zeros(()), zero_c), mb
            )
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            counts = counts if state.balancer is not None else None

        params, opt, opt_metrics = adamw.update(grads, state.opt, state.params, opt_cfg)

        balancer = state.balancer
        trigger = jnp.zeros((), bool)
        if balancer is not None and counts is not None:
            balancer = moe_balancer.post_step_update(balancer, counts, cfg.care)
            trigger = moe_balancer.needs_sync(balancer, cfg.care)
            if sync:
                balancer = moe_balancer.sync(balancer, cfg.care)

        metrics = {
            "loss": loss,
            "sync_trigger": trigger,
            **opt_metrics,
        }
        new_state = TrainState(
            params=params, opt=opt, balancer=balancer, step=state.step + 1
        )
        return new_state, metrics

    return step_fn
