"""AdamW with decoupled weight decay, global-norm clipping and a cosine
schedule -- pure JAX, fp32 moments over (possibly) bf16 params.

ZeRO-1: the moment tensors carry their own PartitionSpecs (see
models/partitioning.zero1_specs); nothing here changes, GSPMD inserts the
reduce-scatter/all-gather pair around the update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: dict
    v: dict
    step: jnp.ndarray


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(step, cfg: OptimConfig):
    """Linear warmup -> cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(grads, state: OptState, params, cfg: OptimConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(m=new_m, v=new_v, step=step), {
        "grad_norm": gnorm,
        "lr": lr,
    }
