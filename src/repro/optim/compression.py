"""Error-feedback gradient compression for the DP all-reduce.

Top-k magnitude sparsification with a residual accumulator [Stich et al.;
Lin et al. DGC]: each step the worker sends only the largest ``ratio``
fraction of gradient entries (per tensor) and folds the rest into a local
residual added back next step.  Convergence-safe thanks to error feedback.

Implemented as a pytree transform usable around any optimiser:

    comp_state = compression.init(params)
    grads, comp_state, stats = compression.compress(grads, comp_state, ratio)

On a real multi-host run the compressed (values, indices) pairs are what
crosses the DP axis; here the dense masked tensor stands in (the bytes
saved are reported analytically in ``stats`` since GSPMD's all-reduce does
not take sparse operands).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _topk_mask(x: jnp.ndarray, ratio: float) -> jnp.ndarray:
    n = x.size
    k = max(int(n * ratio), 1)
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress(grads, residual, ratio: float = 0.01):
    """Returns (sparse_grads, new_residual, stats)."""

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        mask = _topk_mask(acc, ratio)
        sent = acc * mask
        return sent.astype(g.dtype), acc - sent, mask.sum()

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    sent = tdef.unflatten([o[0] for o in out])
    new_res = tdef.unflatten([o[1] for o in out])
    total = sum(int(g.size) for g in flat_g)
    kept = sum(o[2] for o in out)
    stats = {
        "kept_fraction": kept / total,
        # Bytes over the DP axis if sent as (f16 value, i32 index) pairs:
        "compressed_bytes": kept * 6.0,
        "dense_bytes": float(total * 2),
    }
    return sent, new_res, stats
