"""End-to-end training driver with fault tolerance and CARE sync schedule.

Flow:
  1. build (or restore) TrainState; data stream seeks to the restored step;
  2. two compiled programs: ``step`` (no balancer sync) and ``step_sync``;
  3. per step, the host picks the program: DT-x fires every x steps, ET-x
     fires when the previous step's 1-bit trigger scalar was set (the
     paper's server-side-adaptive pattern -- the full count sync happens
     only then);
  4. periodic + on-signal atomic checkpoints; on crash, rerun the command
     and it resumes from the latest checkpoint (restart test:
     tests/test_train_driver.py);
  5. a StragglerMonitor consumes per-step timings (single-host here, but
     the ET telemetry path is the same one a multi-host deployment uses).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1
"""
import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.configs import get_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import model
from repro.optim import adamw
from repro.train import train_loop
from repro.train.elastic import StragglerMonitor


def build(arch: str, *, reduced: bool, seq: int, batch: int, steps: int,
          lr: float):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    opt_cfg = adamw.OptimConfig(lr=lr, total_steps=steps, warmup_steps=min(100, steps // 10 + 1))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)
    return cfg, opt_cfg, data_cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke size)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="simulate a failure at this step (testing)")
    args = ap.parse_args(argv)

    cfg, opt_cfg, data_cfg = build(
        args.arch, reduced=not args.full_size, seq=args.seq,
        batch=args.batch, steps=args.steps, lr=args.lr,
    )

    state = train_loop.init_state(jax.random.key(0), cfg)
    start_step = 0
    if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        state, start_step = checkpoint.restore(state, args.ckpt_dir)
        print(f"[train] restored checkpoint at step {start_step}")

    loader = ShardedLoader(data_cfg, start_step=start_step)

    step_fn = jax.jit(train_loop.make_train_step(
        cfg, opt_cfg, None, sync=False, microbatches=args.microbatches))
    step_sync_fn = jax.jit(train_loop.make_train_step(
        cfg, opt_cfg, None, sync=True, microbatches=args.microbatches))

    monitor = StragglerMonitor(num_hosts=1)
    care = cfg.care
    pending_sync = False
    losses = []
    syncs = 0
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = next(loader)
        t0 = time.time()
        use_sync = cfg.moe and (
            pending_sync if care.comm == "et" else (step + 1) % care.x == 0
        )
        fn = step_sync_fn if use_sync else step_fn
        syncs += int(bool(use_sync))
        state, metrics = fn(state, batch)
        loss = float(metrics["loss"])
        pending_sync = bool(metrics["sync_trigger"])
        losses.append(loss)
        monitor.host_report(0, time.time() - t0)

        if args.log_every and (step + 1) % args.log_every == 0:
            print(f"[train] step {step+1} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f}"
                  + (f" sync={use_sync}" if cfg.moe else ""))
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(state, args.ckpt_dir, step + 1)
        if args.crash_at == step + 1:
            print(f"[train] simulated crash at step {step+1}")
            raise SystemExit(42)

    dt = time.time() - t_start
    n = args.steps - start_step
    print(f"[train] done: {n} steps in {dt:.1f}s "
          f"({dt/max(n,1)*1e3:.0f} ms/step), final loss {losses[-1]:.4f}, "
          f"first loss {losses[0]:.4f}"
          + (f", balancer syncs {syncs}/{n}" if cfg.moe else ""))
    if args.ckpt_dir:
        checkpoint.save(state, args.ckpt_dir, args.steps)
    return {"final_loss": losses[-1], "first_loss": losses[0], "syncs": syncs}


if __name__ == "__main__":
    main()
