"""Nesting-aware analysis of post-SPMD (per-device) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies exactly once (no trip
multiplication), which silently under-reports every scanned layer stack by
a factor of num_layers.  This module re-derives the three roofline
numerators directly from the optimized HLO:

* FLOPs   -- from every ``dot`` op: 2 * prod(result dims) * K, where K is
  the product of the lhs contracting dims; multiplied by the while-nesting
  trip counts supplied by the caller (exact for our lax.scan stacks).
* bytes   -- two flavours:
  - ``bytes_raw``: per top-level instruction, result + operand bytes
    (fusion bodies are NOT traversed -- the fusion instruction's
    params/result are its memory traffic, matching XLA's own
    fusion-level accounting).
  - ``bytes_hbm``: the same accounting restricted to ops that mark a
    kernel/HBM boundary on TPU (fusion, dot, copy, slice ops, reduce,
    collectives, ...), with *slicing-aware* charging: an operand that is
    only dynamic-sliced/gathered inside a fusion is charged at the
    slice size, and an in-place dynamic-update-slice is charged at the
    update size -- NOT the full buffer.  Without this, a lax.scan that
    slices its (S, ...) inputs per step is charged S times the full
    stacked buffer, overstating a recurrent model's traffic by orders
    of magnitude.  The CPU backend also fuses far less aggressively
    than the TPU backend, leaving long element-wise/convert/broadcast
    chains at top level; charging those as HBM round-trips would
    overstate the memory term further, so the roofline uses
    ``bytes_hbm`` and reports ``bytes_raw`` alongside as the
    conservative upper bound.
* collective bytes -- operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, bucketed by kind.

Shapes in the post-SPMD module are per-device shard shapes, so all numbers
are per-chip -- exactly the numerators the per-chip roofline terms want.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Type part matched lazily: tuple types contain parens/commas, so we stop at
# the first "opname(" token (shape dims never form that pattern).
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\("
)
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CALL_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_FUSION_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")

# Ops that read only a slice of their (potentially huge) first operand.
_SLICING_OPS = {"dynamic-slice", "gather"}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Ops that are kernel/HBM boundaries on the TPU backend.  Everything not
# listed here (add/multiply/convert/broadcast/reshape/select/compare/...)
# is assumed fused into a neighbouring kernel by the TPU compiler and
# charged zero incremental HBM traffic in the ``bytes_hbm`` metric.
_HBM_OPS = {
    "fusion", "dot", "convolution", "copy", "copy-start", "copy-done",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "reduce-window", "select-and-scatter", "sort", "transpose",
    "concatenate", "pad", "slice", "reverse", "rng", "rng-bit-generator",
    "custom-call", "cholesky", "triangular-solve", "fft",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _parse_shapes(type_str: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dim_list = [int(d) for d in dims.split(",") if d]
        out.append((dtype, dim_list))
    return out


def _shapes_bytes(shapes) -> int:
    return sum(
        _DTYPE_BYTES[dt] * int(math.prod(dims) if dims else 1)
        for dt, dims in shapes
    )


def _operands(line: str, start: int) -> list[str]:
    depth = 0
    end = start
    for i in range(start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w.\-]+)", line[start + 1 : end])


def _split_computations(hlo_text: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for ln in hlo_text.splitlines():
        stripped = ln.strip()
        if stripped.endswith("{"):
            m = _COMP_START_RE.match(stripped)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(ln)
    return comps, entry


_UNARY_PASSTHROUGH = {"convert", "copy", "bitcast", "reshape"}


def _fusion_bytes(body_lines, shapes):
    """Slicing-aware HBM traffic estimate for one fused computation.

    Returns ``(in_bytes, out_bytes_or_None, in_v2, out_v2_or_None)``.

    v1 (baseline metric):
    * a parameter consumed *only* by dynamic-slice/gather is charged at
      the consumers' result sizes (the kernel reads just the slices);
    * an in-place dynamic-update-slice *root* writes only the update
      region and passes the buffer parameter through untouched (TPU
      aliases it), so ``out_bytes`` is the update size;
    * everything else at full size (None means "use the fusion result").

    v2 (TPU estimate): additionally looks *through* unary convert/copy/
    bitcast chains around the DUS.  The CPU backend emulates bf16 matmuls
    in f32, wrapping the scan's stacked-gradient updates in full-buffer
    bf16<->f32 converts that do not exist in a TPU compile -- v2 charges
    those fusions at update size, which is what the TPU program does.
    """
    params: dict[str, float] = {}
    consumers: dict[str, list] = {}
    defs: dict[str, tuple] = {}
    root = None
    for ln in body_lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        res, type_str, op = m.groups()
        if op == "parameter":
            params[res] = _shapes_bytes(_parse_shapes(type_str))
            continue
        ops = _operands(ln, m.end() - 1)
        defs[res] = (op, ops)
        for pos, o in enumerate(ops):
            if o in params:
                # Only operand 0 of a slicing op is the sliced buffer;
                # index operands are ordinary (tiny) reads.
                kind = op if (op in _SLICING_OPS and pos == 0) else "_full"
                consumers.setdefault(o, []).append((kind, res))
        if ln.lstrip().startswith("ROOT"):
            root = (op, res, ops)

    def walk_back(name):
        while name in defs and defs[name][0] in _UNARY_PASSTHROUGH and defs[name][1]:
            name = defs[name][1][0]
        return name

    free_v1: set[str] = set()
    out_v1: float | None = None
    free_v2: set[str] = set()
    out_v2: float | None = None
    if root is not None:
        r_op, r_res, r_ops = root
        if r_op == "dynamic-update-slice" and len(r_ops) > 1:
            out_v1 = _shapes_bytes(shapes.get(r_ops[1], []))
            if r_ops[0] in params:
                free_v1.add(r_ops[0])
        # v2: root reachable from a DUS through unary ops, whose buffer
        # operand traces back to a parameter through unary ops.
        src = walk_back(r_res)
        if src in defs and defs[src][0] == "dynamic-update-slice":
            d_ops = defs[src][1]
            if len(d_ops) > 1:
                buf = walk_back(d_ops[0])
                if buf in params:
                    free_v2.add(buf)
                    upd = walk_back(d_ops[1])
                    out_v2 = _shapes_bytes(
                        shapes.get(d_ops[1], []) or shapes.get(upd, [])
                    )

    def charge(free):
        total = 0.0
        for p, pb in params.items():
            if p in free:
                continue
            cons = consumers.get(p, [])
            if cons and all(c_op in _SLICING_OPS for c_op, _ in cons):
                total += sum(_shapes_bytes(shapes.get(r, [])) for _, r in cons)
            else:
                total += pb
        return total

    free_v2 |= free_v1
    if out_v2 is None:
        out_v2 = out_v1
    return charge(free_v1), out_v1, charge(free_v2), out_v2


def analyze_module(hlo_text: str, scan_trips: list[int] | None = None) -> dict:
    """Roofline numerators with while-trip multipliers.

    scan_trips: trip counts by while-nesting depth (outermost first); a
    while at depth d multiplies its body by scan_trips[d] (1 if unknown).
    """
    scan_trips = scan_trips or []
    comps, entry = _split_computations(hlo_text)

    # Global name -> shapes table (names are unique module-wide).
    shapes: dict[str, list] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                shapes[m.group(1)] = _parse_shapes(m.group(2))

    per_comp: dict[str, dict] = {}
    for name, lines in comps.items():
        flops = 0.0
        mem_bytes = 0.0
        hbm_bytes = 0.0
        hbm_v2 = 0.0
        colls: dict[str, float] = defaultdict(float)
        children: list[tuple[str, str]] = []  # (kind, comp)
        n_coll = 0
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            res_name, type_str, op = m.groups()
            res_shapes = shapes.get(res_name, [])
            if op == "while":
                wb = _WHILE_BODY_RE.search(ln)
                if wb:
                    tm = _TRIP_RE.search(ln)
                    trip = int(tm.group(1)) if tm else None
                    children.append((("while", trip), wb.group(1)))
                continue
            if op == "conditional":
                cb = _COND_BRANCH_RE.search(ln)
                if cb:
                    for c in re.findall(r"%?([\w.\-]+)", cb.group(1)):
                        children.append(("branch", c))
            if op == "call":
                ca = _CALL_RE.search(ln)
                if ca:
                    children.append(("call", ca.group(1)))

            ops = _operands(ln, m.end() - 1)
            if op not in _SKIP_BYTES_OPS:
                op_bytes = _shapes_bytes(res_shapes) + sum(
                    _shapes_bytes(shapes.get(o, [])) for o in ops
                )
                mem_bytes += op_bytes  # raw: XLA-style fusion-level account
                if op in _HBM_OPS:
                    # Slicing-aware charge for the HBM metric.
                    if op == "fusion":
                        fc = _FUSION_CALLS_RE.search(ln)
                        body = comps.get(fc.group(1)) if fc else None
                        if body is not None:
                            in_b, out_b, in_b2, out_b2 = _fusion_bytes(
                                body, shapes
                            )
                            res_b = _shapes_bytes(res_shapes)
                            hbm_bytes += in_b + (
                                out_b if out_b is not None else res_b
                            )
                            hbm_v2 += in_b2 + (
                                out_b2 if out_b2 is not None else res_b
                            )
                        else:
                            hbm_bytes += op_bytes
                            hbm_v2 += op_bytes
                    elif op in _SLICING_OPS or op == "slice":
                        hbm_bytes += 2.0 * _shapes_bytes(res_shapes)
                        hbm_v2 += 2.0 * _shapes_bytes(res_shapes)
                    elif op == "dynamic-update-slice" and len(ops) > 1:
                        b = 2.0 * _shapes_bytes(shapes.get(ops[1], []))
                        hbm_bytes += b
                        hbm_v2 += b
                    else:
                        hbm_bytes += op_bytes
                        hbm_v2 += op_bytes

            if op == "dot":
                cd = _CONTRACT_RE.search(ln)
                lhs = shapes.get(ops[0], [("f32", [1])])[0][1] if ops else [1]
                k = 1
                if cd:
                    for d in cd.group(1).split(","):
                        if d:
                            k *= lhs[int(d)] if int(d) < len(lhs) else 1
                out_elems = (
                    math.prod(res_shapes[0][1]) if res_shapes and res_shapes[0][1] else 1
                )
                flops += 2.0 * out_elems * k

            kind = None
            for c in COLLECTIVE_OPS:
                if op == c or op == c + "-start":
                    kind = c
                    break
            if kind is not None:
                n_coll += 1
                op_bytes = sum(_shapes_bytes(shapes.get(o, [])) for o in ops)
                if op_bytes == 0:
                    op_bytes = _shapes_bytes(res_shapes)
                colls[kind] += op_bytes

        per_comp[name] = {
            "flops": flops, "bytes": mem_bytes, "bytes_hbm": hbm_bytes,
            "bytes_hbm_v2": hbm_v2,
            "colls": dict(colls), "children": children, "n_coll": n_coll,
        }

    totals = {"flops": 0.0, "bytes": 0.0, "bytes_hbm": 0.0, "bytes_hbm_v2": 0.0}
    coll_totals: dict[str, float] = defaultdict(float)
    n_coll_static = 0

    def visit(name: str, depth: int, mult: float, seen: frozenset):
        nonlocal n_coll_static
        if name not in per_comp or name in seen:
            return
        info = per_comp[name]
        totals["flops"] += info["flops"] * mult
        totals["bytes"] += info["bytes"] * mult
        totals["bytes_hbm"] += info["bytes_hbm"] * mult
        totals["bytes_hbm_v2"] += info["bytes_hbm_v2"] * mult
        for kind, b in info["colls"].items():
            coll_totals[kind] += b * mult
        n_coll_static += info["n_coll"]
        for kind, child in info["children"]:
            if isinstance(kind, tuple) and kind[0] == "while":
                trip = kind[1]
                if trip is None:
                    trip = scan_trips[depth] if depth < len(scan_trips) else 1
                visit(child, depth + 1, mult * trip, seen | {name})
            else:
                visit(child, depth, mult, seen | {name})

    if entry:
        visit(entry, 0, 1.0, frozenset())
    colls_out = dict(coll_totals)
    colls_out["total"] = float(sum(coll_totals.values()))
    return {
        "flops": totals["flops"],
        "bytes": totals["bytes"],
        "bytes_hbm": totals["bytes_hbm"],
        "bytes_hbm_v2": totals["bytes_hbm_v2"],
        "collectives": colls_out,
        "n_collectives_static": n_coll_static,
    }


# Backwards-compatible helpers -------------------------------------------------


def collective_bytes(hlo_text: str) -> dict:
    res = analyze_module(hlo_text, [])
    out = dict(res["collectives"])
    out["count"] = res["n_collectives_static"]
    return out


def collective_bytes_nested(hlo_text: str, scan_trips: list[int]) -> dict:
    res = analyze_module(hlo_text, scan_trips)
    out = dict(res["collectives"])
    out["count_static"] = res["n_collectives_static"]
    return out
