"""Analytic model statistics via abstract evaluation (no allocation)."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model


def abstract_params(cfg: ModelConfig):
    """Parameter tree of ShapeDtypeStructs (jax.eval_shape, no memory)."""
    return jax.eval_shape(
        lambda k: model.init_params(k, cfg), jax.random.key(0)
    )


def count_params(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return int(
        sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
    )


def count_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top-k routed + shared + dense)."""
    total = count_params(cfg)
    if not cfg.moe:
        return total
    e, k = cfg.n_routed_experts, cfg.moe_top_k
    l_moe = cfg.num_layers - cfg.first_dense_layers
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed_total = l_moe * e * per_expert
    routed_active = l_moe * k * per_expert
    return total - routed_total + routed_active
