import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first initialisation).  Everything else follows.

"""Multi-pod AOT dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod);
  2. constructs ShapeDtypeStruct stand-ins for every input (no allocation);
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``;
  4. records ``memory_analysis()``, ``cost_analysis()`` and the collective
     bytes parsed from the optimized per-device HLO;
  5. writes ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Sharding mismatches, OOM-at-compile or unsupported collectives fail the
cell -- they are bugs in the system, not in the driver.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only | --single-pod-only]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_context, make_production_mesh
from repro.models import model, partitioning
from repro.models.parallel import ParallelContext
from repro.optim import adamw
from repro.train import train_loop

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def cell_config(arch: str, shape: ShapeConfig) -> ModelConfig:
    cfg = get_config(arch)
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat=True)
    return cfg


def microbatches_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind != "train":
        return 1
    return 8 if cfg.d_model >= 2048 else 1


def input_specs(arch: str, shape_name: str, ctx: ParallelContext):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    shape = SHAPES[shape_name]
    cfg = cell_config(arch, shape)
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s)), "labels": sds((b, s))}
        if cfg.family == "audio":
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s))}
        if cfg.family == "audio":
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        return {"batch": batch}
    # decode: one new token against a cache of seq_len.
    cache = jax.eval_shape(
        lambda: model.init_decode_cache(None, cfg, b, s, ctx)
    )
    return {
        "tokens": sds((b,)),
        "cache": cache,
        "pos": sds((), i32),
    }


def _shardings(tree_specs, mesh):
    return partitioning.to_shardings(tree_specs, mesh)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, sync_variant=False):
    shape = SHAPES[shape_name]
    cfg = cell_config(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh, cfg.n_routed_experts if cfg.moe else 0)

    abs_params = jax.eval_shape(lambda k: model.init_params(k, cfg), jax.random.key(0))
    p_specs = partitioning.param_specs(abs_params, cfg, ctx)

    specs = input_specs(arch, shape_name, ctx)

    if shape.kind == "train":
        opt_cfg = adamw.OptimConfig()
        mb = microbatches_for(cfg, shape)
        step = train_loop.make_train_step(
            cfg, opt_cfg, ctx, sync=sync_variant, microbatches=mb
        )
        abs_state = jax.eval_shape(
            lambda k: train_loop.init_state(k, cfg, ctx), jax.random.key(0)
        )
        state_specs = train_loop.TrainState(
            params=p_specs,
            opt=adamw.OptState(
                m=partitioning.zero1_specs(p_specs, abs_params, ctx),
                v=partitioning.zero1_specs(p_specs, abs_params, ctx),
                step=jax.sharding.PartitionSpec(),
            ),
            balancer=(
                partitioning.balancer_specs(abs_state.balancer, ctx)
                if abs_state.balancer is not None
                else None
            ),
            step=jax.sharding.PartitionSpec(),
        )
        batch_specs = partitioning.batch_specs(specs["batch"], ctx)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(
                    _shardings(state_specs, mesh),
                    _shardings(batch_specs, mesh),
                ),
                out_shardings=(_shardings(state_specs, mesh), None),
            ).lower(abs_state, specs["batch"])
        scan_trips = [model.num_scanned_layers(cfg)]
        if mb > 1:
            scan_trips = [mb, model.num_scanned_layers(cfg)]
    elif shape.kind == "prefill":

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cfg, ctx, cache_len=shape.seq_len)

        batch_specs = partitioning.batch_specs(specs["batch"], ctx)
        with mesh:
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(
                    _shardings(p_specs, mesh),
                    _shardings(batch_specs, mesh),
                ),
            ).lower(abs_params, specs["batch"])
        scan_trips = [model.num_scanned_layers(cfg)]
    else:  # decode

        def decode_fn(params, tokens, cache, pos):
            return model.decode_step(params, tokens, cache, pos, cfg, ctx)

        cache_specs = partitioning.cache_specs(specs["cache"], ctx)
        tok_specs = partitioning.batch_specs(specs["tokens"], ctx)
        with mesh:
            lowered = jax.jit(
                decode_fn,
                in_shardings=(
                    _shardings(p_specs, mesh),
                    _shardings(tok_specs, mesh),
                    _shardings(cache_specs, mesh),
                    jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                ),
                out_shardings=(None, _shardings(cache_specs, mesh)),
            ).lower(specs_params_placeholder(abs_params), specs["tokens"], specs["cache"], specs["pos"])
        scan_trips = [model.num_scanned_layers(cfg)]
    return lowered, mesh, cfg, scan_trips


def specs_params_placeholder(abs_params):
    return abs_params


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             sync_variant: bool = False, force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + ("__sync" if sync_variant else "")
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "sync_variant": sync_variant, "ok": False,
    }
    try:
        lowered, mesh, cfg, scan_trips = lower_cell(
            arch, shape_name, multi_pod=multi_pod, sync_variant=sync_variant
        )
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        analysis = hlo_analysis.analyze_module(hlo, scan_trips)
        coll = analysis["collectives"]
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            memory={
                k: int(getattr(mem, k, 0) or 0)
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
            },
            cost={
                k: float(cost.get(k, 0.0))
                for k in ("flops", "bytes accessed")
                if isinstance(cost, dict)
            },
            hlo_flops=analysis["flops"],
            hlo_bytes=analysis["bytes"],
            hlo_bytes_hbm=analysis["bytes_hbm"],
            hlo_bytes_hbm_v2=analysis["bytes_hbm_v2"],
            collectives=coll,
            scan_trips=scan_trips,
            num_devices=int(np.prod(list(mesh.shape.values()))),
            hlo_text_len=len(hlo),
        )
        print(
            f"[dryrun] OK  {tag}  lower={rec['lower_s']}s compile={rec['compile_s']}s "
            f"flops={rec['cost'].get('flops', 0):.3e}"
        )
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {tag}: {rec['error'][:200]}")
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sync-variant", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    todo = []
    if args.all:
        for arch, shape_name, skip in cells():
            todo.append((arch, shape_name))
    else:
        todo.append((args.arch, args.shape))

    n_ok = n_fail = 0
    for arch, shape_name in todo:
        for mp in meshes:
            rec = run_cell(
                arch, shape_name, multi_pod=mp, out_dir=out_dir,
                force=args.force, sync_variant=args.sync_variant,
            )
            n_ok += int(rec["ok"])
            n_fail += int(not rec["ok"])
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
