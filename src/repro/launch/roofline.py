"""Roofline terms from dry-run artifacts (deliverable g).

This container is CPU-only, so instead of measuring wall-clock MFU the
three roofline terms are derived from the compiled dry-run artifact of each
(arch x shape x mesh) cell:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_chip / HBM_bandwidth_per_chip
    collective = collective_bytes_per_chip / ICI_link_bandwidth

where the per-chip numerators come from ``hlo_analysis.analyze_module``
over the post-SPMD (per-device-shard shapes) optimized HLO, with lax.scan
while-bodies multiplied by their trip counts (XLA's own cost_analysis
counts loop bodies once, silently dropping a num_layers factor).

The collective term charges a single ICI link per chip -- a v5e chip has
multiple links, so this is the conservative (upper) estimate; ring
collectives on an axis of size A move ~(A-1)/A of the gathered bytes over
each link, which the per-chip operand-byte sum approximates well.

Hardware model: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# Tokens processed per step, per shape (global).
_SHAPE_TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,  # one new token per sequence
    "long_500k": 1,
}
_TRAIN_SHAPES = {"train_4k"}


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6ND / 2ND (global)
    hlo_flops_chip: float
    useful_ratio: float  # model_flops / (hlo_flops_chip * chips)
    step_s: float  # max of the three terms
    mfu: float  # model_flops / (chips * peak * step_s)
    coll_bytes: float
    hbm_bytes: float
    temp_bytes: int
    note: str = ""
    tag: str = ""


def load_artifacts(pattern: str = "*.json", art_dir: Path | None = None) -> list[dict]:
    art_dir = art_dir or ARTIFACTS
    recs = []
    for p in sorted(art_dir.glob(pattern)):
        rec = json.loads(p.read_text())
        if rec.get("ok"):
            recs.append(rec)
    return recs


def model_flops_for(arch: str, shape: str, n_active: int) -> float:
    tokens = _SHAPE_TOKENS.get(shape, 1)
    per_token = 6.0 if shape in _TRAIN_SHAPES else 2.0
    return per_token * n_active * tokens


def _note(c: "CellRoofline") -> str:
    if c.dominant == "collective":
        return (
            "collective-bound: reshard/weight gathers dominate; move the "
            "offending operand onto the mesh axis it is consumed on or "
            "overlap the gather with the preceding layer's compute"
        )
    if c.dominant == "memory":
        if "decode" in c.shape or "long" in c.shape:
            return (
                "memory-bound (expected for decode: weights+KV read per "
                "token); raise per-chip batch or shrink the KV working set "
                "(GQA/MLA already help) to amortise the weight stream"
            )
        return (
            "memory-bound: working set streams from HBM; fuse, widen the "
            "per-chip tile or raise arithmetic intensity (larger per-device "
            "batch) to move toward the compute roof"
        )
    if c.useful_ratio < 0.5:
        return (
            "compute-bound but low useful ratio: remat recompute and/or "
            "padding dominate FLOPs; relax the checkpoint policy or align "
            "tile shapes to reclaim headroom"
        )
    return (
        "compute-bound with high useful ratio: near the practical roof; "
        "remaining headroom is kernel efficiency (MXU utilisation)"
    )


def cell_roofline(rec: dict, n_active: int) -> CellRoofline:
    chips = rec["num_devices"]
    flops_chip = float(rec.get("hlo_flops") or rec["cost"].get("flops", 0.0))
    # Prefer the v2 (TPU in-place DUS) estimate when present; fall back to
    # the baseline metric so old artifacts stay readable.
    bytes_chip = float(
        rec.get("hlo_bytes_hbm_v2")
        or rec.get("hlo_bytes_hbm")
        or rec.get("hlo_bytes")
        or rec["cost"].get("bytes accessed", 0.0)
    )
    coll = float(rec["collectives"].get("total", 0.0))
    compute_s = flops_chip / PEAK_FLOPS
    memory_s = bytes_chip / HBM_BW
    collective_s = coll / ICI_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops_for(rec["arch"], rec["shape"], n_active)
    step_s = max(terms.values())
    useful = mf / max(flops_chip * chips, 1e-30)
    mfu = mf / (chips * PEAK_FLOPS * max(step_s, 1e-30))
    c = CellRoofline(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_chip=flops_chip,
        useful_ratio=useful,
        step_s=step_s,
        mfu=mfu,
        coll_bytes=coll,
        hbm_bytes=bytes_chip,
        temp_bytes=rec["memory"]["temp_size_in_bytes"],
        tag=f"{rec['arch']}__{rec['shape']}__{rec['mesh']}",
    )
    c.note = _note(c)
    return c


def active_params_table() -> dict[str, int]:
    """6ND 'N' per arch: total params for dense, active for MoE."""
    from repro.configs import ARCH_IDS, get_config  # late: keeps module light
    from repro.launch import model_stats

    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        out[arch] = model_stats.count_active_params(cfg)
    return out


def full_table(art_dir: Path | None = None) -> list[CellRoofline]:
    n_active = active_params_table()
    cells = []
    for rec in load_artifacts(art_dir=art_dir):
        if rec.get("sync_variant"):
            continue
        cells.append(cell_roofline(rec, n_active[rec["arch"]]))
    return cells


def markdown_table(cells: list[CellRoofline]) -> str:
    hdr = (
        "| cell | chips | compute (s) | memory (s) | collective (s) | "
        "dominant | useful 6ND/HLO | roofline MFU |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for c in cells:
        lines.append(
            f"| {c.arch} / {c.shape} / {c.mesh} | {c.chips} "
            f"| {c.compute_s:.3e} | {c.memory_s:.3e} | {c.collective_s:.3e} "
            f"| **{c.dominant}** | {c.useful_ratio:.2f} | {c.mfu:.1%} |"
        )
    return hdr + "\n".join(lines)
