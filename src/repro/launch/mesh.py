"""Production mesh construction.

Pure functions only -- importing this module never touches jax device
state; ``make_production_mesh`` is called by the dry-run (under 512 fake
host devices) and by the real launcher (under actual TPU topology).
"""
from __future__ import annotations

import jax

from repro.models.parallel import ParallelContext, choose_ep_axes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_context(mesh, num_experts: int = 0) -> ParallelContext:
    """ParallelContext for a production mesh (handles the pod axis)."""
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a in ("pod", "data"))
    tp_axis = "model"
    if num_experts:
        ep_axes, fsdp = choose_ep_axes(mesh, num_experts, dp_axes, tp_axis)
    else:
        ep_axes, fsdp = (tp_axis,), None
    return ParallelContext(
        mesh=mesh, dp_axes=dp_axes, tp_axis=tp_axis, ep_axes=ep_axes,
        fsdp_axis=fsdp,
    )


def make_debug_mesh(devices=None, shape=(2, 4)):
    """Small mesh for multi-device CPU tests (subprocess with fake devices)."""
    import numpy as np

    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(shape))
    arr = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, ("data", "model"))
