"""Where do the roofline bytes come from?  Per-computation HBM breakdown.

The perf loop's "profiler": re-lowers one cell, applies the same
slicing-aware charging as ``hlo_analysis.analyze_module``, and attributes
the result to (computation, loop-multiplier) pairs and to the largest
individual instructions -- enough to decide *what* to optimise next
without a real-TPU trace (EXPERIMENTS.md Section Perf methodology).

Usage:
  python -m repro.launch.hlo_breakdown --arch rwkv6-1.6b --shape train_4k
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
from collections import defaultdict  # noqa: E402


def charged_bytes(ln, op, res, ops, shapes, comps, hlo_analysis):
    """Slicing-aware HBM charge for one instruction (mirrors analyze_module)."""
    res_b = hlo_analysis._shapes_bytes(shapes.get(res, []))
    if op == "fusion":
        fc = hlo_analysis._FUSION_CALLS_RE.search(ln)
        body = comps.get(fc.group(1)) if fc else None
        if body is not None:
            ib, ob, _ib2, _ob2 = hlo_analysis._fusion_bytes(body, shapes)
            return ib + (ob if ob is not None else res_b)
    if op in hlo_analysis._SLICING_OPS or op == "slice":
        return 2 * res_b
    if op == "dynamic-update-slice" and len(ops) > 1:
        return 2 * hlo_analysis._shapes_bytes(shapes.get(ops[1], []))
    return res_b + sum(
        hlo_analysis._shapes_bytes(shapes.get(o, [])) for o in ops
    )


def breakdown(hlo: str, scan_trips, top_comps=6, top_instr=6):
    from repro.launch import hlo_analysis

    comps, entry = hlo_analysis._split_computations(hlo)
    shapes = {}
    for lines in comps.values():
        for ln in lines:
            m = hlo_analysis._DEF_RE.match(ln)
            if m:
                shapes[m.group(1)] = hlo_analysis._parse_shapes(m.group(2))

    per = {}
    for name, lines in comps.items():
        rows, ch = [], []
        for ln in lines:
            m = hlo_analysis._DEF_RE.match(ln)
            if not m:
                continue
            res, _ts, op = m.groups()
            if op == "while":
                wb = hlo_analysis._WHILE_BODY_RE.search(ln)
                if wb:
                    tm = hlo_analysis._TRIP_RE.search(ln)
                    ch.append((int(tm.group(1)) if tm else None, wb.group(1)))
                continue
            if op in hlo_analysis._SKIP_BYTES_OPS or op not in hlo_analysis._HBM_OPS:
                continue
            ops = hlo_analysis._operands(ln, m.end() - 1)
            b = charged_bytes(ln, op, res, ops, shapes, comps, hlo_analysis)
            rows.append((b, op, ln.strip()))
        per[name] = (rows, ch)

    agg = defaultdict(float)
    detail = defaultdict(list)

    def visit(name, depth, mult, seen):
        if name not in per or name in seen:
            return
        rows, ch = per[name]
        for b, op, ln in rows:
            agg[(name, mult)] += b * mult
            detail[(name, mult)].append((b * mult, op, ln))
        for trip, c in ch:
            t = trip if trip is not None else (
                scan_trips[depth] if depth < len(scan_trips) else 1
            )
            visit(c, depth + 1, mult * t, seen | {name})

    visit(entry, 0, 1.0, frozenset())
    total = sum(agg.values())
    out = [f"total bytes_hbm: {total:.3e}  ({total / 819e9:.2f}s at 819 GB/s)"]
    for (n, m), v in sorted(agg.items(), key=lambda kv: -kv[1])[:top_comps]:
        out.append(f"\n== {n}  (mult={m:.0f}): {v:.3e}  [{v/total:.0%}]")
        for b, op, ln in sorted(detail[(n, m)], reverse=True)[:top_instr]:
            out.append(f"   {b:.2e} {op:10s} {ln[:120]}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top-comps", type=int, default=6)
    ap.add_argument("--top-instr", type=int, default=6)
    args = ap.parse_args()

    from repro.launch import dryrun

    lowered, _mesh, _cfg, scan_trips = dryrun.lower_cell(
        args.arch, args.shape, multi_pod=args.multi_pod
    )
    hlo = lowered.compile().as_text()
    print(breakdown(hlo, scan_trips, args.top_comps, args.top_instr))


if __name__ == "__main__":
    main()
