"""Pallas TPU kernel: fused CARE-biased MoE router.

Fuses the expert-routing hot path of the MoE archs into one VMEM-resident
pass per token tile:

  gate activation -> CARE load-bias -> iterative top-k -> weight
  normalisation -> per-expert dispatch counts

The CARE connection: the selection score is ``logit - bias`` where ``bias``
is derived from the balancer's *approximated* per-expert load (JSAQ applied
to the gate's candidate set).  Like DeepSeek-v3's aux-free balancing, the
bias shifts only the *selection*, never the combine weights -- but here the
bias is maintained by the paper's emulation + sparse sync instead of a
per-step exact update.

Layout / tiling:
* tokens on the sublane axis, experts on the lane axis: a (Tt, E) tile with
  Tt=128 tokens and E<=256 experts is at most 128KiB of VMEM in f32;
* top-k is k sequential masked argmax sweeps over the tile (k<=8, static);
* counts are accumulated across the sequential grid into a single (1, E)
  output block (same block for every program -- the canonical Pallas
  reduction pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOKEN_TILE = 128
NEG_INF = -1e30


def _moe_route_kernel(
    logits_ref,
    bias_ref,
    idx_ref,
    weight_ref,
    counts_ref,
    *,
    top_k: int,
    gate_fn: str,
):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    logits = logits_ref[...].astype(jnp.float32)  # (Tt, E)
    bias = bias_ref[...].astype(jnp.float32)  # (1, E)

    if gate_fn == "softmax":
        z = logits - jnp.max(logits, axis=1, keepdims=True)
        ez = jnp.exp(z)
        gates = ez / jnp.sum(ez, axis=1, keepdims=True)
    elif gate_fn == "sigmoid":
        gates = jax.nn.sigmoid(logits)
    else:
        raise ValueError(gate_fn)

    score = logits - bias  # selection score only; weights stay unbiased
    tile_counts = jnp.zeros(bias.shape, jnp.int32)
    weight_sum = jnp.zeros((logits.shape[0], 1), jnp.float32)
    eids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)

    sel_weights = []
    sel_idx = []
    for i in range(top_k):
        j = jnp.argmax(score, axis=1).astype(jnp.int32)  # (Tt,)
        onehot = (eids == j[:, None]).astype(jnp.float32)
        w = jnp.sum(gates * onehot, axis=1, keepdims=True)  # (Tt, 1)
        sel_idx.append(j[:, None])
        sel_weights.append(w)
        weight_sum = weight_sum + w
        tile_counts = tile_counts + jnp.sum(
            onehot.astype(jnp.int32), axis=0, keepdims=True
        )
        score = jnp.where(onehot > 0, NEG_INF, score)

    idx_ref[...] = jnp.concatenate(sel_idx, axis=1)
    weights = jnp.concatenate(sel_weights, axis=1)
    weight_ref[...] = (weights / (weight_sum + 1e-20)).astype(weight_ref.dtype)
    counts_ref[...] += tile_counts


def moe_route_pallas(
    logits: jax.Array,
    bias: jax.Array,
    top_k: int,
    *,
    gate_fn: str = "softmax",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused biased top-k routing.

    Args:
      logits: (T, E) router logits (f32 or bf16).
      bias: (E,) CARE load bias subtracted from the selection score.
      top_k: experts per token (static, <= 8 typical).
      gate_fn: "softmax" (deepseek-v2) or "sigmoid" (deepseek-v3).

    Returns:
      idx: (T, top_k) int32 expert ids, in selection order.
      weights: (T, top_k) f32 combine weights, normalised over selected.
      counts: (E,) int32 tokens dispatched per expert.
    """
    t, e = logits.shape
    if t % TOKEN_TILE:
        raise ValueError(f"tokens ({t}) must be a multiple of {TOKEN_TILE}")
    grid = (t // TOKEN_TILE,)
    kernel = functools.partial(_moe_route_kernel, top_k=top_k, gate_fn=gate_fn)
    idx, weights, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TOKEN_TILE, e), lambda i: (i, 0)),
            pl.BlockSpec((1, e), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TOKEN_TILE, top_k), lambda i: (i, 0)),
            pl.BlockSpec((TOKEN_TILE, top_k), lambda i: (i, 0)),
            pl.BlockSpec((1, e), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, top_k), jnp.int32),
            jax.ShapeDtypeStruct((t, top_k), jnp.float32),
            jax.ShapeDtypeStruct((1, e), jnp.int32),
        ],
        interpret=interpret,
    )(logits, bias.reshape(1, e))
    return idx, weights, counts[0]
