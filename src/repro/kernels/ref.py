"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` mirrors its kernel's semantics exactly -- including
tie-breaking (iterative argmin/argmax, first-index wins) -- so tests can
``assert_allclose`` bit-for-bit on integer outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def jsaq_route_ref(q_app: jax.Array, num_jobs: int):
    """Oracle for jsaq_route: sequential argmin + increment per domain."""

    def body(q, _):
        j = jnp.argmin(q, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(j, q.shape[1], dtype=q.dtype)
        return q + onehot, j

    q_out, idx = jax.lax.scan(body, q_app, None, length=num_jobs)
    return jnp.swapaxes(idx, 0, 1), q_out


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float,
    causal: bool = True,
    window=None,
    softcap: float = 0.0,
):
    """Oracle for flash_attention: dense softmax SDPA, f32 accumulation.

    q: (B, S, H, dh); k, v: (B, T, KVH, dh/dv) -> (B, S, H, dv).
    """
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    sc = jnp.einsum(
        "bskgd,btkd->bskgt", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    if causal:
        qpos = jnp.arange(s, dtype=jnp.int32)[None, :, None, None, None]
        kpos = jnp.arange(t, dtype=jnp.int32)[None, None, None, None, :]
        ok = kpos <= qpos
        if window is not None:
            ok = ok & (qpos - kpos < window)
        sc = jnp.where(ok, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p.astype(v.dtype), v)
    return out.astype(q.dtype).reshape(b, s, h, v.shape[3])


def moe_route_ref(
    logits: jax.Array, bias: jax.Array, top_k: int, gate_fn: str = "softmax"
):
    """Oracle for moe_route: iterative masked argmax, unbiased weights."""
    logits = logits.astype(jnp.float32)
    if gate_fn == "softmax":
        gates = jax.nn.softmax(logits, axis=1)
    elif gate_fn == "sigmoid":
        gates = jax.nn.sigmoid(logits)
    else:
        raise ValueError(gate_fn)

    score = logits - bias[None, :].astype(jnp.float32)
    idx_list, w_list = [], []
    counts = jnp.zeros((logits.shape[1],), jnp.int32)
    for _ in range(top_k):
        j = jnp.argmax(score, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(j, logits.shape[1], dtype=jnp.float32)
        w = jnp.sum(gates * onehot, axis=1)
        idx_list.append(j)
        w_list.append(w)
        counts = counts + jnp.sum(onehot.astype(jnp.int32), axis=0)
        score = jnp.where(onehot > 0, -1e30, score)
    idx = jnp.stack(idx_list, axis=1)
    weights = jnp.stack(w_list, axis=1)
    weights = weights / (jnp.sum(weights, axis=1, keepdims=True) + 1e-20)
    return idx, weights, counts
