"""Pallas TPU kernel: flash attention (tiled online-softmax SDPA).

The roofline analysis (EXPERIMENTS.md §Perf) shows the f32 score chain of
naive softmax(QK^T)V is the dominant HBM term of every attention arch at
the assigned shapes.  The pure-JAX blocked form (``models/flash.py``)
restructures the HLO; *this* kernel is the TPU endpoint: Q/K/V tiles are
staged into VMEM by the BlockSpec pipeline and the (QB, KB) score tile
lives only in VMEM/VREGs -- the S² tensor never touches HBM.

Tiling:
* grid = (N, S/QB, T/KB) with the KV axis innermost (sequential online
  accumulation); N = batch x heads.
* q tile (QB, dh) on the MXU lhs; scores (QB, KB) with QB=KB=128 are
  exactly one MXU-aligned tile; dv accumulates in an f32 VMEM scratch.
* The running max/denominator (m, l) are (QB, 1) VMEM scratch, carried
  across the KV grid axis -- the canonical flash recurrence.

Semantics match ``kernels.ref.flash_attention_ref`` (and
``models/flash.py``): scale -> optional softcap -> causal/window mask ->
online softmax in f32 -> weighted sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 128
KV_BLOCK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, softcap: float, kv_steps: int,
):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (QB, dh)
    k = k_ref[0]  # (KB, dh)
    v = v_ref[0]  # (KB, dv)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (QB, KB)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qpos = i * Q_BLOCK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = j * KV_BLOCK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        ok = kpos <= qpos
        if window is not None:
            ok = ok & (qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]  # (QB, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (QB, KB)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    """Tiled SDPA.  q: (N, S, dh); k: (N, T, dh); v: (N, T, dv).

    N folds batch x heads (GQA callers broadcast KV heads in the wrapper,
    ``kernels.ops.flash_attention``).  S % 128 == T % 128 == 0.
    Returns (N, S, dv) in q.dtype.
    """
    n, s, dh = q.shape
    t = k.shape[1]
    dv = v.shape[2]
    if s % Q_BLOCK or t % KV_BLOCK:
        raise ValueError(f"S ({s}) and T ({t}) must be multiples of 128")
    kv_steps = t // KV_BLOCK
    grid = (n, s // Q_BLOCK, kv_steps)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_steps=kv_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q_BLOCK, dh), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, KV_BLOCK, dh), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, KV_BLOCK, dv), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_BLOCK, dv), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Q_BLOCK, dv), jnp.float32),
            pltpu.VMEM((Q_BLOCK, 1), jnp.float32),
            pltpu.VMEM((Q_BLOCK, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
