"""Pallas TPU kernel family: fused CARE routing at mean-field scale.

Join-the-Shortest-Approximated-Queue routes each arriving job to the argmin
of the balancer's approximated queue vector and immediately increments that
entry (the balancer knows its own routing decisions -- Eq. 10 in the paper).
The per-job decision is inherently sequential, which is hostile to a SIMD
machine; the TPU adaptation is:

* vectorise over *independent balancer domains* (rows) -- parallel
  simulation replicas, per-device dispatchers, grid runs -- so each VPU
  lane group advances a different domain;
* keep the whole (domain_tile, K) state resident in VMEM across the
  sequential inner loop, so the route/trigger/update chain never touches
  HBM between slots.

Three kernels share the layout and the segmented reduction:

* :func:`jsaq_route_pallas` -- the seed kernel: route ``num_jobs`` jobs by
  sequential JSAQ from a given state (consumed by ``kernels/ops.py`` and
  the kernel unit tests).
* :func:`care_route_pallas` -- the mean-field simulator kernel: the whole
  ``T``-slot CARE loop (route + admit + deterministic service + MSR
  emulation drain + RT/DT/ET/ET+RT/exact trigger + snap) fused into one
  kernel invocation, so a million-server cell never materialises per-slot
  (K,)-sized intermediates in HBM.  Decision-identical to the dense
  ``slotted_sim`` path under ``deterministic_ties`` (asserted by
  ``tests/test_route_backend.py``).
* :func:`serve_route_pallas` -- the serving engine's within-slot arrival
  lane loop (sequential routing over the slot's arrival batch with the
  occupancy/approximation state resident), replacing the dense
  ``lax.scan`` lane body of ``serve/engine.py``.

Segmented-reduction layout
--------------------------

Domains live on the sublane axis (tile of :data:`DOMAIN_TILE` = 8),
servers K on the lane axis padded to the 128-wide lane tile
(:data:`LANE_TILE`) -- the natural (8, 128) VREG shape.  When K exceeds
one lane tile, :func:`seg_argmin` replaces the full-width argmin with a
segmented reduction: a sequential ``fori_loop`` over 128-lane tiles
carries the running per-lane-slot minimum ``vmin`` and the tile index
``tmin`` that achieved it (strict ``<`` keeps the *earliest* tile on
ties), then one cross-tile combine recovers the global argmin as the
minimum global index ``tmin * 128 + lane`` among lanes achieving the
global minimum.  Ties therefore resolve to the lowest *global* server
index, matching ``jnp.argmin`` and the simulators' ``deterministic_ties``
mode exactly.  Only the two (tile, 128) carries are live at any point, so
the reduction working set is independent of K.

Pad-lane safety: callers (``kernels/ops.py``) pad the server axis to a
lane-tile multiple with ``int32`` max / ``+inf`` *before* the call, and
the stateful kernels additionally mask scores with an in-kernel
``lane < servers`` validity mask -- a pad lane can never win the argmin,
never triggers a message, and never contributes to the max/min metrics.

VMEM budget: :func:`care_route_pallas` keeps ~7 (domain_tile, K) int32
carries resident; at one domain row per program that is ~28 bytes/server,
so K = 10^6 wants ~28 MB -- beyond a single TPU core's VMEM.  At that
scale run one domain per program (``domain_tile`` adapts automatically)
and shorten to f16 carries or block the lane axis across the grid; under
the interpreter (CPU CI and the benchmarks here) the arrays live in host
memory and the full sweep runs unmodified.

Grid: one program per domain tile; slots/jobs/lanes are the sequential
``fori_loop`` inside the kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DOMAIN_TILE = 8
LANE_TILE = 128

_I32_MAX = jnp.iinfo(jnp.int32).max


def lane_pad(k: int) -> int:
    """The server axis padded up to a full lane-tile multiple."""
    return max(LANE_TILE, ((k + LANE_TILE - 1) // LANE_TILE) * LANE_TILE)


def domain_tile(d: int) -> int:
    """Largest tile dividing ``d`` (<= DOMAIN_TILE), so no domain padding."""
    return math.gcd(d, DOMAIN_TILE)


def seg_argmin(score: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Row-wise argmin via a segmented lane-tile reduction.

    Args:
      score: (Dt, Kp) values; ``Kp`` must be a multiple of
        :data:`LANE_TILE` when it exceeds one tile.  Callers lift invalid
        (padding) lanes to ``int32`` max / ``+inf`` beforehand.

    Returns:
      ``(j, vmin)``: (Dt, 1) argmin indices (ties -> lowest global index,
      matching ``jnp.argmin``) and (Dt, 1) minimum values.

    For ``Kp`` within one lane tile this is a plain full-width reduction.
    Beyond that, a ``fori_loop`` over 128-lane tiles carries the running
    per-lane-slot minimum and the (earliest) tile achieving it -- the
    working set stays (Dt, 128) regardless of K -- and a final cross-tile
    combine takes the minimum global index among lanes achieving the
    global minimum (a plain lane argmin would return the lowest *lane*,
    not the lowest global index).
    """
    d, kp = score.shape
    if kp <= LANE_TILE:
        vmin = jnp.min(score, axis=1, keepdims=True)
        lane = jax.lax.broadcasted_iota(jnp.int32, score.shape, 1)
        j = jnp.min(
            jnp.where(score == vmin, lane, _I32_MAX), axis=1, keepdims=True
        )
        return j, vmin
    if kp % LANE_TILE:
        raise ValueError(
            f"lane axis ({kp}) beyond one tile must be a multiple of "
            f"{LANE_TILE}"
        )
    nt = kp // LANE_TILE

    def tile(i, carry):
        vmin, tmin = carry
        blk = jax.lax.dynamic_slice(score, (0, i * LANE_TILE), (d, LANE_TILE))
        better = blk < vmin  # strict: ties keep the earliest tile
        return jnp.where(better, blk, vmin), jnp.where(better, i, tmin)

    v0 = jax.lax.dynamic_slice(score, (0, 0), (d, LANE_TILE))
    vmin, tmin = jax.lax.fori_loop(
        1, nt, tile, (v0, jnp.zeros((d, LANE_TILE), jnp.int32))
    )
    lane = jax.lax.broadcasted_iota(jnp.int32, (d, LANE_TILE), 1)
    gidx = tmin * LANE_TILE + lane
    gmin = jnp.min(vmin, axis=1, keepdims=True)
    j = jnp.min(jnp.where(vmin == gmin, gidx, _I32_MAX), axis=1, keepdims=True)
    return j, gmin


# ---------------------------------------------------------------------------
# Seed kernel: batched JSAQ dispatch from a given state.
# ---------------------------------------------------------------------------


def _jsaq_kernel(q_ref, idx_ref, qout_ref, *, num_jobs: int):
    """One domain-tile: route ``num_jobs`` jobs sequentially per domain.

    Pad lanes (if any) carry ``int32`` max from the wrapper, so the
    segmented argmin can never route to them.
    """
    q = q_ref[...].astype(jnp.int32)

    def body(n, q):
        j, _ = seg_argmin(q)  # (Dt, 1); ties -> lowest index
        idx_ref[:, pl.dslice(n, 1)] = j
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, q.shape, 1) == j
        ).astype(q.dtype)
        return q + onehot

    q = jax.lax.fori_loop(0, num_jobs, body, q)
    qout_ref[...] = q.astype(qout_ref.dtype)


def jsaq_route_pallas(
    q_app: jax.Array, num_jobs: int, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Route ``num_jobs`` jobs per domain by sequential JSAQ.

    Args:
      q_app: (D, K) int32 approximated queue lengths, one row per domain.
        ``K`` beyond one lane tile must be a multiple of 128, with pad
        lanes pre-masked to ``int32`` max (``kernels/ops.py`` handles
        both).
      num_jobs: number of jobs to dispatch per domain (static).
      interpret: run the Pallas interpreter (CPU validation).

    Returns:
      (idx, q_out): (D, num_jobs) int32 chosen servers (ties -> lowest
      index), and the post-dispatch state (D, K).
    """
    d, k = q_app.shape
    if d % DOMAIN_TILE:
        raise ValueError(f"domains ({d}) must be a multiple of {DOMAIN_TILE}")
    grid = (d // DOMAIN_TILE,)
    kernel = functools.partial(_jsaq_kernel, num_jobs=num_jobs)
    idx, q_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((DOMAIN_TILE, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((DOMAIN_TILE, num_jobs), lambda i: (i, 0)),
            pl.BlockSpec((DOMAIN_TILE, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, num_jobs), jnp.int32),
            jax.ShapeDtypeStruct((d, k), q_app.dtype),
        ],
        interpret=interpret,
    )(q_app)
    return idx, q_out


# ---------------------------------------------------------------------------
# Mean-field simulator kernel: the whole CARE slot loop, fused.
# ---------------------------------------------------------------------------


def _care_kernel(
    arrive_ref,
    params_ref,
    routed_ref,
    qtrue_ref,
    persrv_ref,
    stats_ref,
    *,
    servers: int,
    cap: int,
    policy: str,
    comm: str,
):
    """One domain-tile: fused CARE trigger+route loop over all slots.

    Mirrors ``slotted_sim._sim_core`` operation for operation under its
    mean-field restrictions (deterministic service of ``msr_slots`` per
    job, MSR emulation, unit rates, deterministic lowest-index ties), so
    the two paths are bit-identical -- but with all (Dt, K) state as
    ``fori_loop`` carries (VMEM-resident on TPU) and no per-job FIFO
    ring, per-slot PRNG keys or one-hot HBM traffic.

    ``params_ref`` carries the per-domain scenario scalars
    ``[x, rt_period, msr_slots, horizon]`` (int32); ``servers`` masks the
    pad lanes; ``cap``/``policy``/``comm`` are trace-time.
    """
    dt, kp = qtrue_ref.shape
    slots = arrive_ref.shape[1]
    arrive = arrive_ref[...]
    x = params_ref[:, 0:1]
    rt_period = params_ref[:, 1:2]
    msr = params_ref[:, 2:3]
    horizon = params_ref[:, 3:4]
    lane = jax.lax.broadcasted_iota(jnp.int32, (dt, kp), 1)
    valid = lane < servers
    zeros = jnp.zeros((dt, kp), jnp.int32)
    zeros1 = jnp.zeros((dt, 1), jnp.int32)

    def slot(t, st):
        (q, qa, hr, eh, ds, ss, ps,
         msgs, deps, arrs, drops, max_aq, max_q, gap) = st
        act = t < horizon  # (dt, 1) bool; pad domains carry horizon 0
        arr = jax.lax.dynamic_slice(arrive, (0, t), (dt, 1))
        arr = (arr > 0) & act

        # --- 1. arrival & routing (lowest-index ties) ----------------
        score = qa if policy == "jsaq" else q
        j, _ = seg_argmin(jnp.where(valid, score, _I32_MAX))
        onehot = lane == j
        q_sel = jnp.sum(jnp.where(onehot, q, 0), axis=1, keepdims=True)
        admit = arr & (q_sel < cap)
        drops = drops + (arr & ~admit).astype(jnp.int32)
        sel = onehot & admit
        hr = jnp.where(sel & (q == 0), msr, hr)
        q = q + sel.astype(jnp.int32)
        was_empty = qa == 0
        qa = qa + sel.astype(jnp.int32)
        eh = jnp.where(sel & was_empty, msr, eh)
        arrs = arrs + admit.astype(jnp.int32)
        ps = ps + sel.astype(jnp.int32)
        routed_ref[:, pl.dslice(t, 1)] = jnp.where(admit, j, -1)

        # --- 2. service (deterministic msr_slots-sized jobs) ----------
        busy = (q > 0) & act
        hr = jnp.where(busy, hr - 1, hr)
        dep = busy & (hr <= 0)
        q = jnp.where(dep, q - 1, q)
        hr = jnp.where(dep & (q > 0), msr, hr)
        deps = deps + jnp.sum(dep.astype(jnp.int32), axis=1, keepdims=True)

        # --- 3. MSR emulation drain -----------------------------------
        ticking = (qa > 0) & act
        eh = jnp.where(ticking, eh - 1, eh)
        dep_e = ticking & (eh <= 0)
        qa = jnp.where(dep_e, qa - 1, qa)
        eh = jnp.where(dep_e, msr, eh)

        # --- 4/5. trigger (comm.evaluate semantics, fused) ------------
        err = jnp.abs(q - qa)
        dsa = ds + dep.astype(jnp.int32)
        ssa = ss + 1
        if comm == "rt":
            trig = ssa >= rt_period
        elif comm == "dt":
            trig = dsa >= x
        elif comm == "et":
            trig = err >= x
        elif comm == "et_rt":
            trig = (err >= x) | (ssa >= rt_period)
        elif comm == "exact":
            trig = dep
        elif comm == "none":
            trig = jnp.zeros_like(dep)
        else:
            raise ValueError(f"unknown communication kind: {comm}")
        trig = trig & act & valid
        if comm == "exact":
            sent = jnp.sum(dep.astype(jnp.int32), axis=1, keepdims=True)
        else:
            sent = jnp.sum(trig.astype(jnp.int32), axis=1, keepdims=True)
        msgs = msgs + jnp.where(act, sent, 0)
        ds = jnp.where(act, jnp.where(trig, 0, dsa), ds)
        ss = jnp.where(act, jnp.where(trig, 0, ssa), ss)
        qa = jnp.where(trig, q, qa)
        eh = jnp.where(trig, msr, eh)

        # --- 6. metrics (pad lanes masked out of the extrema) ---------
        aq = jnp.max(jnp.abs(q - qa), axis=1, keepdims=True)
        qmax = jnp.max(jnp.where(valid, q, 0), axis=1, keepdims=True)
        qmin = jnp.min(jnp.where(valid, q, _I32_MAX), axis=1, keepdims=True)
        return (
            q, qa, hr, eh, ds, ss, ps,
            msgs, deps, arrs, drops,
            jnp.maximum(max_aq, aq),
            jnp.maximum(max_q, qmax),
            jnp.maximum(gap, qmax - qmin),
        )

    init = (
        zeros,  # q_true
        zeros,  # q_app
        zeros,  # head_rem (true tier)
        zeros + jnp.broadcast_to(msr, (dt, kp)),  # emu head (EmuState.init)
        zeros,  # deps_since_msg
        zeros,  # slots_since_msg
        zeros,  # per-server arrivals
        zeros1, zeros1, zeros1, zeros1,  # msgs, deps, arrs, dropped
        zeros1, zeros1, zeros1,  # max_aq, max_q, gap_sup
    )
    (q, _qa, _hr, _eh, _ds, _ss, ps,
     msgs, deps, arrs, drops, max_aq, max_q, gap) = jax.lax.fori_loop(
        0, slots, slot, init
    )
    qtrue_ref[...] = q
    persrv_ref[...] = ps
    stats_ref[...] = jnp.concatenate(
        [msgs, deps, arrs, drops, max_aq, max_q, gap, zeros1], axis=1
    )


def care_route_pallas(
    arrive: jax.Array,
    params: jax.Array,
    *,
    servers: int,
    cap: int,
    policy: str,
    comm: str,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused CARE trigger+route simulation, one domain per row.

    Args:
      arrive: (D, T) int32 per-slot arrival indicators, pre-masked by each
        domain's horizon (``slotted_sim._prep`` output).
      params: (D, 4) int32 per-domain scalars ``[x, rt_period, msr_slots,
        horizon]``.
      servers: K, the live server count (static); the lane axis pads to a
        lane-tile multiple internally and pad lanes are masked everywhere.
      cap: per-server FIFO capacity (arrivals beyond it drop), static.
      policy: "jsq" | "jsaq" (which state vector the argmin consumes).
      comm: trigger kind ("rt" | "dt" | "et" | "et_rt" | "exact" | "none").
      interpret: run the Pallas interpreter (CPU).

    Returns:
      ``(routed, q_true, per_srv, stats)``: (D, T) int32 routed server per
      slot (-1 when no admitted arrival), final (D, K) queue lengths,
      (D, K) per-server admitted arrivals, and (D, 8) int32 stats
      ``[msgs, deps, arrs, dropped, max_aq, max_q, gap_sup, 0]``.
    """
    if policy not in ("jsq", "jsaq"):
        raise ValueError(
            f"care_route_pallas supports policies 'jsq'/'jsaq', got {policy!r}"
        )
    d, t = arrive.shape
    kp = lane_pad(servers)
    dt = domain_tile(d)
    grid = (d // dt,)
    kernel = functools.partial(
        _care_kernel, servers=servers, cap=cap, policy=policy, comm=comm
    )
    routed, q_true, per_srv, stats = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((dt, t), lambda i: (i, 0)),
            pl.BlockSpec((dt, 4), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((dt, t), lambda i: (i, 0)),
            pl.BlockSpec((dt, kp), lambda i: (i, 0)),
            pl.BlockSpec((dt, kp), lambda i: (i, 0)),
            pl.BlockSpec((dt, 8), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, t), jnp.int32),
            jax.ShapeDtypeStruct((d, kp), jnp.int32),
            jax.ShapeDtypeStruct((d, kp), jnp.int32),
            jax.ShapeDtypeStruct((d, 8), jnp.int32),
        ],
        interpret=interpret,
    )(arrive.astype(jnp.int32), params.astype(jnp.int32))
    return routed, q_true[:, :servers], per_srv[:, :servers], stats


# ---------------------------------------------------------------------------
# Serving engine kernel: within-slot sequential arrival-lane routing.
# ---------------------------------------------------------------------------


def _serve_kernel(
    tie_ref,
    qlen_ref,
    qhead_ref,
    busy_ref,
    approx_ref,
    par_ref,
    jv_ref,
    tail_ref,
    admit_ref,
    qlen_out_ref,
    approx_out_ref,
    stats_ref,
    *,
    replicas: int,
    cap: int,
    comm: str,
):
    """One slot's arrival lanes routed sequentially, state resident.

    Mirrors the dense lane scan of ``serve/engine._serve_core`` under
    deterministic (lowest-index) ties: each admitted arrival immediately
    bumps the occupancy/approximation the next lane sees.  The f32
    approximation update is the identical IEEE ``+1.0f``, so the two
    backends stay bit-identical.  ``tie_ref`` rides along only to pin the
    lane count; deterministic ties never consume the uniforms.
    """
    a_n = tie_ref.shape[1]
    rp = qlen_ref.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, rp), 1)
    valid = lane < replicas
    n_arr = par_ref[:, 0:1]
    act = par_ref[:, 1:2] > 0
    qhead = qhead_ref[...]
    busy = busy_ref[...]

    def body(a, st):
        qlen, approx, drops = st
        live = act & (a < n_arr)
        if comm == "exact":
            score = (qlen + busy).astype(jnp.float32)
        else:
            score = approx
        j, _ = seg_argmin(jnp.where(valid, score, jnp.inf))
        onehot = lane == j
        len_j = jnp.sum(jnp.where(onehot, qlen, 0), axis=1, keepdims=True)
        admit = live & (len_j < cap)
        sel = onehot & admit
        tail = (
            jnp.sum(jnp.where(onehot, qhead, 0), axis=1, keepdims=True)
            + len_j
        ) % cap
        qlen = qlen + sel.astype(jnp.int32)
        approx = approx + sel.astype(jnp.float32)
        drops = drops + (live & ~admit).astype(jnp.int32)
        jv_ref[:, pl.dslice(a, 1)] = j
        tail_ref[:, pl.dslice(a, 1)] = tail
        admit_ref[:, pl.dslice(a, 1)] = admit.astype(jnp.int32)
        return qlen, approx, drops

    qlen, approx, drops = jax.lax.fori_loop(
        0,
        a_n,
        body,
        (qlen_ref[...], approx_ref[...], jnp.zeros((1, 1), jnp.int32)),
    )
    qlen_out_ref[...] = qlen
    approx_out_ref[...] = approx
    stats_ref[...] = drops


def serve_route_pallas(
    tie_u: jax.Array,
    q_len: jax.Array,
    q_head: jax.Array,
    busy_cnt: jax.Array,
    approx: jax.Array,
    n_arr: jax.Array,
    act: jax.Array,
    *,
    cap: int,
    comm: str,
    interpret: bool = False,
):
    """Route one slot's arrival lanes sequentially (JSAQ, lowest-index ties).

    Args:
      tie_u: (A,) f32 lane uniforms (unused under deterministic ties; pins
        the lane count).
      q_len / q_head: (R,) int32 pending-ring lengths and head indices.
      busy_cnt: (R,) int32 busy decode-slot counts (the "exact" score term).
      approx: (R,) f32 emulated occupancy.
      n_arr: () int32 live arrival count this slot.
      act: () bool horizon mask.
      cap: pending-ring capacity (static).
      comm: the comm kind; "exact" scores on true occupancy.
      interpret: run the Pallas interpreter (CPU).

    Returns:
      ``(jv, tailv, admitv, q_len', approx', dropped)``: per-lane routed
      replica / ring tail / admit flag (shapes (A,)), the post-slot ring
      lengths and approximation (shapes (R,)), and the () int32 count of
      dropped lanes.
    """
    a_n = tie_u.shape[0]
    r = q_len.shape[0]
    rp = lane_pad(r)

    def pad(v, fill):
        v2 = v[None, :]
        if rp == r:
            return v2
        return jnp.concatenate(
            [v2, jnp.full((1, rp - r), fill, v2.dtype)], axis=1
        )

    par = jnp.stack(
        [n_arr.astype(jnp.int32), act.astype(jnp.int32),
         jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)]
    )[None, :]
    kernel = functools.partial(
        _serve_kernel, replicas=r, cap=cap, comm=comm
    )
    jv, tailv, admitv, qlen_o, approx_o, drops = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, a_n), lambda i: (0, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, a_n), lambda i: (0, 0)),
            pl.BlockSpec((1, a_n), lambda i: (0, 0)),
            pl.BlockSpec((1, a_n), lambda i: (0, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
            pl.BlockSpec((1, rp), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, a_n), jnp.int32),
            jax.ShapeDtypeStruct((1, a_n), jnp.int32),
            jax.ShapeDtypeStruct((1, a_n), jnp.int32),
            jax.ShapeDtypeStruct((1, rp), jnp.int32),
            jax.ShapeDtypeStruct((1, rp), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        tie_u[None, :],
        pad(q_len, 0),
        pad(q_head, 0),
        pad(busy_cnt, 0),
        pad(approx, 0.0),
        par,
    )
    return (
        jv[0],
        tailv[0],
        admitv[0].astype(bool),
        qlen_o[0, :r],
        approx_o[0, :r],
        drops[0, 0],
    )
