"""Pallas TPU kernel: batched JSAQ dispatch.

Join-the-Shortest-Approximated-Queue routes each arriving job to the argmin
of the balancer's approximated queue vector and immediately increments that
entry (the balancer knows its own routing decisions -- Eq. 10 in the paper).
The per-job decision is inherently sequential, which is hostile to a SIMD
machine; the TPU adaptation is:

* vectorise over *independent balancer domains* (rows) -- e.g. parallel
  simulation replicas, per-device dispatchers, or per-layer expert groups --
  so each VPU lane group advances a different domain;
* keep the (domains_tile, K) state resident in VMEM across the whole
  sequential inner loop, so the argmin/update chain never touches HBM.

Layout: domains on the sublane axis (tile of 8), servers K on the lane axis
(padded to 128) -- the natural (8, 128) VREG shape.

Grid: one program per domain tile; jobs dimension is the sequential
``fori_loop`` inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DOMAIN_TILE = 8


def _jsaq_kernel(q_ref, idx_ref, qout_ref, *, num_jobs: int):
    """One domain-tile: route ``num_jobs`` jobs sequentially per domain."""
    q = q_ref[...].astype(jnp.int32)

    def body(n, q):
        j = jnp.argmin(q, axis=1).astype(jnp.int32)  # (Dt,)
        idx_ref[:, pl.dslice(n, 1)] = j[:, None]
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, q.shape, 1) == j[:, None]
        ).astype(q.dtype)
        return q + onehot

    q = jax.lax.fori_loop(0, num_jobs, body, q)
    qout_ref[...] = q.astype(qout_ref.dtype)


def jsaq_route_pallas(
    q_app: jax.Array, num_jobs: int, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Route ``num_jobs`` jobs per domain by sequential JSAQ.

    Args:
      q_app: (D, K) int32 approximated queue lengths, one row per domain.
      num_jobs: number of jobs to dispatch per domain (static).
      interpret: run the Pallas interpreter (CPU validation).

    Returns:
      (idx, q_out): (D, num_jobs) int32 chosen servers (ties -> lowest
      index), and the post-dispatch state (D, K).
    """
    d, k = q_app.shape
    if d % DOMAIN_TILE:
        raise ValueError(f"domains ({d}) must be a multiple of {DOMAIN_TILE}")
    grid = (d // DOMAIN_TILE,)
    kernel = functools.partial(_jsaq_kernel, num_jobs=num_jobs)
    idx, q_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((DOMAIN_TILE, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((DOMAIN_TILE, num_jobs), lambda i: (i, 0)),
            pl.BlockSpec((DOMAIN_TILE, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, num_jobs), jnp.int32),
            jax.ShapeDtypeStruct((d, k), q_app.dtype),
        ],
        interpret=interpret,
    )(q_app)
    return idx, q_out
