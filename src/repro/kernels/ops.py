"""Jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container, unit
tests) they run under the Pallas interpreter, which executes the kernel body
in Python with the same block semantics.  Callers can force either mode.

Wrappers also handle padding to tile multiples so call sites stay clean.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _flash
from repro.kernels import jsaq_route as _jsaq
from repro.kernels import moe_route as _moe
from repro.kernels import ref as _ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("num_jobs", "interpret", "use_pallas"))
def jsaq_route(
    q_app: jax.Array,
    num_jobs: int,
    *,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched JSAQ dispatch (see kernels/jsaq_route.py).

    Pads the domain axis to the tile size and the server axis to a full
    128-lane tile; (D, K) -> ((D,N) idx, (D,K) q').  Pad *lanes* are
    masked to the dtype's max so the argmin can never route to one (on a
    real TPU an unmasked lane-tile pad holds undefined values); pad rows
    are sliced off on the way out.
    """
    if not use_pallas:
        return _ref.jsaq_route_ref(q_app, num_jobs)
    interpret = _default_interpret() if interpret is None else interpret
    d, k = q_app.shape
    tile = _jsaq.DOMAIN_TILE
    pad = (-d) % tile
    if pad:
        q_app = jnp.concatenate(
            [q_app, jnp.zeros((pad, k), q_app.dtype)], axis=0
        )
    kp = _jsaq.lane_pad(k)
    if kp != k:
        q_app = jnp.concatenate(
            [
                q_app,
                jnp.full(
                    (q_app.shape[0], kp - k),
                    jnp.iinfo(q_app.dtype).max,
                    q_app.dtype,
                ),
            ],
            axis=1,
        )
    idx, q_out = _jsaq.jsaq_route_pallas(q_app, num_jobs, interpret=interpret)
    return idx[:d], q_out[:d, :k]


@functools.partial(
    jax.jit,
    static_argnames=("servers", "cap", "policy", "comm", "interpret"),
)
def care_route(
    arrive: jax.Array,
    params: jax.Array,
    *,
    servers: int,
    cap: int,
    policy: str,
    comm: str,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused mean-field CARE simulation (see kernels/jsaq_route.py).

    (D, T) arrivals + (D, 4) per-domain scalars -> (routed, q_true,
    per_srv, stats); the pallas ``route_backend`` of
    ``slotted_sim.simulate_grid`` and the direct entry point for the
    large-K invariants tests and ``benchmarks/bench_route.py``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return _jsaq.care_route_pallas(
        arrive,
        params,
        servers=servers,
        cap=cap,
        policy=policy,
        comm=comm,
        interpret=interpret,
    )


def serve_route(
    tie_u: jax.Array,
    q_len: jax.Array,
    q_head: jax.Array,
    busy_cnt: jax.Array,
    approx: jax.Array,
    n_arr: jax.Array,
    act: jax.Array,
    *,
    cap: int,
    comm: str,
    interpret: bool | None = None,
):
    """One serving slot's fused arrival-lane routing (jsaq_route.py).

    Not jitted here: it is called from inside the serving engine's traced
    scan body (``serve/engine._serve_core``), which owns the jit.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return _jsaq.serve_route_pallas(
        tie_u,
        q_len,
        q_head,
        busy_cnt,
        approx,
        n_arr,
        act,
        cap=cap,
        comm=comm,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("top_k", "gate_fn", "interpret", "use_pallas")
)
def moe_route(
    logits: jax.Array,
    bias: jax.Array,
    top_k: int,
    *,
    gate_fn: str = "softmax",
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused CARE-biased top-k routing (see kernels/moe_route.py)."""
    if not use_pallas:
        return _ref.moe_route_ref(logits, bias, top_k, gate_fn)
    interpret = _default_interpret() if interpret is None else interpret
    t, e = logits.shape
    tile = _moe.TOKEN_TILE
    pad = (-t) % tile
    if pad:
        logits = jnp.concatenate(
            [logits, jnp.full((pad, e), -1e30, logits.dtype)], axis=0
        )
    idx, w, counts = _moe.moe_route_pallas(
        logits, bias, top_k, gate_fn=gate_fn, interpret=interpret
    )
    if pad:
        # Remove phantom-token contributions from the counts.
        pad_idx = idx[t:]
        phantom = jnp.zeros_like(counts).at[pad_idx.reshape(-1)].add(1)
        counts = counts - phantom
    return idx[:t], w[:t], counts


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "interpret",
                     "use_pallas"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> jax.Array:
    """Flash SDPA (see kernels/flash_attn.py).

    q: (B, S, H, dh); k, v: (B, T, KVH, dh/dv).  GQA is handled by
    broadcasting the KV heads here (the VMEM tiles inside the kernel are
    per-head either way).  Returns (B, S, H, dv).
    """
    if not use_pallas:
        return _ref.flash_attention_ref(
            q, k, v, scale=scale, causal=causal, window=window,
            softcap=softcap,
        )
    interpret = _default_interpret() if interpret is None else interpret
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    dv = v.shape[3]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, dv)
    out = _flash.flash_attention_pallas(
        qf, kf, vf, scale=scale, causal=causal, window=window,
        softcap=softcap, interpret=interpret,
    )
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
