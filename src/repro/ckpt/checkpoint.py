"""Checkpointing: sharded .npz trees, atomic, restartable, reshardable.

Design (framework-grade, no orbax dependency):

* the pytree is flattened to ``path -> array`` with '/'-joined key paths;
* arrays are written as one or more ``.npz`` volumes plus a JSON manifest
  carrying step, config hash, tree structure and per-array dtype/shape;
* writes go to ``<dir>/tmp-<step>`` then ``os.replace`` to ``step-<n>``
  (atomic on POSIX), so a crash mid-save never corrupts the latest
  checkpoint;
* ``restore`` accepts any device mesh: arrays land as host numpy and are
  re-sharded by ``jax.device_put`` against the *current* shardings --
  restart on a different topology (elastic recovery) just works;
* ``keep`` rotates old checkpoints; a background thread can be used via
  ``async_save`` (train loop keeps stepping while the previous state
  serialises).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.GetAttrKey):
                keys.append(e.name)
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(e))
        flat[_SEP.join(keys)] = np.asarray(leaf)
    return flat


def save(state, directory: str | os.PathLike, step: int, *, keep: int = 3,
         extra: dict | None = None) -> Path:
    """Atomically write ``state`` under ``directory/step-<step>``."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"tmp-{step}"
    final = directory / f"step-{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(state)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step-{s}", ignore_errors=True)
    return final


_PENDING: list[threading.Thread] = []


def async_save(state, directory, step, **kw) -> threading.Thread:
    """Fire-and-forget save on a background thread (state is snapshotted
    to host first so the train loop can donate/overwrite buffers)."""
    host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
    t = threading.Thread(
        target=save, args=(host_state, directory, step), kwargs=kw, daemon=True
    )
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def all_steps(directory) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    return sorted(
        int(p.name.split("-", 1)[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step-")
    )


def latest_step(directory) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(like, directory, step: int | None = None, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    jax.sharding.Sharding for cross-mesh resharding on load."""
    directory = Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    src = directory / f"step-{step}"
    data = np.load(src / "arrays.npz")
    flat_like = _flatten_paths(like)

    leaves = []
    for path, leaf in flat_like:
        if path not in data:
            raise KeyError(f"checkpoint missing array {path!r}")
        arr = data[path]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs {leaf.shape}"
            )
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step


def _flatten_paths(tree):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.GetAttrKey):
                keys.append(e.name)
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(e))
        out.append((_SEP.join(keys), leaf))
    return out
