"""Benchmark harness: one module per paper table/figure (deliverable d).

Usage:
  PYTHONPATH=src python -m benchmarks.run                 # all benchmarks
  PYTHONPATH=src python -m benchmarks.run --only jct      # substring filter
  PYTHONPATH=src python -m benchmarks.run --quick         # reduced sizes

Prints ``name,us_per_call,derived`` CSV rows to stdout.  The mapping to
paper artifacts:

  bench_comm_vs_error   -> Fig 2 / Fig 6 / Fig 7  (+ Thm 2.3/2.5 bounds)
  bench_jct_ccdf        -> Fig 3 / Figs 8-12       (JCT vs comm budget)
  bench_table5          -> Fig 5                    (communication rates)
  bench_approx_quality  -> Thm 2.3 sweep            (AQ<=x-1, M<=D/x)
  bench_ssc             -> Sec 7 / Thm 7.3          (finite-n SSC trend)
  bench_moe_balance     -> beyond-paper: CARE balancer in MoE training
  bench_serving         -> beyond-paper: CARE dispatch in serving
  bench_roofline        -> Sec Roofline deliverable  (from dry-run artifacts)
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

BENCHES = [
    "bench_comm_vs_error",
    "bench_jct_ccdf",
    "bench_table5",
    "bench_approx_quality",
    "bench_ssc",
    "bench_moe_balance",
    "bench_serving",
    "bench_roofline",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on module name")
    ap.add_argument("--quick", action="store_true", help="reduced problem sizes")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in BENCHES:
        if args.only and args.only not in mod_name:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001 -- keep the harness running
            failures += 1
            print(f"{mod_name}/ERROR,0,{type(e).__name__}: {e}")
            continue
        wall = time.perf_counter() - t0
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        print(
            f"{mod_name}/total,{round(wall * 1e6, 1)},rows={len(rows)}",
            flush=True,
        )
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
