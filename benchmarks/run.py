"""Benchmark harness: one module per paper table/figure (deliverable d).

Usage:
  PYTHONPATH=src python -m benchmarks.run                 # all benchmarks
  PYTHONPATH=src python -m benchmarks.run --only jct      # substring filter
  PYTHONPATH=src python -m benchmarks.run --only jct,ssc  # several filters
  PYTHONPATH=src python -m benchmarks.run --quick         # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --json out.json # structured output

Trajectories recorded with ``--json`` (CI uploads one as a ``BENCH_*``
artifact per PR) are compared mechanically with ``benchmarks/diff.py``,
which fails on metric regressions beyond tolerance.

Prints ``name,us_per_call,derived`` CSV rows to stdout.  With ``--json`` the
same rows (plus any extra per-row columns the modules attach, e.g.
``mean_jct`` / ``rel_comm`` / ``speedup``) are also written as a JSON list of
records -- one object per row with at least ``name``, ``us_per_call`` and
``derived`` -- so ``BENCH_*.json`` trajectories can be recorded across PRs
and diffed mechanically.

Simulation-backed benchmarks submit their *entire figure grid* through
``benchmarks.common.timed_simulate_grid``, which groups cells by their
static part and runs each group as one compiled program
(``slotted_sim.simulate_grid``: scenario knobs are traced operands, the
flattened cell x seed axis is shard_map-sharded across local devices; see
the ``grid/compile_count`` / ``grid/speedup`` rows in quick mode).  The
scenario knobs go beyond the paper's setting: bursty MMPP arrivals
(``arrival="mmpp"``, ``burst_intensity``, ``burst_stay``), heterogeneous
per-server service rates (``service_rates``, with drain-time-aware JSAQ
via ``rate_aware``), and the hybrid ``comm="et_rt"`` trigger (ET-x with an
RT staleness cap).

The mapping to paper artifacts:

  bench_comm_vs_error   -> Fig 2 / Fig 6 / Fig 7  (+ Thm 2.3/2.5 bounds)
  bench_jct_ccdf        -> Fig 3 / Figs 8-12       (JCT vs comm budget
                           + bursty / heterogeneous scenario rows)
  bench_table5          -> Fig 5                    (communication rates)
  bench_approx_quality  -> Thm 2.3 sweep            (AQ<=x-1, M<=D/x)
  bench_ssc             -> Sec 7 / Thm 7.3          (finite-n SSC trend;
                           fused via the traced service/horizon axis)
  bench_heavy_tail      -> beyond-paper: ET-x under Pareto job sizes
  bench_moe_balance     -> beyond-paper: CARE balancer in MoE training
  bench_serving         -> beyond-paper: CARE dispatch in serving
  bench_stream          -> beyond-paper: streaming segment engine
                           (pipelined chunk throughput / overlap /
                           steady-state JCT / bounded-memory soak)
  bench_faults          -> beyond-paper: degraded networks + server faults
  bench_pull            -> beyond-paper: pull policies (JIQ / hyper-
                           scalable JSQ) vs CARE push on one frontier
  bench_retrans         -> beyond-paper: reliable (ack'd) control-plane
                           transport vs fire-and-forget under loss
  bench_roofline        -> Sec Roofline deliverable  (from dry-run artifacts)
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time

# Expose the host's cores as separate XLA CPU devices so simulate_batch can
# shard seed sweeps across them (pmap); the slotted scan fuses into a
# compute-bound single-core loop, so device-level parallelism is the only
# CPU lever.  Set before any jax import; respects an operator-provided
# XLA_FLAGS.
if "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
    _n_dev = min(os.cpu_count() or 1, 8)
    if _n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n_dev}"
        )

BENCHES = [
    "bench_comm_vs_error",
    "bench_jct_ccdf",
    "bench_table5",
    "bench_approx_quality",
    "bench_ssc",
    "bench_heavy_tail",
    "bench_moe_balance",
    "bench_serving",
    "bench_stream",
    "bench_route",
    "bench_faults",
    "bench_pull",
    "bench_retrans",
    "bench_roofline",
]


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return str(v)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default="",
        help="comma-separated substring filter(s) on module names",
    )
    ap.add_argument("--quick", action="store_true", help="reduced problem sizes")
    ap.add_argument(
        "--json",
        default="",
        metavar="OUT",
        help="also write all rows as a JSON list of records to this path",
    )
    args = ap.parse_args(argv)
    if args.json:
        # Fail fast on an unwritable path rather than at the end of a run.
        open(args.json, "w").close()

    print("name,us_per_call,derived")
    failures = 0
    records: list[dict] = []
    only = [s for s in args.only.split(",") if s]
    for mod_name in BENCHES:
        if only and not any(s in mod_name for s in only):
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001 -- keep the harness running
            failures += 1
            print(f"{mod_name}/ERROR,0,{type(e).__name__}: {e}")
            records.append(
                {
                    "name": f"{mod_name}/ERROR",
                    "us_per_call": 0,
                    "derived": f"{type(e).__name__}: {e}",
                }
            )
            continue
        wall = time.perf_counter() - t0
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
            records.append({k: _jsonable(v) for k, v in r.items()})
        print(
            f"{mod_name}/total,{round(wall * 1e6, 1)},rows={len(rows)}",
            flush=True,
        )
        records.append(
            {
                "name": f"{mod_name}/total",
                "us_per_call": round(wall * 1e6, 1),
                "derived": f"rows={len(rows)}",
            }
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.json}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
