"""Mechanically diff two benchmark trajectory files (``run.py --json``).

Closes the ROADMAP "record and diff trajectories" item: CI uploads a
``BENCH_*.json`` artifact per PR, and this tool compares any two such
files row by row, failing on metric regressions beyond tolerance.

Usage:
  python -m benchmarks.diff BASELINE.json NEW.json [--rtol 0.02]
      [--atol 1e-9] [--perf-rtol R] [--allow-missing]

Rules (mechanical on purpose -- no per-benchmark knowledge):

* Records are keyed by ``name``; the ``derived`` string and ``name`` are
  never compared (they restate the numeric columns).
* Wall-clock fields -- ``us_per_call``, ``speedup`` and any field ending in
  ``_s`` -- are machine-dependent and skipped unless ``--perf-rtol`` is
  given (then they are compared *one-sided*: only slowdowns/losses fail).
* Numeric fields present in both records must satisfy
  ``|new - old| <= atol + rtol * |old|``; a NaN appearing (or resolving)
  on one side only is a regression, never a silent pass.
* A compared baseline field missing from the new record is a regression
  (the gate must not weaken silently; regenerate the baseline for
  deliberate schema changes).
* Boolean fields are pass/fail flags: ``True -> False`` is a regression,
  ``False -> True`` an improvement.
* A baseline row missing from the new file is a coverage regression
  (suppress with ``--allow-missing``, e.g. for ``--only`` runs); rows only
  in the new file are reported as additions and never fail.

Exit status: 0 clean, 1 regressions found, 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

PERF_FIELDS = {"us_per_call", "speedup"}
SKIP_FIELDS = {"name", "derived"}


def _is_perf(field: str) -> bool:
    # Suffix matches catch derived wall-clock ratios too (e.g. the stream
    # tier's ``overlap_speedup``) -- ``_perf_regressed`` already treats
    # ``*speedup`` one-sidedly, so the skip set must agree with it.
    return (
        field in PERF_FIELDS
        or field.endswith("_s")
        or field.endswith("speedup")
    )


def _index(records: list[dict]) -> dict[str, dict]:
    return {r["name"]: r for r in records if "name" in r}


def _perf_regressed(field: str, old: float, new: float, rtol: float) -> bool:
    """One-sided perf check: higher time / lower speedup is a regression."""
    if field == "speedup" or field.endswith("speedup"):
        return new < old * (1.0 - rtol)
    return new > old * (1.0 + rtol)


def diff_records(
    baseline: list[dict],
    new: list[dict],
    rtol: float = 0.02,
    atol: float = 1e-9,
    perf_rtol: float | None = None,
    allow_missing: bool = False,
) -> tuple[list[str], list[str]]:
    """Compare trajectories; returns (regressions, notes)."""
    old_by, new_by = _index(baseline), _index(new)
    regressions: list[str] = []
    notes: list[str] = []

    for name in old_by:
        if name not in new_by:
            msg = f"row disappeared: {name}"
            (notes if allow_missing else regressions).append(msg)
    for name in new_by:
        if name not in old_by:
            notes.append(f"new row: {name}")

    for name, old in old_by.items():
        newr = new_by.get(name)
        if newr is None:
            continue
        for field, ov in old.items():
            if field in SKIP_FIELDS:
                continue
            if _is_perf(field) and perf_rtol is None:
                continue  # machine-dependent and not compared: ignore
            if field not in newr:
                # A metric column vanishing is itself a regression: the
                # gate must not weaken silently (regenerate the baseline
                # for deliberate schema changes).
                regressions.append(f"{name}.{field}: field disappeared")
                continue
            nv = newr[field]
            if isinstance(ov, bool) or isinstance(nv, bool):
                if bool(ov) and not bool(nv):
                    regressions.append(
                        f"{name}.{field}: flag regressed True -> {nv}"
                    )
                continue
            if not isinstance(ov, (int, float)) or not isinstance(
                nv, (int, float)
            ):
                continue  # strings / nested values: not compared
            if math.isnan(float(nv)) != math.isnan(float(ov)):
                regressions.append(f"{name}.{field}: {ov} -> {nv} (NaN)")
                continue
            if math.isnan(float(nv)):
                continue  # NaN on both sides: equal by convention
            if _is_perf(field):
                if _perf_regressed(field, float(ov), float(nv), perf_rtol):
                    regressions.append(
                        f"{name}.{field}: perf regressed {ov} -> {nv}"
                    )
                continue
            # Inverted form so an unexpected non-finite value can never
            # slip through a False comparison.
            if not (abs(float(nv) - float(ov)) <= atol + rtol * abs(float(ov))):
                regressions.append(
                    f"{name}.{field}: {ov} -> {nv} (rtol {rtol})"
                )
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--rtol", type=float, default=0.02,
                    help="relative tolerance for metric fields")
    ap.add_argument("--atol", type=float, default=1e-9)
    ap.add_argument("--perf-rtol", type=float, default=None,
                    help="also compare wall-clock fields, one-sided, at "
                         "this relative tolerance (default: skip them)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="rows missing from NEW are notes, not failures")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    regressions, notes = diff_records(
        baseline, new, rtol=args.rtol, atol=args.atol,
        perf_rtol=args.perf_rtol, allow_missing=args.allow_missing,
    )
    for n in notes:
        print(f"note: {n}")
    for r in regressions:
        print(f"REGRESSION: {r}")
    print(
        f"{len(regressions)} regression(s), {len(notes)} note(s) across "
        f"{len(_index(baseline))} baseline rows"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    raise SystemExit(main())
