"""Streaming serving tier: the segment engine of ``serve_stream``.

Four row families measure what the chunked driver buys over one-shot and
naively-chunked execution:

* ``stream/slots_per_sec`` -- raw pipelined throughput: one
  ``serve_stream`` call over the full horizon at a production chunk size
  (4096), clock stopped behind the final blocking carry read.  The wall
  field is machine-dependent (``slots_per_s``: perf-skipped by diff.py).

* ``stream/overlap_ratio`` -- what the async pipeline saves.  The
  *synchronous no-prefetch reference* is the naive chunker a user would
  write without the streaming driver: one ``serve_stream`` call **per
  chunk**, threading ``StreamResult.state`` through, so every chunk pays
  a full device sync plus the host readback of the result counters
  before the next chunk's slab is even sampled.  The pipelined driver
  dispatches chunk k, samples chunk k+1's slab during k's device
  execution, and never materialises mid-stream results.  Both arms
  compute the *bit-identical* trace (asserted via the message / JCT
  accumulators -- the ``stepped_matches_streamed`` flag), so the ratio
  is a pure driver cost.  Gate: best ratio across the chunk ladder
  >= 1.2 (``overlap_ge_1_2``); the ratio itself is recorded one-sided
  (``overlap_speedup``).

* ``stream/jct_load0.98`` -- steady-state JCT at load -> 0.98 from the
  on-device warmup-discarded accumulators (Welford mean/std + log-bucket
  histogram quantiles): the row the fixed-horizon engine cannot produce
  without materialising a per-request JCT array.  Deterministic given
  the seed, so the quantiles are diffable metric columns.

* ``stream/soak`` -- long-horizon memory bound: a >= 1e7-slot run (full
  mode; quick scales down) must hold host peak memory at the level of a
  short probe run, because the driver keeps O(chunk) host state -- the
  sampler's LRU block cache plus one in-flight slab -- independent of
  the total horizon.  Peaks are tracemalloc's (Python + numpy; the
  device carry is O(replicas * queue_cap) by construction), compared
  probe vs 10x-longer soak after a warm-up run so jit compilation is
  excluded (``bounded_memory``).
"""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from benchmarks import common
from repro.serve import engine

# The serving cell of the streaming rows: paper-sized control plane (ET-4
# corrections), modest replica group so CI walls stay in seconds.
_CELL = dict(replicas=8, decode_slots=4, queue_cap=512, comm="et", x=4.0)

OVERLAP_CHUNKS = (128, 256)
THROUGHPUT_CHUNK = 4096


def _cell(slots: int, load: float = 0.95) -> engine.ServeConfig:
    return engine.ServeConfig(slots=slots, load=load, **_CELL)


def _sampler(cell: engine.ServeConfig) -> engine.StreamSampler:
    return engine.StreamSampler(0, engine.StreamParams.for_cell(cell))


def _stream(cell, chunk, slots, **kw):
    return engine.serve_stream(
        0, cell, chunk=chunk, slots=slots, sampler=_sampler(cell), **kw
    )


def _stepped(cell, chunk, slots):
    """The synchronous no-prefetch reference: one blocking segment per
    chunk, state threaded through ``StreamResult`` -- per-chunk device
    sync + host readback, next slab sampled only after."""
    res = engine.serve_stream(
        0, cell, chunk=chunk, slots=chunk, sampler=_sampler(cell),
        prefetch=False,
    )
    for _ in range(1, slots // chunk):
        res = engine.serve_stream(
            0, cell, chunk=chunk, slots=chunk, state=res.state,
            prefetch=False,
        )
    return res


def _best_wall(fn, reps: int):
    """(last result, best-of-reps wall).  ``serve_stream`` blocks on the
    final carry itself, so perf_counter around the call is honest."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def _accumulators_match(a: engine.StreamResult, b: engine.StreamResult) -> bool:
    """Bitwise equality of every on-device accumulator of two runs."""
    return (
        a.messages == b.messages
        and a.completed == b.completed
        and a.dropped == b.dropped
        and a.count == b.count
        and a.mean_jct == b.mean_jct
        and a.max_jct == b.max_jct
        and np.array_equal(a.hist, b.hist)
        and np.array_equal(a.final_occupancy, b.final_occupancy)
    )


def run(quick: bool = False) -> list[dict]:
    rows: list[dict] = []
    reps = 2 if quick else 3

    # --- pipelined throughput -----------------------------------------
    slots = 65_536 if quick else 262_144
    cell = _cell(slots)
    _stream(cell, THROUGHPUT_CHUNK, 2 * THROUGHPUT_CHUNK)  # compile
    res, wall = _best_wall(
        lambda: _stream(cell, THROUGHPUT_CHUNK, slots), reps
    )
    rows.append(
        common.row(
            "stream/slots_per_sec",
            wall,
            slots,
            common.fmt_derived(
                slots_per_sec=slots / wall,
                chunk=THROUGHPUT_CHUNK,
                completed=res.completed,
                msgs_per_slot=res.msgs_per_slot,
            ),
            slots_per_s=slots / wall,
            msgs_per_slot=res.msgs_per_slot,
        )
    )

    # --- overlap vs the synchronous no-prefetch reference -------------
    o_slots = 16_384 if quick else 32_768
    o_cell = _cell(o_slots)
    best_ratio, ratios, match = 0.0, {}, True
    for chunk in OVERLAP_CHUNKS:
        _stream(o_cell, chunk, 2 * chunk)  # compile once per chunk size
        piped, p_wall = _best_wall(
            lambda c=chunk: _stream(o_cell, c, o_slots), reps
        )
        stepped, s_wall = _best_wall(
            lambda c=chunk: _stepped(o_cell, c, o_slots), reps
        )
        match = match and _accumulators_match(piped, stepped)
        ratios[chunk] = s_wall / p_wall
        best_ratio = max(best_ratio, ratios[chunk])
    rows.append(
        common.row(
            "stream/overlap_ratio",
            0.0,
            o_slots,
            common.fmt_derived(
                overlap_ratio=best_ratio,
                **{f"ratio_chunk{c}": r for c, r in ratios.items()},
                stepped_matches_streamed=match,
                overlap_ge_1_2=bool(best_ratio >= 1.2),
            ),
            overlap_speedup=best_ratio,
            stepped_matches_streamed=match,
            overlap_ge_1_2=bool(best_ratio >= 1.2),
        )
    )

    # --- steady-state JCT at load -> 0.98 -----------------------------
    j_slots = 60_000 if quick else 240_000
    j_cell = _cell(j_slots, load=0.98)
    j_res, j_wall = _best_wall(
        lambda: _stream(j_cell, THROUGHPUT_CHUNK, j_slots,
                        warmup=j_slots // 5),
        1,
    )
    summ = j_res.jct_summary()
    rows.append(
        common.row(
            "stream/jct_load0.98",
            j_wall,
            j_slots,
            common.fmt_derived(
                mean_jct=summ["mean"],
                p50=summ["p50"],
                p99=summ["p99"],
                p999=summ["p999"],
                count=summ["count"],
                msgs_per_completion=j_res.msgs_per_completion,
            ),
            mean_jct=summ["mean"],
            p50=summ["p50"],
            p99=summ["p99"],
            p999=summ["p999"],
            count=summ["count"],
        )
    )

    # --- long-horizon soak: host memory independent of the horizon ----
    probe = 65_536 if quick else 1_000_000
    soak = 4 * probe if quick else 10_000_000
    s_cell = _cell(probe)
    chunk = 2_048 if quick else 8_192
    _stream(s_cell, chunk, 2 * chunk)  # compile outside the traces
    tracemalloc.start()
    _stream(s_cell, chunk, probe)
    peak_probe = tracemalloc.get_traced_memory()[1]
    tracemalloc.reset_peak()
    t0 = time.perf_counter()
    s_res = _stream(s_cell, chunk, soak)
    s_wall = time.perf_counter() - t0
    peak_soak = tracemalloc.get_traced_memory()[1]
    tracemalloc.stop()
    # Bounded: a 4x/10x longer horizon may not grow the host peak beyond
    # jitter (the driver holds one slab + an LRU block cache, both
    # O(chunk)); an O(horizon) leak would show up as a ~4x/10x peak.
    bounded = peak_soak <= 1.5 * peak_probe + 32 * 2**20
    rows.append(
        common.row(
            "stream/soak",
            s_wall,
            soak,
            common.fmt_derived(
                soak_slots=soak,
                slots_per_sec=soak / s_wall,
                peak_probe_mb=peak_probe / 2**20,
                peak_soak_mb=peak_soak / 2**20,
                bounded_memory=bool(bounded),
                completed=s_res.completed,
            ),
            soak_slots=soak,
            slots_per_s=soak / s_wall,
            bounded_memory=bool(bounded),
        )
    )
    return rows
