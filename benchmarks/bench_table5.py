"""Paper Figure 5 (the communication-rate table).

Measured messages-per-departure for every implemented architecture at load
0.95, next to the paper's stated rate:

| algorithm              | paper rate            | measured           |
|------------------------|-----------------------|--------------------|
| JSQ                    | 1 (D)                 | 1 by construction  |
| SQ(2)                  | 4 (A) = 2d, d=2       | 4 x arrivals       |
| Round Robin            | 0                     | 0                  |
| DT-x (any approx)      | 1/x                   | measured           |
| ET-x + MSR-x           | <= 1/x                | measured           |
| ET-x + MSR             | <= 1/(x^2-x) (heavy)  | measured           |
"""
from __future__ import annotations

from benchmarks import common
from repro.core.care import metrics, slotted_sim, theory

X = 4  # table row parameter (paper states rates as functions of x)


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    load = 0.95
    entries = [
        ("jsq", dict(policy="jsq", comm="none"), "1"),
        ("sq2", dict(policy="sq2", comm="none"), "2d=4 per arrival"),
        ("rr", dict(policy="rr", comm="none"), "0"),
        (
            f"dt{X}_basic",
            dict(policy="jsaq", comm="dt", x=X, approx="basic"),
            f"1/x={1 / X:.3f}",
        ),
        (
            f"et{X}_msrx",
            dict(policy="jsaq", comm="et", x=X, approx="msr_x"),
            f"<=1/x={1 / X:.3f}",
        ),
        (
            f"et{X}_msr",
            dict(policy="jsaq", comm="et", x=X, approx="msr"),
            f"<=1/(x^2-x)={float(theory.et_msr_relative_comm_backlogged(X)):.3f}",
        ),
    ]
    cfgs = [
        slotted_sim.SimConfig(
            servers=common.SERVERS, slots=slots, load=load, **kw
        )
        for _, kw, _ in entries
    ]
    # One fused submission; cells shared with other figures (e.g. the ET
    # rows of the Thm 2.3 sweep) come from the common cell cache.
    results, walls = common.timed_simulate_grid(cfgs, (0,))
    rows = []
    for (name, kw, paper_rate), cfg, res_list, wall in zip(
        entries, cfgs, results, walls
    ):
        res = res_list[0]
        rel = metrics.relative_communication(res, cfg.policy, cfg.sqd)
        rows.append(
            common.row(
                f"table5/{name}",
                wall,
                slots,
                common.fmt_derived(paper=paper_rate, measured=rel),
                measured=rel,
            )
        )
    return rows
