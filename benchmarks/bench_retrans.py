"""Reliable-transport tier: ack'd control plane vs fire-and-forget loss.

Every row replays the same CARE cell (JSAQ over ET-3 corrections, paper
Section 9.1 fleet at load 0.95) on a 2-slot-delay / 1-slot-jitter wire and
varies only the delivery-drop probability and the transport:

* ``retrans/lossless`` -- the fire-and-forget control on a perfect wire:
  the JCT / message-rate floor every degraded row is measured against.

* ``retrans/ff_drop*`` vs ``retrans/ack_drop*`` -- the **loss ladder**
  (10% / 30% / 50% i.i.d. drops), fire-and-forget vs ``transport="ack"``.
  Under fire-and-forget a lost correction is gone: the balancer routes
  on a stale entry until the *next* ET trigger resyncs it.  Because ET
  corrections carry absolute queue snapshots (not increments), that next
  delivery heals the drift completely, so push-side fire-and-forget
  degrades gently -- the ladder measures exactly how gently.  Under ack
  every send opens a timeout window (traced ``ack_timeout``, exponential
  ``backoff_base``, ``max_retries`` cap); an unacked update retransmits
  a *fresh* snapshot at expiry.  Acks and retransmits ride the same
  delay/jitter/drop wire and are billed in the message counters -- the
  overhead column is honest.  The 50% rung records the regime where the
  window itself becomes the bottleneck: while a send awaits its ack,
  fresh triggers supersede in the pending buffer until the (backed-off)
  window expires, so under extreme loss ack'd staleness *exceeds*
  fire-and-forget's -- reliability is not free.  All four knobs are
  traced ``Scenario`` operands, so each transport's whole ladder shares
  one compiled program per static group (``retrans/grid_compile_count``).

* ``retrans/jiq_*_drop10`` -- **lost-token repair** on the pull tier,
  where loss is *not* self-correcting: a JIQ idle token dropped in
  flight silently thins the token pool (the server goes back to work on
  fallback-routed jobs and may not re-idle for a long time), so the
  balancer routes blind at a rising miss rate.  Under ack the unacked
  token retransmits and the pool holds its occupancy -- the largest JCT
  recovery in the module.

* ``retrans/frontier`` -- the headline: under 10% drop, ack'd ET-3
  restores mean JCT to within a small factor of lossless -- and below
  fire-and-forget's -- at a measured, bounded message-overhead ratio
  (data + acks + retransmits, all billed); and the ack'd pull tier
  repairs the token pool (lower miss rate, retransmits observed).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.care import metrics, slotted_sim

DROPS = (0.1, 0.3, 0.5)

# Paper Section 9.1 setting; load 0.95 is where a thinned update stream
# hurts most.  The wire matches the bench_faults drop ladder plus jitter.
_SLOTTED = dict(servers=30, load=0.95, mean_service=30)
_NET = dict(network="net", net_delay=2, net_jitter=1)
# Ack window: one data leg plus one ack leg is 2 * (delay + jitter) <= 6
# slots, so an 8-slot base timeout retransmits only genuinely lost sends;
# 6 doubling retries push the abandon horizon past 500 slots.
_ACK = dict(transport="ack", ack_timeout=8, backoff_base=2.0, max_retries=6)

# Pull tier at load 0.9 (the bench_pull corner: tokens scarce but the
# idle transition still fires).
_PULL_LOAD = 0.9


def _ff_cell(slots: int, **kw) -> slotted_sim.SimConfig:
    return slotted_sim.SimConfig(
        slots=slots, policy="jsaq", comm="et", x=3, **_SLOTTED, **_NET, **kw,
    )


def _ack_cell(slots: int, **kw) -> slotted_sim.SimConfig:
    return _ff_cell(slots, **_ACK, **kw)


def _jiq_cell(slots: int, ack: bool, **kw) -> slotted_sim.SimConfig:
    extra = _ACK if ack else {}
    return slotted_sim.SimConfig(
        slots=slots, policy="jiq", comm="jiq", servers=30, load=_PULL_LOAD,
        mean_service=30, **_NET, **extra, **kw,
    )


def _mean(vals) -> float:
    return float(np.mean(vals))


def _summarise(per_seed, slots: int) -> dict:
    """Cross-seed means of the counters every ladder row reports."""
    return {
        "jct": _mean([metrics.mean_jct(r.jct) for r in per_seed]),
        "msgs": _mean([r.messages / slots for r in per_seed]),
        "drops": int(np.sum([r.net_drops for r in per_seed])),
        "retrans": int(np.sum([r.retrans for r in per_seed])),
    }


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rows: list[dict] = []
    progs_before = slotted_sim.grid_compile_count()

    # --- loss ladder: fire-and-forget vs ack'd, shared seeds -----------
    named = [("lossless", _ff_cell(slots, net_drop=0.0))]
    for p in DROPS:
        named.append((f"ff_drop{int(p * 100)}", _ff_cell(slots, net_drop=p)))
        named.append((f"ack_drop{int(p * 100)}", _ack_cell(slots, net_drop=p)))
    results, walls = common.timed_simulate_grid([c for _, c in named], seeds)
    ladder: dict = {}
    for (name, _), per_seed, wall in zip(named, results, walls):
        s = _summarise(per_seed, slots)
        ladder[name] = s
        rows.append(
            common.row(
                f"retrans/{name}",
                wall,
                slots,
                common.fmt_derived(
                    mean_jct=s["jct"],
                    msgs_per_slot=s["msgs"],
                    net_drops=s["drops"],
                    retrans=s["retrans"],
                    seeds=len(seeds),
                ),
                mean_jct=s["jct"],
                msgs_per_slot=s["msgs"],
            )
        )

    # --- lost-token repair on the pull tier ----------------------------
    pull_named = [
        ("jiq_ff_drop10", _jiq_cell(slots, ack=False, net_drop=0.1)),
        ("jiq_ack_drop10", _jiq_cell(slots, ack=True, net_drop=0.1)),
    ]
    p_results, p_walls = common.timed_simulate_grid(
        [c for _, c in pull_named], seeds
    )
    pull: dict = {}
    for (name, _), per_seed, wall in zip(pull_named, p_results, p_walls):
        s = _summarise(per_seed, slots)
        tok = metrics.token_summary(
            int(np.sum([r.token_sum for r in per_seed])),
            int(np.sum([r.token_misses for r in per_seed])),
            slots * len(seeds),
            int(np.sum([r.arrivals for r in per_seed])),
        )
        pull[name] = (s, tok)
        rows.append(
            common.row(
                f"retrans/{name}",
                wall,
                slots,
                common.fmt_derived(
                    mean_jct=s["jct"],
                    token_miss_rate=tok["miss_rate"],
                    mean_tokens=tok["mean_tokens"],
                    retrans=s["retrans"],
                    seeds=len(seeds),
                ),
                mean_jct=s["jct"],
                token_miss_rate=tok["miss_rate"],
            )
        )

    # --- compile-count: one program per (policy, transport) group ------
    programs = slotted_sim.grid_compile_count() - progs_before
    rows.append(
        common.row(
            "retrans/grid_compile_count",
            0.0,
            slots,
            common.fmt_derived(
                programs=programs,
                cells=len(named) + len(pull_named),
                # Four static groups: jsaq x {fire_forget, ack} (each
                # ladder rung only moves traced operands) and jiq x both.
                # In a full harness run bench_faults / bench_pull have
                # already compiled the two fire_forget groups, so the
                # delta recorded by CI is 2 (the ack programs).
                fused=programs <= 4,
            ),
            programs=programs,
            fused=programs <= 4,
        )
    )

    # --- headline: ack recovers the lossless JCT at bounded overhead ---
    floor = max(ladder["lossless"]["jct"], 1e-9)
    ratio_ack = ladder["ack_drop10"]["jct"] / floor
    ratio_ff = ladder["ff_drop10"]["jct"] / floor
    msg_overhead = ladder["ack_drop10"]["msgs"] / max(
        ladder["lossless"]["msgs"], 1e-9
    )
    # Data + ack legs alone cost 2x the fire-and-forget floor; 10% drops
    # add the retransmit tail on top.  "Bounded" claims the whole bill
    # stays under 4x while recovering the JCT fire-and-forget gives up.
    ack_recovers = (
        ratio_ack <= 1.15 and ratio_ack < ratio_ff and msg_overhead <= 4.0
    )
    token_repair = (
        pull["jiq_ack_drop10"][1]["miss_rate"]
        <= pull["jiq_ff_drop10"][1]["miss_rate"]
        and pull["jiq_ack_drop10"][0]["retrans"] > 0
    )
    rows.append(
        common.row(
            "retrans/frontier",
            0.0,
            slots,
            common.fmt_derived(
                ack_recovers_jct=ack_recovers,
                jct_ratio_ack=ratio_ack,
                jct_ratio_ff=ratio_ff,
                msg_overhead_ratio=msg_overhead,
                token_pool_repaired=token_repair,
                jiq_miss_ff=pull["jiq_ff_drop10"][1]["miss_rate"],
                jiq_miss_ack=pull["jiq_ack_drop10"][1]["miss_rate"],
            ),
            ack_recovers_jct=ack_recovers,
            token_pool_repaired=token_repair,
        )
    )
    return rows
