"""Beyond-paper figure: sparse communication under heavy-tailed job sizes.

The paper's Theorem 2.5 communication analysis assumes geometric sizes;
the hyper-scalable load-balancing literature (van der Boor et al.,
PAPERS.md) asks whether sparse-feedback designs survive the heavy-tailed
regimes real clusters see.  Two CARE properties make the answer testable:

* the ET-x error bound ``AQ <= x-1`` (Prop 6.8) is *distribution-free* --
  it must hold exactly under any size distribution, Pareto included;
* the message-rate decay in x is an MSR-quality question: heavier tails
  make the mean a worse per-job predictor, so the measured relative
  communication quantifies how much of the Thm 2.5 win survives.

This figure sweeps Pareto tail index (alpha, heavier = smaller) x ET-x at
load 0.95.  Because the size distribution is a traced ``ServiceProcess``
operand (kind static, alpha/mean traced), the **whole grid is one
compiled program**.  Reported per cell: relative communication (messages
per departure; exact-state baseline is 1, Prop 6.1) and the AQ bound
check.  The ``heavy_tail/claim`` row asserts the headline: ET-3 + MSR
still needs well under half the exact-state messages at every swept tail
index, with the deterministic error bound intact.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim

TAILS = (1.5, 2.0, 3.0)  # Pareto alpha: 1.5 has infinite variance
XS = (2, 3, 5, 8)
SEEDS = (0, 1)
CLAIM_X = 3
CLAIM_REL_COMM = 0.5  # ET-3 must save >= half the exact-state messages


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    tails = (1.5, 3.0) if quick else TAILS
    xs = (2, 3, 8) if quick else XS
    cells = [
        (
            tail,
            x,
            slotted_sim.SimConfig(
                servers=common.SERVERS,
                slots=slots,
                load=0.95,
                policy="jsaq",
                comm="et",
                x=x,
                approx="msr",
                service="pareto",
                service_tail=tail,
            ),
        )
        for tail in tails
        for x in xs
    ]
    cfgs = [cfg for _, _, cfg in cells]
    results, walls = common.timed_simulate_grid(cfgs, SEEDS)

    rows: list[dict] = []
    rel_at_claim_x: dict[float, float] = {}
    all_aq_ok = True
    for (tail, x, cfg), res, wall in zip(cells, results, walls):
        rel = float(np.mean([r.msgs_per_departure for r in res]))
        max_aq = max(r.max_aq for r in res)
        aq_ok = max_aq <= x - 1  # distribution-free ET bound (Prop 6.8)
        all_aq_ok &= aq_ok
        if x == CLAIM_X:
            rel_at_claim_x[tail] = rel
        rows.append(
            common.row(
                f"heavy_tail/alpha{tail}/x{x}",
                wall,
                slots * len(SEEDS),
                common.fmt_derived(
                    rel_comm=rel, max_aq=max_aq, aq_ok=aq_ok,
                    seeds=len(SEEDS),
                ),
                rel_comm=rel,
                max_aq=max_aq,
                ok=bool(aq_ok),
            )
        )
    saves = all(rel < CLAIM_REL_COMM for rel in rel_at_claim_x.values())
    worst = max(rel_at_claim_x.values())
    rows.append(
        common.row(
            "heavy_tail/claim",
            0.0,
            slots,
            common.fmt_derived(
                claim_x=CLAIM_X,
                worst_rel_comm=worst,
                threshold=CLAIM_REL_COMM,
                et_saves_messages=saves,
                aq_bound_distribution_free=all_aq_ok,
            ),
            worst_rel_comm=worst,
            # Trajectory-diff gated headline: ET-x message savings and the
            # deterministic error bound both survive Pareto sizes.
            et_saves_messages=bool(saves),
            aq_bound_distribution_free=bool(all_aq_ok),
        )
    )
    return rows
