"""Shared benchmark plumbing: timed simulation runs, a result cache, rows.

Every benchmark module exposes ``run(quick: bool) -> list[dict]``; each row
must carry ``name``, ``us_per_call`` and ``derived`` (the CSV contract of
``benchmarks/run.py``) plus any extra columns for the extended report.

Simulations are cached by (seed(s), SimConfig) because several paper tables
slice the same runs (e.g. the Fig 6 communication sweep and the Thm 2.3
verification reuse identical (comm, approx, x) cells).

Seed sweeps go through :func:`timed_simulate_batch`, which drives
``slotted_sim.simulate_batch`` -- all seeds run in one vmapped scan, so a
batch costs roughly one sequential run's wall time rather than ``n``.
"""
from __future__ import annotations

import time
from typing import Sequence

import jax

from repro.core.care import slotted_sim

_SIM_CACHE: dict = {}
_BATCH_CACHE: dict = {}

DEFAULT_SLOTS = 100_000
QUICK_SLOTS = 20_000

# The paper's simulation setting (Section 9.1).
SERVERS = 30
LOADS = (0.5, 0.8, 0.95)


def sim_slots(quick: bool) -> int:
    return QUICK_SLOTS if quick else DEFAULT_SLOTS


def timed_simulate(seed: int, cfg: slotted_sim.SimConfig):
    """simulate() with wall-time capture and (seed, cfg) memoisation.

    Returns (SimResult, wall_seconds).  Cached calls return the original
    wall time so ``us_per_call`` stays meaningful.
    """
    key = (seed, cfg)
    if key not in _SIM_CACHE:
        # A batched sweep may already contain this (seed, cfg) cell --
        # reuse it (batch wall time attributed evenly across its seeds).
        for (seeds, bcfg), (results, wall) in _BATCH_CACHE.items():
            if bcfg == cfg and seed in seeds:
                _SIM_CACHE[key] = (
                    results[tuple(seeds).index(seed)], wall / len(seeds)
                )
                break
        else:
            t0 = time.perf_counter()
            res = slotted_sim.simulate(jax.random.key(seed), cfg)
            _SIM_CACHE[key] = (res, time.perf_counter() - t0)
    return _SIM_CACHE[key]


def timed_simulate_batch(seeds: Sequence[int], cfg: slotted_sim.SimConfig):
    """simulate_batch() with wall-time capture and (seeds, cfg) memoisation.

    Returns (list[SimResult], wall_seconds) -- one result per seed, computed
    in a single vmapped scan.
    """
    key = (tuple(seeds), cfg)
    if key not in _BATCH_CACHE:
        t0 = time.perf_counter()
        res = slotted_sim.simulate_batch(list(seeds), cfg)
        _BATCH_CACHE[key] = (res, time.perf_counter() - t0)
    return _BATCH_CACHE[key]


def row(name: str, wall_s: float, slots: int, derived: str, **extra) -> dict:
    """One CSV row; us_per_call is wall microseconds per simulated slot."""
    return {
        "name": name,
        "us_per_call": round(1e6 * wall_s / max(slots, 1), 3),
        "derived": derived,
        **extra,
    }


def fmt_derived(**kv) -> str:
    parts = []
    for k, v in kv.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return ";".join(parts)
