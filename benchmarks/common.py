"""Shared benchmark plumbing: grid-fused simulation runs, a cell cache, rows.

Every benchmark module exposes ``run(quick: bool) -> list[dict]``; each row
must carry ``name``, ``us_per_call`` and ``derived`` (the CSV contract of
``benchmarks/run.py``) plus any extra columns for the extended report.

Simulation sweeps go through :func:`timed_simulate_grid`: the caller hands
over its *entire* figure grid as a list of ``SimConfig`` cells; the helper
groups cells by their static part (shapes + kinds) and runs each group as
**one compiled program** via ``slotted_sim.simulate_grid`` -- one jit,
vmapped over the flattened (cell x seed) axis, shard_map-sharded across
local devices.  Compile count per figure is therefore O(#static groups),
not O(#cells).

Results are cached per ``(seed, SimConfig)`` cell because several paper
tables slice the same runs (e.g. the Fig 6 communication sweep and the
Thm 2.3 verification reuse identical (comm, approx, x) cells);
:func:`timed_simulate` and :func:`timed_simulate_batch` serve from the
same cache.
"""
from __future__ import annotations

import time
from typing import Sequence

import jax
import numpy as np

from repro.core.care import slotted_sim
from repro.serve import engine as serve_engine

# (seed, SimConfig) -> (SimResult, attributed wall seconds)
_CELL_CACHE: dict = {}

# (seed, ServeConfig) -> (ServeResult, attributed wall seconds)
_SERVE_CACHE: dict = {}

DEFAULT_SLOTS = 100_000
QUICK_SLOTS = 20_000

# The paper's simulation setting (Section 9.1).
SERVERS = 30
LOADS = (0.5, 0.8, 0.95)


def sim_slots(quick: bool) -> int:
    return QUICK_SLOTS if quick else DEFAULT_SLOTS


def timed_simulate_grid(
    cfgs: Sequence[slotted_sim.SimConfig], seeds: Sequence[int]
):
    """Run a figure grid fused: one ``simulate_grid`` call per static group.

    Returns ``(results, walls)`` aligned with ``cfgs``: ``results[i]`` is
    the list of per-seed :class:`SimResult` for cell ``i`` and ``walls[i]``
    its attributed wall time (a group's wall is split evenly across its
    cells).  Cells already in the cache are served from it and charged
    their original attributed wall time.
    """
    seeds = tuple(int(s) for s in seeds)
    pending: dict = {}  # StaticConfig -> {cfg: None} (ordered, deduped)
    for cfg in cfgs:
        if any((s, cfg) not in _CELL_CACHE for s in seeds):
            pending.setdefault(cfg.static_part(), {})[cfg] = None
    for static, group in pending.items():
        group_cfgs = list(group)
        t0 = time.perf_counter()
        grid = slotted_sim.simulate_grid(
            list(seeds), static, [c.scenario() for c in group_cfgs]
        )
        wall = time.perf_counter() - t0
        per_seed = wall / (len(group_cfgs) * len(seeds))
        for cfg, cell in zip(group_cfgs, grid):
            for s, r in zip(seeds, cell):
                _CELL_CACHE[(s, cfg)] = (r, per_seed)
    results, walls = [], []
    for cfg in cfgs:
        cached = [_CELL_CACHE[(s, cfg)] for s in seeds]
        results.append([r for r, _ in cached])
        walls.append(sum(w for _, w in cached))
    return results, walls


def percell_reference(
    cfgs: Sequence[slotted_sim.SimConfig], seeds: Sequence[int]
):
    """The pre-grid behaviour: one fresh compiled program per cell.

    Mirrors the old ``simulate_batch`` exactly -- a vmapped scan per
    ``SimConfig``, sharded over local devices only when the seed count
    divides them (the old ``pmap`` condition) -- but built fresh per cell
    so every cell pays its own compile, as it did when every scenario knob
    was a static jit argument.  Cells sharing a ``static_part()`` replay
    the same workload stream as the fused grid, so results are comparable
    bit for bit; benchmarks use this as the golden reference the fused
    path must reproduce (``grid_matches_percell`` rows).
    """
    keys = slotted_sim._as_keys(list(seeds))
    n_dev = jax.local_device_count()
    if len(seeds) % n_dev != 0:
        n_dev = 1
    results = []
    for cfg in cfgs:
        static, scn = cfg.static_part(), cfg.scenario()
        batched = jax.vmap(lambda key: slotted_sim._run_one(key, scn, static))
        if n_dev > 1:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P

            mesh = Mesh(np.asarray(jax.local_devices()[:n_dev]), ("runs",))
            batched = shard_map(
                batched, mesh=mesh, in_specs=(P("runs"),), out_specs=P("runs")
            )
        out = jax.jit(batched)(keys)
        out_np = [np.asarray(o) for o in out]
        results.append(
            [
                slotted_sim._finalize(
                    out_np[0][i], tuple(o[i] for o in out_np[1:])
                )
                for i in range(len(seeds))
            ]
        )
    return results


def grids_match(grid_results, percell_results) -> bool:
    """Bitwise per-cell equality of two result grids (messages, AQ, JCT)."""
    return all(
        g.messages == p.messages
        and g.max_aq == p.max_aq
        and np.array_equal(g.jct, p.jct)
        for grow, prow in zip(grid_results, percell_results)
        for g, p in zip(grow, prow)
    )


def timed_serve_grid(
    cells: Sequence[serve_engine.ServeConfig], seeds: Sequence[int]
):
    """Run a serving grid fused: one ``serve_grid`` call per static group.

    The serving analogue of :func:`timed_simulate_grid`: cells are grouped
    by their :meth:`~repro.serve.engine.ServeConfig.static_part` (shapes +
    comm kind; trigger thresholds are traced operands) and each group runs
    as one compiled program -- vmap over (cell x seed), shard_map across
    local devices.  Returns ``(results, walls)`` aligned with ``cells``
    (``results[i]`` is the per-seed list of ``ServeResult``); cached cells
    are served from ``_SERVE_CACHE`` at their original attributed wall.
    """
    seeds = tuple(int(s) for s in seeds)
    pending: dict = {}  # EngineStatic -> {cell: None} (ordered, deduped)
    for cell in cells:
        if any((s, cell) not in _SERVE_CACHE for s in seeds):
            pending.setdefault(cell.static_part(), {})[cell] = None
    for static, group in pending.items():
        group_cells = list(group)
        t0 = time.perf_counter()
        grid = serve_engine.serve_grid(list(seeds), static, group_cells)
        wall = time.perf_counter() - t0
        per_run = wall / (len(group_cells) * len(seeds))
        for cell, row in zip(group_cells, grid):
            for s, r in zip(seeds, row):
                _SERVE_CACHE[(s, cell)] = (r, per_run)
    results, walls = [], []
    for cell in cells:
        cached = [_SERVE_CACHE[(s, cell)] for s in seeds]
        results.append([r for r, _ in cached])
        walls.append(sum(w for _, w in cached))
    return results, walls


def serve_reference(cell: serve_engine.ServeConfig, seed: int) -> dict:
    """One numpy-reference serving run on the cell's shared workload.

    The pre-refactor execution model (a Python per-slot loop) and the
    golden the fused grid must reproduce bit for bit; benchmarks time it
    to build the sequential cost model behind ``serve/grid_speedup``.
    """
    return serve_engine.run_serving_sim(
        cell.engine_config(), slots=cell.slots, load=cell.load,
        mean_prefill=cell.mean_prefill, mean_decode=cell.mean_decode,
        seed=seed, workload=serve_engine.workload_for(cell, seed),
    )


def serve_matches_reference(
    result: serve_engine.ServeResult, ref: dict
) -> bool:
    """Bitwise equality of a fused-grid run and the numpy reference."""
    return (
        result.messages == ref["messages"]
        and result.completed == ref["completed"]
        and np.array_equal(result.jct_by_rid, ref["jct_by_rid"])
        and np.array_equal(result.final_occupancy, ref["final_occupancy"])
    )


def timed_simulate(seed: int, cfg: slotted_sim.SimConfig):
    """simulate() with wall-time capture and (seed, cfg) memoisation.

    Returns (SimResult, wall_seconds).  Cached calls return the original
    (attributed) wall time so ``us_per_call`` stays meaningful.
    """
    key = (int(seed), cfg)
    if key not in _CELL_CACHE:
        t0 = time.perf_counter()
        res = slotted_sim.simulate(jax.random.key(seed), cfg)
        _CELL_CACHE[key] = (res, time.perf_counter() - t0)
    return _CELL_CACHE[key]


def timed_simulate_batch(seeds: Sequence[int], cfg: slotted_sim.SimConfig):
    """simulate_batch() with wall-time capture and per-cell memoisation.

    Returns (list[SimResult], wall_seconds) -- one result per seed; the
    one-cell special case of :func:`timed_simulate_grid`.
    """
    results, walls = timed_simulate_grid([cfg], seeds)
    return results[0], walls[0]


def timed(fn, *args, **kw):
    """``(fn(*args), wall_s)`` with the clock stopped only after every
    array in the returned pytree is materialised.

    The single honest-wall primitive: timing a bare jitted call measures
    dispatch, not execution (JAX is async -- on CPU too), so every
    benchmark that hands back device values must stop the clock behind
    ``jax.block_until_ready`` over the *returned pytree*.  Host-side
    returns (lists, floats, numpy) pass through unchanged.
    """
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kw))
    return out, time.perf_counter() - t0


def row(name: str, wall_s: float, slots: int, derived: str, **extra) -> dict:
    """One CSV row; us_per_call is wall microseconds per simulated slot."""
    return {
        "name": name,
        "us_per_call": round(1e6 * wall_s / max(slots, 1), 3),
        "derived": derived,
        **extra,
    }


def fmt_derived(**kv) -> str:
    parts = []
    for k, v in kv.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return ";".join(parts)
