"""Paper Figures 2, 6 and 7: communication vs tolerated approximation error.

For each load in {0.5, 0.8, 0.95} and x in {2..8} this measures the relative
communication (messages per departure; the exact-state baseline is 1,
Prop 6.1) of:

* ET-x + MSR    (Fig 2 / Fig 6) -- expected to decay quadratically in the
  error budget y = x-1 and to sit *below* the Thm 2.5 bound 1/(x^2-x);
* ET-x + MSR-x  (Fig 7) -- expected below the Thm 2.3 bound 1/x but above
  the ET+MSR curve.

Each cell runs a seed sweep through ``simulate_batch`` (one vmapped scan);
the relative communication is averaged over seeds while the deterministic
guarantee AQ <= x-1 (Prop 6.8) is re-checked on *every* seed.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim, theory

XS = (2, 3, 4, 5, 6, 7, 8)
SEEDS = (0, 1, 2, 3)


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    xs = (2, 3, 5, 8) if quick else XS
    rows: list[dict] = []
    for fig, approx, bound_fn in (
        ("fig6_et_msr", "msr", theory.et_msr_relative_comm_backlogged),
        ("fig7_et_msrx", "msr_x", theory.dt_relative_comm),
    ):
        for load in common.LOADS:
            for x in xs:
                cfg = slotted_sim.SimConfig(
                    servers=common.SERVERS,
                    slots=slots,
                    load=load,
                    policy="jsaq",
                    comm="et",
                    x=x,
                    approx=approx,
                )
                res, wall = common.timed_simulate_batch(SEEDS, cfg)
                rel = float(np.mean([r.msgs_per_departure for r in res]))
                max_aq = max(r.max_aq for r in res)
                bound = float(bound_fn(x))
                ok_aq = max_aq <= x - 1
                ok_bound = rel <= bound + 1e-9
                rows.append(
                    common.row(
                        f"{fig}/load{load}/x{x}",
                        wall,
                        slots * len(SEEDS),
                        common.fmt_derived(
                            rel_comm=rel,
                            bound=bound,
                            below_bound=ok_bound,
                            max_aq=max_aq,
                            aq_ok=ok_aq,
                            seeds=len(SEEDS),
                        ),
                        rel_comm=rel,
                        bound=bound,
                        max_aq=max_aq,
                        ok=bool(ok_aq and ok_bound),
                    )
                )
    return rows
