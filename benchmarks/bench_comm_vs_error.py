"""Paper Figures 2, 6 and 7: communication vs tolerated approximation error.

For each load in {0.5, 0.8, 0.95} and x in {2..8} this measures the relative
communication (messages per departure; the exact-state baseline is 1,
Prop 6.1) of:

* ET-x + MSR    (Fig 2 / Fig 6) -- expected to decay quadratically in the
  error budget y = x-1 and to sit *below* the Thm 2.5 bound 1/(x^2-x);
* ET-x + MSR-x  (Fig 7) -- expected below the Thm 2.3 bound 1/x but above
  the ET+MSR curve.

The whole ``(load, x)`` grid of a figure runs as **one compiled program**
(``slotted_sim.simulate_grid``: load and x are traced ``Scenario``
operands, vmapped over the flattened cell x seed axis and sharded across
devices with ``shard_map``); only the approximation *kind* differs between
the two figures, so the full benchmark compiles exactly two programs
instead of one per cell.  The relative communication is averaged over
seeds while the deterministic guarantee AQ <= x-1 (Prop 6.8) is re-checked
on *every* seed.

In quick mode two extra rows record the fusion win on this box:

* ``grid/compile_count`` -- programs compiled for the figure grids vs the
  number of grid cells;
* ``grid/speedup`` -- end-to-end wall clock of the fused grid (cold,
  including its compile) vs the pre-grid per-cell path (one fresh compile
  per cell, seeds sharded when they divide the device count -- the old
  ``pmap`` behaviour), with per-cell results verified identical.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim, theory

XS = (2, 3, 4, 5, 6, 7, 8)
SEEDS = (0, 1, 2, 3)

FIGS = (
    ("fig6_et_msr", "msr", theory.et_msr_relative_comm_backlogged),
    ("fig7_et_msrx", "msr_x", theory.dt_relative_comm),
)


def _grid_cells(slots: int, xs) -> list[tuple[str, int, slotted_sim.SimConfig]]:
    cells = []
    for fig, approx, _ in FIGS:
        for load in common.LOADS:
            for x in xs:
                cells.append(
                    (
                        fig,
                        x,
                        slotted_sim.SimConfig(
                            servers=common.SERVERS,
                            slots=slots,
                            load=load,
                            policy="jsaq",
                            comm="et",
                            x=x,
                            approx=approx,
                        ),
                    )
                )
    return cells


def _fusion_rows(cells, slots: int) -> list[dict]:
    """Measure the fused grid vs the per-cell loop, both cold."""
    cfgs = [cfg for _, _, cfg in cells]
    compiles_before = slotted_sim.grid_compile_count()

    t0 = time.perf_counter()
    grid_results, _ = common.timed_simulate_grid(cfgs, SEEDS)
    t_grid = time.perf_counter() - t0
    n_programs = slotted_sim.grid_compile_count() - compiles_before

    t0 = time.perf_counter()
    percell_results = common.percell_reference(cfgs, SEEDS)
    t_percell = time.perf_counter() - t0

    match = common.grids_match(grid_results, percell_results)
    total_slots = slots * len(cfgs) * len(SEEDS)
    speedup = t_percell / max(t_grid, 1e-9)
    return [
        common.row(
            "grid/compile_count",
            0.0,
            slots,
            common.fmt_derived(
                programs=n_programs, cells=len(cfgs), seeds=len(SEEDS)
            ),
            programs=n_programs,
            cells=len(cfgs),
        ),
        common.row(
            "grid/speedup",
            t_grid,
            total_slots,
            common.fmt_derived(
                t_grid_s=t_grid,
                t_percell_s=t_percell,
                speedup=speedup,
                grid_matches_percell=match,
                devices=jax.local_device_count(),
            ),
            speedup=speedup,
            # Top-level boolean so the trajectory diff treats a broken
            # grid-vs-percell equivalence as a CI-failing regression.
            grid_matches_percell=bool(match),
        ),
    ]


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    xs = (2, 3, 5, 8) if quick else XS
    cells = _grid_cells(slots, xs)

    rows: list[dict] = []
    # In quick mode, time the cold fused grid against the per-cell loop
    # first (this also fills the cell cache the figure rows read from).
    if quick:
        fusion_rows = _fusion_rows(cells, slots)
    else:
        fusion_rows = []

    cfgs = [cfg for _, _, cfg in cells]
    results, walls = common.timed_simulate_grid(cfgs, SEEDS)

    bound_fns = {fig: bound_fn for fig, _, bound_fn in FIGS}
    for (fig, x, cfg), res, wall in zip(cells, results, walls):
        rel = float(np.mean([r.msgs_per_departure for r in res]))
        max_aq = max(r.max_aq for r in res)
        bound = float(bound_fns[fig](x))
        ok_aq = max_aq <= x - 1
        ok_bound = rel <= bound + 1e-9
        rows.append(
            common.row(
                f"{fig}/load{cfg.load}/x{x}",
                wall,
                slots * len(SEEDS),
                common.fmt_derived(
                    rel_comm=rel,
                    bound=bound,
                    below_bound=ok_bound,
                    max_aq=max_aq,
                    aq_ok=ok_aq,
                    seeds=len(SEEDS),
                ),
                rel_comm=rel,
                bound=bound,
                max_aq=max_aq,
                ok=bool(ok_aq and ok_bound),
            )
        )
    return rows + fusion_rows
