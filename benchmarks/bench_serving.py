"""Serving-tier CARE: request dispatch across replica groups (paper Fig 3,
restated for continuous-batching inference).

Requests are jobs, replica groups are servers; the dispatcher routes over
CARE-approximated occupancy and replicas send corrections through the
shared trigger core.  Compared regimes per load: exact state (1 message
per completion), ET-4, DT-4, RT-16, plus the ET-x frontier (x = 2/8/16)
showing the JCT/communication trade.

The **policy x comm frontier** (``serve/policy/*`` rows) measures the
paper's composition claim -- sparse-communication state approximation
works under *any* queue-driven routing rule -- across the full routing
suite (JSAQ / SQ(2) / round robin / drain-time-aware JSAQ) x (exact /
ET-4 / DT-4 / RT-16), each under uniform and 2:1 heterogeneous replica
speeds.  Rate profiles are traced ``EngineScenario`` operands, so the 32
frontier cells compile one program per (policy, comm) kind pair --
O(#kinds), recorded by ``serve/policy_frontier/compile_count``.

Execution model (post jax port): each load's whole regime ladder is
submitted as fused grids through ``common.timed_serve_grid`` -- cells are
grouped by comm *kind* (thresholds are traced operands, so the entire ET
ladder shares one compiled program) and each group runs as one jitted
vmap-over-(cell x seed) scan, shard_map-sharded across local devices.
Compile count per load is O(#kinds), not O(cells) -- the
``serve/grid_compile_count`` row records it.  ``serve/grid_speedup``
measures the fused wall against the *sequential pre-refactor cost model*
(the numpy per-slot loop, probed on one cell and extrapolated across the
ladder), with the probe's fused result verified bit-identical to the numpy
reference.  ``serve/replicas1024`` scales the vectorised replica step past
1k replicas -- far beyond what the Python loop sustains -- and reports its
own cost-model comparison.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks import common
from repro.serve import engine


LOADS = (0.7, 0.9)
ET_FRONTIER = (2, 8, 16)

# The routing-policy frontier: every policy x comm kind, under uniform and
# 2:1 heterogeneous decode rates (half the replicas double speed; explicit
# all-ones rates keep the uniform control in the *same* compiled program,
# since only the presence of rates is structural).
POLICIES = ("jsaq", "sqd", "rr", "drain")
MATRIX_COMMS = ("exact", "et", "dt", "rt")
RATE_PROFILES = (
    ("uniform", (1.0,) * 8),
    ("hetero21", (2.0,) * 4 + (1.0,) * 4),
)

# The MSR drain must emulate the *nominal* per-replica completion rate --
# decode_slots / mean_work = 16/64 = 0.25 completions/slot/busy replica
# (dyadic, so the f32 traced path stays bit-identical to the reference).
# The old engine default of 1.0 overestimated it 4x, draining the
# approximation to zero and making ET fire on emulation bias rather than
# genuine state drift.
_WORK = dict(mean_prefill=4, mean_decode=60, msr_drain=0.25)


def _cell(load: float, slots: int, **kw) -> engine.ServeConfig:
    return engine.ServeConfig(slots=slots, load=load, **_WORK, **kw)


def _ladder(load: float, slots: int) -> list[tuple[str, engine.ServeConfig]]:
    cells = [
        ("exact", _cell(load, slots, comm="exact")),
        ("et", _cell(load, slots, comm="et", x=4)),
        ("dt", _cell(load, slots, comm="dt", x=4)),
        ("rt", _cell(load, slots, comm="rt", rt_period=16)),
    ]
    for x in ET_FRONTIER:
        cells.append((f"et_x{x}", _cell(load, slots, comm="et", x=x)))
    return cells


def _mean(vals) -> float:
    return float(np.mean(vals))


def run(quick: bool = False) -> list[dict]:
    slots = 4_000 if quick else 20_000
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rows: list[dict] = []

    grid_wall_total = 0.0
    ladder_runs = 0
    no_drops = True  # bit-identity claim guard: the fixed ring never filled
    for load in LOADS:
        named = _ladder(load, slots)
        results, walls = common.timed_serve_grid(
            [c for _, c in named], seeds
        )
        grid_wall_total += sum(walls)
        ladder_runs += len(named) * len(seeds)
        no_drops &= all(r.dropped == 0 for row in results for r in row)
        summary = {}
        for (name, _), per_seed, wall in zip(named, results, walls):
            mean_jct = _mean([r.mean_jct for r in per_seed])
            p99_jct = _mean([r.p99_jct for r in per_seed])
            mpc = _mean([r.msgs_per_completion for r in per_seed])
            completed = int(np.sum([r.completed for r in per_seed]))
            summary[name] = (mean_jct, mpc)
            rows.append(
                common.row(
                    f"serve/load{load}/{name}",
                    wall,
                    slots,
                    common.fmt_derived(
                        mean_jct=mean_jct,
                        p99_jct=p99_jct,
                        msgs_per_completion=mpc,
                        completed=completed,
                        seeds=len(seeds),
                    ),
                    mean_jct=mean_jct,
                    msgs_per_completion=mpc,
                )
            )
        rows.append(
            common.row(
                f"serve/load{load}/headline",
                0.0,
                slots,
                common.fmt_derived(
                    et_jct_vs_exact=summary["et"][0]
                    / max(summary["exact"][0], 1e-9),
                    et_comm_vs_exact=summary["et"][1]
                    / max(summary["exact"][1], 1e-9),
                ),
            )
        )

    # --- policy x comm frontier (uniform + 2:1 heterogeneous speeds) ----
    # queue_cap 4096: rate-blind RR leaves the slow half of a hetero21
    # cell individually unstable, so its backlog grows ~0.09/slot -- the
    # default 512-entry traced ring would fill before the full-mode
    # 20000-slot horizon and the dropped arrivals would void the
    # bit-identity guard (the numpy reference ring grows on demand).
    named_matrix = [
        (policy, comm, pname,
         _cell(0.9, slots, comm=comm, x=4.0, policy=policy,
               decode_rates=rates, queue_cap=4096))
        for policy in POLICIES
        for comm in MATRIX_COMMS
        for pname, rates in RATE_PROFILES
    ]
    progs_before = engine.serve_compile_count()
    m_results, m_walls = common.timed_serve_grid(
        [c for *_, c in named_matrix], seeds
    )
    frontier_programs = engine.serve_compile_count() - progs_before
    no_drops &= all(r.dropped == 0 for row in m_results for r in row)
    frontier: dict = {}
    for (policy, comm, pname, _), per_seed, wall in zip(
        named_matrix, m_results, m_walls
    ):
        mean_jct = _mean([r.mean_jct for r in per_seed])
        mpc = _mean([r.msgs_per_completion for r in per_seed])
        frontier[(policy, comm, pname)] = (mean_jct, mpc)
        rows.append(
            common.row(
                f"serve/policy/{policy}/{comm}/{pname}",
                wall,
                slots,
                common.fmt_derived(
                    mean_jct=mean_jct,
                    p99_jct=_mean([r.p99_jct for r in per_seed]),
                    msgs_per_completion=mpc,
                    completed=int(np.sum([r.completed for r in per_seed])),
                    seeds=len(seeds),
                ),
                mean_jct=mean_jct,
                msgs_per_completion=mpc,
            )
        )
    # Headline: under 2:1 speeds the rate-aware policies hold the exact
    # JCT at a fraction of the messages, while rate-blind round robin
    # collapses -- per profile, everything relative to jsaq at ET-4.
    for pname, _ in RATE_PROFILES:
        jsaq_jct, jsaq_mpc = frontier[("jsaq", "et", pname)]
        rows.append(
            common.row(
                f"serve/policy_frontier/{pname}",
                0.0,
                slots,
                common.fmt_derived(
                    drain_jct_vs_jsaq=frontier[("drain", "et", pname)][0]
                    / max(jsaq_jct, 1e-9),
                    rr_jct_vs_jsaq=frontier[("rr", "et", pname)][0]
                    / max(jsaq_jct, 1e-9),
                    sqd_jct_vs_jsaq=frontier[("sqd", "et", pname)][0]
                    / max(jsaq_jct, 1e-9),
                    et_mpc_vs_exact=jsaq_mpc
                    / max(frontier[("jsaq", "exact", pname)][1], 1e-9),
                ),
            )
        )
    rows.append(
        common.row(
            "serve/policy_frontier/compile_count",
            0.0,
            slots,
            common.fmt_derived(
                programs=frontier_programs,
                cells=len(named_matrix),
                kind_pairs=len(POLICIES) * len(MATRIX_COMMS),
                fused=frontier_programs <= len(POLICIES) * len(MATRIX_COMMS),
            ),
            programs=frontier_programs,
            fused=frontier_programs <= len(POLICIES) * len(MATRIX_COMMS),
        )
    )

    # Steady-state wall: replay both ladders on the *same* seeds (identical
    # workloads, so every compiled program is reused at its exact shape) --
    # the cold pass above paid the O(#kinds) compiles, this one measures
    # pure throughput.
    t0 = time.perf_counter()
    for load in LOADS:
        groups: dict = {}
        for _, cell in _ladder(load, slots):
            groups.setdefault(cell.static_part(), []).append(cell)
        for group_static, group in groups.items():
            engine.serve_grid(list(seeds), group_static, group)
    warm_wall = time.perf_counter() - t0

    # Sequential pre-refactor cost model: the numpy per-slot loop, timed
    # on one ladder cell and extrapolated across every (cell, seed) run
    # the fused grids executed.  The probe doubles as the bit-identity
    # check of the fused path against the golden reference.
    probe_cell = _cell(LOADS[-1], slots, comm="et", x=4)
    t0 = time.perf_counter()
    ref = common.serve_reference(probe_cell, seeds[0])
    probe_wall = time.perf_counter() - t0
    probe_fused = common.timed_serve_grid([probe_cell], (seeds[0],))[0][0][0]
    matches = common.serve_matches_reference(probe_fused, ref)
    cost_model = probe_wall * ladder_runs
    rows.append(
        common.row(
            "serve/grid_speedup",
            warm_wall / max(ladder_runs, 1),
            slots,
            common.fmt_derived(
                t_grid_warm_s=round(warm_wall, 3),
                t_grid_cold_s=round(grid_wall_total, 3),
                t_seq_model_s=round(cost_model, 3),
                speedup=cost_model / max(warm_wall, 1e-9),
                grid_matches_reference=matches,
                no_drops=no_drops,
                runs=ladder_runs,
                devices=common.jax.local_device_count(),
            ),
            speedup=cost_model / max(warm_wall, 1e-9),
            grid_matches_reference=matches,
            no_drops=no_drops,
        )
    )

    # Past-1k-replica cell: the vectorised replica step at a scale the
    # Python loop cannot sustain (its cost model is probed on a short
    # prefix and extrapolated).
    big = _cell(
        0.9, 512 if quick else 2_048, comm="et", x=4,
        replicas=1024, decode_slots=16, queue_cap=128,
    )
    big_seeds = (0, 1)
    big_res, _ = common.timed_serve_grid([big], big_seeds)
    t0 = time.perf_counter()
    engine.serve_grid(list(big_seeds), big.static_part(), [big])
    big_wall = time.perf_counter() - t0  # warm replay: compile paid above
    probe_slots = 64
    probe = dataclasses.replace(big, slots=probe_slots, max_slots=None)
    t0 = time.perf_counter()
    common.serve_reference(probe, 0)
    big_model = (time.perf_counter() - t0) / probe_slots * big.slots
    big_model *= len(big_seeds)
    per_seed = big_res[0]
    rows.append(
        common.row(
            "serve/replicas1024",
            big_wall,
            big.slots,
            common.fmt_derived(
                replicas=big.replicas,
                offered=int(np.sum([r.offered for r in per_seed])),
                completed=int(np.sum([r.completed for r in per_seed])),
                dropped=int(np.sum([r.dropped for r in per_seed])),
                mean_jct=_mean([r.mean_jct for r in per_seed]),
                msgs_per_completion=_mean(
                    [r.msgs_per_completion for r in per_seed]
                ),
                t_seq_model_s=round(big_model, 3),
                speedup=big_model / max(big_wall, 1e-9),
            ),
            mean_jct=_mean([r.mean_jct for r in per_seed]),
            msgs_per_completion=_mean(
                [r.msgs_per_completion for r in per_seed]
            ),
            no_drops=all(r.dropped == 0 for r in per_seed),
            speedup=big_model / max(big_wall, 1e-9),
        )
    )

    rows.append(
        common.row(
            "serve/grid_compile_count",
            0.0,
            slots,
            common.fmt_derived(
                programs=engine.serve_compile_count(),
                loads=len(LOADS),
                kinds=4,
                policy_kind_pairs=len(POLICIES) * len(MATRIX_COMMS),
                cells=len(_ladder(LOADS[0], slots)) * len(LOADS)
                + len(named_matrix) + 1,
            ),
            programs=engine.serve_compile_count(),
        )
    )
    return rows
