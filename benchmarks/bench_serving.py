"""Serving-tier CARE: request dispatch across replica groups (paper Fig 3,
restated for continuous-batching inference).

Requests are jobs, replica groups are servers; the dispatcher routes by
JSAQ over CARE-approximated occupancy and replicas send ET-x corrections.
Compared regimes: exact state (1 message per completion), ET-4, DT-4, RT,
and the x-sweep of ET to show the JCT/communication frontier.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.serve import engine


def _run_one(name, cfg, slots, load, rows):
    t0 = time.perf_counter()
    out = engine.run_serving_sim(cfg, slots=slots, load=load, seed=0)
    wall = time.perf_counter() - t0
    rows.append(
        common.row(
            name,
            wall,
            slots,
            common.fmt_derived(
                mean_jct=out["mean_jct"],
                p99_jct=out["p99_jct"],
                msgs_per_completion=out["msgs_per_completion"],
                completed=out["completed"],
            ),
            mean_jct=out["mean_jct"],
            msgs_per_completion=out["msgs_per_completion"],
        )
    )
    return out


def run(quick: bool = False) -> list[dict]:
    slots = 4_000 if quick else 20_000
    rows: list[dict] = []
    for load in (0.7, 0.9):
        base = {}
        for comm in ("exact", "et", "dt", "rt"):
            cfg = engine.EngineConfig(comm=comm, et_x=4, dt_x=4, rt_period=16)
            base[comm] = _run_one(
                f"serve/load{load}/{comm}", cfg, slots, load, rows
            )
        # ET frontier: JCT degradation vs message reduction as x grows.
        for x in (2, 8, 16):
            cfg = engine.EngineConfig(comm="et", et_x=x)
            _run_one(f"serve/load{load}/et_x{x}", cfg, slots, load, rows)
        rows.append(
            common.row(
                f"serve/load{load}/headline",
                0.0,
                slots,
                common.fmt_derived(
                    et_jct_vs_exact=base["et"]["mean_jct"]
                    / max(base["exact"]["mean_jct"], 1e-9),
                    et_comm_vs_exact=base["et"]["msgs_per_completion"]
                    / max(base["exact"]["msgs_per_completion"], 1e-9),
                ),
            )
        )
    return rows
