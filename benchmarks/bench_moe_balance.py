"""Beyond-paper benchmark: the CARE balancer at the MoE/expert tier.

Two sections:

**A. Training tier** (``repro/train`` + ``repro/core/moe_balancer``): a
reduced DeepSeek-V2-family model whose gate is *initialised with a
persistent expert skew* trains for a few dozen steps.  With a single
in-process dispatcher the balancer's emulation is exact (Remark 4.6), so
this section demonstrates the PI *controller*: the JSAQ bias driven by the
approximated load cancels the skew (off vs care), and the ET trigger
correctly stays silent (zero messages) because the error is zero.

**B. Dispatch tier** (``repro/core/dispatch_sim``): the paper's full
multi-dispatcher queueing setting mapped onto expert parallelism --
``D`` routers, ``E`` experts with finite service capacity and backlog
queues, heterogeneous drifting traffic.  Here communication *matters*:
pure local emulation (off) blows up the queue gap; ET-x matches or beats
the every-step-sync baseline at ~10% of the messages -- the paper's
headline restated for EP.  (Every-step sync can even be *worse* than
sparse sync: identical state at all dispatchers causes herding, the
[VKO20] incast effect approximate state is known to mitigate.)

Reported: expert-load imbalance / queue gap / backlog, and the number of
messages or syncs, per regime.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.configs.base import CareConfig
from repro.core import moe_balancer
from repro.core.dispatch_sim import DispatchSimConfig, dispatch_batch
from repro.data import pipeline
from repro.optim import adamw
from repro.train import train_loop

BATCH, SEQ = 8, 128
GATE_SKEW = 1.5  # persistent per-expert gate preference injected at init


def _reduced_moe(care: CareConfig):
    cfg = get_config("deepseek-v2-236b").reduced()
    return dataclasses.replace(cfg, care=care, remat=False)


def _skew_gate(params) -> None:
    """Amplify the first gate columns: a gate that systematically prefers
    some experts (the persistent-imbalance source the controller must fix)."""
    g = params["layers"]["moe"]["gate"]
    e = g.shape[-1]
    mult = (
        1.0
        + GATE_SKEW * jax.nn.one_hot(0, e)
        + 0.7 * GATE_SKEW * jax.nn.one_hot(1, e)
    )
    params["layers"]["moe"]["gate"] = g * mult[None, None, :]


def _train(cfg, steps: int, sync_every_step: bool, seed: int = 0):
    """Host-level loop mirroring launch/train.py's two-program schedule."""
    data_cfg = pipeline.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=SEQ, global_batch=BATCH, seed=seed
    )
    state = train_loop.init_state(jax.random.key(seed), cfg, None)
    _skew_gate(state.params)
    step_fn = jax.jit(
        train_loop.make_train_step(cfg, adamw.OptimConfig(), None, sync=False)
    )
    sync_fn = jax.jit(lambda b: moe_balancer.sync(b, cfg.care))

    losses, imb, syncs = [], [], 0
    for s in range(steps):
        batch = {
            k: jnp.asarray(v)
            for k, v in pipeline.global_batch_at(s, data_cfg).items()
        }
        prev_counts = (
            state.balancer.true_counts if state.balancer is not None else None
        )
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if state.balancer is not None:
            step_counts = np.asarray(state.balancer.true_counts - prev_counts)
            per_layer = step_counts  # (L, E)
            mean = per_layer.mean(axis=-1) + 1e-9
            imb.append(float((per_layer.max(axis=-1) / mean).mean()))
            do_sync = sync_every_step or bool(metrics["sync_trigger"])
            if do_sync and cfg.care.enabled:
                state = dataclasses.replace(
                    state, balancer=sync_fn(state.balancer)
                )
                syncs += 1
    # The loop only forces metrics["loss"]; the last step's state update
    # can still be in flight when the caller's clock stops.
    jax.block_until_ready(state)
    return losses, imb, syncs


def _section_a(quick: bool) -> list[dict]:
    steps = 12 if quick else 48
    regimes = {
        "off": (CareConfig(enabled=False), False),
        "sync_every": (CareConfig(enabled=True, comm="dt", x=1), True),
        "care_dt8": (CareConfig(enabled=True, comm="dt", x=8), False),
        "care_et": (CareConfig(enabled=True, comm="et", x=2), False),
    }
    rows = []
    results = {}
    for name, (care, every) in regimes.items():
        cfg = _reduced_moe(care)
        (losses, imb, syncs), wall = common.timed(_train, cfg, steps, every)
        half = len(imb) // 2
        tail_imb = float(np.mean(imb[half:])) if imb else 0.0
        results[name] = (tail_imb, losses[-1], syncs)
        rows.append(
            common.row(
                f"moe_balance/train/{name}",
                wall,
                steps,
                common.fmt_derived(
                    imb_max_over_mean=tail_imb,
                    final_loss=losses[-1],
                    syncs=syncs,
                    sync_rate=syncs / steps,
                ),
                imbalance=tail_imb,
                syncs=syncs,
            )
        )
    imb_off = results["off"][0]
    imb_full = results["sync_every"][0]
    imb_dt = results["care_dt8"][0]
    sync_saving = 1.0 - results["care_dt8"][2] / max(results["sync_every"][2], 1)
    rows.append(
        common.row(
            "moe_balance/train/headline",
            0.0,
            steps,
            common.fmt_derived(
                imbalance_off=imb_off,
                imbalance_fullsync=imb_full,
                imbalance_care_dt8=imb_dt,
                comm_saving=sync_saving,
                care_improves_on_off=bool(imb_dt <= imb_off - 0.1),
                care_matches_fullsync=bool(imb_dt <= imb_full + 0.1),
            ),
        )
    )
    return rows


def _section_b(quick: bool) -> list[dict]:
    # The queue sim needs ~400 steps of warm-up before the steady-state
    # window is meaningful, so quick mode keeps the full horizon but fewer
    # seeds.  Reported per regime (seed-averaged): the steady-state queue
    # gap (paper's SSC metric), the transient gap (convergence cost of
    # sparse state), backlog, and relative communication.
    steps, seeds = (800, 2) if quick else (800, 5)
    regimes = [
        ("no_bias", DispatchSimConfig(enabled=False, comm="off", steps=steps)),
        ("off", DispatchSimConfig(comm="off", steps=steps)),
        ("exact", DispatchSimConfig(comm="exact", x=1, steps=steps)),
        ("dt8", DispatchSimConfig(comm="dt", x=8, steps=steps)),
        ("et4", DispatchSimConfig(comm="et", x=4, steps=steps)),
        ("et8", DispatchSimConfig(comm="et", x=8, steps=steps)),
    ]
    rows = []
    results = {}
    for name, cfg in regimes:
        # All seeds in one vmapped scan (dispatch_batch), not a Python
        # loop; timed() blocks on the returned results before the clock
        # stops.
        rs, wall = common.timed(dispatch_batch, range(seeds), cfg)
        agg = {
            "tail_gap": float(np.mean([r.tail_gap for r in rs])),
            "transient_gap": float(np.mean([r.transient_gap for r in rs])),
            "tail_backlog": float(np.mean([r.tail_backlog for r in rs])),
            "rel_comm": float(np.mean([r.rel_comm for r in rs])),
            "max_err": float(np.max([r.max_err for r in rs])),
        }
        results[name] = agg
        rows.append(
            common.row(
                f"moe_balance/dispatch/{name}",
                wall,
                cfg.steps * seeds,
                common.fmt_derived(
                    queue_gap=agg["tail_gap"],
                    transient_gap=agg["transient_gap"],
                    backlog=agg["tail_backlog"],
                    rel_comm=agg["rel_comm"],
                    max_err_mu=agg["max_err"],
                ),
            )
        )
    ex, et, off = results["exact"], results["et4"], results["off"]
    rows.append(
        common.row(
            "moe_balance/dispatch/headline",
            0.0,
            steps,
            common.fmt_derived(
                et4_gap_vs_exact=et["tail_gap"] / max(ex["tail_gap"], 1e-9),
                et4_rel_comm=et["rel_comm"],
                comm_saving=1.0 - et["rel_comm"],
                # ET with ~5% of the messages matches (here: beats, by
                # avoiding herding) the every-step exact-state baseline.
                et_matches_exact=bool(et["tail_gap"] <= 1.1 * ex["tail_gap"]),
                # Never communicating pays a large convergence cost even
                # though the PI controller eventually balances locally.
                off_transient_vs_et=off["transient_gap"]
                / max(et["transient_gap"], 1e-9),
            ),
        )
    )
    return rows


def run(quick: bool = False) -> list[dict]:
    return _section_a(quick) + _section_b(quick)
