"""Theorem 2.3 verification sweep: AQ <= x-1 and M <= D/x, for all t.

Runs every (pattern, algorithm) combination the theorem covers -- DT-x and
ET-x with the basic and MSR-x approximations -- across loads and x values,
asserting both deterministic guarantees on the simulated trajectories.
ET-x + MSR rows verify the AQ bound only (Prop 6.8: ET bounds the error for
ANY emulation algorithm; the message bound is stochastic, Prop 6.9).

The whole sweep is submitted as one grid (``common.timed_simulate_grid``):
``load`` and ``x`` are traced ``Scenario`` operands, so the cells group
into one compiled program per (comm, approx) kind pair -- five programs
for the whole table instead of one per cell -- and cells shared with the
Fig 6 sweep (``bench_comm_vs_error``) are served from the common cache.
"""
from __future__ import annotations

from benchmarks import common
from repro.core.care import slotted_sim

COMBOS = [
    ("dt", "basic"),
    ("dt", "msr_x"),
    ("et", "basic"),
    ("et", "msr_x"),
    ("et", "msr"),
]


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    xs = (2, 4) if quick else (2, 3, 4, 6, 8)
    loads = (0.95,) if quick else (0.8, 0.95)

    cells = []
    for comm, approx in COMBOS:
        for load in loads:
            for x in xs:
                cells.append(
                    (
                        comm,
                        approx,
                        load,
                        x,
                        slotted_sim.SimConfig(
                            servers=common.SERVERS,
                            slots=slots,
                            load=load,
                            policy="jsaq",
                            comm=comm,
                            x=x,
                            approx=approx,
                        ),
                    )
                )
    results, walls = common.timed_simulate_grid(
        [cfg for *_, cfg in cells], (0,)
    )

    rows = []
    n_fail = 0
    for (comm, approx, load, x, _), res_list, wall in zip(
        cells, results, walls
    ):
        res = res_list[0]
        aq_ok = res.max_aq <= x - 1
        msg_bound_applies = not (comm == "et" and approx == "msr")
        msg_ok = (not msg_bound_applies) or (
            res.messages <= res.departures / x + 1
        )
        ok = aq_ok and msg_ok
        n_fail += int(not ok)
        rows.append(
            common.row(
                f"thm23/{comm}_{approx}/load{load}/x{x}",
                wall,
                slots,
                common.fmt_derived(
                    max_aq=res.max_aq,
                    aq_bound=x - 1,
                    msgs_per_dep=res.msgs_per_departure,
                    ok=ok,
                ),
                ok=ok,
            )
        )
    rows.append(
        common.row(
            "thm23/summary", 0.0, slots,
            common.fmt_derived(cells=len(rows), violations=n_fail),
            # Top-level so the trajectory diff gates on the violation count.
            violations=n_fail,
        )
    )
    return rows
