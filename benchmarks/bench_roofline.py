"""Deliverable (g): roofline terms per (arch x shape x mesh) cell.

Reads the dry-run artifacts (benchmark cells were compiled AOT against the
production meshes by ``repro.launch.dryrun``) and reports, per cell, the
three roofline terms in seconds, the dominant bottleneck, the useful-FLOP
ratio (6ND model FLOPs over compiled HLO FLOPs) and the projected roofline
MFU.  ``us_per_call`` is the projected step time in microseconds.
"""
from __future__ import annotations

from benchmarks import common
from repro.launch import roofline


def run(quick: bool = False) -> list[dict]:
    cells = roofline.full_table()
    rows = []
    by_dominant = {"compute": 0, "memory": 0, "collective": 0}
    for c in cells:
        if quick and c.mesh != "pod16x16":
            continue
        by_dominant[c.dominant] += 1
        rows.append(
            {
                "name": f"roofline/{c.tag}",
                "us_per_call": round(c.step_s * 1e6, 1),
                "derived": common.fmt_derived(
                    dominant=c.dominant,
                    compute_s=c.compute_s,
                    memory_s=c.memory_s,
                    collective_s=c.collective_s,
                    useful=c.useful_ratio,
                    mfu=c.mfu,
                ),
                "dominant": c.dominant,
                "mfu": c.mfu,
            }
        )
    rows.append(
        {
            "name": "roofline/summary",
            "us_per_call": 0.0,
            "derived": common.fmt_derived(
                cells=len(cells),
                compute_bound=by_dominant["compute"],
                memory_bound=by_dominant["memory"],
                collective_bound=by_dominant["collective"],
            ),
        }
    )
    return rows
