"""Mean-field scale: the fused Pallas route kernel vs the dense backend.

The paper's mean-field / diffusion claims (Sections 5-7) are statements
about n -> infinity; the dense slotted backend tops out around 10^4-10^5
servers because every slot materialises the full per-server carry through
the scan *and* a (K, B) FIFO ring.  The fused kernel
(``kernels/jsaq_route.care_route_pallas``) keeps the per-server state
resident across its in-kernel slot loop, drops the per-job ring (no JCT at
mean-field scale), and evaluates the trigger predicate in the same kernel
-- one ``pallas_call`` per simulation instead of one scan step per slot.

Rows:

* ``route/parity`` -- the kernel is *decision identical* to the dense
  backend (trajectory-diff gated bool): same messages, same AQ sup, same
  per-server arrival vector at every swept K where both backends run.
* ``route/crossover`` -- dense-vs-kernel wall clock over the server sweep
  (times as machine-dependent ``*_s`` fields; the crossover point itself
  as a string note) plus the ``speedup`` at the largest dense-feasible K.
* ``route/servers1e3..1e6`` -- per-K simulation metrics from the kernel
  path: messages, AQ sup vs the Theorem 2.3 bound, sup queue gap.  These
  are exact integers from a fixed stream (deterministic ties +
  deterministic service), so the 2% trajectory gate pins them tight.
* ``route/ssc/*`` -- the diffusion-limit prediction at mean-field scale:
  sup_t max_ij |Q_i - Q_j| stays O(1) as n grows through {1e3..1e6}, so
  the sqrt(n)-scaled gap collapses (Theorem 7.3 read through the SSC
  lens); ``route/ssc/summary`` gates the monotone-collapse claim.

Quick mode sweeps n in {1e3, 1e4, 1e5} on a 1000-slot horizon; full mode
lengthens the horizon and adds the kernel-only n = 1e6 point (the dense
backend is not run there -- that scale is the kernel's reason to exist).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim

SWEEP = (1_000, 10_000, 100_000)
FULL_EXTRA = (1_000_000,)
QUICK_SLOTS = 1_000
FULL_SLOTS = 4_000
X = 3
SEED = 7


def _label(k: int) -> str:
    return f"{k:.0e}".replace("e+0", "e").replace("e+", "e")


def _cfg(servers: int, slots: int, backend: str) -> slotted_sim.SimConfig:
    return slotted_sim.SimConfig(
        servers=servers,
        slots=slots,
        load=0.95,
        mean_service=8,
        policy="jsaq",
        comm="dt",
        x=X,
        approx="msr",
        service="deterministic",
        buffer_cap=16,
        deterministic_ties=True,
        route_backend=backend,
    )


def _timed(cfg: slotted_sim.SimConfig):
    """(result, cold_s, warm_s): first call pays the compile, second runs
    the cached program -- the crossover compares steady-state walls."""
    key = jax.random.key(SEED)
    t0 = time.perf_counter()
    res = slotted_sim.simulate(key, cfg)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = slotted_sim.simulate(key, cfg)
    warm = time.perf_counter() - t0
    return res, cold, warm


def run(quick: bool = False) -> list[dict]:
    slots = QUICK_SLOTS if quick else FULL_SLOTS
    sweep = SWEEP if quick else SWEEP + FULL_EXTRA
    rows: list[dict] = []

    parity = True
    walls: dict[int, dict[str, float]] = {}
    kernel_res: dict[int, slotted_sim.SimResult] = {}
    for k in sweep:
        rp, cold_p, warm_p = _timed(_cfg(k, slots, "pallas"))
        kernel_res[k] = rp
        walls[k] = {"pallas": warm_p, "pallas_cold": cold_p}
        if k in SWEEP:  # dense reference runs only at feasible scales
            rd, cold_d, warm_d = _timed(_cfg(k, slots, "dense"))
            walls[k]["dense"] = warm_d
            parity = parity and (
                rd.messages == rp.messages
                and rd.departures == rp.departures
                and rd.max_aq == rp.max_aq
                and rd.queue_gap_sup == rp.queue_gap_sup
                and np.array_equal(
                    rd.per_server_arrivals, rp.per_server_arrivals
                )
                and np.array_equal(rd.final_q, rp.final_q)
            )

        label = _label(k)
        aq_bound = rp.max_aq <= X - 1
        rows.append(
            common.row(
                f"route/servers{label}",
                walls[k]["pallas"],
                slots,
                common.fmt_derived(
                    msgs=rp.messages,
                    deps=rp.departures,
                    max_aq=rp.max_aq,
                    gap_sup=rp.queue_gap_sup,
                    aq_bound=aq_bound,
                ),
                msgs=rp.messages,
                deps=rp.departures,
                max_aq=rp.max_aq,
                gap_sup=rp.queue_gap_sup,
                # Theorem 2.3 at mean-field scale, gate-pinned.
                aq_bound=bool(aq_bound),
            )
        )

    rows.append(
        common.row(
            "route/parity",
            0.0,
            slots,
            common.fmt_derived(
                parity=parity, dense_cells=len(SWEEP), comm="dt"
            ),
            # The acceptance claim: kernel == dense, decision for decision.
            parity=bool(parity),
        )
    )

    # Crossover: smallest swept K where the kernel's steady-state wall
    # beats the dense backend's.  Wall clocks are machine-dependent (all
    # ``*_s`` / ``speedup`` fields, skipped by the trajectory gate); the
    # crossover point rides along as a string note.
    cross = next(
        (k for k in SWEEP if walls[k]["pallas"] < walls[k]["dense"]), None
    )
    dense_big = SWEEP[-1]
    extra = {f"dense_{_label(k)}_s": walls[k]["dense"] for k in SWEEP}
    extra.update(
        {f"pallas_{_label(k)}_s": walls[k]["pallas"] for k in sweep}
    )
    rows.append(
        common.row(
            "route/crossover",
            sum(w["pallas"] for w in walls.values()),
            slots * len(sweep),
            common.fmt_derived(
                crossover="none" if cross is None else _label(cross),
                speedup_at_1e5=walls[dense_big]["dense"]
                / max(walls[dense_big]["pallas"], 1e-9),
            ),
            crossover="none" if cross is None else _label(cross),
            speedup=walls[dense_big]["dense"]
            / max(walls[dense_big]["pallas"], 1e-9),
            **extra,
        )
    )

    # SSC at mean-field scale: the sup queue gap is O(1) in n, so the
    # sqrt(n)-scaled gap collapses monotonically through the sweep.
    scaled = {
        k: kernel_res[k].queue_gap_sup / np.sqrt(k) for k in sweep
    }
    for k in sweep:
        rows.append(
            common.row(
                f"route/ssc/n{_label(k)}",
                0.0,
                slots,
                common.fmt_derived(
                    gap_sup=kernel_res[k].queue_gap_sup,
                    gap_over_sqrt_n=float(scaled[k]),
                ),
                gap_over_sqrt_n=float(scaled[k]),
            )
        )
    collapses = all(
        scaled[b] <= scaled[a] for a, b in zip(sweep, sweep[1:])
    )
    rows.append(
        common.row(
            "route/ssc/summary",
            0.0,
            slots,
            common.fmt_derived(
                scaled_gap_first=float(scaled[sweep[0]]),
                scaled_gap_last=float(scaled[sweep[-1]]),
                collapses=collapses,
            ),
            collapses=bool(collapses),
        )
    )
    return rows
