"""Fault-injection tier: CARE under degraded networks and server faults.

Three row families measure the degraded control plane end to end:

* ``faults/delay*`` -- the **delay frontier** (slotted tier): CARE
  (JSAQ over ET-3 corrections) vs fresh-but-stale SQ(2) (per-arrival
  queries billed as 2d in-band round-trips, answers stale by the same
  delivery delay) under 1/4/8/16-slot delays.  Every knob of the ladder
  (delay, jitter, drop, thresholds) is a traced ``Scenario`` operand, so
  each policy's whole ladder shares one compiled program
  (``faults/grid_compile_count``).  The ``faults/frontier`` headline
  claims the paper's robustness story: once the network is slow enough
  that SQ(d)'s answers go stale in flight (>= 4 slots here), event-driven
  CARE corrections hold a *lower* JCT at *no more* than SQ(d)'s message
  rate -- queries pay 2d messages per arrival for state that is exactly
  as stale as the pushed corrections.

* ``faults/drop*`` -- the **loss ladder**: i.i.d. delivery-drop
  probabilities 0 -> 0.5 at a fixed 2-slot delay.  Lost corrections are
  billed on the wire (the sender cannot know) and never retransmitted;
  the rows record how gracefully JCT degrades as the update stream thins.

* ``faults/crash_recovery`` -- **graceful degradation** (numpy serving
  engine, engineered fault stream): one replica crash-stops at a known
  slot and recovers later.  Three runs replay the identical workload:
  fault-free control, crash with suspect masking
  (``suspect_age`` staleness timeout), and crash with masking disabled.
  The headline bool claims post-recovery mean JCT with masking within
  10% of the fault-free control -- the resync force-send plus suspect
  exclusion contain the damage to the outage window.  Full mode adds a
  stochastic crash/recovery ladder on the slotted tier (the heavy
  ``slow`` cells -- excluded from the ``--quick`` CI baseline).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim
from repro.serve import engine

DELAYS = (1, 4, 8, 16)
DROPS = (0.0, 0.1, 0.3, 0.5)

# Paper Section 9.1 setting; load 0.95 is where staleness hurts most.
_SLOTTED = dict(servers=30, load=0.95, mean_service=30)


def _care_cell(slots: int, **kw) -> slotted_sim.SimConfig:
    return slotted_sim.SimConfig(
        slots=slots, policy="jsaq", comm="et", x=3, network="net",
        **_SLOTTED, **kw,
    )


def _sqd_cell(slots: int, **kw) -> slotted_sim.SimConfig:
    # SQ(2) routes on per-arrival queries (2d round-trips billed in-band,
    # answers stale by the delivery delay); the balancer-side stream it
    # would otherwise listen to is throttled to a negligible RT trickle.
    return slotted_sim.SimConfig(
        slots=slots, policy="sq2", comm="rt", rt_rate=1e-4, network="net",
        **_SLOTTED, **kw,
    )


def _mean(vals) -> float:
    return float(np.mean(vals))


def _jct_msgs(per_seed, slots: int) -> tuple[float, float]:
    """(mean JCT, messages per slot) averaged across seeds."""
    jct = _mean([float(r.jct.mean()) if r.jct.size else 0.0 for r in per_seed])
    msgs = _mean([r.messages / slots for r in per_seed])
    return jct, msgs


def _crash_workload(cfg: engine.EngineConfig, slots: int, crash_at: int,
                    recover_at: int, target: int, seed: int):
    """The shared workload with an engineered single-crash fault stream.

    ``fault_u`` is forced quiet everywhere except one crash draw at
    ``crash_at`` and one recovery draw at ``recover_at`` for ``target``;
    the arrival / tie-break / subset streams are the untouched
    ``SeedSequence`` children, so the fault-free control replays the
    exact same offered load.
    """
    wl = engine.sample_workload(
        seed, replicas=cfg.num_replicas, decode_slots=cfg.decode_slots,
        slots=slots, load=0.85, mean_prefill=4, mean_decode=28,
        with_fault=True,
    )
    fu = wl.fault_u
    fu[:] = 0.9  # quiet: above both rates, no transition fires
    fu[crash_at, target] = 0.0
    fu[recover_at, target] = 0.0
    return wl


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rows: list[dict] = []

    # --- delay frontier: CARE ET-3 vs fresh-but-stale SQ(2) ------------
    progs_before = slotted_sim.grid_compile_count()
    named = [
        (f"delay{d}/{tag}", mk(slots, net_delay=d))
        for d in DELAYS
        for tag, mk in (("care_et", _care_cell), ("sqd", _sqd_cell))
    ]
    results, walls = common.timed_simulate_grid([c for _, c in named], seeds)
    frontier: dict = {}
    for (name, _), per_seed, wall in zip(named, results, walls):
        jct, msgs = _jct_msgs(per_seed, slots)
        frontier[name] = (jct, msgs)
        rows.append(
            common.row(
                f"faults/{name}",
                wall,
                slots,
                common.fmt_derived(
                    mean_jct=jct,
                    msgs_per_slot=msgs,
                    net_drops=int(np.sum([r.net_drops for r in per_seed])),
                    seeds=len(seeds),
                ),
                mean_jct=jct,
                msgs_per_slot=msgs,
            )
        )
    # Headline: at every delay >= 4, CARE holds lower JCT at no more than
    # SQ(d)'s message rate.
    slow = [d for d in DELAYS if d >= 4]
    care_wins = all(
        frontier[f"delay{d}/care_et"][0] < frontier[f"delay{d}/sqd"][0]
        and frontier[f"delay{d}/care_et"][1] <= frontier[f"delay{d}/sqd"][1]
        for d in slow
    )
    d_ref = slow[0]
    rows.append(
        common.row(
            "faults/frontier",
            0.0,
            slots,
            common.fmt_derived(
                care_beats_stale_sqd=care_wins,
                jct_ratio_d4=frontier[f"delay{d_ref}/care_et"][0]
                / max(frontier[f"delay{d_ref}/sqd"][0], 1e-9),
                msg_ratio_d4=frontier[f"delay{d_ref}/care_et"][1]
                / max(frontier[f"delay{d_ref}/sqd"][1], 1e-9),
                delays_checked=len(slow),
            ),
            care_beats_stale_sqd=care_wins,
        )
    )

    # --- drop ladder at a fixed 2-slot delay ---------------------------
    # Same static group as the CARE frontier cells: only traced operands
    # (delay, drop) differ, so the ladder reuses the compiled program.
    drop_named = [
        (f"drop{p}", _care_cell(slots, net_delay=2, net_drop=p))
        for p in DROPS
    ]
    d_results, d_walls = common.timed_simulate_grid(
        [c for _, c in drop_named], seeds
    )
    for (name, _), per_seed, wall in zip(drop_named, d_results, d_walls):
        jct, msgs = _jct_msgs(per_seed, slots)
        rows.append(
            common.row(
                f"faults/{name}",
                wall,
                slots,
                common.fmt_derived(
                    mean_jct=jct,
                    msgs_per_slot=msgs,
                    net_drops=int(np.sum([r.net_drops for r in per_seed])),
                    seeds=len(seeds),
                ),
                mean_jct=jct,
            )
        )
    programs = slotted_sim.grid_compile_count() - progs_before
    rows.append(
        common.row(
            "faults/grid_compile_count",
            0.0,
            slots,
            common.fmt_derived(
                programs=programs,
                cells=len(named) + len(drop_named),
                # One program per (policy, comm) static group: CARE
                # (shared by frontier + drop ladder) and SQ(2).
                fused=programs <= 2,
            ),
            programs=programs,
            fused=programs <= 2,
        )
    )

    # --- crash/recovery: engineered outage on the serving engine -------
    c_slots = 2_500 if quick else 4_000
    crash_at, recover_at = c_slots // 4, c_slots // 2
    # Post-recovery window: start a quarter-horizon past the recovery so
    # the outage backlog (the crashed replica's frozen queue plus what the
    # survivors absorbed) has drained and the tail measures the restored
    # steady state, not the catch-up transient.
    window = recover_at + c_slots // 4
    # msr_drain = decode_slots / mean_work = 8/32: the MSR emulation must
    # match the nominal per-replica completion rate (see bench_serving).
    # comm="et_rt": the suspect timeout only works on top of the RT
    # keepalive -- a healthy replica is guaranteed a message every
    # rt_period slots, so age > suspect_age (> rt_period) singles out the
    # crashed one instead of whoever ET happened to keep quiet.
    base = dict(num_replicas=8, decode_slots=8, comm="et_rt", et_x=3,
                rt_period=8, mean_prefill=4.0, mean_decode=28.0,
                msr_drain=0.25)
    variants = (
        ("fault_free", dict(fault="none")),
        ("suspect_on", dict(fault="crash", crash_rate=0.5, recover_rate=0.5,
                            suspect_age=16)),
        ("suspect_off", dict(fault="crash", crash_rate=0.5, recover_rate=0.5)),
    )
    tail_jct: dict = {}
    for name, kw in variants:
        cfg = engine.EngineConfig(**base, **kw)
        wl = _crash_workload(cfg, c_slots, crash_at, recover_at,
                             target=3, seed=0)
        t0 = time.perf_counter()
        out = engine.run_serving_sim(
            cfg, slots=c_slots, load=0.85, mean_prefill=4, mean_decode=28,
            seed=0, workload=wl,
        )
        wall = time.perf_counter() - t0
        jbr, arr = out["jct_by_rid"], wl.arrival_slot
        in_tail = (arr >= window) & (jbr >= 0)
        tail = float(jbr[in_tail].mean()) if in_tail.any() else 0.0
        tail_jct[name] = tail
        rows.append(
            common.row(
                f"faults/crash/{name}",
                wall,
                c_slots,
                common.fmt_derived(
                    tail_mean_jct=tail,
                    mean_jct=out["mean_jct"],
                    completed=out["completed"],
                    messages=out["messages"],
                ),
                tail_mean_jct=tail,
            )
        )
    ratio = tail_jct["suspect_on"] / max(tail_jct["fault_free"], 1e-9)
    rows.append(
        common.row(
            "faults/crash_recovery",
            0.0,
            c_slots,
            common.fmt_derived(
                recovered_within_10pct=ratio <= 1.1,
                tail_jct_ratio=ratio,
                unmasked_ratio=tail_jct["suspect_off"]
                / max(tail_jct["fault_free"], 1e-9),
            ),
            recovered_within_10pct=ratio <= 1.1,
        )
    )

    # --- stochastic crash ladder (full mode only: the heavy cells) -----
    # The pytest twin of these cells is marked ``slow``; here the gate is
    # ``--quick``, so the CI baseline never records them and full runs
    # may take the wall hit.
    if not quick:
        ladder = [
            (f"crash_rate{cr}", slotted_sim.SimConfig(
                slots=slots, policy="jsaq", comm="et", x=3, fault="crash",
                crash_rate=cr, recover_rate=0.01, suspect_age=32,
                **_SLOTTED,
            ))
            for cr in (1e-5, 1e-4, 5e-4)
        ]
        l_results, l_walls = common.timed_simulate_grid(
            [c for _, c in ladder], seeds
        )
        for (name, _), per_seed, wall in zip(ladder, l_results, l_walls):
            jct, msgs = _jct_msgs(per_seed, slots)
            rows.append(
                common.row(
                    f"faults/{name}",
                    wall,
                    slots,
                    common.fmt_derived(
                        mean_jct=jct,
                        msgs_per_slot=msgs,
                        seeds=len(seeds),
                    ),
                    mean_jct=jct,
                )
            )
    return rows
