"""Pull-policy tier: JIQ / hyper-scalable JSQ vs CARE push on one frontier.

The pull (server-initiated) policies route on *tokens* servers push at
their own initiative -- JIQ on the idle transition, the hyper-scalable
threshold policy ("hsq", van der Boor et al. 2019) on a downward crossing
of ``x`` plus a traced-rate keepalive.  Token traffic rides the same
trigger/message accounting (and, degraded, the same ``comm.net_step``
delay/jitter/drop wire) as CARE's push corrections, so every row below
sits on one honest message-rate vs JCT frontier:

* ``pull/slotted/*`` -- the **clean frontier** (slotted tier, load 0.9):
  CARE ET-3 / DT-3 / RT, query-based SQ(2) (billed 2d round-trips per
  arrival), JIQ and hsq, all replaying the identical arrival stream.
  ``rel_comm`` is messages per job relative to the exact-state baseline.

* ``pull/slotted_net/*`` -- the **degraded frontier**: the same policies
  under a 2-slot delay, 1-slot jitter and 10% drop.  Tokens are lost and
  delayed like any other message; a stale JIQ token of a busy server is
  simply spent and never refreshed (the safe-staleness property -- no
  retransmission exists).

* ``pull/serve*/*`` -- the serving tier (request dispatch over replica
  groups), clean and degraded, via the fused ``serve_grid`` programs;
  ``pull/parity`` asserts the jitted runs replay the numpy
  ``CareDispatcher`` bit for bit *including the token counters*.

* ``pull/frontier`` -- the headline bools: JIQ spends **<= 1 message per
  job** on both tiers (its defining bound -- CARE RT/DT sit well below,
  SQ(2) at 4), and hsq holds the CARE ET-3 mean-JCT envelope (<= 1.10x)
  at load 0.9 while staying inside the same pull budget.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.care import metrics, slotted_sim
from repro.serve import engine

# Slotted-tier frontier policies (paper Section 9.1 fleet, load 0.9 --
# high enough that tokens are scarce and the fallback paths are exercised,
# low enough that jiq's idle transitions still happen).
_SLOTTED_LOAD = 0.9
_HSQ_RATE = 0.02  # hsq token-refresh (keepalive) rate, msgs/slot/server

_CLEAN = [
    ("care_et3", dict(policy="jsaq", comm="et", x=3, approx="msr")),
    ("care_dt3", dict(policy="jsaq", comm="dt", x=3, approx="msr")),
    ("care_rt", dict(policy="jsaq", comm="rt", rt_rate=_HSQ_RATE,
                     approx="msr")),
    ("sq2", dict(policy="sq2", comm="none")),
    ("jiq", dict(policy="jiq", comm="jiq")),
    ("hsq", dict(policy="hsq", comm="hsq", x=3, rt_rate=_HSQ_RATE)),
]

# Degraded control plane: tokens and corrections share the same wire.
_NET = dict(network="net", net_delay=2, net_jitter=1, net_drop=0.1)
_DEGRADED = [
    ("care_et3", dict(policy="jsaq", comm="et", x=3, approx="msr", **_NET)),
    # SQ(2) under a network routes on query round-trips billed in-band
    # (the balancer push stream is throttled to a negligible trickle).
    ("sq2", dict(policy="sq2", comm="rt", rt_rate=1e-4, **_NET)),
    ("jiq", dict(policy="jiq", comm="jiq", **_NET)),
    ("hsq", dict(policy="hsq", comm="hsq", x=3, rt_rate=_HSQ_RATE, **_NET)),
]

# Serving tier: the bench_serving work profile at its load-0.9 corner.
_WORK = dict(mean_prefill=4, mean_decode=60, msr_drain=0.25)
_SERVE_NET = dict(network="net", net_delay=2, net_drop=0.1, suspect_age=8)


def _serve_cells(slots: int, degraded: bool) -> list[tuple[str, engine.ServeConfig]]:
    extra = _SERVE_NET if degraded else {}
    # Degraded CARE runs ET-3 over the hybrid et_rt trigger: the suspect
    # timeout only works on top of a keepalive (see bench_faults).
    care_comm = dict(comm="et_rt", rt_period=32) if degraded else dict(
        comm="et"
    )

    def cell(**kw):
        return engine.ServeConfig(slots=slots, load=0.9, **_WORK, **extra,
                                  **kw)

    return [
        ("care_et3", cell(x=3, **care_comm)),
        ("sqd", cell(policy="sqd", sqd=2, comm="et", x=3)),
        ("jiq", cell(policy="jiq", comm="jiq")),
        # hsq's threshold keys on replica occupancy: x = decode_slots, so
        # a token advertises a free decode slot (x=3 would never fire --
        # a busy replica's occupancy never drops that low at load 0.9).
        ("hsq", cell(policy="hsq", comm="hsq", x=16, rt_period=32)),
    ]


def _mean(vals) -> float:
    return float(np.mean(vals))


def _slotted_rows(tier: str, named, seeds, slots: int, rows: list[dict]):
    """Run one slotted frontier and append its rows; returns the summary."""
    cfgs = [slotted_sim.SimConfig(slots=slots, load=_SLOTTED_LOAD, **kw)
            for _, kw in named]
    results, walls = common.timed_simulate_grid(cfgs, seeds)
    summary: dict = {}
    for (name, kw), cfg, per_seed, wall in zip(named, cfgs, results, walls):
        jct = _mean([metrics.mean_jct(r.jct) for r in per_seed])
        rel = _mean([
            metrics.relative_communication(r, kw["policy"])
            if cfg.network == "none" else r.msgs_per_departure
            for r in per_seed
        ])
        tok = metrics.token_summary(
            int(np.sum([r.token_sum for r in per_seed])),
            int(np.sum([r.token_misses for r in per_seed])),
            slots * len(seeds),
            int(np.sum([r.arrivals for r in per_seed]))
            if kw["policy"] in ("jiq", "hsq") else 0,
        )
        summary[name] = (jct, rel, tok)
        rows.append(
            common.row(
                f"{tier}/{name}",
                wall,
                slots,
                common.fmt_derived(
                    mean_jct=jct,
                    rel_comm=rel,
                    token_miss_rate=tok["miss_rate"],
                    seeds=len(seeds),
                ),
                mean_jct=jct,
                rel_comm=rel,
            )
        )
    return summary


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rows: list[dict] = []

    # --- slotted tier: clean + degraded frontiers ----------------------
    clean = _slotted_rows("pull/slotted", _CLEAN, seeds, slots, rows)
    _slotted_rows("pull/slotted_net", _DEGRADED, seeds, slots, rows)

    # --- serving tier: clean + degraded, fused grids -------------------
    s_slots = 2_000 if quick else 4_000
    serve_summary: dict = {}
    for tier, degraded in (("pull/serve", False), ("pull/serve_net", True)):
        named = _serve_cells(s_slots, degraded)
        results, walls = common.timed_serve_grid(
            [c for _, c in named], seeds
        )
        for (name, _), per_seed, wall in zip(named, results, walls):
            jct = _mean([r.mean_jct for r in per_seed])
            mpc = _mean([r.msgs_per_completion for r in per_seed])
            misses = int(np.sum([r.token_misses for r in per_seed]))
            serve_summary[(tier, name)] = (jct, mpc)
            rows.append(
                common.row(
                    f"{tier}/{name}",
                    wall,
                    s_slots,
                    common.fmt_derived(
                        mean_jct=jct,
                        msgs_per_completion=mpc,
                        token_misses=misses,
                        seeds=len(seeds),
                    ),
                    mean_jct=jct,
                    msgs_per_completion=mpc,
                )
            )

    # --- jax <-> numpy parity on every pull cell (token counters too) --
    parity = True
    for degraded in (False, True):
        for name, cell in _serve_cells(s_slots, degraded):
            if cell.policy not in ("jiq", "hsq"):
                continue
            res = common.timed_serve_grid([cell], seeds[:1])[0][0][0]
            ref = common.serve_reference(cell, seeds[0])
            parity &= common.serve_matches_reference(res, ref)
            parity &= res.token_misses == ref["token_misses"]
            parity &= res.token_sum == ref["token_sum"]
    rows.append(
        common.row(
            "pull/parity",
            0.0,
            s_slots,
            common.fmt_derived(pull_backends_bitwise=parity, cells=4),
            pull_backends_bitwise=parity,
        )
    )

    # --- headline: the pull bounds on one frontier ---------------------
    jiq_budget = (
        clean["jiq"][1] <= 1.0
        and serve_summary[("pull/serve", "jiq")][1] <= 1.0
    )
    hsq_ratio = clean["hsq"][0] / max(clean["care_et3"][0], 1e-9)
    hsq_envelope = hsq_ratio <= 1.10 and clean["hsq"][1] <= 1.0
    rows.append(
        common.row(
            "pull/frontier",
            0.0,
            slots,
            common.fmt_derived(
                jiq_at_most_one_msg_per_job=jiq_budget,
                hsq_within_et3_envelope=hsq_envelope,
                hsq_jct_ratio=hsq_ratio,
                jiq_rel_comm=clean["jiq"][1],
                sq2_rel_comm=clean["sq2"][1],
            ),
            jiq_at_most_one_msg_per_job=jiq_budget,
            hsq_within_et3_envelope=hsq_envelope,
        )
    )
    return rows
