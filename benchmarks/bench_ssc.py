"""Section 7 State Space Collapse, as a finite-n trend (Theorem 7.3).

The diffusion scaling has event rates Theta(n) with a fixed horizon; in
slot units we realise n by scaling the mean service time and the horizon
together (each "diffusion time unit" spans n x more slots while per-unit
rates stay Theta(n)).  Queue lengths then live on the sqrt(n) scale, so SSC
predicts sup_t max_ij |Q_i - Q_j| / sqrt(n) -> 0 whenever the approximation
error is o(sqrt(n)) -- which ET-x with *fixed* x satisfies trivially.

Reported: the scaled queue gap for n in {1, 2, 4, 8} under JSAQ + ET-2 +
MSR, and under round-robin as a non-collapsing contrast.

The sweep goes through ``common.timed_simulate_grid`` like every other
figure.  Here ``n`` scales ``slots`` and ``mean_service`` -- *shape* and
emulation-constant structure, which stay compile-time by design -- so each
(policy, n) cell is its own static group; the fused path still serves the
shared cell cache and the uniform grid interface.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim

NS = (1, 2, 4, 8)
BASE_SLOTS = 20_000
BASE_SERVICE = 10
SERVERS = 10


def run(quick: bool = False) -> list[dict]:
    ns = (1, 4) if quick else NS
    combos = [("jsaq", "et", "msr"), ("rr", "none", "msr")]
    cells = [
        (
            policy,
            n,
            slotted_sim.SimConfig(
                servers=SERVERS,
                slots=BASE_SLOTS * n,
                load=0.95,
                mean_service=BASE_SERVICE * n,
                policy=policy,
                comm=comm,
                x=2,
                approx=approx,
            ),
        )
        for policy, comm, approx in combos
        for n in ns
    ]
    results, walls = common.timed_simulate_grid(
        [cfg for _, _, cfg in cells], (0,)
    )

    rows = []
    trend: dict = {}
    for (policy, n, cfg), res_list, wall in zip(cells, results, walls):
        res = res_list[0]
        scaled = res.queue_gap_sup / np.sqrt(n)
        trend.setdefault(policy, []).append(scaled)
        rows.append(
            common.row(
                f"ssc/{policy}/n{n}",
                wall,
                cfg.slots,
                common.fmt_derived(
                    gap_sup=res.queue_gap_sup,
                    gap_over_sqrt_n=float(scaled),
                    max_aq=res.max_aq,
                ),
                gap_over_sqrt_n=float(scaled),
            )
        )
    collapsing = trend["jsaq"][-1] <= trend["jsaq"][0] * 1.5
    rows.append(
        common.row(
            "ssc/summary",
            0.0,
            BASE_SLOTS,
            common.fmt_derived(
                jsaq_scaled_gap_first=float(trend["jsaq"][0]),
                jsaq_scaled_gap_last=float(trend["jsaq"][-1]),
                rr_scaled_gap_last=float(trend["rr"][-1]),
                jsaq_collapses=bool(collapsing),
            ),
            # Top-level so the trajectory diff gates on the SSC claim.
            jsaq_collapses=bool(collapsing),
        )
    )
    return rows
