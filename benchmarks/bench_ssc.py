"""Section 7 State Space Collapse, as a finite-n trend (Theorem 7.3).

The diffusion scaling has event rates Theta(n) with a fixed horizon; in
slot units we realise n by scaling the mean service time and the horizon
together (each "diffusion time unit" spans n x more slots while per-unit
rates stay Theta(n)).  Queue lengths then live on the sqrt(n) scale, so SSC
predicts sup_t max_ij |Q_i - Q_j| / sqrt(n) -> 0 whenever the approximation
error is o(sqrt(n)) -- which ET-x with *fixed* x satisfies trivially.

Reported: the scaled queue gap for n in {1, 2, 4, 8} under JSAQ + ET-2 +
MSR, and under round-robin as a non-collapsing contrast.

Since the service axis became traced (``mean_service`` is a
``ServiceProcess`` operand and the horizon is the traced
``Scenario.horizon`` over a padded fixed-length scan), the whole diffusion
grid fuses: every cell of a policy shares one ``StaticConfig``
(``slots = max_n * base``), so the figure compiles **one program per
policy combo** -- O(#policies), not O(policies x n) as it did when ``n``
scaled compile-time structure.  The ``ssc/grid_compile_count`` row records
the program count; ``ssc/grid_speedup`` times the fused grid against the
pre-refactor cost model (one fresh compiled program per (policy, n) cell
at its own *unpadded* horizon), while the bitwise golden check
(``grid_matches_percell``) uses a per-cell reference over the shared
padded static, the only shape whose workload stream coincides with the
fused grid's.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks import common
from repro.core.care import slotted_sim

NS = (1, 2, 4, 8)
BASE_SLOTS = 20_000
BASE_SERVICE = 10
SERVERS = 10
SEEDS = (0,)


def run(quick: bool = False) -> list[dict]:
    ns = (1, 4) if quick else NS
    max_slots = BASE_SLOTS * max(ns)
    combos = [("jsaq", "et", "msr"), ("rr", "none", "msr")]
    cells = [
        (
            policy,
            n,
            slotted_sim.SimConfig(
                servers=SERVERS,
                slots=BASE_SLOTS * n,
                max_slots=max_slots,
                load=0.95,
                mean_service=BASE_SERVICE * n,
                policy=policy,
                comm=comm,
                x=2,
                approx=approx,
            ),
        )
        for policy, comm, approx in combos
        for n in ns
    ]
    cfgs = [cfg for _, _, cfg in cells]

    compiles_before = slotted_sim.grid_compile_count()
    t0 = time.perf_counter()
    results, walls = common.timed_simulate_grid(cfgs, SEEDS)
    t_grid = time.perf_counter() - t0
    n_programs = slotted_sim.grid_compile_count() - compiles_before

    # Golden reference: one fresh compiled program per cell *over the same
    # padded static* -- the workload stream is keyed to the scan shape, so
    # only this path is bit-comparable to the fused grid.
    percell = common.percell_reference(cfgs, SEEDS)
    match = common.grids_match(results, percell)

    # Timing reference: the true pre-refactor cost model -- one program per
    # (policy, n) cell, each compiled at its own *unpadded* horizon (the
    # padded percell path above would inflate the baseline by scanning
    # every cell at max_slots).  Results are discarded: a different scan
    # shape draws a different stream, so only the wall clock is meaningful.
    unpadded = [dataclasses.replace(cfg, max_slots=None) for cfg in cfgs]
    t0 = time.perf_counter()
    common.percell_reference(unpadded, SEEDS)
    t_percell = time.perf_counter() - t0

    rows = []
    trend: dict = {}
    for (policy, n, cfg), res_list, wall in zip(cells, results, walls):
        res = res_list[0]
        scaled = res.queue_gap_sup / np.sqrt(n)
        trend.setdefault(policy, []).append(scaled)
        rows.append(
            common.row(
                f"ssc/{policy}/n{n}",
                wall,
                cfg.slots,
                common.fmt_derived(
                    gap_sup=res.queue_gap_sup,
                    gap_over_sqrt_n=float(scaled),
                    max_aq=res.max_aq,
                ),
                gap_over_sqrt_n=float(scaled),
            )
        )
    collapsing = trend["jsaq"][-1] <= trend["jsaq"][0] * 1.5
    rows.append(
        common.row(
            "ssc/summary",
            0.0,
            BASE_SLOTS,
            common.fmt_derived(
                jsaq_scaled_gap_first=float(trend["jsaq"][0]),
                jsaq_scaled_gap_last=float(trend["jsaq"][-1]),
                rr_scaled_gap_last=float(trend["rr"][-1]),
                jsaq_collapses=bool(collapsing),
            ),
            # Top-level so the trajectory diff gates on the SSC claim.
            jsaq_collapses=bool(collapsing),
        )
    )
    fused = n_programs <= len(combos)
    rows.append(
        common.row(
            "ssc/grid_compile_count",
            0.0,
            max_slots,
            common.fmt_derived(
                programs=n_programs, cells=len(cfgs), combos=len(combos)
            ),
            programs=n_programs,
            cells=len(cfgs),
            # The acceptance claim: the whole diffusion grid fuses into at
            # most one program per policy combo (trajectory-diff gated).
            fused_per_policy=bool(fused),
        )
    )
    rows.append(
        common.row(
            "ssc/grid_speedup",
            t_grid,
            max_slots * len(cfgs) * len(SEEDS),
            common.fmt_derived(
                t_grid_s=t_grid,
                t_prerefactor_s=t_percell,
                speedup=t_percell / max(t_grid, 1e-9),
                grid_matches_percell=match,
            ),
            speedup=t_percell / max(t_grid, 1e-9),
            grid_matches_percell=bool(match),
        )
    )
    return rows
