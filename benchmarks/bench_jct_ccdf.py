"""Paper Figures 3 and 8-12: job completion times under communication budgets.

For each load in {0.5, 0.8, 0.95} this compares the JCT distribution of the
exact-state baselines (JSQ, SQ(2), Round Robin) against CARE combinations:

* JSAQ + ET-x + MSR    for x in {2, 3, 5, 7}   (the sparse-comm champion);
* JSAQ + DT-x + MSR-x  for x in {2, 3, 5}      (the high-comm regime winner);

reporting mean / p50 / p99 / p99.9 JCT, the measured relative communication,
and the headline checks from the paper:

* ET-3 + MSR rivals SQ(2) (mean JCT within ~10%) using ~10% of JSQ's
  messages (Fig 3 / Fig 10);
* ET-x + MSR still beats Round Robin at < 2% relative communication
  (Fig 10 / Fig 12).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.care import metrics, slotted_sim


def _cfg(slots, load, **kw):
    return slotted_sim.SimConfig(
        servers=common.SERVERS, slots=slots, load=load, **kw
    )


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    et_xs = (3, 7) if quick else (2, 3, 5, 7)
    dt_xs = (3,) if quick else (2, 3, 5)
    rows: list[dict] = []
    for load in common.LOADS:
        variants: list[tuple[str, slotted_sim.SimConfig]] = [
            ("jsq", _cfg(slots, load, policy="jsq", comm="none")),
            ("sq2", _cfg(slots, load, policy="sq2", comm="none")),
            ("rr", _cfg(slots, load, policy="rr", comm="none")),
        ]
        for x in et_xs:
            variants.append(
                (f"et{x}_msr",
                 _cfg(slots, load, policy="jsaq", comm="et", x=x, approx="msr"))
            )
        for x in dt_xs:
            variants.append(
                (f"dt{x}_msrx",
                 _cfg(slots, load, policy="jsaq", comm="dt", x=x, approx="msr_x"))
            )

        results = {}
        for name, cfg in variants:
            res, wall = common.timed_simulate(0, cfg)
            results[name] = res
            summ = metrics.jct_summary(res.jct)
            rel = metrics.relative_communication(res, cfg.policy, cfg.sqd)
            rows.append(
                common.row(
                    f"jct/load{load}/{name}",
                    wall,
                    slots,
                    common.fmt_derived(
                        mean_jct=summ["mean"],
                        p99=summ["p99"],
                        rel_comm=rel,
                    ),
                    mean_jct=summ["mean"],
                    p50=summ["p50"],
                    p99=summ["p99"],
                    p999=summ["p999"],
                    rel_comm=rel,
                )
            )

        # Headline checks (paper Figs 3 / 10 / 12), evaluated at this load.
        if "et3_msr" in results:
            m_et3 = float(np.mean(results["et3_msr"].jct))
            m_sq2 = float(np.mean(results["sq2"].jct))
            m_rr = float(np.mean(results["rr"].jct))
            rel3 = results["et3_msr"].msgs_per_departure
            sparse_name = f"et{max(et_xs)}_msr"
            m_sparse = float(np.mean(results[sparse_name].jct))
            rel_sparse = results[sparse_name].msgs_per_departure
            rows.append(
                common.row(
                    f"jct/load{load}/headline",
                    0.0,
                    slots,
                    common.fmt_derived(
                        et3_vs_sq2=m_et3 / m_sq2,
                        et3_rel_comm=rel3,
                        sparse_vs_rr=m_sparse / m_rr,
                        sparse_rel_comm=rel_sparse,
                        et3_rivals_sq2=bool(m_et3 <= m_sq2 * 1.15),
                        sparse_beats_rr=bool(
                            (m_sparse < m_rr) or load < 0.75
                        ),
                    ),
                )
            )
    return rows
