"""Paper Figures 3 and 8-12: job completion times under communication budgets.

For each load in {0.5, 0.8, 0.95} this compares the JCT distribution of the
exact-state baselines (JSQ, SQ(2), Round Robin) against CARE combinations:

* JSAQ + ET-x + MSR    for x in {2, 3, 5, 7}   (the sparse-comm champion);
* JSAQ + DT-x + MSR-x  for x in {2, 3, 5}      (the high-comm regime winner);

reporting mean / p50 / p99 / p99.9 JCT (pooled over a seed sweep), the
measured relative communication, and the headline checks from the paper:

* ET-3 + MSR rivals SQ(2) (mean JCT within ~10%) using ~10% of JSQ's
  messages (Fig 3 / Fig 10);
* ET-x + MSR still beats Round Robin at < 2% relative communication
  (Fig 10 / Fig 12).

The full figure -- every load, every variant, plus the two scenario rows
below -- is submitted as **one grid** (``common.timed_simulate_grid``):
load and x are traced ``Scenario`` operands, so all cells sharing a
(policy, comm, approx, arrival) kind combination share one compiled
program, vmapped over the flattened cell x seed axis and sharded across
devices with ``shard_map``.  Compiles per figure: one per kind
combination (~6), not one per cell (~34).

Beyond the paper, two scenario rows exercise the workload layer end to end
at load 0.95: ``bursty`` (MMPP-modulated arrivals, burst_intensity 1.7) and
``hetero`` (half the servers at rate 1.5x, half at 0.5x, with drain-time
aware JSAQ) -- both still satisfy the ET error bound.

In quick mode the module also measures the ``simulate_batch`` speedup: 8
seeds in one batched (and shard_map-sharded) scan vs 8 sequential
``simulate`` calls (row ``jct/batch_speedup``; both paths pre-warmed so
jit compilation is excluded, best-of-3 each).  The speedup scales with the
device count the harness exposes (``benchmarks/run.py`` forces one XLA CPU
device per core): the scan body fuses into a compute-bound loop, so on CPU
the win comes from device-level parallelism, not from vmap alone.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core.care import metrics, slotted_sim

SEEDS = (0, 1, 2, 3)
SPEEDUP_SEEDS = tuple(range(100, 108))


def _cfg(slots, load, **kw):
    return slotted_sim.SimConfig(
        servers=common.SERVERS, slots=slots, load=load, **kw
    )


def _pooled(results):
    """Pool JCT samples and average scalar metrics over a seed sweep."""
    jct = np.concatenate([r.jct for r in results]) if results else np.array([])
    return jct


def _mean_rel(results, policy, sqd):
    return float(
        np.mean([metrics.relative_communication(r, policy, sqd) for r in results])
    )


def _batch_speedup_row(slots: int) -> dict:
    """8 sequential simulate() calls vs one simulate_batch() over 8 seeds."""
    cfg = _cfg(slots, 0.95, policy="jsaq", comm="et", x=3, approx="msr")
    # Warm both jit caches (same batch width!) so the timing excludes
    # compilation, then take the best of a few repetitions of each path.
    slotted_sim.simulate(jax.random.key(999), cfg)
    slotted_sim.simulate_batch([900 + s for s in range(len(SPEEDUP_SEEDS))], cfg)

    t_seq = float("inf")
    t_batch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        seq = [
            slotted_sim.simulate(jax.random.key(s), cfg) for s in SPEEDUP_SEEDS
        ]
        t_seq = min(t_seq, time.perf_counter() - t0)

        t0 = time.perf_counter()
        batch = slotted_sim.simulate_batch(list(SPEEDUP_SEEDS), cfg)
        t_batch = min(t_batch, time.perf_counter() - t0)

    # vmap is semantics-preserving: the batch must reproduce the sequential
    # runs exactly, otherwise the speedup row is meaningless.
    agree = all(
        s.messages == b.messages and s.max_aq == b.max_aq
        for s, b in zip(seq, batch)
    )
    return common.row(
        "jct/batch_speedup",
        t_batch,
        slots * len(SPEEDUP_SEEDS),
        common.fmt_derived(
            seeds=len(SPEEDUP_SEEDS),
            devices=jax.local_device_count(),
            t_seq_s=t_seq,
            t_batch_s=t_batch,
            speedup=t_seq / max(t_batch, 1e-9),
            batch_matches_sequential=agree,
        ),
        speedup=t_seq / max(t_batch, 1e-9),
        # Top-level so the trajectory diff gates on it (derived is skipped).
        batch_matches_sequential=bool(agree),
    )


def _scenario_variants(slots):
    hetero_rates = tuple(1.5 if i < common.SERVERS // 2 else 0.5
                         for i in range(common.SERVERS))
    return [
        ("bursty/et3_msr",
         _cfg(slots, 0.95, policy="jsaq", comm="et", x=3, approx="msr",
              arrival="mmpp", burst_intensity=1.7)),
        ("bursty/sq2",
         _cfg(slots, 0.95, policy="sq2", comm="none",
              arrival="mmpp", burst_intensity=1.7)),
        ("hetero/et3_msr",
         _cfg(slots, 0.95, policy="jsaq", comm="et", x=3, approx="msr",
              service_rates=hetero_rates)),
        ("hetero/sq2",
         _cfg(slots, 0.95, policy="sq2", comm="none",
              service_rates=hetero_rates)),
    ]


def run(quick: bool = False) -> list[dict]:
    slots = common.sim_slots(quick)
    et_xs = (3, 7) if quick else (2, 3, 5, 7)
    dt_xs = (3,) if quick else (2, 3, 5)

    # Build the complete figure grid up front: every (load, variant) cell
    # plus the scenario rows, submitted as one fused sweep.
    per_load: list[tuple[float, str, slotted_sim.SimConfig]] = []
    for load in common.LOADS:
        variants: list[tuple[str, slotted_sim.SimConfig]] = [
            ("jsq", _cfg(slots, load, policy="jsq", comm="none")),
            ("sq2", _cfg(slots, load, policy="sq2", comm="none")),
            ("rr", _cfg(slots, load, policy="rr", comm="none")),
        ]
        for x in et_xs:
            variants.append(
                (f"et{x}_msr",
                 _cfg(slots, load, policy="jsaq", comm="et", x=x, approx="msr"))
            )
        for x in dt_xs:
            variants.append(
                (f"dt{x}_msrx",
                 _cfg(slots, load, policy="jsaq", comm="dt", x=x, approx="msr_x"))
            )
        per_load.extend((load, name, cfg) for name, cfg in variants)
    scenario_cells = _scenario_variants(slots)

    all_cfgs = [cfg for _, _, cfg in per_load]
    all_cfgs += [cfg for _, cfg in scenario_cells]
    all_results, all_walls = common.timed_simulate_grid(all_cfgs, SEEDS)
    res_iter = iter(zip(all_results, all_walls))

    rows: list[dict] = []
    by_load: dict = {}
    for load, name, cfg in per_load:
        res, wall = next(res_iter)
        by_load.setdefault(load, {})[name] = res
        jct = _pooled(res)
        summ = metrics.jct_summary(jct)
        rel = _mean_rel(res, cfg.policy, cfg.sqd)
        rows.append(
            common.row(
                f"jct/load{load}/{name}",
                wall,
                slots * len(SEEDS),
                common.fmt_derived(
                    mean_jct=summ["mean"],
                    p99=summ["p99"],
                    rel_comm=rel,
                    seeds=len(SEEDS),
                ),
                mean_jct=summ["mean"],
                p50=summ["p50"],
                p99=summ["p99"],
                p999=summ["p999"],
                rel_comm=rel,
            )
        )

    # Headline checks (paper Figs 3 / 10 / 12), evaluated per load.
    for load, results in by_load.items():
        if "et3_msr" not in results:
            continue
        # metrics.mean_jct is zero-completion safe (no NaN rows on short
        # quick horizons); the ratio denominators are floored likewise.
        m_et3 = metrics.mean_jct(_pooled(results["et3_msr"]))
        m_sq2 = max(metrics.mean_jct(_pooled(results["sq2"])), 1e-9)
        m_rr = max(metrics.mean_jct(_pooled(results["rr"])), 1e-9)
        rel3 = float(np.mean(
            [r.msgs_per_departure for r in results["et3_msr"]]
        ))
        sparse_name = f"et{max(et_xs)}_msr"
        m_sparse = metrics.mean_jct(_pooled(results[sparse_name]))
        rel_sparse = float(np.mean(
            [r.msgs_per_departure for r in results[sparse_name]]
        ))
        rows.append(
            common.row(
                f"jct/load{load}/headline",
                0.0,
                slots,
                common.fmt_derived(
                    et3_vs_sq2=m_et3 / m_sq2,
                    et3_rel_comm=rel3,
                    sparse_vs_rr=m_sparse / m_rr,
                    sparse_rel_comm=rel_sparse,
                    et3_rivals_sq2=bool(m_et3 <= m_sq2 * 1.15),
                    sparse_beats_rr=bool(
                        (m_sparse < m_rr) or load < 0.75
                    ),
                ),
                # Paper headline claims as top-level flags: flipping one
                # must fail the CI trajectory diff, not just reword a
                # derived string it skips.
                et3_rivals_sq2=bool(m_et3 <= m_sq2 * 1.15),
                sparse_beats_rr=bool((m_sparse < m_rr) or load < 0.75),
            )
        )

    # Scenario layer: bursty arrivals and heterogeneous service rates,
    # part of the same fused grid (their kinds are their own programs).
    for name, cfg in scenario_cells:
        res, wall = next(res_iter)
        jct = _pooled(res)
        summ = metrics.jct_summary(jct)
        rel = _mean_rel(res, cfg.policy, cfg.sqd)
        max_aq = max(r.max_aq for r in res)
        rows.append(
            common.row(
                f"jct/scenario/{name}",
                wall,
                slots * len(SEEDS),
                common.fmt_derived(
                    mean_jct=summ["mean"],
                    p99=summ["p99"],
                    rel_comm=rel,
                    max_aq=max_aq,
                    aq_ok=bool(cfg.comm != "et" or max_aq <= cfg.x - 1),
                ),
                mean_jct=summ["mean"],
                p99=summ["p99"],
                rel_comm=rel,
                aq_ok=bool(cfg.comm != "et" or max_aq <= cfg.x - 1),
            )
        )

    if quick:
        rows.append(_batch_speedup_row(slots))
    return rows
