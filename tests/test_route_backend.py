"""Pallas ``route_backend`` equivalence: fused kernels vs dense reference.

The fused mean-field kernel (``kernels/jsaq_route.care_route_pallas``) and
the serving-tier lane kernel (``serve_route_pallas``) must be *decision
identical* to the dense traced backends under deterministic (lowest-index)
ties -- not statistically close: the same arrival stream must produce the
same routed server, the same trigger firings and the same counters, bit for
bit, in interpret mode on CPU and therefore structurally on TPU.

Three layers:

* **Slotted parity matrix** -- ``simulate``/``simulate_grid`` with
  ``route_backend="pallas"`` vs ``"dense"`` across the (policy x comm)
  golden matrix at small K, comparing every integer counter and the full
  per-server state vectors.
* **Serving parity** -- ``serve_one``/``serve_grid`` with the fused
  arrival-lane kernel vs the dense inner scan and the numpy
  ``CareDispatcher`` reference, comparing JCTs in rid order.
* **Mean-field invariants at large K** (marked ``slow``) -- conservation,
  the AQ <= x-1 trigger bound and per-server bookkeeping at K = 10^4,
  where dense-vs-pallas comparison is no longer the cheap check.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import SimConfig, simulate
from repro.core.care import slotted_sim
from repro.kernels import ops as kernel_ops
from repro.serve import engine

POLICIES = ["jsq", "jsaq"]
KINDS = ["et", "dt", "rt", "et_rt", "exact", "none"]

KEY = jax.random.key(7)


def _cfg(policy, comm, backend, **kw):
    base = dict(
        servers=12, slots=2000, load=0.9, mean_service=8, x=3,
        policy=policy, comm=comm, approx="msr", service="deterministic",
        buffer_cap=64, deterministic_ties=True, route_backend=backend,
    )
    base.update(kw)
    return SimConfig(**base)


def _assert_same(rd, rp):
    assert rd.arrivals == rp.arrivals
    assert rd.departures == rp.departures
    assert rd.messages == rp.messages
    assert rd.max_aq == rp.max_aq
    assert rd.max_queue == rp.max_queue
    assert rd.queue_gap_sup == rp.queue_gap_sup
    assert rd.dropped == rp.dropped
    np.testing.assert_array_equal(rd.per_server_arrivals, rp.per_server_arrivals)
    np.testing.assert_array_equal(rd.final_q, rp.final_q)


class TestSlottedParity:
    @pytest.mark.parametrize("comm", KINDS)
    @pytest.mark.parametrize("policy", POLICIES)
    def test_matrix(self, policy, comm):
        rd = simulate(KEY, _cfg(policy, comm, "dense"))
        rp = simulate(KEY, _cfg(policy, comm, "pallas"))
        _assert_same(rd, rp)

    def test_segmented_lane_path(self):
        # K beyond one 128-lane tile exercises the kernel's segmented
        # argmin inside the full CARE slot loop.
        rd = simulate(KEY, _cfg("jsaq", "dt", "dense", servers=200))
        rp = simulate(KEY, _cfg("jsaq", "dt", "pallas", servers=200))
        _assert_same(rd, rp)
        assert rp.messages > 0  # DT actually fires at this load

    def test_grid_entry_point(self):
        cells = [_cfg("jsaq", "dt", "pallas", x=x) for x in (2, 4)]
        static = cells[0].static_part()
        scns = [c.scenario() for c in cells]
        grid = slotted_sim.simulate_grid([3, 5], static, scns, shard=False)
        for c, cell in enumerate(cells):
            dense = dataclasses.replace(cell, route_backend="dense")
            for s, seed in enumerate([3, 5]):
                rd = simulate(jax.random.key(seed), dense)
                _assert_same(rd, grid[c][s])

    @pytest.mark.parametrize(
        "bad", [
            dict(policy="rr"),
            dict(approx="basic"),
            dict(service="geometric"),
            dict(deterministic_ties=False),
            dict(service_rates=tuple([1.0] * 11 + [2.0])),
        ],
    )
    def test_rejects_unsupported(self, bad):
        cfg = dataclasses.replace(_cfg("jsaq", "dt", "pallas"), **bad)
        with pytest.raises(ValueError, match="route_backend='pallas'"):
            simulate(KEY, cfg)


SERVE_BASE = dict(
    replicas=8, decode_slots=4, slots=1500, load=0.9, x=3, rt_period=32,
    mean_prefill=2, mean_decode=16, queue_cap=256, policy="jsaq",
    deterministic_ties=True,
)


class TestServingParity:
    @pytest.mark.parametrize("comm", ["et", "dt", "exact"])
    def test_vs_dense_and_reference(self, comm):
        dense = engine.ServeConfig(**SERVE_BASE, comm=comm)
        pallas = dataclasses.replace(dense, route_backend="pallas")
        rd = engine.serve_one(7, dense)
        rp = engine.serve_one(7, pallas)
        np.testing.assert_array_equal(rd.jct_by_rid, rp.jct_by_rid)
        assert rd.messages == rp.messages
        assert rd.dropped == rp.dropped
        np.testing.assert_array_equal(rd.final_occupancy, rp.final_occupancy)
        # The numpy dispatcher with deterministic ties is the ground truth
        # both jax backends must reproduce.
        ref = engine.run_serving_sim(
            dense.engine_config(), slots=dense.slots, load=dense.load,
            mean_prefill=dense.mean_prefill, mean_decode=dense.mean_decode,
            seed=7, workload=engine.workload_for(dense, 7),
        )
        assert rp.messages == ref["messages"]
        np.testing.assert_array_equal(rp.jct_by_rid, ref["jct_by_rid"])

    def test_grid_matches_serve_one(self):
        cells = [
            engine.ServeConfig(**SERVE_BASE, comm="dt",
                               route_backend="pallas"),
            engine.ServeConfig(**{**SERVE_BASE, "x": 5}, comm="dt",
                               route_backend="pallas"),
        ]
        grid = engine.serve_grid([7, 11], cells[0].static_part(), cells,
                                 shard=False)
        for c, cell in enumerate(cells):
            for s, seed in enumerate([7, 11]):
                one = engine.serve_one(seed, cell)
                np.testing.assert_array_equal(
                    one.jct_by_rid, grid[c][s].jct_by_rid
                )
                assert one.messages == grid[c][s].messages

    def test_rejects_unsupported(self):
        with pytest.raises(ValueError, match="deterministic_ties"):
            engine.ServeConfig(
                **{**SERVE_BASE, "deterministic_ties": False},
                comm="dt", route_backend="pallas",
            ).static_part()
        with pytest.raises(ValueError, match="policy"):
            engine.ServeConfig(
                **{**SERVE_BASE, "policy": "sqd"},
                comm="dt", route_backend="pallas",
            ).static_part()


@pytest.mark.slow
class TestMeanFieldInvariants:
    """Direct kernel invariants at K = 10^4 (dense comparison too slow)."""

    K = 10_000
    T = 400
    X = 3

    def _run(self, comm, seed=0, load=0.9):
        # The paper's slotted model: one dispatcher, one Bernoulli(load)
        # arrival per slot (0/1 indicator), K parallel servers.
        rng = np.random.default_rng(seed)
        arrive = (rng.random(size=(8, self.T)) < load).astype(np.int32)
        params = np.tile(
            np.array([[self.X, 64, 8, self.T]], np.int32), (8, 1)
        )
        routed, q_true, per_srv, stats = kernel_ops.care_route(
            jax.numpy.asarray(arrive), jax.numpy.asarray(params),
            servers=self.K, cap=64, policy="jsaq", comm=comm,
        )
        return (np.asarray(arrive), np.asarray(q_true),
                np.asarray(per_srv), np.asarray(stats))

    @pytest.mark.parametrize("comm", ["et", "dt"])
    def test_conservation_and_bounds(self, comm):
        arrive, q_true, per_srv, stats = self._run(comm)
        msgs, deps, arrs, dropped, max_aq, max_q, gap = stats[:, :7].T
        # Conservation: admitted - departed = backlog, per domain.
        np.testing.assert_array_equal(arrs - deps, q_true.sum(axis=1))
        # Per-server bookkeeping sums to the admitted total.
        np.testing.assert_array_equal(per_srv.sum(axis=1), arrs)
        # Nothing dropped at this load/cap and every offer admitted.
        np.testing.assert_array_equal(arrs + dropped, arrive.sum(axis=1))
        # Theorem 2.3: the trigger pins AQ <= x-1.
        assert (max_aq <= self.X - 1).all()
        assert (max_q >= 0).all() and (gap >= 0).all()

    def test_ssc_gap_collapses(self):
        # State-space collapse: sup-gap stays O(1) while K = 10^4 -- the
        # mean-field regime the kernel exists to reach.
        _, _, _, stats = self._run("dt")
        assert (stats[:, 6] <= 4).all()
