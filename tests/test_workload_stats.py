"""Statistical property tests for the workload samplers (hypothesis).

The traced service/arrival operands only help if the samplers actually
realise the distributions they claim, so these check, over
hypothesis-chosen seeds and parameters:

* geometric / pareto / weibull empirical means within tolerance of the
  requested traced ``mean`` (discretisation adds at most +1);
* the Pareto tail index recovered from the continuous sampler by the
  Hill estimator;
* MMPP and diurnal-modulated Bernoulli long-run arrival rates equal to
  ``load`` (rate balance and sine-curve zero-mean respectively).

``derandomize=True`` keeps the example set fixed so CI cannot flake on an
unlucky draw; tolerances are sized for the fixed sample counts below.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # statistical tests skip; deterministic ones still run
    given = settings = st = None

# Hypothesis-heavy statistical sweeps: part of the full suite, skipped by
# the fast tier-1 gate (pytest -m "not slow").
pytestmark = pytest.mark.slow

from repro.core.care import workload

N = 200_000
SLOTS = 100_000


def _sizes(kind, mean, tail, seed):
    sp = workload.ServiceProcess.create(kind=kind, mean=mean, tail=tail)
    return np.asarray(workload.service_sizes(jax.random.key(seed), N, sp))


if st is not None:
    seeds = st.integers(0, 2**16 - 1)
    STATS = settings(max_examples=8, deadline=None, derandomize=True)

    @STATS
    @given(seed=seeds, mean=st.floats(5.0, 60.0))
    def test_geometric_mean(seed, mean):
        s = _sizes("geometric", mean, 2.0, seed)
        assert s.min() >= 1
        assert abs(s.mean() - mean) / mean < 0.05

    @STATS
    @given(seed=seeds, mean=st.floats(10.0, 50.0), tail=st.floats(2.2, 4.0))
    def test_pareto_mean(seed, mean, tail):
        # tail > 2.2 keeps the variance finite so the sample mean
        # concentrates; ceil-discretisation adds at most +1.
        s = _sizes("pareto", mean, tail, seed)
        assert s.min() >= 1
        assert -0.12 * mean < s.mean() - mean < 0.12 * mean + 1.0

    @STATS
    @given(seed=seeds, tail=st.floats(1.3, 3.5))
    def test_pareto_tail_index_hill(seed, tail):
        sp = workload.ServiceProcess.create(
            kind="pareto", mean=30.0, tail=tail
        )
        u = jax.random.uniform(jax.random.key(seed), (N,), jnp.float32,
                               1e-7, 1.0 - 1e-7)
        x = np.sort(np.asarray(workload.pareto_raw(u, sp.scale, sp.inv_tail)))
        k = N // 50  # Hill estimator over the top 2% order statistics
        top = x[-k:]
        hill = 1.0 / np.mean(np.log(top / top[0]))
        assert abs(hill - tail) < 0.35 * tail

    @STATS
    @given(seed=seeds, mean=st.floats(10.0, 50.0), tail=st.floats(0.6, 2.5))
    def test_weibull_mean(seed, mean, tail):
        s = _sizes("weibull", mean, tail, seed)
        assert s.min() >= 1
        assert -0.10 * mean < s.mean() - mean < 0.10 * mean + 1.0

    # jit once at module level; the rates enter traced so every hypothesis
    # example reuses one compiled program instead of retracing the scan.
    _MMPP_FN = jax.jit(
        lambda key, hi, lo, stay: workload.mmpp_arrivals_from_rates(
            key, SLOTS, hi, lo, stay
        )
    )

    @STATS
    @given(seed=seeds, load=st.floats(0.2, 0.9),
           intensity=st.floats(1.1, 2.0))
    def test_mmpp_long_run_rate(seed, load, intensity):
        lam_hi = min(intensity * load, 1.0)
        lam_lo = max(2.0 * load - lam_hi, 0.0)
        arrive = np.asarray(
            _MMPP_FN(jax.random.key(seed), jnp.float32(lam_hi),
                     jnp.float32(lam_lo), jnp.float32(0.98))
        )
        # Bursts of mean length 50 leave ~SLOTS/50 independent blocks.
        assert abs(arrive.mean() - load) < 0.06

    @STATS
    @given(seed=seeds, load=st.floats(0.2, 0.7),
           amp_frac=st.floats(0.2, 0.9))
    def test_diurnal_long_run_rate(seed, load, amp_frac):
        # amp <= min(1, 1/load - 1) keeps the instantaneous rate a
        # probability; over whole periods the sine averages out, so the
        # long-run mean rate is exactly load.
        amp = amp_frac * min(1.0, 1.0 / load - 1.0)
        t_idx = jnp.arange(SLOTS, dtype=jnp.int32)
        mod = workload.diurnal_modulation(t_idx, jnp.float32(amp),
                                          jnp.float32(1000.0))
        arrive = np.asarray(
            jax.random.bernoulli(jax.random.key(seed), load * mod, (SLOTS,))
        )
        assert abs(arrive.mean() - load) < 0.02


def test_deterministic_sizes_exact():
    s = _sizes("deterministic", 7.0, 2.0, 0)
    assert np.all(s == 7)


def test_diurnal_amp_zero_is_exactly_one():
    t_idx = jnp.arange(1024, dtype=jnp.int32)
    mod = workload.diurnal_modulation(t_idx, jnp.float32(0.0),
                                      jnp.float32(333.0))
    assert np.all(np.asarray(mod) == 1.0)


@pytest.mark.parametrize(
    "kind,tail,err",
    [("pareto", 1.0, "tail"), ("pareto", 0.5, "tail"),
     ("weibull", 0.0, "shape"), ("badkind", 2.0, "kind")],
)
def test_create_rejects_invalid(kind, tail, err):
    with pytest.raises(ValueError, match=err):
        workload.ServiceProcess.create(kind=kind, mean=30.0, tail=tail)


def test_create_rejects_sub_slot_mean():
    with pytest.raises(ValueError, match="mean"):
        workload.ServiceProcess.create(mean=0.5)
