"""Per-architecture smoke tests on reduced same-family configs.

For every assigned arch: instantiate the reduced config, run one forward /
train step on CPU, assert output shapes and no NaNs.  Also checks
prefill+decode consistency against the full forward for one arch per
family (the strictest correctness check we can run without hardware).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import common, model

B, S = 2, 32


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size, jnp.int32)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k2, (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = model.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    loss, aux = jax.jit(lambda p: model.train_loss(p, batch, cfg))(params)
    loss_v = float(loss)
    # loss is finite and near log(vocab) at init
    assert np.isfinite(loss_v)
    assert 0.5 * np.log(cfg.vocab_size) < loss_v < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grads_finite(arch):
    cfg = get_config(arch).reduced()
    params = model.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))

    @jax.jit
    def loss_fn(p):
        loss, _ = model.train_loss(p, batch, cfg)
        return loss

    grads = jax.grad(loss_fn)(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), "non-finite gradient"


@pytest.mark.parametrize(
    "arch",
    ["smollm-135m", "gemma2-9b", "deepseek-v2-236b", "rwkv6-1.6b", "hymba-1.5b",
     "whisper-small"],
)
def test_prefill_decode_consistency(arch):
    """logits(prefill over S) == logits(prefill over S-1, then 1 decode)."""
    cfg = get_config(arch).reduced()
    params = model.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    cache_len = S + 4

    full_logits, _ = jax.jit(
        lambda p, b: model.prefill(p, b, cfg, cache_len=cache_len)
    )(params, batch)

    short = dict(batch)
    short["tokens"] = batch["tokens"][:, : S - 1]
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cfg, cache_len=cache_len))(
        params, short
    )
    step_logits, _ = jax.jit(
        lambda p, t, c: model.decode_step(p, t, c, jnp.int32(S - 1), cfg)
    )(params, batch["tokens"][:, S - 1], cache)

    np.testing.assert_allclose(
        np.asarray(full_logits), np.asarray(step_logits), rtol=2e-2, atol=2e-2
    )


def test_param_counts_full_configs():
    """Full (non-reduced) configs must hit the advertised scale (via math,
    not allocation): structural check on a few archs."""
    from repro.launch import model_stats

    n = model_stats.count_params(get_config("smollm-135m"))
    assert 0.10e9 < n < 0.17e9, n
    n = model_stats.count_params(get_config("deepseek-v3-671b"))
    assert 0.6e12 < n < 0.75e12, n
    n = model_stats.count_params(get_config("gemma2-9b"))
    assert 8e9 < n < 11e9, n
    n = model_stats.count_params(get_config("rwkv6-1.6b"))
    assert 1.2e9 < n < 2.2e9, n


def test_moe_counts_exported():
    cfg = get_config("deepseek-v2-236b").reduced()
    params = model.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    loss, aux = jax.jit(lambda p: model.train_loss(p, batch, cfg))(params)
    counts = aux["counts"]
    l_scan = cfg.num_layers - cfg.first_dense_layers
    assert counts.shape == (l_scan, cfg.n_routed_experts)
    total = float(counts.sum())
    assert total == l_scan * B * S * cfg.moe_top_k
