"""Cross-tier policy consistency: serving routing == slotted routing.

The slotted simulator (``repro.core.care.routing``) and the serving engine
(``repro.serve.engine``) implement the *same* paper policies -- JSAQ,
SQ(d), drain-time-aware JSAQ -- with deliberately different randomness
plumbing (jax PRNG keys with Gumbel tie-breaks vs pre-drawn float32
uniforms with rank tie-breaks).  These tests catch drift between the two
implementations of one policy: a shared CARE queue system (deterministic
unit service, the shared comm core advancing the approximation under a
matched comm kind) is evolved step by step, and at every arrival *both*
tiers' route step is asked for a decision over the identical state vector.

Whenever the decision is forced -- the (scaled, subset-restricted) minimum
is unique -- the two implementations must agree exactly; tie-broken steps
are advanced with the serving tier's pick so the trajectory stays shared
(the tie-break *distributions* match by construction, uniform over the tie
set, but the draws are not comparable across PRNG schemes).  For SQ(d) the
sampled subset is held fixed across tiers by recomputing the slotted
tier's key-derived subset and handing it to the serving tier's masked
pick, so the comparison isolates the selection rule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.care import comm as comm_lib
from repro.core.care import routing as routing_lib
from repro.serve import engine


def _slotted_decision(policy, occ, key, d, drain_slots):
    """The slotted tier's route step on state vector ``occ``."""
    j, _ = routing_lib.route(
        policy,
        q_true=jnp.asarray(occ),
        q_app=jnp.asarray(occ),
        rr_ptr=jnp.zeros((), jnp.int32),
        key=key,
        d=d,
        drain_slots=None if drain_slots is None else jnp.asarray(drain_slots),
    )
    return int(j)


def _serving_decision(policy, occ, u, mask, drain_slots):
    """The serving tier's route step on the same state vector."""
    if policy == "jsaq":
        score, m = occ, None
    elif policy == "sqd":
        score, m = occ, mask
    else:  # the drain-time-aware score q * E[S]/r
        score, m = occ * drain_slots, None
    return engine.pick_min_tied(score, u, mask=m)


def _sqd_subset(key, k, d):
    """Recompute route_sqd's key-derived subset (its first split child)."""
    key_perm, _ = jax.random.split(key)
    sample = np.asarray(jax.random.permutation(key_perm, k))[:d]
    mask = np.zeros(k, bool)
    mask[sample] = True
    return mask


def _run_shared_trajectory(policy, comm, seed, steps=400, k=6, d=2,
                           drain_slots=None):
    """Evolve one CARE system; compare both tiers' decision at each arrival.

    Returns ``(checked, total)``: forced (unique-min) decision points that
    were compared, and total arrivals routed.  Any disagreement asserts.
    """
    rng = np.random.default_rng(seed)
    q = np.zeros(k, np.int64)  # true queue lengths
    app = np.zeros(k, np.float32)  # CARE-approximated state
    comm_state = comm_lib.CommState.init(k, xp=np)
    ccfg = comm_lib.CommConfig(kind=comm, x=2, rt_period=8)
    key = jax.random.key(seed)
    checked = total = 0
    for _ in range(steps):
        # Near-saturation Poisson arrivals routed sequentially within the
        # slot (the serving engine's lane semantics): unit service would
        # drain one-arrival-per-slot traffic instantly and every decision
        # would be an all-zeros tie -- heavy traffic is what differentiates
        # the queues and forces decisions.
        for _arr in range(int(rng.poisson(0.9 * k))):
            total += 1
            occ = q.astype(np.float32) if comm == "exact" else app.copy()
            key, sk = jax.random.split(key)
            u = rng.random(dtype=np.float32)
            mask = _sqd_subset(sk, k, d) if policy == "sqd" else None
            # The slotted tier spells drain-time awareness as JSAQ plus
            # the drain_slots operand (rate_aware); the serving tier as
            # its own "drain" policy kind -- same rule, two spellings.
            slotted_policy = "jsaq" if policy == "drain" else policy
            slotted_j = _slotted_decision(slotted_policy, occ, sk, d,
                                          drain_slots)
            serving_j = _serving_decision(policy, occ, u, mask, drain_slots)
            if policy == "sqd":
                cand = occ[mask]
            elif policy == "drain":
                cand = occ * drain_slots
            else:
                cand = occ
            if (cand == cand.min()).sum() == 1:  # forced decision
                checked += 1
                assert slotted_j == serving_j, (
                    f"{policy}/{comm}: slotted routed {slotted_j}, "
                    f"serving routed {serving_j} on occ={occ}"
                )
            j = serving_j  # advance the shared trajectory
            q[j] += 1
            app[j] += np.float32(1.0)
        # Deterministic unit service: every busy server completes one job.
        dep = (q > 0).astype(np.int64)
        q = q - dep
        # MSR-style emulation at *half* the true rate (dyadic f32) + the
        # shared trigger core.  A unit drain would mirror deterministic
        # unit service exactly -- zero error, no triggers, and every comm
        # kind would degenerate to the same trajectory; the deliberate
        # mismatch keeps the routed state genuinely approximate.
        busy = app > 0
        app = np.maximum(
            app - np.float32(0.5) * busy.astype(np.float32), np.float32(0.0)
        )
        err = np.abs(q.astype(np.float32) - app)
        trig, comm_state = comm_lib.evaluate(comm_state, ccfg, err, dep,
                                             xp=np)
        app = np.where(trig, q.astype(np.float32), app)
    return checked, total


class TestJsaqConsistency:
    @pytest.mark.parametrize("comm", ["exact", "et", "dt"])
    def test_decisions_agree(self, comm):
        checked, total = _run_shared_trajectory("jsaq", comm, seed=11)
        # The comparison must actually bite: a healthy fraction of
        # decisions is forced (unique minimum) under these dynamics --
        # lowest under comm="exact", whose integer queue lengths tie
        # more often than the fractional approximations.
        assert checked >= total * 0.1
        assert total >= 200


class TestDeterministicTies:
    """Unified lowest-index tie-breaking across all three route paths.

    Under ``deterministic_ties`` every backend -- the slotted
    ``routing.route``, the serving ``pick_min_tied`` and the Pallas
    kernel's segmented argmin -- must agree on *every* decision, ties
    included (no forced-decision filtering): they all resolve to the
    lowest index, which is what makes dense-vs-kernel bit-parity
    assertable at all.
    """

    def test_all_three_paths_agree_on_ties(self):
        from repro.kernels import ops as kernel_ops

        rng = np.random.default_rng(5)
        for trial in range(30):
            k = int(rng.integers(2, 40))
            occ = rng.integers(0, 4, size=k).astype(np.int64)  # many ties
            j_ref = int(np.argmin(occ))  # lowest index among minima
            j_slot = _slotted_decision_det(occ)
            j_serve = engine.pick_min_tied(
                occ.astype(np.float32), np.float32(rng.random()),
                deterministic=True,
            )
            idx, _ = kernel_ops.jsaq_route(
                jnp.asarray(occ.astype(np.int32))[None, :], 1,
                interpret=True,
            )
            j_kern = int(np.asarray(idx)[0, 0])
            assert j_slot == j_serve == j_kern == j_ref, (
                f"trial {trial}: occ={occ} slotted={j_slot} "
                f"serving={j_serve} kernel={j_kern} ref={j_ref}"
            )

    def test_u_is_ignored(self):
        occ = np.asarray([2.0, 1.0, 1.0, 1.0], np.float32)
        picks = {
            engine.pick_min_tied(occ, np.float32(u), deterministic=True)
            for u in (0.0, 0.3, 0.6, 0.999)
        }
        assert picks == {1}


def _slotted_decision_det(occ):
    """The slotted tier's deterministic-ties route step."""
    j, _ = routing_lib.route(
        "jsaq",
        q_true=jnp.asarray(occ),
        q_app=jnp.asarray(occ),
        rr_ptr=jnp.zeros((), jnp.int32),
        key=jax.random.key(0),
        deterministic=True,
    )
    return int(j)


class TestSqdConsistency:
    @pytest.mark.parametrize("comm", ["exact", "et"])
    @pytest.mark.parametrize("d", [2, 3])
    def test_decisions_agree_on_shared_subset(self, comm, d):
        checked, total = _run_shared_trajectory("sqd", comm, seed=23, d=d)
        # Restricting to d candidates makes unique minima *more* common.
        assert checked >= total * 0.3
        assert total >= 200


class TestDrainConsistency:
    def test_decisions_agree_under_rate_asymmetry(self):
        # 2:1 speeds: the drain score q * E[S]/r must pick the same
        # server in both tiers whenever the scaled minimum is unique.
        rates = np.asarray([2.0, 2.0, 2.0, 1.0, 1.0, 1.0], np.float32)
        drain_slots = routing_lib.expected_drain_slots(
            np.float32(6.0), rates
        )
        checked, total = _run_shared_trajectory(
            "drain", "et", seed=37, drain_slots=drain_slots
        )
        assert checked >= total * 0.2

    def test_slotted_route_accepts_serving_drain_operand(self):
        # The two tiers share one expected_drain_slots implementation;
        # feeding the serving tier's operand through the slotted route()
        # must reproduce the serving argmin on unambiguous states.
        rates = np.asarray([2.0, 1.0, 0.5, 1.0], np.float32)
        drain_slots = routing_lib.expected_drain_slots(np.float32(8.0),
                                                       rates)
        # drain_slots = [4, 8, 16, 8] -> scores [16, 24, 24, 48]: the
        # queue of 4 at the double-speed server wins over the queue of
        # 1.5 at the half-speed one, uniquely.
        occ = np.asarray([4.0, 3.0, 1.5, 6.0], np.float32)
        j_slot = _slotted_decision("jsaq", occ, jax.random.key(0), 2,
                                   drain_slots)
        j_serve = _serving_decision("drain", occ, np.float32(0.5), None,
                                    drain_slots)
        assert j_slot == j_serve == 0
