"""Zero-completion safety of the metric reductions.

Short-horizon quick runs (and padded cells with tiny traced horizons) can
legitimately finish with *no completed jobs*; every percentile / mean
reduction must then produce defined zeros, never NaN -- a NaN row is a CI
trajectory-diff regression by design (``benchmarks/diff.py``).
"""
import warnings

import numpy as np

from repro.core.care import metrics, slotted_sim


EMPTY = np.array([], dtype=np.int64)


def test_jct_summary_empty_is_zero_not_nan():
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # np raises RuntimeWarning on empty mean
        s = metrics.jct_summary(EMPTY)
    assert s == {"count": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0,
                 "p99": 0.0, "p999": 0.0}
    assert all(np.isfinite(v) for v in s.values())


def test_jct_summary_accepts_lists():
    s = metrics.jct_summary(np.asarray([4, 4, 4]))
    assert s["count"] == 3 and s["mean"] == 4.0 and s["p999"] == 4.0


def test_mean_jct_empty_and_nonempty():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert metrics.mean_jct(EMPTY) == 0.0
    assert metrics.mean_jct(np.asarray([2, 4])) == 3.0


def test_ccdf_empty_samples():
    grid, frac = metrics.ccdf(EMPTY)
    assert np.all(frac == 0.0)
    assert np.all(np.isfinite(frac))


def test_ccdf_dominates_empty_inputs():
    assert metrics.ccdf_dominates(EMPTY, EMPTY) in (True, False)


def test_relative_communication_zero_departures():
    r = slotted_sim.SimResult(
        jct=EMPTY, arrivals=0, departures=0, messages=0, max_aq=0,
        max_queue=0, overflow=False,
        per_server_arrivals=np.zeros(4, np.int64),
        final_q=np.zeros(4, np.int64),
    )
    assert metrics.relative_communication(r, "jsaq") == 0.0
    assert np.isfinite(metrics.relative_communication(r, "jsq"))


def test_simulation_with_zero_completions_yields_finite_summary():
    # A horizon shorter than one mean service: jobs arrive, none finish.
    cfg = slotted_sim.SimConfig(slots=5, load=1.0, mean_service=50)
    res = slotted_sim.simulate(__import__("jax").random.key(0), cfg)
    s = metrics.jct_summary(res.jct)
    assert res.jct.size == 0
    assert all(np.isfinite(v) for v in s.values())


# --- log-bucket JCT histogram (streaming-engine tail accumulator) ---------


def test_jct_bucket_edges_partition_int32():
    """Every bucket's edge range maps back to that bucket, exhaustively
    near every boundary (and the bucket index is monotone in j)."""
    edges = metrics.jct_bucket_edges()
    assert edges.shape == (metrics.HIST_BUCKETS + 1,)
    assert edges[0] == 1 and edges[-1] == 2**31
    assert np.all(np.diff(edges) > 0)
    # Probe each boundary from both sides plus the bucket interior.
    for b in range(metrics.HIST_BUCKETS):
        lo, hi = int(edges[b]), int(edges[b + 1])
        probes = [lo, lo + (hi - lo) // 2, hi - 1]
        got = metrics.jct_bucket(np.asarray(probes, np.int64))
        assert np.all(got == b), (b, probes, got)


def test_jct_bucket_matches_between_numpy_and_jax():
    import jax.numpy as jnp

    j = np.concatenate([
        np.arange(1, 70),
        2 ** np.arange(2, 31, dtype=np.int64),
        2 ** np.arange(2, 31, dtype=np.int64) - 1,
        np.asarray([np.iinfo(np.int32).max]),
    ])
    b_np = metrics.jct_bucket(j, xp=np)
    b_jx = np.asarray(metrics.jct_bucket(jnp.asarray(j), xp=jnp))
    assert np.array_equal(b_np, b_jx)


def test_jct_bucket_clips_nonpositive():
    assert metrics.jct_bucket(np.asarray([0, -5, 1])).tolist() == [0, 0, 0]


def test_log_hist_quantiles_empty_is_zero():
    hist = np.zeros(metrics.HIST_BUCKETS, np.int64)
    q = metrics.log_hist_quantiles(hist, (0.5, 0.99))
    assert np.all(q == 0.0) and np.all(np.isfinite(q))


def test_log_hist_quantiles_exact_small_buckets():
    # Samples 1/2/3 live in single-value buckets: quantiles are exact.
    samples = np.asarray([1] * 10 + [2] * 10 + [3] * 10)
    hist = np.bincount(metrics.jct_bucket(samples),
                       minlength=metrics.HIST_BUCKETS)
    p50, = metrics.log_hist_quantiles(hist, (0.5,))
    assert p50 == 2.0


def test_log_hist_quantiles_bounded_by_sub_octave():
    rng = np.random.default_rng(0)
    samples = rng.integers(1, 10_000, size=20_000)
    hist = np.bincount(metrics.jct_bucket(samples),
                       minlength=metrics.HIST_BUCKETS)
    for q in (0.5, 0.9, 0.99, 0.999):
        est, = metrics.log_hist_quantiles(hist, (q,))
        exact = np.quantile(samples, q)
        # A bucket spans <= 25% relative width, so the histogram estimate
        # lands within one sub-octave of the exact sample quantile.
        assert abs(est - exact) <= 0.25 * exact + 1.0, (q, est, exact)


def test_stream_summary_empty_and_roundtrip():
    empty = metrics.stream_summary(
        0, 0.0, 0.0, 0, np.zeros(metrics.HIST_BUCKETS, np.int64)
    )
    assert empty["count"] == 0 and empty["p999"] == 0.0
    assert all(np.isfinite(v) for v in empty.values())

    samples = np.asarray([10, 20, 30, 40], np.int64)
    hist = np.bincount(metrics.jct_bucket(samples),
                       minlength=metrics.HIST_BUCKETS)
    s = metrics.stream_summary(
        samples.size, samples.mean(),
        ((samples - samples.mean()) ** 2).sum(), samples.max(), hist,
    )
    assert s["count"] == 4 and s["mean"] == 25.0 and s["max"] == 40
    assert abs(s["std"] - samples.std()) < 1e-6


def test_stream_summary_all_discarded_takes_zero_count_path():
    # Warmup can discard every completion from the quantile histogram
    # while the exact max was tracked pre-discard: the summary must take
    # the zero-count disambiguated path (count=0, zero quantiles, max
    # preserved), never clamp the empty histogram's zero "quantiles"
    # into [0, max] as if they described a sample.
    empty_hist = np.zeros(metrics.HIST_BUCKETS, np.int64)
    s = metrics.stream_summary(0, 0.0, 0.0, 37, empty_hist)
    assert s["count"] == 0 and s["max"] == 37
    assert s["p50"] == s["p90"] == s["p99"] == s["p999"] == 0.0
    assert all(np.isfinite(v) for v in s.values())
    # Moments tracked but no histogram mass (every sample dropped from
    # the quantile buckets): same disambiguated path, not a 0.0
    # "quantile" next to a nonzero count.
    s = metrics.stream_summary(12, 37.0, 4.0, 37, empty_hist)
    assert s["count"] == 0 and s["max"] == 37
    assert s["p999"] == 0.0


def test_stream_summary_single_bucket_clamps_to_max():
    # Every sample in one bucket: interpolation inside the bucket would
    # overshoot the sample maximum, so the clamp must pin every quantile
    # at (or below) the tracked exact max -- never above it.
    samples = np.full(50, 17, np.int64)
    hist = np.bincount(metrics.jct_bucket(samples),
                       minlength=metrics.HIST_BUCKETS)
    s = metrics.stream_summary(
        samples.size, 17.0, 0.0, 17, hist,
    )
    for k in ("p50", "p90", "p99", "p999"):
        assert 16.0 <= s[k] <= 17.0, (k, s[k])
    assert s["max"] == 17 and s["std"] == 0.0


def test_stream_summary_single_sample_is_finite():
    hist = np.bincount(metrics.jct_bucket(np.asarray([5])),
                       minlength=metrics.HIST_BUCKETS)
    s = metrics.stream_summary(1, 5.0, 0.0, 5, hist)
    assert s["count"] == 1
    assert all(np.isfinite(v) for v in s.values())
    assert s["p999"] <= 5.0


def test_token_summary_empty_window_is_finite_zeros():
    # The pull-token counters' analogue of the jct_summary contract: an
    # empty window (no slots run, no jobs routed) yields finite zeros
    # with a count field, so aggregation never divides by zero.
    s = metrics.token_summary(0, 0, 0, 0)
    assert s == {"count": 0, "mean_tokens": 0.0, "miss_rate": 0.0,
                 "hit_rate": 0.0}
    assert all(np.isfinite(v) for v in s.values())


def test_token_summary_partial_windows():
    # Slots ran but nothing was routed (zero-arrival window): pool
    # occupancy is defined, the rate fields stay finite zeros.
    s = metrics.token_summary(30, 0, 10, 0)
    assert s["count"] == 0 and s["mean_tokens"] == 3.0
    assert s["miss_rate"] == 0.0 and s["hit_rate"] == 0.0
    # Routed jobs but a zero-slot window (degenerate caller) stays finite.
    s = metrics.token_summary(0, 2, 0, 8)
    assert s["count"] == 8 and s["mean_tokens"] == 0.0
    assert s["miss_rate"] == 0.25 and s["hit_rate"] == 0.75


def test_token_summary_rates():
    s = metrics.token_summary(120, 25, 60, 100)
    assert s["count"] == 100
    assert s["mean_tokens"] == 2.0
    assert s["miss_rate"] == 0.25 and s["hit_rate"] == 0.75
