"""Zero-completion safety of the metric reductions.

Short-horizon quick runs (and padded cells with tiny traced horizons) can
legitimately finish with *no completed jobs*; every percentile / mean
reduction must then produce defined zeros, never NaN -- a NaN row is a CI
trajectory-diff regression by design (``benchmarks/diff.py``).
"""
import warnings

import numpy as np

from repro.core.care import metrics, slotted_sim


EMPTY = np.array([], dtype=np.int64)


def test_jct_summary_empty_is_zero_not_nan():
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # np raises RuntimeWarning on empty mean
        s = metrics.jct_summary(EMPTY)
    assert s == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0, "p999": 0.0}
    assert all(np.isfinite(v) for v in s.values())


def test_jct_summary_accepts_lists():
    s = metrics.jct_summary(np.asarray([4, 4, 4]))
    assert s["mean"] == 4.0 and s["p999"] == 4.0


def test_mean_jct_empty_and_nonempty():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert metrics.mean_jct(EMPTY) == 0.0
    assert metrics.mean_jct(np.asarray([2, 4])) == 3.0


def test_ccdf_empty_samples():
    grid, frac = metrics.ccdf(EMPTY)
    assert np.all(frac == 0.0)
    assert np.all(np.isfinite(frac))


def test_ccdf_dominates_empty_inputs():
    assert metrics.ccdf_dominates(EMPTY, EMPTY) in (True, False)


def test_relative_communication_zero_departures():
    r = slotted_sim.SimResult(
        jct=EMPTY, arrivals=0, departures=0, messages=0, max_aq=0,
        max_queue=0, overflow=False,
        per_server_arrivals=np.zeros(4, np.int64),
        final_q=np.zeros(4, np.int64),
    )
    assert metrics.relative_communication(r, "jsaq") == 0.0
    assert np.isfinite(metrics.relative_communication(r, "jsq"))


def test_simulation_with_zero_completions_yields_finite_summary():
    # A horizon shorter than one mean service: jobs arrive, none finish.
    cfg = slotted_sim.SimConfig(slots=5, load=1.0, mean_service=50)
    res = slotted_sim.simulate(__import__("jax").random.key(0), cfg)
    s = metrics.jct_summary(res.jct)
    assert res.jct.size == 0
    assert all(np.isfinite(v) for v in s.values())
