"""Tests for the fault-injection / degraded-network control plane.

Covers the tentpole contracts of the robustness layer:

* **net_step semantics** (numpy, no jit): deterministic delivery delay
  with send-time payload snapshots, piggyback batching of triggers that
  fire while the channel is busy, i.i.d. drops (counted on the wire, no
  ack), jittered delay bounds, and the staleness clock.
* **Zero-operand identity**: ``network="net"`` / ``fault="crash"`` with
  all-neutral operands is bit-identical to the historical instant,
  fault-free program on both tiers -- the defaults cannot move goldens.
* **jax <-> numpy bit-parity** for a (policy x comm x network x fault)
  matrix on the serving tier, including delayed, dropped and
  crash/recovery sample paths, and single-run <-> fused-grid parity on
  the slotted tier.
* **Degraded-regime invariants**: conservation of jobs under
  crash/recovery, no job routed to a suspect-dead server while healthy
  candidates exist, and the resync-on-recovery retry path restoring the
  approximation immediately after a crash ends.
* **Config validation / backend guards**: every invalid operand is
  rejected with an error naming the offending field; the Pallas backends
  refuse non-``none`` kinds instead of silently computing
  instant-delivery results.
* **SQ(d) message accounting**: under the network model the 2d query
  round-trips are counted as real wire traffic (not analytically).
* **Reliable transport** (``transport="ack"``): ack'd sends with
  timeout/retransmit/backoff windows, fresh-snapshot retransmits,
  abandon-after-max_retries self-suspects, keepalive-driven suspect
  masking, eventual delivery under drop < 1, and jax <-> numpy parity
  for ack cells across the matrix (pull-token retransmits included).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.care import comm as comm_lib
from repro.core.care import routing as routing_lib
from repro.core.care import slotted_sim as sim
from repro.serve import engine


# ---------------------------------------------------------------------------
# net_step unit semantics (numpy -- no jit, direct state inspection).
# ---------------------------------------------------------------------------


def _ncfg(delay=0, jitter=0, drop=0.0):
    return comm_lib.NetworkConfig(
        kind="net", delay=np.int32(delay), jitter=np.int32(jitter),
        drop=np.float32(drop),
    )


def _drive(cfg, triggers, payloads, drop_u=None, jit_u=None, k=1):
    """Step a single-server channel through a trigger/payload schedule.

    Returns per-slot (delivered, payload) plus the final state.
    """
    state = comm_lib.NetState.init(k, xp=np, payload_dtype=np.float32)
    t_n = len(triggers)
    out = []
    for t in range(t_n):
        du = (
            np.full(k, 0.99, np.float32) if drop_u is None
            else np.full(k, drop_u[t], np.float32)
        )
        ju = (
            np.zeros(k, np.float32) if jit_u is None
            else np.full(k, jit_u[t], np.float32)
        )
        delivered, payload, sent, state = comm_lib.net_step(
            state, cfg, np.full(k, triggers[t], bool),
            np.full(k, payloads[t], np.float32), du, ju, xp=np,
        )
        out.append((bool(delivered[0]), float(payload[0]), int(sent)))
    return out, state


class TestNetStep:
    def test_zero_delay_is_instant(self):
        out, _ = _drive(_ncfg(delay=0), [True, False], [5.0, 9.0])
        assert out[0] == (True, 5.0, 1)
        assert out[1][0] is False

    def test_delay_applies_send_time_snapshot(self):
        # Sent at t=0 with payload 5.0; the queue then changes (payload 9)
        # but delivery at t=3 must apply the *send-time* snapshot.
        out, _ = _drive(
            _ncfg(delay=3),
            [True, False, False, False, False],
            [5.0, 9.0, 9.0, 9.0, 9.0],
        )
        assert [o[0] for o in out] == [False, False, False, True, False]
        assert out[3][1] == 5.0
        assert sum(o[2] for o in out) == 1

    def test_piggyback_batches_triggers_behind_in_flight(self):
        # Trigger at t=0 and again at t=1 while the channel is busy: the
        # second is piggybacked -- sent with a *fresh* snapshot the slot
        # the channel frees (t=2), delivered at t=4.  Two messages total.
        out, _ = _drive(
            _ncfg(delay=2),
            [True, True, False, False, False],
            [5.0, 6.0, 7.0, 8.0, 9.0],
        )
        delivered = [o[0] for o in out]
        assert delivered == [False, False, True, False, True]
        assert out[2][1] == 5.0  # first message: t=0 snapshot
        assert out[4][1] == 7.0  # piggybacked send: fresh t=2 snapshot
        assert sum(o[2] for o in out) == 2

    def test_drop_costs_a_message_and_is_never_delivered(self):
        out, state = _drive(
            _ncfg(delay=2, drop=0.5),
            [True, False, False, False],
            [5.0, 5.0, 5.0, 5.0],
            drop_u=[0.1, 0.99, 0.99, 0.99],  # 0.1 < 0.5 -> lost
        )
        assert not any(o[0] for o in out)
        assert sum(o[2] for o in out) == 1  # lost messages still cost
        assert int(state.drops) == 1

    def test_jitter_bounds_delivery_window(self):
        # jit_u ~ 1 -> extra = floor(u * (jitter+1)) = jitter (max);
        # jit_u = 0 -> extra = 0 (min).  Base delay 2, jitter 3.
        late, _ = _drive(
            _ncfg(delay=2, jitter=3),
            [True] + [False] * 7, [5.0] * 8, jit_u=[0.999] * 8,
        )
        assert [o[0] for o in late].index(True) == 5  # delay + jitter
        early, _ = _drive(
            _ncfg(delay=2, jitter=3),
            [True] + [False] * 7, [5.0] * 8, jit_u=[0.0] * 8,
        )
        assert [o[0] for o in early].index(True) == 2  # base delay

    def test_age_is_slots_since_delivery(self):
        out, state = _drive(
            _ncfg(delay=2),
            [True, False, False, False, False],
            [5.0] * 5,
        )
        # Delivery at t=2 resets the staleness clock; it then counts up.
        assert [o[0] for o in out] == [False, False, True, False, False]
        assert int(state.age[0]) == 2

    def test_crash_wipes_queued_piggyback(self):
        # Regression: a trigger queued behind an in-flight message
        # (pending=True) describes *pre-crash* state; a crash (can_send
        # False) must wipe it, or the stale snapshot fires at the next
        # free slot ahead of the recovery resync.
        cfg = _ncfg(delay=3)
        state = comm_lib.NetState.init(1, xp=np, payload_dtype=np.float32)
        du = np.full(1, 0.99, np.float32)
        ju = np.zeros(1, np.float32)

        def step(trig, payload, can_send=None):
            return comm_lib.net_step(
                state, cfg, np.array([trig]),
                np.full(1, payload, np.float32), du, ju, xp=np,
                can_send=None if can_send is None else np.array([can_send]),
            )

        _, _, s0, state = step(True, 5.0)  # t=0: in flight 3 slots
        _, _, _, state = step(True, 6.0)  # t=1: queued behind it
        assert bool(state.pending[0])
        # t=2: the server crashes mid-flight; the queued intent dies too.
        _, _, _, state = step(False, 7.0, can_send=False)
        assert not bool(state.pending[0])
        # The channel frees (t=3 delivery) but nothing new is ever sent.
        sent_after = 0
        for _ in range(5):
            _, _, sent, state = step(False, 8.0, can_send=False)
            sent_after += int(sent)
        assert int(s0) == 1 and sent_after == 0


# ---------------------------------------------------------------------------
# Reliable transport (transport="ack") unit semantics, numpy.
# ---------------------------------------------------------------------------


def _ack_cfg(delay=0, jitter=0, drop=0.0, timeout=4, base=2.0, retries=8,
             ka=0):
    return comm_lib.NetworkConfig(
        kind="net", delay=np.int32(delay), jitter=np.int32(jitter),
        drop=np.float32(drop), transport="ack",
        ack_timeout=np.int32(timeout), backoff_base=np.float32(base),
        max_retries=np.int32(retries), ka_period=np.int32(ka),
    )


def _ack_step(state, cfg, trig, payload, drop_u=0.99, can_send=None):
    """One single-server ack-transport slot with lossless ack/ka legs."""
    ack_u = np.stack([
        np.full(1, 0.99, np.float32),  # ack drop draw (never lost)
        np.zeros(1, np.float32),  # ack jitter (minimum)
        np.full(1, 0.99, np.float32),  # keepalive drop draw
        np.zeros(1, np.float32),  # keepalive jitter
    ])
    return comm_lib.net_step_ack(
        state, cfg, np.array([trig]), np.full(1, payload, np.float32),
        np.full(1, drop_u, np.float32), np.zeros(1, np.float32), ack_u,
        xp=np,
        can_send=None if can_send is None else np.array([can_send]),
    )


class TestAckTransport:
    def test_round_trip_closes_window_and_bills_the_ack(self):
        cfg = _ack_cfg(delay=2, timeout=10)
        state = comm_lib.AckNetState.init(1, xp=np,
                                          payload_dtype=np.float32)
        log = []
        for t in range(6):
            delivered, payload, sent, state = _ack_step(
                state, cfg, t == 0, float(t + 5)
            )
            log.append((bool(delivered[0]), float(payload[0]), int(sent)))
        # Data lands at t=2 with the t=0 snapshot; its ack (same 2-slot
        # wire) lands at t=4 and closes the window -- no retransmit.
        assert [d for d, _, _ in log] == [
            False, False, True, False, False, False
        ]
        assert log[2][1] == 5.0
        # 1 data message + 1 ack, both billed on the wire.
        assert sum(s for _, _, s in log) == 2
        assert int(state.retrans) == 0 and int(state.awaiting[0]) == -1
        assert not bool(state.gave_up[0])

    def test_dropped_data_retransmits_fresh_snapshot(self):
        # Instant wire, timeout 2: the t=0 send is lost; the window
        # expires at t=2 and the retransmit snapshots the *current*
        # payload (7.0), never the stale t=0 one.
        cfg = _ack_cfg(delay=0, drop=0.5, timeout=2)
        state = comm_lib.AckNetState.init(1, xp=np,
                                          payload_dtype=np.float32)
        out = []
        for t, du in enumerate([0.1, 0.99, 0.99]):  # 0.1 < 0.5 -> lost
            delivered, payload, sent, state = _ack_step(
                state, cfg, t == 0, float(t + 5), drop_u=du
            )
            out.append((bool(delivered[0]), float(payload[0])))
        assert out[0] == (False, 0.0) and out[1][0] is False
        assert out[2] == (True, 7.0)
        assert int(state.retrans) == 1 and int(state.drops) == 1
        assert not bool(state.gave_up[0])

    def test_backoff_grows_and_abandon_marks_self_suspect(self):
        # Every transmission is lost.  timeout=1, base=2, max_retries=1:
        # send at t=0 (window 1), retransmit at t=1 (window doubles to
        # 2), expire again at t=3 -> abandon: gave_up, no further sends.
        cfg = _ack_cfg(delay=0, drop=0.9, timeout=1, base=2.0, retries=1)
        state = comm_lib.AckNetState.init(1, xp=np,
                                          payload_dtype=np.float32)
        sent_log = []
        for t in range(6):
            _, _, sent, state = _ack_step(
                state, cfg, t == 0, 5.0, drop_u=0.0
            )
            sent_log.append(int(sent))
        assert sent_log == [1, 1, 0, 0, 0, 0]
        assert bool(state.gave_up[0])
        assert int(state.retrans) == 1 and int(state.drops) == 2
        assert int(state.awaiting[0]) == -1

    def test_keepalives_fire_on_period_and_reset_last_heard(self):
        # No data traffic at all: the server's keepalive clock fires
        # every ka_period slots, is billed, and resets the balancer's
        # last-heard clock (ka_age) on delivery.
        cfg = _ack_cfg(delay=0, timeout=4, ka=3)
        state = comm_lib.AckNetState.init(1, xp=np,
                                          payload_dtype=np.float32)
        ages, sent_log = [], []
        for _ in range(7):
            _, _, sent, state = _ack_step(state, cfg, False, 5.0)
            ages.append(int(state.ka_age[0]))
            sent_log.append(int(sent))
        assert sent_log == [0, 0, 1, 0, 0, 1, 0]
        assert ages == [1, 2, 0, 1, 2, 0, 1]

    def test_crashed_server_goes_silent_and_window_holds(self):
        # can_send False: no keepalives, no retransmit -- the expired
        # window holds at zero and fires on the first healthy slot.
        cfg = _ack_cfg(delay=0, drop=0.9, timeout=1, base=1.0, retries=8,
                       ka=2)
        state = comm_lib.AckNetState.init(1, xp=np,
                                          payload_dtype=np.float32)
        _, _, s0, state = _ack_step(state, cfg, True, 5.0, drop_u=0.0)
        assert int(s0) == 1  # lost on the wire, window now open
        for _ in range(4):
            _, _, sent, state = _ack_step(
                state, cfg, False, 6.0, can_send=False
            )
            assert int(sent) == 0
        assert int(state.awaiting[0]) == 0  # held, not cycling
        assert int(state.retrans) == 0
        # First healthy slot: the held window fires the retransmit, and
        # the instant lossless round trip closes it.
        delivered, payload, sent, state = _ack_step(
            state, cfg, False, 7.0, drop_u=0.99
        )
        assert bool(delivered[0]) and float(payload[0]) == 7.0
        assert int(state.retrans) == 1

    def test_keepalive_silence_of_crashed_server_raises_ka_age(self):
        cfg = _ack_cfg(delay=0, timeout=4, ka=2)
        state = comm_lib.AckNetState.init(1, xp=np,
                                          payload_dtype=np.float32)
        for _ in range(6):
            _, _, _, state = _ack_step(
                state, cfg, False, 5.0, can_send=False
            )
        assert int(state.ka_age[0]) == 6  # never heard from


# ---------------------------------------------------------------------------
# Eventual delivery: with drop < 1 and unbounded retries, every fired
# trigger lands (hypothesis property when available; seeded sweep else).
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


def _slots_to_delivery(seed, drop, delay, jitter, timeout, horizon=8000):
    """Slots until the single trigger fired at t=0 is delivered (-1)."""
    rng = np.random.default_rng(seed)
    cfg = comm_lib.NetworkConfig(
        kind="net", delay=np.int32(delay), jitter=np.int32(jitter),
        drop=np.float32(drop), transport="ack",
        ack_timeout=np.int32(timeout), backoff_base=np.float32(1.2),
        max_retries=np.int32(10**6),  # effectively unbounded
        ka_period=np.int32(0),
    )
    state = comm_lib.AckNetState.init(1, xp=np, payload_dtype=np.float32)
    for t in range(horizon):
        delivered, _, _, state = comm_lib.net_step_ack(
            state, cfg, np.array([t == 0]), np.full(1, 5.0, np.float32),
            rng.random(1).astype(np.float32),
            rng.random(1).astype(np.float32),
            rng.random((4, 1)).astype(np.float32), xp=np,
        )
        if bool(delivered[0]):
            return t
    return -1


class TestEventualDelivery:
    if _HAVE_HYPOTHESIS:
        @settings(max_examples=40, deadline=None)
        @given(
            seed=st.integers(0, 2**31 - 1),
            drop=st.floats(0.0, 0.6),
            delay=st.integers(0, 4),
            jitter=st.integers(0, 3),
            timeout=st.integers(1, 8),
        )
        def test_trigger_is_eventually_delivered(
            self, seed, drop, delay, jitter, timeout
        ):
            # The geometric tail: ~30 transmissions fit in the horizon at
            # base 1.2, so P(fail) <= 0.6^30 -- negligible by design.
            t = _slots_to_delivery(seed, drop, delay, jitter, timeout)
            assert t >= 0
    else:
        @pytest.mark.parametrize("seed", range(12))
        def test_trigger_is_eventually_delivered(self, seed):
            rng = np.random.default_rng(1000 + seed)
            t = _slots_to_delivery(
                seed,
                drop=float(rng.uniform(0.0, 0.6)),
                delay=int(rng.integers(0, 5)),
                jitter=int(rng.integers(0, 4)),
                timeout=int(rng.integers(1, 9)),
            )
            assert t >= 0

    def test_lossless_wire_delivers_at_base_delay(self):
        assert _slots_to_delivery(0, 0.0, delay=3, jitter=0, timeout=4) == 3


# ---------------------------------------------------------------------------
# snapshot_state / restore_state: scalar counters promote to int64 so
# multi-segment soak aggregation cannot wrap int32.
# ---------------------------------------------------------------------------


class TestSnapshotPromotion:
    def test_counters_promote_and_round_trip(self):
        near = np.iinfo(np.int32).max - 10
        st_np = comm_lib.AckNetState.init(4, xp=np)
        st_np = dataclasses.replace(
            st_np, drops=np.int32(near), retrans=np.int32(near - 5)
        )
        snap = comm_lib.snapshot_state(st_np)
        assert snap.drops.dtype == np.int64
        assert snap.retrans.dtype == np.int64
        # Host-side aggregation across segments happens in int64: the sum
        # exceeds int32 range without wrapping.
        total = int(snap.drops) + int(snap.retrans)
        assert total == 2 * near - 5 > np.iinfo(np.int32).max
        # Per-server arrays keep their carry dtypes (only scalar counters
        # promote), and restore narrows back to the compiled carry's i32.
        assert snap.timer.dtype == np.int32
        back = comm_lib.restore_state(snap, xp=np)
        assert back.drops.dtype == np.int32
        assert int(back.drops) == near

    def test_restore_saturates_instead_of_wrapping(self):
        st_np = comm_lib.NetState.init(2, xp=np)
        snap = comm_lib.snapshot_state(st_np)
        snap = dataclasses.replace(
            snap, drops=np.int64(np.iinfo(np.int32).max) + 1000
        )
        back = comm_lib.restore_state(snap, xp=np)
        assert int(back.drops) == np.iinfo(np.int32).max  # monotone, no wrap


# ---------------------------------------------------------------------------
# Zero-operand identity: defaults cannot move any golden.
# ---------------------------------------------------------------------------


class TestZeroOperandIdentity:
    def test_slotted_net_zero_operands_bit_identical(self):
        base = sim.SimConfig(servers=8, slots=3000, load=0.9,
                             mean_service=10, policy="jsaq", comm="et", x=3)
        key = jax.random.PRNGKey(7)
        r0 = sim.simulate(key, base)
        r1 = sim.simulate(key, dataclasses.replace(base, network="net"))
        r2 = sim.simulate(
            key, dataclasses.replace(base, fault="crash", crash_rate=0.0,
                                     recover_rate=0.0)
        )
        for r in (r1, r2):
            assert np.array_equal(r0.jct, r.jct)
            assert (r0.messages, r0.arrivals, r0.departures) == (
                r.messages, r.arrivals, r.departures)
            assert np.array_equal(r0.final_q, r.final_q)
        assert r1.net_drops == 0

    def test_serving_net_zero_operands_bit_identical(self):
        base = engine.ServeConfig(replicas=6, decode_slots=4, slots=600,
                                  load=0.9, queue_cap=256)
        r0 = engine.serve_one(11, base)
        r1 = engine.serve_one(11, dataclasses.replace(base, network="net"))
        r2 = engine.serve_one(
            11, dataclasses.replace(base, fault="crash"))
        for r in (r1, r2):
            assert np.array_equal(r0.jct_by_rid, r.jct_by_rid)
            assert r0.messages == r.messages
            assert np.array_equal(r0.final_occupancy, r.final_occupancy)
        assert r1.net_drops == 0


# ---------------------------------------------------------------------------
# jax <-> numpy golden matrix (serving tier), degraded cells included.
# ---------------------------------------------------------------------------

_MATRIX = [
    dict(),  # fault-free control
    dict(network="net", net_delay=4),
    dict(network="net", net_delay=2, net_jitter=3, net_drop=0.2),
    dict(network="net", net_delay=4, suspect_age=8, policy="drain",
         decode_rates=(1.0, 0.5, 1.0, 2.0, 1.0, 0.5)),
    dict(network="net", net_delay=4, net_drop=0.1, suspect_age=8,
         policy="sqd", sqd=3),
    dict(fault="crash", crash_rate=0.02, recover_rate=0.2, suspect_age=6),
    dict(fault="slow", crash_rate=0.05, recover_rate=0.2, slow_factor=0.5),
    dict(comm="et_rt", network="net", net_delay=3, net_drop=0.1,
         fault="crash", crash_rate=0.02, recover_rate=0.2, suspect_age=10),
    dict(policy="rr", network="net", net_delay=4),
    dict(comm="rt", network="net", net_delay=1, fault="crash",
         crash_rate=0.01, recover_rate=0.3),
    dict(policy="rr", network="net", net_delay=4, suspect_age=8),
    # Pull family: comm must equal the policy (the token channel).
    dict(policy="jiq", comm="jiq"),
    dict(policy="jiq", comm="jiq", network="net", net_delay=2,
         net_jitter=1, net_drop=0.1, suspect_age=8),
    dict(policy="jiq", comm="jiq", network="net", net_delay=1,
         fault="crash", crash_rate=0.02, recover_rate=0.2, suspect_age=6),
    dict(policy="hsq", comm="hsq", x=3.0),
    dict(policy="hsq", comm="hsq", x=3.0, rt_period=32, network="net",
         net_delay=3, net_drop=0.1, fault="crash", crash_rate=0.02,
         recover_rate=0.2, suspect_age=10),
    # Reliable transport: ack'd sends with timeout/retransmit/backoff,
    # keepalive-driven suspect masking; pull tokens retransmit too.
    dict(network="net", net_delay=2, net_jitter=1, net_drop=0.1,
         transport="ack", ack_timeout=5, backoff_base=2.0, max_retries=4,
         ka_period=16, suspect_age=12),
    dict(comm="et_rt", network="net", net_delay=3, net_drop=0.15,
         transport="ack", ack_timeout=4, backoff_base=1.5, max_retries=2,
         ka_period=8, suspect_age=10, fault="crash", crash_rate=0.02,
         recover_rate=0.2),
    dict(policy="jiq", comm="jiq", network="net", net_delay=2,
         net_drop=0.2, transport="ack", ack_timeout=5, backoff_base=2.0,
         max_retries=6),
    dict(policy="hsq", comm="hsq", x=3.0, rt_period=32, network="net",
         net_delay=1, net_jitter=2, net_drop=0.1, transport="ack",
         ack_timeout=6, backoff_base=1.5, max_retries=3, ka_period=12,
         suspect_age=10, fault="crash", crash_rate=0.02,
         recover_rate=0.2),
]


class TestServingParity:
    @pytest.mark.parametrize("knobs", _MATRIX)
    def test_numpy_matches_jax(self, knobs):
        cell = engine.ServeConfig(replicas=6, decode_slots=4, slots=400,
                                  load=0.9, queue_cap=256, **knobs)
        wl = engine.workload_for(cell, 3)
        ref = engine.run_serving_sim(
            cell.engine_config(), slots=cell.slots, load=cell.load,
            mean_decode=cell.mean_decode, mean_prefill=cell.mean_prefill,
            seed=3, workload=wl,
        )
        res = engine.serve_one(3, cell)
        assert np.array_equal(ref["jct_by_rid"], res.jct_by_rid)
        assert ref["messages"] == res.messages
        assert np.array_equal(ref["final_occupancy"], res.final_occupancy)
        assert ref["net_drops"] == res.net_drops
        assert ref["retrans"] == res.retrans
        assert ref["token_misses"] == res.token_misses
        assert ref["token_sum"] == res.token_sum

    @pytest.mark.slow
    def test_grid_matches_single_runs(self):
        base = engine.ServeConfig(replicas=6, decode_slots=4, slots=400,
                                  load=0.9, queue_cap=256, network="net",
                                  suspect_age=8)
        cells = [
            dataclasses.replace(base, net_delay=d, net_drop=p)
            for d in (1, 8) for p in (0.0, 0.2)
        ]
        res = engine.serve_grid([3, 5], cells[0].static_part(), cells)
        for i, cell in enumerate(cells):
            for j, seed in enumerate((3, 5)):
                one = engine.serve_one(seed, cell)
                assert np.array_equal(res[i][j].jct_by_rid, one.jct_by_rid)
                assert res[i][j].messages == one.messages
                assert res[i][j].net_drops == one.net_drops


class TestStreamDegraded:
    """Segment-engine chunk invariance under the degraded control plane.

    The stream carry threads NetState (in-flight payload buffers, ages)
    and the fault mask across chunk boundaries; any chunking must replay
    the monolithic fixed-horizon run bit for bit on every knob combo of
    the serving parity matrix.
    """

    @pytest.mark.parametrize("knobs", _MATRIX)
    def test_stream_matches_fixed_horizon(self, knobs):
        cell = engine.ServeConfig(replicas=6, decode_slots=4, slots=400,
                                  load=0.9, queue_cap=256, **knobs)
        sampler = engine.StreamSampler(
            3, engine.StreamParams.for_cell(cell)
        )
        wl = sampler.full(cell.slots)
        ref = engine.serve_one(3, cell, workload=wl)
        for chunk in (64, cell.slots):
            s = engine.StreamSampler(
                3, engine.StreamParams.for_cell(cell)
            )
            res = engine.serve_stream(3, cell, chunk=chunk, sampler=s)
            assert res.completed == ref.completed
            assert res.messages == ref.messages
            assert res.net_drops == ref.net_drops
            assert res.retrans == ref.retrans
            assert res.dropped == ref.dropped
            assert res.token_misses == ref.token_misses
            assert res.token_sum == ref.token_sum
            np.testing.assert_array_equal(
                res.final_occupancy, ref.final_occupancy
            )


# ---------------------------------------------------------------------------
# Slotted tier: degraded cells conserve jobs; grid == single run.
# ---------------------------------------------------------------------------

_SLOTTED_CELLS = [
    dict(network="net", net_delay=4),
    dict(network="net", net_delay=2, net_jitter=2, net_drop=0.3),
    dict(policy="sq2", network="net", net_delay=4),
    dict(fault="crash", crash_rate=0.005, recover_rate=0.1, suspect_age=20),
    dict(fault="slow", crash_rate=0.01, recover_rate=0.1, slow_factor=0.5),
    dict(policy="jsq", network="net", net_delay=6, fault="crash",
         crash_rate=0.005, recover_rate=0.1, suspect_age=16),
    # Reliable transport: the ack cells thread AckNetState through the
    # same scan, so grid fusion must preserve them bit for bit too.
    dict(network="net", net_delay=2, net_jitter=1, net_drop=0.2,
         transport="ack", ack_timeout=5, backoff_base=2.0, max_retries=4,
         ka_period=16, suspect_age=24),
    dict(network="net", net_delay=3, net_drop=0.3, transport="ack",
         ack_timeout=4, backoff_base=1.5, max_retries=2, fault="crash",
         crash_rate=0.005, recover_rate=0.1, suspect_age=20),
]


class TestSlottedDegraded:
    @pytest.mark.parametrize("knobs", _SLOTTED_CELLS)
    def test_conservation_and_grid_parity(self, knobs):
        cfg = sim.SimConfig(servers=8, slots=3000, load=0.9,
                            mean_service=10, comm="et", x=3, **knobs)
        r = sim.simulate(jax.random.key(13), cfg)
        assert r.arrivals == r.departures + int(r.final_q.sum())
        rg = sim.simulate_grid(
            [13], cfg.static_part(), [cfg.scenario()]
        )[0][0]
        assert np.array_equal(r.jct, rg.jct)
        assert (r.messages, r.net_drops) == (rg.messages, rg.net_drops)
        assert r.retrans == rg.retrans

    def test_slotted_pull_ack_repairs_tokens(self):
        # A dropped JIQ token retransmits under transport="ack": the
        # retransmit counter moves, and the program stays conservative.
        cfg = sim.SimConfig(servers=8, slots=4000, load=0.9,
                            mean_service=10, policy="jiq", comm="jiq",
                            network="net", net_delay=2, net_drop=0.25,
                            transport="ack", ack_timeout=5,
                            backoff_base=2.0, max_retries=6)
        r = sim.simulate(jax.random.key(13), cfg)
        assert r.retrans > 0
        assert r.arrivals == r.departures + int(r.final_q.sum())


# ---------------------------------------------------------------------------
# Degraded-regime invariants on the numpy reference dispatcher.
# ---------------------------------------------------------------------------


def _engineered_crash_workload(cfg, slots, crash_at, recover_at, target):
    """A workload whose fault stream crashes `target` on an exact window."""
    wl = engine.sample_workload(
        0, replicas=cfg.num_replicas, decode_slots=cfg.decode_slots,
        slots=slots, load=0.9, mean_prefill=2, mean_decode=8,
        with_net=cfg.network != "none", with_fault=True,
    )
    # crash_rate = recover_rate = 0.5; 0.9 never transitions, 0.0 always.
    wl.fault_u[:] = 0.9
    wl.fault_u[crash_at, target] = 0.0
    wl.fault_u[recover_at, target] = 0.0
    return wl


def _replay(cfg, wl, slots, per_route=None, per_slot=None):
    disp = engine.CareDispatcher(cfg, 0)
    finished = []
    offered = 0
    for now in range(slots):
        b = int(wl.base[now])
        for i in range(int(wl.n_arr[now])):
            rid = b + i
            j = disp.route(
                engine.Request(rid=rid, arrival=now,
                               prefill_cost=int(wl.prefill[rid]),
                               decode_len=int(wl.decode[rid])),
                now, u=float(wl.tie_u[rid]), sub_u=wl.sub_u[rid],
            )
            offered += 1
            if per_route is not None:
                per_route(disp, j)
        finished.extend(disp.step(
            now,
            drop_u=None if wl.net_drop_u is None else wl.net_drop_u[now],
            jit_u=None if wl.net_jit_u is None else wl.net_jit_u[now],
            fault_u=None if wl.fault_u is None else wl.fault_u[now],
        ))
        if per_slot is not None:
            per_slot(disp, offered, finished, now)
    return disp, finished, offered


class TestDegradedInvariants:
    def test_conservation_under_crash_recovery(self):
        cfg = engine.EngineConfig(
            num_replicas=6, decode_slots=3, comm="et", et_x=3,
            fault="crash", crash_rate=0.5, recover_rate=0.5,
            suspect_age=8,
        )
        wl = _engineered_crash_workload(cfg, 200, 50, 120, target=2)

        def check(disp, offered, finished, now):
            in_system = int(disp.true_occupancy().sum())
            assert offered == len(finished) + in_system

        _replay(cfg, wl, 200, per_slot=check)

    def test_no_job_routed_to_suspect_dead_server(self):
        # A crashed replica stops sending; once its staleness clock passes
        # suspect_age the balancer must route around it whenever any
        # healthy candidate exists (jsaq considers all replicas, so one
        # always does here).
        cfg = engine.EngineConfig(
            num_replicas=6, decode_slots=3, comm="et", et_x=2,
            fault="crash", crash_rate=0.5, recover_rate=0.5,
            suspect_age=4,
        )
        wl = _engineered_crash_workload(cfg, 200, 40, 160, target=2)
        hits = []

        def per_route(disp, j):
            age = disp.comm.slots_since_msg
            suspect = age > cfg.suspect_age
            if suspect.any() and not suspect.all():
                assert not suspect[j], (
                    f"routed to suspect replica {j} (ages {age})"
                )
            if disp.faulted is not None and disp.faulted[2]:
                hits.append(j)

        _replay(cfg, wl, 200, per_route=per_route)
        # While replica 2 was down and suspect, traffic went elsewhere.
        assert hits and 2 not in hits[cfg.suspect_age + 1:]

    # Every routing policy must honour the suspect mask -- including the
    # fixed rr path (which used to ignore it) and the pull family (whose
    # token pool composes with the mask like any other score).  SQ(d) is
    # the one deliberate exception: an all-suspect sampled subset falls
    # back to the raw sample, so its property is conditioned on the
    # subset containing a healthy candidate.
    _POLICY_SUSPECT = [
        ("jsaq", "et", {}),
        ("drain", "et",
         dict(decode_rates=(1.0, 0.5, 1.0, 2.0, 1.0, 0.5))),
        ("rr", "et", {}),
        ("sqd", "et", dict(sqd=3)),
        ("jiq", "jiq", {}),
        ("hsq", "hsq", dict(rt_period=8)),
    ]

    @pytest.mark.parametrize("policy,comm,extra", _POLICY_SUSPECT)
    def test_no_policy_routes_to_suspect_dead_server(
        self, policy, comm, extra
    ):
        cfg = engine.EngineConfig(
            num_replicas=6, decode_slots=3, comm=comm, et_x=2,
            policy=policy, fault="crash", crash_rate=0.5,
            recover_rate=0.5, suspect_age=4, **extra,
        )
        wl = _engineered_crash_workload(cfg, 200, 40, 160, target=2)
        exercised = []

        def per_route(disp, j):
            suspect = disp.comm.slots_since_msg > cfg.suspect_age
            if suspect.any() and not suspect.all():
                exercised.append(j)
                if cfg.policy == "sqd":
                    sub = disp.last_subset
                    if (sub & ~suspect).any():
                        assert not suspect[j], (
                            f"sqd routed to suspect {j} with healthy "
                            f"candidates in the subset {sub}"
                        )
                else:
                    assert not suspect[j], (
                        f"{cfg.policy} routed to suspect replica {j}"
                    )

        _replay(cfg, wl, 200, per_route=per_route)
        if policy != "jiq":
            # jiq has no keepalive, so windows where *some but not all*
            # replicas look fresh are not guaranteed; every push/RT-backed
            # policy must have exercised the masked path.
            assert exercised

    def test_mid_flight_outage_never_fires_pre_crash_snapshot(self):
        # Engineered outage under the network model: while a replica is
        # down its queued piggyback must stay wiped (no pre-crash
        # snapshot can fire at the next free slot), and conservation
        # holds throughout.
        cfg = engine.EngineConfig(
            num_replicas=6, decode_slots=3, comm="et", et_x=2,
            network="net", net_delay=4, fault="crash", crash_rate=0.5,
            recover_rate=0.5, suspect_age=8,
        )
        wl = _engineered_crash_workload(cfg, 200, 50, 120, target=2)

        def check(disp, offered, finished, now):
            in_system = int(disp.true_occupancy().sum())
            assert offered == len(finished) + in_system
            if disp.faulted is not None and disp.faulted[2]:
                assert not bool(disp.net.pending[2]), (
                    f"slot {now}: crashed replica 2 still queues its "
                    f"pre-crash snapshot"
                )

        _replay(cfg, wl, 200, per_slot=check)

    def test_resync_on_recovery_restores_approximation(self):
        # The recovery slot forces a resync send (RT keepalive retry
        # path): with instant delivery the dispatcher's view of the
        # recovered replica is exact at the end of that very slot --
        # well within one RT keepalive period.
        cfg = engine.EngineConfig(
            num_replicas=6, decode_slots=3, comm="et_rt", et_x=3,
            rt_period=16, fault="crash", crash_rate=0.5, recover_rate=0.5,
        )
        recover_at = 120
        wl = _engineered_crash_workload(cfg, 200, 50, recover_at, target=2)
        errs = {}

        def per_slot(disp, offered, finished, now):
            true = disp.true_occupancy().astype(np.float32)
            errs[now] = abs(float(true[2] - disp.approx[2]))

        _replay(cfg, wl, 200, per_slot=per_slot)
        assert errs[recover_at] == 0.0
        # And the ET bound holds again from the resync slot onwards.
        assert max(errs[t] for t in range(recover_at, 200)) < cfg.et_x


# ---------------------------------------------------------------------------
# Slotted routing layer: the candidate mask is honoured by every policy,
# including the fixed rr and random paths (they used to ignore it).
# ---------------------------------------------------------------------------


class TestRoutingMasks:
    def test_rr_skips_masked_servers_cyclically(self):
        mask = jnp.array([True, False, False, True, True])
        ptr = jnp.int32(1)
        seq = []
        for _ in range(6):
            j, ptr = routing_lib.route_rr(ptr, 5, mask)
            seq.append(int(j))
        assert seq == [3, 4, 0, 3, 4, 0]

    def test_rr_all_true_mask_matches_unmasked(self):
        ptr_m = ptr_u = jnp.int32(0)
        for _ in range(7):
            jm, ptr_m = routing_lib.route_rr(ptr_m, 3, jnp.ones(3, bool))
            ju, ptr_u = routing_lib.route_rr(ptr_u, 3, None)
            assert int(jm) == int(ju)

    def test_rr_all_false_mask_degrades_to_unmasked(self):
        j, ptr = routing_lib.route_rr(jnp.int32(2), 4, jnp.zeros(4, bool))
        assert (int(j), int(ptr)) == (2, 3)

    def test_random_samples_only_eligible(self):
        mask = jnp.array([False, True, False, True, False])
        picks = {
            int(routing_lib.route_random(5, jax.random.key(s), mask))
            for s in range(40)
        }
        assert picks == {1, 3}

    def test_random_all_true_mask_bit_identical_to_unmasked(self):
        for s in range(20):
            key = jax.random.key(s)
            assert int(
                routing_lib.route_random(6, key, jnp.ones(6, bool))
            ) == int(routing_lib.route_random(6, key, None))

    def test_random_all_false_mask_degrades_to_unmasked(self):
        for s in range(10):
            key = jax.random.key(s)
            assert int(
                routing_lib.route_random(4, key, jnp.zeros(4, bool))
            ) == int(routing_lib.route_random(4, key, None))

    def test_route_dispatch_threads_mask_for_every_policy(self):
        q = jnp.array([3, 1, 2, 5], jnp.int32)
        mask = jnp.array([False, False, True, True])
        key = jax.random.key(0)
        tokens = jnp.array([2, 9, 4, 1], jnp.int32)
        for policy in ("jsq", "jsaq", "sq2", "sqd", "rr", "random",
                       "jiq", "hsq"):
            j, _ = routing_lib.route(
                policy, q, q, jnp.int32(0), key, mask=mask, tokens=tokens,
            )
            assert int(j) in (2, 3), policy
        # Pull routing joins the most-token server; the mask excludes the
        # global maximum (server 1), so server 2 wins.
        j, _ = routing_lib.route(
            "jiq", q, q, jnp.int32(0), key, mask=mask, tokens=tokens,
        )
        assert int(j) == 2


# ---------------------------------------------------------------------------
# Validation errors name the offending field; Pallas backends refuse.
# ---------------------------------------------------------------------------


class TestValidation:
    @pytest.mark.parametrize("knobs,field", [
        (dict(network="net", net_drop=-0.1), "net_drop"),
        (dict(network="net", net_drop=1.0), "net_drop"),
        (dict(network="net", net_delay=-1), "net_delay"),
        (dict(network="net", net_jitter=-2), "net_jitter"),
        (dict(fault="crash", crash_rate=0.1, recover_rate=0.0),
         "recover_rate"),
        (dict(fault="crash", crash_rate=1.5, recover_rate=0.5),
         "crash_rate"),
        (dict(fault="slow", crash_rate=0.1, recover_rate=0.1,
              slow_factor=0.0), "slow_factor"),
        (dict(net_delay=3), "net_delay"),  # operand without the kind
        (dict(suspect_age=5), "suspect_age"),
        (dict(network="bogus"), "network"),
        (dict(fault="bogus"), "fault"),
        # Reliable-transport operands: the zero-operand ack cell is not
        # an identity -- it is rejected, naming the field to set.
        (dict(network="net", transport="ack"), "ack_timeout"),
        (dict(transport="ack", ack_timeout=4), "network"),
        (dict(network="net", transport="ack", ack_timeout=4,
              backoff_base=0.5), "backoff_base"),
        (dict(network="net", transport="ack", ack_timeout=4,
              max_retries=-1), "max_retries"),
        (dict(network="net", ack_timeout=3), "ack_timeout"),
        (dict(network="net", ka_period=8), "ka_period"),
        (dict(network="net", transport="bogus"), "transport"),
    ])
    def test_serving_rejects_named_field(self, knobs, field):
        cell = engine.ServeConfig(replicas=4, decode_slots=2, slots=50,
                                  **knobs)
        with pytest.raises(ValueError, match=field):
            cell.static_part()

    @pytest.mark.parametrize("knobs,field", [
        (dict(network="net", net_drop=1.25), "net_drop"),
        (dict(fault="crash", crash_rate=0.2), "recover_rate"),
        (dict(crash_rate=0.2, recover_rate=0.5), "crash_rate"),
        (dict(network="net", transport="ack"), "ack_timeout"),
        (dict(transport="ack", ack_timeout=4), "network"),
        (dict(network="net", ack_timeout=3), "ack_timeout"),
    ])
    def test_slotted_rejects_named_field(self, knobs, field):
        cfg = sim.SimConfig(servers=4, slots=100, **knobs)
        with pytest.raises(ValueError, match=field):
            sim.simulate(jax.random.PRNGKey(0), cfg)

    @pytest.mark.parametrize("knobs,match", [
        (dict(policy="jiq", comm="et"), "requires comm='jiq'"),
        (dict(policy="jiq", comm="exact"), "comm='exact'"),
        (dict(policy="hsq", comm="et_rt"), "requires comm='hsq'"),
        (dict(comm="hsq"), "token channel"),  # default push policy
        (dict(policy="hsq", comm="hsq", rt_period=-4), "token_refresh"),
    ])
    def test_serving_rejects_invalid_pull_pairing(self, knobs, match):
        cell = engine.ServeConfig(replicas=4, decode_slots=2, slots=50,
                                  **knobs)
        with pytest.raises(ValueError, match=match):
            cell.static_part()

    @pytest.mark.parametrize("knobs,match", [
        (dict(policy="jiq", comm="et"), "requires comm='jiq'"),
        (dict(policy="hsq", comm="exact"), "comm='exact'"),
        (dict(comm="jiq"), "token channel"),
        (dict(policy="hsq", comm="hsq", rt_rate=-0.5), "token_refresh"),
    ])
    def test_slotted_rejects_invalid_pull_pairing(self, knobs, match):
        cfg = sim.SimConfig(servers=4, slots=100, **knobs)
        with pytest.raises(ValueError, match=match):
            sim.simulate(jax.random.PRNGKey(0), cfg)

    def test_exact_comm_cannot_compose_with_network(self):
        with pytest.raises(ValueError, match="exact"):
            sim.SimConfig(comm="exact", network="net").static_part()
        with pytest.raises(ValueError, match="exact"):
            engine.ServeConfig(comm="exact", network="net").static_part()

    def test_stale_ring_capacity_guards_query_policies(self):
        cfg = sim.SimConfig(servers=4, slots=100, policy="jsq",
                            network="net", net_delay=40, net_delay_cap=32)
        with pytest.raises(ValueError, match="net_delay_cap"):
            sim.simulate(jax.random.PRNGKey(0), cfg)

    def test_pallas_backends_refuse_degraded_kinds(self):
        slotted = sim.SimConfig(
            servers=8, slots=100, policy="jsq", service="deterministic",
            route_backend="pallas", deterministic_ties=True,
            network="net", net_delay=2,
        )
        with pytest.raises(NotImplementedError, match="network='net'"):
            sim.simulate(jax.random.PRNGKey(0), slotted)
        serving = engine.ServeConfig(
            route_backend="pallas", deterministic_ties=True,
            fault="crash", crash_rate=0.1, recover_rate=0.5,
        )
        with pytest.raises(NotImplementedError, match="fault='crash'"):
            serving.static_part()


# ---------------------------------------------------------------------------
# SQ(d) query round-trips as real counted wire traffic.
# ---------------------------------------------------------------------------


class TestSqdAccounting:
    def test_serving_counts_2d_queries_on_the_wire(self):
        base = engine.ServeConfig(replicas=6, decode_slots=4, slots=400,
                                  load=0.9, queue_cap=256, policy="sqd",
                                  sqd=3, comm="rt", rt_period=64)
        off = engine.serve_one(3, base)
        on = engine.serve_one(
            3, dataclasses.replace(base, network="net"))
        # Same workload stream bytes; the network cell additionally bills
        # 2d messages per routed arrival (the queries themselves).
        assert on.messages == off.messages + 2 * 3 * off.offered

    def test_slotted_exact_state_messages_no_double_count(self):
        cfg = sim.SimConfig(servers=8, slots=2000, load=0.9,
                            mean_service=10, policy="sq2", comm="rt",
                            rt_rate=0.01, network="net", net_delay=2)
        r = sim.simulate(jax.random.PRNGKey(3), cfg)
        # Queries are already inside result.messages; the analytic helper
        # must return them unchanged rather than adding 4 per arrival.
        assert sim.exact_state_messages(
            r, "sq2", network="net") == r.messages
        assert r.messages >= 4 * r.arrivals
