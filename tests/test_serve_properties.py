"""Hypothesis property tests for serving-engine invariants.

The numpy reference engine (:class:`repro.serve.engine.CareDispatcher`) is
stepped slot by slot under randomly drawn configurations and workloads --
the jax engine is bit-identical to it (tests/test_serve_engine.py), so
invariants proved here transfer to the traced path.  Checked:

* **Conservation at every slot**: offered == completed + queued +
  in-flight, after each engine step.
* **JCT floor**: a request occupies a decode slot for one iteration per
  unit of work, so ``jct >= max(prefill + decode, 1)``.
* **Exact-state accounting** (Prop 6.1): under ``exact`` the message count
  equals the completion count at every slot -- in particular messages
  never exceed completions.
* **Post-trigger ET-x error bound** (Prop 6.8 restated for the serving
  tier): at every slot end the occupancy approximation error is < x
  (and <= x-1 when ``msr_drain`` keeps the approximation integral) --
  ET fires the same slot the error reaches x and the message snaps the
  approximation to the truth.
* **Policy-suite invariants** (the routing-policy axis): work
  conservation holds per slot for every policy under heterogeneous
  ``decode_rates``; SQ(d) only ever routes inside its sampled subset
  (which always has exactly d members); drain-time-aware JSAQ replays
  JSAQ's exact trajectory whenever the rates are uniform (the score is
  an argmin-invariant scaling with an identical f32 tie set).
"""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import engine  # noqa: E402

# Hypothesis-heavy: part of the full suite, skipped by the fast tier-1
# gate (pytest -m "not slow").
pytestmark = pytest.mark.slow


@st.composite
def serving_runs(draw, comms=("exact", "et", "dt", "rt", "et_rt")):
    comm = draw(st.sampled_from(comms))
    cfg = engine.EngineConfig(
        num_replicas=draw(st.integers(1, 6)),
        decode_slots=draw(st.integers(1, 4)),
        comm=comm,
        et_x=draw(st.integers(1, 6)),
        dt_x=draw(st.integers(1, 6)),
        rt_period=draw(st.integers(1, 24)),
        msr_drain=draw(st.sampled_from([1.0, 0.5, 2.0])),
    )
    slots = draw(st.integers(30, 120))
    load = draw(st.floats(0.3, 1.4))
    seed = draw(st.integers(0, 2**31 - 1))
    return cfg, slots, load, seed


def _replay(cfg, slots, load, seed, per_slot_check, per_route_check=None):
    """Drive the dispatcher slot by slot, calling the invariant hooks."""
    rate_scale = engine.mean_decode_rate(cfg.decode_rates)
    wl = engine.sample_workload(
        seed, replicas=cfg.num_replicas, decode_slots=cfg.decode_slots,
        slots=slots, load=load, mean_prefill=2, mean_decode=6,
        rate_scale=rate_scale,
    )
    disp = engine.CareDispatcher(cfg, seed)
    finished = []
    offered = 0
    for now in range(slots):
        b = int(wl.base[now])
        for i in range(int(wl.n_arr[now])):
            rid = b + i
            j = disp.route(
                engine.Request(
                    rid=rid, arrival=now,
                    prefill_cost=int(wl.prefill[rid]),
                    decode_len=int(wl.decode[rid]),
                ),
                now, u=float(wl.tie_u[rid]), sub_u=wl.sub_u[rid],
            )
            offered += 1
            if per_route_check is not None:
                per_route_check(disp, j)
        finished.extend(disp.step(now))
        per_slot_check(disp, offered, finished, now)
    return disp, wl, finished


class TestConservation:
    @settings(max_examples=25, deadline=None)
    @given(serving_runs())
    def test_offered_equals_completed_plus_in_system(self, run):
        cfg, slots, load, seed = run

        def check(disp, offered, finished, now):
            in_system = int(disp.true_occupancy().sum())
            assert offered == len(finished) + in_system

        _replay(cfg, slots, load, seed, check)


class TestJctFloor:
    @settings(max_examples=25, deadline=None)
    @given(serving_runs())
    def test_jct_at_least_prefill_plus_decode(self, run):
        cfg, slots, load, seed = run
        _, _, finished = _replay(cfg, slots, load, seed,
                                 lambda *a: None)
        for req in finished:
            jct = req.finished - req.arrival + 1
            assert jct >= max(req.prefill_cost + req.decode_len, 1)
            assert req.started >= req.arrival


class TestExactAccounting:
    @settings(max_examples=25, deadline=None)
    @given(serving_runs(comms=("exact",)))
    def test_messages_track_completions(self, run):
        cfg, slots, load, seed = run

        def check(disp, offered, finished, now):
            # Prop 6.1: one message per departure -- never more messages
            # than completions, and exactly one each.
            assert disp.messages <= disp.total_completions
            assert disp.messages == disp.total_completions

        _replay(cfg, slots, load, seed, check)


@st.composite
def policy_runs(draw):
    """Runs across the routing-policy suite, optionally rate-asymmetric."""
    r = draw(st.integers(2, 6))
    rates = draw(
        st.one_of(
            st.none(),
            st.lists(
                st.sampled_from([0.5, 1.0, 1.5, 2.0]),
                min_size=r, max_size=r,
            ).map(tuple),
        )
    )
    cfg = engine.EngineConfig(
        num_replicas=r,
        decode_slots=draw(st.integers(1, 4)),
        comm=draw(st.sampled_from(["exact", "et", "dt", "rt"])),
        et_x=draw(st.integers(1, 6)),
        dt_x=draw(st.integers(1, 6)),
        rt_period=draw(st.integers(1, 24)),
        msr_drain=draw(st.sampled_from([1.0, 0.5, 0.25])),
        policy=draw(st.sampled_from(["jsaq", "sqd", "rr", "drain"])),
        sqd=draw(st.integers(1, r)),
        decode_rates=rates,
        mean_prefill=2.0,
        mean_decode=6.0,
    )
    slots = draw(st.integers(30, 120))
    load = draw(st.floats(0.3, 1.4))
    seed = draw(st.integers(0, 2**31 - 1))
    return cfg, slots, load, seed


class TestPolicyConservation:
    @settings(max_examples=30, deadline=None)
    @given(policy_runs())
    def test_conservation_under_any_policy_and_rates(self, run):
        # Work conservation is policy- and rate-independent: every offered
        # request is completed, queued, or in a decode slot -- in
        # particular the heterogeneous credit schedule never loses or
        # double-counts a request.
        cfg, slots, load, seed = run

        def check(disp, offered, finished, now):
            in_system = int(disp.true_occupancy().sum())
            assert offered == len(finished) + in_system

        _replay(cfg, slots, load, seed, check)


class TestSqdSubset:
    @settings(max_examples=25, deadline=None)
    @given(policy_runs())
    def test_routes_only_inside_sampled_subset(self, run):
        cfg, slots, load, seed = run
        cfg = dataclasses.replace(cfg, policy="sqd")

        def on_route(disp, j):
            assert disp.last_subset is not None
            assert int(disp.last_subset.sum()) == cfg.sqd
            assert disp.last_subset[j]

        _replay(cfg, slots, load, seed, lambda *a: None,
                per_route_check=on_route)


class TestDrainReducesToJsaq:
    @settings(max_examples=25, deadline=None)
    @given(policy_runs(), st.sampled_from([0.5, 1.0, 2.0]))
    def test_uniform_rates_replay_jsaq_exactly(self, run, rate):
        # Scaling every queue length by the same positive E[S]/r is
        # argmin-invariant with an identical f32 tie set, so the drain
        # policy must replay JSAQ's trajectory message for message.
        cfg, slots, load, seed = run
        uniform = (rate,) * cfg.num_replicas
        runs = {}
        for policy in ("drain", "jsaq"):
            cfg_p = dataclasses.replace(
                cfg, policy=policy, decode_rates=uniform
            )
            disp, _, finished = _replay(cfg_p, slots, load, seed,
                                        lambda *a: None)
            runs[policy] = (
                disp.messages,
                disp.total_completions,
                sorted((f.rid, f.finished) for f in finished),
                disp.true_occupancy().tolist(),
            )
        assert runs["drain"] == runs["jsaq"]


class TestEtErrorBound:
    @settings(max_examples=25, deadline=None)
    @given(serving_runs(comms=("et", "et_rt")))
    def test_post_trigger_error_below_x(self, run):
        cfg, slots, load, seed = run
        x = cfg.et_x
        integral = float(cfg.msr_drain).is_integer()

        def check(disp, offered, finished, now):
            err = np.abs(disp.true_occupancy() - disp.approx)
            # ET fires the slot the error reaches x and snaps to truth, so
            # the end-of-slot error stays strictly below x...
            assert float(err.max()) < x
            # ...and below x-1 whenever the approximation stays integral
            # (the discrete analogue of AQ <= x-1, Prop 6.8).
            if integral:
                assert float(err.max()) <= x - 1

        _replay(cfg, slots, load, seed, check)
