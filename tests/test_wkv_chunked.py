"""Chunked-parallel WKV6 must match the sequential recurrence exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _inputs(key, b, s, h, n, decay_scale=1.0):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, s, h, n), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, n), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, n), jnp.float32)
    # log-decay <= 0 with realistic spread: lw = -exp(decay).
    decay = decay_scale * jax.random.normal(ks[3], (b, s, h, n), jnp.float32)
    lw = -jnp.exp(decay)
    u = 0.5 * jax.random.normal(ks[4], (h, n), jnp.float32)
    s0 = jax.random.normal(ks[5], (b, h, n, n), jnp.float32)
    return r, k, v, lw, u, s0


@pytest.mark.parametrize("s,chunk", [(64, 32), (128, 32), (96, 16), (64, 64)])
def test_chunked_matches_scan(s, chunk):
    r, k, v, lw, u, s0 = _inputs(jax.random.key(0), 2, s, 3, 8)
    out_seq, st_seq = ssm._wkv6_scan(r, k, v, jnp.exp(lw), u, s0)
    out_ch, st_ch = ssm._wkv6_chunked(r, k, v, lw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out_ch), np.asarray(out_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_ch), np.asarray(st_seq),
                               rtol=2e-4, atol=2e-4)


def test_chunked_matches_scan_strong_decay():
    """Large decays (w ~ 0) must stay finite and accurate.

    Without the lw >= -30 clamp the in-chunk cumsum differences cancel
    catastrophically in f32 (0.07 max error vs a float64 sequential
    reference); with it the chunked form is within 3e-4 of float64.
    """
    r, k, v, lw, u, s0 = _inputs(jax.random.key(1), 1, 64, 2, 8,
                                 decay_scale=3.0)
    out_seq, st_seq = ssm._wkv6_scan(r, k, v, jnp.exp(lw), u, s0)
    out_ch, st_ch = ssm._wkv6_chunked(r, k, v, lw, u, s0, chunk=32)
    assert np.isfinite(np.asarray(out_ch)).all()
    np.testing.assert_allclose(np.asarray(out_ch), np.asarray(out_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_ch), np.asarray(st_seq),
                               rtol=2e-3, atol=2e-3)


def test_chunked_grads_finite():
    r, k, v, lw, u, s0 = _inputs(jax.random.key(2), 1, 64, 2, 8)

    def loss(args):
        r, k, v, lw = args
        out, st = ssm._wkv6_chunked(r, k, v, lw, u, s0, chunk=32)
        return jnp.sum(out**2) + jnp.sum(st**2)

    g = jax.grad(loss)((r, k, v, lw))
    for a in g:
        assert np.isfinite(np.asarray(a)).all()


def test_time_mix_dispatches_to_chunked():
    """rwkv_time_mix output is invariant to the scan/chunked dispatch."""
    from repro.configs import get_config
    from repro.models import common

    cfg = get_config("rwkv6-1.6b").reduced()
    kg = common.KeyGen(jax.random.key(0))
    p = ssm.init_rwkv_time_mix(kg, cfg)
    b, s, d = 2, ssm.WKV_CHUNK * 2, cfg.d_model  # divisible -> chunked
    x = 0.1 * jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32)
    out_c, st_c, _ = ssm.rwkv_time_mix(p, x, cfg)
    # odd length -> falls back to the sequential scan
    x2 = jnp.concatenate([x, x[:, :1]], axis=1)
    out_s, st_s, _ = ssm.rwkv_time_mix(p, x2, cfg)
    np.testing.assert_allclose(
        np.asarray(out_c), np.asarray(out_s[:, : s]), rtol=2e-3, atol=2e-3
    )
