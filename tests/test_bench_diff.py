"""Unit tests for the mechanical trajectory differ (benchmarks/diff.py)."""
from benchmarks.diff import diff_records


def _row(name, **kv):
    return {"name": name, "derived": "ignored", **kv}


class TestDiffRecords:
    def test_clean(self):
        base = [_row("a", rel_comm=0.1, ok=True, us_per_call=5.0)]
        new = [_row("a", rel_comm=0.1005, ok=True, us_per_call=50.0)]
        regs, notes = diff_records(base, new)
        assert regs == []

    def test_metric_regression(self):
        base = [_row("a", rel_comm=0.10)]
        new = [_row("a", rel_comm=0.15)]
        regs, _ = diff_records(base, new)
        assert len(regs) == 1 and "rel_comm" in regs[0]

    def test_flag_regression_one_sided(self):
        base = [_row("a", ok=True), _row("b", ok=False)]
        new = [_row("a", ok=False), _row("b", ok=True)]
        regs, _ = diff_records(base, new)
        assert len(regs) == 1 and regs[0].startswith("a.ok")

    def test_missing_row(self):
        base = [_row("a", v=1.0), _row("b", v=1.0)]
        new = [_row("a", v=1.0)]
        regs, _ = diff_records(base, new)
        assert any("disappeared" in r for r in regs)
        regs, notes = diff_records(base, new, allow_missing=True)
        assert regs == [] and any("disappeared" in n for n in notes)

    def test_new_row_is_note(self):
        base = [_row("a", v=1.0)]
        new = [_row("a", v=1.0), _row("c", v=9.9)]
        regs, notes = diff_records(base, new)
        assert regs == [] and any("new row" in n for n in notes)

    def test_perf_fields_skipped_by_default(self):
        base = [_row("a", us_per_call=1.0, speedup=4.0, t_grid_s=1.0)]
        new = [_row("a", us_per_call=99.0, speedup=0.5, t_grid_s=9.0)]
        regs, _ = diff_records(base, new)
        assert regs == []

    def test_suffix_speedup_is_a_perf_field(self):
        # Derived wall-clock ratios (stream tier's ``overlap_speedup``) are
        # machine-dependent: skipped by default, one-sided (lower is worse)
        # under --perf-rtol -- matching _perf_regressed's suffix rule.
        base = [_row("a", overlap_speedup=1.42)]
        new = [_row("a", overlap_speedup=1.51)]
        regs, _ = diff_records(base, new)
        assert regs == []
        regs, _ = diff_records(base, new, perf_rtol=0.25)
        assert regs == []  # an improvement never fails
        new = [_row("a", overlap_speedup=0.9)]
        regs, _ = diff_records(base, new, perf_rtol=0.25)
        assert len(regs) == 1 and "overlap_speedup" in regs[0]

    def test_perf_one_sided_when_enabled(self):
        base = [_row("a", us_per_call=1.0, speedup=4.0)]
        # Faster + higher speedup: improvements never fail.
        new = [_row("a", us_per_call=0.5, speedup=8.0)]
        regs, _ = diff_records(base, new, perf_rtol=0.25)
        assert regs == []
        new = [_row("a", us_per_call=2.0, speedup=1.0)]
        regs, _ = diff_records(base, new, perf_rtol=0.25)
        assert len(regs) == 2

    def test_nan_is_a_regression_not_a_pass(self):
        base = [_row("a", mean_jct=80.3)]
        new = [_row("a", mean_jct=float("nan"))]
        regs, _ = diff_records(base, new)
        assert len(regs) == 1 and "NaN" in regs[0]
        # NaN on both sides compares equal (a knowingly-NaN metric).
        base = [_row("a", mean_jct=float("nan"))]
        regs, _ = diff_records(base, new)
        assert regs == []

    def test_dropped_metric_field_is_a_regression(self):
        base = [_row("a", rel_comm=0.1, mean_jct=80.0)]
        new = [_row("a", mean_jct=80.0)]
        regs, _ = diff_records(base, new)
        assert len(regs) == 1 and "field disappeared" in regs[0]
        # ... but a skipped perf field may vanish freely.
        base = [_row("a", mean_jct=80.0, us_per_call=5.0)]
        new = [_row("a", mean_jct=80.0)]
        regs, _ = diff_records(base, new)
        assert regs == []

    def test_int_fields_exact_within_tolerance(self):
        base = [_row("a", max_aq=2)]
        new = [_row("a", max_aq=3)]
        regs, _ = diff_records(base, new)
        assert len(regs) == 1
