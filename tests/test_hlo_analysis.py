"""Unit tests for the nesting-aware HLO roofline analysis.

The synthetic HLO snippets below pin down the accounting rules the
roofline depends on: dot FLOPs, while-trip multiplication, collective
bucketing, and -- critically -- the slicing-aware HBM charging (a scanned
dynamic-slice must NOT be charged the full stacked buffer per trip).
"""
import textwrap

from repro.launch import hlo_analysis


def _mod(body: str) -> str:
    return textwrap.dedent(body)


class TestDotFlops:
    def test_simple_dot(self):
        hlo = _mod("""
        ENTRY %main (a: f32[128,256], b: f32[256,512]) -> f32[128,512] {
          %a = f32[128,256] parameter(0)
          %b = f32[256,512] parameter(1)
          ROOT %d = f32[128,512] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        assert res["flops"] == 2 * 128 * 512 * 256


class TestWhileTrips:
    def test_known_trip_count_multiplies(self):
        hlo = _mod("""
        %body (p: (f32[64,64], f32[64,64])) -> (f32[64,64], f32[64,64]) {
          %p = (f32[64,64], f32[64,64]) parameter(0)
          %x = f32[64,64] get-tuple-element(%p), index=0
          %y = f32[64,64] get-tuple-element(%p), index=1
          %d = f32[64,64] dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          ROOT %t = (f32[64,64], f32[64,64]) tuple(%d, %y)
        }
        %cond (p: (f32[64,64], f32[64,64])) -> pred[] {
          %p = (f32[64,64], f32[64,64]) parameter(0)
          ROOT %c = pred[] constant(false)
        }
        ENTRY %main (a: f32[64,64], b: f32[64,64]) -> (f32[64,64], f32[64,64]) {
          %a = f32[64,64] parameter(0)
          %b = f32[64,64] parameter(1)
          %t0 = (f32[64,64], f32[64,64]) tuple(%a, %b)
          ROOT %w = (f32[64,64], f32[64,64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        assert res["flops"] == 12 * 2 * 64 * 64 * 64

    def test_unknown_trip_uses_caller_hint(self):
        hlo = _mod("""
        %body (p: (f32[32,32], f32[32,32])) -> (f32[32,32], f32[32,32]) {
          %p = (f32[32,32], f32[32,32]) parameter(0)
          %x = f32[32,32] get-tuple-element(%p), index=0
          %y = f32[32,32] get-tuple-element(%p), index=1
          %d = f32[32,32] dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          ROOT %t = (f32[32,32], f32[32,32]) tuple(%d, %y)
        }
        %cond (p: (f32[32,32], f32[32,32])) -> pred[] {
          %p = (f32[32,32], f32[32,32]) parameter(0)
          ROOT %c = pred[] constant(false)
        }
        ENTRY %main (a: f32[32,32], b: f32[32,32]) -> (f32[32,32], f32[32,32]) {
          %a = f32[32,32] parameter(0)
          %b = f32[32,32] parameter(1)
          %t0 = (f32[32,32], f32[32,32]) tuple(%a, %b)
          ROOT %w = (f32[32,32], f32[32,32]) while(%t0), condition=%cond, body=%body
        }
        """)
        res = hlo_analysis.analyze_module(hlo, scan_trips=[7])
        assert res["flops"] == 7 * 2 * 32 * 32 * 32


class TestCollectives:
    def test_all_reduce_bytes(self):
        hlo = _mod("""
        %add (x: f32[], y: f32[]) -> f32[] {
          %x = f32[] parameter(0)
          %y = f32[] parameter(1)
          ROOT %s = f32[] add(%x, %y)
        }
        ENTRY %main (a: f32[1024]) -> f32[1024] {
          %a = f32[1024] parameter(0)
          ROOT %ar = f32[1024] all-reduce(%a), to_apply=%add
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        assert res["collectives"]["all-reduce"] == 1024 * 4
        assert res["collectives"]["total"] == 1024 * 4


class TestSlicingAwareBytes:
    def test_scanned_dynamic_slice_charges_slice_not_buffer(self):
        """A fusion that only dynamic-slices its big param must be charged
        the slice size, even when the while body runs many trips."""
        hlo = _mod("""
        %fused_slice (p0: f32[4096,128], p1: s32[]) -> f32[1,128] {
          %p0 = f32[4096,128] parameter(0)
          %p1 = s32[] parameter(1)
          %z = s32[] constant(0)
          ROOT %ds = f32[1,128] dynamic-slice(%p0, %p1, %z), dynamic_slice_sizes={1,128}
        }
        %body (p: (f32[4096,128], s32[])) -> (f32[4096,128], s32[]) {
          %p = (f32[4096,128], s32[]) parameter(0)
          %buf = f32[4096,128] get-tuple-element(%p), index=0
          %i = s32[] get-tuple-element(%p), index=1
          %f = f32[1,128] fusion(%buf, %i), kind=kLoop, calls=%fused_slice
          ROOT %t = (f32[4096,128], s32[]) tuple(%buf, %i)
        }
        %cond (p: (f32[4096,128], s32[])) -> pred[] {
          %p = (f32[4096,128], s32[]) parameter(0)
          ROOT %c = pred[] constant(false)
        }
        ENTRY %main (a: f32[4096,128]) -> (f32[4096,128], s32[]) {
          %a = f32[4096,128] parameter(0)
          %i0 = s32[] constant(0)
          %t0 = (f32[4096,128], s32[]) tuple(%a, %i0)
          ROOT %w = (f32[4096,128], s32[]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4096"}}
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        # per trip: read slice + write result (2 * 1*128*4) + 4 B index.
        assert res["bytes_hbm"] == 4096 * (128 * 4 * 2 + 4)
        # The raw metric keeps the conservative full-buffer accounting.
        assert res["bytes"] > res["bytes_hbm"] * 100

    def test_inplace_dus_root_charges_update(self):
        hlo = _mod("""
        %fused_dus (p0: f32[4096,128], p1: f32[1,128], p2: s32[]) -> f32[4096,128] {
          %p0 = f32[4096,128] parameter(0)
          %p1 = f32[1,128] parameter(1)
          %p2 = s32[] parameter(2)
          %z = s32[] constant(0)
          ROOT %dus = f32[4096,128] dynamic-update-slice(%p0, %p1, %p2, %z)
        }
        ENTRY %main (a: f32[4096,128], u: f32[1,128], i: s32[]) -> f32[4096,128] {
          %a = f32[4096,128] parameter(0)
          %u = f32[1,128] parameter(1)
          %i = s32[] parameter(2)
          ROOT %f = f32[4096,128] fusion(%a, %u, %i), kind=kLoop, calls=%fused_dus
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        # read update + write update region (+ the 4-byte index param).
        assert res["bytes_hbm"] == 2 * 128 * 4 + 4

    def test_plain_fusion_charges_params_and_result(self):
        hlo = _mod("""
        %fused_add (p0: f32[256,256], p1: f32[256,256]) -> f32[256,256] {
          %p0 = f32[256,256] parameter(0)
          %p1 = f32[256,256] parameter(1)
          ROOT %s = f32[256,256] add(%p0, %p1)
        }
        ENTRY %main (a: f32[256,256], b: f32[256,256]) -> f32[256,256] {
          %a = f32[256,256] parameter(0)
          %b = f32[256,256] parameter(1)
          ROOT %f = f32[256,256] fusion(%a, %b), kind=kLoop, calls=%fused_add
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        assert res["bytes_hbm"] == 3 * 256 * 256 * 4

    def test_top_level_gather_charges_result(self):
        hlo = _mod("""
        ENTRY %main (t: f32[50000,512], i: s32[64,1]) -> f32[64,512] {
          %t = f32[50000,512] parameter(0)
          %i = s32[64,1] parameter(1)
          ROOT %g = f32[64,512] gather(%t, %i), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,512}
        }
        """)
        res = hlo_analysis.analyze_module(hlo)
        assert res["bytes_hbm"] == 2 * 64 * 512 * 4
