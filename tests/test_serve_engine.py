"""Serving-tier engine tests: goldens, jax-vs-numpy bit-identity, grid fusion.

Three layers of evidence that the jax port of the serving engine did not
change the physics:

* **Golden regression** -- fingerprints of the numpy reference engine on a
  fixed seed (JCT vector head/sum, message counts, per-replica occupancy at
  checkpoint slots) pinned for every comm kind.  Captured at the PR that
  split the workload/tie-break RNG streams (``SeedSequence.spawn``); any
  change to the stream keying or the slot semantics moves them.
* **Backend equivalence** -- the jitted ``lax.scan`` engine must reproduce
  the numpy ``CareDispatcher`` *bit for bit* on a shared pre-sampled
  workload: JCT vector (rid order), message totals, end-of-slot occupancy
  trace, final occupancy -- for every comm kind, including fractional
  (dyadic) ``msr_drain``.
* **Grid equivalence** -- ``serve_grid`` (one compiled program, vmap over
  cell x seed, shard_map padding, padded horizon + arrival lanes) must
  reproduce per-cell ``serve_one`` runs exactly; padding is
  semantics-preserving by construction and asserted here.
"""
import dataclasses

import numpy as np
import pytest

from repro.serve import engine

KINDS = ["exact", "et", "dt", "rt", "et_rt"]


def small_cell(comm: str, **kw) -> engine.ServeConfig:
    base = dict(
        replicas=8, decode_slots=4, slots=2000, load=0.9, comm=comm, x=3,
        rt_period=32, mean_prefill=2, mean_decode=16, queue_cap=256,
    )
    base.update(kw)
    return engine.ServeConfig(**base)


def run_reference(cell: engine.ServeConfig, seed: int, **kw) -> dict:
    """numpy reference run on the cell's (memoised) shared workload."""
    return engine.run_serving_sim(
        cell.engine_config(), slots=cell.slots, load=cell.load,
        mean_prefill=cell.mean_prefill, mean_decode=cell.mean_decode,
        seed=seed, workload=engine.workload_for(cell, seed), **kw,
    )


# Fingerprints of the numpy engine at seed 7 on small_cell(comm):
# (offered, completed, messages, jct_sum, jct[:8],
#  occupancy@600, occupancy@1999).
GOLDEN = {
    "exact": (
        3247, 3168, 3168, 108767,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [5, 5, 5, 5, 5, 5, 4, 5],
        [11, 9, 10, 10, 9, 10, 10, 10],
    ),
    "et": (
        3247, 3166, 4245, 112641,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [5, 5, 5, 5, 5, 3, 6, 5],
        [9, 9, 10, 10, 10, 11, 11, 11],
    ),
    "dt": (
        3247, 3158, 1024, 129408,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [7, 7, 7, 5, 7, 5, 6, 6],
        [13, 12, 10, 8, 11, 15, 10, 10],
    ),
    "rt": (
        3247, 3156, 496, 128238,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [6, 7, 3, 7, 4, 8, 5, 8],
        [13, 11, 11, 10, 11, 12, 11, 12],
    ),
    "et_rt": (
        3247, 3166, 4245, 112641,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [5, 5, 5, 5, 5, 3, 6, 5],
        [9, 9, 10, 10, 10, 11, 11, 11],
    ),
}


class TestNumpyGolden:
    @pytest.mark.parametrize("comm", KINDS)
    def test_reference_engine_fingerprint(self, comm):
        out = run_reference(small_cell(comm), 7, checkpoints=(600, 1999))
        offered, completed, msgs, jct_sum, jct_head, occ600, occ1999 = GOLDEN[
            comm
        ]
        assert out["offered"] == offered
        assert out["completed"] == completed
        assert out["messages"] == msgs
        assert int(out["jct"].sum()) == jct_sum
        assert out["jct"][:8].tolist() == jct_head
        assert out["occupancy"][600].tolist() == occ600
        assert out["occupancy"][1999].tolist() == occ1999

    def test_workload_streams_are_split(self):
        """Workload and tie-break streams are independent SeedSequence
        children -- not the correlated ``default_rng(seed)`` pair the old
        engine used for both."""
        wl = engine.workload_for(small_cell("et"), 7)
        legacy = np.random.default_rng(7)
        legacy_n_arr = legacy.poisson(small_cell("et").arrival_rate(),
                                      size=2000)
        assert not np.array_equal(wl.n_arr, legacy_n_arr)
        # Same seed, same parameters -> same stream (memoised or not).
        wl2 = engine.sample_workload(
            7, replicas=8, decode_slots=4, slots=2000, load=0.9,
            mean_prefill=2, mean_decode=16,
        )
        np.testing.assert_array_equal(wl.work, wl2.work)
        np.testing.assert_array_equal(wl.tie_u, wl2.tie_u)

    def test_workload_shared_across_comm_kinds(self):
        """Cells differing only in trigger parameters replay one stream --
        the paper's comparison method."""
        assert small_cell("et").workload_key() == small_cell(
            "exact"
        ).workload_key()
        wa = engine.workload_for(small_cell("et"), 3)
        wb = engine.workload_for(small_cell("exact", x=7.0), 3)
        np.testing.assert_array_equal(wa.n_arr, wb.n_arr)


class TestBackendEquivalence:
    @pytest.mark.parametrize("comm", KINDS)
    def test_jax_matches_numpy_bitwise(self, comm):
        cell = small_cell(comm)
        ref = run_reference(cell, 7, checkpoints=(600, 1999))
        res = engine.serve_one(7, cell, trace_occupancy=True)
        assert res.messages == ref["messages"]
        assert res.completed == ref["completed"]
        assert res.offered == ref["offered"]
        assert res.dropped == 0
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])
        np.testing.assert_array_equal(res.jct, ref["jct"])
        np.testing.assert_array_equal(
            res.final_occupancy, ref["final_occupancy"]
        )
        for slot, occ in ref["occupancy"].items():
            np.testing.assert_array_equal(res.occupancy[slot], occ)

    def test_fractional_dyadic_drain_still_bitwise(self):
        # msr_drain=0.25 keeps the f32 approximation on dyadic values, so
        # the traced engine still cannot round differently from the f64
        # reference.
        cell = small_cell("et", msr_drain=0.25, x=4)
        ref = run_reference(cell, 5)
        res = engine.serve_one(5, cell)
        assert res.messages == ref["messages"]
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])

    def test_full_ring_drops_and_conserves(self):
        # The traced ring is fixed-capacity: overload must drop (counted)
        # and conservation holds over admitted requests.
        cell = engine.ServeConfig(
            replicas=2, decode_slots=1, slots=400, load=3.0, comm="et",
            x=2, mean_prefill=2, mean_decode=16, queue_cap=8,
        )
        res = engine.serve_one(0, cell)
        assert res.dropped > 0
        admitted = res.offered - res.dropped
        assert admitted == res.completed + int(res.final_occupancy.sum())


class TestGridEquivalence:
    def test_grid_matches_single_runs(self):
        # An ET-x ladder plus a shorter-horizon cell: one compiled program
        # (x and horizon are traced operands), results must equal the
        # per-cell serve_one references bit for bit even though the grid
        # pads both the horizon and the arrival lanes differently.
        cells = [
            small_cell("et", x=2, slots=1500),
            small_cell("et", x=4, slots=1500),
            small_cell("et", x=8, slots=1500),
            small_cell("et", x=4, slots=1000, max_slots=1500),
        ]
        static = dataclasses.replace(cells[0].static_part(), slots=1500)
        seeds = [0, 1]
        grid = engine.serve_grid(seeds, static, cells)
        for cell, row in zip(cells, grid):
            for seed, got in zip(seeds, row):
                ref = engine.serve_one(seed, cell)
                assert got.messages == ref.messages
                assert got.completed == ref.completed
                np.testing.assert_array_equal(got.jct_by_rid, ref.jct_by_rid)
                np.testing.assert_array_equal(
                    got.final_occupancy, ref.final_occupancy
                )

    def test_grid_unsharded_matches_sharded(self):
        cells = [small_cell("dt", x=2, slots=800),
                 small_cell("dt", x=5, slots=800)]
        static = cells[0].static_part()
        a = engine.serve_grid([0, 1, 2], static, cells, shard=True)
        b = engine.serve_grid([0, 1, 2], static, cells, shard=False)
        for ra, rb in zip(a, b):
            for xa, xb in zip(ra, rb):
                assert xa.messages == xb.messages
                np.testing.assert_array_equal(xa.jct_by_rid, xb.jct_by_rid)

    def test_grid_rejects_mismatched_static(self):
        cells = [small_cell("et")]
        static = dataclasses.replace(cells[0].static_part(), comm="dt")
        with pytest.raises(ValueError, match="does not match"):
            engine.serve_grid([0], static, cells)

    def test_grid_rejects_oversized_cell(self):
        cells = [small_cell("et", slots=4000)]
        static = dataclasses.replace(cells[0].static_part(), slots=2000)
        with pytest.raises(ValueError, match="exceeds"):
            engine.serve_grid([0], static, cells)


class TestPickMinTied:
    def test_matches_reference_enumeration(self):
        rng = np.random.default_rng(0)
        for _ in range(200):
            occ = rng.integers(0, 4, size=rng.integers(1, 12)).astype(float)
            u = np.float32(rng.random())
            ties = np.flatnonzero(occ == occ.min())
            j = engine.pick_min_tied(occ, u)
            assert j in ties
            # Rank formula: the float32 product picks floor(u * n) capped.
            rank = min(int(np.float32(u) * np.float32(len(ties))),
                       len(ties) - 1)
            assert j == ties[rank]

    def test_uniform_over_ties(self):
        occ = np.array([1.0, 0.0, 0.0, 0.0])
        counts = np.zeros(4, int)
        for u in np.linspace(0, 0.999, 999, dtype=np.float32):
            counts[engine.pick_min_tied(occ, u)] += 1
        assert counts[0] == 0
        assert counts[1:].min() > 300  # ~333 each over the tie set
