"""Serving-tier engine tests: goldens, jax-vs-numpy bit-identity, grid fusion.

Three layers of evidence that the jax port of the serving engine did not
change the physics:

* **Golden regression** -- fingerprints of the numpy reference engine on a
  fixed seed (JCT vector head/sum, message counts, per-replica occupancy at
  checkpoint slots) pinned for every comm kind.  Captured at the PR that
  split the workload/tie-break RNG streams (``SeedSequence.spawn``); any
  change to the stream keying or the slot semantics moves them.
* **Backend equivalence** -- the jitted ``lax.scan`` engine must reproduce
  the numpy ``CareDispatcher`` *bit for bit* on a shared pre-sampled
  workload: JCT vector (rid order), message totals, end-of-slot occupancy
  trace, final occupancy -- for every comm kind, including fractional
  (dyadic) ``msr_drain``.
* **Grid equivalence** -- ``serve_grid`` (one compiled program, vmap over
  cell x seed, shard_map padding, padded horizon + arrival lanes) must
  reproduce per-cell ``serve_one`` runs exactly; padding is
  semantics-preserving by construction and asserted here.
* **Policy x comm matrix** -- every (policy in {jsaq, sqd, rr, drain}) x
  (comm in {exact, et, dt, rt}) cell has a numpy golden and a
  jax-vs-numpy bit-identity assertion, including 2:1 heterogeneous
  ``decode_rates`` and a non-dyadic rate profile (both backends carry the
  emulation in float32, so the IEEE ops match exactly); plus unit tests
  for the masked ``pick_min_tied`` and the shared ``subset_mask``
  derivation the SQ(d) path rides on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import engine

KINDS = ["exact", "et", "dt", "rt", "et_rt"]
POLICIES = ["jsaq", "sqd", "rr", "drain"]
MATRIX_KINDS = ["exact", "et", "dt", "rt"]  # the policy x comm test matrix
HETERO_21 = (2.0, 2.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0)  # 2:1 replica speeds


def small_cell(comm: str, **kw) -> engine.ServeConfig:
    base = dict(
        replicas=8, decode_slots=4, slots=2000, load=0.9, comm=comm, x=3,
        rt_period=32, mean_prefill=2, mean_decode=16, queue_cap=256,
    )
    base.update(kw)
    return engine.ServeConfig(**base)


def run_reference(cell: engine.ServeConfig, seed: int, **kw) -> dict:
    """numpy reference run on the cell's (memoised) shared workload."""
    return engine.run_serving_sim(
        cell.engine_config(), slots=cell.slots, load=cell.load,
        mean_prefill=cell.mean_prefill, mean_decode=cell.mean_decode,
        seed=seed, workload=engine.workload_for(cell, seed), **kw,
    )


# Fingerprints of the numpy engine at seed 7 on small_cell(comm):
# (offered, completed, messages, jct_sum, jct[:8],
#  occupancy@600, occupancy@1999).
GOLDEN = {
    "exact": (
        3247, 3168, 3168, 108767,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [5, 5, 5, 5, 5, 5, 4, 5],
        [11, 9, 10, 10, 9, 10, 10, 10],
    ),
    "et": (
        3247, 3166, 4245, 112641,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [5, 5, 5, 5, 5, 3, 6, 5],
        [9, 9, 10, 10, 10, 11, 11, 11],
    ),
    "dt": (
        3247, 3158, 1024, 129408,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [7, 7, 7, 5, 7, 5, 6, 6],
        [13, 12, 10, 8, 11, 15, 10, 10],
    ),
    "rt": (
        3247, 3156, 496, 128238,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [6, 7, 3, 7, 4, 8, 5, 8],
        [13, 11, 11, 10, 11, 12, 11, 12],
    ),
    "et_rt": (
        3247, 3166, 4245, 112641,
        [15, 24, 15, 28, 19, 23, 22, 26],
        [5, 5, 5, 5, 5, 3, 6, 5],
        [9, 9, 10, 10, 10, 11, 11, 11],
    ),
}


class TestNumpyGolden:
    @pytest.mark.parametrize("comm", KINDS)
    def test_reference_engine_fingerprint(self, comm):
        out = run_reference(small_cell(comm), 7, checkpoints=(600, 1999))
        offered, completed, msgs, jct_sum, jct_head, occ600, occ1999 = GOLDEN[
            comm
        ]
        assert out["offered"] == offered
        assert out["completed"] == completed
        assert out["messages"] == msgs
        assert int(out["jct"].sum()) == jct_sum
        assert out["jct"][:8].tolist() == jct_head
        assert out["occupancy"][600].tolist() == occ600
        assert out["occupancy"][1999].tolist() == occ1999

    def test_workload_streams_are_split(self):
        """Workload and tie-break streams are independent SeedSequence
        children -- not the correlated ``default_rng(seed)`` pair the old
        engine used for both."""
        wl = engine.workload_for(small_cell("et"), 7)
        legacy = np.random.default_rng(7)
        legacy_n_arr = legacy.poisson(small_cell("et").arrival_rate(),
                                      size=2000)
        assert not np.array_equal(wl.n_arr, legacy_n_arr)
        # Same seed, same parameters -> same stream (memoised or not).
        wl2 = engine.sample_workload(
            7, replicas=8, decode_slots=4, slots=2000, load=0.9,
            mean_prefill=2, mean_decode=16,
        )
        np.testing.assert_array_equal(wl.work, wl2.work)
        np.testing.assert_array_equal(wl.tie_u, wl2.tie_u)

    def test_workload_shared_across_comm_kinds(self):
        """Cells differing only in trigger parameters replay one stream --
        the paper's comparison method."""
        assert small_cell("et").workload_key() == small_cell(
            "exact"
        ).workload_key()
        wa = engine.workload_for(small_cell("et"), 3)
        wb = engine.workload_for(small_cell("exact", x=7.0), 3)
        np.testing.assert_array_equal(wa.n_arr, wb.n_arr)


# Fingerprints of the numpy engine at seed 7 per (policy, comm) cell of
# the routing-policy matrix (small_cell(comm, policy=policy)):
# (offered, completed, messages, jct_sum, final_occupancy_sum).  Captured
# at the PR that lifted the policy axis into the serving tier.  Structural
# sanity is baked in: "rr" rows share one JCT trajectory across comm kinds
# (round robin never reads the state the comm axis approximates) and
# "drain" rows equal "jsaq" rows exactly (uniform rates -- the drain score
# is an argmin-invariant scaling).
POLICY_GOLDEN = {
    ("jsaq", "exact"): (3247, 3168, 3168, 108767, 79),
    ("jsaq", "et"): (3247, 3166, 4245, 112641, 81),
    ("jsaq", "dt"): (3247, 3158, 1024, 129408, 89),
    ("jsaq", "rt"): (3247, 3156, 496, 128238, 91),
    ("sqd", "exact"): (3247, 3163, 3163, 121102, 84),
    ("sqd", "et"): (3247, 3162, 4225, 122015, 85),
    ("sqd", "dt"): (3247, 3151, 1020, 139920, 96),
    ("sqd", "rt"): (3247, 3148, 496, 142961, 99),
    ("rr", "exact"): (3247, 3161, 3161, 120303, 86),
    ("rr", "et"): (3247, 3161, 4233, 120303, 86),
    ("rr", "dt"): (3247, 3161, 1025, 120303, 86),
    ("rr", "rt"): (3247, 3161, 496, 120303, 86),
    ("drain", "exact"): (3247, 3168, 3168, 108767, 79),
    ("drain", "et"): (3247, 3166, 4245, 112641, 81),
    ("drain", "dt"): (3247, 3158, 1024, 129408, 89),
    ("drain", "rt"): (3247, 3156, 496, 128238, 91),
}

# Fingerprints at seed 7 under 2:1 heterogeneous decode rates (ET-3,
# msr_drain=0.25 so the emulation runs at per-rate nominal capacity):
# (offered, completed, messages, jct_sum).  The rate-blind "rr" pays ~3x
# the JCT of the state-driven policies -- the heterogeneity is real.
HETERO_GOLDEN = {
    "jsaq": (4848, 4682, 441, 190212),
    "sqd": (4848, 4668, 442, 207745),
    "rr": (4848, 4001, 586, 588396),
    "drain": (4848, 4674, 446, 194580),
}


def policy_cell(policy: str, comm: str, **kw) -> engine.ServeConfig:
    return small_cell(comm, policy=policy, **kw)


def hetero_cell(policy: str, comm: str = "et") -> engine.ServeConfig:
    return policy_cell(
        policy, comm, decode_rates=HETERO_21, msr_drain=0.25
    )


class TestPolicyMatrix:
    """Every (policy, comm) cell: numpy golden + jax bit-identity."""

    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("comm", MATRIX_KINDS)
    def test_numpy_golden(self, policy, comm):
        out = run_reference(policy_cell(policy, comm), 7)
        offered, completed, msgs, jct_sum, occ_sum = POLICY_GOLDEN[
            (policy, comm)
        ]
        assert out["offered"] == offered
        assert out["completed"] == completed
        assert out["messages"] == msgs
        assert int(out["jct"].sum()) == jct_sum
        assert int(out["final_occupancy"].sum()) == occ_sum

    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("comm", MATRIX_KINDS)
    def test_jax_matches_numpy_bitwise(self, policy, comm):
        cell = policy_cell(policy, comm)
        ref = run_reference(cell, 7, checkpoints=(600, 1999))
        res = engine.serve_one(7, cell, trace_occupancy=True)
        assert res.messages == ref["messages"]
        assert res.completed == ref["completed"]
        assert res.dropped == 0
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])
        np.testing.assert_array_equal(
            res.final_occupancy, ref["final_occupancy"]
        )
        for slot, occ in ref["occupancy"].items():
            np.testing.assert_array_equal(res.occupancy[slot], occ)

    @pytest.mark.parametrize("policy", POLICIES)
    def test_hetero_rates_golden_and_bitwise(self, policy):
        cell = hetero_cell(policy)
        ref = run_reference(cell, 7)
        offered, completed, msgs, jct_sum = HETERO_GOLDEN[policy]
        assert ref["offered"] == offered
        assert ref["completed"] == completed
        assert ref["messages"] == msgs
        assert int(ref["jct"].sum()) == jct_sum
        res = engine.serve_one(7, cell)
        assert res.messages == ref["messages"]
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])
        np.testing.assert_array_equal(
            res.final_occupancy, ref["final_occupancy"]
        )

    def test_nondyadic_rates_still_bitwise(self):
        # Both backends carry the approximation in float32, so bit-identity
        # survives non-dyadic rates and drains (same IEEE single ops).
        cell = small_cell(
            "et", policy="drain", msr_drain=0.25,
            decode_rates=(1.5, 4 / 3, 1.0, 0.75, 1.25, 1.0, 2.0, 0.5),
        )
        ref = run_reference(cell, 5)
        res = engine.serve_one(5, cell)
        assert res.messages == ref["messages"]
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])

    def test_drain_reduces_to_jsaq_on_uniform_rates(self):
        a = run_reference(policy_cell("drain", "et"), 7)
        b = run_reference(policy_cell("jsaq", "et"), 7)
        assert a["messages"] == b["messages"]
        np.testing.assert_array_equal(a["jct_by_rid"], b["jct_by_rid"])

    def test_rr_trajectory_is_comm_invariant(self):
        # Round robin never reads the approximated state, so the comm axis
        # may only change the message count, never the routing trajectory.
        jcts = [
            run_reference(policy_cell("rr", comm), 7)["jct_by_rid"]
            for comm in MATRIX_KINDS
        ]
        for other in jcts[1:]:
            np.testing.assert_array_equal(jcts[0], other)

    def test_workload_shared_across_policies(self):
        # The policy axis never re-keys the stream -- the paper's
        # comparison method (identical input under every policy), and what
        # makes the matrix a controlled comparison.
        wa = engine.workload_for(policy_cell("jsaq", "et"), 3)
        wb = engine.workload_for(policy_cell("sqd", "et", sqd=4), 3)
        np.testing.assert_array_equal(wa.n_arr, wb.n_arr)
        np.testing.assert_array_equal(wa.sub_u, wb.sub_u)

    def test_mismatched_policy_static_rejected(self):
        cells = [policy_cell("sqd", "et")]
        static = dataclasses.replace(cells[0].static_part(), policy="jsaq")
        with pytest.raises(ValueError, match="does not match"):
            engine.serve_grid([0], static, cells)


class TestBackendEquivalence:
    @pytest.mark.parametrize("comm", KINDS)
    def test_jax_matches_numpy_bitwise(self, comm):
        cell = small_cell(comm)
        ref = run_reference(cell, 7, checkpoints=(600, 1999))
        res = engine.serve_one(7, cell, trace_occupancy=True)
        assert res.messages == ref["messages"]
        assert res.completed == ref["completed"]
        assert res.offered == ref["offered"]
        assert res.dropped == 0
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])
        np.testing.assert_array_equal(res.jct, ref["jct"])
        np.testing.assert_array_equal(
            res.final_occupancy, ref["final_occupancy"]
        )
        for slot, occ in ref["occupancy"].items():
            np.testing.assert_array_equal(res.occupancy[slot], occ)

    def test_fractional_dyadic_drain_still_bitwise(self):
        # msr_drain=0.25 keeps the f32 approximation on dyadic values, so
        # the traced engine still cannot round differently from the f64
        # reference.
        cell = small_cell("et", msr_drain=0.25, x=4)
        ref = run_reference(cell, 5)
        res = engine.serve_one(5, cell)
        assert res.messages == ref["messages"]
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])

    def test_full_ring_drops_and_conserves(self):
        # The traced ring is fixed-capacity: overload must drop (counted)
        # and conservation holds over admitted requests.
        cell = engine.ServeConfig(
            replicas=2, decode_slots=1, slots=400, load=3.0, comm="et",
            x=2, mean_prefill=2, mean_decode=16, queue_cap=8,
        )
        res = engine.serve_one(0, cell)
        assert res.dropped > 0
        admitted = res.offered - res.dropped
        assert admitted == res.completed + int(res.final_occupancy.sum())


class TestGridEquivalence:
    def test_grid_matches_single_runs(self):
        # An ET-x ladder plus a shorter-horizon cell: one compiled program
        # (x and horizon are traced operands), results must equal the
        # per-cell serve_one references bit for bit even though the grid
        # pads both the horizon and the arrival lanes differently.
        cells = [
            small_cell("et", x=2, slots=1500),
            small_cell("et", x=4, slots=1500),
            small_cell("et", x=8, slots=1500),
            small_cell("et", x=4, slots=1000, max_slots=1500),
        ]
        static = dataclasses.replace(cells[0].static_part(), slots=1500)
        seeds = [0, 1]
        grid = engine.serve_grid(seeds, static, cells)
        for cell, row in zip(cells, grid):
            for seed, got in zip(seeds, row):
                ref = engine.serve_one(seed, cell)
                assert got.messages == ref.messages
                assert got.completed == ref.completed
                np.testing.assert_array_equal(got.jct_by_rid, ref.jct_by_rid)
                np.testing.assert_array_equal(
                    got.final_occupancy, ref.final_occupancy
                )

    def test_policy_grid_matches_single_runs(self):
        # One compiled program per policy sweeps x *and* the rate profile
        # (decode_rates is a traced operand): uniform-ones and 2:1 cells
        # share the program, and every cell must equal its serve_one
        # reference bit for bit.
        ones = (1.0,) * 8
        for policy in ["sqd", "drain"]:
            cells = [
                policy_cell(policy, "et", x=2, decode_rates=ones,
                            msr_drain=0.25),
                policy_cell(policy, "et", x=4, decode_rates=ones,
                            msr_drain=0.25),
                policy_cell(policy, "et", x=4, decode_rates=HETERO_21,
                            msr_drain=0.25),
            ]
            static = cells[0].static_part()
            grid = engine.serve_grid([0, 1], static, cells)
            for cell, row in zip(cells, grid):
                for seed, got in zip([0, 1], row):
                    ref = engine.serve_one(seed, cell)
                    assert got.messages == ref.messages
                    assert got.completed == ref.completed
                    np.testing.assert_array_equal(
                        got.jct_by_rid, ref.jct_by_rid
                    )

    def test_grid_unsharded_matches_sharded(self):
        cells = [small_cell("dt", x=2, slots=800),
                 small_cell("dt", x=5, slots=800)]
        static = cells[0].static_part()
        a = engine.serve_grid([0, 1, 2], static, cells, shard=True)
        b = engine.serve_grid([0, 1, 2], static, cells, shard=False)
        for ra, rb in zip(a, b):
            for xa, xb in zip(ra, rb):
                assert xa.messages == xb.messages
                np.testing.assert_array_equal(xa.jct_by_rid, xb.jct_by_rid)

    def test_grid_rejects_mismatched_static(self):
        cells = [small_cell("et")]
        static = dataclasses.replace(cells[0].static_part(), comm="dt")
        with pytest.raises(ValueError, match="does not match"):
            engine.serve_grid([0], static, cells)

    def test_grid_rejects_oversized_cell(self):
        cells = [small_cell("et", slots=4000)]
        static = dataclasses.replace(cells[0].static_part(), slots=2000)
        with pytest.raises(ValueError, match="exceeds"):
            engine.serve_grid([0], static, cells)


class TestPickMinTied:
    def test_matches_reference_enumeration(self):
        rng = np.random.default_rng(0)
        for _ in range(200):
            occ = rng.integers(0, 4, size=rng.integers(1, 12)).astype(float)
            u = np.float32(rng.random())
            ties = np.flatnonzero(occ == occ.min())
            j = engine.pick_min_tied(occ, u)
            assert j in ties
            # Rank formula: the float32 product picks floor(u * n) capped.
            rank = min(int(np.float32(u) * np.float32(len(ties))),
                       len(ties) - 1)
            assert j == ties[rank]

    def test_uniform_over_ties(self):
        occ = np.array([1.0, 0.0, 0.0, 0.0])
        counts = np.zeros(4, int)
        for u in np.linspace(0, 0.999, 999, dtype=np.float32):
            counts[engine.pick_min_tied(occ, u)] += 1
        assert counts[0] == 0
        assert counts[1:].min() > 300  # ~333 each over the tie set

    def test_masked_subset_matches_reference_enumeration(self):
        # The SQ(d) path: the argmin (and its tie set) is restricted to
        # the mask, and the f32 rank arithmetic is unchanged.
        rng = np.random.default_rng(1)
        hits = 0
        for _ in range(300):
            n = int(rng.integers(2, 12))
            occ = rng.integers(0, 4, size=n).astype(float)
            mask = rng.random(n) < 0.5
            if not mask.any():
                continue
            hits += 1
            u = np.float32(rng.random())
            j = engine.pick_min_tied(occ, u, mask=mask)
            assert mask[j]
            cand = np.flatnonzero(mask)
            sub_min = occ[cand].min()
            assert occ[j] == sub_min
            ties = cand[occ[cand] == sub_min]
            rank = min(int(np.float32(u) * np.float32(len(ties))),
                       len(ties) - 1)
            assert j == ties[rank]
        assert hits > 200

    def test_masked_edge_cases(self):
        occ = np.array([3.0, 1.0, 2.0, 0.0])
        # Single candidate: returned regardless of u, even when a smaller
        # occupancy exists outside the mask.
        only = np.array([True, False, False, False])
        for u in (np.float32(0.0), np.float32(0.5), np.float32(0.999)):
            assert engine.pick_min_tied(occ, u, mask=only) == 0
        # All-masked: the -1 sentinel (the engine never routes on an empty
        # subset -- sqd >= 1 -- but the helper must not crash or alias).
        none = np.zeros(4, bool)
        assert engine.pick_min_tied(occ, np.float32(0.3), mask=none) == -1
        # Mask of everything degenerates to the unmasked pick.
        full = np.ones(4, bool)
        for u in (np.float32(0.1), np.float32(0.9)):
            assert engine.pick_min_tied(occ, u, mask=full) == \
                engine.pick_min_tied(occ, u)

    def test_inf_occupancy_outside_mask_never_ties(self):
        # A masked-out zero must not join the tie set of a masked-in zero.
        occ = np.array([0.0, 0.0, 5.0, 0.0])
        mask = np.array([False, True, True, False])
        for u in np.linspace(0, 0.999, 64, dtype=np.float32):
            assert engine.pick_min_tied(occ, u, mask=mask) == 1


class TestSubsetMask:
    def test_numpy_and_jax_derive_identical_subsets(self):
        # The same pre-drawn f32 row must yield the same d-subset on both
        # backends -- the SQ(d) bit-identity hinges on it.
        rng = np.random.default_rng(2)
        for _ in range(200):
            n = int(rng.integers(1, 16))
            d = int(rng.integers(1, min(n, engine.SQD_MAX) + 1))
            row = rng.random(engine.SQD_MAX, dtype=np.float32)
            m_np = engine.subset_mask(row, n, d, xp=np)
            m_jx = np.asarray(engine.subset_mask(jnp.asarray(row), n, d,
                                                 xp=jnp))
            np.testing.assert_array_equal(m_np, m_jx)
            assert int(m_np.sum()) == d  # always d distinct replicas

    def test_subset_is_uniform_over_pairs(self):
        # d=2 over 4 replicas: each of the 6 unordered pairs ~1/6.
        rng = np.random.default_rng(3)
        counts: dict = {}
        for _ in range(3000):
            m = engine.subset_mask(
                rng.random(engine.SQD_MAX, dtype=np.float32), 4, 2, xp=np
            )
            counts[tuple(np.flatnonzero(m))] = counts.get(
                tuple(np.flatnonzero(m)), 0
            ) + 1
        assert len(counts) == 6
        assert min(counts.values()) > 3000 / 6 * 0.7

    def test_boundary_uniforms(self):
        # u = 0 picks the first available replica; u -> 1 the last (the
        # min() clamp keeps the f32 product from indexing past the end).
        lo = np.zeros(engine.SQD_MAX, np.float32)
        hi = np.full(engine.SQD_MAX, np.float32(1.0 - 1e-7))
        np.testing.assert_array_equal(
            np.flatnonzero(engine.subset_mask(lo, 5, 2, xp=np)), [0, 1]
        )
        np.testing.assert_array_equal(
            np.flatnonzero(engine.subset_mask(hi, 5, 2, xp=np)), [3, 4]
        )


class TestSqdSuspectFallback:
    """SQ(d) x suspect masking: the sampled subset intersected with the
    healthy set can be empty (every sampled replica is suspect) -- the
    router must then fall back to the *raw sampled subset*, never to the
    full replica set (which would silently change the d-choices physics)
    and never produce the -1 empty-mask sentinel."""

    def _dispatcher(self, suspect: np.ndarray) -> engine.CareDispatcher:
        cfg = engine.EngineConfig(
            num_replicas=6, decode_slots=2, policy="sqd", sqd=2,
            comm="et", suspect_age=4, fault="crash", crash_rate=0.01,
            recover_rate=0.1,
        )
        disp = engine.CareDispatcher(cfg)
        # Age the suspect replicas past the staleness bound through the
        # trigger clock (the no-network staleness source in route()).
        disp.comm = dataclasses.replace(
            disp.comm,
            slots_since_msg=np.where(suspect, 9, 0).astype(np.int32),
        )
        return disp

    def test_all_suspect_subset_falls_back_to_raw_sample(self):
        # sub_u = 0 samples the subset {0, 1} (see test_boundary_uniforms);
        # both are suspect, so mask & healthy is all-False and the route
        # must still land inside {0, 1}.
        disp = self._dispatcher(
            np.array([True, True, False, False, False, False])
        )
        lo = np.zeros(engine.SQD_MAX, np.float32)
        j = disp.route(
            engine.Request(rid=0, arrival=0, prefill_cost=1, decode_len=1),
            now=0, u=np.float32(0.0), sub_u=lo,
        )
        assert j in (0, 1)
        np.testing.assert_array_equal(
            disp.last_subset,
            [True, True, False, False, False, False],
        )

    def test_partial_overlap_excludes_suspect_member(self):
        # Subset {0, 1} with only replica 0 suspect: the intersection is
        # {1}, so every tie-break uniform must pick 1.
        for u in (0.0, 0.5, 0.999):
            disp = self._dispatcher(
                np.array([True, False, False, False, False, False])
            )
            lo = np.zeros(engine.SQD_MAX, np.float32)
            j = disp.route(
                engine.Request(
                    rid=0, arrival=0, prefill_cost=1, decode_len=1
                ),
                now=0, u=np.float32(u), sub_u=lo,
            )
            assert j == 1

    def test_traced_engine_matches_under_aggressive_suspicion(self):
        # suspect_age=1 under a delayed network keeps most replicas
        # suspect most slots, so the all-suspect-subset fallback fires
        # constantly -- the jax lane must still replay the numpy
        # reference bit for bit.
        cell = small_cell(
            "et", policy="sqd", sqd=2, slots=600, network="net",
            net_delay=3, suspect_age=1,
        )
        ref = run_reference(cell, 7)
        res = engine.serve_one(7, cell)
        assert res.messages == ref["messages"]
        assert res.completed == ref["completed"]
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])
        np.testing.assert_array_equal(
            res.final_occupancy, ref["final_occupancy"]
        )


# Fingerprints of the pull family at seed 7 on small_cell: (offered,
# completed, messages, jct_sum, final_occupancy_sum, token_misses,
# token_sum).  At load 0.9 replicas are almost never idle, so JIQ sends
# nearly no tokens (5 messages over 2000 slots) and degrades to the
# uniform fallback -- exactly the regime van der Boor et al. describe;
# hsq's threshold crossings + rt_period keepalive restore a usable pool.
PULL_GOLDEN = {
    "jiq": (3247, 3109, 5, 185837, 138, 3242, 7),
    "hsq": (3247, 3130, 570, 155311, 117, 3127, 164),
}


class TestPullPolicies:
    """JIQ / hyper-scalable JSQ on the serving tier: numpy goldens, jax
    bit-identity (token counters included), and the <= 1 message/job
    communication bound that motivates the pull family."""

    @pytest.mark.parametrize("policy", ["jiq", "hsq"])
    def test_numpy_golden(self, policy):
        extra = dict(x=3) if policy == "hsq" else {}
        ref = run_reference(small_cell(policy, policy=policy, **extra), 7)
        (offered, completed, msgs, jct_sum, occ_sum, misses,
         tok_sum) = PULL_GOLDEN[policy]
        assert ref["offered"] == offered
        assert ref["completed"] == completed
        assert ref["messages"] == msgs
        assert int(ref["jct"].sum()) == jct_sum
        assert int(ref["final_occupancy"].sum()) == occ_sum
        assert ref["token_misses"] == misses
        assert ref["token_sum"] == tok_sum

    @pytest.mark.parametrize("policy", ["jiq", "hsq"])
    def test_jax_matches_numpy_bitwise(self, policy):
        extra = dict(x=3) if policy == "hsq" else {}
        cell = small_cell(policy, policy=policy, **extra)
        ref = run_reference(cell, 7, checkpoints=(600, 1999))
        res = engine.serve_one(7, cell, trace_occupancy=True)
        assert res.messages == ref["messages"]
        assert res.completed == ref["completed"]
        assert res.token_misses == ref["token_misses"]
        assert res.token_sum == ref["token_sum"]
        np.testing.assert_array_equal(res.jct_by_rid, ref["jct_by_rid"])
        np.testing.assert_array_equal(
            res.final_occupancy, ref["final_occupancy"]
        )
        for slot, occ in ref["occupancy"].items():
            np.testing.assert_array_equal(res.occupancy[slot], occ)

    @pytest.mark.parametrize("policy", ["jiq", "hsq"])
    def test_pull_messages_at_most_one_per_job(self, policy):
        # The pull family's defining bound: a token is only ever sent on
        # an idleness/threshold transition, at most one per completed job
        # (plus the rt_period keepalive for hsq, still within the bound
        # at these horizons).
        extra = dict(x=3) if policy == "hsq" else {}
        ref = run_reference(small_cell(policy, policy=policy, **extra), 7)
        assert ref["messages"] <= ref["completed"]

    def test_workload_shared_with_push_policies(self):
        # The pull cells replay the identical arrival/work stream the push
        # matrix uses -- the controlled-comparison invariant extends to
        # the new policy kinds.
        wa = engine.workload_for(small_cell("et"), 3)
        wb = engine.workload_for(small_cell("jiq", policy="jiq"), 3)
        np.testing.assert_array_equal(wa.n_arr, wb.n_arr)
        np.testing.assert_array_equal(wa.tie_u, wb.tie_u)


# ---------------------------------------------------------------------------
# Segment engine (serve_stream): chunk-invariance goldens.
# ---------------------------------------------------------------------------

# (policy x comm x network x fault) sample of the matrix: the satellite
# combos exercise every static code path the chunk carry threads (the
# exhaustive degraded matrix lives in tests/test_faults.py).
STREAM_MATRIX = [
    dict(policy="jsaq", comm="et"),
    dict(policy="jsaq", comm="exact"),
    dict(policy="sqd", sqd=3, comm="dt"),
    dict(policy="rr", comm="rt"),
    dict(policy="drain", comm="et_rt",
         decode_rates=(2.0, 2.0, 1.0, 1.0, 0.5, 0.5)),
    dict(policy="sqd", sqd=2, comm="et", network="net", net_delay=3,
         net_drop=0.1, suspect_age=8),
    dict(policy="jsaq", comm="et_rt", fault="crash", crash_rate=0.02,
         recover_rate=0.2, suspect_age=10),
]


def stream_cell(slots=400, **knobs) -> engine.ServeConfig:
    return engine.ServeConfig(
        replicas=6, decode_slots=4, slots=slots, load=0.9, queue_cap=256,
        **knobs,
    )


def fresh_stream(seed, cell, **kw):
    """serve_stream on a fresh sampler (streams never share block caches)."""
    sampler = engine.StreamSampler(seed, engine.StreamParams.for_cell(cell))
    return engine.serve_stream(seed, cell, sampler=sampler, **kw)


class TestStreamEngine:
    @pytest.mark.parametrize("knobs", STREAM_MATRIX)
    def test_chunk_invariant_and_matches_fixed_horizon(self, knobs):
        """Every chunk size replays the monolithic fixed-horizon run bit
        for bit -- counters, final occupancy, and the full carried state."""
        cell = stream_cell(**knobs)
        sampler = engine.StreamSampler(
            3, engine.StreamParams.for_cell(cell)
        )
        wl = sampler.full(cell.slots)
        ref = engine.serve_one(3, cell, workload=wl)

        carries = []
        for chunk in (1, 7, 64, cell.slots):
            res = fresh_stream(3, cell, chunk=chunk)
            assert res.completed == ref.completed
            assert res.messages == ref.messages
            assert res.dropped == ref.dropped
            assert res.net_drops == ref.net_drops
            np.testing.assert_array_equal(
                res.final_occupancy, ref.final_occupancy
            )
            # warmup=0: the accumulators see every completion.
            assert res.count == ref.completed
            carries.append(jax.tree.leaves(
                jax.tree.map(np.asarray, res.state.carry)
            ))
        for leaves in carries[1:]:
            assert len(leaves) == len(carries[0])
            for a, b in zip(carries[0], leaves):
                np.testing.assert_array_equal(a, b)

    def test_stream_metrics_match_host_recomputation(self):
        """count / histogram / max are exact vs the fixed engine's JCT
        sample; mean / std agree to f32 combine tolerance."""
        cell = stream_cell()
        sampler = engine.StreamSampler(
            7, engine.StreamParams.for_cell(cell)
        )
        wl = sampler.full(cell.slots)
        ref = engine.serve_one(7, cell, workload=wl)
        res = fresh_stream(7, cell, chunk=64)
        from repro.core.care import metrics

        jct = ref.jct
        assert res.count == jct.size
        assert res.max_jct == int(jct.max())
        host_hist = np.bincount(
            metrics.jct_bucket(jct), minlength=metrics.HIST_BUCKETS
        )
        np.testing.assert_array_equal(res.hist, host_hist)
        assert abs(res.mean_jct - jct.mean()) <= 1e-4 * max(jct.mean(), 1)
        assert abs(res.std_jct - jct.std()) <= 1e-3 * max(jct.std(), 1)
        s = res.jct_summary()
        assert s["count"] == jct.size and s["max"] == int(jct.max())
        # Histogram quantiles land within one sub-octave (<= 25%).
        for q, key in ((0.5, "p50"), (0.99, "p99")):
            exact = np.quantile(jct, q)
            assert abs(s[key] - exact) <= 0.25 * exact + 1.0

    def test_warmup_discards_pre_threshold_completions(self):
        cell = stream_cell()
        sampler = engine.StreamSampler(
            3, engine.StreamParams.for_cell(cell)
        )
        wl = sampler.full(cell.slots)
        ref = engine.serve_one(3, cell, workload=wl)
        warm = 200
        res = fresh_stream(3, cell, chunk=64, warmup=warm)
        # Counters are never warmup-gated; only the JCT accumulators are.
        assert res.completed == ref.completed
        assert res.messages == ref.messages
        done = ref.jct_by_rid >= 0
        comp_t = wl.arrival_slot[done] + ref.jct_by_rid[done] - 1
        measured = ref.jct_by_rid[done][comp_t >= warm]
        assert res.count == measured.size
        assert res.max_jct == int(measured.max())
        from repro.core.care import metrics

        np.testing.assert_array_equal(
            res.hist,
            np.bincount(metrics.jct_bucket(measured),
                        minlength=metrics.HIST_BUCKETS),
        )

    def test_all_completions_in_warmup_is_nan_safe(self):
        cell = stream_cell(slots=100)
        res = fresh_stream(3, cell, chunk=32, warmup=10**6)
        assert res.count == 0
        assert res.mean_jct == 0.0 and np.isfinite(res.std_jct)
        s = res.jct_summary()
        assert s == {"count": 0, "mean": 0.0, "std": 0.0, "p50": 0.0,
                     "p90": 0.0, "p99": 0.0, "p999": 0.0, "max": 0}

    def test_resume_matches_single_segment(self):
        cell = stream_cell()
        one = fresh_stream(3, cell, chunk=64)
        sampler = engine.StreamSampler(
            3, engine.StreamParams.for_cell(cell)
        )
        r1 = engine.serve_stream(3, cell, chunk=64, sampler=sampler,
                                 slots=160)
        r2 = engine.serve_stream(3, cell, chunk=64, state=r1.state,
                                 slots=cell.slots - 160)
        assert r2.slots == one.slots
        assert r2.offered == one.offered
        assert r2.completed == one.completed
        assert r2.messages == one.messages
        np.testing.assert_array_equal(r2.final_occupancy,
                                      one.final_occupancy)
        np.testing.assert_array_equal(r2.hist, one.hist)

    def test_sampler_slabs_are_prefix_stable(self):
        """Any slabbing assembles the same trace: blocks are keyed by
        (seed, params, block index), never by sampling order."""
        cell = stream_cell()
        params = engine.StreamParams.for_cell(cell)
        a = engine.StreamSampler(3, params)
        b = engine.StreamSampler(3, params)
        whole = a.full(3000)  # spans multiple STREAM_BLOCKs
        # Sample b out of order and in odd pieces.
        pieces = [b.slab(2900, 3000), b.slab(0, 7), b.slab(7, 2900)]
        n_arr = np.concatenate(
            [pieces[1].n_arr, pieces[2].n_arr, pieces[0].n_arr]
        )
        work = np.concatenate(
            [pieces[1].work, pieces[2].work, pieces[0].work]
        )
        tie = np.concatenate(
            [pieces[1].tie_u, pieces[2].tie_u, pieces[0].tie_u]
        )
        np.testing.assert_array_equal(whole.n_arr, n_arr)
        np.testing.assert_array_equal(whole.work, work)
        np.testing.assert_array_equal(whole.tie_u, tie)

    def test_diurnal_rate_modulates_arrivals(self):
        cell = stream_cell()
        params = engine.StreamParams.for_cell(
            cell, diurnal_amp=0.9, diurnal_period=2048
        )
        s = engine.StreamSampler(3, params)
        rates = s.rate_at(np.arange(2048))
        assert rates.max() > 1.5 * rates.min()
        # Arrivals track the modulation: the peak half-period offers more.
        wl = s.slab(0, 2048)
        peak = wl.n_arr[:1024].sum()
        trough = wl.n_arr[1024:].sum()
        assert peak > trough

    def test_stream_validation(self):
        cell = stream_cell()
        with pytest.raises(ValueError, match="slots"):
            fresh_stream(3, cell, slots=0)
        with pytest.raises(ValueError, match="chunk"):
            fresh_stream(3, cell, chunk=0)
        with pytest.raises(ValueError, match="int32"):
            fresh_stream(3, cell, slots=2**31)
        with pytest.raises(ValueError, match="slab"):
            engine.StreamSampler(
                3, engine.StreamParams.for_cell(cell)
            ).slab(5, 5)
