"""Sharding-rule tests: parallel.hint divisibility and the partitioning
decisions the perf pass depends on (embed gating, CM tensor-parallelism)."""
import os

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import model, parallel, partitioning


@pytest.fixture(scope="module")
def ctx():
    # Single-device "mesh" with both axes size 1: every rule must degrade
    # to replication (divisibility guard) without erroring.
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return parallel.ParallelContext(mesh=mesh, dp_axes=("data",))


class TestHint:
    def test_none_ctx_is_noop(self):
        x = jnp.ones((4, 6))
        assert parallel.hint(x, None, "data", "model") is x

    def test_non_divisible_axis_downgrades(self, ctx):
        # dims divisible by 1 always; use a fake wider mesh via the real
        # helper logic: with axis size 1 everything divides, so this
        # checks the pass-through path shape preservation.
        x = jnp.ones((4, 6, 8))
        y = parallel.hint(x, ctx, "data", None, "model")
        assert y.shape == x.shape

    def test_tuple_axis_entries(self, ctx):
        x = jnp.ones((4, 8))
        y = parallel.hint(x, ctx, ("data", "model"), None)
        assert y.shape == x.shape


class TestEmbedGating:
    """d_model-sharded embeddings only for untied-head MoE archs."""

    def _embed_spec(self, arch, ctx):
        cfg = get_config(arch).reduced()
        abs_params = jax.eval_shape(
            lambda k: model.init_params(k, cfg), jax.random.key(0)
        )
        specs = partitioning.param_specs(abs_params, cfg, ctx)
        return specs["embed"], cfg

    def test_moe_untied_is_d_sharded(self, ctx):
        spec, cfg = self._embed_spec("deepseek-v2-236b", ctx)
        assert not cfg.tie_embeddings and cfg.moe
        assert tuple(spec) in ((None, "model"), (None, None))
        # with a divisible mesh the rule itself must be embed_d:
        assert partitioning._base_spec("embed_d", 2, "model") == P(None, "model")

    def test_tied_dense_is_vocab_sharded(self, ctx):
        spec, cfg = self._embed_spec("gemma2-9b", ctx)
        assert cfg.tie_embeddings and not cfg.moe
        assert partitioning._base_spec("embed", 2, "model") == P("model", None)

    def test_untied_dense_is_vocab_sharded(self, ctx):
        _, cfg = self._embed_spec("chameleon-34b", ctx)
        assert not cfg.tie_embeddings and not cfg.moe
        # rule stays "embed" (vocab) because cfg.moe is False
        assert partitioning._base_spec("embed", 2, "model") == P("model", None)


class TestCmRules:
    def test_channel_mix_stays_tensor_parallel(self, ctx):
        """Replicated CM weights were measured 4x worse for decode --
        guard against reintroduction."""
        assert partitioning._CM_RULES == {"wk": "col", "wv": "row", "wr": "col"}


class TestProductionMeshSpecs:
    """On the real 512-device production mesh shapes divide and the spec
    entries must actually be sharded (not silently downgraded)."""

    def test_full_mesh_specs(self):
        if jax.device_count() < 2:
            pytest.skip("needs the forced multi-device dryrun env")

    def test_divisible_helper(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # 7 not divisible by anything but 1 -> None
        spec = partitioning._divisible(P("model", None), (7, 4), mesh)
        assert tuple(spec) == ("model", None)  # axis size 1 divides all
