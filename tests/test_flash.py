"""Blocked flash attention must match the dense softmax attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import flash


def _qkv(key, b, s, t, h, kvh, dh, dv=None):
    ks = jax.random.split(key, 3)
    dv = dv or dh
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kvh, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kvh, dv), jnp.float32)
    return q, k, v


def _dense(q, k, v, **kw):
    return flash._dense_sdpa(
        q, k, v,
        scale=kw.get("scale", 1.0 / q.shape[-1] ** 0.5),
        q_positions=kw.get("q_positions"),
        causal=kw.get("causal", True),
        window=kw.get("window"),
        softcap=kw.get("softcap", 0.0),
    )


def _flash(q, k, v, **kw):
    return flash.flash_sdpa(
        q, k, v,
        scale=kw.get("scale", 1.0 / q.shape[-1] ** 0.5),
        q_positions=kw.get("q_positions"),
        causal=kw.get("causal", True),
        window=kw.get("window"),
        softcap=kw.get("softcap", 0.0),
        kv_block=kw.get("kv_block", 64),
    )


CASES = [
    dict(),  # plain causal MHA
    dict(window=jnp.asarray(48)),  # sliding window (traced scalar)
    dict(softcap=50.0),  # gemma2-style logit cap
    dict(causal=False),  # encoder / cross attention
    dict(window=jnp.asarray(16), softcap=30.0),
]


@pytest.mark.parametrize("case", range(len(CASES)))
@pytest.mark.parametrize("kvh", [4, 1, 2])
def test_flash_matches_dense(case, kvh):
    kw = CASES[case]
    q, k, v = _qkv(jax.random.key(case), 2, 128, 256, 4, kvh, 16)
    ref = _dense(q, k, v, **kw)
    out = _flash(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_gqa_different_dv():
    q, k, v = _qkv(jax.random.key(7), 1, 64, 128, 8, 2, 16, dv=32)
    ref = _dense(q, k, v)
    out = _flash(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_offset_query_positions():
    """Decode-style: queries living at the cache tail."""
    q, k, v = _qkv(jax.random.key(8), 2, 8, 128, 4, 4, 16)
    qpos = (120 + jnp.arange(8, dtype=jnp.int32))[None, :].repeat(2, 0)
    ref = _dense(q, k, v, q_positions=qpos)
    out = _flash(q, k, v, q_positions=qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_non_divisible_falls_back():
    q, k, v = _qkv(jax.random.key(9), 1, 32, 100, 2, 2, 8)
    ref = _dense(q, k, v)
    out = flash.flash_sdpa(q, k, v, scale=1.0 / 8**0.5, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_grads_match_dense():
    q, k, v = _qkv(jax.random.key(10), 1, 64, 128, 2, 2, 8)

    def loss_d(args):
        return jnp.sum(_dense(*args) ** 2)

    def loss_f(args):
        return jnp.sum(_flash(*args) ** 2)

    gd = jax.grad(loss_d)((q, k, v))
    gf = jax.grad(loss_f)((q, k, v))
    for a, b in zip(gd, gf):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-4, atol=5e-4)
