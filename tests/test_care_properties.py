"""Hypothesis property tests for CARE invariants.

Rather than driving the full simulator through hypothesis (slow under jit),
these test the *approximation component* state machine directly on random
arrival/departure sample paths, checking the paper's structural identities:

* Eq. (11): the error depends only on true-vs-emulated departure counts.
* Prop 6.7 / Eq. (18): deterministic AQ bounds for DT-x and ET-x.
* Prop 6.4 / 6.8: message-count bounds.
* Flow conservation (Eq. 1).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.care import approx as approx_lib

# Hypothesis-heavy: part of the full suite, skipped by the fast tier-1
# gate (pytest -m "not slow").
pytestmark = pytest.mark.slow


def _replay(arrivals, services, x, kind, comm, msr_slots=4):
    """Replay a single-server sample path through the emulation machinery.

    arrivals: list[bool] per slot; services: per-job sizes (slots).
    Returns (max_err_end_of_slot, messages, departures).
    """
    acfg = approx_lib.ApproxConfig(kind=kind, msr_slots=msr_slots, x=x)
    emu = approx_lib.EmuState.init(jnp.zeros((1,), jnp.int32), acfg)
    q_true = 0
    head_rem = 0
    fifo: list[int] = []
    deps_since = 0
    msgs = 0
    deps = 0
    max_err = 0
    job = 0
    for arr in arrivals:
        if arr:
            size = services[job % len(services)]
            job += 1
            fifo.append(size)
            if q_true == 0:
                head_rem = size
            q_true += 1
            emu = approx_lib.emu_arrival(emu, jnp.array(0), acfg)
        if q_true > 0:
            head_rem -= 1
            if head_rem <= 0:
                q_true -= 1
                deps += 1
                deps_since += 1
                fifo.pop(0)
                head_rem = fifo[0] if fifo else 0
        emu = approx_lib.emu_drain_slot(emu, acfg)
        err = int(abs(q_true - int(emu.q_app[0])))
        if comm == "dt":
            trig = deps_since >= x
        elif comm == "et":
            trig = err >= x
        else:
            trig = False
        if trig:
            msgs += 1
            deps_since = 0
            emu = approx_lib.emu_message_reset(
                emu, jnp.array([q_true], jnp.int32), jnp.array([True]), acfg
            )
        max_err = max(max_err, int(abs(q_true - int(emu.q_app[0]))))
    return max_err, msgs, deps


path = st.lists(st.booleans(), min_size=10, max_size=120)
sizes = st.lists(st.integers(1, 9), min_size=1, max_size=40)
xs = st.integers(2, 5)


@settings(max_examples=30, deadline=None)
@given(path, sizes, xs, st.sampled_from(["basic", "msr_x"]))
def test_thm23_aq_bound(arrivals, services, x, kind):
    """DT-x with basic/MSR-x keeps end-of-slot error <= x-1 on ANY path."""
    max_err, msgs, deps = _replay(arrivals, services, x, kind, "dt")
    assert max_err <= x - 1
    assert msgs <= deps // x + 1


@settings(max_examples=30, deadline=None)
@given(path, sizes, xs, st.sampled_from(["basic", "msr", "msr_x"]))
def test_et_aq_bound_any_emulation(arrivals, services, x, kind):
    """ET-x bounds the error for ANY emulation algorithm (Prop 6.8)."""
    max_err, _, _ = _replay(arrivals, services, x, kind, "et")
    assert max_err <= x - 1


@settings(max_examples=30, deadline=None)
@given(path, sizes, xs)
def test_et_msrx_message_bound(arrivals, services, x):
    """ET-x + MSR-x: emulated deps capped at x-1 so a message needs >= x
    true departures (Sec 6.4): M <= D/x (+1 boundary)."""
    _, msgs, deps = _replay(arrivals, services, x, "msr_x", "et")
    assert msgs <= deps // x + 1


@settings(max_examples=20, deadline=None)
@given(path, sizes)
def test_basic_overestimates(arrivals, services):
    """The basic approximation can never under-estimate the queue."""
    acfg = approx_lib.ApproxConfig(kind="basic", msr_slots=4, x=3)
    emu = approx_lib.EmuState.init(jnp.zeros((1,), jnp.int32), acfg)
    q_true, head_rem, fifo = 0, 0, []
    job = 0
    for arr in arrivals:
        if arr:
            size = services[job % len(services)]
            job += 1
            fifo.append(size)
            if q_true == 0:
                head_rem = size
            q_true += 1
            emu = approx_lib.emu_arrival(emu, jnp.array(0), acfg)
        if q_true > 0:
            head_rem -= 1
            if head_rem <= 0:
                q_true -= 1
                fifo.pop(0)
                head_rem = fifo[0] if fifo else 0
        emu = approx_lib.emu_drain_slot(emu, acfg)
        assert int(emu.q_app[0]) >= q_true


@settings(max_examples=20, deadline=None)
@given(path, sizes, st.integers(2, 4))
def test_msrx_truncation(arrivals, services, x):
    """MSR-x never emulates more than x-1 departures between messages."""
    acfg = approx_lib.ApproxConfig(kind="msr_x", msr_slots=2, x=x)
    emu = approx_lib.EmuState.init(jnp.zeros((1,), jnp.int32), acfg)
    for arr in arrivals:
        if arr:
            emu = approx_lib.emu_arrival(emu, jnp.array(0), acfg)
        emu = approx_lib.emu_drain_slot(emu, acfg)
        assert int(emu.emu_deps[0]) <= x - 1
        assert int(emu.q_app[0]) >= 0
