"""Tests for the static/traced config split and the fused grid simulator.

Three layers of protection for the "one compiled program per figure" path:

1. **Goldens** -- metric fingerprints captured from the pre-split
   compile-per-cell simulator (every scenario knob was a static jit
   argument).  The traced-operand path must reproduce them *bit for bit*:
   the ``Scenario`` derivations (``rt_period``, MMPP ``lam_hi/lam_lo``)
   intentionally run in host float64 so no f32-vs-f64 rounding can leak
   into the arrival streams.
2. **Grid equivalence** -- ``simulate_grid`` must equal per-cell
   ``simulate`` on fixed seeds (messages, max_aq, full JCT arrays):
   vmap / shard_map / padding are all semantics-preserving.
3. **Topology** -- padding indices are exercised directly, and a
   subprocess forced to 8 host devices re-runs a ragged grid (3 runs over
   8 shards) that must match the in-process device count's results.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import SimConfig, simulate, simulate_batch, simulate_grid
from repro.core.care import slotted_sim
from repro.core.dispatch_sim import DispatchSimConfig, dispatch_batch
from repro.core.dispatch_sim import simulate as dispatch_simulate

# ---------------------------------------------------------------------------
# 1. Goldens: the traced path reproduces the compile-per-cell seed simulator.
# ---------------------------------------------------------------------------

HETERO_RATES = tuple(1.5 if i < 15 else 0.5 for i in range(30))

GOLDEN_CELLS = {
    "et_msr": dict(slots=4000, load=0.95, policy="jsaq", comm="et", x=3, approx="msr"),
    "et_msr_x5": dict(slots=4000, load=0.8, policy="jsaq", comm="et", x=5, approx="msr"),
    "dt_msrx": dict(slots=4000, load=0.9, policy="jsaq", comm="dt", x=3, approx="msr_x"),
    "rt": dict(slots=4000, load=0.9, policy="jsaq", comm="rt", rt_rate=0.02, approx="msr"),
    "et_rt": dict(slots=4000, load=0.5, policy="jsaq", comm="et_rt", x=3, rt_rate=0.01, approx="msr"),
    "jsq": dict(slots=4000, load=0.95, policy="jsq", comm="none"),
    "sq2": dict(slots=4000, load=0.95, policy="sq2", comm="none"),
    "rr": dict(slots=4000, load=0.95, policy="rr", comm="none"),
    "mmpp": dict(slots=4000, load=0.95, policy="jsaq", comm="et", x=3, approx="msr",
                 arrival="mmpp", burst_intensity=1.7, burst_stay=0.97),
    "hetero": dict(slots=4000, load=0.95, policy="jsaq", comm="et", x=3, approx="msr",
                   service_rates=HETERO_RATES),
    "basic": dict(slots=4000, load=0.9, policy="jsaq", comm="dt", x=4, approx="basic"),
}

# Captured from the seed implementation (SimConfig fully static) at the
# commit introducing the split; keys are (cell, seed) -> fingerprint.
GOLDENS = json.loads("""
{"et_msr/s0":{"messages":417,"max_aq":2,"departures":3740,"arrivals":3815,"dropped":0,"max_queue":6,"gap_sup":6,"jct_sum":301134,"jct_n":3740,"per_srv_sum":55473},
"et_msr/s7":{"messages":379,"max_aq":2,"departures":3720,"arrivals":3791,"dropped":0,"max_queue":6,"gap_sup":6,"jct_sum":294476,"jct_n":3720,"per_srv_sum":55018},
"et_msr_x5/s0":{"messages":53,"max_aq":4,"departures":3159,"arrivals":3207,"dropped":0,"max_queue":6,"gap_sup":6,"jct_sum":201963,"jct_n":3159,"per_srv_sum":46925},
"et_msr_x5/s7":{"messages":42,"max_aq":4,"departures":3160,"arrivals":3211,"dropped":0,"max_queue":5,"gap_sup":5,"jct_sum":189754,"jct_n":3160,"per_srv_sum":46340},
"dt_msrx/s0":{"messages":1178,"max_aq":2,"departures":3568,"arrivals":3619,"dropped":0,"max_queue":5,"gap_sup":5,"jct_sum":200744,"jct_n":3568,"per_srv_sum":52187},
"dt_msrx/s7":{"messages":1177,"max_aq":2,"departures":3559,"arrivals":3599,"dropped":0,"max_queue":5,"gap_sup":5,"jct_sum":187847,"jct_n":3559,"per_srv_sum":52847},
"rt/s0":{"messages":2400,"max_aq":4,"departures":3563,"arrivals":3619,"dropped":0,"max_queue":4,"gap_sup":4,"jct_sum":216091,"jct_n":3563,"per_srv_sum":52046},
"rt/s7":{"messages":2400,"max_aq":4,"departures":3549,"arrivals":3599,"dropped":0,"max_queue":4,"gap_sup":4,"jct_sum":207202,"jct_n":3549,"per_srv_sum":51905},
"et_rt/s0":{"messages":1200,"max_aq":2,"departures":1940,"arrivals":1958,"dropped":0,"max_queue":3,"gap_sup":3,"jct_sum":71989,"jct_n":1940,"per_srv_sum":28616},
"et_rt/s7":{"messages":1200,"max_aq":2,"departures":1973,"arrivals":1989,"dropped":0,"max_queue":3,"gap_sup":3,"jct_sum":69378,"jct_n":1973,"per_srv_sum":28863},
"jsq/s0":{"messages":0,"max_aq":20,"departures":3773,"arrivals":3815,"dropped":0,"max_queue":3,"gap_sup":3,"jct_sum":150163,"jct_n":3773,"per_srv_sum":54425},
"jsq/s7":{"messages":0,"max_aq":21,"departures":3763,"arrivals":3791,"dropped":0,"max_queue":2,"gap_sup":2,"jct_sum":141963,"jct_n":3763,"per_srv_sum":55419},
"sq2/s0":{"messages":0,"max_aq":22,"departures":3728,"arrivals":3815,"dropped":0,"max_queue":8,"gap_sup":8,"jct_sum":350621,"jct_n":3728,"per_srv_sum":55535},
"sq2/s7":{"messages":0,"max_aq":15,"departures":3692,"arrivals":3791,"dropped":0,"max_queue":8,"gap_sup":8,"jct_sum":370990,"jct_n":3692,"per_srv_sum":54888},
"rr/s0":{"messages":0,"max_aq":19,"departures":3634,"arrivals":3815,"dropped":0,"max_queue":20,"gap_sup":20,"jct_sum":550694,"jct_n":3634,"per_srv_sum":55299},
"rr/s7":{"messages":0,"max_aq":19,"departures":3613,"arrivals":3791,"dropped":0,"max_queue":20,"gap_sup":20,"jct_sum":532031,"jct_n":3613,"per_srv_sum":54889},
"mmpp/s0":{"messages":413,"max_aq":2,"departures":3706,"arrivals":3778,"dropped":0,"max_queue":5,"gap_sup":5,"jct_sum":295680,"jct_n":3706,"per_srv_sum":55010},
"mmpp/s7":{"messages":379,"max_aq":2,"departures":3714,"arrivals":3791,"dropped":0,"max_queue":6,"gap_sup":5,"jct_sum":282532,"jct_n":3714,"per_srv_sum":54871},
"hetero/s0":{"messages":465,"max_aq":2,"departures":3728,"arrivals":3815,"dropped":0,"max_queue":7,"gap_sup":7,"jct_sum":317649,"jct_n":3728,"per_srv_sum":39668},
"hetero/s7":{"messages":415,"max_aq":2,"departures":3708,"arrivals":3791,"dropped":0,"max_queue":8,"gap_sup":8,"jct_sum":314079,"jct_n":3708,"per_srv_sum":39191},
"basic/s0":{"messages":874,"max_aq":3,"departures":3554,"arrivals":3619,"dropped":0,"max_queue":5,"gap_sup":5,"jct_sum":220946,"jct_n":3554,"per_srv_sum":51588},
"basic/s7":{"messages":878,"max_aq":3,"departures":3550,"arrivals":3599,"dropped":0,"max_queue":5,"gap_sup":5,"jct_sum":213382,"jct_n":3550,"per_srv_sum":52433}}
""")


def _fingerprint(r) -> dict:
    return dict(
        messages=r.messages,
        max_aq=r.max_aq,
        departures=r.departures,
        arrivals=r.arrivals,
        dropped=r.dropped,
        max_queue=r.max_queue,
        gap_sup=r.queue_gap_sup,
        jct_sum=int(np.sum(r.jct)),
        jct_n=int(r.jct.shape[0]),
        per_srv_sum=int(np.sum(r.per_server_arrivals * np.arange(30))),
    )


class TestGoldens:
    @pytest.mark.parametrize("cell", sorted(GOLDEN_CELLS))
    @pytest.mark.parametrize("seed", [0, 7])
    def test_traced_path_matches_seed_simulator(self, cell, seed):
        r = simulate(jax.random.key(seed), SimConfig(**GOLDEN_CELLS[cell]))
        assert _fingerprint(r) == GOLDENS[f"{cell}/s{seed}"]


# ---------------------------------------------------------------------------
# 2. simulate_grid == per-cell simulate, exactly.
# ---------------------------------------------------------------------------

GRID_CFGS = [
    SimConfig(slots=3000, load=0.95, x=3, comm="et", approx="msr"),
    SimConfig(slots=3000, load=0.8, x=5, comm="et", approx="msr"),
    SimConfig(slots=3000, load=0.5, x=2, comm="et", approx="msr",
              rt_rate=0.05),
]
GRID_SEEDS = (0, 3)


def _assert_same(a, b):
    assert a.messages == b.messages
    assert a.max_aq == b.max_aq
    assert a.departures == b.departures
    assert a.arrivals == b.arrivals
    assert np.array_equal(a.jct, b.jct)
    assert np.array_equal(a.per_server_arrivals, b.per_server_arrivals)
    assert np.array_equal(a.final_q, b.final_q)


class TestSimulateGrid:
    def test_per_cell_equivalence(self):
        static = GRID_CFGS[0].static_part()
        assert all(c.static_part() == static for c in GRID_CFGS)
        grid = simulate_grid(
            list(GRID_SEEDS), static, [c.scenario() for c in GRID_CFGS]
        )
        assert len(grid) == len(GRID_CFGS)
        for cell, cfg in zip(grid, GRID_CFGS):
            assert len(cell) == len(GRID_SEEDS)
            for res, seed in zip(cell, GRID_SEEDS):
                _assert_same(res, simulate(jax.random.key(seed), cfg))

    def test_batch_is_one_cell_grid(self):
        cfg = GRID_CFGS[0]
        batch = simulate_batch(list(GRID_SEEDS), cfg)
        for res, seed in zip(batch, GRID_SEEDS):
            _assert_same(res, simulate(jax.random.key(seed), cfg))

    def test_shard_flag_is_semantics_free(self):
        static = GRID_CFGS[0].static_part()
        scns = [c.scenario() for c in GRID_CFGS]
        a = simulate_grid([5], static, scns, shard=True)
        b = simulate_grid([5], static, scns, shard=False)
        for ca, cb in zip(a, b):
            _assert_same(ca[0], cb[0])

    def test_mixed_x_and_rates_grid(self):
        # x and service_rates vary per cell within one compiled program.
        rates_a = tuple(1.5 if i < 15 else 0.5 for i in range(30))
        rates_b = tuple(0.5 if i < 15 else 1.5 for i in range(30))
        cfgs = [
            SimConfig(slots=2000, load=0.9, x=2, service_rates=rates_a),
            SimConfig(slots=2000, load=0.95, x=4, service_rates=rates_b),
        ]
        static = cfgs[0].static_part()
        assert cfgs[1].static_part() == static
        grid = simulate_grid([1], static, [c.scenario() for c in cfgs])
        for cell, cfg in zip(grid, cfgs):
            _assert_same(cell[0], simulate(jax.random.key(1), cfg))


# ---------------------------------------------------------------------------
# 2b. Traced service axis + padded fixed horizon.
# ---------------------------------------------------------------------------


class TestTracedServiceAxis:
    def test_mixed_mean_and_horizon_grid_matches_percell(self):
        """The bench_ssc shape: (mean_service, horizon) vary per cell inside
        one compiled program; each cell must equal its own per-cell run
        bit for bit (the per-cell path shares the padded StaticConfig, so
        the workload streams coincide)."""
        cfgs = [
            SimConfig(slots=1000, max_slots=4000, load=0.95,
                      mean_service=10, servers=10, x=2),
            SimConfig(slots=2000, max_slots=4000, load=0.95,
                      mean_service=20, servers=10, x=2),
            SimConfig(slots=4000, max_slots=4000, load=0.95,
                      mean_service=40, servers=10, x=2),
        ]
        static = cfgs[0].static_part()
        assert all(c.static_part() == static for c in cfgs)
        grid = simulate_grid([0, 3], static, [c.scenario() for c in cfgs])
        for cell, cfg in zip(grid, cfgs):
            for res, seed in zip(cell, (0, 3)):
                _assert_same(res, simulate(jax.random.key(seed), cfg))

    def test_horizon_mask_freezes_the_tail(self):
        """Slots past the traced horizon are no-ops: arrivals stop, nothing
        serves, no messages fire (RT would otherwise keep messaging
        through the padding)."""
        cfg = SimConfig(slots=1500, max_slots=4000, load=0.9, comm="rt",
                        rt_rate=0.05, approx="msr")
        r = simulate(jax.random.key(0), cfg)
        # ~0.9 * 1500 arrivals, not 0.9 * 4000.
        assert 1150 <= r.arrivals <= 1500
        # RT-0.05 on 30 servers: ~0.05 * 30 * 1500 messages, not * 4000.
        assert r.messages <= 0.05 * 30 * 1500 + 30
        assert r.arrivals == r.departures + int(r.final_q.sum())

    def test_unpadded_equals_padding_free_default(self):
        cfg = SimConfig(slots=2000, load=0.9, x=3)
        _assert_same(
            simulate(jax.random.key(1), cfg),
            simulate(
                jax.random.key(1), dataclasses.replace(cfg, max_slots=2000)
            ),
        )

    @pytest.mark.parametrize(
        "kind,tail", [("pareto", 1.6), ("weibull", 0.8), ("deterministic", 2.0)]
    )
    def test_service_kind_grid_matches_percell(self, kind, tail):
        """Heavy-tailed / deterministic sizes: tail and mean are traced per
        cell; the fused grid equals per-cell simulate bit for bit, and the
        distribution-free ET bound AQ <= x-1 (Prop 6.8) holds."""
        cfgs = [
            SimConfig(slots=2000, load=0.9, x=3, service=kind,
                      service_tail=tail, mean_service=20),
            SimConfig(slots=2000, load=0.8, x=2, service=kind,
                      service_tail=tail + 0.5, mean_service=35),
        ]
        static = cfgs[0].static_part()
        assert cfgs[1].static_part() == static
        grid = simulate_grid([2], static, [c.scenario() for c in cfgs])
        for cell, cfg in zip(grid, cfgs):
            _assert_same(cell[0], simulate(jax.random.key(2), cfg))
            assert cell[0].max_aq <= cfg.x - 1

    def test_mixed_service_kinds_fail_loudly(self):
        cfgs = [
            SimConfig(slots=1000, service="pareto", service_tail=2.0),
            SimConfig(slots=1000, service="weibull", service_tail=1.0),
        ]
        with pytest.raises(ValueError):
            slotted_sim.stack_scenarios([c.scenario() for c in cfgs])

    def test_diurnal_amp_zero_is_flat(self):
        """amp=0 is bit-identical to the unmodulated arrival stream, so
        flat cells share the diurnal cells' compiled program for free."""
        cfg = SimConfig(slots=2000, load=0.9, x=3)
        _assert_same(
            simulate(jax.random.key(4), cfg),
            simulate(
                jax.random.key(4),
                dataclasses.replace(cfg, diurnal_amp=0.0,
                                    diurnal_period=500.0),
            ),
        )

    def test_diurnal_grid_matches_percell(self):
        cfgs = [
            SimConfig(slots=2000, load=0.6, diurnal_amp=0.5,
                      diurnal_period=400.0),
            SimConfig(slots=2000, load=0.6, diurnal_amp=0.0),
        ]
        static = cfgs[0].static_part()
        assert cfgs[1].static_part() == static
        grid = simulate_grid([5], static, [c.scenario() for c in cfgs])
        for cell, cfg in zip(grid, cfgs):
            _assert_same(cell[0], simulate(jax.random.key(5), cfg))

    def test_max_slots_below_slots_rejected(self):
        with pytest.raises(ValueError):
            SimConfig(slots=2000, max_slots=1000).static_part()

    def test_diurnal_amp_validated(self):
        # Peaks above probability 1 would be silently clipped by the
        # u < rate draw, breaking the long-run-rate invariant.
        with pytest.raises(ValueError, match="peak"):
            SimConfig(load=0.95, diurnal_amp=0.5).scenario()
        with pytest.raises(ValueError, match="amp"):
            SimConfig(load=0.3, diurnal_amp=1.5).scenario()
        SimConfig(load=0.5, diurnal_amp=0.8).scenario()  # 0.9 <= 1: fine
        # mmpp clips at the modulated *burst-state* rate, not load:
        # lam_hi = 0.96, so amp=0.5 peaks at 1.44 even though load 0.6 fits.
        with pytest.raises(ValueError, match="mmpp"):
            SimConfig(arrival="mmpp", load=0.6, burst_intensity=1.6,
                      diurnal_amp=0.5).scenario()
        SimConfig(arrival="mmpp", load=0.3, burst_intensity=1.6,
                  diurnal_amp=0.5).scenario()  # 0.48 * 1.5 = 0.72: fine

    def test_diurnal_amp_validated_at_grid_boundary(self):
        # A hand-built Scenario (created without knowing the arrival kind)
        # must still be rejected where it meets an mmpp StaticConfig.
        scn = slotted_sim.Scenario.create(
            servers=30, load=0.6, burst_intensity=1.6, diurnal_amp=0.5
        )
        static = SimConfig(arrival="mmpp", load=0.6).static_part()
        with pytest.raises(ValueError, match="peak"):
            simulate_grid([0], static, [scn])


# ---------------------------------------------------------------------------
# 3. Padding + device topology.
# ---------------------------------------------------------------------------


class TestPadding:
    def test_pad_indices_multiple(self):
        idx = slotted_sim._pad_indices(8, 4)
        assert list(idx) == list(range(8))

    def test_pad_indices_ragged(self):
        idx = slotted_sim._pad_indices(9, 4)
        assert len(idx) == 12
        assert list(idx[:9]) == list(range(9))
        assert list(idx[9:]) == [0, 1, 2]  # wrap-around duplicates

    def test_pad_indices_fewer_runs_than_devices(self):
        idx = slotted_sim._pad_indices(3, 8)
        assert len(idx) == 8
        assert list(idx) == [0, 1, 2, 0, 1, 2, 0, 1]


_SUBPROCESS_SCRIPT = """
import json, sys
import numpy as np
import jax
from repro.core import SimConfig, simulate_grid

assert jax.local_device_count() == {n_dev}, jax.local_device_count()
cfgs = [
    SimConfig(slots=2000, load=0.95, x=3),
    SimConfig(slots=2000, load=0.8, x=2),
    SimConfig(slots=2000, load=0.5, x=4),
]
# 3 cells x 1 seed = 3 runs: ragged over {n_dev} devices, exercising padding.
grid = simulate_grid([11], cfgs[0].static_part(), [c.scenario() for c in cfgs])
print(json.dumps([
    dict(messages=r[0].messages, max_aq=r[0].max_aq,
         jct=np.asarray(r[0].jct).tolist())
    for r in grid
]))
"""


class TestDeviceTopology:
    @pytest.mark.slow
    def test_1_vs_8_device_consistency(self):
        """A ragged grid forced onto 8 host devices matches 1 device."""
        outs = {}
        for n_dev in (1, 8):
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={n_dev}"
            )
            env["JAX_PLATFORMS"] = "cpu"
            env["PYTHONPATH"] = "src" + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
            )
            proc = subprocess.run(
                [sys.executable, "-c", _SUBPROCESS_SCRIPT.format(n_dev=n_dev)],
                capture_output=True,
                text=True,
                timeout=600,
                env=env,
                cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            )
            assert proc.returncode == 0, proc.stderr[-2000:]
            outs[n_dev] = json.loads(proc.stdout)
        assert outs[1] == outs[8]


# ---------------------------------------------------------------------------
# dispatch tier: the vmapped seed batch equals the per-seed loop.
# ---------------------------------------------------------------------------


class TestDispatchBatch:
    def test_matches_sequential(self):
        cfg = DispatchSimConfig(steps=120, comm="et", x=4)
        batch = dispatch_batch([0, 1], cfg)
        for seed, b in zip([0, 1], batch):
            s = dispatch_simulate(seed, cfg)
            assert b.messages == s.messages
            assert np.allclose(b.gap, s.gap)
            assert np.allclose(b.backlog, s.backlog)
            assert abs(b.max_err - s.max_err) < 1e-5
