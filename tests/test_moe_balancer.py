"""Unit + property tests for the CARE MoE balancer and the dispatch sim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs.base import CareConfig
from repro.core import moe_balancer
from repro.core.dispatch_sim import DispatchSimConfig, simulate


def _state(l=2, e=8):
    return moe_balancer.BalancerState.init(l, e)


class TestSelectionBias:
    def test_zero_when_balanced(self):
        s = _state()
        s = dataclasses.replace(s, load_approx=jnp.full((2, 8), 5.0))
        b = moe_balancer.selection_bias(s, CareConfig())
        np.testing.assert_allclose(np.asarray(b), 0.0, atol=1e-5)

    def test_positive_for_overloaded(self):
        s = _state(1, 4)
        s = dataclasses.replace(
            s, load_approx=jnp.asarray([[10.0, 1.0, 1.0, 1.0]])
        )
        b = np.asarray(moe_balancer.selection_bias(s, CareConfig()))
        assert b[0, 0] > 0 and (b[0, 1:] < 0).all()

    def test_disabled_is_zero(self):
        s = _state()
        s = dataclasses.replace(
            s, load_approx=jax.random.uniform(jax.random.key(0), (2, 8))
        )
        b = moe_balancer.selection_bias(s, CareConfig(enabled=False))
        assert not np.asarray(b).any()

    def test_clip_bounds_proportional_term(self):
        cfg = CareConfig(bias_alpha=0.3, bias_clip=2.0)
        s = _state(1, 4)
        s = dataclasses.replace(
            s, load_approx=jnp.asarray([[1000.0, 0.0, 0.0, 0.0]])
        )
        b = np.asarray(moe_balancer.selection_bias(s, cfg))
        assert np.abs(b).max() <= cfg.bias_alpha * cfg.bias_clip + 1e-6


class TestPostStepUpdate:
    def test_drain_and_accumulate(self):
        cfg = CareConfig(drain=0.5, gamma=0.0)
        s = _state(1, 4)
        counts = jnp.asarray([[4.0, 0.0, 0.0, 0.0]])
        s = moe_balancer.post_step_update(s, counts, cfg)
        np.testing.assert_allclose(np.asarray(s.load_approx[0, 0]), 2.0)
        np.testing.assert_allclose(np.asarray(s.true_counts), np.asarray(counts))
        assert int(s.steps_since_sync) == 1

    def test_integral_bias_zero_mean(self):
        cfg = CareConfig(gamma=0.1)
        s = _state(1, 4)
        for _ in range(5):
            s = moe_balancer.post_step_update(
                s, jnp.asarray([[8.0, 2.0, 1.0, 1.0]]), cfg
            )
        b = np.asarray(s.bias)
        np.testing.assert_allclose(b.mean(axis=-1), 0.0, atol=1e-5)
        assert b[0, 0] > 0  # persistently overloaded expert accumulates bias

    def test_integral_cancels_persistent_skew(self):
        """PI controller drives a constant-skew dispatch toward balance."""
        cfg = CareConfig(bias_alpha=0.3, gamma=0.05)
        s = _state(1, 8)
        skew = jnp.asarray([2.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, -1.0])
        tokens = 256

        def dispatch(bias):
            # Soft router: counts proportional to softmax(skew - bias).
            p = jax.nn.softmax(skew - bias[0])
            return (tokens * p)[None, :]

        imb0 = imb = None
        for i in range(200):
            counts = dispatch(moe_balancer.selection_bias(s, cfg))
            s = moe_balancer.post_step_update(s, counts, cfg)
            imb = float(jnp.max(counts) / jnp.mean(counts))
            if i == 0:
                imb0 = imb
        assert imb0 > 2.0  # skewed at the start
        assert imb < 1.15  # integral bias cancelled the skew


class TestSync:
    def test_single_dispatcher_snap_is_noop(self):
        """Remark 4.6: one dispatcher knows everything -- nothing to learn."""
        cfg = CareConfig()
        s = _state(1, 4)
        for c in ([[3.0, 1.0, 0.0, 0.0]], [[0.0, 2.0, 2.0, 0.0]]):
            s = moe_balancer.post_step_update(s, jnp.asarray(c), cfg)
        before = np.asarray(s.load_approx)
        s2 = moe_balancer.sync(s, cfg)
        np.testing.assert_allclose(np.asarray(s2.load_approx), before)
        assert int(s2.steps_since_sync) == 0
        assert not np.asarray(s2.true_counts).any()

    def test_multi_dispatcher_snap_to_global_mean(self):
        cfg = CareConfig()
        z = jnp.zeros((1, 2, 1, 4), jnp.float32)
        s = moe_balancer.BalancerState(
            load_approx=z,
            true_load=jnp.asarray(
                [[[[4.0, 0.0, 0.0, 0.0]], [[0.0, 4.0, 0.0, 0.0]]]]
            ),
            true_counts=z,
            bias=z,
            steps_since_sync=jnp.asarray(3, jnp.int32),
        )
        s2 = moe_balancer.sync(s, cfg)
        got = np.asarray(s2.load_approx)
        np.testing.assert_allclose(got[0, 0, 0], [2.0, 2.0, 0.0, 0.0])
        np.testing.assert_allclose(got[0, 1, 0], [2.0, 2.0, 0.0, 0.0])


class TestNeedsSync:
    def test_dt_counts_steps(self):
        cfg = CareConfig(comm="dt", x=3)
        s = _state()
        for i in range(3):
            assert not bool(moe_balancer.needs_sync(s, cfg)) or i == 3
            s = moe_balancer.post_step_update(s, jnp.ones((2, 8)), cfg)
        assert bool(moe_balancer.needs_sync(s, cfg))

    def test_et_fires_on_divergence(self):
        cfg = CareConfig(comm="et", x=2)
        s = _state(1, 4)
        s = dataclasses.replace(
            s,
            load_approx=jnp.asarray([[0.0, 0.0, 0.0, 0.0]]),
            true_load=jnp.asarray([[10.0, 1.0, 1.0, 0.0]]),
        )
        assert bool(moe_balancer.needs_sync(s, cfg))

    def test_et_silent_when_exact(self):
        cfg = CareConfig(comm="et", x=2)
        s = _state()
        for _ in range(10):
            s = moe_balancer.post_step_update(s, jnp.ones((2, 8)), cfg)
        assert not bool(moe_balancer.needs_sync(s, cfg))


@given(
    counts=st.lists(
        st.lists(st.floats(0.0, 100.0), min_size=4, max_size=4),
        min_size=1,
        max_size=6,
    ),
    drain=st.floats(0.1, 0.99),
)
@settings(max_examples=30, deadline=None)
def test_property_true_load_tracks_emulation_single_dispatcher(counts, drain):
    """With one dispatcher, load_approx == true_load at every step."""
    cfg = CareConfig(drain=drain)
    s = moe_balancer.BalancerState.init(1, 4)
    for c in counts:
        s = moe_balancer.post_step_update(s, jnp.asarray([c]), cfg)
        np.testing.assert_allclose(
            np.asarray(s.load_approx), np.asarray(s.true_load), rtol=1e-5
        )


class TestDispatchSim:
    @pytest.fixture(scope="class")
    def small(self):
        return dict(experts=16, dispatchers=4, tokens_per_step=64, top_k=2,
                    steps=200)

    def test_exact_bounds_error(self, small):
        r = simulate(0, DispatchSimConfig(comm="exact", x=1, **small))
        # Error is measured before the snap: bounded by one step's surprise.
        assert r.max_err < 8.0
        assert r.msgs_per_step == small["dispatchers"]

    def test_et_bounds_error_near_threshold(self, small):
        x = 3
        r = simulate(0, DispatchSimConfig(comm="et", x=x, **small))
        # Between messages the error stays below x + one step's growth.
        assert r.max_err < x + 6.0

    def test_et_uses_less_communication(self, small):
        r_et = simulate(0, DispatchSimConfig(comm="et", x=4, **small))
        r_ex = simulate(0, DispatchSimConfig(comm="exact", x=1, **small))
        assert r_et.messages < 0.5 * r_ex.messages

    def test_bias_beats_no_bias(self, small):
        r_b = simulate(0, DispatchSimConfig(comm="et", x=4, **small))
        r_nb = simulate(
            0, DispatchSimConfig(enabled=False, comm="off", **small)
        )
        assert r_b.tail_gap < 0.5 * r_nb.tail_gap

    def test_queue_is_stable_under_balancing(self, small):
        r = simulate(0, DispatchSimConfig(comm="et", x=4, **small))
        # Utilisation < 1 and balanced -> backlog stays bounded (no blow-up).
        assert r.tail_backlog < 50 * DispatchSimConfig(**small).mu
