"""Substrate tests: data pipeline, checkpointing, optimizer, elastic plan,
serving engine, and the end-to-end train driver (crash -> restore)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.ckpt import checkpoint
from repro.data import pipeline
from repro.optim import adamw
from repro.serve.engine import EngineConfig, run_serving_sim
from repro.train import elastic


CFG = pipeline.DataConfig(vocab_size=512, seq_len=64, global_batch=8)


class TestPipeline:
    def test_deterministic(self):
        a = pipeline.global_batch_at(3, CFG)
        b = pipeline.global_batch_at(3, CFG)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_shifted(self):
        b = pipeline.global_batch_at(0, CFG)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_steps_differ(self):
        a = pipeline.global_batch_at(0, CFG)
        b = pipeline.global_batch_at(1, CFG)
        assert (a["tokens"] != b["tokens"]).any()

    @given(dp_size=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 50))
    @settings(max_examples=20, deadline=None)
    def test_property_shards_partition_global(self, dp_size, step):
        """Union of shards == the global batch, regardless of dp_size
        (elastic re-sharding keeps the global stream identical)."""
        glob = pipeline.global_batch_at(step, CFG)["tokens"]
        rows = np.zeros_like(glob)
        for r in range(dp_size):
            shard = pipeline.shard_batch_at(step, CFG, r, dp_size)["tokens"]
            rows[r::dp_size] = shard
        np.testing.assert_array_equal(rows, glob)

    def test_loader_skip_to(self):
        l1 = pipeline.ShardedLoader(CFG, start_step=5)
        l2 = pipeline.ShardedLoader(CFG)
        l2.skip_to(5)
        np.testing.assert_array_equal(next(l1)["tokens"], next(l2)["tokens"])

    def test_vocab_bounds(self):
        b = pipeline.global_batch_at(0, CFG)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < CFG.vocab_size


class TestCheckpoint:
    def _state(self, key=0):
        k = jax.random.key(key)
        return {
            "w": jax.random.normal(k, (4, 8)),
            "opt": {"m": jnp.zeros((4, 8)), "step": jnp.asarray(7)},
        }

    def test_roundtrip(self, tmp_path):
        s = self._state()
        checkpoint.save(s, tmp_path, 10)
        got, step = checkpoint.restore(self._state(1), tmp_path)
        assert step == 10
        np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(s["w"]))

    def test_latest_and_rotation(self, tmp_path):
        s = self._state()
        for st_ in (1, 2, 3, 4, 5):
            checkpoint.save(s, tmp_path, st_, keep=2)
        assert checkpoint.all_steps(tmp_path) == [4, 5]
        assert checkpoint.latest_step(tmp_path) == 5

    def test_shape_mismatch_raises(self, tmp_path):
        checkpoint.save(self._state(), tmp_path, 1)
        bad = {"w": jnp.zeros((2, 2)),
               "opt": {"m": jnp.zeros((4, 8)), "step": jnp.asarray(0)}}
        with pytest.raises(ValueError):
            checkpoint.restore(bad, tmp_path)

    def test_async_save(self, tmp_path):
        checkpoint.async_save(self._state(), tmp_path, 3)
        checkpoint.wait_pending()
        assert checkpoint.latest_step(tmp_path) == 3


class TestAdamW:
    def test_decreases_quadratic_loss(self):
        cfg = adamw.OptimConfig(lr=0.1, warmup_steps=1, total_steps=100,
                                weight_decay=0.0)
        params = {"w": jnp.asarray([2.0, -3.0])}
        opt = adamw.init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw.update(g, opt, params, cfg)
        assert float(loss(params)) < 0.05

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.OptimConfig(lr=1.0, warmup_steps=10, total_steps=100)
        lr0 = float(adamw.schedule(jnp.asarray(0), cfg))
        lr10 = float(adamw.schedule(jnp.asarray(10), cfg))
        lr100 = float(adamw.schedule(jnp.asarray(100), cfg))
        assert lr0 < 0.2 and abs(lr10 - 1.0) < 1e-5
        assert abs(lr100 - cfg.min_lr_frac) < 1e-2

    def test_clipping_bounds_update(self):
        cfg = adamw.OptimConfig(lr=1.0, clip_norm=1.0, warmup_steps=1,
                                weight_decay=0.0)
        params = {"w": jnp.zeros((4,))}
        opt = adamw.init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw.update(g, opt, params, cfg)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


class TestElastic:
    def test_full_pod(self):
        p = elastic.plan_mesh(256)
        assert (p.pods, p.data, p.model) == (1, 16, 16)
        assert p.dropped_chips == 0

    def test_degraded_pod_sheds_dp(self):
        p = elastic.plan_mesh(240)  # lost a host (16 chips)
        assert p.model == 16 and p.data <= 15
        assert p.chips <= 240

    def test_multi_pod(self):
        p = elastic.plan_mesh(512)
        assert (p.pods, p.data, p.model) == (2, 16, 16)

    def test_remesh_plan_flags_recompile(self):
        a, b = elastic.plan_mesh(512), elastic.plan_mesh(256)
        plan = elastic.remesh_plan(a, b)
        assert plan["recompile"] and plan["dp_new"] < plan["dp_old"]

    def test_straggler_detection_sparse_messages(self):
        mon = elastic.StragglerMonitor(num_hosts=4, evict_after=3)
        rng = np.random.default_rng(0)
        for step in range(50):
            for h in range(4):
                t = 1.0 + 0.01 * rng.standard_normal()
                if h == 3:
                    t *= 3.0  # persistent straggler
                mon.host_report(h, t)
            mon.evictions()
        assert 3 in mon.evictions() or mon.strikes[3] >= 3
        assert mon.message_rate < 0.5  # ET telemetry stays sparse


class TestServingEngine:
    def test_et_matches_exact_jct(self):
        ex = run_serving_sim(EngineConfig(comm="exact"), slots=4000, load=0.8)
        et = run_serving_sim(EngineConfig(comm="et", et_x=4), slots=4000,
                             load=0.8)
        assert et["mean_jct"] <= 1.1 * ex["mean_jct"]
        # Prop 6.9: MSR emulation may message slightly more than 1/dep at
        # small x (emulated-departure triggers); stays bounded.
        assert et["msgs_per_completion"] <= 1.3

    def test_et_large_x_is_sparse(self):
        ex = run_serving_sim(EngineConfig(comm="exact"), slots=4000, load=0.8)
        et = run_serving_sim(EngineConfig(comm="et", et_x=16), slots=4000,
                             load=0.8)
        assert et["mean_jct"] <= 1.15 * ex["mean_jct"]
        assert et["msgs_per_completion"] <= 0.4

    def test_all_offered_eventually_complete_under_capacity(self):
        r = run_serving_sim(EngineConfig(comm="et"), slots=6000, load=0.5)
        assert r["completed"] >= 0.95 * r["offered"]

    def test_exact_is_one_message_per_completion(self):
        r = run_serving_sim(EngineConfig(comm="exact"), slots=3000, load=0.8)
        assert abs(r["msgs_per_completion"] - 1.0) < 1e-6


class TestTrainDriver:
    def test_crash_restart_resumes_stream(self, tmp_path):
        from repro.launch import train as train_driver

        args = ["--arch", "smollm-135m", "--steps", "8", "--batch", "2",
                "--seq", "32", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "2", "--log-every", "0"]
        with pytest.raises(SystemExit) as e:
            train_driver.main(args + ["--crash-at", "4"])
        assert e.value.code == 42
        assert checkpoint.latest_step(tmp_path) == 4
        out = train_driver.main(args)
        assert np.isfinite(out["final_loss"])
