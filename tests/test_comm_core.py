"""Tests for the unified communication core (``repro.core.care.comm``).

Three layers of evidence that the consolidation onto one protocol module
did not change the physics:

* **Golden regression** -- message counts, ``max_aq``, departures, arrivals
  and mean JCT on fixed seeds must equal, bit for bit, the values produced
  by the seed (pre-refactor) simulators.  Same for the MoE dispatch tier.
* **Reference replay** -- ``comm.evaluate`` is replayed against a
  straight-line Python reference of the paper's trigger semantics on random
  sample paths, for every pattern and both array backends (numpy / jax).
* **Batch equivalence** -- ``simulate_batch`` must reproduce per-seed
  ``simulate`` exactly (vmap is semantics-preserving).
"""
import jax
import numpy as np
import pytest

from repro.core import dispatch_sim
from repro.core.care import comm as comm_lib
from repro.core.care import slotted_sim, workload

KEY7 = jax.random.key(7)

# Captured from the seed simulator (commit 7874f0a) at slots=20_000,
# key=jax.random.key(7): (messages, max_aq, departures, arrivals, mean_jct).
SLOTTED_GOLDEN = {
    ("et", "msr", 3, 0.95, "jsaq"): (2087, 2, 18913, 18994, 79.80040184000423),
    ("et", "msr", 5, 0.9, "jsaq"): (421, 4, 17859, 17964, 90.51251469847136),
    ("et", "msr_x", 3, 0.95, "jsaq"): (3888, 2, 18922, 18994, 65.55623084240567),
    ("dt", "msr_x", 3, 0.9, "jsaq"): (5956, 2, 17899, 17964, 55.152857701547575),
    ("dt", "basic", 2, 0.8, "jsaq"): (7998, 1, 16015, 16040, 37.49572275991258),
    ("rt", "msr", 3, 0.9, "jsaq"): (6000, 4, 17889, 17964, 70.11940298507463),
    ("none", "msr", 3, 0.95, "jsq"): (0, 40, 18950, 18994, 37.47646437994723),
}

# Seed dispatch simulator at steps=120, x=2, seed=0: messages per comm mode.
DISPATCH_GOLDEN = {"exact": 960, "dt": 480, "et": 652, "off": 0}


class TestGoldenRegression:
    @pytest.mark.parametrize("case", sorted(SLOTTED_GOLDEN, key=str))
    def test_slotted_matches_seed_simulator(self, case):
        comm, approx, x, load, policy = case
        cfg = slotted_sim.SimConfig(
            slots=20_000, comm=comm, approx=approx, x=x, load=load, policy=policy
        )
        r = slotted_sim.simulate(KEY7, cfg)
        msgs, max_aq, deps, arrs, mean_jct = SLOTTED_GOLDEN[case]
        assert r.messages == msgs
        assert r.max_aq == max_aq
        assert r.departures == deps
        assert r.arrivals == arrs
        assert float(r.jct.mean()) == pytest.approx(mean_jct, rel=1e-12)
        # Thm 2.3 / Prop 6.8: deterministic AQ bound for DT-x and ET-x.
        if comm in ("dt", "et"):
            assert r.max_aq <= x - 1

    @pytest.mark.parametrize("comm", sorted(DISPATCH_GOLDEN))
    def test_dispatch_matches_seed_simulator(self, comm):
        cfg = dispatch_sim.DispatchSimConfig(steps=120, comm=comm, x=2)
        r = dispatch_sim.simulate(0, cfg)
        assert r.messages == DISPATCH_GOLDEN[comm]


def _reference_replay(kind, x, period, errs, deps):
    """Straight-line reference of the paper's trigger semantics."""
    k = errs.shape[1]
    deps_since = np.zeros(k, int)
    slots_since = np.zeros(k, int)
    msgs = 0
    trig_log = []
    for t in range(errs.shape[0]):
        deps_since = deps_since + deps[t]
        slots_since = slots_since + 1
        if kind == "rt":
            trig = slots_since >= period
        elif kind == "dt":
            trig = deps_since >= x
        elif kind == "et":
            trig = errs[t] >= x
        elif kind == "et_rt":
            trig = (errs[t] >= x) | (slots_since >= period)
        elif kind == "exact":
            trig = deps[t] > 0
        else:
            trig = np.zeros(k, bool)
        msgs += int(deps[t].sum()) if kind == "exact" else int(trig.sum())
        deps_since = np.where(trig, 0, deps_since)
        slots_since = np.where(trig, 0, slots_since)
        trig_log.append(trig.copy())
    return np.array(trig_log), msgs


class TestEvaluateAgainstReference:
    KINDS = ["none", "rt", "dt", "et", "et_rt", "exact"]

    @pytest.mark.parametrize("xp_name", ["numpy", "jax"])
    @pytest.mark.parametrize("kind", KINDS)
    def test_replay(self, kind, xp_name):
        import jax.numpy as jnp

        xp = np if xp_name == "numpy" else jnp
        rng = np.random.default_rng(42)
        t, k, x, period = 200, 5, 3, 7
        errs = rng.integers(0, 5, (t, k))
        deps = rng.integers(0, 2, (t, k))
        cfg = comm_lib.CommConfig(kind=kind, x=x, rt_period=period)
        state = comm_lib.CommState.init(k, xp=xp)
        trig_log = []
        for i in range(t):
            trig, state = comm_lib.evaluate(
                state, cfg, xp.asarray(errs[i]), xp.asarray(deps[i]), xp=xp
            )
            trig_log.append(np.asarray(trig))
        ref_trig, ref_msgs = _reference_replay(kind, x, period, errs, deps)
        np.testing.assert_array_equal(np.array(trig_log), ref_trig)
        assert int(state.msgs) == ref_msgs

    def test_et_resets_counters_only_for_triggered(self):
        state = comm_lib.CommState.init(3, xp=np)
        cfg = comm_lib.CommConfig(kind="et", x=2)
        trig, state = comm_lib.evaluate(
            state, cfg, np.array([0, 2, 5]), np.array([1, 1, 1]), xp=np
        )
        np.testing.assert_array_equal(trig, [False, True, True])
        np.testing.assert_array_equal(state.deps_since_msg, [1, 0, 0])
        np.testing.assert_array_equal(state.slots_since_msg, [1, 0, 0])
        assert int(state.msgs) == 2


def _pull_reference_replay(kind, x, period, qs, deps):
    """Straight-line reference of the pull-token trigger semantics.

    ``qs[t]`` is the end-of-slot queue length, ``deps[t]`` that slot's
    departures: jiq fires on the idle transition (departures emptied the
    queue), hsq on a downward crossing of ``x`` or after ``period``
    silent slots (the token-refresh keepalive)."""
    k = qs.shape[1]
    slots_since = np.zeros(k, int)
    msgs = 0
    trig_log = []
    for t in range(qs.shape[0]):
        slots_since = slots_since + 1
        if kind == "jiq":
            trig = (deps[t] > 0) & (qs[t] == 0)
        else:  # hsq
            trig = ((qs[t] < x) & (qs[t] + deps[t] >= x)) | (
                slots_since >= period
            )
        msgs += int(trig.sum())
        slots_since = np.where(trig, 0, slots_since)
        trig_log.append(trig.copy())
    return np.array(trig_log), msgs


class TestPullTriggerAgainstReference:
    @pytest.mark.parametrize("xp_name", ["numpy", "jax"])
    @pytest.mark.parametrize("kind", ["jiq", "hsq"])
    def test_replay(self, kind, xp_name):
        import jax.numpy as jnp

        xp = np if xp_name == "numpy" else jnp
        rng = np.random.default_rng(17)
        t, k, x, period = 200, 5, 3, 7
        qs = rng.integers(0, 6, (t, k))
        deps = rng.integers(0, 2, (t, k))
        cfg = comm_lib.CommConfig(kind=kind, x=x, rt_period=period)
        state = comm_lib.CommState.init(k, xp=xp)
        trig_log = []
        for i in range(t):
            trig, state = comm_lib.evaluate(
                state, cfg, xp.zeros(k), xp.asarray(deps[i]), xp=xp,
                q=xp.asarray(qs[i]),
            )
            trig_log.append(np.asarray(trig))
        ref_trig, ref_msgs = _pull_reference_replay(kind, x, period, qs, deps)
        np.testing.assert_array_equal(np.array(trig_log), ref_trig)
        assert int(state.msgs) == ref_msgs

    def test_jiq_fires_only_on_idle_transition(self):
        cfg = comm_lib.CommConfig(kind="jiq")
        state = comm_lib.CommState.init(4, xp=np)
        # busy+departure, idle+departure, idle+no-departure, busy only.
        trig, state = comm_lib.evaluate(
            state, cfg, np.zeros(4), np.array([1, 1, 0, 0]), xp=np,
            q=np.array([2, 0, 0, 3]),
        )
        np.testing.assert_array_equal(trig, [False, True, False, False])
        assert int(state.msgs) == 1

    def test_hsq_keepalive_refires_after_silent_period(self):
        # No threshold crossing anywhere: the rt_period keepalive alone
        # must fire every `period` slots -- the traced token-refresh rate
        # (and what keeps suspect detection non-vacuous under jiq-style
        # silence).
        cfg = comm_lib.CommConfig(kind="hsq", x=3, rt_period=4)
        state = comm_lib.CommState.init(2, xp=np)
        fired_at = []
        for t in range(12):
            trig, state = comm_lib.evaluate(
                state, cfg, np.zeros(2), np.zeros(2, int), xp=np,
                q=np.array([5, 5]),  # always far above threshold
            )
            if bool(trig.any()):
                fired_at.append(t)
        assert fired_at == [3, 7, 11]

    def test_crashed_sender_defers_token_until_recovery(self):
        # can_send=False suppresses the send but counters keep advancing,
        # so the first healthy slot re-fires the due keepalive -- the
        # stale-token drain/recovery path of the pull policies.
        cfg = comm_lib.CommConfig(kind="hsq", x=3, rt_period=2)
        state = comm_lib.CommState.init(1, xp=np)
        down = np.array([False])
        for _ in range(5):
            trig, state = comm_lib.evaluate(
                state, cfg, np.zeros(1), np.zeros(1, int), xp=np,
                q=np.array([5]), can_send=down,
            )
            assert not bool(trig.any())
        up = np.array([True])
        trig, state = comm_lib.evaluate(
            state, cfg, np.zeros(1), np.zeros(1, int), xp=np,
            q=np.array([5]), can_send=up,
        )
        assert bool(trig.all())
        assert int(state.msgs) == 1


class TestBatchEquivalence:
    def test_simulate_batch_matches_sequential(self):
        cfg = slotted_sim.SimConfig(
            slots=4_000, comm="et", approx="msr", x=3, load=0.95
        )
        seeds = [0, 1, 2, 3]
        batch = slotted_sim.simulate_batch(seeds, cfg)
        for s, b in zip(seeds, batch):
            r = slotted_sim.simulate(jax.random.key(s), cfg)
            assert r.messages == b.messages
            assert r.max_aq == b.max_aq
            assert r.arrivals == b.arrivals
            assert r.departures == b.departures
            np.testing.assert_array_equal(r.jct, b.jct)
            np.testing.assert_array_equal(r.final_q, b.final_q)

    def test_simulate_batch_accepts_key_array(self):
        import jax.numpy as jnp

        cfg = slotted_sim.SimConfig(slots=2_000)
        keys = jnp.stack([jax.random.key(s) for s in (5, 6)])
        res = slotted_sim.simulate_batch(keys, cfg)
        assert len(res) == 2
        ref = slotted_sim.simulate(jax.random.key(5), cfg)
        assert res[0].messages == ref.messages


class TestHybridTrigger:
    def test_et_rt_bounds_error_and_staleness(self):
        # Light traffic: plain ET can stay silent for long stretches; the
        # hybrid adds RT fallback messages yet keeps the deterministic bound.
        base = dict(slots=8_000, x=4, load=0.5, policy="jsaq", approx="msr")
        r_et = slotted_sim.simulate(
            KEY7, slotted_sim.SimConfig(comm="et", **base)
        )
        r_hyb = slotted_sim.simulate(
            KEY7, slotted_sim.SimConfig(comm="et_rt", rt_rate=0.02, **base)
        )
        assert r_hyb.max_aq <= 3  # ET part still guarantees AQ <= x-1
        assert r_hyb.messages >= r_et.messages
        # RT fallback floor: every server reports at least every 50 slots.
        assert r_hyb.messages >= (8_000 // 50) * 30


class TestScenarios:
    def test_mmpp_long_run_rate(self):
        arr = workload.mmpp_arrivals(jax.random.key(0), 60_000, 0.8, 1.7, 0.98)
        assert float(np.asarray(arr).mean()) == pytest.approx(0.8, abs=0.03)

    def test_mmpp_intensity_one_is_bernoulli_rate(self):
        arr = workload.mmpp_arrivals(jax.random.key(1), 40_000, 0.6, 1.0, 0.98)
        assert float(np.asarray(arr).mean()) == pytest.approx(0.6, abs=0.03)

    def test_service_units_long_run_average(self):
        rates = np.array([0.5, 1.0, 1.5, 0.3], np.float32)
        t = 1000
        units = np.stack(
            [
                np.asarray(workload.service_units(np.int32(i), rates))
                for i in range(t)
            ]
        )
        np.testing.assert_allclose(units.mean(0), rates, atol=2 / t)

    def test_bursty_sim_keeps_et_bound_and_conservation(self):
        cfg = slotted_sim.SimConfig(
            slots=10_000, arrival="mmpp", burst_intensity=1.7, load=0.9,
            comm="et", x=3, approx="msr",
        )
        r = slotted_sim.simulate(jax.random.key(0), cfg)
        assert r.max_aq <= 2
        assert r.arrivals == r.departures + int(np.asarray(r.final_q).sum())

    def test_hetero_rate_aware_prefers_fast_servers(self):
        rates = tuple(1.5 if i < 15 else 0.5 for i in range(30))
        cfg = slotted_sim.SimConfig(
            slots=10_000, service_rates=rates, load=0.85,
            comm="et", x=3, approx="msr",
        )
        r = slotted_sim.simulate(jax.random.key(0), cfg)
        fast = int(r.per_server_arrivals[:15].sum())
        slow = int(r.per_server_arrivals[15:].sum())
        assert fast > 2 * slow  # drain-time-aware JSAQ tracks capacity
        assert r.arrivals == r.departures + int(np.asarray(r.final_q).sum())
        assert r.max_aq <= 2  # ET bound holds under heterogeneity too

    def test_full_fifo_drops_instead_of_corrupting(self):
        # One server, tiny buffer, overload: the ring must drop beyond-cap
        # arrivals (counted) and conservation must hold over admitted jobs.
        cfg = slotted_sim.SimConfig(
            servers=1, slots=2_000, load=0.9, mean_service=30,
            buffer_cap=4, policy="rr", comm="none",
        )
        r = slotted_sim.simulate(jax.random.key(0), cfg)
        assert r.overflow
        assert r.dropped > 0
        assert r.max_queue <= 4
        assert r.arrivals == r.departures + int(np.asarray(r.final_q).sum())


class TestServingEngine:
    """Hypothesis-free coverage of the vectorised serving tier (the
    substrate suite that also exercises it skips entirely when hypothesis
    is missing)."""

    def test_exact_comm_one_message_per_completion(self):
        from repro.serve import engine

        r = engine.run_serving_sim(
            engine.EngineConfig(comm="exact"), slots=2_000, load=0.8, seed=1
        )
        assert r["completed"] > 0
        assert r["messages"] == r["completed"]

    def test_et_is_sparse_and_serves_comparable_jct(self):
        from repro.serve import engine

        ex = engine.run_serving_sim(
            engine.EngineConfig(comm="exact"), slots=3_000, load=0.8, seed=2
        )
        et = engine.run_serving_sim(
            engine.EngineConfig(comm="et", et_x=8), slots=3_000, load=0.8, seed=2
        )
        assert et["msgs_per_completion"] < 0.7
        assert et["mean_jct"] <= ex["mean_jct"] * 1.25

    def test_zero_work_request_completes(self):
        from repro.serve import engine

        disp = engine.CareDispatcher(engine.EngineConfig(comm="et"), seed=0)
        disp.route(engine.Request(rid=0, arrival=0, prefill_cost=0, decode_len=0), 0)
        finished = disp.step(0)
        assert [r.rid for r in finished] == [0]
        assert disp._store == {}

    def test_queue_ring_grows_under_overload(self):
        from repro.serve import engine

        cfg = engine.EngineConfig(num_replicas=2, decode_slots=1)
        disp = engine.CareDispatcher(cfg, seed=0, queue_cap=4)
        for rid in range(32):  # far beyond 2 replicas * cap 4
            disp.route(
                engine.Request(rid=rid, arrival=0, prefill_cost=1, decode_len=1),
                0,
            )
        assert int(disp.true_occupancy().sum()) == 32
        done = []
        for now in range(200):
            done.extend(disp.step(now))
        assert len(done) == 32
