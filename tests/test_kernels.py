"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes and dtypes per the assignment; integer outputs must match
bit-for-bit, float outputs to allclose tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


class TestJsaqRoute:
    @pytest.mark.parametrize("d", [8, 16, 40])
    @pytest.mark.parametrize("k", [8, 30, 128])
    @pytest.mark.parametrize("n", [1, 7, 32])
    def test_matches_ref(self, d, k, n):
        key = jax.random.key(d * 1000 + k * 10 + n)
        q = jax.random.randint(key, (d, k), 0, 50, jnp.int32)
        idx_p, q_p = ops.jsaq_route(q, n, interpret=True)
        idx_r, q_r = ref.jsaq_route_ref(q, n)
        np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_r))
        np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))

    def test_padding_path(self):
        # Non-multiple of the domain tile exercises the padding wrapper.
        q = jax.random.randint(jax.random.key(0), (13, 16), 0, 9, jnp.int32)
        idx_p, q_p = ops.jsaq_route(q, 5, interpret=True)
        idx_r, q_r = ref.jsaq_route_ref(q, 5)
        np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_r))
        np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))

    def test_balances(self):
        # Routing many jobs from a uniform state must end near-uniform:
        # max-min <= 1 after any number of JSAQ dispatches.
        q = jnp.zeros((8, 32), jnp.int32)
        _, q_out = ops.jsaq_route(q, 100, interpret=True)
        gap = np.asarray(q_out.max(axis=1) - q_out.min(axis=1))
        assert (gap <= 1).all()

    def test_conservation(self):
        q = jax.random.randint(jax.random.key(3), (8, 16), 0, 20, jnp.int32)
        _, q_out = ops.jsaq_route(q, 17, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(q_out.sum(axis=1)), np.asarray(q.sum(axis=1)) + 17
        )

    @pytest.mark.parametrize("k", [130, 200, 300])
    def test_lane_tile_segmented(self, k):
        # K beyond one 128-lane tile exercises the segmented reduction
        # (per-tile argmin + cross-tile combine) and the lane padding.
        q = jax.random.randint(jax.random.key(k), (8, k), 0, 50, jnp.int32)
        idx_p, q_p = ops.jsaq_route(q, 9, interpret=True)
        idx_r, q_r = ref.jsaq_route_ref(q, 9)
        np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_r))
        np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))

    def test_pad_lanes_never_win(self):
        # K not a multiple of 128: the wrapper must mask pad lanes to the
        # dtype max so the argmin can never route to one, even when every
        # real queue is huge (on a real TPU unmasked pads are undefined).
        q = jnp.full((8, 130), 10**6, jnp.int32)
        idx_p, q_p = ops.jsaq_route(q, 32, interpret=True)
        assert (np.asarray(idx_p) < 130).all()
        np.testing.assert_array_equal(
            np.asarray(q_p.sum(axis=1)), 130 * 10**6 + 32
        )

    def test_ties_lowest_index(self):
        # Segmented cross-tile combine must pick the lowest *global* index
        # among tied minima (matching jnp.argmin), not the lowest lane
        # within the winning tile.
        q = jnp.full((8, 260), 7, jnp.int32)
        q = q.at[:, 3].set(1).at[:, 200].set(1)
        idx_p, _ = ops.jsaq_route(q, 1, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx_p[:, 0]), 3)
        # And when only a later tile holds the minimum:
        q2 = jnp.full((8, 260), 7, jnp.int32).at[:, 200].set(1)
        idx2, _ = ops.jsaq_route(q2, 1, interpret=True)
        np.testing.assert_array_equal(np.asarray(idx2[:, 0]), 200)


class TestMoeRoute:
    @pytest.mark.parametrize("t", [128, 256])
    @pytest.mark.parametrize("e", [16, 64, 256])
    @pytest.mark.parametrize("k", [1, 2, 8])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, t, e, k, dtype):
        key = jax.random.key(t + e + k)
        logits = jax.random.normal(key, (t, e), dtype)
        bias = jax.random.normal(jax.random.fold_in(key, 1), (e,), jnp.float32)
        idx_p, w_p, c_p = ops.moe_route(logits, bias, k, interpret=True)
        idx_r, w_r, c_r = ref.moe_route_ref(logits, bias, k)
        np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_r))
        np.testing.assert_allclose(
            np.asarray(w_p), np.asarray(w_r), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(c_p), np.asarray(c_r))

    @pytest.mark.parametrize("gate_fn", ["softmax", "sigmoid"])
    def test_gate_fns(self, gate_fn):
        logits = jax.random.normal(jax.random.key(9), (128, 32))
        bias = jnp.zeros((32,))
        idx_p, w_p, c_p = ops.moe_route(
            logits, bias, 4, gate_fn=gate_fn, interpret=True
        )
        idx_r, w_r, c_r = ref.moe_route_ref(logits, bias, 4, gate_fn)
        np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_r))
        np.testing.assert_allclose(
            np.asarray(w_p), np.asarray(w_r), rtol=1e-5, atol=1e-6
        )

    def test_padding_and_count_correction(self):
        # 200 tokens pads to 256; phantom tokens must not pollute counts.
        logits = jax.random.normal(jax.random.key(5), (200, 16))
        bias = jnp.zeros((16,))
        idx, w, counts = ops.moe_route(logits, bias, 2, interpret=True)
        assert idx.shape == (200, 2)
        assert int(counts.sum()) == 200 * 2

    def test_bias_steers_selection(self):
        # A huge bias on expert 0 must divert all tokens away from it,
        # while weights stay derived from the *unbiased* gates.
        logits = jnp.zeros((128, 8))
        bias = jnp.zeros((8,)).at[0].set(1e9)
        idx, w, counts = ops.moe_route(logits, bias, 2, interpret=True)
        assert int(counts[0]) == 0

    def test_weights_normalised(self):
        logits = jax.random.normal(jax.random.key(11), (128, 64))
        _, w, _ = ops.moe_route(logits, jnp.zeros((64,)), 8, interpret=True)
        np.testing.assert_allclose(np.asarray(w.sum(axis=1)), 1.0, rtol=1e-5)

    def test_topk_matches_lax_topk_when_unbiased(self):
        # With zero bias the selected set must equal lax.top_k's set.
        logits = jax.random.normal(jax.random.key(13), (128, 32))
        idx, _, _ = ops.moe_route(logits, jnp.zeros((32,)), 4, interpret=True)
        _, topk_idx = jax.lax.top_k(logits, 4)
        np.testing.assert_array_equal(
            np.sort(np.asarray(idx), axis=1), np.sort(np.asarray(topk_idx), axis=1)
        )
