"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret=True).

Shape/dtype sweep per the kernel-testing contract: every (S, T, heads,
GQA group, dtype, mask variant) cell asserts allclose against
``kernels.ref.flash_attention_ref``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(key, b, s, t, h, kvh, dh, dv, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kvh, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kvh, dv), jnp.float32).astype(dtype)
    return q, k, v


def _run(q, k, v, **kw):
    scale = kw.pop("scale", 1.0 / q.shape[-1] ** 0.5)
    out = ops.flash_attention(q, k, v, scale=scale, interpret=True, **kw)
    want = ref.flash_attention_ref(q, k, v, scale=scale, **kw)
    tol = 2e-2 if q.dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,t", [(128, 128), (128, 256), (256, 128)])
def test_flash_kernel_causal(dtype, s, t):
    if t < s:
        pytest.skip("queries beyond keys are fully masked")
    q, k, v = _qkv(jax.random.key(0), 2, s, t, 4, 4, 64, 64, dtype)
    _run(q, k, v, causal=True)


@pytest.mark.parametrize("g", [2, 4])
def test_flash_kernel_gqa(g):
    q, k, v = _qkv(jax.random.key(1), 1, 128, 256, 4, 4 // g, 32, 32,
                   jnp.float32)
    _run(q, k, v, causal=True)


def test_flash_kernel_window():
    q, k, v = _qkv(jax.random.key(2), 1, 256, 256, 2, 2, 64, 64, jnp.float32)
    _run(q, k, v, causal=True, window=100)


def test_flash_kernel_softcap():
    q, k, v = _qkv(jax.random.key(3), 1, 128, 128, 2, 2, 64, 64, jnp.float32)
    _run(q, k, v, causal=True, softcap=50.0)


def test_flash_kernel_non_causal():
    q, k, v = _qkv(jax.random.key(4), 1, 128, 256, 2, 2, 64, 128,
                   jnp.float32)
    _run(q, k, v, causal=False)


def test_flash_kernel_rejects_ragged():
    q, k, v = _qkv(jax.random.key(5), 1, 96, 128, 2, 2, 64, 64, jnp.float32)
    with pytest.raises(ValueError):
        ops.flash_attention(q, k, v, scale=0.125, interpret=True)
