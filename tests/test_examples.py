"""Smoke tests: every example must run end-to-end in quick mode.

The examples are the repo's user-facing surface; without this gate they
silently rot when the library API moves (exactly what happened to
``multipod_dryrun`` when ``Compiled.cost_analysis`` changed shape).  Each
runs as a subprocess with reduced sizes -- the same code paths, seconds
not minutes.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXAMPLES = {
    "quickstart": ["examples/quickstart.py", "--slots", "2000"],
    "serve_care": ["examples/serve_care.py", "--slots", "1000"],
    "serve_stream": [
        "examples/serve_stream.py",
        "--slots", "20000", "--chunk", "2048",
    ],
    "train_moe_care": [
        "examples/train_moe_care.py",
        "--steps", "6", "--batch", "2", "--seq", "32", "--ckpt-every", "2",
    ],
    "multipod_dryrun": [
        "examples/multipod_dryrun.py",
        "--arch", "qwen3-0.6b", "--shape", "train_4k", "--single-pod",
    ],
}

EXPECT = {
    "quickstart": "compiled programs",
    "serve_care": "ET dispatcher",
    "serve_stream": "steady-state JCT",
    "train_moe_care": "[done]",
    "multipod_dryrun": "compiles cleanly",
}


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(EXAMPLES))
def test_example_runs_quick(name):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    # multipod_dryrun forces its own 256/512-device host platform; the
    # others run on whatever the session provides.
    if name != "multipod_dryrun":
        env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable] + EXAMPLES[name],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert EXPECT[name] in proc.stdout, proc.stdout[-2000:]
