"""Behaviour tests for the CARE slotted simulator against the paper's claims."""
import jax
import numpy as np
import pytest

from repro.core import SimConfig, simulate
from repro.core.care import metrics, theory

KEY = jax.random.key(7)
T = 30_000


def _run(**kw):
    return simulate(KEY, SimConfig(slots=T, **kw))


class TestTheorem23:
    """DT-x / ET-x with basic or MSR-x: AQ <= x-1 and M <= D/x, always."""

    @pytest.mark.parametrize("comm", ["dt", "et"])
    @pytest.mark.parametrize("approx", ["basic", "msr_x"])
    @pytest.mark.parametrize("x", [2, 3, 5])
    def test_bounds(self, comm, approx, x):
        r = _run(load=0.9, policy="jsaq", comm=comm, approx=approx, x=x)
        assert r.max_aq <= x - 1
        assert r.messages <= r.departures / x + 1
        assert not r.overflow

    def test_et_any_emulation_bounded(self):
        # Prop 6.8: ET-x bounds AQ for ANY emulation algorithm, incl. MSR.
        for x in (2, 4):
            r = _run(load=0.95, policy="jsaq", comm="et", approx="msr", x=x)
            assert r.max_aq <= x - 1


class TestTheorem25:
    """ET-x + MSR: relative communication decays quadratically (heavy load)."""

    def test_quadratic_decay(self):
        rel = {}
        for x in (2, 4, 8):
            r = _run(load=0.95, policy="jsaq", comm="et", approx="msr", x=x)
            rel[x] = r.msgs_per_departure
        # Monotone and at least quadratically decreasing between x and 2x.
        assert rel[4] < rel[2] / 2.5
        assert rel[8] < rel[4] / 2.5
        # Paper abstract: error <= 2 (x=3) with < ~17% of full communication.
        r3 = _run(load=0.95, policy="jsaq", comm="et", approx="msr", x=3)
        assert r3.msgs_per_departure < theory.et_msr_relative_comm_backlogged(3)

    def test_below_heavy_load_bound(self):
        # Fig 6: measured communication is below the 1/(x^2-x) upper bound.
        for x in (3, 5):
            r = _run(load=0.95, policy="jsaq", comm="et", approx="msr", x=x)
            assert r.msgs_per_departure <= theory.et_msr_relative_comm_backlogged(x)


class TestPerformanceOrdering:
    """Fig 3: JSQ <= JSAQ(ET-3, MSR) <= SQ2-ish << RR << Random at high load."""

    @pytest.fixture(scope="class")
    def results(self):
        out = {}
        out["jsq"] = _run(load=0.95, policy="jsq", comm="none")
        out["jsaq"] = _run(load=0.95, policy="jsaq", comm="et", x=3, approx="msr")
        out["sq2"] = _run(load=0.95, policy="sq2", comm="none")
        out["rr"] = _run(load=0.95, policy="rr", comm="none")
        out["random"] = _run(load=0.95, policy="random", comm="none")
        return out

    def test_jsq_best(self, results):
        m = {k: metrics.jct_summary(v.jct)["mean"] for k, v in results.items()}
        assert m["jsq"] <= m["jsaq"] <= m["rr"]
        assert m["rr"] < m["random"]

    def test_jsaq_beats_sq2_with_sparse_comm(self, results):
        # The headline: JSAQ + ET-3 + MSR rivals SQ(2) using ~10% of the
        # communication JSQ needs (SQ(2) itself needs >= 1 msg/job).
        m_jsaq = metrics.jct_summary(results["jsaq"].jct)["mean"]
        m_sq2 = metrics.jct_summary(results["sq2"].jct)["mean"]
        assert m_jsaq <= m_sq2 * 1.10
        assert results["jsaq"].msgs_per_departure < 0.15

    def test_mass_conservation(self, results):
        for name, r in results.items():
            assert r.arrivals == r.departures + int(r.final_q.sum()), name


class TestApproximationSemantics:
    def test_basic_never_underestimates(self):
        # Basic approx >= true queue always  =>  JSAQ w/ basic + frequent DT
        # cannot misroute to a long queue believed short; check via max_aq==
        # deps-since-msg bound and via a direct invariant run.
        r = _run(load=0.8, policy="jsaq", comm="dt", approx="basic", x=3)
        assert r.max_aq <= 2

    def test_jsaq_equals_jsq_with_x1(self):
        # ET-1 forces a message on any error: approximations are exact at
        # slot ends, so JSAQ makes the same decisions as JSQ.
        r_jsaq = _run(load=0.9, policy="jsaq", comm="et", approx="msr", x=1)
        r_jsq = _run(load=0.9, policy="jsq", comm="none")
        m1 = metrics.jct_summary(r_jsaq.jct)["mean"]
        m2 = metrics.jct_summary(r_jsq.jct)["mean"]
        assert abs(m1 - m2) / m2 < 0.05
        assert r_jsaq.max_aq == 0

    def test_rt_has_no_deterministic_bound_but_tracks(self):
        r = _run(load=0.9, policy="jsaq", comm="rt", rt_rate=0.02, approx="msr")
        # No deterministic guarantee (Sec 6.2) -- just sanity: errors finite,
        # system stable.
        assert r.max_aq < r.max_queue + 1
        assert not r.overflow


class TestPullPolicies:
    """Server-initiated (pull) policies on the CARE comm core: JIQ and the
    hyper-scalable threshold policy ("hsq"), van der Boor et al. 2019.
    Tokens ride the same trigger/message accounting as the push kinds, so
    ``msgs_per_departure`` compares honestly against CARE ET/DT/RT."""

    @pytest.fixture(scope="class")
    def results(self):
        out = {}
        out["jiq"] = _run(load=0.9, policy="jiq", comm="jiq")
        out["hsq"] = _run(load=0.9, policy="hsq", comm="hsq", x=3,
                          rt_rate=0.02)
        out["et3"] = _run(load=0.9, policy="jsaq", comm="et", x=3,
                          approx="msr")
        out["random"] = _run(load=0.9, policy="random", comm="none")
        return out

    def test_pull_messages_at_most_one_per_job(self, results):
        # The defining communication bound of the pull family: a token is
        # only sent on an idleness (jiq) or threshold (hsq) transition --
        # at most one per completed job even counting hsq's periodic
        # refresh at these rates.
        for name in ("jiq", "hsq"):
            assert results[name].msgs_per_departure <= 1.0, name

    def test_jiq_beats_random_with_sparse_tokens(self, results):
        # At load 0.9 idle servers are rare, so most routings miss the
        # pool (the uniform fallback) -- yet the occasional token still
        # cuts mean JCT far below blind random routing.
        m_jiq = metrics.jct_summary(results["jiq"].jct)["mean"]
        m_rnd = metrics.jct_summary(results["random"].jct)["mean"]
        assert m_jiq < m_rnd * 0.25
        assert results["jiq"].token_misses > 0

    def test_hsq_within_et3_envelope(self, results):
        # The paper-adjacent headline this repo benchmarks: the
        # hyper-scalable policy holds the CARE ET-3 JCT envelope at load
        # 0.9 while staying within the <= 1 msg/job pull budget.
        m_hsq = metrics.jct_summary(results["hsq"].jct)["mean"]
        m_et3 = metrics.jct_summary(results["et3"].jct)["mean"]
        assert m_hsq <= m_et3 * 1.10

    def test_mass_conservation_and_counters(self, results):
        for name in ("jiq", "hsq"):
            r = results[name]
            assert r.arrivals == r.departures + int(r.final_q.sum()), name
            assert 0 <= r.token_misses <= r.arrivals, name
            assert r.token_sum >= 0, name


class TestConstrainedRouting:
    """Multi-class arrivals with per-class server-affinity masks."""

    GROUP_A = tuple([True] * 5 + [False] * 5)
    GROUP_B = tuple([False] * 5 + [True] * 5)

    def test_single_class_affinity_is_enforced(self):
        # One class pinned to the first half of the fleet: the masked-out
        # servers must see zero arrivals (this is the regression for the
        # silently-ignored (1, K) affinity).
        r = _run(servers=10, load=0.8, policy="jsaq", comm="et", x=3,
                 class_mix=(1.0,), class_affinity=(self.GROUP_A,))
        assert int(r.per_server_arrivals[5:].sum()) == 0
        assert int(r.per_server_arrivals[:5].sum()) == r.arrivals

    def test_balanced_two_class_split(self):
        r = _run(servers=10, load=0.8, policy="jsaq", comm="et", x=3,
                 class_mix=(0.5, 0.5),
                 class_affinity=(self.GROUP_A, self.GROUP_B))
        a = int(r.per_server_arrivals[:5].sum())
        b = int(r.per_server_arrivals[5:].sum())
        assert a > 0 and b > 0
        assert abs(a / (a + b) - 0.5) < 0.05

    def test_all_true_single_class_matches_classless_run(self):
        # A vacuous (all-eligible) mask must be decision-identical to the
        # historical classless program -- same JCT vector, same messages.
        base = _run(servers=10, load=0.9, policy="jsaq", comm="et", x=3)
        masked = _run(servers=10, load=0.9, policy="jsaq", comm="et", x=3,
                      class_mix=(1.0,),
                      class_affinity=(tuple([True] * 10),))
        np.testing.assert_array_equal(base.jct, masked.jct)
        assert base.messages == masked.messages

    def test_affinity_composes_with_pull_routing(self):
        r = _run(servers=10, load=0.7, policy="jiq", comm="jiq",
                 class_mix=(0.5, 0.5),
                 class_affinity=(self.GROUP_A, self.GROUP_B))
        assert int(r.per_server_arrivals[:5].sum()) > 0
        assert int(r.per_server_arrivals[5:].sum()) > 0
        assert r.arrivals == r.departures + int(r.final_q.sum())

    def test_empty_affinity_row_rejected(self):
        with pytest.raises(ValueError, match="no eligible server"):
            _run(servers=4, load=0.5, policy="jsaq", comm="et",
                 class_mix=(0.5, 0.5),
                 class_affinity=((True, True, False, False),
                                 (False, False, False, False)))

    def test_affinity_without_mix_rejected(self):
        with pytest.raises(ValueError, match="requires class_mix"):
            _run(servers=4, load=0.5, policy="jsaq", comm="et",
                 class_affinity=((True, True, True, True),))

    def test_pallas_backend_rejects_constrained_routing(self):
        with pytest.raises(NotImplementedError, match="affinity"):
            _run(servers=8, load=0.5, policy="jsaq", comm="et",
                 approx="msr", service="deterministic",
                 deterministic_ties=True, route_backend="pallas",
                 class_mix=(1.0,),
                 class_affinity=(tuple([True] * 4 + [False] * 4),))


class TestSSC:
    """Finite-n trend of Theorem 7.3: queue gap stays o(sqrt(n))."""

    def test_gap_shrinks_in_diffusion_scale(self):
        # n indexes the event rate; in slot units we scale horizon and mean
        # service together, keeping per-unit-time rates Theta(n).
        gaps = []
        for n, slots in [(1, 20_000), (4, 80_000)]:
            cfg = SimConfig(
                servers=10,
                slots=slots,
                load=0.95,
                mean_service=10 * n,
                policy="jsaq",
                comm="et",
                x=2,
                approx="msr",
            )
            r = simulate(KEY, cfg)
            gaps.append(r.queue_gap_sup / np.sqrt(n))
        assert gaps[1] <= gaps[0] * 1.5  # scaled gap does not blow up
