"""Theory-vs-empirics: Thm 2.5's O(1/x^2) ET-x message-frequency scaling.

``repro.core.care.theory`` states the paper's closed forms; these tests
check them against *measured* message rates from short runs of two tiers:

* the slotted simulator (the paper's own Section 9 setting, heavy load --
  the backlogged regime Thm 2.5 assumes), and
* the serving engine (continuous batching with the MSR drain matched to
  the nominal per-replica completion rate).

Thm 2.5 upper-bounds the relative communication of ET-x + MSR by
``1/(x^2 - x)``, so measurements must sit below the bound -- but "matching
the theory" also means the *scale* and *decay* are right, not just the
inequality: each measured point must be within an order of magnitude of
the bound curve, and the fitted log-log slope must be O(1/x^2)-compatible
(measured ~ -2.7 slotted / ~ -2.4 serving on the pinned seeds; a 1/x law
would fit ~ -1, exponential collapse far steeper than -3.5).

Everything here is deterministic: fixed seeds, fused grids (the x ladder
is a traced operand, so each tier compiles one program).
"""
import numpy as np
import pytest

from repro.core.care import slotted_sim, theory
from repro.serve import engine

# Long-horizon measured ladders: part of the full suite, skipped by the
# fast tier-1 gate (pytest -m "not slow").
pytestmark = pytest.mark.slow


def _loglog_slope(xs, ys) -> float:
    return float(np.polyfit(np.log(np.asarray(xs, float)),
                            np.log(np.asarray(ys, float)), 1)[0])


class TestTheoryCurves:
    def test_bound_shapes(self):
        xs = np.array([2, 3, 5, 8, 16])
        b = theory.et_msr_relative_comm_backlogged(xs)
        assert np.all(np.diff(b) < 0)
        # Asymptotically 1/(x^2 - x) is exactly quadratic decay.
        assert _loglog_slope(xs[2:], b[2:]) == pytest.approx(-2.0, abs=0.15)
        np.testing.assert_allclose(
            theory.headline_relative_comm(xs - 1), b, rtol=1e-12
        )


class TestSlottedEmpirics:
    XS = (3, 4, 6, 8)

    @pytest.fixture(scope="class")
    def rel_comm(self):
        cells = [
            slotted_sim.SimConfig(
                slots=20_000, comm="et", approx="msr", x=x, load=0.95
            )
            for x in self.XS
        ]
        grid = slotted_sim.simulate_grid(
            [7], cells[0].static_part(), [c.scenario() for c in cells]
        )
        return [
            row[0].messages / max(row[0].departures, 1) for row in grid
        ]

    def test_measured_below_thm25_bound(self, rel_comm):
        for x, rel in zip(self.XS, rel_comm):
            assert rel <= theory.et_msr_relative_comm_backlogged(x)

    def test_measured_matches_bound_scale(self, rel_comm):
        # Within an order of magnitude of the bound curve: the 1/(x^2 - x)
        # prediction is the right magnitude, not just a loose ceiling.
        for x, rel in zip(self.XS, rel_comm):
            assert rel >= theory.et_msr_relative_comm_backlogged(x) / 10.0

    def test_message_frequency_decays_quadratically(self, rel_comm):
        slope = _loglog_slope(self.XS, rel_comm)
        assert -3.5 <= slope <= -1.5


class TestServingEmpirics:
    XS = (2, 4, 8)

    @pytest.fixture(scope="class")
    def rel_comm(self):
        # decode_slots / (mean_prefill + mean_decode) = 16/64 = 0.25: the
        # MSR drain equals the nominal per-replica completion rate, the
        # serving analogue of the theorem's mean-service emulation.
        cells = [
            engine.ServeConfig(
                replicas=8, decode_slots=16, slots=6_000, load=0.95,
                comm="et", x=x, mean_prefill=4, mean_decode=60,
                msr_drain=0.25,
            )
            for x in self.XS
        ]
        grid = engine.serve_grid([0], cells[0].static_part(), cells)
        return [row[0].msgs_per_completion for row in grid]

    def test_measured_below_thm25_bound(self, rel_comm):
        for x, rel in zip(self.XS, rel_comm):
            assert rel <= theory.et_msr_relative_comm_backlogged(x)

    def test_measured_matches_bound_scale(self, rel_comm):
        for x, rel in zip(self.XS, rel_comm):
            assert rel >= theory.et_msr_relative_comm_backlogged(x) / 10.0

    def test_message_frequency_decays_quadratically(self, rel_comm):
        slope = _loglog_slope(self.XS, rel_comm)
        assert -3.5 <= slope <= -1.5


class TestServingRateAwareEmpirics:
    """The rate-aware ET ladder: Thm 2.5's decay under 2:1 rate asymmetry.

    The theorem is stated for homogeneous servers; the ROADMAP's
    "heterogeneous-rate theory" item asks whether the communication
    scaling survives rate asymmetry.  Empirical half, serving tier:
    drain-time-aware JSAQ over 2:1 ``decode_rates`` (half the replicas
    double speed), MSR drain scaled per replica to its nominal completion
    rate (msr_drain * r_i).  The measured ET-x message rate must still
    sit below the homogeneous 1/(x^2 - x) bound, stay within an order of
    magnitude of it, and decay with an O(1/x^2)-compatible log-log slope
    (~ -2.3 on the pinned seed).
    """

    XS = (2, 4, 8)
    RATES_21 = (2.0,) * 4 + (1.0,) * 4  # 2:1 replica speeds, mean 1.5

    @pytest.fixture(scope="class")
    def rel_comm(self):
        cells = [
            engine.ServeConfig(
                replicas=8, decode_slots=16, slots=6_000, load=0.95,
                comm="et", x=x, mean_prefill=4, mean_decode=60,
                msr_drain=0.25, policy="drain", decode_rates=self.RATES_21,
            )
            for x in self.XS
        ]
        # One compiled program: x *and* the rate profile are traced.
        grid = engine.serve_grid([0], cells[0].static_part(), cells)
        return [row[0].msgs_per_completion for row in grid]

    def test_measured_below_thm25_bound(self, rel_comm):
        for x, rel in zip(self.XS, rel_comm):
            assert rel <= theory.et_msr_relative_comm_backlogged(x)

    def test_measured_matches_bound_scale(self, rel_comm):
        for x, rel in zip(self.XS, rel_comm):
            assert rel >= theory.et_msr_relative_comm_backlogged(x) / 10.0

    def test_message_frequency_decays_quadratically(self, rel_comm):
        slope = _loglog_slope(self.XS, rel_comm)
        assert -3.5 <= slope <= -1.5
